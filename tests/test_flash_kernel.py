"""Pallas flash-attention kernel vs jnp oracle (interpret mode on CPU):
forward + gradients, causal + non-causal, GQA grouping, shape sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as FA
from repro.models import layers as L


def _mk(B, Sq, Skv, H, KH, hd, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KH, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KH, hd), dtype)
    return q, k, v


def _to_kernel_layout(q, k, v):
    """[B,S,H,hd] -> q [B*H, S, hd] grouped so head bh // rep = kv head."""
    B, Sq, H, hd = q.shape
    KH = k.shape[2]
    rep = H // KH
    qg = q.reshape(B, Sq, KH, rep, hd).transpose(0, 2, 3, 1, 4)
    qf = qg.reshape(B * KH * rep, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KH, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KH, v.shape[1], hd)
    return qf, kf, vf, rep


def _from_kernel_layout(of, B, S, H, hd, KH):
    rep = H // KH
    return of.reshape(B, KH, rep, S, hd).transpose(0, 3, 1, 2, 4) \
             .reshape(B, S, H, hd)


@pytest.mark.parametrize("shape", [
    (1, 128, 128, 2, 2, 32),     # MHA
    (2, 256, 256, 4, 2, 16),     # GQA rep=2
    (1, 128, 128, 8, 2, 64),     # GQA rep=4
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_fwd_matches_oracle(shape, causal):
    B, Sq, Skv, H, KH, hd = shape
    q, k, v = _mk(B, Sq, Skv, H, KH, hd)
    qf, kf, vf, rep = _to_kernel_layout(q, k, v)
    o = FA.flash_attention_pallas(qf, kf, vf, causal, 64, 64, rep, True)
    got = _from_kernel_layout(o, B, Sq, H, hd, KH)
    want = L.full_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_grads_match_oracle():
    B, S, H, KH, hd = 1, 128, 4, 2, 32
    q, k, v = _mk(B, S, S, H, KH, hd, seed=3)
    qf, kf, vf, rep = _to_kernel_layout(q, k, v)

    def loss_kernel(qf, kf, vf):
        o = FA.flash_attention_pallas(qf, kf, vf, True, 64, 64, rep, True)
        return jnp.sum(o * o)

    def loss_ref(q, k, v):
        o = L.full_attention(q, k, v, causal=True)
        return jnp.sum(o * o)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(qf, kf, vf)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    gq = _from_kernel_layout(gk[0], B, S, H, hd, KH)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gr[0]),
                               rtol=5e-4, atol=5e-4, err_msg="dq")
    dk = gk[1].reshape(B, KH, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(gr[1]),
                               rtol=5e-4, atol=5e-4, err_msg="dk")
    dv = gk[2].reshape(B, KH, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(gr[2]),
                               rtol=5e-4, atol=5e-4, err_msg="dv")


def test_flash_bf16_inputs():
    B, S, H, KH, hd = 1, 128, 2, 2, 32
    q, k, v = _mk(B, S, S, H, KH, hd, dtype=jnp.bfloat16)
    qf, kf, vf, rep = _to_kernel_layout(q, k, v)
    o = FA.flash_attention_pallas(qf, kf, vf, True, 64, 64, rep, True)
    assert o.dtype == jnp.bfloat16
    want = L.full_attention(q, k, v, causal=True)
    got = _from_kernel_layout(o, B, S, H, hd, KH)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)
