"""Seqlock stress tests for the shm map plane (DESIGN.md §10).

A writer PROCESS republishes device snapshots in a tight loop while the
reader polls concurrently: a successful snapshot must never surface a torn
read (the all-equal invariant the writer maintains holds on every read, the
observed sequence number is always even, and the retry budget is never
exhausted). Also covers the aggregator's failure rules: a killed worker is
detected via its registered pid and excluded from the merge while its
already-merged contribution stays; a worker stuck mid-publish (odd seqlock)
is demoted to stale for the cycle, not crashed on.

The two multi-process tests are marked slow (deselected from tier-1, run by
CI's bench job via `pytest -m slow`); the in-process seqlock tests stay
tier-1.
"""
import multiprocessing as mp
import os
import signal

import numpy as np
import pytest

import waiters
from repro.core import daemon as D, maps as M, shm as SH

SPECS = [
    M.MapSpec("arr", M.MapKind.ARRAY, max_entries=64),
    M.MapSpec("hist", M.MapKind.LOG2HIST),
]

N_READS = 200
RETRY_BUDGET = 2000          # 1ms backoff per retry — 2s worst case per read


def _writer_main(root: str, specs, stop_file: str) -> None:
    """Republish as fast as possible; every publish keeps each map
    internally all-equal to a monotonically increasing counter, so any torn
    read is detectable as a mixed-value snapshot."""
    region = SH.ShmRegion.create(root, specs, worker_id="w0")
    st = M.init_states(specs, np)
    i = 0
    while not os.path.exists(stop_file):
        i += 1
        st["arr"]["values"][:] = i
        st["hist"]["bins"][:] = 3 * i + 1
        region.publish_device(st)


def _victim_main(root: str, specs, ready_file: str) -> None:
    region = SH.ShmRegion.create(root, specs, worker_id="victim")
    st = M.init_states(specs, np)
    st["arr"]["values"][7] = 123
    region.publish_device(st)
    with open(ready_file, "w") as f:
        f.write("ok")
    waiters.park()           # parent SIGKILLs us


@pytest.mark.slow
def test_no_torn_reads_under_republish_storm(tmp_path):
    root = str(tmp_path / "shm")
    stop = str(tmp_path / "stop")
    ctx = mp.get_context("spawn")
    p = ctx.Process(target=_writer_main, args=(root, SPECS, stop))
    p.start()
    try:
        waiters.wait_for(lambda: "w0" in SH.list_workers(root),
                         msg="worker dir")
        region = SH.ShmRegion.attach(root, mode="r", worker_id="w0")
        # wait until the writer is actually publishing
        waiters.wait_for(lambda: int(region.seq[0]) > 2,
                         msg="first publishes")

        max_retries = 0
        last = {"arr": 0, "hist": 0}
        for _ in range(N_READS):
            for name, field, of in (("arr", "values", None),
                                    ("hist", "bins", None)):
                st, seq, retries = region.snapshot_device_meta(
                    name, retries=RETRY_BUDGET)
                assert seq % 2 == 0, f"torn read surfaced: odd seq {seq}"
                vals = st[field]
                assert (vals == vals.flat[0]).all(), \
                    f"torn read surfaced: mixed {name} snapshot {vals}"
                # counters only move forward
                cur = int(vals.flat[0])
                assert cur >= last[name], f"{name} went backwards"
                last[name] = cur
                max_retries = max(max_retries, retries)
        assert last["arr"] > 0, "never observed a publish"
        # bounded retries: the even window must be reachable well inside
        # the budget even under a storm of publishes
        assert max_retries < RETRY_BUDGET // 4, \
            f"retry pressure too high: {max_retries}"
    finally:
        with open(stop, "w") as f:
            f.write("stop")
        exitcode = waiters.wait_for_exit(p)
    assert exitcode == 0


@pytest.mark.slow
def test_killed_worker_detected_and_excluded(tmp_path):
    root = str(tmp_path / "shm")
    ready = str(tmp_path / "ready")
    ctx = mp.get_context("spawn")
    p = ctx.Process(target=_victim_main, args=(root, SPECS, ready))
    p.start()
    try:
        waiters.wait_for_path(ready)
        agg = D.Aggregator(root)
        status = agg.poll_once()
        assert status["alive"] == ["victim"] and status["dead"] == []
        g = SH.GlobalView.attach(root)
        assert int(g.snapshot("arr")["values"][7]) == 123

        os.kill(p.pid, signal.SIGKILL)
        waiters.wait_for_exit(p)
        status = agg.poll_once()
        # dead: harvested once, then excluded from polling forever
        assert status["dead"] == ["victim"] and status["alive"] == []
        # the already-merged contribution stays in the global view
        assert int(g.snapshot("arr")["values"][7]) == 123
        status = agg.poll_once()
        assert status["dead"] == ["victim"] and status["alive"] == []
    finally:
        if p.is_alive():          # pragma: no cover - cleanup path
            p.kill()
            p.join()


# ---------------------------------------------------------------- in-process

def test_snapshot_meta_reports_even_seq_and_retries(tmp_path):
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][:] = 9
    region.publish_device(st)
    out, seq, retries = region.snapshot_device_meta("arr")
    assert seq == 2 and seq % 2 == 0 and retries == 0
    np.testing.assert_array_equal(out["values"], 9)


def test_stale_seqlock_worker_skipped_not_crashed(tmp_path):
    """A worker stuck mid-publish (odd seqlock) forfeits only that cycle:
    the aggregator marks it stale, keeps its baseline, and never surfaces
    the half-written data; once the seqlock settles the worker rejoins."""
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][0] = 5
    region.publish_device(st)

    agg = D.Aggregator(root, snapshot_retries=3)
    status = agg.poll_once()
    assert status["stale"] == []
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][0]) == 5

    # crash mid-publish: odd seqlock + half-written garbage in the section
    region.seq[0] += 1
    region.device["arr"]["values"][0] = 999
    status = agg.poll_once()
    assert status["stale"] == ["w0"]
    assert int(g.snapshot("arr")["values"][0]) == 5   # garbage never merged

    # publish completes: worker rejoins, the now-consistent data merges.
    # publish_device self-heals the stuck-odd parity (no extra odd flip)
    # and rewrites the section checksums over the recovered content
    st["arr"]["values"][0] = 6
    region.publish_device(st)
    assert int(region.seq[0]) % 2 == 0
    status = agg.poll_once()
    assert status["stale"] == []
    assert int(g.snapshot("arr")["values"][0]) == 6
