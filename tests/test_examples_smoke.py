"""Examples can't silently rot: run them as real subprocesses and require a
zero exit code (each example asserts its own end-to-end invariants and exits
non-zero on failure). Marked slow — deselected from tier-1, run by CI's
bench job via `pytest -m slow`."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(name, tmp_path, extra_env=None):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_REPO, "src"),
               **(extra_env or {}))
    out = subprocess.run(
        [sys.executable, os.path.join(_REPO, "examples", name)],
        capture_output=True, text=True, env=env, cwd=str(tmp_path),
        timeout=1200)
    assert out.returncode == 0, \
        f"{name} exited {out.returncode}\n--- stdout\n{out.stdout[-2000:]}" \
        f"\n--- stderr\n{out.stderr[-2000:]}"
    return out.stdout


def test_trace_training_live_inject(tmp_path):
    out = _run_example("trace_training.py", tmp_path,
                       {"BPFTIME_SHM": str(tmp_path / "shm")})
    assert "did NOT restart" in out
    assert "jit cache of the running step stayed 1" in out


def test_opensnoop_syscalls(tmp_path):
    out = _run_example("opensnoop_syscalls.py", tmp_path)
    assert "latest committed checkpoint: step 8" in out
    assert "OK" in out


def test_fleet_agg_multiprocess(tmp_path):
    """3 worker processes, one daemon-merged global histogram (the
    interprocess map plane, DESIGN.md §10)."""
    out = _run_example("fleet_agg.py", tmp_path)
    assert "global total=768 (= 3 workers x 256 events)" in out
    assert "OK: global histogram is the exact bin-wise sum" in out
    assert "12 workers -> 3 node aggregators (fan-in 4)" in out
    assert "OK: hierarchical tree view is bit-identical to the flat merge" in out


def test_chaos_drill_multiprocess(tmp_path):
    """3-worker fleet, one SIGKILLed mid-publish, daemon crashed at an
    injected boundary and restarted from the fold journal; global view
    converges to the oracle (DESIGN.md §11)."""
    out = _run_example("chaos_drill.py", tmp_path)
    assert "SIGKILLed mid-publish (seqlock left odd)" in out
    assert "daemon restarted from the fold journal" in out
    assert "OK: global view converged to the oracle" in out
    assert "OK: chaos drill survived worker SIGKILL + daemon crash" in out
