"""Sharded global hash views (DESIGN.md §15): property + stress tests.

Each shard is its own seqlocked + CRC'd mini-section holding exactly the
keys whose home slot is congruent to it. Invariants pinned here:

  * partition completeness: the shards are a disjoint cover of the global
    table's reachable content, with every key in the shard
    n_shard_of_key says (the reader's routing function);
  * per-shard torn-read contract under a republish storm: observed seq
    always even, payload never mixed, retry budget never approached;
  * isolation: the aggregator republishes ONLY dirty shards, so a reader
    polling shard A never retries against traffic on shard B;
  * corruption detect-and-skip: bytes flipped after the CRC was written
    surface as SnapshotCorruption, never as a silently wrong table.
"""
import multiprocessing as mp
import os

import numpy as np
import pytest

import waiters
from repro.core import daemon as D, maps as M, shm as SH
from test_shm_merge_differential import (SPECS, apply_event, gen_tape)

HSH = next(s for s in SPECS if s.kind == M.MapKind.HASH)


def _fleet_with_shards(root, tape, n_workers, n_shards):
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(n_workers)}
    states = {w: M.init_states(SPECS, np) for w in range(n_workers)}
    for step, w, _, ev in tape:
        apply_event(states[w], ev, step)
    for w in range(n_workers):
        regions[w].publish_device(states[w])
    agg = D.Aggregator(root,
                       config=D.AggregatorConfig(hash_shards=n_shards))
    agg.poll_once()
    return agg, regions, states


@pytest.mark.parametrize("n_shards", [1, 2, 4, 7])
@pytest.mark.parametrize("seed", [0, 3])
def test_shards_partition_global_content(tmp_path, n_shards, seed):
    root = str(tmp_path / "shm")
    rng = np.random.default_rng(seed)
    tape = gen_tape(rng, 3, n_events=150,
                    ops=("hash_add", "hash_set", "hash_del"))
    agg, _, _ = _fleet_with_shards(root, tape, 3, n_shards)

    shards = SH.HashShards.attach(root)
    want = M.n_hash_items(agg.hash_tbl[HSH.name])
    union: dict = {}
    for s in range(n_shards):
        st, seq, retries = shards.snapshot(HSH.name, s)
        assert seq % 2 == 0 and retries == 0
        items = M.n_hash_items(st)
        for k, v in items.items():
            # disjointness + routing: each key in exactly the shard the
            # reader-side routing function names
            assert k not in union
            assert M.n_shard_of_key(k, HSH.max_entries, n_shards) == s
            union[k] = v
    assert union == want            # completeness


def test_only_dirty_shards_republish(tmp_path):
    """Isolation: touching keys of one shard must not bump the seqlock of
    any other shard (a polling reader on a quiet shard sees zero write
    traffic)."""
    root = str(tmp_path / "shm")
    n_shards = 4
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    # one key per shard
    keys = {}
    for k in range(200):
        s = M.n_shard_of_key(k, HSH.max_entries, n_shards)
        if s not in keys:
            keys[s] = k
        if len(keys) == n_shards:
            break
    assert len(keys) == n_shards
    for s, k in keys.items():
        M.n_hash_update(st["hsh"], k, 1)
    region.publish_device(st)
    agg = D.Aggregator(root,
                       config=D.AggregatorConfig(hash_shards=n_shards))
    agg.poll_once()
    shards = SH.HashShards.attach(root)
    seqs0 = {s: shards.snapshot(HSH.name, s)[1] for s in range(n_shards)}
    publishes0 = agg.shard_publishes

    # touch ONLY shard 0's key
    M.n_hash_update(st["hsh"], keys[0], 5)
    region.publish_device(st)
    agg.poll_once()
    seqs1 = {s: shards.snapshot(HSH.name, s)[1] for s in range(n_shards)}
    assert seqs1[0] > seqs0[0]
    for s in range(1, n_shards):
        assert seqs1[s] == seqs0[s], f"quiet shard {s} republished"
    assert agg.shard_publishes == publishes0 + 1

    # a no-op cycle republishes nothing at all
    agg.poll_once()
    assert agg.shard_publishes == publishes0 + 1
    assert {s: shards.snapshot(HSH.name, s)[1]
            for s in range(n_shards)} == seqs1


def test_shard_corruption_detected_never_served(tmp_path):
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    M.n_hash_update(st["hsh"], 3, 9)
    region.publish_device(st)
    agg = D.Aggregator(root, config=D.AggregatorConfig(hash_shards=2))
    agg.poll_once()
    s = M.n_shard_of_key(3, HSH.max_entries, 2)
    shards = SH.HashShards.attach(root)
    st0, seq0, _ = shards.snapshot(HSH.name, s)
    assert M.n_hash_items(st0) == {3: 9}

    # flip payload bytes AFTER the CRC was written (consistent seq):
    # corrupt through the file, the reader's attach is read-only
    d = os.path.join(SH.HashShards._dir(root), HSH.name, str(s))
    fn = next(f for f in sorted(os.listdir(d))
              if f.endswith(".npy") and not f.startswith("."))
    arr = np.lib.format.open_memmap(os.path.join(d, fn), mode="r+")
    arr.reshape(-1).view(np.uint8)[0] ^= 0xA5
    arr.flush()

    with pytest.raises(SH.SnapshotCorruption):
        shards.snapshot(HSH.name, s)


# --------------------------------------------------------------------------
# republish storm: writers vs polling readers (real processes)
# --------------------------------------------------------------------------

N_READS = 150
RETRY_BUDGET = 2000


def _storm_writer(root, stop_file):
    """Republish every shard as fast as possible; iteration i writes value
    i to every key, so any torn read surfaces as a mixed-value table."""
    shards = SH.HashShards.attach(root)
    # writer needs r+ sections: reopen in create mode over the same files
    shards = SH.HashShards.create(root, SH.read_meta_specs(root),
                                  shards.n_shards)
    n_shards = shards.n_shards
    by_shard = {s: [] for s in range(n_shards)}
    for k in range(64):
        s = M.n_shard_of_key(k, HSH.max_entries, n_shards)
        if len(by_shard[s]) < 2:
            by_shard[s].append(k)
    i = 0
    while not os.path.exists(stop_file):
        i += 1
        for s in range(n_shards):
            state = M.n_hash_canonical(
                HSH, {k: i for k in by_shard[s]})
            shards.publish(HSH.name, s, state)


@pytest.mark.slow
def test_no_torn_shard_reads_under_republish_storm(tmp_path):
    root = str(tmp_path / "shm")
    SH.ShmRegion.create(root, SPECS, worker_id="w0")
    n_shards = 3
    SH.HashShards.create(root, SPECS, n_shards)
    stop = str(tmp_path / "stop")
    ctx = mp.get_context("spawn")
    p = ctx.Process(target=_storm_writer, args=(root, stop))
    p.start()
    try:
        shards = SH.HashShards.attach(root)
        waiters.wait_for(
            lambda: any(shards.snapshot(HSH.name, s, retries=RETRY_BUDGET)[1]
                        > 0 for s in range(n_shards)),
            msg="first shard publish")
        max_retries = 0
        last = {s: 0 for s in range(n_shards)}
        for _ in range(N_READS):
            for s in range(n_shards):
                st, seq, retries = shards.snapshot(
                    HSH.name, s, retries=RETRY_BUDGET)
                assert seq % 2 == 0, f"torn shard read: odd seq {seq}"
                vals = set(M.n_hash_items(st).values())
                assert len(vals) <= 1, \
                    f"torn shard read: mixed values {vals}"
                if vals:
                    cur = vals.pop()
                    assert cur >= last[s], f"shard {s} went backwards"
                    last[s] = cur
                max_retries = max(max_retries, retries)
        assert any(v > 0 for v in last.values()), "never saw a publish"
        assert max_retries < RETRY_BUDGET // 4, \
            f"retry pressure too high: {max_retries}"
    finally:
        with open(stop, "w") as f:
            f.write("stop")
        exitcode = waiters.wait_for_exit(p)
    assert exitcode == 0
