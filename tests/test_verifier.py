"""SP1 security tests: the verifier must reject each class of unsafe
program (paper §5.2 — the userspace verifier's guarantees)."""
import pytest

from repro.core import asm, isa, verifier
from repro.core.maps import MapKind, MapSpec
from repro.core.verifier import VerifierError

ARR = MapSpec("a", MapKind.ARRAY, max_entries=8)
HASH = MapSpec("h", MapKind.HASH, max_entries=8)
HIST = MapSpec("hist", MapKind.LOG2HIST)


def reject(text, match, specs=()):
    a = asm.assemble(text)
    with pytest.raises(VerifierError, match=match):
        verifier.verify(a.insns, list(specs))


def accept(text, specs=()):
    a = asm.assemble(text)
    return verifier.verify(a.insns, list(specs))


def test_reject_uninit_reg_read():
    reject("mov r0, r3\nexit", "uninitialized r3")


def test_reject_r0_unset_at_exit():
    reject("mov r2, 1\nexit", "uninitialized r0")


def test_reject_write_to_r10():
    reject("mov r10, 0\nmov r0, 0\nexit", "frame pointer")


def test_reject_stack_oob_write():
    reject("mov r1, 1\nstxdw [r10+0], r1\nmov r0, 0\nexit", "out of bounds")
    reject("mov r1, 1\nstxdw [r10-520], r1\nmov r0, 0\nexit",
           "out of bounds")


def test_reject_uninit_stack_read():
    reject("ldxdw r0, [r10-8]\nexit", "uninitialized stack")


def test_partial_stack_init_read_rejected():
    reject("""
        mov r2, 1
        stxw [r10-8], r2     ; only 4 bytes initialized
        ldxdw r0, [r10-8]    ; reads 8
        exit
    """, "uninitialized stack")


def test_reject_ctx_write():
    reject("mov r2, 1\nstxdw [r1+0], r2\nmov r0, 0\nexit", "read-only ctx")


def test_reject_ctx_oob_read():
    reject("ldxdw r0, [r1+512]\nexit", "out of bounds")


def test_reject_unaligned_ctx_read():
    reject("ldxdw r0, [r1+4]\nexit", "unaligned")


def test_reject_variable_ptr_arith():
    reject("""
        ldxdw r2, [r1+0]
        mov r3, r10
        add r3, r2          ; variable offset
        ldxdw r0, [r3+0]
        exit
    """, "variable pointer")


def test_reject_ptr_on_32bit_alu():
    reject("mov r2, r10\nadd32 r2, -8\nmov r0, 0\nexit",
           "32-bit arithmetic on pointer")


def test_reject_ptr_plus_ptr():
    reject("mov r2, r10\nadd r2, r1\nmov r0, 0\nexit", "pointer")


def test_reject_ptr_compare():
    reject("jgt r10, 5, l\nl:\nmov r0, 0\nexit", "comparison on pointer")


def test_reject_ptr_spill():
    reject("mov r2, r10\nstxdw [r10-8], r2\nmov r0, 0\nexit", "spilling")


def test_reject_unknown_helper():
    reject("call 9999\nexit", "unknown helper")


def test_reject_nonconst_map_fd():
    reject("""
        ldxdw r6, [r1+0]
        mov r1, r6
        mov r2, r10
        add r2, -8
        mov r3, 0
        stxdw [r10-8], r3
        mov r2, r10
        add r2, -8
        call map_lookup_elem
        exit
    """, "compile-time constant", specs=[ARR])


def test_reject_bad_map_fd():
    reject("""
        mov r3, 0
        stxdw [r10-8], r3
        mov r1, 5
        mov r2, r10
        add r2, -8
        call map_lookup_elem
        exit
    """, "out of range", specs=[ARR])


def test_reject_wrong_map_kind():
    reject("""
        mov r1, 0
        mov r2, 7
        call hist_add
        mov r0, 0
        exit
    """, "not allowed", specs=[ARR])


def test_reject_helper_key_not_pointer():
    reject("""
        mov r1, 0
        mov r2, 42
        call map_lookup_elem
        exit
    """, "stack pointer", specs=[ARR])


def test_reject_ringbuf_bad_size():
    rb = MapSpec("rb", MapKind.RINGBUF, max_entries=4, rec_width=2)
    reject("""
        mov r6, 1
        stxdw [r10-8], r6
        mov r1, 0
        mov r2, r10
        add r2, -8
        mov r3, 24          ; > 8*rec_width
        mov r4, 0
        call ringbuf_output
        exit
    """, "invalid", specs=[rb])


def test_reject_fall_off_end():
    reject("mov r0, 1", "falls off end")


def test_reject_cond_jump_off_end():
    a = asm.assemble("mov r0, 1\njeq r0, 1, 5\nexit")
    with pytest.raises(VerifierError):
        verifier.verify(a.insns, [])


def test_reject_jump_into_lddw_middle():
    insns = [
        isa.Insn(isa.BPF_JMP | isa.BPF_JA, off=1),          # into lddw slot 2
        isa.Insn(isa.BPF_LD | isa.BPF_IMM | isa.BPF_DW, dst=0, imm64=7),
        isa.Insn(isa.BPF_JMP | isa.BPF_EXIT),
    ]
    with pytest.raises(VerifierError, match="invalid slot"):
        verifier.verify(insns, [])


def test_reject_conflicting_ptr_offsets_at_join():
    reject("""
        ldxdw r2, [r1+0]
        mov r4, 7
        stxdw [r10-8], r4
        stxdw [r10-16], r4
        mov r3, r10
        jeq r2, 0, same
        add r3, -16
        ja go
        same:
        add r3, -8
        go:
        ldxdw r0, [r3+0]    ; r3 offset differs across paths
        exit
    """, "conflicting")


def test_reject_empty_and_too_long():
    with pytest.raises(VerifierError, match="empty"):
        verifier.verify([], [])
    insns = [isa.Insn(isa.BPF_ALU64 | isa.BPF_MOV, dst=0, imm=1)] * 5000
    with pytest.raises(VerifierError, match="too long"):
        verifier.verify(insns, [])


def test_accept_loop_marks_tier2():
    v = accept("""
        mov r6, 5
        mov r0, 0
        l:
        add r0, 1
        sub r6, 1
        jgt r6, 0, l
        exit
    """)
    assert v.tier == "loop"


def test_accept_dag_marks_tier1():
    v = accept("""
        mov r0, 0
        jeq r0, 0, l
        add r0, 1
        l:
        exit
    """)
    assert v.tier == "dag"


def test_const_join_widens_to_scalar():
    # same-register different consts across paths: usable as scalar
    accept("""
        ldxdw r2, [r1+0]
        jeq r2, 0, a
        mov r3, 1
        ja go
        a:
        mov r3, 2
        go:
        mov r0, r3
        add r0, 1
        exit
    """)
