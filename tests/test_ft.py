"""Fault tolerance: heartbeats, straggler detection, elastic planning,
restart-from-checkpoint with injected failures, elastic reshard restore."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.ckpt import checkpoint as CK
from repro.data.pipeline import SyntheticDataset
from repro.ft import fault_tolerance as FT
from repro.train.train_step import init_train_state, make_train_step

CFG = registry.smoke("qwen2-0.5b")


def test_heartbeat_dead_detection():
    hb = FT.HeartbeatMonitor(num_hosts=4, timeout_s=10.0,
                             clock=lambda: 100.0)
    for h in (0, 1, 3):
        hb.beat(h, t=95.0)
    hb.beat(2, t=80.0)          # stale
    assert hb.dead(now=100.0) == [2]
    hb.beat(2, t=99.0)
    assert hb.dead(now=100.0) == []


def test_straggler_detection():
    rng = np.random.default_rng(0)
    times = np.abs(rng.normal(1.0, 0.05, (8, 20)))
    times[5] *= 2.5             # straggler
    assert FT.detect_stragglers(times) == [5]
    assert FT.detect_stragglers(times[:, :2]) == []   # too few samples


def test_elastic_plan():
    p = FT.plan_elastic((16, 16), 0)
    assert p.action == "continue"
    p = FT.plan_elastic((16, 16), 16)
    assert p.action == "reshard" and p.new_shape == (15, 16)
    p = FT.plan_elastic((2, 16, 16), 40)
    assert p.action == "reshard" and p.new_shape == (1, 29, 16)
    p = FT.plan_elastic((16, 16), 255)
    assert p.action == "halt"


def test_supervisor_restart_from_checkpoint(tmp_path):
    """Inject a failure mid-training; the supervisor restores the latest
    checkpoint and training completes with the right final step."""
    tcfg = TrainConfig(warmup=2)
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    step = jax.jit(make_train_step(CFG, tcfg))
    data = SyntheticDataset(CFG, ShapeConfig("f", 32, 4, "train"), tcfg)
    like = jax.eval_shape(lambda: init_train_state(
        jax.random.PRNGKey(0), CFG, tcfg))

    sup = FT.TrainSupervisor(str(tmp_path), save_every=5, max_restarts=2)
    CK.save(str(tmp_path), 0, state)
    fails = {12}

    def failure_hook(step_no):
        if step_no in fails:
            fails.discard(step_no)
            raise FT._Injected(f"host died at step {step_no}")

    final = sup.run(
        state, step, data.next, total_steps=20,
        save_fn=lambda s, st: CK.save(str(tmp_path), s, st),
        restore_fn=lambda: CK.restore(str(tmp_path),
                                      CK.latest(str(tmp_path)), like),
        failure_hook=failure_hook)
    assert int(final["step"]) == 20
    assert sup.restarts == 1


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint saved from one mesh restores onto a different mesh
    (shrunk data axis) with identical values."""
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    tcfg = TrainConfig()
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    CK.save(str(tmp_path), 1, state)

    like = jax.eval_shape(lambda: init_train_state(
        jax.random.PRNGKey(0), CFG, tcfg))
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    from repro.dist import sharding as SH
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), like)
    restored = CK.restore(str(tmp_path), 1, like, mesh=mesh,
                          shardings=shardings)
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_step_time_straggler_pipeline():
    """The bpftime angle: per-host step times land in a PERCPU map via the
    sys_step_end tracepoint; detection reads the aggregated window."""
    from repro.core import maps as M
    from repro.core.runtime import BpftimeRuntime
    rt = BpftimeRuntime()
    prog = """
        ldxdw r6, [r1+ctx:arg0]     ; step
        mod r6, 16
        stxdw [r10-8], r6
        ldxdw r3, [r1+ctx:arg1]     ; step time (us)
        lddw r1, map:step_times
        mov r2, r10
        add r2, -8
        call map_fetch_add
        mov r0, 0
        exit
    """
    pid = rt.load_asm("times", prog,
                      [M.MapSpec("step_times", M.MapKind.ARRAY,
                                 max_entries=16)], "tracepoint")
    rt.attach(pid, "tracepoint:sys_step_end:enter")
    for s in range(32):
        rt.syscalls.invoke("sys_step_end", [s, 1000 + s], impl=lambda: None)
    vals = rt.host_maps["step_times"]["values"]
    assert int(vals[0]) == 1000 + 0 + 1000 + 16
