"""Per-architecture smoke tests (reduced configs, CPU):
forward/train-step shapes + finiteness, and prefill+decode == full forward
(the KV-cache / SSM-state correctness property)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, registry
from repro.models import layers as L, registry as MR, transformer as TF

ALL = sorted(ARCHS)


def make_batch(cfg, B, S, key=0):
    k = jax.random.PRNGKey(key)
    ks = jax.random.split(k, 4)
    Ft = cfg.frontend_tokens
    batch = {}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            ks[0], (B, S, cfg.d_model), jnp.float32) * 0.02
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0,
                                             cfg.vocab_size, jnp.int32)
        batch["labels"] = jax.random.randint(ks[2], (B, S), 0,
                                             cfg.vocab_size, jnp.int32)
        return batch
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(
            ks[0], (B, Ft, cfg.d_model), jnp.float32) * 0.02
        batch["tokens"] = jax.random.randint(ks[1], (B, S - Ft), 0,
                                             cfg.vocab_size, jnp.int32)
        labels = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size,
                                    jnp.int32)
        batch["labels"] = labels.at[:, :Ft].set(-1)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0,
                                             cfg.vocab_size, jnp.int32)
        batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_train_step_smoke(arch):
    cfg = registry.smoke(arch)
    B, S = 2, 16
    params = MR.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, B, S)

    def loss(p):
        l, m = MR.loss_fn(p, batch, cfg, remat=True)
        return l

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val)), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: grad not finite"
    assert float(gnorm) > 0, f"{arch}: zero grads"


@pytest.mark.parametrize("arch", ALL)
def test_logits_shape_and_vocab(arch):
    cfg = registry.smoke(arch)
    B, S = 2, 8
    params = MR.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, B, S)
    if cfg.family == "encdec":
        from repro.models import encdec as ED
        logits = ED.forward_train(params, batch, cfg)
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        logits, _ = TF.forward(params, batch["tokens"], cfg,
                               embeds=batch.get("embeds"), mode="train")
        assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_matches_full_forward(arch):
    """Serve-path correctness: teacher-forced full forward at positions
    [P, P+1] must equal prefill(P tokens) + 2 decode steps."""
    cfg = registry.smoke(arch)
    if cfg.num_experts:
        # capacity drops are data-dependent and differ between a 20-token
        # full pass and 1-token decode steps (expected for dropping MoE);
        # parity needs drop-free capacity.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    B, P, EXTRA = 2, 8, 2
    S = P + EXTRA
    params = MR.init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg, B, S, key=7)

    if cfg.family == "encdec":
        from repro.models import encdec as ED
        enc_out = ED.encode(params, batch["enc_embeds"], cfg)
        full = ED.decode_train(params, batch["tokens"], enc_out, cfg)
        cache = MR.make_cache(cfg, B, S, jnp.float32, enc_seq=S)
        pre_logits, cache = ED.prefill(params, batch["tokens"][:, :P],
                                       enc_out, cache, cfg)
        np.testing.assert_allclose(np.asarray(pre_logits),
                                   np.asarray(full[:, :P]), rtol=2e-3,
                                   atol=2e-3)
        toks = batch["tokens"]
    else:
        full, _ = TF.forward(params, batch["tokens"], cfg,
                             embeds=batch.get("embeds"), mode="train")
        cache = MR.make_cache(cfg, B, S, jnp.float32)
        Ft = cfg.frontend_tokens
        pre_batch = {"tokens": batch["tokens"][:, :P - Ft]
                     if Ft else batch["tokens"][:, :P]}
        if Ft:
            pre_batch["embeds"] = batch["embeds"]
        pre_logits, cache = MR.prefill_fn(params, pre_batch, cache, cfg)
        np.testing.assert_allclose(np.asarray(pre_logits),
                                   np.asarray(full[:, :P]), rtol=2e-3,
                                   atol=2e-3, err_msg=f"{arch} prefill")
        toks = jnp.concatenate(
            [jnp.zeros((B, Ft), jnp.int32), batch["tokens"]], axis=1) \
            if Ft else batch["tokens"]

    for t in range(EXTRA):
        step_tok = toks[:, P + t][:, None]
        logits, cache = MR.decode_fn(params, step_tok, cache, cfg)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, P + t]),
            rtol=5e-3, atol=5e-3, err_msg=f"{arch} decode step {t}")


def test_flash_matches_full_attention():
    key = jax.random.PRNGKey(0)
    B, S, H, KH, hd = 2, 512, 8, 2, 32
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, hd),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, hd),
                          jnp.float32)
    for qc, kc in [(128, 128), (256, 64), (512, 512), (64, 256)]:
        got = L.flash_attention(q, k, v, causal=True, q_chunk=qc,
                                kv_chunk=kc)
        want = L.full_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"chunks {qc}x{kc}")


def test_flash_noncausal_matches_full():
    key = jax.random.PRNGKey(3)
    B, Sq, Skv, H, KH, hd = 1, 256, 512, 4, 4, 16
    q = jax.random.normal(key, (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Skv, KH, hd),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Skv, KH, hd),
                          jnp.float32)
    got = L.flash_attention(q, k, v, causal=False, q_chunk=128, kv_chunk=128)
    want = L.full_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_moe_routes_to_topk_experts_only():
    from repro.models import moe as MOE
    cfg = registry.smoke("llama4-scout-17b-a16e")
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    y = MOE.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_capacity_drops_are_soft():
    """With capacity_factor tiny, output must stay finite (drops, no NaN)."""
    from repro.models import moe as MOE
    cfg = dataclasses.replace(registry.smoke("kimi-k2-1t-a32b"),
                              capacity_factor=0.05)
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    y = MOE.apply_moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_param_counts_sane():
    # full-size param counts should be in the right ballpark
    approx = {
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "llama3.2-1b": (0.9e9, 1.6e9),
        "phi4-mini-3.8b": (2.5e9, 4.5e9),
        "starcoder2-15b": (12e9, 18e9),
        "qwen2-vl-72b": (60e9, 85e9),
        "mamba2-780m": (0.5e9, 1.1e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "llama4-scout-17b-a16e": (90e9, 125e9),
        "jamba-v0.1-52b": (40e9, 65e9),
        "seamless-m4t-medium": (0.4e9, 1.4e9),
    }
    for arch, (lo, hi) in approx.items():
        n = ARCHS[arch].param_counts()["total"]
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B params not in " \
                              f"[{lo / 1e9}, {hi / 1e9}]B"


def test_kimi_active_params():
    c = ARCHS["kimi-k2-1t-a32b"].param_counts()
    assert 20e9 <= c["active"] <= 45e9, f"active {c['active'] / 1e9:.1f}B"
