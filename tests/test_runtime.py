"""Integration tests for the bpftime runtime: attach/collect/execute,
loader relocation, syscall hooks with override, shm control plane + daemon,
vectorized-vs-scan equivalence, and the host-callback baseline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (daemon, events as E, jit as J, loader, maps as M,
                        vectorized as V, vm)
from repro.core.runtime import BpftimeRuntime
from repro.core.shm import ShmRegion

COUNT_BY_LAYER = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:layer_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

HIST_RMS = """
    ldxdw r2, [r1+ctx:rms]
    lddw r1, map:rms_hist
    call hist_add
    mov r0, 0
    exit
"""

ARR = M.MapSpec("layer_counts", M.MapKind.ARRAY, max_entries=16)
HIST = M.MapSpec("rms_hist", M.MapKind.LOG2HIST)


def make_runtime(attach_ret=False):
    rt = BpftimeRuntime()
    pid = rt.load_asm("count_by_layer", COUNT_BY_LAYER, [ARR], "uprobe")
    rt.attach(pid, "uprobe:block")
    pid2 = rt.load_asm("hist_rms", HIST_RMS, [HIST], "uprobe")
    rt.attach(pid2, "uretprobe:block" if attach_ret else "uprobe:block")
    return rt


def fake_step(rt, n_layers=4, mode="scan"):
    """Emulates a probed train step: scan over layers, each emitting an
    entry event for site 'block'."""
    with rt.collector() as col:
        def body(c, x):
            h = E.probe_site("block", x * c, kind=E.KIND_ENTRY)
            return c + 1.0, h.sum()

        xs = jnp.ones((n_layers, 8), jnp.float32)
        c, ys = E.probed_scan(body, jnp.float32(1.0), xs)
        rows = col.take_all_rows()
    maps_state = rt.init_device_maps()
    aux = J.make_aux(time_ns=123)
    maps_state, aux = rt.probe_stage(rows, maps_state, aux, mode=mode)
    return rows, maps_state, aux


def test_probe_stage_counts_per_layer():
    rt = make_runtime()
    rows, maps_state, _ = fake_step(rt, n_layers=4)
    assert rows.shape == (4, E.EVENT_WIDTH)
    counts = np.asarray(maps_state["layer_counts"]["values"])
    np.testing.assert_array_equal(counts[:4], [1, 1, 1, 1])
    hist = np.asarray(maps_state["rms_hist"]["bins"])
    assert hist.sum() == 4


def test_unattached_site_is_nop():
    rt = BpftimeRuntime()
    pid = rt.load_asm("c", COUNT_BY_LAYER, [ARR], "uprobe")
    rt.attach(pid, "uprobe:some_other_site")
    with rt.collector() as col:
        E.probe_site("block", jnp.ones((4,)), kind=E.KIND_ENTRY)
        rows = col.take_all_rows()
    assert rows.shape[0] == 0


def test_no_collector_site_is_identity():
    x = jnp.ones((4,))
    y = E.probe_site("whatever", x)
    assert y is x


def test_attach_detach_epoch():
    rt = BpftimeRuntime()
    pid = rt.load_asm("c", COUNT_BY_LAYER, [ARR], "uprobe")
    e0 = rt.attach_epoch
    lid = rt.attach(pid, "uprobe:block")
    assert rt.attach_epoch == e0 + 1
    rt.detach(lid)
    assert rt.attach_epoch == e0 + 2
    assert not rt.device_attach


def test_vectorized_matches_scan():
    rt = make_runtime()
    for pid, p in rt.progs.items():
        assert V.is_vector_safe(p.vprog), p.name
    _, m_scan, _ = fake_step(rt, n_layers=6, mode="scan")
    _, m_vec, _ = fake_step(rt, n_layers=6, mode="vectorized")
    for name in ("layer_counts", "rms_hist"):
        for f in m_scan[name]:
            np.testing.assert_array_equal(np.asarray(m_scan[name][f]),
                                          np.asarray(m_vec[name][f]),
                                          err_msg=f"{name}.{f}")


def test_vector_safety_accepts_hash_rejects_loops():
    rt = BpftimeRuntime()
    hash_prog = """
        ldxdw r6, [r1+0]
        stxdw [r10-8], r6
        lddw r1, map:h
        mov r2, r10
        add r2, -8
        mov r3, 1
        call map_fetch_add
        mov r0, 0
        exit
    """
    pid = rt.load_asm("h", hash_prog,
                      [M.MapSpec("h", M.MapKind.HASH, max_entries=8)])
    # HASH fetch_add is batchable since the fused pipeline (sort-by-key +
    # segment_sum scatter); bit-identical to scan mode by differential test.
    assert V.is_vector_safe(rt.progs[pid].vprog)

    loop_prog = """
        mov r6, 5
        mov r0, 0
        l:
        add r0, 1
        sub r6, 1
        jgt r6, 0, l
        exit
    """
    pid2 = rt.load_asm("loop", loop_prog, [])
    assert not V.is_vector_safe(rt.progs[pid2].vprog)


def test_vector_safety_rejects_live_fetch_add_result():
    rt = BpftimeRuntime()
    prog = """
        mov r6, 0
        stxdw [r10-8], r6
        lddw r1, map:layer_counts
        mov r2, r10
        add r2, -8
        mov r3, 1
        call map_fetch_add
        add r0, 1          ; READS the fetch-add result
        exit
    """
    pid = rt.load_asm("live", prog, [ARR])
    assert not V.is_vector_safe(rt.progs[pid].vprog)


# ---------------------------------------------------------------- traceable

def test_traceable_uprobe_uretprobe():
    rt = BpftimeRuntime()
    pid = rt.load_asm("c", COUNT_BY_LAYER, [ARR], "uprobe")
    rt.attach(pid, "uprobe:mlp")
    rt.attach(pid, "uretprobe:mlp")

    @E.traceable("mlp")
    def mlp(x):
        return x * 2.0

    with rt.collector() as col:
        mlp(jnp.ones((8,), jnp.float32))
        rows = col.take_all_rows()
    assert rows.shape[0] == 2
    kinds = sorted(int(k) for k in rows[:, 1])
    assert kinds == [E.KIND_ENTRY, E.KIND_EXIT]


# ---------------------------------------------------------------- loader

def test_loader_relocation_with_shifted_fds():
    rt = BpftimeRuntime()
    rt.create_map(M.MapSpec("decoy", M.MapKind.ARRAY, max_entries=4))
    rt.create_map(M.MapSpec("decoy2", M.MapKind.HASH, max_entries=4))
    pid = rt.load_asm("c", COUNT_BY_LAYER, [ARR], "uprobe")
    # layer_counts got global fd 2; program must still hit the right map
    rt.attach(pid, "uprobe:block")
    _, maps_state, _ = fake_step_single(rt)
    assert np.asarray(maps_state["layer_counts"]["values"]).sum() == 1
    assert np.asarray(maps_state["decoy"]["values"]).sum() == 0


def fake_step_single(rt):
    with rt.collector() as col:
        E.probe_site("block", jnp.ones((8,), jnp.float32),
                     kind=E.KIND_ENTRY)
        rows = col.take_all_rows()
    ms = rt.init_device_maps()
    aux = J.make_aux()
    ms, aux = rt.probe_stage(rows, ms, aux)
    return rows, ms, aux


def test_program_object_json_roundtrip():
    obj = loader.build_object("c", COUNT_BY_LAYER, [ARR], "uprobe",
                              attach_to="uprobe:block")
    obj2 = loader.ProgramObject.from_json(obj.to_json())
    assert obj2.insns_hex == obj.insns_hex
    assert obj2.map_specs()[0].name == "layer_counts"
    assert obj2.relocs == obj.relocs


def test_undeclared_map_rejected():
    with pytest.raises(loader.LoadError):
        loader.build_object("bad", "lddw r1, map:nope\nmov r0, 0\nexit", [])


def test_incompatible_map_redeclaration_rejected():
    rt = BpftimeRuntime()
    rt.create_map(ARR)
    with pytest.raises(loader.LoadError):
        rt.create_map(M.MapSpec("layer_counts", M.MapKind.HASH,
                                max_entries=8))


# ---------------------------------------------------------------- syscalls

FILTER_BIG_FETCH = """
    ldxdw r6, [r1+ctx:arg0]
    jle r6, 5, out
    mov r1, 99
    call override_return
    out:
    mov r0, 0
    exit
"""

COUNT_SYSCALLS = """
    ldxdw r6, [r1+ctx:sys_id]
    stxdw [r10-8], r6
    lddw r1, map:sys_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""


def test_syscall_filter_override():
    rt = BpftimeRuntime()
    pid = rt.load_asm("flt", FILTER_BIG_FETCH, [], "filter")
    rt.attach(pid, "filter:sys_data_fetch")
    calls = []
    r = rt.syscalls.invoke("sys_data_fetch", [3],
                           impl=lambda: calls.append(1) or "batch")
    assert not r.overridden and r.value == "batch"
    r = rt.syscalls.invoke("sys_data_fetch", [9],
                           impl=lambda: calls.append(1) or "batch")
    assert r.overridden and r.ret_code == 99 and r.value is None
    assert len(calls) == 1


def test_syscall_tracepoint_counts():
    rt = BpftimeRuntime()
    spec = M.MapSpec("sys_counts", M.MapKind.ARRAY, max_entries=32)
    pid = rt.load_asm("cnt", COUNT_SYSCALLS, [spec], "tracepoint")
    rt.attach(pid, "tracepoint:sys_log:enter")
    rt.attach(pid, "tracepoint:sys_log:exit")
    rt.syscalls.invoke("sys_log", [1], impl=lambda: None)
    rt.syscalls.invoke("sys_log", [2], impl=lambda: None)
    from repro.core.syscalls import SYSCALL_IDS
    assert rt.host_maps["sys_counts"]["values"][SYSCALL_IDS["sys_log"]] == 4


# ---------------------------------------------------------------- shm/daemon

def test_shm_publish_snapshot_and_daemon_render(tmp_path):
    rt = make_runtime()
    shm = rt.setup_shm(str(tmp_path / "shm"))
    _, maps_state, _ = fake_step(rt)
    rt.publish(maps_state)

    other = ShmRegion.attach(str(tmp_path / "shm"))
    snap = other.snapshot_device("layer_counts")
    np.testing.assert_array_equal(snap["values"][:4], [1, 1, 1, 1])
    txt = daemon.summarize(other)
    assert "layer_counts" in txt and "rms_hist" in txt
    assert "progs" not in txt  # programs listed separately
    progs = other.read_programs()
    assert "count_by_layer" in progs


def test_live_attach_via_daemon_request(tmp_path):
    """The paper's inject-into-running-process: a daemon queues a program;
    the trainer picks it up between steps; the next step is instrumented."""
    rt = BpftimeRuntime()
    rt.create_map(ARR)
    rt.setup_shm(str(tmp_path / "shm"))
    e0 = rt.attach_epoch

    # daemon side
    other = ShmRegion.attach(str(tmp_path / "shm"))
    obj = loader.build_object("c", COUNT_BY_LAYER, [ARR], "uprobe",
                              attach_to="uprobe:block")
    daemon.request_load_attach(other, obj.to_json())

    # trainer side, at a step boundary
    applied = rt.poll_control()
    assert len(applied) == 1 and "error" not in applied[0]
    assert rt.attach_epoch == e0 + 1
    _, ms, _ = fake_step_single(rt)
    assert np.asarray(ms["layer_counts"]["values"]).sum() == 1
    # idempotent poll
    assert rt.poll_control() == []


# ---------------------------------------------------------------- callback

def test_host_callback_probe_baseline():
    from repro.core import callback_probe
    rt = make_runtime()
    with rt.collector() as col:
        E.probe_site("block", jnp.ones((8,), jnp.float32),
                     kind=E.KIND_ENTRY)
        rows = col.take_all_rows()

    @jax.jit
    def step(rows):
        tok = callback_probe.host_probe_stage(rt, rows, jnp.int64(7))
        return tok

    tok = step(rows)
    assert int(tok) == rows.shape[0]
    assert rt.host_maps["layer_counts"]["values"][0] == 1
    assert rt.host_maps["rms_hist"]["bins"].sum() == 1


# ---------------------------------------------------------------- ringbuf

def test_ringbuf_device_to_host_drain():
    rt = BpftimeRuntime()
    rb = M.MapSpec("events_rb", M.MapKind.RINGBUF, max_entries=8,
                   rec_width=4)
    prog = """
        ldxdw r6, [r1+ctx:layer]
        stxdw [r10-32], r6
        ldxdw r6, [r1+ctx:numel]
        stxdw [r10-24], r6
        lddw r1, map:events_rb
        mov r2, r10
        add r2, -32
        mov r3, 16
        mov r4, 0
        call ringbuf_output
        mov r0, 0
        exit
    """
    pid = rt.load_asm("rb", prog, [rb], "uprobe")
    rt.attach(pid, "uprobe:block")
    _, ms, _ = fake_step(rt, n_layers=3)
    recs, cursor = rt.ringbuf_drain(ms, "events_rb", 0)
    assert cursor == 3
    assert [r[0] for r in recs] == [0, 1, 2]      # layer ids
    assert all(r[1] == 8 for r in recs)            # numel
