"""Serve engine: continuous batching, admission filters, eviction
accounting, decode determinism across slot assignments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import maps as M
from repro.core.runtime import BpftimeRuntime
from repro.models import registry as MR
from repro.serve.engine import Request, ServeEngine

CFG = registry.smoke("qwen2-0.5b")


@pytest.fixture(scope="module")
def params():
    return MR.init_params(jax.random.PRNGKey(0), CFG)


def test_engine_completes_all(params):
    eng = ServeEngine(params, CFG, slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4)
            for i in range(5)]
    eng.submit_all(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 4 for r in reqs if not r.rejected)


def test_engine_greedy_matches_unbatched(params):
    """Batched continuous decoding == one-at-a-time greedy decoding."""
    def solo_decode(prompt, n):
        cache = MR.make_cache(CFG, 1, 32, jnp.float32)
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        logits, cache = MR.prefill_fn(params, {"tokens": toks}, cache, CFG)
        out = [int(jnp.argmax(logits[0, -1, :CFG.vocab_size]))]
        for _ in range(n - 1):
            l, cache = MR.decode_fn(
                params, jnp.asarray([[out[-1]]], jnp.int32), cache, CFG)
            out.append(int(jnp.argmax(l[0, -1, :CFG.vocab_size])))
        return out

    prompts = [[5, 6, 7], [9, 8], [3, 3, 3, 3]]
    eng = ServeEngine(params, CFG, slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts)]
    eng.submit_all(reqs)
    for r in reqs:
        want = solo_decode(r.prompt, 5)
        assert r.out[:5] == want, f"req {r.rid}: {r.out[:5]} != {want}"


def test_admission_filter_rejects(params):
    rt = BpftimeRuntime()
    prog = """
        ldxdw r6, [r1+ctx:arg1]
        jle r6, 3, ok
        mov r1, 429
        call override_return
        ok:
        mov r0, 0
        exit
    """
    pid = rt.load_asm("admit", prog, [], "filter")
    rt.attach(pid, "filter:sys_serve_admit")
    eng = ServeEngine(params, CFG, slots=2, max_seq=32, runtime=rt)
    reqs = [Request(rid=0, prompt=[1, 2], max_new=3),
            Request(rid=1, prompt=[1, 2, 3, 4, 5], max_new=3)]
    eng.submit_all(reqs)
    assert not reqs[0].rejected and reqs[0].done
    assert reqs[1].rejected and not reqs[1].out
