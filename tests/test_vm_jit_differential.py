"""Differential tests: interpreter oracle (vm.py) vs JAX JIT (jit.py) vs the
program-table interpreter (table_interp.py — the live attach/detach lane),
on hand-written programs and hypothesis-generated random ones."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # hypothesis is optional: only the property tests need it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import asm, isa, jit, maps as M, table_interp, verifier, vm


def _mk_maps(specs):
    return M.init_states(specs, np), M.init_states(specs, jnp)


def _check_outputs(label, res, oracle_aux, np_maps, specs, r0, maps_out,
                   aux_out, check_maps):
    assert isa.u64(int(r0)) == isa.u64(res.r0), \
        f"r0 mismatch: {label}={isa.u64(int(r0)):#x} vm={isa.u64(res.r0):#x}"
    if check_maps:
        for sp in specs:
            for k, arr in np_maps[sp.name].items():
                np.testing.assert_array_equal(
                    np.asarray(maps_out[sp.name][k]), arr,
                    err_msg=f"[{label}] map {sp.name}.{k}")
    assert int(aux_out["override_set"]) == oracle_aux.override_set
    if oracle_aux.override_set:
        assert isa.u64(int(aux_out["override_val"])) == \
            oracle_aux.override_val


def run_both(text, ctx_words=None, specs=(), aux_kw=None, check_maps=True):
    """Assemble, verify, run oracle + JIT + table interpreter, compare
    r0/maps/aux across all three."""
    ctx_words = ctx_words or [0] * 8
    specs = list(specs)
    a = asm.assemble(text)
    assert not a.map_relocs, "use numeric fds in tests or relocate first"
    vprog = verifier.verify(a.insns, specs, ctx_words=len(ctx_words))

    aux_kw = aux_kw or {}
    np_maps, j_maps = _mk_maps(specs)
    oracle_aux = vm.Aux(**aux_kw)
    res = vm.run(a.insns, vm.pack_ctx(ctx_words), specs, np_maps, oracle_aux)

    prog = jit.compile_program(vprog)
    ctx = jnp.asarray([isa.s64(isa.u64(w)) for w in ctx_words], jnp.int64)
    jaux = jit.make_aux(**aux_kw)
    f = jax.jit(lambda c, m, x: prog(c, m, x))
    r0, j_maps_out, jaux_out = f(ctx, j_maps, jaux)
    _check_outputs("jit", res, oracle_aux, np_maps, specs, r0, j_maps_out,
                   jaux_out, check_maps)

    # the live-attach lane must agree with the oracle on the SAME corpus
    _, t_maps = _mk_maps(specs)
    t_r0, t_maps_out, t_aux_out = table_interp.run_program(
        vprog, ctx, t_maps, jit.make_aux(**aux_kw))
    _check_outputs("table", res, oracle_aux, np_maps, specs, t_r0,
                   t_maps_out, t_aux_out, check_maps)

    # ... and so must the batched (lockstep SIMT) interpreter, wherever its
    # eligibility gate admits the program
    if table_interp.batched_encodable(vprog):
        _, b_maps = _mk_maps(specs)
        b_r0, b_maps_out = table_interp.run_program_batched(
            vprog, ctx[None, :], b_maps, jit.make_aux(**aux_kw))
        assert isa.u64(int(b_r0[0])) == isa.u64(res.r0), \
            f"r0 mismatch: batched={isa.u64(int(b_r0[0])):#x} " \
            f"vm={isa.u64(res.r0):#x}"
        if check_maps:
            for sp in specs:
                for k, arr in np_maps[sp.name].items():
                    np.testing.assert_array_equal(
                        np.asarray(b_maps_out[sp.name][k]), arr,
                        err_msg=f"[batched] map {sp.name}.{k}")
    return res, r0


# ---------------------------------------------------------------- basics

def test_mov_add_exit():
    run_both("""
        mov r0, 7
        add r0, 35
        exit
    """)


def test_alu64_ops():
    run_both("""
        mov r1, 1000
        mov r2, 37
        mov r0, r1
        mul r0, r2          ; 37000
        div r0, 7           ; 5285
        mod r0, 1000        ; 285
        xor r0, 0xff
        lsh r0, 3
        rsh r0, 1
        arsh r0, 1
        neg r0
        and r0, 0xffff
        or  r0, 0x10000
        sub r0, 5
        exit
    """)


def test_alu32_zero_extend():
    run_both("""
        mov r0, -1          ; 0xffffffffffffffff
        add32 r0, 1         ; 32-bit wrap -> 0, zero-extended
        mov r1, -1
        mov32 r1, -1        ; 0x00000000ffffffff
        add r0, r1
        exit
    """)


def test_div_mod_by_zero_semantics():
    # eBPF: div by 0 -> 0; mod by 0 -> dst unchanged
    run_both("""
        mov r0, 42
        mov r1, 0
        div r0, r1
        mov r2, 13
        mod r2, r1
        add r0, r2          ; 0 + 13
        exit
    """)


def test_shift_masking():
    run_both("""
        mov r0, 1
        mov r1, 65          ; masked to 1 for 64-bit shifts
        lsh r0, r1          ; 1 << 1 = 2
        mov r2, 1
        mov r3, 33          ; masked to 1 for 32-bit shifts
        lsh32 r2, r3
        add r0, r2          ; 2 + 2
        exit
    """)


def test_branches_and_labels():
    res, _ = run_both("""
        mov r1, 10
        mov r0, 0
        jgt r1, 5, big
        mov r0, 111
        ja out
        big:
        mov r0, 222
        out:
        exit
    """)
    assert res.r0 == 222


def test_signed_vs_unsigned_compare():
    res, _ = run_both("""
        mov r1, -1          ; u64 max
        mov r0, 0
        jsgt r1, 0, spos    ; signed: -1 > 0 false
        add r0, 1
        spos:
        jgt r1, 0, upos     ; unsigned: max > 0 true
        add r0, 100
        upos:
        exit
    """)
    assert res.r0 == 1


def test_jmp32():
    res, _ = run_both("""
        lddw r1, 0x1_00000005   ; low 32 bits = 5
        mov r0, 0
        jeq32 r1, 5, yes
        ja out
        yes:
        mov r0, 1
        out:
        exit
    """)
    assert res.r0 == 1


def test_stack_load_store_sizes():
    run_both("""
        mov r1, 0x1234567890abcdef
        lddw r1, 0x1234567890abcdef
        stxdw [r10-8], r1
        ldxb r0, [r10-8]    ; 0xef
        ldxh r2, [r10-8]    ; 0xcdef
        add r0, r2
        ldxw r3, [r10-8]    ; 0x90abcdef
        add r0, r3
        ldxdw r4, [r10-8]
        add r0, r4
        stw [r10-16], -1
        ldxw r5, [r10-16]   ; 0xffffffff zero-extended
        add r0, r5
        exit
    """)


def test_ctx_reads():
    res, _ = run_both("""
        ldxdw r0, [r1+0]
        ldxdw r2, [r1+8]
        add r0, r2
        ldxw r3, [r1+16]    ; low half of word 2
        add r0, r3
        exit
    """, ctx_words=[11, 31, 0x1_0000_0007])
    assert res.r0 == 11 + 31 + 7


def test_loop_tier2():
    # sum 1..10 — back-edge forces tier-2 while_loop JIT
    res, _ = run_both("""
        mov r1, 10
        mov r0, 0
        loop:
        add r0, r1
        sub r1, 1
        jgt r1, 0, loop
        exit
    """)
    assert res.r0 == 55


# ---------------------------------------------------------------- helpers/maps

def _arr(name="a", n=8):
    return M.MapSpec(name, M.MapKind.ARRAY, max_entries=n)


def _hash(name="h", n=8):
    return M.MapSpec(name, M.MapKind.HASH, max_entries=n)


def test_array_map_update_lookup():
    res, _ = run_both("""
        mov r6, 3           ; key
        stxdw [r10-8], r6
        mov r6, 99
        stxdw [r10-16], r6
        mov r1, 0           ; fd 0
        mov r2, r10
        add r2, -8
        mov r3, r10
        add r3, -16
        mov r4, 0
        call map_update_elem
        mov r1, 0
        mov r2, r10
        add r2, -8
        call map_lookup_elem
        exit
    """, specs=[_arr()])
    assert res.r0 == 99


def test_array_fetch_add():
    res, _ = run_both("""
        mov r6, 2
        stxdw [r10-8], r6
        mov r1, 0
        mov r2, r10
        add r2, -8
        mov r3, 5
        call map_fetch_add      ; old = 0
        mov r1, 0
        mov r2, r10
        add r2, -8
        mov r3, 7
        call map_fetch_add      ; old = 5
        exit
    """, specs=[_arr()])
    assert res.r0 == 5


def test_array_oob_is_noop():
    res, _ = run_both("""
        mov r6, 1000        ; out of bounds key
        stxdw [r10-8], r6
        mov r1, 0
        mov r2, r10
        add r2, -8
        mov r3, 5
        call map_fetch_add
        exit
    """, specs=[_arr()])
    assert res.r0 == 0


def test_hash_map_update_lookup_delete():
    res, _ = run_both("""
        lddw r6, 0xdeadbeefcafe
        stxdw [r10-8], r6
        mov r6, 1234
        stxdw [r10-16], r6
        mov r1, 0
        mov r2, r10
        add r2, -8
        mov r3, r10
        add r3, -16
        mov r4, 0
        call map_update_elem
        mov r1, 0
        mov r2, r10
        add r2, -8
        call map_lookup_elem
        mov r7, r0
        mov r1, 0
        mov r2, r10
        add r2, -8
        call map_delete_elem
        mov r1, 0
        mov r2, r10
        add r2, -8
        call map_lookup_elem    ; gone -> 0
        add r0, r7
        exit
    """, specs=[_hash()])
    assert res.r0 == 1234


def test_hash_collisions_fill_table():
    # insert n+2 distinct keys into an n=4 table; two must fail with -7
    text = ["mov r8, 0"]
    for k in range(6):
        text += [
            f"mov r6, {100 + k}",
            "stxdw [r10-8], r6",
            f"mov r6, {k}",
            "stxdw [r10-16], r6",
            "mov r1, 0",
            "mov r2, r10", "add r2, -8",
            "mov r3, r10", "add r3, -16",
            "mov r4, 0",
            "call map_update_elem",
            "and r0, 0xff",
            "add r8, r0",
        ]
    text += ["mov r0, r8", "exit"]
    res, _ = run_both("\n".join(text), specs=[_hash("h", 4)])
    # 4 inserts succeed (r0=0), 2 fail with -7 (&0xff = 0xf9)
    assert res.r0 == 2 * 0xF9


def test_hist_add():
    res, _ = run_both("""
        mov r1, 0
        mov r2, 1000
        call hist_add
        mov r1, 0
        mov r2, 3
        call hist_add
        mov r1, 0
        mov r2, 0
        call hist_add
        mov r0, 0
        exit
    """, specs=[M.MapSpec("hist", M.MapKind.LOG2HIST)])


def test_ringbuf_output():
    res, _ = run_both("""
        mov r6, 41
        stxdw [r10-16], r6
        mov r6, 42
        stxdw [r10-8], r6
        mov r1, 0
        mov r2, r10
        add r2, -16
        mov r3, 16
        mov r4, 0
        call ringbuf_output
        exit
    """, specs=[M.MapSpec("rb", M.MapKind.RINGBUF, max_entries=4, rec_width=2)])
    assert res.r0 == 0


def test_override_return():
    res, _ = run_both("""
        mov r1, 255
        call override_return
        mov r0, 0
        exit
    """)
    assert res.aux.override_set == 1 and res.aux.override_val == 255


def test_log2_helper():
    res, _ = run_both("""
        mov r1, 4096
        call log2
        exit
    """)
    assert res.r0 == 13  # bit_length(4096)


def test_aux_helpers():
    res, _ = run_both("""
        call ktime_get_ns
        mov r6, r0
        call get_smp_processor_id
        add r6, r0
        call get_current_pid_tgid
        add r6, r0
        mov r0, r6
        exit
    """, aux_kw=dict(time_ns=1000, cpu=3, pid=77))
    assert res.r0 == 1080


def test_prandom_deterministic():
    res, _ = run_both("""
        call get_prandom_u32
        mov r6, r0
        call get_prandom_u32
        add r6, r0
        mov r0, r6
        exit
    """)


def test_branchy_map_updates_predication():
    # the untaken branch's map update must NOT happen (T1 predication)
    res, _ = run_both("""
        ldxdw r6, [r1+0]
        mov r7, 1            ; key 1
        jgt r6, 100, hot
        mov r7, 0            ; key 0
        hot:
        stxdw [r10-8], r7
        mov r1, 0
        mov r2, r10
        add r2, -8
        mov r3, 1
        call map_fetch_add
        mov r0, r7
        exit
    """, ctx_words=[50], specs=[_arr()])
    assert res.r0 == 0


# ---------------------------------------------------------------- hypothesis

_ALU64 = ["add", "sub", "mul", "div", "or", "and", "lsh", "rsh", "mod",
          "xor", "arsh"]

if HAVE_HYPOTHESIS:
    @st.composite
    def straightline_program(draw):
        """Random straight-line ALU program over r0-r5 + ctx/stack ops."""
        lines = [f"ldxdw r{i}, [r1+{8 * i}]" for i in range(2, 6)]
        lines.append("mov r0, 0")
        n = draw(st.integers(2, 25))
        for _ in range(n):
            op = draw(st.sampled_from(_ALU64 + ["mov"]))
            w = draw(st.sampled_from(["", "32"]))
            dst = draw(st.integers(0, 5))
            if dst == 1:
                dst = 0  # keep r1 = ctx ptr intact
            if draw(st.booleans()):
                src = draw(st.integers(2, 5))
                lines.append(f"{op}{w} r{dst}, r{src}")
            else:
                imm = draw(st.integers(-2**31, 2**31 - 1))
                lines.append(f"{op}{w} r{dst}, {imm}")
        # occasional stack round-trip
        if draw(st.booleans()):
            lines.append("stxdw [r10-8], r0")
            lines.append("ldxdw r0, [r10-8]")
        lines.append("exit")
        return "\n".join(lines)

    @settings(max_examples=60, deadline=None)
    @given(prog=straightline_program(),
           ctx=st.lists(st.integers(0, 2**63 - 1), min_size=8, max_size=8))
    def test_differential_random_straightline(prog, ctx):
        run_both(prog, ctx_words=ctx)

    @st.composite
    def branchy_program(draw):
        """Random DAG with forward branches (tier-1 if-conversion stress)."""
        lines = ["ldxdw r2, [r1+0]", "ldxdw r3, [r1+8]", "mov r0, 0"]
        nblk = draw(st.integers(1, 4))
        for b in range(nblk):
            cond = draw(st.sampled_from(["jeq", "jgt", "jsgt", "jlt",
                                         "jset"]))
            imm = draw(st.integers(-100, 100))
            lines.append(f"{cond} r2, {imm}, skip{b}")
            for _ in range(draw(st.integers(1, 3))):
                op = draw(st.sampled_from(_ALU64))
                imm2 = draw(st.integers(-1000, 1000))
                lines.append(f"{op} r0, {imm2}")
            lines.append("add r3, 1")
            lines.append(f"skip{b}:")
            lines.append("add r0, r3")
        lines.append("exit")
        return "\n".join(lines)

    @settings(max_examples=40, deadline=None)
    @given(prog=branchy_program(),
           ctx=st.lists(st.integers(-200, 200), min_size=8, max_size=8))
    def test_differential_random_branches(prog, ctx):
        run_both(prog, ctx_words=[isa.u64(c) for c in ctx])

    @settings(max_examples=20, deadline=None)
    @given(keys=st.lists(st.integers(-50, 50), min_size=1, max_size=12),
           deltas=st.lists(st.integers(-5, 5), min_size=12, max_size=12))
    def test_differential_hash_fetch_add(keys, deltas):
        lines = []
        for k, d in zip(keys, deltas):
            lines += [
                f"mov r6, {k}",
                "stxdw [r10-8], r6",
                "mov r1, 0",
                "mov r2, r10", "add r2, -8",
                f"mov r3, {d}",
                "call map_fetch_add",
            ]
        lines += ["mov r0, 0", "exit"]
        run_both("\n".join(lines), specs=[_hash("h", 8)])
