"""Commutativity-widening rules (DESIGN.md §14): the effect-footprint
lattice the verifier publishes, and the three lane-widening rules that
consume it — each with its certifying differential (fused/batched output
bit-identical to the scan/sequential oracle over K seeds), plus the
negative cases proving the widenings do not over-approximate."""
import random
import threading

import jax
import numpy as np
import pytest

from repro.core import asm, events as E, fuzz, jit as J, maps as M
from repro.core import table_interp, verifier
from repro.core.runtime import (BpftimeRuntime, WIDEN_STATS,
                                _has_ordering_conflict)
from repro.core.verifier import MapFootprint, footprints_disjoint

ARR8 = M.MapSpec("a", M.MapKind.ARRAY, max_entries=8)
HSH8 = M.MapSpec("h", M.MapKind.HASH, max_entries=8)


def _verify(text, specs):
    a = asm.assemble(text)
    assert not a.map_relocs
    return verifier.verify(a.insns, specs, ctx_words=8)


def _fetch_add(key_lines, fd=0, delta=1):
    return "\n".join(key_lines + [
        f"mov r1, {fd}", "mov r2, r10", "add r2, -8",
        f"mov r3, {delta}", "call map_fetch_add", "mov r0, 0", "exit"])


def _distinct_home_keys(max_entries, want=2, lo=0, hi=64):
    """Keys whose open-addressing home slots are pairwise distinct."""
    out, homes = [], set()
    for k in range(lo, hi):
        h = M._np_hash_idx(k, max_entries)
        if h not in homes:
            homes.add(h)
            out.append(k)
            if len(out) == want:
                return out
    raise AssertionError("no distinct-home keys found")


def _colliding_home_keys(max_entries):
    homes = {}
    for k in range(64):
        h = M._np_hash_idx(k, max_entries)
        if h in homes:
            return homes[h], k
        homes[h] = k
    raise AssertionError("no colliding keys found")


# ==========================================================================
# the footprint lattice itself
# ==========================================================================

def test_static_key_footprint():
    vp = _verify(_fetch_add(["stdw [r10-8], 3"]), [ARR8])
    fp = vp.footprints[0]
    assert fp.ops == frozenset({"map_fetch_add"})
    assert fp.commutative_only
    assert fp.static_keys == frozenset({3})
    assert vp.footprint_of("a") is fp
    assert vp.footprint_of("nope") is None


def test_dynamic_key_footprint():
    vp = _verify(_fetch_add(["ldxdw r6, [r1+0]", "and r6, 7",
                             "stxdw [r10-8], r6"]), [ARR8])
    fp = vp.footprints[0]
    assert fp.commutative_only
    assert fp.static_keys is None          # key not provably constant


def test_const_reg_store_is_static():
    # stxdw of a CONST-typed register carries the constant into the slot
    vp = _verify(_fetch_add(["mov r6, 5", "stxdw [r10-8], r6"]), [ARR8])
    assert vp.footprints[0].static_keys == frozenset({5})


def test_mixed_ops_not_commutative():
    text = "\n".join([
        "stdw [r10-8], 2", "stdw [r10-16], 9",
        "mov r1, 0", "mov r2, r10", "add r2, -8",
        "mov r3, r10", "add r3, -16", "mov r4, 0",
        "call map_update_elem",
        "stdw [r10-8], 2",
        "mov r1, 0", "mov r2, r10", "add r2, -8", "mov r3, 1",
        "call map_fetch_add", "mov r0, 0", "exit"])
    fp = _verify(text, [ARR8]).footprints[0]
    assert fp.ops == frozenset({"map_update_elem", "map_fetch_add"})
    assert not fp.commutative_only
    assert fp.static_keys == frozenset({2})


def test_branch_divergent_key_is_dynamic():
    """Different constants on two paths: the stack-const lattice merges by
    intersection, so the key is NOT static at the call."""
    text = "\n".join([
        "ldxdw r6, [r1+0]", "stdw [r10-8], 1",
        "jgt r6, 5, L1", "stdw [r10-8], 2", "L1:",
        "mov r1, 0", "mov r2, r10", "add r2, -8", "mov r3, 1",
        "call map_fetch_add", "mov r0, 0", "exit"])
    assert _verify(text, [ARR8]).footprints[0].static_keys is None


def test_branch_same_key_stays_static():
    text = "\n".join([
        "ldxdw r6, [r1+0]", "stdw [r10-8], 4",
        "jgt r6, 5, L1", "stdw [r10-8], 4", "L1:",
        "mov r1, 0", "mov r2, r10", "add r2, -8", "mov r3, 1",
        "call map_fetch_add", "mov r0, 0", "exit"])
    assert _verify(text, [ARR8]).footprints[0].static_keys == \
        frozenset({4})


def _fp(kind=M.MapKind.ARRAY, keys=(0,), n=8, comm=True):
    return MapFootprint(fd=0, name="x", kind=kind, max_entries=n,
                        ops=frozenset({"map_fetch_add"}),
                        commutative_only=comm,
                        static_keys=None if keys is None
                        else frozenset(keys))


def test_footprints_disjoint_predicate():
    assert footprints_disjoint(_fp(keys=(0, 1)), _fp(keys=(2, 3)))
    assert not footprints_disjoint(_fp(keys=(0, 1)), _fp(keys=(1, 2)))
    assert not footprints_disjoint(_fp(keys=None), _fp(keys=(2,)))
    assert not footprints_disjoint(None, _fp(keys=(2,)))
    # out-of-bounds keys: clamp/no-op semantics are not reasoned about
    assert not footprints_disjoint(_fp(keys=(99,)), _fp(keys=(2,)))
    # HASH is positional-excluded: layout depends on insert order
    assert not footprints_disjoint(_fp(kind=M.MapKind.HASH, keys=(0,)),
                                   _fp(kind=M.MapKind.HASH, keys=(2,)))


def test_footprints_survive_relocation():
    """verify-once/relocate-anywhere must carry static keys through
    resolve() and recompute footprints against the concrete registry."""
    from repro.core import loader, reloc
    obj = loader.build_object("w_reloc", """
        stdw [r10-8], 3
        lddw r1, map:rm
        mov r2, r10
        add r2, -8
        mov r3, 1
        call map_fetch_add
        mov r0, 0
        exit
    """, [M.MapSpec("rm", M.MapKind.ARRAY, max_entries=8)], "uprobe")
    vabs = reloc.verify_relocatable(obj)
    spec = [M.MapSpec("other", M.MapKind.ARRAY, max_entries=4),
            M.MapSpec("rm", M.MapKind.ARRAY, max_entries=8)]
    vb = reloc.resolve(vabs, {"rm": 1, "other": 0}, spec)
    assert vb.footprints[1].static_keys == frozenset({3})
    assert vb.footprints[1].name == "rm"


# ==========================================================================
# rule 1: fused-lane widening — disjoint static positional footprints
# ==========================================================================

UPD_K = """
    ldxdw r6, [r1+ctx:layer]
    stdw [r10-8], {key}
    stxdw [r10-16], r6
    lddw r1, map:w_arr
    mov r2, r10
    add r2, -8
    mov r3, r10
    add r3, -16
    mov r4, 0
    call map_update_elem
    mov r0, 0
    exit
"""

W_ARR = M.MapSpec("w_arr", M.MapKind.ARRAY, max_entries=16)


def _two_updaters(k1, k2):
    rt = BpftimeRuntime()
    p1 = rt.load_asm("upd1", UPD_K.format(key=k1), [W_ARR], "uprobe")
    rt.attach(p1, "uprobe:wdA")
    p2 = rt.load_asm("upd2", UPD_K.format(key=k2), [W_ARR], "uprobe")
    rt.attach(p2, "uprobe:wdB")
    return rt, [rt.progs[p1].vprog, rt.progs[p2].vprog]


def _tape(n=12, sites=("wdA", "wdB")):
    rng = np.random.default_rng(3)
    rows = np.zeros((n, E.EVENT_WIDTH), np.int64)
    rows[:, 0] = [E.SITES.get_or_create(sites[i % len(sites)])
                  for i in range(n)]
    rows[:, 1] = E.KIND_ENTRY
    rows[:, 2] = rng.integers(0, 99, n)
    import jax.numpy as jnp
    return jnp.asarray(rows)


def test_disjoint_static_updates_widen_fused():
    """Non-commutative sharing (update/update) on provably disjoint
    static ARRAY cells: unobservable interleave -> no fused fallback."""
    rt, vps = _two_updaters(2, 5)
    before = WIDEN_STATS["fused_disjoint_pairs"]
    assert not _has_ordering_conflict(vps)
    assert WIDEN_STATS["fused_disjoint_pairs"] == before + 1


def test_overlapping_static_updates_still_conflict():
    _, vps = _two_updaters(2, 2)
    assert _has_ordering_conflict(vps)


def test_oob_static_key_not_widened():
    _, vps = _two_updaters(2, 99)          # 99 >= max_entries
    assert _has_ordering_conflict(vps)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rule1_certificate_fused_matches_scan(seed):
    """The certificate: a previously scan-demoted pair now runs in fused
    mode and stays bit-identical to the scan oracle across seeds."""
    rt, vps = _two_updaters(3, 7)
    assert not _has_ordering_conflict(vps)
    rng = np.random.default_rng(seed)
    import jax.numpy as jnp
    rows = np.zeros((16, E.EVENT_WIDTH), np.int64)
    rows[:, 0] = rng.permutation(
        [E.SITES.get_or_create(s) for s in ("wdA", "wdB")] * 8)
    rows[:, 1] = E.KIND_ENTRY
    rows[:, 2] = rng.integers(0, 1000, 16)
    rows = jnp.asarray(rows)
    ms_scan, _ = rt.probe_stage(rows, rt.init_device_maps(),
                                J.make_aux(), mode="scan")
    ms_fused, _ = rt.probe_stage(rows, rt.init_device_maps(),
                                 J.make_aux(), mode="fused")
    for k in ms_scan["w_arr"]:
        np.testing.assert_array_equal(np.asarray(ms_fused["w_arr"][k]),
                                      np.asarray(ms_scan["w_arr"][k]),
                                      err_msg=f"w_arr.{k} seed={seed}")


# ==========================================================================
# rules 1+2 on the live table: cross-slot widening in _recompute_vec
# ==========================================================================

LT_ARR = M.MapSpec("wt_counts", M.MapKind.ARRAY, max_entries=64)
LT_HASH = M.MapSpec("wt_hash", M.MapKind.HASH, max_entries=64)

HASH_STATIC_K = """
    ldxdw r6, [r1+ctx:layer]
    stdw [r10-8], {key}
    lddw r1, map:wt_hash
    mov r2, r10
    add r2, -8
    mov r3, r6
    call map_fetch_add
    mov r0, 0
    exit
"""

HASH_DYN = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:wt_hash
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

ARR_STATIC_ADD = """
    stdw [r10-8], {key}
    lddw r1, map:wt_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

ARR_STATIC_UPD = """
    ldxdw r6, [r1+ctx:layer]
    stdw [r10-8], {key}
    stxdw [r10-16], r6
    lddw r1, map:wt_counts
    mov r2, r10
    add r2, -8
    mov r3, r10
    add r3, -16
    mov r4, 0
    call map_update_elem
    mov r0, 0
    exit
"""


def _live_rt():
    rt = BpftimeRuntime()
    for sp in (LT_ARR, LT_HASH):
        rt.create_map(sp)
    rt.enable_live_attach(max_programs=4, max_insns=64,
                          arm=("uprobe:wt_blk", "uretprobe:wt_blk"))
    return rt


def _wt_tape(seed=7, n=24):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    rows = np.zeros((n, E.EVENT_WIDTH), np.int64)
    rows[:, 0] = E.SITES.get_or_create("wt_blk")
    rows[:, 1] = np.where(np.arange(n) % 3 == 2, E.KIND_EXIT,
                          E.KIND_ENTRY)
    rows[:, 2] = rng.integers(1, 32, n)
    return jnp.asarray(rows)


def test_static_hash_sharing_stays_batched():
    """Rule 2: two slots fetch-adding the SAME hash at static keys whose
    union is home-slot collision-free keep their batched lanes."""
    k1, k2 = _distinct_home_keys(LT_HASH.max_entries)
    rt = _live_rt()
    pa = rt.load_asm("wt_h1", HASH_STATIC_K.format(key=k1), [LT_HASH],
                     "uprobe")
    pb = rt.load_asm("wt_h2", HASH_STATIC_K.format(key=k2), [LT_HASH],
                     "uprobe")
    la = rt.attach(pa, "uprobe:wt_blk", mode="table")
    before = table_interp.WIDEN_STATS["batched_hash_widened"]
    lb = rt.attach(pb, "uretprobe:wt_blk", mode="table")
    assert rt.live.host["vec"][la.slot] == 1
    assert rt.live.host["vec"][lb.slot] == 1
    assert table_interp.WIDEN_STATS["batched_hash_widened"] > before


def test_colliding_home_slots_demote():
    k1, k2 = _colliding_home_keys(LT_HASH.max_entries)
    rt = _live_rt()
    pa = rt.load_asm("wt_h1", HASH_STATIC_K.format(key=k1), [LT_HASH],
                     "uprobe")
    pb = rt.load_asm("wt_h2", HASH_STATIC_K.format(key=k2), [LT_HASH],
                     "uprobe")
    la = rt.attach(pa, "uprobe:wt_blk", mode="table")
    lb = rt.attach(pb, "uretprobe:wt_blk", mode="table")
    assert rt.live.host["vec"][la.slot] == 0
    assert rt.live.host["vec"][lb.slot] == 0


def test_dynamic_hash_sharing_still_demotes():
    rt = _live_rt()
    pa = rt.load_asm("wt_h1", HASH_STATIC_K.format(key=1), [LT_HASH],
                     "uprobe")
    pb = rt.load_asm("wt_hd", HASH_DYN, [LT_HASH], "uprobe")
    la = rt.attach(pa, "uprobe:wt_blk", mode="table")
    lb = rt.attach(pb, "uretprobe:wt_blk", mode="table")
    assert rt.live.host["vec"][la.slot] == 0
    assert rt.live.host["vec"][lb.slot] == 0


def test_seq_noncommutative_disjoint_widens():
    """Rule 1 on the table lane: a batched fetch-add slot sharing an
    ARRAY with a sequential updater stays batched when their static cells
    are disjoint, demotes when they overlap."""
    rt = _live_rt()
    pa = rt.load_asm("wt_add", ARR_STATIC_ADD.format(key=2), [LT_ARR],
                     "uprobe")
    pu = rt.load_asm("wt_upd", ARR_STATIC_UPD.format(key=5), [LT_ARR],
                     "uprobe")
    la = rt.attach(pa, "uprobe:wt_blk", mode="table")
    before = table_interp.WIDEN_STATS["seq_disjoint_widened"]
    lu = rt.attach(pu, "uretprobe:wt_blk", mode="table")
    assert rt.live.host["vec"][lu.slot] == 0       # updater: sequential
    assert rt.live.host["vec"][la.slot] == 1       # disjoint: stays vec
    assert table_interp.WIDEN_STATS["seq_disjoint_widened"] > before

    rt2 = _live_rt()
    pa2 = rt2.load_asm("wt_add", ARR_STATIC_ADD.format(key=5), [LT_ARR],
                       "uprobe")
    pu2 = rt2.load_asm("wt_upd", ARR_STATIC_UPD.format(key=5), [LT_ARR],
                       "uprobe")
    la2 = rt2.attach(pa2, "uprobe:wt_blk", mode="table")
    rt2.attach(pu2, "uretprobe:wt_blk", mode="table")
    assert rt2.live.host["vec"][la2.slot] == 0     # overlap: demoted


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rule2_certificate_widened_table_matches_scan(seed):
    """Certificate: the widened (still-batched) hash-sharing slots are
    bit-identical to a scan-mode oracle across seeds."""
    k1, k2 = _distinct_home_keys(LT_HASH.max_entries)
    rt = _live_rt()
    pa = rt.load_asm("wt_h1", HASH_STATIC_K.format(key=k1), [LT_HASH],
                     "uprobe")
    pb = rt.load_asm("wt_h2", HASH_STATIC_K.format(key=k2), [LT_HASH],
                     "uprobe")
    la = rt.attach(pa, "uprobe:wt_blk", mode="table")
    lb = rt.attach(pb, "uretprobe:wt_blk", mode="table")
    assert rt.live.host["vec"][la.slot] == 1
    assert rt.live.host["vec"][lb.slot] == 1
    rows = _wt_tape(seed=seed)
    maps_live, _ = jax.jit(
        lambda r, m: rt.probe_stage(r, m, J.make_aux()))(
            rows, rt.init_device_maps())

    rt2 = BpftimeRuntime()
    for sp in (LT_ARR, LT_HASH):
        rt2.create_map(sp)
    p1 = rt2.load_asm("wt_h1", HASH_STATIC_K.format(key=k1), [LT_HASH],
                      "uprobe")
    rt2.attach(p1, "uprobe:wt_blk")
    p2 = rt2.load_asm("wt_h2", HASH_STATIC_K.format(key=k2), [LT_HASH],
                      "uprobe")
    rt2.attach(p2, "uretprobe:wt_blk")
    maps_scan, _ = jax.jit(
        lambda r, m: rt2.probe_stage(r, m, J.make_aux(), mode="scan"))(
            rows, rt2.init_device_maps())
    for k in maps_scan["wt_hash"]:
        np.testing.assert_array_equal(
            np.asarray(maps_live["wt_hash"][k]),
            np.asarray(maps_scan["wt_hash"][k]),
            err_msg=f"wt_hash.{k} seed={seed}")


# ==========================================================================
# rule 3: self-hash collision-free batched encodability
# ==========================================================================

def _rule3_text(k1, k2):
    return "\n".join([
        "ldxdw r6, [r1+0]",
        "jgt r6, 100, L1",
        f"stdw [r10-8], {k1}",
        "mov r1, 1", "mov r2, r10", "add r2, -8", "mov r3, 1",
        "call map_fetch_add",
        "L1:",
        f"stdw [r10-8], {k2}",
        "mov r1, 1", "mov r2, r10", "add r2, -8", "mov r3, 5",
        "call map_fetch_add",
        "mov r0, 0", "exit"])


def test_branchy_static_hash_batched_encodable():
    n = fuzz.FUZZ_SPECS[1].max_entries
    k1, k2 = _distinct_home_keys(n)
    vp = _verify(_rule3_text(k1, k2), fuzz.FUZZ_SPECS)
    assert table_interp.batched_encodable(vp)

    c1, c2 = _colliding_home_keys(n)
    vp2 = _verify(_rule3_text(c1, c2), fuzz.FUZZ_SPECS)
    assert not table_interp.batched_encodable(vp2)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_rule3_certificate_all_lanes_and_splits(seed):
    """Certificate: K seeds x N in {1,2,3} worker splits, every lane the
    gates admit — bit-identical for the branchy static-key hash program
    the old no-cond-branch restriction used to demote."""
    n = fuzz.FUZZ_SPECS[1].max_entries
    k1, k2 = _distinct_home_keys(n)
    case = fuzz.FuzzCase(seed=seed, text=_rule3_text(k1, k2),
                         tape=fuzz._gen_tape(random.Random(seed), 8))
    r = fuzz.run_case(case)
    assert r.accepted and not r.diverged, r.mismatches
    assert "batched" in r.lanes            # rule 3 admitted it
    assert "merge3" in r.lanes             # commutative + dead results


# ==========================================================================
# satellite: counter plane reset / thread-safety
# ==========================================================================

def test_verifier_stats_reset_and_concurrent_verify():
    verifier.reset_stats()
    assert verifier.STATS["verify_calls"] == 0
    text = _fetch_add(["stdw [r10-8], 1"])
    insns = asm.assemble(text).insns

    def worker():
        for _ in range(20):
            verifier.verify(insns, [ARR8], ctx_words=8)

    ts = [threading.Thread(target=worker) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert verifier.STATS["verify_calls"] == 80
    verifier.reset_stats()
    assert verifier.STATS["verify_calls"] == 0
    assert type(verifier.STATS) is dict    # test_reloc pins plain-dict use
