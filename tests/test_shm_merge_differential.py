"""Differential/property harness for the interprocess map plane
(DESIGN.md §10): for random interleaved event tapes split across N worker
processes, the daemon-merged global maps must be bit-identical to the
single-process oracle that scans the whole tape in (step, wid, seq) order.

Covers all 5 map kinds, N in {1, 2, 3}, including hash collisions (tiny
table), tombstone deletes (broken probe chains), and ringbuf
overwrite/dropped propagation. The merge contract the generator enforces
(and DESIGN.md documents): cross-worker ops on SHARED state are
commutative (fetch-add / hist / ringbuf-emit); non-commutative hash ops
(update/delete) only ever run on the key's OWNER worker.

Deterministic corpus runs without hypothesis; the property test adds
randomized tapes when hypothesis is installed (importorskip, as elsewhere).
"""
import shutil
import tempfile

import numpy as np
import pytest

from repro.core import daemon as D, maps as M, shm as SH

SPECS = [
    M.MapSpec("arr", M.MapKind.ARRAY, max_entries=16),
    M.MapSpec("pc", M.MapKind.PERCPU_ARRAY, max_entries=8, num_shards=2),
    M.MapSpec("hist", M.MapKind.LOG2HIST),
    # capacity 8 with a 7-key universe: collisions guaranteed, no overflow
    M.MapSpec("hsh", M.MapKind.HASH, max_entries=8),
    M.MapSpec("rb", M.MapKind.RINGBUF, max_entries=6, rec_width=3,
              flags={"step_lane": 0}),
]

OWNED_KEYS = [3, 11, 19, 27]        # 3, 11, 19 collide in an 8-slot table
SHARED_KEYS = [5, 42, 99]           # fetch-add only, any worker


# --------------------------------------------------------------------------
# tape model: (step, wid, wseq, ev) — ev = (op, *args)
# --------------------------------------------------------------------------

def apply_event(states: dict, ev: tuple, step: int) -> None:
    op = ev[0]
    if op == "arr_add":
        M.n_array_fetch_add(states["arr"], ev[1], ev[2])
    elif op == "pc_add":
        shard, idx, delta = ev[1:]
        if 0 <= idx < states["pc"]["values"].shape[1]:
            states["pc"]["values"][shard, idx] += delta
    elif op == "hist":
        M.n_hist_add(states["hist"], ev[1])
    elif op == "hash_add":
        M.n_hash_fetch_add(states["hsh"], ev[1], ev[2])
    elif op == "hash_set":
        M.n_hash_update(states["hsh"], ev[1], ev[2])
    elif op == "hash_del":
        M.n_hash_delete(states["hsh"], ev[1])
    elif op == "rb":
        M.n_ringbuf_emit(states["rb"], [step, ev[1], ev[2]])
    else:  # pragma: no cover
        raise AssertionError(op)


def gen_tape(rng: np.random.Generator, n_workers: int, n_events: int,
             p_step: float = 0.3, ops=None) -> list[tuple]:
    ops = ops or ("arr_add", "pc_add", "hist", "hash_add", "hash_set",
                  "hash_del", "rb")
    step = 0
    wseq = [0] * n_workers
    tape = []
    for i in range(n_events):
        if rng.random() < p_step:
            step += 1
        op = ops[rng.integers(len(ops))]
        if op in ("hash_set", "hash_del"):
            k = OWNED_KEYS[rng.integers(len(OWNED_KEYS))]
            wid = k % n_workers                     # owner-only
            ev = (op, k, int(rng.integers(-50, 50))) if op == "hash_set" \
                else (op, k)
        elif op == "hash_add":
            if rng.random() < 0.5:
                k = OWNED_KEYS[rng.integers(len(OWNED_KEYS))]
                wid = k % n_workers                 # ordered vs set/del
            else:
                k = SHARED_KEYS[rng.integers(len(SHARED_KEYS))]
                wid = int(rng.integers(n_workers))
            ev = (op, k, int(rng.integers(-20, 20)))
        else:
            wid = int(rng.integers(n_workers))
            if op == "arr_add":
                ev = (op, int(rng.integers(-2, 18)),  # incl. out-of-bounds
                      int(rng.integers(-9, 10)))
            elif op == "pc_add":
                ev = (op, int(rng.integers(2)), int(rng.integers(8)),
                      int(rng.integers(1, 7)))
            elif op == "hist":
                ev = (op, int(rng.integers(-4, 1 << 20)))
            else:
                ev = ("rb", int(rng.integers(1000)), i)
        tape.append((step, wid, wseq[wid], ev))
        wseq[wid] += 1
    return tape


def oracle_states(tape: list[tuple]) -> dict:
    """The single-process scan oracle: the whole tape in the canonical
    interleave order (step, wid, seq) on the numpy twins."""
    st = M.init_states(SPECS, np)
    for step, wid, wseq, ev in sorted(tape, key=lambda t: t[:3]):
        apply_event(st, ev, step)
    return st


def run_fleet(root: str, tape: list[tuple], n_workers: int,
              rounds: int = 3) -> dict:
    """Worker processes' side, in-process: each worker applies its subtape
    in `rounds` publish chunks with the aggregator polling between chunks
    (exercising incremental delta extraction), then a final poll."""
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(n_workers)}
    states = {w: M.init_states(SPECS, np) for w in range(n_workers)}
    per_worker = {w: [t for t in tape if t[1] == w]
                  for w in range(n_workers)}
    chunks = {w: np.array_split(np.arange(len(per_worker[w])), rounds)
              for w in range(n_workers)}
    agg = D.Aggregator(root)
    for r in range(rounds):
        for w in range(n_workers):
            for i in chunks[w][r]:
                step, _, _, ev = per_worker[w][i]
                apply_event(states[w], ev, step)
            regions[w].publish_device(states[w])
        agg.poll_once()
    return agg.poll_once()


def assert_global_matches_oracle(root: str, oracle: dict) -> None:
    g = SH.GlobalView.attach(root)
    for spec in SPECS:
        got = g.snapshot(spec.name)
        if spec.kind == M.MapKind.HASH:
            # the published global table is canonical (sorted-key rebuild);
            # compare against the canonicalized oracle CONTENT — probe-
            # reachable keys and values, bit-identical table layout
            want = M.n_hash_canonical(spec, M.n_hash_items(oracle[spec.name]))
        else:
            want = oracle[spec.name]
        for f in got:
            np.testing.assert_array_equal(
                got[f], np.asarray(want[f]),
                err_msg=f"{spec.name}.{f}")


def _roundtrip(tape, n_workers, rounds=3):
    root = tempfile.mkdtemp(prefix="mergediff_")
    try:
        run_fleet(root, tape, n_workers, rounds=rounds)
        assert_global_matches_oracle(root, oracle_states(tape))
    finally:
        shutil.rmtree(root, ignore_errors=True)


# --------------------------------------------------------------------------
# deterministic corpus
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_workers", [1, 2, 3])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_tape_all_kinds(n_workers, seed):
    rng = np.random.default_rng(seed)
    tape = gen_tape(rng, n_workers, n_events=80)
    _roundtrip(tape, n_workers)


@pytest.mark.parametrize("n_workers", [2, 3])
def test_hash_collisions_and_tombstones(n_workers):
    """set/del/re-add churn on colliding owned keys: broken probe chains on
    the worker side must still merge to the oracle's visible content."""
    rng = np.random.default_rng(7)
    tape = gen_tape(rng, n_workers, n_events=120,
                    ops=("hash_add", "hash_set", "hash_del"))
    # guarantee the tombstone scenario explicitly: insert colliding chain,
    # delete the middle, re-add past it — all on each key's owner
    step = max(t[0] for t in tape) + 1
    wseq = {w: 1 + max((t[2] for t in tape if t[1] == w), default=0)
            for w in range(n_workers)}
    for k in (3, 11, 19):
        w = k % n_workers
        tape.append((step, w, wseq[w], ("hash_set", k, k * 10)))
        wseq[w] += 1
    w = 11 % n_workers
    tape.append((step, w, wseq[w], ("hash_del", 11)))
    wseq[w] += 1
    w = 19 % n_workers
    tape.append((step, w, wseq[w], ("hash_add", 19, 5)))
    _roundtrip(tape, n_workers)


@pytest.mark.parametrize("n_workers", [1, 2, 3])
def test_ringbuf_overwrite_and_dropped(n_workers):
    """More emits than global capacity: the merged ring holds exactly the
    oracle's surviving window, head counts every emit, dropped propagates
    from the global head (not the per-worker counters)."""
    rng = np.random.default_rng(11)
    tape = gen_tape(rng, n_workers, n_events=64, ops=("rb",))
    _roundtrip(tape, n_workers)
    # cross-check the dropped accounting directly
    oracle = oracle_states(tape)
    assert int(oracle["rb"]["head"][0]) == 64
    assert int(oracle["rb"]["dropped"][0]) == 64 - 6


def test_single_publish_no_chunking():
    """rounds=1 (one cumulative publish per worker) must equal the fully
    incremental path — delta extraction against a zero baseline."""
    rng = np.random.default_rng(13)
    tape = gen_tape(rng, 3, n_events=60)
    _roundtrip(tape, 3, rounds=1)


def test_empty_and_skewed_workers():
    """One worker gets the whole tape, the others none."""
    rng = np.random.default_rng(17)
    tape = gen_tape(rng, 1, n_events=40)
    # re-label as a 3-worker fleet where w1/w2 stay silent
    _roundtrip(tape, 3)


def test_worker_restart_resets_baseline(tmp_path):
    """A worker that reboots (new boot id, zeroed maps) must not subtract
    its old counts: the aggregator resets that worker's baseline and keeps
    the old incarnation's contribution."""
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][1] = 5
    region.publish_device(st)
    agg = D.Aggregator(root)
    agg.poll_once()
    # reboot: create() rewrites worker.json with a fresh boot id + zero maps
    region2 = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st2 = M.init_states(SPECS, np)
    st2["arr"]["values"][1] = 2
    region2.publish_device(st2)
    agg.poll_once()
    merged = SH.GlobalView.attach(root).snapshot("arr")["values"]
    assert int(merged[1]) == 7          # 5 (old incarnation) + 2 (new)


def _mark_worker_dead(root: str, wid: str) -> dict:
    """Simulate a crashed worker: point worker.json at a nonexistent pid
    (keeping boot id), as if the process died without cleanup."""
    import json
    import os
    p = os.path.join(root, "workers", wid, "worker.json")
    with open(p) as f:
        info = json.load(f)
    old = dict(info)
    info["pid"] = 2 ** 22 + 11  # above default pid_max: never a live pid
    # atomic replace (fresh inode): the registry parse cache keys on stat
    SH._atomic_json(p, info)
    return old


def test_dead_worker_readmitted_on_new_boot(tmp_path):
    """A worker that dies and is later restarted under the SAME id must be
    re-admitted (fresh baseline) once its boot id changes — death is not a
    permanent exclusion of the id, only of the incarnation."""
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][1] = 5
    region.publish_device(st)
    agg = D.Aggregator(root)
    agg.poll_once()

    _mark_worker_dead(root, "w0")
    status = agg.poll_once()
    assert status["dead"] == ["w0"]
    assert int(SH.GlobalView.attach(root).snapshot("arr")["values"][1]) == 5

    # supervisor restarts the worker: same id, new boot, fresh maps
    region2 = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st2 = M.init_states(SPECS, np)
    st2["arr"]["values"][1] = 2
    region2.publish_device(st2)
    status = agg.poll_once()
    assert status["alive"] == ["w0"] and status["dead"] == []
    assert int(SH.GlobalView.attach(root).snapshot("arr")["values"][1]) == 7


def test_seq_regression_never_folds_negative_delta(tmp_path):
    """The restart race: a new incarnation zeroes the shm section BEFORE
    rewriting worker.json, so the aggregator (still seeing the dead old
    pid and old boot) would harvest an all-zero snapshot and fold it as a
    -everything delta. The seqlock regression guard forfeits that harvest
    instead; the merged contribution stays."""
    import json
    import os
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][0] = 100
    M.n_hash_update(st["hsh"], 3, 7)
    region.publish_device(st)
    agg = D.Aggregator(root)
    agg.poll_once()
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][0]) == 100

    old_info = _mark_worker_dead(root, "w0")
    # restart under way: section re-created (zeroed, seq back to 0) while
    # worker.json still names the dead old incarnation
    region2 = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    p = os.path.join(root, "workers", "w0", "worker.json")
    with open(p) as f:
        new_info = json.load(f)
    old_info["pid"] = 2 ** 22 + 11
    SH._atomic_json(p, old_info)

    status = agg.poll_once()            # harvest forfeited, not -100'd
    assert status["dead"] == ["w0"]
    assert int(g.snapshot("arr")["values"][0]) == 100
    assert M.n_hash_items(agg.hash_tbl["hsh"]) == {3: 7}

    # the restart completes: worker.json now names the live new boot
    SH._atomic_json(p, new_info)
    st2 = M.init_states(SPECS, np)
    st2["arr"]["values"][0] = 1
    region2.publish_device(st2)
    status = agg.poll_once()
    assert status["alive"] == ["w0"] and status["dead"] == []
    assert int(g.snapshot("arr")["values"][0]) == 101


def test_restart_then_die_within_one_poll_not_double_counted(tmp_path):
    """A worker that restarts AND dies between two polls: the harvest must
    diff against the NEW incarnation's zero baseline (restart detection
    runs before the dead path) and record death under the new boot id, so
    re-admission can't double-count the final contribution."""
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][1] = 5
    region.publish_device(st)
    agg = D.Aggregator(root)
    agg.poll_once()
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][1]) == 5

    # restart: new boot, publish TWICE (seq 4 >= tracked 2, so the
    # SeqRegression guard alone cannot catch this), then die
    region2 = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st2 = M.init_states(SPECS, np)
    st2["arr"]["values"][1] = 3
    region2.publish_device(st2)
    region2.publish_device(st2)
    _mark_worker_dead(root, "w0")

    status = agg.poll_once()
    assert status["dead"] == ["w0"]
    assert int(g.snapshot("arr")["values"][1]) == 8   # 5 + 3, not 5-5+3
    status = agg.poll_once()                          # no re-admission
    assert status["dead"] == ["w0"] and status["alive"] == []
    assert int(g.snapshot("arr")["values"][1]) == 8   # not double-counted


def test_worker_restart_ringbuf_step_regression_stays_monotone(tmp_path):
    """A restarted worker whose step counter restarts at 0 must still
    produce monotone interleave keys (step tags clamped to the worker's
    floor): new records sort AFTER the old incarnation's, never before."""
    root = str(tmp_path / "shm")
    spec = next(s for s in SPECS if s.kind == M.MapKind.RINGBUF)
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    for i in range(5):
        M.n_ringbuf_emit(st["rb"], [5 + i, 100 + i, i])   # steps 5..9
    region.publish_device(st)
    agg = D.Aggregator(root)
    agg.poll_once()

    region2 = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st2 = M.init_states(SPECS, np)
    for i in range(3):
        M.n_ringbuf_emit(st2["rb"], [i, 200 + i, i])      # steps regress
    region2.publish_device(st2)
    agg.poll_once()

    oracle = M.init_state(spec, np)
    for i in range(5):
        M.n_ringbuf_emit(oracle, [5 + i, 100 + i, i])
    for i in range(3):
        M.n_ringbuf_emit(oracle, [i, 200 + i, i])
    merged = SH.GlobalView.attach(root).snapshot("rb")
    for f in ("data", "head", "dropped"):
        np.testing.assert_array_equal(merged[f], np.asarray(oracle[f]),
                                      err_msg=f"rb.{f}")


def test_recreate_region_reuses_inodes_and_seq_discipline(tmp_path):
    """A worker restart must NOT truncate section files in place (a live
    aggregator's mmap of that inode would SIGBUS mid-read): re-creation
    reuses the inodes and zeroes them under the seqlock, landing on seq=0
    (the aggregator's SeqRegression signal)."""
    import os
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][0] = 9
    region.publish_device(st)
    assert int(region.seq[0]) == 2

    base = os.path.join(root, "workers", "w0")
    paths = [os.path.join(base, "device", "arr.values.npy"),
             os.path.join(base, "device", ".seq.npy"),
             os.path.join(base, "control", ".reqseq.npy")]
    inodes = [os.stat(p).st_ino for p in paths]

    region2 = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    assert [os.stat(p).st_ino for p in paths] == inodes
    # the OLD handle's mmaps track the same inode: zeroed, seq back to 0
    assert int(region.seq[0]) == 0
    assert int(region.device["arr"]["values"][0]) == 0
    out, seq, _ = region2.snapshot_device_meta("arr")
    assert seq == 0 and int(out["values"][0]) == 0


def test_cli_attach_unknown_worker_rejected(tmp_path, capsys):
    root = str(tmp_path / "shm")
    SH.ShmRegion.create(root, SPECS, worker_id="w0")
    objpath = tmp_path / "prog.json"
    objpath.write_text("{}")            # never read: validation fails first
    rc = D.main([root, "attach", str(objpath), "--worker", "w9"])
    assert rc == 1
    assert "unknown worker" in capsys.readouterr().err
    rc = D.main([root, "detach", "1", "--worker", "w9"])
    assert rc == 1
    assert "unknown worker" in capsys.readouterr().err


def test_single_process_region_rebuilds_on_spec_change(tmp_path):
    """worker_id=None has exactly one creator, so a re-run with evolved
    specs rebuilds the region (seed behavior); fleet workers must still
    agree with the first writer."""
    root = str(tmp_path / "shm")
    SH.ShmRegion.create(root, SPECS)
    new_specs = [M.MapSpec("other", M.MapKind.ARRAY, max_entries=4)]
    region = SH.ShmRegion.create(root, new_specs)
    assert [s.name for s in SH.read_meta_specs(root)] == ["other"]
    region.publish_device({"other": {"values": np.arange(4)}})
    np.testing.assert_array_equal(
        region.snapshot_device("other")["values"], np.arange(4))


def test_cli_map_unknown_worker_and_legacy_watcher_on_fleet(tmp_path,
                                                            capsys):
    root = str(tmp_path / "shm")
    SH.ShmRegion.create(root, SPECS, worker_id="w0")
    rc = D.main([root, "map", "dump", "--section", "device",
                 "--worker", "w9"])
    assert rc == 1
    assert "unknown worker" in capsys.readouterr().err
    # the legacy single-process watcher points at the subcommands instead
    # of dying on the missing top-level section
    rc = D.main([root, "--once"])
    assert rc == 1
    assert "fleet-layout" in capsys.readouterr().err


def test_global_hash_overflow_counted_not_silent(tmp_path):
    """When the UNION of worker keys overflows the spec-sized global
    table, the lost adds are counted and surfaced in the status — never
    silently dropped."""
    root = str(tmp_path / "shm")
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(2)}
    for w, base in ((0, 0), (1, 100)):
        st = M.init_states(SPECS, np)
        for k in range(6):                       # 6 + 6 keys, capacity 8
            M.n_hash_fetch_add(st["hsh"], base + k, 1)
        regions[w].publish_device(st)
    agg = D.Aggregator(root)
    status = agg.poll_once()
    assert status["hash_dropped"]["hsh"] == 4
    assert len(M.n_hash_items(agg.hash_tbl["hsh"])) == 8


def test_worker_restart_ringbuf_stream_monotone(tmp_path):
    """A restarted worker's ringbuf positions continue AFTER the old
    incarnation's final head (rb_offset): the global head never regresses
    and the merged ring equals one ring that saw the concatenated stream."""
    root = str(tmp_path / "shm")
    spec = next(s for s in SPECS if s.kind == M.MapKind.RINGBUF)
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    for i in range(5):
        M.n_ringbuf_emit(st["rb"], [0, 100 + i, i])
    region.publish_device(st)
    agg = D.Aggregator(root)
    agg.poll_once()

    # reboot: fresh boot id, zeroed maps, local positions restart at 0
    region2 = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st2 = M.init_states(SPECS, np)
    for i in range(5, 8):
        M.n_ringbuf_emit(st2["rb"], [0, 100 + i, i])
    region2.publish_device(st2)
    agg.poll_once()

    oracle = M.init_state(spec, np)
    for i in range(8):
        M.n_ringbuf_emit(oracle, [0, 100 + i, i])
    merged = SH.GlobalView.attach(root).snapshot("rb")
    for f in ("data", "head", "dropped"):
        np.testing.assert_array_equal(merged[f], np.asarray(oracle[f]),
                                      err_msg=f"rb.{f}")


def test_incompatible_flags_rejected(tmp_path):
    """flags are load-bearing (step_lane drives the ringbuf interleave):
    a worker joining with different flags must be rejected, not silently
    merged under the first writer's semantics."""
    root = str(tmp_path / "shm")
    SH.ShmRegion.create(root, SPECS, worker_id="w0")
    bad = [s if s.name != "rb" else
           M.MapSpec("rb", M.MapKind.RINGBUF, max_entries=6, rec_width=3)
           for s in SPECS]
    with pytest.raises(ValueError, match="incompatible"):
        SH.ShmRegion.create(root, bad, worker_id="w1")


def test_aggregator_restart_preserves_reader_mmaps(tmp_path):
    """Restarting the aggregator over an already-published global section
    must reset it UNDER the seqlock, in the same files: a reader holding
    the old mmaps keeps seeing consistent (never torn) state and picks up
    the fresh merge without reattaching."""
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][2] = 5
    region.publish_device(st)
    D.Aggregator(root).poll_once()

    reader = SH.GlobalView.attach(root)
    held = reader.section["arr"]["values"]        # mmap of the old files
    assert int(held[2]) == 5

    agg2 = D.Aggregator(root)                     # restart over live section
    assert int(reader.seq[0]) % 2 == 0            # parity preserved
    agg2.poll_once()
    # same inodes: the held mapping tracks the fresh merge
    assert int(held[2]) == 5
    np.testing.assert_array_equal(reader.snapshot("arr")["values"][2], 5)


# --------------------------------------------------------------------------
# maps-level twins (the machinery the aggregator reuses)
# --------------------------------------------------------------------------

def test_n_hash_fetch_add_batch_matches_twins():
    """numpy batch twin vs sequential numpy twin vs jnp batch twin — all
    bit-identical, including a broken probe chain."""
    import jax
    import jax.numpy as jnp
    spec = M.MapSpec("h", M.MapKind.HASH, max_entries=8)
    st_seq, st_bat = M.init_state(spec, np), M.init_state(spec, np)
    st_j = M.init_state(spec, jnp)
    for s in (st_seq, st_bat):
        for k, v in ((3, 10), (11, 20), (19, 30)):
            M.n_hash_fetch_add(s, k, v)
        M.n_hash_delete(s, 11)
    for k, v in ((3, 10), (11, 20), (19, 30)):
        st_j, _ = M.j_hash_fetch_add(st_j, jnp.int64(k), jnp.int64(v),
                                     jnp.asarray(True))
    st_j, _ = M.j_hash_delete(st_j, jnp.int64(11), jnp.asarray(True))

    keys = np.array([19, 42, 3, 19, 42, 99, 3, 27, 11, 42], np.int64)
    deltas = np.arange(1, 11, dtype=np.int64)
    ok = np.array([1, 1, 1, 1, 0, 1, 1, 1, 1, 1], bool)
    for k, d, o in zip(keys, deltas, ok):
        if o:
            M.n_hash_fetch_add(st_seq, int(k), int(d))
    M.n_hash_fetch_add_batch(st_bat, keys, deltas, ok)
    st_j = M.j_hash_fetch_add_batch(st_j, jnp.asarray(keys),
                                    jnp.asarray(deltas), jnp.asarray(ok))
    for f in ("keys", "used", "values"):
        np.testing.assert_array_equal(st_bat[f], st_seq[f],
                                      err_msg=f"np-batch {f}")
        np.testing.assert_array_equal(np.asarray(st_j[f]), st_seq[f],
                                      err_msg=f"jnp-batch {f}")


def test_n_hash_items_reachability():
    """Items are exactly the lookup-visible keys — a zombie entry behind a
    tombstone is excluded, like a sequential probe would miss it."""
    spec = M.MapSpec("h", M.MapKind.HASH, max_entries=8)
    st = M.init_state(spec, np)
    for k, v in ((3, 10), (11, 20), (19, 30)):
        M.n_hash_fetch_add(st, k, v)
    M.n_hash_delete(st, 11)
    items = M.n_hash_items(st)
    for k in (3, 11, 19, 27):
        slot, _ = M._n_hash_find(st, k)
        if slot is None:
            assert k not in items
        else:
            assert items[k] == int(st["values"][slot])


def test_summary_delta_merge_twins():
    spec = M.MapSpec("a", M.MapKind.ARRAY, max_entries=4)
    base = {"values": np.array([1, 2, 3, 4], np.int64)}
    cur = {"values": np.array([1, 5, 3, 10], np.int64)}
    delta = M.n_summary_delta(spec, cur, base)
    np.testing.assert_array_equal(delta["values"], [0, 3, 0, 6])
    acc = {"values": np.array([100, 0, 0, 1], np.int64)}
    M.n_summary_merge(spec, acc, delta)
    np.testing.assert_array_equal(acc["values"], [100, 3, 0, 7])
    # jnp twins agree
    import jax.numpy as jnp
    jd = M.j_summary_delta(spec, {"values": jnp.asarray(cur["values"])},
                           {"values": jnp.asarray(base["values"])})
    np.testing.assert_array_equal(np.asarray(jd["values"]), delta["values"])


def test_ringbuf_merge_single_worker_is_identity():
    spec = M.MapSpec("rb", M.MapKind.RINGBUF, max_entries=4, rec_width=2,
                     flags={"step_lane": 0})
    st = M.init_state(spec, np)
    for i in range(9):
        M.n_ringbuf_emit(st, [i, 100 + i])
    tagged, head = M.n_ringbuf_tagged(st, "w0", 0, step_lane=0)
    merged = M.ringbuf_merge_global(spec, tagged, head)
    for f in ("data", "head", "dropped"):
        np.testing.assert_array_equal(merged[f], st[f], err_msg=f)


# --------------------------------------------------------------------------
# property-based (hypothesis, optional like the rest of the suite)
# --------------------------------------------------------------------------

try:        # hypothesis is optional: only the property test needs it
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(n_workers=hst.integers(1, 3),
           n_events=hst.integers(1, 120),
           seed=hst.integers(0, 2**31 - 1),
           p_step=hst.floats(0.0, 1.0),
           rounds=hst.integers(1, 4))
    def test_property_merge_equals_oracle(n_workers, n_events, seed, p_step,
                                          rounds):
        rng = np.random.default_rng(seed)
        tape = gen_tape(rng, n_workers, n_events, p_step=p_step)
        _roundtrip(tape, n_workers, rounds=rounds)
