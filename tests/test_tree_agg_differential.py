"""Differential testing of HIERARCHICAL aggregation (DESIGN.md §15).

The tree (worker -> node-local aggregator -> global) must be observably
equivalent to the flat plane: for any tape and any topology, the published
global view is bit-identical to the flat sequential oracle from
test_shm_merge_differential — same summary sums, same canonical hash
tables, same (step, wid, pos) ringbuf interleave. The per-kind merge twins
were designed commutative and associative precisely so they reassociate
into a tree; these tests are the proof obligation for that claim.

Also covers the tree-specific failure rules: a worker restarting mid-tree
(its node resets the baseline, the old contribution survives), and a dead
node whose unconsumed stream batches the parent harvests before retiring
it (workers orphaned, re-admission on a new boot keeps the cursor).
"""
import numpy as np
import pytest

from repro.core import daemon as D, maps as M, shm as SH
from repro.core.treeagg import NodeAggregator, TreeAggregator, plan_tree
from test_shm_merge_differential import (
    SPECS, apply_event, assert_global_matches_oracle, gen_tape,
    oracle_states)


def run_tree(root: str, tape: list[tuple], n_workers: int, fan_in: int,
             depth: int, rounds: int = 3, device_fold: bool = True) -> dict:
    """run_fleet's tree twin: workers apply their subtapes in `rounds`
    publish chunks with a full tree cycle (leaves first, then the root)
    between chunks, exercising incremental delta-batch extraction at every
    level."""
    # zero-padded ids: the ringbuf interleave key is (step, wid, pos) with
    # wid compared as the REGISTERED string — w02 < w10 keeps the string
    # order equal to the oracle's numeric order at any fleet size
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w:02d}")
               for w in range(n_workers)}
    states = {w: M.init_states(SPECS, np) for w in range(n_workers)}
    per_worker = {w: [t for t in tape if t[1] == w]
                  for w in range(n_workers)}
    chunks = {w: np.array_split(np.arange(len(per_worker[w])), rounds)
              for w in range(n_workers)}
    cfg = D.AggregatorConfig(device_fold=device_fold)
    tree = TreeAggregator(root, fan_in=fan_in, depth=depth, config=cfg,
                          worker_ids=[f"w{w:02d}"
                                      for w in range(n_workers)])
    for r in range(rounds):
        for w in range(n_workers):
            for i in chunks[w][r]:
                step, _, _, ev = per_worker[w][i]
                apply_event(states[w], ev, step)
            regions[w].publish_device(states[w])
        tree.poll_once()
    return tree.poll_once()


# --------------------------------------------------------------------------
# random tapes x random topologies: bit-identity against the flat oracle
# --------------------------------------------------------------------------

TOPOLOGIES = [
    # (n_workers, fan_in, depth, seed) — fan-in 2..8, depth 1..3, 4..32
    (4, 2, 1, 0),
    (6, 2, 3, 1),
    (8, 3, 2, 2),
    (12, 4, 2, 3),
    (16, 4, 1, 4),
    (24, 5, 2, 5),
    (32, 8, 1, 6),
    (32, 8, 2, 7),
]


@pytest.mark.parametrize("n_workers,fan_in,depth,seed", TOPOLOGIES)
def test_tree_matches_flat_oracle(tmp_path, n_workers, fan_in, depth, seed):
    rng = np.random.default_rng(seed)
    tape = gen_tape(rng, n_workers, n_events=max(150, 8 * n_workers))
    run_tree(str(tmp_path / "shm"), tape, n_workers, fan_in, depth)
    assert_global_matches_oracle(str(tmp_path / "shm"),
                                 oracle_states(tape))


@pytest.mark.parametrize("ops", [
    ("arr_add", "arr_set"),
    ("pc_add",),
    ("hist_obs",),
    ("hash_add", "hash_set", "hash_del"),
    ("rb_emit",),
])
@pytest.mark.parametrize("n_workers,fan_in,depth", [(8, 3, 2), (9, 2, 3)])
def test_tree_per_kind_identity(tmp_path, ops, n_workers, fan_in, depth):
    """Each map kind's merge twin reassociates independently: tapes
    restricted to one kind stay bit-identical through any topology."""
    rng = np.random.default_rng(sum(map(ord, "".join(ops))) % 997)
    tape = gen_tape(rng, n_workers, n_events=120, ops=ops)
    run_tree(str(tmp_path / "shm"), tape, n_workers, fan_in, depth)
    assert_global_matches_oracle(str(tmp_path / "shm"),
                                 oracle_states(tape))


def test_tree_numpy_fold_twin_identical(tmp_path):
    """device_fold=False (numpy twins) and the jitted device reductions
    are merge twins of each other: both bit-identical to the oracle."""
    rng = np.random.default_rng(11)
    tape = gen_tape(rng, 8, n_events=200)
    run_tree(str(tmp_path / "a"), tape, 8, 3, 2, device_fold=True)
    run_tree(str(tmp_path / "b"), tape, 8, 3, 2, device_fold=False)
    oracle = oracle_states(tape)
    assert_global_matches_oracle(str(tmp_path / "a"), oracle)
    assert_global_matches_oracle(str(tmp_path / "b"), oracle)


def test_plan_tree_shapes():
    """Topology planner invariants: every worker lands in exactly one
    level-0 node, every node has exactly one consumer (parent node or the
    root), no single-child chains."""
    for nw, fi, dp in [(4, 2, 1), (32, 8, 2), (7, 3, 3), (2, 2, 3)]:
        plan = plan_tree([f"w{i}" for i in range(nw)], fan_in=fi, depth=dp)
        covered = [w for nd in plan["levels"][0] for w in nd["workers"]]
        assert sorted(covered) == sorted(f"w{i}" for i in range(nw))
        consumed = [c for nd in plan["nodes"].values()
                    for c in nd["children"]]
        tops = [nid for nid, nd in plan["nodes"].items()
                if nd["parent"] is None]
        assert sorted(consumed + tops) == sorted(plan["nodes"])
        for lvl in plan["levels"][1:]:
            for nd in lvl:
                assert len(nd["children"]) >= 1
            assert sum(len(nd["children"]) for nd in lvl) > len(lvl) \
                or len(lvl) == 1


# --------------------------------------------------------------------------
# worker restart mid-tree
# --------------------------------------------------------------------------

def test_worker_restart_mid_tree_keeps_old_contribution(tmp_path):
    """A worker rebooting under a node aggregator: the node resets that
    worker's baseline (never subtracts the old counts), forwards only the
    new incarnation's deltas, and the global view ends at old + new —
    the same rule the flat plane pins, proven through a stream hop."""
    root = str(tmp_path / "shm")
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(4)}
    states = {w: M.init_states(SPECS, np) for w in range(4)}
    for w in range(4):
        states[w]["arr"]["values"][1] = 5 + w
        M.n_hash_update(states[w]["hsh"], 3 + 8 * w, 10 + w)
        regions[w].publish_device(states[w])
    tree = TreeAggregator(root, fan_in=2, depth=1,
                          worker_ids=[f"w{w}" for w in range(4)])
    tree.poll_once()
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][1]) == 5 + 6 + 7 + 8

    # w1 reboots: fresh boot id, zeroed maps, then publishes new counts
    region2 = SH.ShmRegion.create(root, SPECS, worker_id="w1")
    st2 = M.init_states(SPECS, np)
    st2["arr"]["values"][1] = 2
    M.n_hash_update(st2["hsh"], 11, 100)
    region2.publish_device(st2)
    tree.poll_once()
    tree.poll_once()
    assert int(g.snapshot("arr")["values"][1]) == 5 + 6 + 7 + 8 + 2
    # key 11 (= 3 + 8*1): old incarnation set it to 11, the rebooted one
    # to 100 — a fresh baseline makes the new content a +100 delta
    items = M.n_hash_items(tree.root_agg.hash_tbl["hsh"])
    assert items[11] == 11 + 100


def test_worker_dies_under_node_contribution_stays(tmp_path):
    """Dead-worker harvest one level down: the node harvests the final
    snapshot, reports the worker dead in its batch, and the root's global
    view keeps the contribution while listing the worker dead."""
    root = str(tmp_path / "shm")
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(4)}
    states = {w: M.init_states(SPECS, np) for w in range(4)}
    for w in range(4):
        states[w]["arr"]["values"][2] = 10 * (w + 1)
        regions[w].publish_device(states[w])
    tree = TreeAggregator(root, fan_in=2, depth=1,
                          worker_ids=[f"w{w}" for w in range(4)])
    tree.poll_once()

    from test_shm_merge_differential import _mark_worker_dead
    _mark_worker_dead(root, "w2")
    tree.poll_once()
    status = tree.poll_once()
    assert "w2" in status["dead"] and "w2" not in status["alive"]
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][2]) == 10 + 20 + 30 + 40


# --------------------------------------------------------------------------
# dead node: harvest-only retirement
# --------------------------------------------------------------------------

def _mark_node_dead(root: str, nid: str) -> None:
    import json
    import os
    p = os.path.join(SH.node_base(root, nid), "node.json")
    with open(p) as f:
        info = json.load(f)
    info["pid"] = 2 ** 22 + 11
    # atomic replace (fresh inode): the registry parse cache keys on stat
    SH._atomic_json(p, info)


def test_dead_node_remaining_batches_harvested(tmp_path):
    """A node that died with committed-but-unconsumed batches: the parent
    drains the stream to its head, folds every batch, THEN retires the
    node (DEAD, node_gone). Nothing emitted is ever lost; nothing is
    double-folded; the node's workers go orphaned (not silently adopted —
    each worker has exactly one fold path)."""
    root = str(tmp_path / "shm")
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(2)}
    states = {w: M.init_states(SPECS, np) for w in range(2)}
    for w in range(2):
        states[w]["arr"]["values"][0] = 7 * (w + 1)
        regions[w].publish_device(states[w])

    node = NodeAggregator(root, "n0_0", workers=["w0", "w1"])
    node.poll_once()                       # emits batch 1
    for w in range(2):
        states[w]["arr"]["values"][0] += 100
        regions[w].publish_device(states[w])
    node.poll_once()                       # emits batch 2
    assert node.stream.head() == 2

    _mark_node_dead(root, "n0_0")
    root_agg = D.Aggregator(root)          # has consumed NOTHING yet
    status = root_agg.poll_once()
    # both batches harvested before retirement
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][0]) == 107 + 114
    assert status["nodes"]["n0_0"]["alive"] is False
    assert status["nodes"]["n0_0"]["last_seq"] == 2
    assert status["health"]["n0_0"]["state"] == D.DEAD
    reasons = [t[3] for t in status["health"]["n0_0"]["transitions"]]
    assert "node_gone" in reasons
    # workers stay orphaned: claimed by the (retired) node's registration,
    # never direct-folded by the root
    assert "w0" not in root_agg.workers and "w1" not in root_agg.workers

    # retired means retired: further cycles don't resurrect it
    status = root_agg.poll_once()
    assert status["nodes"]["n0_0"]["alive"] is False


def test_dead_node_readmitted_on_new_boot_keeps_cursor(tmp_path):
    """A restarted node (same id, new boot) is re-admitted and the parent
    keeps its stream cursor — the stream outlives incarnations, so batches
    the old incarnation committed are folded exactly once."""
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][4] = 50
    region.publish_device(st)

    node = NodeAggregator(root, "n0_0", workers=["w0"])
    node.poll_once()
    root_agg = D.Aggregator(root)
    root_agg.poll_once()
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][4]) == 50

    _mark_node_dead(root, "n0_0")
    status = root_agg.poll_once()
    assert status["nodes"]["n0_0"]["alive"] is False

    # supervisor restarts the node: journal intact -> same emit baseline
    node2 = NodeAggregator(root, "n0_0", workers=["w0"])
    st["arr"]["values"][4] = 53
    region.publish_device(st)
    node2.poll_once()
    status = root_agg.poll_once()
    assert status["nodes"]["n0_0"]["alive"] is True
    assert int(g.snapshot("arr")["values"][4]) == 53    # not 50 + 53
