"""Distribution layer unit tests: sharding rules, divisibility fallbacks,
hlo_cost analyzer, compression, multi-device psum smoke (subprocess)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import sharding as SH


def mk_mesh(shape=(2, 2), axes=("data", "model")):
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        pytest.skip("needs multiple devices")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def test_spec_rules_single_device_mesh():
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    # dims divisible by 1 -> axes kept
    assert SH.spec_for(["embed", "embedding"], (1024, 64), mesh) == \
        P("model", "data")
    assert SH.spec_for(["a", "wq"], (64, 128), mesh) == P("data", "model")
    assert SH.spec_for(["n", "scale"], (64,), mesh) == P(None)
    # stacked leading dim padded with None
    assert SH.spec_for(["stack", "wq"], (4, 64, 128), mesh) == \
        P(None, "data", "model")


def test_divisibility_fallback():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    m = FakeMesh()
    # 14 heads * 64 = 896 divides; but a 14-dim would not
    assert SH.spec_for(["x", "wq"], (896, 896), m) == P("data", "model")
    assert SH.spec_for(["x", "wq"], (896, 14), m) == P("data", None)


def test_adafactor_moment_rules():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    m = FakeMesh()
    # w_in [E, D, F] -> (model, fsdp, None); vr drops last -> (model, fsdp)
    assert SH.spec_for(["f", "w_in", "vr"], (384, 7168), m) == \
        P("model", "data")
    # vc drops second-to-last -> (model, None)
    assert SH.spec_for(["f", "w_in", "vc"], (384, 2048), m) == \
        P("model", None)


def test_fit_spec_drops_nondividing():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    m = FakeMesh()
    s = SH.fit_spec(P(None, "data"), (1, 1), m)
    assert s == P(None, None)
    s = SH.fit_spec(P("data", None), (32, 7), m)
    assert s == P("data", None)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert SH.constrain(x, "batch", None) is x


# ---------------------------------------------------------------- hlo_cost

def test_hlo_cost_scan_multiplier():
    from repro.launch import hlo_cost
    x = jnp.ones((64, 64), jnp.float32)
    f = jax.jit(lambda x: jax.lax.scan(
        lambda c, _: (c @ c, None), x, None, length=5)[0])
    c = hlo_cost.analyze(f.lower(x).compile().as_text())
    assert abs(c.flops - 5 * 2 * 64**3) / (5 * 2 * 64**3) < 0.01


def test_hlo_cost_plain_matmul():
    from repro.launch import hlo_cost
    a = jnp.ones((32, 128), jnp.float32)
    b = jnp.ones((128, 16), jnp.float32)
    f = jax.jit(lambda a, b: a @ b)
    c = hlo_cost.analyze(f.lower(a, b).compile().as_text())
    assert c.flops == 2 * 32 * 128 * 16
    assert c.bytes > 0


def test_hlo_cost_collectives_multidevice():
    """psum byte accounting under a real 4-device SPMD partition
    (subprocess so the main process keeps 1 device)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        import sys
        sys.path.insert(0, "src")
        from repro.launch import hlo_cost
        mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("d",))
        sh = NamedSharding(mesh, P("d"))
        f = jax.jit(lambda x: jnp.sum(x), in_shardings=(sh,))
        x = jax.ShapeDtypeStruct((1024,), jnp.float32)
        c = hlo_cost.analyze(f.lower(x).compile().as_text())
        assert "all-reduce" in c.collective_counts, c.collective_counts
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".", timeout=300)
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_no_f64_in_lowered_train_step():
    """x64 mode must not leak f64 into model compute (explicit dtypes)."""
    from repro.configs import registry
    from repro.configs.base import TrainConfig
    from repro.train.train_step import (abstract_train_state,
                                        make_train_step)
    from repro.launch import specs as SP
    from repro.configs.base import ShapeConfig
    cfg = registry.smoke("llama3.2-1b")
    tcfg = TrainConfig()
    state = abstract_train_state(cfg, tcfg)
    shape = ShapeConfig("t", 16, 4, "train")
    batch = SP.train_batch_specs(cfg, shape, tcfg)
    txt = jax.jit(make_train_step(cfg, tcfg)).lower(state, batch).as_text()
    assert "f64[" not in txt, "f64 leaked into the step function"
