"""Training-loop integration: loss goes down, grad-accum equivalence,
probe instrumentation during training, live attach without restart,
eBPF veto of bad batches, checkpoint determinism."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import maps as M
from repro.core.runtime import BpftimeRuntime
from repro.data.pipeline import SyntheticDataset
from repro.models import registry as MR
from repro.train.train_step import init_train_state, make_train_step

CFG = registry.smoke("llama3.2-1b")
SHAPE = ShapeConfig("t", 32, 8, "train")


def _data(tcfg, cfg=CFG, shape=SHAPE, runtime=None):
    return SyntheticDataset(cfg, shape, tcfg, seed=3, runtime=runtime)


def test_loss_decreases():
    tcfg = TrainConfig(warmup=2, total_steps=30, lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    step = jax.jit(make_train_step(CFG, tcfg))
    data = _data(tcfg)
    losses = []
    for _ in range(30):
        state, m = step(state, data.next())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::6]
    assert int(state["step"]) == 30


def test_grad_accum_equivalence():
    """k microbatches of size m == one batch of k*m (same data)."""
    tcfg_full = TrainConfig(microbatch=0, warmup=1, lr=1e-3,
                            clip_norm=1e9)
    tcfg_acc = dataclasses.replace(tcfg_full, microbatch=2)
    state0 = init_train_state(jax.random.PRNGKey(0), CFG, tcfg_full)

    data_full = _data(tcfg_full)
    data_acc = _data(tcfg_acc)
    b_full, b_acc = data_full.next(), data_acc.next()
    np.testing.assert_array_equal(
        b_acc["tokens"].reshape(b_full["tokens"].shape), b_full["tokens"])

    s1, m1 = jax.jit(make_train_step(CFG, tcfg_full))(state0, b_full)
    s2, m2 = jax.jit(make_train_step(CFG, tcfg_acc))(state0, b_acc)
    for (p1, p2) in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                                   rtol=2e-4, atol=2e-5)


COUNT_BLOCKS = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:blk_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

VETO_ALWAYS = """
    mov r1, 1
    call override_return
    mov r0, 0
    exit
"""


def _probe_runtime():
    rt = BpftimeRuntime()
    pid = rt.load_asm(
        "blk", COUNT_BLOCKS,
        [M.MapSpec("blk_counts", M.MapKind.ARRAY, max_entries=64)], "uprobe")
    rt.attach(pid, "uprobe:block")
    return rt


@pytest.mark.parametrize("mode", ["scan", "vectorized"])
def test_probed_training_counts_blocks(mode):
    rt = _probe_runtime()
    tcfg = TrainConfig(warmup=2, total_steps=10)
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg, rt)
    step = jax.jit(make_train_step(CFG, tcfg, rt, probe_mode=mode))
    data = _data(tcfg, runtime=rt)
    for _ in range(3):
        state, m = step(state, data.next())
    counts = np.asarray(state["maps"]["blk_counts"]["values"])
    # 2 layers x 3 steps (uprobe on entry only)
    np.testing.assert_array_equal(counts[:2], [3, 3])


def test_probed_microbatch_training():
    rt = _probe_runtime()
    tcfg = TrainConfig(warmup=2, microbatch=2)
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg, rt)
    step = jax.jit(make_train_step(CFG, tcfg, rt))
    data = _data(tcfg, runtime=rt)
    state, m = step(state, data.next())
    counts = np.asarray(state["maps"]["blk_counts"]["values"])
    # 2 layers x 4 microbatches
    np.testing.assert_array_equal(counts[:2], [4, 4])


def test_live_attach_no_restart():
    """Attach mid-training: the step re-jits, state carries over, events
    start flowing — the ptrace-injection analogue."""
    rt = BpftimeRuntime()
    rt.create_map(M.MapSpec("blk_counts", M.MapKind.ARRAY, max_entries=64))
    tcfg = TrainConfig(warmup=2)
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg, rt)
    data = _data(tcfg, runtime=rt)

    cache = {}

    def step_fn():
        e = rt.attach_epoch
        if e not in cache:
            cache[e] = jax.jit(make_train_step(CFG, tcfg, rt))
        return cache[e]

    for _ in range(2):                      # uninstrumented steps
        state, _ = step_fn()(state, data.next())
    assert np.asarray(state["maps"]["blk_counts"]["values"]).sum() == 0

    pid = rt.load_asm(
        "blk", COUNT_BLOCKS,
        [M.MapSpec("blk_counts", M.MapKind.ARRAY, max_entries=64)], "uprobe")
    rt.attach(pid, "uprobe:block")          # live injection
    for _ in range(2):
        state, _ = step_fn()(state, data.next())
    counts = np.asarray(state["maps"]["blk_counts"]["values"])
    np.testing.assert_array_equal(counts[:2], [2, 2])
    assert int(state["step"]) == 4          # training never restarted
    assert len(cache) == 2                  # exactly one re-jit


def test_device_filter_vetoes_update():
    """A filter program overriding on a device event freezes the params
    for that step (guard-rail semantics)."""
    rt = BpftimeRuntime()
    pid = rt.load_asm("veto", VETO_ALWAYS, [], "filter")
    rt.attach(pid, "probe:loss")
    tcfg = TrainConfig(warmup=2)
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg, rt)
    step = jax.jit(make_train_step(CFG, tcfg, rt))
    data = _data(tcfg, runtime=rt)
    p0 = jax.tree.map(np.asarray, state["params"])
    state, m = step(state, data.next())
    assert int(m["vetoed"]) == 1
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(state["params"])):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_data_fetch_filter_skips_batches():
    rt = BpftimeRuntime()
    # skip even steps: arg0 = step
    prog = """
        ldxdw r6, [r1+ctx:arg0]
        mod r6, 2
        jne r6, 0, out
        mov r1, 1
        call override_return
        out:
        mov r0, 0
        exit
    """
    pid = rt.load_asm("skip", prog, [], "filter")
    rt.attach(pid, "filter:sys_data_fetch")
    tcfg = TrainConfig()
    data = _data(tcfg, runtime=rt)
    got = [data.next() is not None for _ in range(6)]
    assert got == [False, True, False, True, False, True]


def test_checkpoint_save_restore_resume(tmp_path):
    from repro.ckpt import checkpoint as CK
    tcfg = TrainConfig(warmup=2, lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    step = jax.jit(make_train_step(CFG, tcfg))
    data = _data(tcfg)
    batches = [data.next() for _ in range(6)]
    for b in batches[:3]:
        state, _ = step(state, b)
    CK.save(str(tmp_path), 3, state)
    assert CK.latest(str(tmp_path)) == 3

    # continue 3 more steps
    ref = state
    for b in batches[3:]:
        ref, _ = step(ref, b)

    # restore + replay the same 3 steps -> identical params
    like = jax.eval_shape(lambda: init_train_state(
        jax.random.PRNGKey(0), CFG, tcfg))
    restored = CK.restore(str(tmp_path), 3, like)
    assert int(restored["step"]) == 3
    for b in batches[3:]:
        restored, _ = step(restored, b)
    for a, b_ in zip(jax.tree.leaves(ref["params"]),
                     jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_async_checkpoint(tmp_path):
    from repro.ckpt import checkpoint as CK
    tcfg = TrainConfig()
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    t = CK.save(str(tmp_path), 1, state, blocking=False)
    t.join(timeout=60)
    assert CK.latest(str(tmp_path)) == 1


def test_checkpoint_veto_via_filter(tmp_path):
    from repro.ckpt import checkpoint as CK
    rt = BpftimeRuntime()
    pid = rt.load_asm("nockpt", VETO_ALWAYS, [], "filter")
    rt.attach(pid, "filter:sys_checkpoint_save")
    tcfg = TrainConfig()
    state = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    CK.save(str(tmp_path), 1, state, runtime=rt)
    assert CK.latest(str(tmp_path)) is None   # vetoed


def test_int8_compression_error_small():
    from repro.dist.compression import compression_error, int8_roundtrip
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01}
    err = float(compression_error(g))
    assert err < 0.02
    rt = int8_roundtrip(g)
    assert rt["w"].dtype == g["w"].dtype
