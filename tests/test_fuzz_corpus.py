"""Regression corpus of minimized fuzzer-found programs (tests/corpus/):
each is replayed deterministically through the full differential matrix
in tier-1.  A corpus entry that diverges again means a fixed bug has
been reintroduced; one whose recorded lane set changes means a lane
eligibility gate silently moved."""
import glob
import json
import os

import pytest

from repro.core import fuzz

CORPUS = sorted(glob.glob(
    os.path.join(os.path.dirname(__file__), "corpus", "*.json")))


def _load(path):
    with open(path) as fh:
        return json.load(fh)


def test_corpus_is_not_empty():
    assert len(CORPUS) >= 3


@pytest.mark.parametrize("path", CORPUS, ids=[os.path.basename(p)
                                              for p in CORPUS])
def test_corpus_case_replays_clean(path):
    d = _load(path)
    case = fuzz.FuzzCase.from_json(d)
    r = fuzz.run_case(case)
    assert r.accepted, r.rejected
    assert not r.diverged, r.mismatches or r.crashed
    # lane set is part of the pinned behavior: a gate that silently
    # widens (re-admitting a buggy shape) or narrows (losing coverage)
    # shows up here before it shows up as a divergence
    assert r.lanes == d["lanes"], (r.lanes, d["lanes"])


def test_ringbuf_two_sites_stays_out_of_vectorized():
    """Seed-99 find: two ringbuf_output sites per ring under per-site
    vectorized apply reorder records; is_vector_safe must keep rejecting
    this shape."""
    d = _load(os.path.join(os.path.dirname(__file__), "corpus",
                           "ringbuf_two_sites.json"))
    case = fuzz.FuzzCase.from_json(d)
    r = fuzz.run_case(case)
    assert "vectorized" not in r.lanes


def test_live_fetch_add_stays_out_of_merge():
    """Seed-136 find: a live fetch_add result is an order-observing read;
    _merge_eligible must keep refusing the shm-merge lanes."""
    d = _load(os.path.join(os.path.dirname(__file__), "corpus",
                           "live_fetch_add_split.json"))
    case = fuzz.FuzzCase.from_json(d)
    r = fuzz.run_case(case)
    assert not any(ln.startswith("merge") for ln in r.lanes)
