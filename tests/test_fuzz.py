"""Grammar fuzzer harness (DESIGN.md §14): generator determinism and
acceptance rate, the repair pass, the shrink loop, and the pinned-seed
differential matrix (oracle vs jit vs table vs batched vs vectorized vs
1/2/3-worker shm-merge) that gates every PR in CI."""
import random

import pytest

from repro.core import asm, fuzz, verifier

# Pinned PR-gate seeds, chosen so every lane the gates can admit appears
# at least twice: seeds {2,8,19,26,34} exercise the batched SIMT lane,
# {8,9,19,34} the shadow-vmap vectorized lane, {9,19,26,34,45,51} the
# 1/2/3-worker shm-merge lanes, and all of them jit+table.
GATE_SEEDS = [0, 1, 2, 8, 9, 19, 26, 34, 45, 51]


# ------------------------------------------------------------- generator
def test_generation_is_seed_deterministic():
    for seed in (0, 7, 123):
        a = fuzz.generate_case(seed)
        b = fuzz.generate_case(seed)
        assert a.text == b.text
        assert a.tape == b.tape
    assert fuzz.generate_case(0).text != fuzz.generate_case(1).text


def test_acceptance_rate_over_seed_budget():
    """ISSUE gate: >= 90% of generated programs verifier-accepted at a
    fixed seed budget (verify only — no lane execution, stays fast)."""
    n, ok = 60, 0
    for seed in range(n):
        case = fuzz.generate_case(seed)
        a = asm.assemble(case.text)
        try:
            verifier.verify(a.insns, fuzz.FUZZ_SPECS,
                            ctx_words=fuzz.CTX_WORDS)
            ok += 1
        except verifier.VerifierError:
            pass
    assert ok / n >= 0.9, f"acceptance {ok}/{n}"


def test_repaired_text_always_assembles():
    """Whatever the generator emits (including injected breakage — dead
    labels, clobbered registers), the repair pass yields assemblable
    text; the verifier may still reject, but never the assembler."""
    for seed in range(20):
        rng = random.Random(seed)
        asm.assemble(fuzz.repair(fuzz.generate_text(rng, breakage=0.3)))


# ------------------------------------------------------------- repair
def test_repair_redirects_dangling_label():
    out = fuzz.repair("mov r2, 1\njeq r2, 1, nowhere\nmov r0, 0\nexit")
    lines = out.splitlines()
    assert "jeq r2, 1, __repair_out" in lines
    assert "__repair_out:" in lines
    a = asm.assemble(out)
    verifier.verify(a.insns, fuzz.FUZZ_SPECS, ctx_words=fuzz.CTX_WORDS)


def test_repair_zeroes_uninit_read_in_place():
    out = fuzz.repair("add r3, 7\nmov r0, r3\nexit").splitlines()
    assert out.index("mov r3, 0") == out.index("add r3, 7") - 1


def test_repair_handles_post_call_clobber():
    """r4 written, then clobbered by a call, then read: the prologue-zero
    strategy misses this; in-place insertion must catch it."""
    text = "\n".join(["mov r4, 9", "call ktime_get_ns", "add r4, 1",
                      "mov r0, 0", "exit"])
    out = fuzz.repair(text)
    a = asm.assemble(out)
    verifier.verify(a.insns, fuzz.FUZZ_SPECS, ctx_words=fuzz.CTX_WORDS)
    lines = out.splitlines()
    assert lines.index("mov r4, 0") == lines.index("add r4, 1") - 1


def test_repair_preserves_ctx_pointer():
    # r1 is the ctx pointer at entry; repair must not zero it before a load
    out = fuzz.repair("ldxdw r6, [r1+0]\nmov r0, r6\nexit")
    assert "mov r1, 0" not in out.splitlines()
    a = asm.assemble(out)
    verifier.verify(a.insns, fuzz.FUZZ_SPECS, ctx_words=fuzz.CTX_WORDS)


def test_repair_is_idempotent():
    for seed in range(10):
        t1 = fuzz.repair(fuzz.generate_text(random.Random(seed),
                                            breakage=0.3))
        assert fuzz.repair(t1) == t1


# ------------------------------------------------------------- case model
def test_case_json_round_trip():
    case = fuzz.generate_case(3)
    again = fuzz.FuzzCase.from_json(case.to_json())
    assert (again.seed, again.text, again.tape) == \
        (case.seed, case.text, case.tape)


def test_rejected_program_is_not_a_divergence():
    case = fuzz.FuzzCase(seed=0, text="add r5, 1\nexit",
                         tape=[[0] * fuzz.CTX_WORDS])
    r = fuzz.run_case(case)
    assert not r.accepted and r.rejected and not r.diverged


# ------------------------------------------------------------- shrinker
def test_shrinker_minimizes_against_injected_predicate():
    """The loop itself: with a predicate that only needs two specific
    lines, shrinking converges to exactly those lines, in order."""
    text = "\n".join(f"mov r{i % 9}, {i}" for i in range(16))
    case = fuzz.FuzzCase(seed=0, text=text, tape=[])

    def needs(text, _case):
        lines = text.splitlines()
        return "mov r3, 3" in lines and "mov r3, 12" in lines

    mini = fuzz.shrink_case(case, still_fails=needs)
    assert mini.text.splitlines() == ["mov r3, 3", "mov r3, 12"]


def test_shrinker_keeps_case_when_nothing_removable():
    case = fuzz.FuzzCase(seed=0, text="a\nb", tape=[])
    mini = fuzz.shrink_case(case, still_fails=lambda t, c: t == "a\nb")
    assert mini.text == "a\nb"


# ------------------------------------------------------------- the matrix
@pytest.mark.parametrize("seed", GATE_SEEDS)
def test_differential_matrix_pinned_seeds(seed):
    """The PR gate: every lane the program's footprints admit must be
    bit-identical with the sequential numpy oracle — r0 per event,
    override aux per event, and final map state, across 1/2/3-worker
    shm-merge splits."""
    case = fuzz.generate_case(seed)
    r = fuzz.run_case(case)
    assert r.accepted, r.rejected
    assert not r.diverged, r.mismatches or r.crashed


def test_pinned_seeds_cover_every_lane():
    """If a grammar/eligibility change silently stops any lane from being
    exercised by the gate seeds, fail loudly rather than green-wash."""
    seen = set()
    for seed in GATE_SEEDS:
        seen.update(fuzz.run_case(fuzz.generate_case(seed)).lanes)
    assert {"jit", "table", "batched", "vectorized",
            "merge1", "merge2", "merge3"} <= seen, seen


def test_campaign_driver_summary(tmp_path):
    s = fuzz.fuzz(range(4), out_dir=str(tmp_path))
    assert s["seeds"] == 4
    assert s["divergences"] == 0 and s["failures"] == []
    assert s["acceptance_rate"] >= 0.75
    assert list(tmp_path.iterdir()) == []   # no repros on a clean run


def test_cli_exit_codes(capsys):
    assert fuzz.main(["--seeds", "0-2"]) == 0
    out = capsys.readouterr().out
    assert "3 seeds" in out
