"""Live attach/detach via the program-table interpreter lane: trace
stability (NO retrace on attach), bit-identical semantics vs scan mode,
slot lifecycle, and control-plane rejection paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import events as E, jit as J, loader, maps as M
from repro.core.runtime import BpftimeRuntime
from repro.core.verifier import VerifierError

COUNT_BY_LAYER = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:lt_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

HASH_BY_LAYER = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:lt_hash
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

HIST_RMS = """
    ldxdw r2, [r1+ctx:rms]
    lddw r1, map:lt_hist
    call hist_add
    mov r0, 0
    exit
"""

LOOP_SUM = """
    ldxdw r6, [r1+ctx:layer]
    mov r7, 0
    loop:
    add r7, 1
    sub r6, 1
    jsgt r6, 0, loop
    stxdw [r10-8], r7
    lddw r1, map:lt_counts
    mov r2, r10
    add r2, -8
    mov r3, r7
    call map_fetch_add
    mov r0, 0
    exit
"""

ARR = M.MapSpec("lt_counts", M.MapKind.ARRAY, max_entries=64)
HASH = M.MapSpec("lt_hash", M.MapKind.HASH, max_entries=64)
HIST = M.MapSpec("lt_hist", M.MapKind.LOG2HIST)
SPECS = [ARR, HASH, HIST]
PROGS = [("lt_count", COUNT_BY_LAYER, [ARR], "uprobe:lt_block"),
         ("lt_hashp", HASH_BY_LAYER, [HASH], "uprobe:lt_block"),
         ("lt_histp", HIST_RMS, [HIST], "uretprobe:lt_block")]


def make_tape(n=48):
    rng = np.random.default_rng(7)
    rows = np.zeros((n, E.EVENT_WIDTH), np.int64)
    rows[:, 0] = E.SITES.get_or_create("lt_block")
    rows[:, 1] = np.where(np.arange(n) % 3 == 2, E.KIND_EXIT, E.KIND_ENTRY)
    rows[:, 2] = rng.integers(0, 32, n)
    rows[:, 6] = rng.integers(1, 1 << 30, n)
    return jnp.asarray(rows)


def live_runtime():
    rt = BpftimeRuntime()
    for sp in SPECS:
        rt.create_map(sp)
    rt.enable_live_attach(max_programs=4, max_insns=64,
                          arm=("uprobe:lt_block", "uretprobe:lt_block"))
    return rt


def scan_runtime():
    rt = BpftimeRuntime()
    for sp in SPECS:
        rt.create_map(sp)
    for name, text, maps, target in PROGS:
        pid = rt.load_asm(name, text, maps, "uprobe")
        rt.attach(pid, target)
    return rt


def map_values(maps_state):
    return {name: {k: np.asarray(v) for k, v in maps_state[name].items()}
            for name in ("lt_counts", "lt_hash", "lt_hist")}


def test_interp_lane_matches_scan_mode():
    """Hot-attached programs through the table interpreter produce exactly
    the state a static scan-mode attachment produces."""
    rows = make_tape()
    rt = live_runtime()
    for name, text, maps, target in PROGS:
        pid = rt.load_asm(name, text, maps, "uprobe")
        rt.attach(pid, target, mode="table")
    maps_live = rt.init_device_maps()
    stage = jax.jit(lambda r, m: rt.probe_stage(r, m, J.make_aux()))
    maps_live, _ = stage(rows, maps_live)

    rt2 = scan_runtime()
    maps_scan = rt2.init_device_maps()
    maps_scan, _ = jax.jit(
        lambda r, m: rt2.probe_stage(r, m, J.make_aux(), mode="scan"))(
            rows, maps_scan)

    got, want = map_values(maps_live), map_values(maps_scan)
    for name in want:
        for k in want[name]:
            np.testing.assert_array_equal(got[name][k], want[name][k],
                                          err_msg=f"{name}.{k}")


def test_attach_live_does_not_retrace():
    """The headline paper property: attach/detach on a RUNNING compiled
    step is a data write — the jit cache must not grow."""
    rows = make_tape()
    rt = live_runtime()
    pid = rt.load_asm(*PROGS[0][:3], "uprobe")

    @jax.jit
    def stage(r, m):
        m, _ = rt.probe_stage(r, m, J.make_aux())
        return m

    maps = rt.init_device_maps()
    maps = stage(rows, maps)
    assert stage._cache_size() == 1
    assert np.asarray(maps["lt_counts"]["values"]).sum() == 0

    lid = rt.attach(pid, "uprobe:lt_block", mode="table")
    maps = rt.sync_live_table(maps)
    maps = stage(rows, maps)
    n_entry = int(np.asarray(rows[:, 1] == E.KIND_ENTRY).sum())
    assert np.asarray(maps["lt_counts"]["values"]).sum() == n_entry
    assert stage._cache_size() == 1, "live attach retraced the step"
    assert int(np.asarray(maps["__live_table__"]["gen"])[0]) == 1

    rt.detach(lid)
    maps = rt.sync_live_table(maps)
    before = np.asarray(maps["lt_counts"]["values"]).sum()
    maps = stage(rows, maps)
    assert np.asarray(maps["lt_counts"]["values"]).sum() == before
    assert stage._cache_size() == 1, "live detach retraced the step"
    assert int(np.asarray(maps["__live_table__"]["gen"])[0]) == 2


def test_detach_routes_live_links():
    rt = live_runtime()
    pid = rt.load_asm(*PROGS[0][:3], "uprobe")
    lid = rt.attach(pid, "uprobe:lt_block", mode="table")
    assert rt.live.host["active"][0] == 1
    rt.detach(lid)                      # generic detach routes to the table
    assert rt.live.host["active"][0] == 0
    assert int(lid) not in rt.links


def test_slot_reuse_and_full_table():
    rt = live_runtime()
    pid = rt.load_asm(*PROGS[0][:3], "uprobe")
    lids = [rt.attach(pid, "uprobe:lt_block", mode="table") for _ in range(4)]
    with pytest.raises(loader.LoadError, match="full"):
        rt.attach(pid, "uprobe:lt_block", mode="table")
    rt.detach(lids[1])
    lid = rt.attach(pid, "uprobe:lt_block", mode="table")
    assert lid.slot == 1                # freed slot is reused


def test_attach_live_rejects_unknown_map():
    """A program touching a map created AFTER the interpreter was compiled
    cannot go live (the compiled graph has no branch for it) — and the
    rejection must leave the generation counter untouched."""
    rt = live_runtime()
    new_map = M.MapSpec("lt_after", M.MapKind.ARRAY, max_entries=8)
    prog = COUNT_BY_LAYER.replace("map:lt_counts", "map:lt_after")
    pid = rt.load_asm("late", prog, [new_map], "uprobe")
    with pytest.raises(VerifierError, match="created after"):
        rt.attach(pid, "uprobe:lt_block", mode="table")
    assert rt.live.host["gen"][0] == 0


def test_attach_live_rejects_oversized_program():
    rt = BpftimeRuntime()
    rt.create_map(ARR)
    rt.enable_live_attach(max_programs=1, max_insns=8)
    pid = rt.load_asm(*PROGS[0][:3], "uprobe")
    with pytest.raises(VerifierError, match="padded"):
        rt.attach(pid, "uprobe:lt_block", mode="table")
    assert rt.live.host["gen"][0] == 0


def test_attach_live_requires_enable():
    rt = BpftimeRuntime()
    rt.create_map(ARR)
    pid = rt.load_asm(*PROGS[0][:3], "uprobe")
    with pytest.raises(loader.LoadError, match="enable_live_attach"):
        rt.attach(pid, "uprobe:lt_block", mode="table")


def test_loop_program_in_lane():
    """Tier-2 (fuel-bounded loop) bytecode runs natively in the table
    interpreter and matches the scan-mode result."""
    rows = make_tape(24)
    rt = BpftimeRuntime()
    rt.create_map(ARR)
    rt.enable_live_attach(arm=("uprobe:lt_block",))
    pid = rt.load_asm("loopy", LOOP_SUM, [ARR], "uprobe")
    assert rt.progs[pid].vprog.tier == "loop"
    rt.attach(pid, "uprobe:lt_block", mode="table")
    maps, _ = jax.jit(lambda r, m: rt.probe_stage(r, m, J.make_aux()))(
        rows, rt.init_device_maps())

    rt2 = BpftimeRuntime()
    rt2.create_map(ARR)
    pid2 = rt2.load_asm("loopy", LOOP_SUM, [ARR], "uprobe")
    rt2.attach(pid2, "uprobe:lt_block")
    maps2, _ = jax.jit(
        lambda r, m: rt2.probe_stage(r, m, J.make_aux(), mode="scan"))(
            rows, rt2.init_device_maps())
    np.testing.assert_array_equal(np.asarray(maps["lt_counts"]["values"]),
                                  np.asarray(maps2["lt_counts"]["values"]))


def test_live_lane_composes_with_fused_lane():
    """Static fused attachments and hot-attached table programs run in one
    probe stage; disjoint maps, so order across lanes is irrelevant."""
    rows = make_tape()
    rt = live_runtime()
    # static attachment (fused lane) on the hist map
    pid_h = rt.load_asm("lt_histp", HIST_RMS, [HIST], "uprobe")
    rt.attach(pid_h, "uretprobe:lt_block", mode="fused")
    # hot attachment (table lane) on the array map
    pid_c = rt.load_asm("lt_count", COUNT_BY_LAYER, [ARR], "uprobe")
    rt.attach(pid_c, "uprobe:lt_block", mode="table")

    maps, _ = jax.jit(lambda r, m: rt.probe_stage(r, m, J.make_aux()))(
        rows, rt.init_device_maps())
    n_entry = int(np.asarray(rows[:, 1] == E.KIND_ENTRY).sum())
    n_exit = rows.shape[0] - n_entry
    assert np.asarray(maps["lt_counts"]["values"]).sum() == n_entry
    assert np.asarray(maps["lt_hist"]["bins"]).sum() == n_exit


def test_long_loop_fuel_matches_scan_lane():
    """Fuel-budget parity: the scan-lane T2 budget is max_insns BLOCK steps
    while the interpreter counts INSNS — the encoded fuel is scaled by the
    longest block so any execution completing under the scan lane's budget
    completes (identically) in the table lane. 30k iterations of a 3-insn
    loop body used to truncate at 65536 insns (regression test)."""
    from repro.core import table_interp, vm
    long_loop = """
        ldxdw r6, [r1+ctx:layer]
        mov r7, 0
        loop:
        add r7, 1
        sub r6, 1
        jsgt r6, 0, loop
        mov r8, r7
        and r8, 63
        stxdw [r10-8], r8
        lddw r1, map:lt_counts
        mov r2, r10
        add r2, -8
        mov r3, r7
        call map_fetch_add
        mov r0, 0
        exit
    """
    rt = BpftimeRuntime()
    rt.create_map(ARR)
    pid = rt.load_asm("long", long_loop, [ARR], "uprobe")
    vprog = rt.progs[pid].vprog

    ctx = np.zeros((E.EVENT_WIDTH,), np.int64)
    ctx[2] = 30_000                     # ctx:layer — loop iterations
    np_maps = M.init_states(vprog.map_specs, np)
    res = vm.run(vprog.insns, vm.pack_ctx([int(w) for w in ctx]),
                 vprog.map_specs, np_maps)
    assert res.insns_executed > 65_536  # beyond the old insn-fuel ceiling
    r0, j_maps, _ = table_interp.run_program(
        vprog, jnp.asarray(ctx), M.init_states(vprog.map_specs, jnp),
        J.make_aux())
    assert int(r0) == res.r0 == 0
    np.testing.assert_array_equal(np.asarray(j_maps["lt_counts"]["values"]),
                                  np_maps["lt_counts"]["values"])
    assert np_maps["lt_counts"]["values"][30_000 & 63] == 30_000


def test_run_training_applies_daemon_live_inject(tmp_path):
    """The PRODUCTION loop (launch.train.run_training) must both pick up a
    daemon live injection AND push it onto its running compiled step —
    without re-jitting (the jit cache stays on one epoch)."""
    from repro.core import daemon, loader
    from repro.core.shm import ShmRegion
    from repro.launch.train import run_training

    rt = BpftimeRuntime()
    rt.create_map(ARR)
    rt.enable_live_attach(max_programs=2, max_insns=64,
                          arm=("probe:grad.norm",))
    epoch_at_compile = {}

    prog = loader.build_object(
        "inject", COUNT_BY_LAYER.replace("ctx:layer", "ctx:step"), [ARR],
        "uprobe", attach_to="probe:grad.norm")

    def on_step(s, state, metrics):
        epoch_at_compile[s] = rt.attach_epoch
        if s == 2:      # a 'daemon' injects while training runs
            other = ShmRegion.attach(str(tmp_path / "shm"))
            # promote=False pins the link to the interpreter: this test's
            # invariant is that a NON-promoted live inject never re-jits
            # (promotion is exercised in tests/test_promotion.py)
            daemon.request_load_attach(other, prog.to_json(), live=True,
                                       promote=False)

    state, hist = run_training(
        "qwen2-0.5b", steps=6, smoke=True, runtime=rt,
        shm_dir=str(tmp_path / "shm"), probe_mode="fused",
        seq_len=16, batch=2, log_every=0, on_step=on_step)

    # injected at the boundary after step 2 -> counts steps 3..6
    counts = np.asarray(state["maps"]["lt_counts"]["values"])
    assert counts.sum() == 4, counts[:8]
    # one attach_epoch for the whole run: the injection did not re-jit
    assert len(set(epoch_at_compile.values())) == 1
    assert rt.live.host["gen"][0] == 1
    assert rt.shm.read_status()["live_slots"]["0"] == "inject"


def test_armed_sites_collect_without_programs():
    rt = live_runtime()
    assert (E.SITES.get_or_create("lt_block"), E.KIND_ENTRY) in \
        rt.wanted_sites()
    with rt.collector() as col:
        E.probe_site("lt_block", jnp.ones((4,), jnp.float32),
                     kind=E.KIND_ENTRY)
        rows = col.take_all_rows()
    assert rows.shape[0] == 1           # collected even with zero programs


def test_batched_vec_flags_and_cross_slot_demotion():
    """The batched (lockstep) interpreter only takes slots whose HASH
    layout order is provably event-order; two slots sharing a HASH map
    interleave inserts, so BOTH demote to the sequential scan — and the
    demotion is recomputed (lifted) when the conflict detaches."""
    rt = live_runtime()
    pid_c = rt.load_asm("lt_count", COUNT_BY_LAYER, [ARR], "uprobe")
    pid_h = rt.load_asm("lt_hashp", HASH_BY_LAYER, [HASH], "uprobe")
    pid_h2 = rt.load_asm("lt_hashq", HASH_BY_LAYER, [HASH], "uprobe")

    lk_c = rt.attach(pid_c, "uprobe:lt_block", mode="table")
    lk_h = rt.attach(pid_h, "uprobe:lt_block", mode="table")
    assert rt.live.host["vec"][lk_c.slot] == 1
    assert rt.live.host["vec"][lk_h.slot] == 1     # sole owner of the HASH

    lk_h2 = rt.attach(pid_h2, "uretprobe:lt_block", mode="table")
    assert rt.live.host["vec"][lk_h.slot] == 0     # shared HASH: demoted
    assert rt.live.host["vec"][lk_h2.slot] == 0
    assert rt.live.host["vec"][lk_c.slot] == 1     # ARRAY slot unaffected

    # the demoted mix still matches a scan-mode oracle bit-for-bit
    rows = make_tape()
    maps, _ = jax.jit(lambda r, m: rt.probe_stage(r, m, J.make_aux()))(
        rows, rt.init_device_maps())

    rt2 = BpftimeRuntime()
    for sp in SPECS:
        rt2.create_map(sp)
    for name, text, mp, tgt in (("lt_count", COUNT_BY_LAYER, [ARR],
                                 "uprobe:lt_block"),
                                ("lt_hashp", HASH_BY_LAYER, [HASH],
                                 "uprobe:lt_block"),
                                ("lt_hashq", HASH_BY_LAYER, [HASH],
                                 "uretprobe:lt_block")):
        p = rt2.load_asm(name, text, mp, "uprobe")
        rt2.attach(p, tgt, mode="fused")
    maps2, _ = jax.jit(
        lambda r, m: rt2.probe_stage(r, m, J.make_aux(), mode="scan"))(
            rows, rt2.init_device_maps())
    for name in ("lt_counts", "lt_hash"):
        for k in maps[name]:
            np.testing.assert_array_equal(np.asarray(maps[name][k]),
                                          np.asarray(maps2[name][k]),
                                          err_msg=f"{name}.{k}")

    rt.detach(lk_h2)                               # conflict gone
    assert rt.live.host["vec"][lk_h.slot] == 1     # demotion lifted
