"""Relocatable verification (CO-RE, DESIGN.md §13): verify ONE abstract
program, relocate it onto every config world in src/repro/configs/ —
bit-identical to verifying from scratch in each world, with the verifier
invoked exactly once."""
import numpy as np
import pytest

from repro.configs import registry
from repro.core import asm, events as E, loader, maps as M, reloc, verifier, vm
from repro.core.layout import (EVENT_LAYOUT, CtxLayout, layout_fingerprint)
from repro.core.maps import MapKind, MapSpec

# two ctx fields + two maps: the representative per-layer probe shape
PROG = """
    ldxdw r6, [r1+ctx:layer]
    ldxdw r7, [r1+ctx:rms]
    stxdw [r10-8], r6
    lddw r1, map:rl_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    lddw r1, map:rl_hist
    mov r2, r7
    call hist_add
    mov r0, 0
    exit
"""

DECLARED = [MapSpec("rl_counts", MapKind.ARRAY, max_entries=64),
            MapSpec("rl_hist", MapKind.LOG2HIST)]


def _abstract():
    obj = loader.build_object("rl_probe", PROG, list(DECLARED), "uprobe")
    return obj, reloc.verify_relocatable(obj)


def _concrete_text(fd_of, layout=EVENT_LAYOUT):
    """The verify-from-scratch control: same source with fds and ctx byte
    offsets hard-coded for one world (no relocation machinery at all)."""
    t = PROG.replace("ctx:layer", str(layout.byte_of("layer")))
    t = t.replace("ctx:rms", str(layout.byte_of("rms")))
    t = t.replace("map:rl_counts", str(fd_of["rl_counts"]))
    return t.replace("map:rl_hist", str(fd_of["rl_hist"]))


def _worlds():
    """>= 12 distinct concrete registries derived from every config in
    src/repro/configs: decoy maps shift the real maps' fd positions, and
    odd worlds reverse the declared order, so the lddw targets genuinely
    move between worlds."""
    worlds = []
    for i, arch in enumerate(sorted(registry.ARCHS)):
        for smoke in (False, True):
            cfg = registry.smoke(arch) if smoke else registry.get(arch)
            n_decoy = (i + (1 if smoke else 0)) % 4
            decoys = [MapSpec(f"decoy_{arch[:8]}_{j}", MapKind.ARRAY,
                              max_entries=8 + cfg.num_layers % 8 + j)
                      for j in range(n_decoy)]
            reals = list(DECLARED) if i % 2 == 0 else list(DECLARED[::-1])
            specs = decoys + reals
            worlds.append((f"{arch}{'-smoke' if smoke else ''}", specs))
    assert len(worlds) >= 12
    return worlds


def _pack(row):
    return b"".join(int(v).to_bytes(8, "little", signed=True) for v in row)


def _rows(layout=EVENT_LAYOUT, n=32, seed=0):
    rng = np.random.default_rng(seed)
    rows = np.zeros((n, layout.words), np.int64)
    rows[:, layout.word_of("layer")] = rng.integers(0, 64, n)
    rows[:, layout.word_of("rms")] = rng.integers(1, 1 << 30, n)
    return rows


def _vm_states(specs, insns, rows):
    states = {s.name: M.init_state(s, np) for s in specs}
    for row in rows:
        vm.run(insns, _pack(row), specs, states)
    return states


def test_one_verification_relocates_to_every_config_world():
    obj, vabs = _abstract()
    assert vabs.is_abstract
    verifier.STATS["verify_calls"] = 0

    worlds = _worlds()
    resolved = []
    for name, specs in worlds:
        fd_of = {s.name: i for i, s in enumerate(specs)}
        resolved.append((name, specs, fd_of,
                         reloc.resolve(vabs, fd_of, specs)))
    # the whole fleet bound from ONE verification: zero verifier re-entry
    assert verifier.STATS["verify_calls"] == 0
    assert vabs.reloc.resolved is False        # source record untouched

    rows = _rows()
    for name, specs, fd_of, vprog in resolved:
        # differential control: assemble + verify this world from scratch
        scratch = verifier.verify(
            asm.assemble(_concrete_text(fd_of)).insns, specs)
        blob_a = b"".join(i.encode() for i in vprog.insns)
        blob_b = b"".join(i.encode() for i in scratch.insns)
        assert blob_a == blob_b, f"world {name}: relocated bytecode differs"
        assert vprog.touched_map_fds == scratch.touched_map_fds
        # and the relocated program computes the same map state
        sa = _vm_states(specs, vprog.insns, rows)
        sb = _vm_states(specs, scratch.insns, rows)
        assert np.array_equal(sa["rl_counts"]["values"],
                              sb["rl_counts"]["values"]), name
        assert np.array_equal(sa["rl_hist"]["bins"],
                              sb["rl_hist"]["bins"]), name


def test_fingerprints_separate_worlds():
    seen = {}
    for name, specs in _worlds():
        fp = layout_fingerprint(specs, E.EVENT_WIDTH)
        assert fp not in seen or seen[fp] == [
            (s.name, s.kind, s.max_entries) for s in specs], \
            f"distinct registries {name} collide on one fingerprint"
        seen[fp] = [(s.name, s.kind, s.max_entries) for s in specs]
    assert len(set(seen)) > 1


def test_relocate_onto_permuted_ctx_layout():
    """The same verified program reads a PERMUTED event layout correctly
    once relocated — the CO-RE field-offset story, not just map fds."""
    _, vabs = _abstract()
    perm = CtxLayout.from_btf("permuted", {"layer": 9, "rms": 1}, words=16)
    specs = list(DECLARED)
    fd_of = {s.name: i for i, s in enumerate(specs)}
    v_base = reloc.resolve(vabs, fd_of, specs)
    v_perm = reloc.resolve(vabs, fd_of, specs, ctx_layout=perm)

    base_rows = _rows()
    perm_rows = np.zeros_like(base_rows)
    perm_rows[:, 9] = base_rows[:, EVENT_LAYOUT.word_of("layer")]
    perm_rows[:, 1] = base_rows[:, EVENT_LAYOUT.word_of("rms")]

    sa = _vm_states(specs, v_base.insns, base_rows)
    sb = _vm_states(specs, v_perm.insns, perm_rows)
    assert np.array_equal(sa["rl_counts"]["values"],
                          sb["rl_counts"]["values"])
    assert np.array_equal(sa["rl_hist"]["bins"], sb["rl_hist"]["bins"])


def test_relocated_attach_matches_scratch_in_jitted_pipeline():
    """One world end-to-end through the fused jitted probe stage:
    load_relocatable (zero verifier work) vs load_asm (full verify)."""
    import jax

    from repro.core import jit as J
    from repro.core.runtime import BpftimeRuntime

    _, vabs = _abstract()
    site = E.SITES.get_or_create("rl_site")
    rows = np.zeros((256, E.EVENT_WIDTH), np.int64)
    rows[:, 0] = site
    rows[:, 1] = E.KIND_ENTRY
    rows[:, EVENT_LAYOUT.word_of("layer")] = \
        np.arange(256) % 48
    rows[:, EVENT_LAYOUT.word_of("rms")] = 1 + np.arange(256)

    def run_world(load):
        rt = BpftimeRuntime()
        rt.create_map(MapSpec("decoy_jit", MapKind.ARRAY, max_entries=8))
        pid = load(rt)
        rt.attach(pid, "uprobe:rl_site")
        stage = jax.jit(lambda r, m: rt.probe_stage(r, m, J.make_aux()))
        maps, _ = stage(rows, rt.init_device_maps())
        return jax.tree.map(np.asarray, maps)

    verifier.STATS["verify_calls"] = 0
    ma = run_world(lambda rt: rt.load_relocatable(vabs, "rl_probe"))
    assert verifier.STATS["verify_calls"] == 0
    mb = run_world(lambda rt: rt.load_asm("rl_probe", PROG, DECLARED))
    assert verifier.STATS["verify_calls"] == 1
    assert np.array_equal(ma["rl_counts"]["values"],
                          mb["rl_counts"]["values"])
    assert np.array_equal(ma["rl_hist"]["bins"], mb["rl_hist"]["bins"])
    assert ma["rl_counts"]["values"].sum() == 256


# --------------------------------------------------------------- negatives
def test_missing_map_symbol_rejected():
    _, vabs = _abstract()
    specs = [DECLARED[0]]                       # no rl_hist in this world
    fd_of = {s.name: i for i, s in enumerate(specs)}
    with pytest.raises(reloc.RelocationError, match="rl_hist"):
        reloc.resolve(vabs, fd_of, specs)
    assert vabs.reloc.resolved is False


def test_map_kind_mismatch_rejected():
    _, vabs = _abstract()
    specs = [DECLARED[0],
             MapSpec("rl_hist", MapKind.ARRAY, max_entries=64)]
    fd_of = {s.name: i for i, s in enumerate(specs)}
    with pytest.raises(reloc.RelocationError, match="rl_hist"):
        reloc.resolve(vabs, fd_of, specs)


def test_ctx_field_out_of_bounds_rejected():
    _, vabs = _abstract()
    specs = list(DECLARED)
    fd_of = {s.name: i for i, s in enumerate(specs)}
    oob = CtxLayout.from_btf("wide", {"layer": 2, "rms": 20}, words=24)
    with pytest.raises(reloc.RelocationError):
        reloc.resolve(vabs, fd_of, specs, ctx_layout=oob, ctx_words=16)
    assert vabs.reloc.resolved is False


def test_failed_relocation_leaves_live_generation_untouched():
    """A bad relocation must be rejected BEFORE any runtime mutation: the
    live table generation, registry, and program set stay as they were."""
    from repro.core.runtime import BpftimeRuntime

    _, vabs = _abstract()
    rt = BpftimeRuntime()
    rt.create_map(DECLARED[0])
    rt.create_map(MapSpec("rl_hist", MapKind.ARRAY, max_entries=64))
    rt.enable_live_attach(max_programs=2, max_insns=64,
                          arm=("uprobe:rl_site",))
    gen0 = int(rt.live.host["gen"][0])
    n_specs, n_progs = len(rt.map_specs), len(rt.progs)
    with pytest.raises(Exception):
        rt.load_relocatable(vabs, "rl_probe")   # rl_hist kind mismatch
    assert int(rt.live.host["gen"][0]) == gen0
    assert len(rt.map_specs) == n_specs
    assert len(rt.progs) == n_progs
