"""End-to-end behaviour test for the paper's system: the full bpftime
workflow — load (CO-RE relocate + verify + JIT) -> attach -> instrumented
training with in-graph execution -> shm publish -> daemon snapshot ->
live re-attach -> detach — in one scenario."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import events as E, loader, maps as M
from repro.core.daemon import render_log2_hist, request_load_attach
from repro.core.runtime import BpftimeRuntime
from repro.core.shm import ShmRegion
from repro.data.pipeline import SyntheticDataset
from repro.train.train_step import init_train_state, make_train_step

PROG = """
    mov r9, r1
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:hits
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    ldxdw r2, [r9+ctx:rms]
    lddw r1, map:hist
    call hist_add
    mov r0, 0
    exit
"""
MAPS = [M.MapSpec("hits", M.MapKind.ARRAY, max_entries=64),
        M.MapSpec("hist", M.MapKind.LOG2HIST)]


def test_full_bpftime_workflow(tmp_path):
    rt = BpftimeRuntime()
    for m in MAPS:
        rt.create_map(m)
    shm = rt.setup_shm(str(tmp_path / "shm"))

    cfg = registry.smoke("llama3.2-1b")
    tcfg = TrainConfig(warmup=2, lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg, rt)
    data = SyntheticDataset(cfg, ShapeConfig("e2e", 32, 4, "train"), tcfg,
                            runtime=rt)

    cache = {}

    def step_fn():
        e = rt.attach_epoch
        if e not in cache:
            cache[e] = jax.jit(make_train_step(cfg, tcfg, rt))
        return cache[e]

    # phase 1: uninstrumented
    losses = []
    for _ in range(3):
        state, m = step_fn()(state, data.next())
        losses.append(float(m["loss"]))
    assert np.asarray(state["maps"]["hits"]["values"]).sum() == 0

    # phase 2: daemon injects the program into the RUNNING loop
    obj = loader.build_object("watch", PROG, MAPS, "uprobe",
                              attach_to="uprobe:block")
    daemon_view = ShmRegion.attach(str(tmp_path / "shm"))
    request_load_attach(daemon_view, obj.to_json())
    applied = rt.poll_control()
    assert applied and "error" not in applied[0]

    for _ in range(4):
        state, m = step_fn()(state, data.next())
        losses.append(float(m["loss"]))
        rt.publish(state["maps"])

    hits = np.asarray(state["maps"]["hits"]["values"])
    np.testing.assert_array_equal(hits[:cfg.num_layers], [4] * cfg.num_layers)
    assert int(np.asarray(state["maps"]["hist"]["bins"]).sum()) == \
        4 * cfg.num_layers

    # phase 3: daemon reads a consistent snapshot + renders
    snap = daemon_view.snapshot_device("hits")
    np.testing.assert_array_equal(snap["values"], hits)
    txt = render_log2_hist(daemon_view.snapshot_device("hist")["bins"])
    assert "|" in txt
    assert "watch" in daemon_view.read_programs()

    # phase 4: detach; sites become nops again, training continues
    link = [l for l in rt.links.values()
            if l.target == "uprobe:block"][0]
    rt.detach(link.link_id)
    state, m = step_fn()(state, data.next())
    hits2 = np.asarray(state["maps"]["hits"]["values"])
    np.testing.assert_array_equal(hits2, hits)     # unchanged after detach
    assert int(state["step"]) == 8                 # never restarted
    assert losses[-1] < losses[0]                  # and it actually trained
