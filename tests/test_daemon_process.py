"""Cross-PROCESS control plane: the monitor daemon runs as a real separate
process (subprocess) against a live shm region — the paper's bpftime-daemon
story, not just same-process API calls — plus the live program-table
round trip (request_load_attach(live=True) -> table update -> detach)."""
import os
import sys

import waiters

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daemon, events as E, jit as J, loader, maps as M
from repro.core.runtime import BpftimeRuntime
from repro.core.shm import ShmRegion


def test_daemon_subprocess_reads_live_maps(tmp_path):
    rt = BpftimeRuntime()
    rt.create_map(M.MapSpec("counters", M.MapKind.ARRAY, max_entries=8))
    rt.create_map(M.MapSpec("lat", M.MapKind.LOG2HIST))
    rt.setup_shm(str(tmp_path / "shm"))

    # trainer-side activity: host maps are shm-backed (live)
    rt.host_maps["counters"]["values"][3] = 42
    rt.host_maps["lat"]["bins"][5] = 7
    # device-map snapshot publish
    dev = rt.init_device_maps()
    dev["counters"]["values"] = dev["counters"]["values"].at[1].set(99)
    rt.publish(dev)

    env = dict(os.environ, PYTHONPATH="src")
    out = waiters.run_cli(
        [sys.executable, "-m", "repro.core.daemon",
         str(tmp_path / "shm"), "--once"],
        env=env, cwd=os.getcwd())
    assert out.returncode == 0, out.stderr[-2000:]
    assert "counters" in out.stdout
    assert "{1: 99}" in out.stdout          # device snapshot visible
    assert "lat" in out.stdout


def test_daemon_subprocess_injects_program(tmp_path):
    """Daemon CLI --attach queues a program; the trainer picks it up."""
    from repro.core import loader
    rt = BpftimeRuntime()
    spec = M.MapSpec("hits", M.MapKind.ARRAY, max_entries=8)
    rt.create_map(spec)
    rt.setup_shm(str(tmp_path / "shm"))

    obj = loader.build_object("inject", """
        mov r6, 0
        stxdw [r10-8], r6
        lddw r1, map:hits
        mov r2, r10
        add r2, -8
        mov r3, 1
        call map_fetch_add
        mov r0, 0
        exit
    """, [spec], "uprobe", attach_to="uprobe:block")
    objpath = tmp_path / "prog.json"
    objpath.write_text(obj.to_json())

    env = dict(os.environ, PYTHONPATH="src")
    out = waiters.run_cli(
        [sys.executable, "-m", "repro.core.daemon",
         str(tmp_path / "shm"), "--attach", str(objpath)],
        env=env, cwd=os.getcwd())
    assert out.returncode == 0, out.stderr[-2000:]

    applied = rt.poll_control()
    assert len(applied) == 1 and "error" not in applied[0]
    assert rt.device_attach            # program is live


# ---------------------------------------------------------------- live table

HITS_PROG = """
    mov r6, 0
    stxdw [r10-8], r6
    lddw r1, map:hits
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""


def _live_trainer(tmp_path):
    """Trainer side: live lane enabled, shm up, step already compiled."""
    rt = BpftimeRuntime()
    spec = M.MapSpec("hits", M.MapKind.ARRAY, max_entries=8)
    rt.create_map(spec)
    rt.enable_live_attach(max_programs=2, max_insns=32,
                          arm=("uprobe:block",))
    rt.setup_shm(str(tmp_path / "shm"))

    rows = np.zeros((4, E.EVENT_WIDTH), np.int64)
    rows[:, 0] = E.SITES.get_or_create("block")
    rows[:, 1] = E.KIND_ENTRY
    rows = jnp.asarray(rows)

    @jax.jit
    def stage(r, m):
        m, _ = rt.probe_stage(r, m, J.make_aux())
        return m

    maps = stage(rows, rt.init_device_maps())
    assert stage._cache_size() == 1
    return rt, stage, rows, maps


def test_live_round_trip_through_shm(tmp_path):
    """Full paper scenario over a REAL shm region: a daemon-side handle
    queues a live load+attach, the trainer applies it into the running
    compiled step (generation bumps, no retrace), the daemon confirms via
    the published status, then detaches — all without the trainer ever
    re-jitting."""
    rt, stage, rows, maps = _live_trainer(tmp_path)

    spec = M.MapSpec("hits", M.MapKind.ARRAY, max_entries=8)
    obj = loader.build_object("hits_live", HITS_PROG, [spec], "uprobe",
                              attach_to="uprobe:block")
    other = ShmRegion.attach(str(tmp_path / "shm"))
    daemon.request_load_attach(other, obj.to_json(), live=True)

    applied = rt.poll_control()
    assert len(applied) == 1 and "error" not in applied[0]
    maps = rt.sync_live_table(maps)
    maps = stage(rows, maps)
    assert stage._cache_size() == 1, "live inject retraced the step"
    assert np.asarray(maps["hits"]["values"])[0] == rows.shape[0]

    status = other.read_status()
    assert status["live_gen"] == 1
    assert status["live_slots"]["0"] == "hits_live"
    lid = applied[0]["link_id"]
    assert status["links"][str(lid)] == "uprobe:block"

    daemon.request_detach(other, lid)
    assert rt.poll_control() == [{"op": "detach", "link_id": lid}]
    maps = rt.sync_live_table(maps)
    before = int(np.asarray(maps["hits"]["values"])[0])
    maps = stage(rows, maps)
    assert int(np.asarray(maps["hits"]["values"])[0]) == before
    assert other.read_status()["live_gen"] == 2
    assert other.read_status()["live_slots"]["0"] is None


def test_live_reject_leaves_generation_untouched(tmp_path):
    """A verifier-failing program and a program against an unknown map are
    both rejected at the control plane: error reported, generation counter
    (and therefore the running table) untouched."""
    rt, stage, rows, maps = _live_trainer(tmp_path)
    other = ShmRegion.attach(str(tmp_path / "shm"))

    # (a) fails verification outright: r0 never set before exit
    bad = loader.ProgramObject(
        name="bad", prog_type="uprobe",
        insns_hex="9500000000000000",        # bare `exit`
        maps=[], relocs={}, attach_to="uprobe:block")
    daemon.request_load_attach(other, bad.to_json(), live=True)
    applied = rt.poll_control()
    assert "error" in applied[0] and "r0" in applied[0]["error"]

    # (b) verifies, but touches a map unknown to the compiled interpreter
    late = M.MapSpec("late_map", M.MapKind.ARRAY, max_entries=8)
    obj = loader.build_object(
        "late", HITS_PROG.replace("map:hits", "map:late_map"), [late],
        "uprobe", attach_to="uprobe:block")
    daemon.request_load_attach(other, obj.to_json(), live=True)
    applied = rt.poll_control()
    assert "error" in applied[0] and "created after" in applied[0]["error"]

    assert rt.live.host["gen"][0] == 0
    assert other.read_status()["live_gen"] == 0
    maps = rt.sync_live_table(maps)
    maps = stage(rows, maps)
    assert stage._cache_size() == 1
    assert np.asarray(maps["hits"]["values"]).sum() == 0


def _live_fleet_worker(root, wid):
    """One fleet worker: live lane enabled, joined as workers/<wid>/, step
    already compiled."""
    rt = BpftimeRuntime()
    spec = M.MapSpec("hits", M.MapKind.ARRAY, max_entries=8)
    rt.create_map(spec)
    rt.enable_live_attach(max_programs=2, max_insns=32,
                          arm=("uprobe:block",))
    rt.setup_shm(root, worker_id=wid)

    rows = np.zeros((4, E.EVENT_WIDTH), np.int64)
    rows[:, 0] = E.SITES.get_or_create("block")
    rows[:, 1] = E.KIND_ENTRY
    rows = jnp.asarray(rows)

    @jax.jit
    def stage(r, m):
        m, _ = rt.probe_stage(r, m, J.make_aux())
        return m

    maps = stage(rows, rt.init_device_maps())
    assert stage._cache_size() == 1
    return rt, stage, rows, maps


def test_cli_live_attach_fans_out_to_whole_fleet(tmp_path, capsys):
    """A live attach issued once through the bpftool-style CLI reaches
    EVERY worker's program table; no worker retraces (jit cache stays 1
    per worker) — the fleet-wide injection-without-restart story."""
    root = str(tmp_path / "shm")
    wids = ["w0", "w1", "w2"]
    fleet = {wid: _live_fleet_worker(root, wid) for wid in wids}

    spec = M.MapSpec("hits", M.MapKind.ARRAY, max_entries=8)
    obj = loader.build_object("fleet_live", HITS_PROG, [spec], "uprobe",
                              attach_to="uprobe:block")
    objpath = tmp_path / "prog.json"
    objpath.write_text(obj.to_json())

    rc = daemon.main([root, "attach", str(objpath), "--live"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "w0" in out and "w1" in out and "w2" in out

    for wid in wids:
        rt, stage, rows, maps = fleet[wid]
        applied = rt.poll_control()
        assert len(applied) == 1 and "error" not in applied[0], (wid, applied)
        maps = rt.sync_live_table(maps)
        maps = stage(rows, maps)
        assert stage._cache_size() == 1, f"{wid} retraced on live attach"
        assert np.asarray(maps["hits"]["values"])[0] == rows.shape[0]
        assert rt.shm.read_status()["live_gen"] == 1
        fleet[wid] = (rt, stage, rows, maps)

    # prog list sees every worker's link
    rc = daemon.main([root, "prog", "list"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "fleet_live" in out
    for wid in wids:
        assert f"(worker {wid})" in out

    # detach fans out the same way
    lid = int(next(iter(fleet["w0"][0].links)))
    rc = daemon.main([root, "detach", str(lid)])
    assert rc == 0
    capsys.readouterr()
    for wid in wids:
        rt, stage, rows, maps = fleet[wid]
        assert rt.poll_control() == [{"op": "detach", "link_id": lid}]
        maps = rt.sync_live_table(maps)
        before = int(np.asarray(maps["hits"]["values"])[0])
        maps = stage(rows, maps)
        assert stage._cache_size() == 1
        assert int(np.asarray(maps["hits"]["values"])[0]) == before


def test_daemon_cli_live_inject(tmp_path):
    """The daemon CLI --attach --live queues a live-table injection."""
    rt, stage, rows, maps = _live_trainer(tmp_path)
    spec = M.MapSpec("hits", M.MapKind.ARRAY, max_entries=8)
    obj = loader.build_object("cli_live", HITS_PROG, [spec], "uprobe",
                              attach_to="uprobe:block")
    objpath = tmp_path / "prog.json"
    objpath.write_text(obj.to_json())

    env = dict(os.environ, PYTHONPATH="src")
    out = waiters.run_cli(
        [sys.executable, "-m", "repro.core.daemon",
         str(tmp_path / "shm"), "--attach", str(objpath), "--live"],
        env=env, cwd=os.getcwd())
    assert out.returncode == 0, out.stderr[-2000:]
    assert "live" in out.stdout

    applied = rt.poll_control()
    assert len(applied) == 1 and "error" not in applied[0]
    assert rt.live.host["active"][0] == 1
    assert not rt.device_attach         # no epoch-lane attachment happened
    maps = rt.sync_live_table(maps)
    maps = stage(rows, maps)
    assert stage._cache_size() == 1
    assert np.asarray(maps["hits"]["values"])[0] == rows.shape[0]
