"""Cross-PROCESS control plane: the monitor daemon runs as a real separate
process (subprocess) against a live shm region — the paper's bpftime-daemon
story, not just same-process API calls."""
import os
import subprocess
import sys

import numpy as np

from repro.core import maps as M
from repro.core.runtime import BpftimeRuntime


def test_daemon_subprocess_reads_live_maps(tmp_path):
    rt = BpftimeRuntime()
    rt.create_map(M.MapSpec("counters", M.MapKind.ARRAY, max_entries=8))
    rt.create_map(M.MapSpec("lat", M.MapKind.LOG2HIST))
    rt.setup_shm(str(tmp_path / "shm"))

    # trainer-side activity: host maps are shm-backed (live)
    rt.host_maps["counters"]["values"][3] = 42
    rt.host_maps["lat"]["bins"][5] = 7
    # device-map snapshot publish
    dev = rt.init_device_maps()
    dev["counters"]["values"] = dev["counters"]["values"].at[1].set(99)
    rt.publish(dev)

    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.daemon",
         str(tmp_path / "shm"), "--once"],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "counters" in out.stdout
    assert "{1: 99}" in out.stdout          # device snapshot visible
    assert "lat" in out.stdout


def test_daemon_subprocess_injects_program(tmp_path):
    """Daemon CLI --attach queues a program; the trainer picks it up."""
    from repro.core import loader
    rt = BpftimeRuntime()
    spec = M.MapSpec("hits", M.MapKind.ARRAY, max_entries=8)
    rt.create_map(spec)
    rt.setup_shm(str(tmp_path / "shm"))

    obj = loader.build_object("inject", """
        mov r6, 0
        stxdw [r10-8], r6
        lddw r1, map:hits
        mov r2, r10
        add r2, -8
        mov r3, 1
        call map_fetch_add
        mov r0, 0
        exit
    """, [spec], "uprobe", attach_to="uprobe:block")
    objpath = tmp_path / "prog.json"
    objpath.write_text(obj.to_json())

    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.daemon",
         str(tmp_path / "shm"), "--attach", str(objpath)],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]

    applied = rt.poll_control()
    assert len(applied) == 1 and "error" not in applied[0]
    assert rt.device_attach            # program is live
