"""Per-kernel validation: Pallas (interpret=True on CPU) vs ref.py oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import hash_update, ops, ref, ringbuf_emit
from repro.kernels import tensor_stats as ts

SHAPES = [(7,), (128,), (1024,), (1025,), (4, 333), (16, 1024), (3, 5, 129),
          (8192,), (1,)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_tensor_stats_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    x = (jax.random.normal(key, shape, jnp.float32) * 10).astype(dtype)
    got = ts.tensor_stats_pallas(x, interpret=True)
    want = ref.tensor_stats(x)
    for k in ("mean", "rms", "min", "max", "absmax"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-5, err_msg=k)
    assert int(got["nan_cnt"]) == int(want["nan_cnt"])
    assert int(got["inf_cnt"]) == int(want["inf_cnt"])


def test_tensor_stats_nan_inf():
    x = jnp.asarray([1.0, jnp.nan, -jnp.inf, 4.0, jnp.inf, -2.0], jnp.float32)
    got = ts.tensor_stats_pallas(x, interpret=True)
    want = ref.tensor_stats(x)
    assert int(got["nan_cnt"]) == 1 and int(got["inf_cnt"]) == 2
    np.testing.assert_allclose(float(got["min"]), float(want["min"]))
    np.testing.assert_allclose(float(got["max"]), float(want["max"]))
    np.testing.assert_allclose(float(got["mean"]), float(want["mean"]),
                               rtol=1e-6)


def test_tensor_stats_all_bad():
    x = jnp.asarray([jnp.nan, jnp.inf], jnp.float32)
    got = ts.tensor_stats_pallas(x, interpret=True)
    assert float(got["min"]) == 0.0 and float(got["max"]) == 0.0


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 3000), scale=st.floats(0.01, 1e4),
       seed=st.integers(0, 2**16))
def test_tensor_stats_property(n, scale, seed):
    x = (jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
         * scale)
    got = ts.tensor_stats_pallas(x, interpret=True)
    want = ref.tensor_stats(x)
    np.testing.assert_allclose(np.asarray(got["rms"]), np.asarray(want["rms"]),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got["absmax"]),
                               np.asarray(want["absmax"]), rtol=1e-6)
    # invariants: rms >= |mean|, min <= mean <= max
    assert float(got["rms"]) >= abs(float(got["mean"])) - 1e-4
    assert float(got["min"]) - 1e-5 <= float(got["mean"]) <= float(got["max"]) + 1e-5


@pytest.mark.parametrize("n,b", [(8, 5), (16, 32), (64, 100), (4, 10)])
def test_hash_fetch_add_matches_ref(n, b):
    rng = np.random.default_rng(n * 1000 + b)
    keys = jnp.asarray(rng.integers(-20, 20, b), jnp.int64)
    deltas = jnp.asarray(rng.integers(-5, 6, b), jnp.int64)
    valid = jnp.asarray(rng.integers(0, 2, b), bool)
    kt = jnp.zeros((n,), jnp.int64)
    ut = jnp.zeros((n,), jnp.int64)
    vt = jnp.zeros((n,), jnp.int64)
    got = hash_update.hash_fetch_add_batch_pallas(kt, ut, vt, keys, deltas,
                                                  valid, interpret=True)
    want = ref.hash_fetch_add_batch(kt, ut, vt, keys, deltas, valid)
    for g, w, name in zip(got, want, ("keys", "used", "values")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_hash_fetch_add_matches_scalar_map_ops():
    """Property: batched kernel == sequential per-event j_hash_fetch_add."""
    from repro.core import maps as M
    spec = M.MapSpec("h", M.MapKind.HASH, max_entries=16)
    st_j = M.init_states([spec])["h"]
    rng = np.random.default_rng(7)
    keys = rng.integers(-10, 10, 40)
    deltas = rng.integers(1, 5, 40)
    for k, d in zip(keys, deltas):
        st_j, _ = M.j_hash_fetch_add(st_j, jnp.int64(k), jnp.int64(d),
                                     jnp.asarray(True))
    got = hash_update.hash_fetch_add_batch_pallas(
        jnp.zeros((16,), jnp.int64), jnp.zeros((16,), jnp.int64),
        jnp.zeros((16,), jnp.int64), jnp.asarray(keys, jnp.int64),
        jnp.asarray(deltas, jnp.int64), jnp.ones((40,), bool),
        interpret=True)
    np.testing.assert_array_equal(np.asarray(st_j["values"]),
                                  np.asarray(got[2]))
    np.testing.assert_array_equal(np.asarray(st_j["keys"]),
                                  np.asarray(got[0]))


@pytest.mark.parametrize("cap,b,w", [(8, 5, 4), (4, 12, 2), (16, 16, 8)])
def test_ringbuf_emit_matches_ref(cap, b, w):
    rng = np.random.default_rng(cap * 100 + b)
    rows = jnp.asarray(rng.integers(-100, 100, (b, w)), jnp.int64)
    valid = jnp.asarray(rng.integers(0, 2, b), bool)
    data = jnp.zeros((cap, w), jnp.int64)
    head = jnp.asarray([3], jnp.int64)
    gd, gh = ringbuf_emit.ringbuf_emit_batch_pallas(data, head, rows, valid,
                                                    interpret=True)
    wd, wh = ref.ringbuf_emit_batch(data, head, rows, valid)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(wh))


def test_log2_histogram_total():
    x = jnp.asarray(np.random.default_rng(0).normal(size=500), jnp.float32)
    h = ref.log2_histogram(x)
    assert int(h.sum()) == 500


def test_ops_dispatch():
    x = jnp.ones((64,), jnp.float32)
    a = ops.tensor_stats(x, impl="ref")
    b = ops.tensor_stats(x, impl="pallas_interpret")
    np.testing.assert_allclose(float(a["mean"]), float(b["mean"]))
