"""Differential tests for the fused probe pipeline: the single-pass fused
dispatch, the batched-HASH vectorized path, the word-oriented stack, and
ringbuf `dropped` accounting must all produce states bit-identical to the
seed scan mode / the numpy oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import asm, events as E, isa, jit as J, maps as M
from repro.core import vectorized as V, verifier, vm
from repro.core.runtime import BpftimeRuntime

COUNT_BY_LAYER = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:layer_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

COUNT_KEY_HASH = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:hkeys
    mov r2, r10
    add r2, -8
    mov r3, 2
    call map_fetch_add
    mov r0, 0
    exit
"""

HIST_RMS = """
    ldxdw r2, [r1+ctx:rms]
    lddw r1, map:rms_hist
    call hist_add
    mov r0, 0
    exit
"""

RB_PROG = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-32], r6
    ldxdw r6, [r1+ctx:numel]
    stxdw [r10-24], r6
    lddw r1, map:events_rb
    mov r2, r10
    add r2, -32
    mov r3, 16
    mov r4, 0
    call ringbuf_output
    mov r0, 0
    exit
"""

# T2: data-dependent loop -> combined-scan lane of the fused pipeline
LOOP_ACC = """
    ldxdw r6, [r1+ctx:layer]
    and r6, 3
    add r6, 1
    mov r8, 0
    l:
    add r8, 1
    sub r6, 1
    jgt r6, 0, l
    stxdw [r10-8], r8
    lddw r1, map:loop_acc
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

ARR = M.MapSpec("layer_counts", M.MapKind.ARRAY, max_entries=16)
HASH_SMALL = M.MapSpec("hkeys", M.MapKind.HASH, max_entries=4)
HIST = M.MapSpec("rms_hist", M.MapKind.LOG2HIST)
RB = M.MapSpec("events_rb", M.MapKind.RINGBUF, max_entries=4, rec_width=4)
LOOP_ARR = M.MapSpec("loop_acc", M.MapKind.ARRAY, max_entries=8)


def _tape(rows_spec):
    """rows_spec: list of (site_name, kind, layer, rms, numel)."""
    rows = np.zeros((len(rows_spec), E.EVENT_WIDTH), np.int64)
    for i, (site, kind, layer, rms, numel) in enumerate(rows_spec):
        rows[i, 0] = E.SITES.get_or_create(site)
        rows[i, 1] = kind
        rows[i, 2] = layer
        rows[i, 6] = rms
        rows[i, 4] = numel
    return jnp.asarray(rows)


def _run_mode(rt, rows, mode):
    ms = rt.init_device_maps()
    aux = J.make_aux(time_ns=7, cpu=1, pid=42)
    return rt.probe_stage(rows, ms, aux, mode=mode)


def _assert_states_equal(a, b, tag):
    for name in a:
        for field in a[name]:
            np.testing.assert_array_equal(
                np.asarray(a[name][field]), np.asarray(b[name][field]),
                err_msg=f"[{tag}] {name}.{field}")


MIXED_TAPE = [
    ("fpA", E.KIND_ENTRY, 0, 5, 8),
    ("fpB", E.KIND_ENTRY, 1, 300, 8),
    ("fpA", E.KIND_EXIT, 2, 17, 16),
    ("fpA", E.KIND_ENTRY, 1, 9, 8),
    ("fp_unattached", E.KIND_ENTRY, 3, 1, 8),
    ("fpB", E.KIND_ENTRY, 0, 70000, 32),
    ("fpA", E.KIND_ENTRY, 0, 2, 8),
    ("fpB", E.KIND_EXIT, 5, 12, 8),
    ("fpA", E.KIND_ENTRY, 6, 1023, 8),
    ("fpA", E.KIND_ENTRY, 1, 0, 8),
]


def _multi_runtime():
    """3 programs across 2 sites and 2 kinds; ARRAY + HASH + LOG2HIST."""
    rt = BpftimeRuntime()
    p1 = rt.load_asm("count_by_layer", COUNT_BY_LAYER, [ARR], "uprobe")
    rt.attach(p1, "uprobe:fpA")
    rt.attach(p1, "uprobe:fpB")
    p2 = rt.load_asm("count_key_hash", COUNT_KEY_HASH, [HASH_SMALL],
                     "uprobe")
    rt.attach(p2, "uprobe:fpA")
    rt.attach(p2, "uretprobe:fpB")
    p3 = rt.load_asm("hist_rms", HIST_RMS, [HIST], "uprobe")
    rt.attach(p3, "uretprobe:fpA")
    rt.attach(p3, "uprobe:fpB")
    return rt


def test_fused_multi_program_multi_site_matches_scan():
    rt = _multi_runtime()
    rows = _tape(MIXED_TAPE)
    ms_scan, _ = _run_mode(rt, rows, "scan")
    ms_vec, _ = _run_mode(rt, rows, "vectorized")
    ms_fused, _ = _run_mode(rt, rows, "fused")
    _assert_states_equal(ms_scan, ms_vec, "vectorized-vs-scan")
    _assert_states_equal(ms_scan, ms_fused, "fused-vs-scan")


def test_fused_matches_scan_under_jit():
    rt = _multi_runtime()
    rows = _tape(MIXED_TAPE)

    @jax.jit
    def scan_f(rows, ms, aux):
        return rt.probe_stage(rows, ms, aux, mode="scan")

    @jax.jit
    def fused_f(rows, ms, aux):
        return rt.probe_stage(rows, ms, aux, mode="fused")

    ms0 = rt.init_device_maps()
    aux0 = J.make_aux(time_ns=7)
    a, _ = scan_f(rows, ms0, aux0)
    b, _ = fused_f(rows, ms0, aux0)
    _assert_states_equal(a, b, "jit fused-vs-scan")


def test_fused_hash_duplicate_and_overflow_keys():
    """Duplicate keys aggregate; distinct keys beyond capacity drop in
    first-occurrence order — bit-identical keys/used/values tables."""
    rt = BpftimeRuntime()
    pid = rt.load_asm("hk", COUNT_KEY_HASH, [HASH_SMALL], "uprobe")
    rt.attach(pid, "uprobe:fpH")
    spec = [("fpH", E.KIND_ENTRY, layer, 0, 0)
            for layer in (9, 2, 9, 7, 2, 11, 5, 9, 3, 7, 1, 9)]
    rows = _tape(spec)
    ms_scan, _ = _run_mode(rt, rows, "scan")
    ms_fused, _ = _run_mode(rt, rows, "fused")
    ms_vec, _ = _run_mode(rt, rows, "vectorized")
    _assert_states_equal(ms_scan, ms_fused, "hash fused")
    _assert_states_equal(ms_scan, ms_vec, "hash vectorized")
    # sanity: duplicates aggregated (key 9 appeared 4x with delta 2)
    kt = np.asarray(ms_fused["hkeys"]["keys"])
    vt = np.asarray(ms_fused["hkeys"]["values"])
    assert vt[list(kt).index(9)] == 8


def test_hash_batch_matches_sequential_twin():
    """maps-level differential: j_hash_fetch_add_batch vs sequential
    j_hash_fetch_add vs the numpy twin, with colliding keys and a broken
    probe chain (delete between inserts)."""
    n = 8
    spec = M.MapSpec("h", M.MapKind.HASH, max_entries=n)
    # pre-populate + delete to create a broken chain
    st_np = M.init_state(spec, np)
    for k, v in ((3, 10), (11, 20), (19, 30)):   # likely colliding mod 8
        M.n_hash_fetch_add(st_np, k, v)
    M.n_hash_delete(st_np, 11)
    # jnp.array (copy): jnp.asarray may alias the numpy buffer on CPU, and
    # the numpy twin below mutates st_np in place.
    st_j = jax.tree.map(lambda a: jnp.array(a), st_np)

    keys = np.array([19, 42, 3, 19, 42, 99, 3, 27, 11, 42], np.int64)
    deltas = np.arange(1, 11, dtype=np.int64)
    ok = np.array([1, 1, 1, 1, 0, 1, 1, 1, 1, 1], bool)

    # numpy twin, sequential
    for k, d, o in zip(keys, deltas, ok):
        if o:
            M.n_hash_fetch_add(st_np, int(k), int(d))
    # jnp sequential twin
    st_seq = {k: v for k, v in st_j.items()}
    for k, d, o in zip(keys, deltas, ok):
        st_seq, _ = M.j_hash_fetch_add(st_seq, jnp.int64(k), jnp.int64(d),
                                       jnp.asarray(bool(o)))
    # batched
    st_b = M.j_hash_fetch_add_batch(st_j, jnp.asarray(keys),
                                    jnp.asarray(deltas), jnp.asarray(ok))
    for field in ("keys", "used", "values"):
        np.testing.assert_array_equal(np.asarray(st_b[field]),
                                      np.asarray(st_seq[field]),
                                      err_msg=f"batch-vs-seq {field}")
        np.testing.assert_array_equal(np.asarray(st_b[field]),
                                      st_np[field],
                                      err_msg=f"batch-vs-np {field}")


def test_hash_batch_jit_and_empty_batch():
    spec = M.MapSpec("h", M.MapKind.HASH, max_entries=16)
    st = M.init_state(spec, jnp)
    keys = jnp.asarray([5, 5, 6], jnp.int64)
    deltas = jnp.asarray([1, 2, 3], jnp.int64)
    f = jax.jit(M.j_hash_fetch_add_batch)
    out = f(st, keys, deltas, jnp.asarray([True, True, True]))
    assert int(out["values"][np.asarray(out["keys"]).tolist().index(5)]) == 3
    # all-invalid batch is a no-op
    out2 = f(st, keys, deltas, jnp.zeros((3,), bool))
    for field in ("keys", "used", "values"):
        np.testing.assert_array_equal(np.asarray(out2[field]),
                                      np.asarray(st[field]))


# ---------------------------------------------------------------- ringbuf

def test_ringbuf_dropped_parity_scan_fused_oracle():
    rt = BpftimeRuntime()
    pid = rt.load_asm("rb", RB_PROG, [RB], "uprobe")
    rt.attach(pid, "uprobe:fpR")
    spec = [("fpR", E.KIND_ENTRY, i, 0, 100 + i) for i in range(10)]
    rows = _tape(spec)
    ms_scan, _ = _run_mode(rt, rows, "scan")
    ms_fused, _ = _run_mode(rt, rows, "fused")
    ms_vec, _ = _run_mode(rt, rows, "vectorized")
    _assert_states_equal(ms_scan, ms_fused, "ringbuf fused")
    _assert_states_equal(ms_scan, ms_vec, "ringbuf vectorized")
    # cap=4, 10 emits -> 6 overwrote unread records
    assert int(ms_scan["events_rb"]["dropped"][0]) == 6
    assert int(ms_scan["events_rb"]["head"][0]) == 10

    # numpy twin parity
    st = M.init_state(RB, np)
    for i in range(10):
        M.n_ringbuf_emit(st, [i, 100 + i, 0, 0])
    assert st["dropped"][0] == 6
    np.testing.assert_array_equal(st["data"],
                                  np.asarray(ms_scan["events_rb"]["data"]))


def test_ringbuf_no_drop_below_capacity():
    st_j = M.init_state(RB, jnp)
    st_n = M.init_state(RB, np)
    for i in range(4):
        st_j = M.j_ringbuf_emit(st_j, jnp.full((4,), i, jnp.int64),
                                jnp.asarray(True))
        M.n_ringbuf_emit(st_n, [i] * 4)
    assert int(st_j["dropped"][0]) == 0 and st_n["dropped"][0] == 0
    st_j = M.j_ringbuf_emit(st_j, jnp.zeros((4,), jnp.int64),
                            jnp.asarray(True))
    M.n_ringbuf_emit(st_n, [0] * 4)
    assert int(st_j["dropped"][0]) == 1 and st_n["dropped"][0] == 1


# ---------------------------------------------------------------- T2 lane

def test_fused_combined_scan_for_loop_programs():
    rt = BpftimeRuntime()
    p1 = rt.load_asm("loop_acc", LOOP_ACC, [LOOP_ARR], "uprobe")
    rt.attach(p1, "uprobe:fpL")
    p2 = rt.load_asm("count_by_layer", COUNT_BY_LAYER, [ARR], "uprobe")
    rt.attach(p2, "uprobe:fpL")      # T1 rides the vector lane
    assert rt.progs[p1].vprog.tier == "loop"
    spec = [("fpL", E.KIND_ENTRY, i % 5, i, 0) for i in range(9)]
    spec.append(("fp_unattached", E.KIND_ENTRY, 1, 1, 0))
    rows = _tape(spec)
    ms_scan, _ = _run_mode(rt, rows, "scan")
    ms_fused, _ = _run_mode(rt, rows, "fused")
    _assert_states_equal(ms_scan, ms_fused, "loop fused")
    assert np.asarray(ms_fused["loop_acc"]["values"]).sum() == 9


LOOP_RB = """
    ldxdw r6, [r1+ctx:layer]
    and r6, 3
    add r6, 1
    mov r8, 0
    l:
    add r8, 1
    sub r6, 1
    jgt r6, 0, l
    stxdw [r10-32], r8
    stxdw [r10-24], r8
    lddw r1, map:shared_rb
    mov r2, r10
    add r2, -32
    mov r3, 16
    mov r4, 0
    call ringbuf_output
    mov r0, 0
    exit
"""

RB_SHARED = M.MapSpec("shared_rb", M.MapKind.RINGBUF, max_entries=32,
                      rec_width=4)
RB_PROG_SHARED = RB_PROG.replace("map:events_rb", "map:shared_rb")


def test_fused_falls_back_on_cross_program_ringbuf():
    """Two DIFFERENT programs (one loop-tier, one vector-safe) emitting to
    ONE ringbuf: record interleaving is order-sensitive, so the fused
    scheduler must fall back to seed scan ordering — states bit-identical
    including the data stream."""
    from repro.core.runtime import _has_ordering_conflict
    rt = BpftimeRuntime()
    p1 = rt.load_asm("loop_rb", LOOP_RB, [RB_SHARED], "uprobe")
    rt.attach(p1, "uprobe:fpS1")
    p2 = rt.load_asm("t1_rb", RB_PROG_SHARED, [RB_SHARED], "uprobe")
    rt.attach(p2, "uprobe:fpS2")
    assert _has_ordering_conflict(
        [rt.progs[p1].vprog, rt.progs[p2].vprog])
    spec = [("fpS1" if i % 2 else "fpS2", E.KIND_ENTRY, i, 0, 100 + i)
            for i in range(8)]
    rows = _tape(spec)
    ms_scan, _ = _run_mode(rt, rows, "scan")
    ms_fused, _ = _run_mode(rt, rows, "fused")
    _assert_states_equal(ms_scan, ms_fused, "shared-ringbuf fallback")


def test_fused_falls_back_on_multi_attached_scan_ringbuf():
    """A loop-tier ringbuf program attached to TWO sites loses
    per-attachment record order in a combined scan — must fall back."""
    rt = BpftimeRuntime()
    p1 = rt.load_asm("loop_rb", LOOP_RB, [RB_SHARED], "uprobe")
    rt.attach(p1, "uprobe:fpM1")
    rt.attach(p1, "uprobe:fpM2")
    spec = [("fpM1" if i % 2 else "fpM2", E.KIND_ENTRY, i, 0, 0)
            for i in range(6)]
    rows = _tape(spec)
    ms_scan, _ = _run_mode(rt, rows, "scan")
    ms_fused, _ = _run_mode(rt, rows, "fused")
    _assert_states_equal(ms_scan, ms_fused, "multi-attach fallback")


def test_commutative_sharing_stays_fused():
    """Two programs sharing one ARRAY map via fetch_add only: commutative,
    no fallback needed — and still bit-identical."""
    from repro.core.runtime import _has_ordering_conflict
    rt = BpftimeRuntime()
    p1 = rt.load_asm("count_by_layer", COUNT_BY_LAYER, [ARR], "uprobe")
    rt.attach(p1, "uprobe:fpC1")
    prog2 = COUNT_BY_LAYER.replace("ctx:layer", "ctx:numel")
    p2 = rt.load_asm("count_by_numel", prog2, [ARR], "uprobe")
    rt.attach(p2, "uprobe:fpC2")
    assert not _has_ordering_conflict(
        [rt.progs[p1].vprog, rt.progs[p2].vprog])
    spec = [("fpC1" if i % 2 else "fpC2", E.KIND_ENTRY, i % 4, 0, i % 3)
            for i in range(10)]
    rows = _tape(spec)
    ms_scan, _ = _run_mode(rt, rows, "scan")
    ms_fused, _ = _run_mode(rt, rows, "fused")
    _assert_states_equal(ms_scan, ms_fused, "commutative sharing")


def test_touched_maps_footprint():
    rt = BpftimeRuntime()
    pid = rt.load_asm("count_by_layer", COUNT_BY_LAYER, [ARR], "uprobe")
    vp = rt.progs[pid].vprog
    assert vp.touched_map_names() == ("layer_counts",)
    assert vp.touched_aux == frozenset()
    pid2 = rt.load_asm("hist_rms", HIST_RMS, [HIST], "uprobe")
    vp2 = rt.progs[pid2].vprog
    assert vp2.touched_map_names() == ("rms_hist",)


# ---------------------------------------------------------------- word stack

def _run_both(text, ctx_words=None):
    """vm oracle vs JIT on a map-free program; returns (r0_vm, r0_jit)."""
    ctx_words = ctx_words or [0] * 8
    a = asm.assemble(text)
    vprog = verifier.verify(a.insns, [], ctx_words=len(ctx_words))
    res = vm.run(a.insns, vm.pack_ctx(ctx_words), [], {})
    prog = J.compile_program(vprog)
    ctx = jnp.asarray([isa.s64(isa.u64(w)) for w in ctx_words], jnp.int64)
    r0, _, _ = jax.jit(prog)(ctx, {}, J.make_aux())
    assert isa.u64(int(r0)) == isa.u64(res.r0), \
        f"jit={isa.u64(int(r0)):#x} vm={isa.u64(res.r0):#x}"
    return res.r0, int(r0)


def test_word_stack_aligned_roundtrip():
    _run_both("""
        lddw r6, 0x1122334455667788
        stxdw [r10-8], r6
        ldxdw r0, [r10-8]
        exit
    """)


def test_word_stack_subword_load_zero_extends():
    _run_both("""
        lddw r6, 0xfedcba9876543210
        stxdw [r10-8], r6
        ldxw r0, [r10-8]
        exit
    """)
    _run_both("""
        lddw r6, 0xfedcba9876543210
        stxdw [r10-8], r6
        ldxh r0, [r10-6]
        exit
    """)
    _run_both("""
        lddw r6, 0xfedcba9876543210
        stxdw [r10-8], r6
        ldxb r0, [r10-3]
        exit
    """)


def test_word_stack_unaligned_cross_word():
    """8-byte load/store spanning two stack words stays byte-exact."""
    _run_both("""
        lddw r6, 0x0102030405060708
        stxdw [r10-16], r6
        lddw r6, 0x1112131415161718
        stxdw [r10-8], r6
        ldxdw r0, [r10-13]
        exit
    """)
    _run_both("""
        lddw r6, 0x00000000deadbeef
        stxdw [r10-16], r6
        stxdw [r10-8], r6
        stxw [r10-10], r6
        ldxdw r3, [r10-16]
        ldxdw r0, [r10-8]
        xor r0, r3
        exit
    """)


def test_word_stack_byte_stores_then_word_load():
    _run_both("""
        mov r6, 0
        stxdw [r10-8], r6
        mov r6, 0xab
        stxb [r10-8], r6
        mov r6, 0xcd
        stxb [r10-5], r6
        mov r6, 0x1234
        stxh [r10-4], r6
        ldxdw r0, [r10-8]
        exit
    """)


def test_word_stack_st_imm_sign_extension():
    _run_both("""
        mov r6, 0
        stxdw [r10-8], r6
        stw [r10-8], -2
        ldxdw r0, [r10-8]
        exit
    """)


def test_memann_aligned_flag():
    a = asm.assemble("""
        mov r6, 1
        stxdw [r10-8], r6
        stxb [r10-9], r6
        ldxdw r0, [r10-8]
        exit
    """)
    vp = verifier.verify(a.insns, [], ctx_words=8)
    anns = [ann for ann in vp.anns.values()
            if isinstance(ann, verifier.MemAnn) and ann.region == "stack"]
    flags = {(ann.off, ann.size): ann.aligned for ann in anns}
    assert flags[(504, 8)] is True
    assert flags[(503, 1)] is False
