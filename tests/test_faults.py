"""Chaos matrix for the fleet plane (DESIGN.md §11).

Every FaultPlan fault class gets a test where the injected fault actually
FIRES (asserted via the plan's counters) and the final global view is still
bit-identical to the no-fault oracle — reusing the differential harness
from test_shm_merge_differential. The aggregator-crash tests kill the
daemon at seeded points and assert the journal-recovered successor never
double-folds or loses a delta; the health tests walk a worker through
killed / stalled / recovered and check the `fleet health` CLI surfaces the
transitions.

Single-process tests carry the `chaos` marker (tier-1 + CI chaos job);
the multi-process SIGKILL scenarios are `chaos + slow`.
"""
import json
import multiprocessing as mp
import os
import signal
import subprocess
import time

import numpy as np
import pytest

from repro.core import daemon as D, faults as F, maps as M, shm as SH

from test_shm_merge_differential import (
    SPECS, apply_event, assert_global_matches_oracle, gen_tape,
    oracle_states, _mark_worker_dead)

pytestmark = pytest.mark.chaos


# --------------------------------------------------------------------------
# fleet scaffolding: the differential harness's run_fleet, but with a
# FaultPlan installed — worker publishes may be abandoned (TornPublish),
# the daemon may crash (InjectedCrash, restarted from the journal)
# --------------------------------------------------------------------------

def _make_fleet(root, n_workers):
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(n_workers)}
    states = {w: M.init_states(SPECS, np) for w in range(n_workers)}
    return regions, states


def _fast_cfg(**kw):
    """Tight retry budget + microsecond backoff: a stuck-odd worker costs
    the cycle ~1ms instead of the production half-second demotion window."""
    kw.setdefault("snapshot_retries", 8)
    kw.setdefault("backoff_base", 1e-5)
    kw.setdefault("backoff_max", 1e-4)
    return D.AggregatorConfig(**kw)


def _chaos_fleet(root, tape, n_workers, plan, rounds=4, config=None):
    """Run the fleet under an installed FaultPlan. Worker publishes hit by
    torn_publish/stuck_odd are abandoned mid-flight (seqlock left odd) and
    NOT retried within the round — the next round's publish self-heals.
    A daemon crash replaces the Aggregator with a fresh instance (journal
    recovery). Ends with a fault-free convergence round."""
    config = config or _fast_cfg()
    regions, states = _make_fleet(root, n_workers)
    per_worker = {w: [t for t in tape if t[1] == w]
                  for w in range(n_workers)}
    chunks = {w: np.array_split(np.arange(len(per_worker[w])), rounds)
              for w in range(n_workers)}
    agg = D.Aggregator(root, config=config)
    restarts = 0
    with F.plan(plan):
        for r in range(rounds):
            for w in range(n_workers):
                for i in chunks[w][r]:
                    step, _, _, ev = per_worker[w][i]
                    apply_event(states[w], ev, step)
                try:
                    regions[w].publish_device(states[w])
                except F.TornPublish:
                    pass              # abandoned publish: seqlock stays odd
            try:
                agg.poll_once()
            except F.InjectedCrash:
                agg = D.Aggregator(root, config=config)   # journal restart
                restarts += 1
    # convergence: clean republish (self-heals any stuck-odd seqlock and
    # rewrites any corrupted section) + two clean polls
    for w in range(n_workers):
        regions[w].publish_device(states[w])
    agg.poll_once()
    status = agg.poll_once()
    return agg, status, restarts


# --------------------------------------------------------------------------
# per-class: the fault fires AND the view converges to the oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_torn_publish_fires_and_converges(tmp_path, seed):
    root = str(tmp_path / "shm")
    tape = gen_tape(np.random.default_rng(seed), 2, n_events=60)
    plan = F.FaultPlan(seed=seed, rates={"torn_publish": 0.6})
    _chaos_fleet(root, tape, 2, plan)
    assert plan.counters["torn_publish"] >= 1
    assert_global_matches_oracle(root, oracle_states(tape))


@pytest.mark.parametrize("seed", [0, 1])
def test_stuck_odd_fires_and_converges(tmp_path, seed):
    root = str(tmp_path / "shm")
    tape = gen_tape(np.random.default_rng(10 + seed), 2, n_events=60)
    plan = F.FaultPlan(seed=seed, rates={"stuck_odd": 0.5})
    agg, status, _ = _chaos_fleet(root, tape, 2, plan)
    assert plan.counters["stuck_odd"] >= 1
    assert_global_matches_oracle(root, oracle_states(tape))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_corrupt_snapshot_detected_skipped_and_converges(tmp_path, seed):
    """Scribbled bytes land AFTER the CRC write: the section has a
    consistent (even, stable) seqlock but a checksum mismatch. The
    aggregator must skip the worker for the cycle (corrupt_skipped), keep
    its baseline, and fold the clean republish later — never the garbage."""
    root = str(tmp_path / "shm")
    tape = gen_tape(np.random.default_rng(20 + seed), 2, n_events=60)
    plan = F.FaultPlan(seed=seed, rates={"corrupt_snapshot": 0.7})
    agg, status, _ = _chaos_fleet(root, tape, 2, plan)
    assert plan.counters["corrupt_snapshot"] >= 1
    assert sum(agg.corrupt_skipped.values()) >= 1
    assert status["corrupt_skipped"] == agg.corrupt_skipped
    assert_global_matches_oracle(root, oracle_states(tape))


def test_slow_worker_fires_and_converges(tmp_path):
    root = str(tmp_path / "shm")
    tape = gen_tape(np.random.default_rng(30), 2, n_events=40)
    plan = F.FaultPlan(seed=3, rates={"slow_worker": 0.8}, slow_s=0.0005)
    _chaos_fleet(root, tape, 2, plan)
    assert plan.counters["slow_worker"] >= 1
    assert_global_matches_oracle(root, oracle_states(tape))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_mixed_fault_matrix_converges(tmp_path, seed):
    """All in-process fault classes at once, daemon crashes included."""
    root = str(tmp_path / "shm")
    tape = gen_tape(np.random.default_rng(40 + seed), 3, n_events=90)
    plan = F.FaultPlan(
        seed=seed, crash_at=7 + 3 * seed,
        rates={"torn_publish": 0.25, "stuck_odd": 0.15,
               "corrupt_snapshot": 0.25, "slow_worker": 0.1},
        slow_s=0.0003)
    _, _, restarts = _chaos_fleet(root, tape, 3, plan, rounds=5)
    assert restarts >= 1 and plan.counters["daemon_crash"] >= 1
    assert sum(plan.counters.values()) >= 2
    assert_global_matches_oracle(root, oracle_states(tape))


# --------------------------------------------------------------------------
# aggregator crash + journal recovery: never double-fold, never lose
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", list(range(8)))
def test_aggregator_crash_restart_bit_identical(tmp_path, seed):
    """Crash the daemon at a seeded agg:* boundary point (cycle begin,
    pre/post merge, pre/post publish, pre/post journal) and restart it from
    the fold journal: the recovered global view must stay bit-identical to
    the oracle across all 5 map kinds — no lost delta, no double fold."""
    rng = np.random.default_rng(100 + seed)
    root = str(tmp_path / "shm")
    tape = gen_tape(rng, 3, n_events=80)
    # ~11 agg points per cycle x 5 rounds: [1, 30] always fires
    crash_at = int(rng.integers(1, 30))
    plan = F.FaultPlan(seed=seed, crash_at=crash_at)
    _, _, restarts = _chaos_fleet(root, tape, 3, plan, rounds=5)
    assert restarts == 1 and plan.counters["daemon_crash"] == 1
    assert_global_matches_oracle(root, oracle_states(tape))


def test_crash_between_publish_and_journal_no_double_fold(tmp_path):
    """The classic double-fold hazard: the global view was published but
    the journal write didn't happen (crash at agg:pre_journal). The
    restarted daemon re-folds the same delta from the PREVIOUS journal's
    baseline — cumulative snapshots make the re-fold idempotent, so the
    published value never double-counts."""
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 1)
    agg = D.Aggregator(root)
    states[0]["arr"]["values"][2] = 10
    regions[0].publish_device(states[0])
    agg.poll_once()                       # journaled baseline: arr[2]=10

    states[0]["arr"]["values"][2] = 17    # +7 delta
    regions[0].publish_device(states[0])
    # one-worker publishing cycle fires, in order: cycle_begin, pre_merge,
    # post_merge, pre_publish, post_publish, pre_journal, cycle_end —
    # the 6th agg point is exactly the publish/journal gap
    plan = F.FaultPlan(seed=0, crash_at=6)
    with F.plan(plan):
        with pytest.raises(F.InjectedCrash):
            agg.poll_once()
    assert plan.points.get("agg:post_publish", 0) == 1
    assert plan.points.get("agg:pre_journal", 0) == 1
    # published view already holds 17; the journal still has baseline 10
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][2]) == 17
    agg2 = D.Aggregator(root)             # journal restart
    agg2.poll_once()
    agg2.poll_once()
    assert int(g.snapshot("arr")["values"][2]) == 17   # NOT 24 (10+7+7)


def test_journal_restart_without_new_publish_keeps_view(tmp_path):
    """Restart with NO worker activity: the re-published global view must
    reproduce the journaled accumulators exactly (summary/hist/hash/rb)."""
    root = str(tmp_path / "shm")
    tape = gen_tape(np.random.default_rng(55), 2, n_events=70)
    regions, states = _make_fleet(root, 2)
    per_worker = {w: [t for t in tape if t[1] == w] for w in range(2)}
    agg = D.Aggregator(root)
    for w in range(2):
        for step, _, _, ev in per_worker[w]:
            apply_event(states[w], ev, step)
        regions[w].publish_device(states[w])
    agg.poll_once()
    agg2 = D.Aggregator(root)             # fresh process, journal only
    agg2.poll_once()
    assert_global_matches_oracle(root, oracle_states(tape))


def test_journal_disabled_still_correct_fresh(tmp_path):
    cfg = D.AggregatorConfig(journal=False)
    root = str(tmp_path / "shm")
    tape = gen_tape(np.random.default_rng(66), 2, n_events=50)
    plan = F.FaultPlan(seed=0)            # no faults: plain pass-through
    _chaos_fleet(root, tape, 2, plan, config=cfg)
    assert not os.path.exists(os.path.join(root, "global", "journal.json"))
    assert_global_matches_oracle(root, oracle_states(tape))


# --------------------------------------------------------------------------
# health state machine + fleet health CLI
# --------------------------------------------------------------------------

def _transitions(agg, wid):
    return [(fr, to, why) for _, fr, to, why in
            agg.health[wid]["transitions"]]


def test_health_killed_stalled_recovered(tmp_path):
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 3)
    cfg = D.AggregatorConfig(snapshot_retries=3, degraded_after=2,
                             quarantine_after=2)
    agg = D.Aggregator(root, config=cfg)
    for w in range(3):
        states[w]["arr"]["values"][w] = w + 1
        regions[w].publish_device(states[w])
    status = agg.poll_once()
    assert all(status["health"][f"w{w}"]["state"] == D.HEALTHY
               for w in range(3))

    # w0: killed — pid gone at the next poll
    _mark_worker_dead(root, "w0")
    # w1: stalled mid-publish — seqlock stuck odd
    regions[1].seq[0] += 1
    status = agg.poll_once()
    assert status["health"]["w0"]["state"] == D.DEAD
    assert ("HEALTHY", "DEAD", "pid_gone") in _transitions(agg, "w0")
    assert status["health"]["w1"]["state"] == D.STALE
    assert ("HEALTHY", "STALE", "seqlock_timeout") in _transitions(agg, "w1")

    # stalled long enough: quarantined (probed with a reduced budget)
    status = agg.poll_once()
    assert status["health"]["w1"]["quarantined"]
    assert any(why == "quarantined" for _, _, why in _transitions(agg, "w1"))

    # w1 recovers: publish completes (parity self-heal), seq advances
    states[1]["arr"]["values"][1] = 20
    regions[1].publish_device(states[1])
    status = agg.poll_once()
    assert status["health"]["w1"]["state"] == D.HEALTHY
    assert not status["health"]["w1"]["quarantined"]
    whys = [why for _, _, why in _transitions(agg, "w1")]
    assert "readmitted" in whys and "recovered" in whys
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][1]) == 20


def test_health_degraded_on_no_seq_advance(tmp_path):
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 1)
    cfg = D.AggregatorConfig(degraded_after=3)
    agg = D.Aggregator(root, config=cfg)
    regions[0].publish_device(states[0])
    agg.poll_once()
    for _ in range(3):                    # idle worker: no new publishes
        status = agg.poll_once()
    assert status["health"]["w0"]["state"] == D.DEGRADED
    assert ("HEALTHY", "DEGRADED", "no_seq_advance") in \
        _transitions(agg, "w0")
    regions[0].publish_device(states[0])  # any publish advances seq
    status = agg.poll_once()
    assert status["health"]["w0"]["state"] == D.HEALTHY


def test_health_new_incarnation_readmits_dead_worker(tmp_path):
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 1)
    regions[0].publish_device(states[0])
    agg = D.Aggregator(root)
    agg.poll_once()
    _mark_worker_dead(root, "w0")
    agg.poll_once()
    assert agg.health["w0"]["state"] == D.DEAD
    # same wid, new boot id: restart of the trainer process
    region2 = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][5] = 3
    region2.publish_device(st)
    status = agg.poll_once()
    assert status["health"]["w0"]["state"] == D.HEALTHY
    assert ("DEAD", "HEALTHY", "new_incarnation") in _transitions(agg, "w0")


def test_fleet_health_cli(tmp_path, capsys):
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 2)
    agg = D.Aggregator(root, config=D.AggregatorConfig(snapshot_retries=3))
    for w in range(2):
        regions[w].publish_device(states[w])
    agg.poll_once()
    _mark_worker_dead(root, "w1")
    agg.poll_once()

    rc = D.main([root, "fleet", "health"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "w0" in out and "HEALTHY" in out
    assert "w1" in out and "DEAD" in out
    assert "pid_gone" in out              # transition reason surfaced

    rc = D.main([root, "fleet", "health", "--json"])
    out = capsys.readouterr().out
    assert rc == 0
    doc = json.loads(out)
    assert doc["health"]["w0"]["state"] == D.HEALTHY
    assert doc["health"]["w1"]["state"] == D.DEAD
    assert doc["health"]["w1"]["transitions"][-1][3] == "pid_gone"


def test_fleet_health_cli_no_daemon(tmp_path, capsys):
    """fleet health before any aggregator ran: explicit error, rc != 0."""
    root = str(tmp_path / "shm")
    os.makedirs(root, exist_ok=True)
    rc = D.main([root, "fleet", "health"])
    assert rc != 0
    assert "no aggregated fleet" in capsys.readouterr().err.lower()


# --------------------------------------------------------------------------
# pid reuse
# --------------------------------------------------------------------------

def test_pid_reuse_not_mistaken_for_live_worker(tmp_path):
    """The OS recycled the dead worker's pid to an unrelated LIVE process
    (here: this very test process). Identity = (pid, start tick), so the
    kill-0 liveness probe alone would be fooled; the start-tick check must
    harvest the worker as dead and keep its merged contribution."""
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 1)
    states[0]["arr"]["values"][4] = 9
    regions[0].publish_device(states[0])
    agg = D.Aggregator(root)
    status = agg.poll_once()
    assert status["alive"] == ["w0"]

    # the imposter must be a DIFFERENT live process: the in-process harness
    # registered this test process as the worker, so its own pid would
    # carry the matching start tick
    imposter = subprocess.Popen(["sleep", "60"])
    plan = F.FaultPlan(seed=0)
    try:
        F.simulate_pid_reuse(root, "w0", imposter.pid, plan)
        assert plan.counters["pid_reuse"] == 1
        status = agg.poll_once()
    finally:
        imposter.kill()
        imposter.wait()
    assert status["dead"] == ["w0"] and status["alive"] == []
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][4]) == 9    # contribution stays


@pytest.mark.slow
def test_pid_reuse_with_respawned_process(tmp_path):
    """Same hazard with a REAL recycled pid: a live subprocess whose pid
    replaces the registered worker's. Its /proc start tick differs from the
    recorded one, so worker_alive must say dead."""
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 1)
    regions[0].publish_device(states[0])
    agg = D.Aggregator(root)
    agg.poll_once()
    imposter = subprocess.Popen(["sleep", "60"])
    try:
        F.simulate_pid_reuse(root, "w0", imposter.pid)
        assert not SH.worker_alive(root, "w0")
        status = agg.poll_once()
        assert status["dead"] == ["w0"]
    finally:
        imposter.kill()
        imposter.wait()


def test_worker_alive_falls_back_without_pid_start(tmp_path):
    """Regions written by older code have no pid_start: liveness degrades
    to the kill-0 probe instead of rejecting every worker."""
    root = str(tmp_path / "shm")
    _make_fleet(root, 1)
    p = os.path.join(root, "workers", "w0", "worker.json")
    with open(p) as f:
        info = json.load(f)
    assert "pid_start" in info
    del info["pid_start"]
    with open(p, "w") as f:
        json.dump(info, f)
    assert SH.worker_alive(root, "w0")    # this process is alive


# --------------------------------------------------------------------------
# config: retry budget, backoff, coalescing back-pressure, rb_lost
# --------------------------------------------------------------------------

def test_seqlock_budget_and_backoff_configurable(tmp_path):
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 1)
    regions[0].publish_device(states[0])
    regions[0].seq[0] += 1                # stuck odd forever
    cfg = D.AggregatorConfig(snapshot_retries=4, backoff_base=1e-5,
                             backoff_max=1e-4)
    agg = D.Aggregator(root, config=cfg)
    t0 = time.monotonic()
    status = agg.poll_once()
    dt = time.monotonic() - t0
    assert status["stale"] == ["w0"]
    # 4 retries x <=1e-4s backoff (+ map count) stays far under a second;
    # the old hardcoded budget at 1ms/retry would not
    assert dt < 0.5


def test_snapshot_backoff_is_bounded_exponential(tmp_path):
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    region.publish_device(M.init_states(SPECS, np))
    region.seq[0] += 1
    with pytest.raises(TimeoutError):
        region.snapshot_device_meta("arr", retries=3, backoff_base=1e-5,
                                    backoff_max=1e-4)


def test_ringbuf_overrun_counted_as_lost(tmp_path):
    """Back-pressure accounting: a worker emits more records between polls
    than the ring holds; the overwritten-before-fold records are counted in
    rb_lost rather than silently vanishing."""
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 1)
    regions[0].publish_device(states[0])
    agg = D.Aggregator(root)
    agg.poll_once()                       # baseline: head 0
    cap = next(s for s in SPECS if s.name == "rb").max_entries
    n = cap + 9                           # 9 records fall off the ring
    for i in range(n):
        M.n_ringbuf_emit(states[0]["rb"], [0, 0, i])
    regions[0].publish_device(states[0])
    status = agg.poll_once()
    assert status["rb_lost"]["rb"]["w0"] == 9
    assert agg.rb_lost["rb"]["w0"] == 9


def test_coalescing_skips_then_flushes(tmp_path):
    """With coalesce_threshold=0 every busy cycle defers publishing until
    publish_max_lag is reached (or the fleet goes idle) — and the deferred
    deltas are NEVER dropped: the final view matches the oracle."""
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 1)
    cfg = D.AggregatorConfig(coalesce_threshold=0, publish_max_lag=3)
    agg = D.Aggregator(root, config=cfg)
    regions[0].publish_device(states[0])
    agg.poll_once()                       # first publish always goes out
    g = SH.GlobalView.attach(root)
    for i in range(2):                    # two busy cycles: both coalesced
        states[0]["arr"]["values"][0] += 5
        regions[0].publish_device(states[0])
        status = agg.poll_once()
    assert agg.coalesced_cycles == 2
    assert status["coalesced_cycles"] == 2
    assert int(g.snapshot("arr")["values"][0]) == 0    # deferred
    status = agg.poll_once()              # idle cycle: pending lag flushes
    assert int(g.snapshot("arr")["values"][0]) == 10   # nothing lost


def test_coalescing_respects_max_lag(tmp_path):
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 1)
    cfg = D.AggregatorConfig(coalesce_threshold=0, publish_max_lag=2)
    agg = D.Aggregator(root, config=cfg)
    regions[0].publish_device(states[0])
    agg.poll_once()
    g = SH.GlobalView.attach(root)
    vals = []
    for i in range(4):                    # busy every cycle
        states[0]["arr"]["values"][0] += 1
        regions[0].publish_device(states[0])
        agg.poll_once()
        vals.append(int(g.snapshot("arr")["values"][0]))
    # lag cap 2: at least every second busy cycle publishes
    assert vals[-1] >= 3 and agg.coalesced_cycles >= 1


def test_aggregator_config_defaults_match_legacy(tmp_path):
    """snapshot_retries passed positionally (legacy API) still wins over
    the config default."""
    root = str(tmp_path / "shm")
    _make_fleet(root, 1)
    agg = D.Aggregator(root, snapshot_retries=7)
    assert agg.snapshot_retries == 7 and agg.config.snapshot_retries == 7
    agg = D.Aggregator(root, config=D.AggregatorConfig(snapshot_retries=9))
    assert agg.snapshot_retries == 9
    # backoff defaults documented in shm.py flow through unchanged
    cfg = D.AggregatorConfig()
    assert cfg.backoff_base == SH.BACKOFF_BASE
    assert cfg.backoff_max == SH.BACKOFF_MAX


# --------------------------------------------------------------------------
# heartbeats + stragglers (repro.ft wired into the daemon)
# --------------------------------------------------------------------------

def test_heartbeat_dead_after_idle_cycles(tmp_path):
    root = str(tmp_path / "shm")
    regions, states = _make_fleet(root, 2)
    cfg = D.AggregatorConfig(heartbeat_timeout_cycles=2.0)
    agg = D.Aggregator(root, config=cfg)
    for w in range(2):
        regions[w].publish_device(states[w])
    agg.poll_once()
    # w1 keeps publishing; w0 goes silent
    for _ in range(4):
        states[1]["arr"]["values"][0] += 1
        regions[1].publish_device(states[1])
        status = agg.poll_once()
    assert "w0" in status["hb_dead"] and "w1" not in status["hb_dead"]


def test_straggler_detection_from_step_times(tmp_path):
    """Workers publish per-step wall times into a shared ARRAY map; the
    daemon feeds them to repro.ft.detect_stragglers and degrades the slow
    worker."""
    specs = SPECS + [M.MapSpec("step_ms", M.MapKind.ARRAY, max_entries=8)]
    root = str(tmp_path / "shm")
    regions = {w: SH.ShmRegion.create(root, specs, worker_id=f"w{w}")
               for w in range(3)}
    states = {w: M.init_states(specs, np) for w in range(3)}
    cfg = D.AggregatorConfig(step_time_map="step_ms", straggler_factor=1.5,
                             straggler_min_samples=4)
    agg = D.Aggregator(root, config=cfg)
    for w in range(3):
        # 6 recent step times in the live HOST map (what the sys_step_end
        # probe writes); w2 is 3x slower than its peers
        base = 300 if w == 2 else 100
        regions[w].host["step_ms"]["values"][:6] = base
        regions[w].publish_device(states[w])
    status = agg.poll_once()
    assert status["stragglers"] == ["w2"]
    assert agg.health["w2"]["state"] == D.DEGRADED
    assert any(why == "straggler" for _, _, why in _transitions(agg, "w2"))
    assert agg.health["w0"]["state"] == D.HEALTHY


# --------------------------------------------------------------------------
# multi-process SIGKILL scenarios (chaos + slow)
# --------------------------------------------------------------------------

def _killed_worker_main(root, specs, counter_file):
    """Worker that SIGKILLs itself mid-publish (3rd publish_begin) via a
    FaultPlan; counters are flushed to counter_file before the kill."""
    plan = F.FaultPlan(seed=0, kill_at=3, counter_file=counter_file)
    F.install(plan)
    region = SH.ShmRegion.create(root, specs, worker_id="victim")
    st = M.init_states(specs, np)
    i = 0
    while True:
        i += 1
        st["arr"]["values"][0] = i
        region.publish_device(st)         # 3rd call never returns


@pytest.mark.slow
def test_sigkill_mid_publish_detected_and_healed(tmp_path):
    """A worker process SIGKILLed inside publish_device leaves the seqlock
    odd (kill fires at publish_begin). The daemon must mark it stale (never
    crash or surface half-written data), then harvest it as dead, keeping
    its last consistent contribution."""
    root = str(tmp_path / "shm")
    counter_file = str(tmp_path / "counters.json")
    ctx = mp.get_context("spawn")
    p = ctx.Process(target=_killed_worker_main,
                    args=(root, SPECS, counter_file))
    p.start()
    p.join(timeout=120)
    assert p.exitcode == -signal.SIGKILL
    with open(counter_file) as f:
        counters = json.load(f)["counters"]
    assert counters["kill_worker"] == 1

    region = SH.ShmRegion.attach(root, mode="r", worker_id="victim")
    assert int(region.seq[0]) % 2 == 1    # died mid-publish: seqlock odd

    agg = D.Aggregator(root, config=D.AggregatorConfig(snapshot_retries=3))
    status = agg.poll_once()
    # dead harvest snapshots with the stuck-odd seqlock: the worker lands
    # in dead (pid gone) and the half-publish contributes nothing
    assert status["dead"] == ["victim"]
    assert agg.health["victim"]["state"] == D.DEAD
