"""Fast smoke test for the benchmark harness's --json mode: exercises the
probe-pipeline benchmark end-to-end on a small tape and checks the
machine-readable output schema that later PRs track."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_bench_probe_json_smoke(tmp_path):
    from benchmarks import run as bench_run
    out = tmp_path / "BENCH_probe.json"
    bench_run.main(["--json", str(out), "--fast"])
    d = json.loads(out.read_text())
    assert d["n_programs"] == 3
    assert d["n_events"] == 512
    assert set(d["modes"]) == {"scan", "vectorized", "fused"}
    for mode, r in d["modes"].items():
        assert r["ns_per_event"] > 0, mode
    assert d["speedup_fused_vs_scan"] > 0
