"""Fast smoke test for the benchmark harness's --json mode: exercises the
probe-pipeline benchmark end-to-end on a small tape and checks the
machine-readable output schema that later PRs track."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_bench_probe_json_smoke(tmp_path):
    from benchmarks import run as bench_run
    out = tmp_path / "BENCH_probe.json"
    bench_run.main(["--json", str(out), "--fast"])
    d = json.loads(out.read_text())
    assert d["n_programs"] == 3
    assert d["n_events"] == 512
    assert set(d["modes"]) == {"scan", "vectorized", "fused", "interp"}
    for mode, r in d["modes"].items():
        assert r["ns_per_event"] > 0, mode
    assert d["speedup_fused_vs_scan"] > 0
    assert d["interp_overhead_vs_scan"] > 0
    assert d["attach_latency_ms"] > 0


def test_regression_gate_on_current_baseline():
    """The committed baseline must pass its own gate, and decayed results
    must fail it — so CI can trust a red gate."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import check_regression as cr
    base = json.load(open(os.path.join(os.path.dirname(__file__), "..",
                                       "benchmarks", "BENCH_baseline.json")))
    assert cr.check(base, base, tolerance=2.0) == []
    bad = json.loads(json.dumps(base))
    bad["speedup_fused_vs_scan"] = 1.0
    bad["modes"]["interp"]["ns_per_event"] *= 10
    bad["attach_latency_ms"] *= 10
    assert len(cr.check(bad, base, tolerance=2.0)) == 3
