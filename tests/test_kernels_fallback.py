"""The Pallas kernels package (repro.kernels) is an OPTIONAL accelerator
layer: events.py and vectorized.py import it lazily inside functions and
carry self-contained jnp fallback twins. This tier-1 suite pins that
contract — the core probe pipeline must keep working, bit-identically,
when the package is unimportable (hosts without the accelerator toolchain).

The block is simulated the stdlib way: sys.modules["repro.kernels"] = None
makes any `import repro.kernels...` raise ImportError.
"""
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import events as E, jit as J, maps as M, vectorized as V
from repro.core.runtime import BpftimeRuntime

COUNT_BY_LAYER = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:fb_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

RB_PROG = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-32], r6
    ldxdw r6, [r1+ctx:numel]
    stxdw [r10-24], r6
    lddw r1, map:fb_rb
    mov r2, r10
    add r2, -32
    mov r3, 16
    mov r4, 0
    call ringbuf_output
    mov r0, 0
    exit
"""

ARR = M.MapSpec("fb_counts", M.MapKind.ARRAY, max_entries=16)
RB = M.MapSpec("fb_rb", M.MapKind.RINGBUF, max_entries=8, rec_width=4)


def _block_kernels(monkeypatch):
    """Make every `import repro.kernels[...]` raise ImportError."""
    for mod in list(sys.modules):
        if mod == "repro.kernels" or mod.startswith("repro.kernels."):
            monkeypatch.delitem(sys.modules, mod, raising=False)
    monkeypatch.setitem(sys.modules, "repro.kernels", None)


def _run_pipeline(mode):
    """Collector -> probe_stage round trip: stats path (events) + batched
    ringbuf apply (vectorized) both cross the lazy-import boundary."""
    rt = BpftimeRuntime()
    pid = rt.load_asm("fb_count", COUNT_BY_LAYER, [ARR], "uprobe")
    rt.attach(pid, "uprobe:fb_block")
    pid2 = rt.load_asm("fb_rb", RB_PROG, [RB], "uprobe")
    rt.attach(pid2, "uprobe:fb_block")
    with rt.collector() as col:
        def body(c, x):
            h = E.probe_site("fb_block", x * c, kind=E.KIND_ENTRY)
            return c + 1.0, h.sum()

        xs = jnp.ones((4, 8), jnp.float32)
        _, _ = E.probed_scan(body, jnp.float32(1.0), xs)
        rows = col.take_all_rows()
    ms, aux = rt.probe_stage(rows, rt.init_device_maps(), J.make_aux(),
                             mode=mode)
    return {name: {f: np.asarray(a) for f, a in st.items()}
            for name, st in ms.items()}


@pytest.mark.parametrize("mode", ["fused", "vectorized", "scan"])
def test_probe_pipeline_works_without_kernels(monkeypatch, mode):
    want = _run_pipeline(mode)                  # kernels importable
    _block_kernels(monkeypatch)
    with pytest.raises(ImportError):
        import repro.kernels                    # noqa: F401 — block is live
    got = _run_pipeline(mode)                   # fallback twins
    assert got.keys() == want.keys()
    for name in want:
        for f in want[name]:
            np.testing.assert_array_equal(got[name][f], want[name][f],
                                          err_msg=f"{name}.{f} [{mode}]")


def test_default_tensor_stats_fallback_matches_kernel(monkeypatch):
    from repro.kernels import ref as KREF
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.concatenate([
        rng.normal(size=37).astype(np.float32),
        [np.nan, np.inf, -np.inf, 0.0]]).astype(np.float32))
    want = {k: np.asarray(v) for k, v in KREF.tensor_stats(x).items()}
    _block_kernels(monkeypatch)
    got = E.default_tensor_stats(x)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), want[k],
                                      err_msg=k)


def test_ringbuf_fallback_twin_matches_kernel():
    from repro.kernels import ref as KREF
    rng = np.random.default_rng(1)
    data = jnp.asarray(rng.integers(-5, 5, (8, 4)), jnp.int64)
    head = jnp.asarray([3], jnp.int64)
    rows = jnp.asarray(rng.integers(-99, 99, (16, 4)), jnp.int64)
    valid = jnp.asarray(rng.random(16) < 0.7)
    dk, hk = KREF.ringbuf_emit_batch(data, head, rows, valid)
    df, hf = V._ringbuf_emit_batch_fallback(data, head, rows, valid)
    np.testing.assert_array_equal(np.asarray(df), np.asarray(dk))
    np.testing.assert_array_equal(np.asarray(hf), np.asarray(hk))


def test_collector_stats_path_without_kernels(monkeypatch):
    """events.Collector._stats is the per-site trace-time path — it must
    produce identical event rows through the fallback."""
    def rows_once():
        rt = BpftimeRuntime()
        pid = rt.load_asm("fb_count", COUNT_BY_LAYER, [ARR], "uprobe")
        rt.attach(pid, "uprobe:fb_block")
        with rt.collector() as col:
            E.probe_site("fb_block", jnp.arange(12, dtype=jnp.float32),
                         kind=E.KIND_ENTRY)
            return np.asarray(col.take_all_rows())

    want = rows_once()
    _block_kernels(monkeypatch)
    got = rows_once()
    np.testing.assert_array_equal(got, want)
