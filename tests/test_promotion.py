"""The unified attach API (PR 7) + background promotion to the fused lane.

Covers: auto-mode routing, the Link handle, deprecation shims for the old
attach_live/detach_live twins, promotion bit-identity across the swap
boundary (jit cache stays 1 per lane), detach-mid-promotion cancellation,
recompile-on-stale-world, control-plane routing, and promotion while the
aggregator is being crash/restarted at an injected agg:cycle boundary.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import daemon as D, events as E, faults as F, jit as J, \
    loader, maps as M
from repro.core.runtime import BpftimeRuntime
from repro.core.shm import ShmRegion

COUNT_BY_LAYER = """
    ldxdw r6, [r1+ctx:layer]
    stxdw [r10-8], r6
    lddw r1, map:pm_counts
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""

HIST_RMS = """
    ldxdw r2, [r1+ctx:rms]
    lddw r1, map:pm_hist
    call hist_add
    mov r0, 0
    exit
"""

ARR = M.MapSpec("pm_counts", M.MapKind.ARRAY, max_entries=64)
HIST = M.MapSpec("pm_hist", M.MapKind.LOG2HIST)
SPECS = [ARR, HIST]


def make_tape(n=48, seed=7):
    rng = np.random.default_rng(seed)
    rows = np.zeros((n, E.EVENT_WIDTH), np.int64)
    rows[:, 0] = E.SITES.get_or_create("pm_block")
    rows[:, 1] = np.where(np.arange(n) % 3 == 2, E.KIND_EXIT, E.KIND_ENTRY)
    rows[:, 2] = rng.integers(0, 32, n)
    rows[:, 6] = rng.integers(1, 1 << 30, n)
    return jnp.asarray(rows)


def live_rt(**kw):
    rt = BpftimeRuntime()
    for sp in SPECS:
        rt.create_map(sp)
    rt.enable_live_attach(max_programs=4, max_insns=64,
                          arm=("uprobe:pm_block", "uretprobe:pm_block"),
                          **kw)
    return rt


def stage_builder(rt):
    return lambda: jax.jit(lambda r, m: rt.probe_stage(r, m, J.make_aux()))


def sig_of(*args):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)),
        args)


def scan_reference(progs, tapes):
    """Run PROGS statically in scan mode over the concatenated tapes —
    the oracle every lane combination must match bit-for-bit."""
    rt = BpftimeRuntime()
    for sp in SPECS:
        rt.create_map(sp)
    for name, text, mp, tgt in progs:
        pid = rt.load_asm(name, text, mp, "uprobe")
        rt.attach(pid, tgt, mode="fused")
    maps = rt.init_device_maps()
    stage = jax.jit(
        lambda r, m: rt.probe_stage(r, m, J.make_aux(), mode="scan"))
    for rows in tapes:
        maps, _ = stage(rows, maps)
    return maps


def assert_maps_equal(got, want, names=("pm_counts", "pm_hist")):
    for name in names:
        for k in want[name]:
            np.testing.assert_array_equal(np.asarray(got[name][k]),
                                          np.asarray(want[name][k]),
                                          err_msg=f"{name}.{k}")


# --------------------------------------------------------------- unified API

def test_attach_auto_mode_routing():
    """auto = table iff the live lane can host the program RIGHT NOW
    (enabled + site collected + free slot + encodable), else fused."""
    rt = live_rt()
    pid = rt.load_asm("pm_count", COUNT_BY_LAYER, [ARR], "uprobe")
    lk = rt.attach(pid, "uprobe:pm_block")
    assert lk.lane == "table" and lk.slot == 0
    assert lk.promotion_state == "interp" and lk.promote

    # un-collected site: the trace-fixed collector would never feed the
    # table, so auto takes the epoch-bump path
    lk2 = rt.attach(pid, "uprobe:pm_elsewhere")
    assert lk2.lane == "fused" and lk2.promotion_state == "none"
    rt.detach(lk2)

    # table full -> fused fallback
    fillers = [rt.attach(pid, "uprobe:pm_block", mode="table")
               for _ in range(3)]
    assert rt.live.free_slot() is None
    lk3 = rt.attach(pid, "uprobe:pm_block")
    assert lk3.lane == "fused"
    for f in fillers:
        f.detach()

    # no live lane at all -> fused
    rt2 = BpftimeRuntime()
    rt2.create_map(ARR)
    pid2 = rt2.load_asm("pm_count", COUNT_BY_LAYER, [ARR], "uprobe")
    assert rt2.attach(pid2, "uprobe:pm_block").lane == "fused"

    # host targets take the host lane whatever the live lane says
    lkh = rt.attach(pid, "tracepoint:sys_step_end:enter")
    assert lkh.lane == "host" and lkh.promotion_state == "none"
    with pytest.raises(ValueError, match="device target"):
        rt.attach(pid, "filter:sys_step_end", mode="table")
    with pytest.raises(ValueError, match="bad attach mode"):
        rt.attach(pid, "uprobe:pm_block", mode="eager")


def test_link_handle_roundtrips():
    rt = live_rt()
    pid = rt.load_asm("pm_count", COUNT_BY_LAYER, [ARR], "uprobe")
    lk = rt.attach(pid, "uprobe:pm_block", mode="table")
    assert int(lk) == lk.link_id and rt.links[int(lk)] is lk
    lk.detach()                              # handle-side detach
    assert int(lk) not in rt.links and rt.live.free_slot() == 0
    lk2 = rt.attach(pid, "uprobe:pm_block", mode="fused")
    rt.detach(int(lk2))                      # detach by bare integer id
    assert not rt.device_attach


def test_deprecation_shims_still_work():
    rt = live_rt()
    pid = rt.load_asm("pm_count", COUNT_BY_LAYER, [ARR], "uprobe")
    with pytest.warns(DeprecationWarning, match="attach_live"):
        lk = rt.attach_live(pid, "uprobe:pm_block")
    assert lk.lane == "table" and not lk.promote   # pinned, like the old API
    assert rt.live.host["active"][lk.slot] == 1
    with pytest.warns(DeprecationWarning, match="detach_live"):
        rt.detach_live(int(lk))
    assert int(lk) not in rt.links
    assert rt.live.host["active"][0] == 0


# --------------------------------------------------------------- promotion

def test_promotion_bit_identity_across_swap():
    """The tentpole invariant: interp phase -> (one generation boundary)
    -> fused phase produces EXACTLY the state of an all-scan oracle over
    the same tape — nothing skipped, nothing double-counted — while the
    live step's jit cache stays at 1 and the fused step was compiled once,
    in the background path."""
    rows1, rows2 = make_tape(seed=7), make_tape(seed=11)
    rt = live_rt()
    step = stage_builder(rt)()
    maps = rt.init_device_maps()

    pid = rt.load_asm("pm_count", COUNT_BY_LAYER, [ARR], "uprobe")
    lk = rt.attach(pid, "uprobe:pm_block")        # auto -> table
    maps = rt.sync_live_table(maps)
    maps, _ = step(rows1, maps)                   # interp phase
    assert step._cache_size() == 1

    # arm the engine (synchronous for determinism) — schedules the link,
    # compiles the fused step against the future attach state
    eng = rt.enable_promotion(stage_builder(rt), sig_of(rows1, maps),
                              background=False)
    assert lk.promotion_state == "ready", lk.promotion_error
    assert lk.lane == "table"                     # not yet swapped

    epoch0 = rt.attach_epoch
    maps = rt.sync_live_table(maps)               # THE generation boundary
    assert lk.lane == "fused" and lk.promotion_state == "fused"
    assert lk.slot is None and rt.live.free_slot() == 0
    assert rt.attach_epoch == epoch0 + 1
    fused = rt.take_promoted_step()
    assert fused is not None
    assert rt.take_promoted_step() is None        # consumed exactly once

    maps, _ = fused(rows2, maps)                  # fused phase
    assert step._cache_size() == 1, "foreground step retraced"
    assert eng.compiles == 1, "promotion compiled more than once"

    oracle = scan_reference(
        [("pm_count", COUNT_BY_LAYER, [ARR], "uprobe:pm_block")],
        [rows1, rows2])
    assert_maps_equal(maps, oracle)

    # the old (pre-promotion) step still runs — empty table, no static
    # attach in ITS trace — and must now be a no-op on the counters
    before = int(np.asarray(maps["pm_counts"]["values"]).sum())
    maps_idle, _ = step(rows2, maps)
    assert int(np.asarray(maps_idle["pm_counts"]["values"]).sum()) == before

    # re-promoting the same world is a pure cache hit
    rt.detach(lk)
    lk2 = rt.attach(pid, "uprobe:pm_block", mode="table")
    eng.schedule(lk2)
    rt.sync_live_table(maps_idle)
    assert lk2.lane == "fused" and eng.compiles == 1


def test_detach_mid_promotion_cancels_cleanly():
    """A link detached while its compile is in flight never swaps in: the
    thread backs off, the slot is already free, no epoch bump happens."""
    rows = make_tape()
    rt = live_rt()
    maps = rt.init_device_maps()
    pid = rt.load_asm("pm_count", COUNT_BY_LAYER, [ARR], "uprobe")

    gate = threading.Event()

    def gated_builder():
        gate.wait(10)
        return stage_builder(rt)()

    eng = rt.enable_promotion(gated_builder, sig_of(rows, maps),
                              background=True)
    lk = rt.attach(pid, "uprobe:pm_block", mode="table")
    assert lk.promotion_state == "compiling"
    epoch0 = rt.attach_epoch
    rt.detach(lk)                                 # mid-compile
    assert lk.promotion_state == "cancelled"
    gate.set()
    eng.wait()
    assert eng.pending() == 0                     # never queued for apply
    maps = rt.sync_live_table(maps)
    assert rt.take_promoted_step() is None
    assert rt.attach_epoch == epoch0
    assert not rt.device_attach
    assert rt.live.free_slot() == 0               # slot really freed


def test_promotion_reschedules_when_world_moves():
    """An artifact compiled against a stale attach state must never swap
    in: apply_ready detects the signature drift, recompiles, and the NEXT
    boundary promotes — results stay bit-identical to the oracle that saw
    both programs."""
    rows1, rows2 = make_tape(seed=3), make_tape(seed=5)
    rt = live_rt()
    step = stage_builder(rt)()
    maps = rt.init_device_maps()
    pid_c = rt.load_asm("pm_count", COUNT_BY_LAYER, [ARR], "uprobe")
    pid_h = rt.load_asm("pm_histp", HIST_RMS, [HIST], "uprobe")

    eng = rt.enable_promotion(stage_builder(rt), sig_of(rows1, maps),
                              background=False)
    lk = rt.attach(pid_c, "uprobe:pm_block", mode="table")
    assert lk.promotion_state == "ready" and eng.compiles == 1

    # the world moves before the boundary: a second program lands on the
    # fused lane, so the ready artifact's trace is missing it
    rt.attach(pid_h, "uretprobe:pm_block", mode="fused")
    maps = rt.sync_live_table(maps)
    assert lk.lane == "table", "stale artifact must not swap in"
    assert lk.promotion_state == "ready" and eng.compiles == 2

    maps, _ = step(rows1, maps)                   # interp + fused coexist
    maps = rt.sync_live_table(maps)               # next boundary: matches
    assert lk.lane == "fused" and lk.promotion_state == "fused"
    fused = rt.take_promoted_step()
    maps, _ = fused(rows2, maps)

    oracle = scan_reference(
        [("pm_count", COUNT_BY_LAYER, [ARR], "uprobe:pm_block"),
         ("pm_histp", HIST_RMS, [HIST], "uretprobe:pm_block")],
        [rows1, rows2])
    assert_maps_equal(maps, oracle)


# --------------------------------------------------------- control plane

def test_poll_control_routes_modes_and_status(tmp_path):
    rt = live_rt()
    rt.setup_shm(str(tmp_path / "shm"))
    obj = loader.build_object(
        "pm_count", COUNT_BY_LAYER, [ARR], "uprobe",
        attach_to="uprobe:pm_block")
    other = ShmRegion.attach(str(tmp_path / "shm"))

    D.request_load_attach(other, obj.to_json(), mode="table", promote=False)
    D.request_load_attach(other, obj.to_json(), live=True)       # legacy
    D.request_load_attach(other, obj.to_json(), mode="fused")
    applied = rt.poll_control()
    assert [a["lane"] for a in applied] == ["table", "table", "fused"]
    assert applied[0]["promotion"] == "interp"

    status = rt.shm.read_status()
    lanes = {lid: p["lane"] for lid, p in status["promotions"].items()}
    assert sorted(lanes.values()) == ["fused", "table", "table"]
    states = {lid: p["state"] for lid, p in status["promotions"].items()}
    assert states[str(applied[0]["link_id"])] == "interp"
    assert states[str(applied[2]["link_id"])] == "none"

    D.request_detach(other, applied[1]["link_id"])
    rt.poll_control()
    assert applied[1]["link_id"] not in rt.links


def test_promotion_under_agg_cycle_fault_never_tears(tmp_path):
    """Chaos x promotion: the daemon crashes at an injected agg:cycle
    boundary while the worker promotes its link between publishes; after a
    journal restart the global view still converges to the exact oracle —
    the swap can't tear or double-fold the fleet's state."""
    root = str(tmp_path / "shm")
    rows1, rows2 = make_tape(seed=21), make_tape(seed=22)
    rt = live_rt()
    rt.setup_shm(root, worker_id="w0")
    maps = rt.init_device_maps()
    pid = rt.load_asm("pm_count", COUNT_BY_LAYER, [ARR], "uprobe")
    eng = rt.enable_promotion(stage_builder(rt), sig_of(rows1, maps),
                              background=False)
    lk = rt.attach(pid, "uprobe:pm_block", mode="table")
    assert lk.promotion_state == "ready"

    maps = rt.sync_live_table(maps)               # boundary 1: swap
    assert lk.lane == "fused" and eng.compiles == 1
    fused = rt.take_promoted_step()

    maps, _ = fused(rows1, maps)                  # fused: counts rows1
    rt.publish(maps)

    agg = D.Aggregator(root)
    with F.plan(F.FaultPlan(seed=0, crash_at=1)):
        with pytest.raises(F.InjectedCrash):
            agg.poll_once()
    agg = D.Aggregator(root)                      # journal restart
    agg.poll_once()

    maps, _ = fused(rows2, maps)                  # keep training
    rt.publish(maps)
    agg.poll_once()
    agg.poll_once()

    oracle = scan_reference(
        [("pm_count", COUNT_BY_LAYER, [ARR], "uprobe:pm_block")],
        [rows1, rows2])
    from repro.core import shm as SH
    g = SH.GlobalView.attach(root)
    np.testing.assert_array_equal(
        g.snapshot("pm_counts")["values"],
        np.asarray(oracle["pm_counts"]["values"]))
