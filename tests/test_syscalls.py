"""Direct tests for repro.core.syscalls — previously only exercised through
the runtime integration suite.

Covers the three contract surfaces:
  * `override_return` filter semantics: overrides apply on sys_enter ONLY,
    first-override-wins across hooks, the real impl is skipped, and exit
    probes observe the overridden return code;
  * tracepoint enter/exit pairing: enter hooks see ret=0, exit hooks see
    the impl's real return code (via ret_code_of);
  * shm-backed host maps: syscall-hook map updates land in the mmapped
    host section live (no publish step), visible to an attached daemon and
    to the bpftool-style CLI.
"""
import numpy as np
import pytest

from repro.core import daemon, loader, maps as M, syscalls as S
from repro.core.runtime import BpftimeRuntime
from repro.core.shm import ShmRegion

ARR = M.MapSpec("ret_log", M.MapKind.ARRAY, max_entries=32)

# override calls > 5 on arg0 with code 99
FILTER_BIG = """
    ldxdw r6, [r1+ctx:arg0]
    jle r6, 5, out
    mov r1, 99
    call override_return
    out:
    mov r0, 0
    exit
"""

FILTER_ALWAYS_77 = """
    mov r1, 77
    call override_return
    mov r0, 0
    exit
"""

# ret_log[sys_id] += ctx.ret  (enter sees ret=0, exit sees the real rc)
SUM_RET_BY_SYSCALL = """
    ldxdw r6, [r1+ctx:sys_id]
    stxdw [r10-8], r6
    ldxdw r3, [r1+ctx:ret]
    lddw r1, map:ret_log
    mov r2, r10
    add r2, -8
    call map_fetch_add
    mov r0, 0
    exit
"""

# ret_log[arg1] += 1  (counts hook executions per tag)
COUNT_BY_ARG1 = """
    ldxdw r6, [r1+ctx:arg1]
    stxdw [r10-8], r6
    lddw r1, map:ret_log
    mov r2, r10
    add r2, -8
    mov r3, 1
    call map_fetch_add
    mov r0, 0
    exit
"""


def make_table(specs):
    """A standalone SyscallTable on plain numpy host maps — no runtime."""
    host = {s.name: M.init_state(s, np) for s in specs}
    fd_of = {s.name: i for i, s in enumerate(specs)}
    return S.SyscallTable(host, list(specs), pid=4242), host, fd_of


def load_insns(name, text, specs, fd_of, prog_type="tracepoint"):
    obj = loader.build_object(name, text, list(specs), prog_type)
    return loader.relocate(obj, fd_of)


# ---------------------------------------------------------------- override

def test_override_filters_on_sys_enter():
    tbl, _, fd_of = make_table([])
    tbl.attach("sys_data_fetch", "enter", "flt",
               load_insns("flt", FILTER_BIG, [], fd_of, "filter"), [])
    calls = []

    r = tbl.invoke("sys_data_fetch", [3], impl=lambda: calls.append(1) or "b")
    assert not r.overridden and r.value == "b" and r.ret_code == 0
    r = tbl.invoke("sys_data_fetch", [9], impl=lambda: calls.append(1) or "b")
    assert r.overridden and r.override_val == 99 and r.ret_code == 99
    assert r.value is None          # real impl skipped
    assert calls == [1]             # only the non-overridden call ran impl


def test_override_on_exit_phase_is_ignored():
    """override_return is a sys_enter feature: an exit hook setting it must
    not rewrite the already-returned code nor mark the call overridden."""
    tbl, _, fd_of = make_table([])
    tbl.attach("sys_log", "exit", "flt",
               load_insns("flt", FILTER_ALWAYS_77, [], fd_of, "filter"), [])
    r = tbl.invoke("sys_log", [1], impl=lambda: "x", ret_code_of=lambda v: 5)
    assert not r.overridden and r.value == "x" and r.ret_code == 5


def test_first_override_wins_but_all_enter_hooks_run():
    specs = [ARR]
    tbl, host, fd_of = make_table(specs)
    tbl.attach("sys_log", "enter", "flt99",
               load_insns("flt99", FILTER_BIG, [], fd_of, "filter"), [])
    tbl.attach("sys_log", "enter", "flt77",
               load_insns("flt77", FILTER_ALWAYS_77, [], fd_of, "filter"), [])
    tbl.attach("sys_log", "enter", "cnt",
               load_insns("cnt", COUNT_BY_ARG1, specs, fd_of), specs)
    r = tbl.invoke("sys_log", [9, 2], impl=lambda: "x")
    assert r.overridden and r.override_val == 99       # attach order wins
    # the observer hook after both filters still executed
    assert int(host["ret_log"]["values"][2]) == 1
    # earlier filter passes -> the later one's override applies
    r = tbl.invoke("sys_log", [3, 2], impl=lambda: "x")
    assert r.overridden and r.override_val == 77
    assert int(host["ret_log"]["values"][2]) == 2


# ---------------------------------------------------------------- pairing

def test_enter_exit_pairing_sees_ret_code():
    specs = [ARR]
    tbl, host, fd_of = make_table(specs)
    insns = load_insns("sum_ret", SUM_RET_BY_SYSCALL, specs, fd_of)
    tbl.attach("sys_data_fetch", "enter", "sum_ret", insns, specs)
    tbl.attach("sys_data_fetch", "exit", "sum_ret", insns, specs)

    tbl.invoke("sys_data_fetch", [1], impl=lambda: "v",
               ret_code_of=lambda v: 7)
    sid = S.SYSCALL_IDS["sys_data_fetch"]
    # enter contributed ret=0, exit contributed ret=7
    assert int(host["ret_log"]["values"][sid]) == 7

    # an overridden call: enter hook ran BEFORE the filter decision is
    # applied, exit hook observes the override value as the return code
    tbl.attach("sys_data_fetch", "enter", "flt",
               load_insns("flt", FILTER_BIG, [], fd_of, "filter"), [])
    tbl.invoke("sys_data_fetch", [9], impl=lambda: "v",
               ret_code_of=lambda v: 7)
    assert int(host["ret_log"]["values"][sid]) == 7 + 99


def test_counts_and_detach():
    specs = [ARR]
    tbl, host, fd_of = make_table(specs)
    insns = load_insns("cnt", COUNT_BY_ARG1, specs, fd_of)
    tbl.attach("sys_heartbeat", "enter", "cnt", insns, specs)
    tbl.invoke("sys_heartbeat", [0, 4], impl=lambda: None)
    tbl.invoke("sys_heartbeat", [0, 4], impl=lambda: None)
    assert tbl.counts["sys_heartbeat"] == 2
    assert int(host["ret_log"]["values"][4]) == 2
    tbl.detach("sys_heartbeat", "enter", "cnt")
    tbl.invoke("sys_heartbeat", [0, 4], impl=lambda: None)
    assert tbl.counts["sys_heartbeat"] == 3      # dispatch still counts
    assert int(host["ret_log"]["values"][4]) == 2  # hook no longer fires


def test_unknown_syscall_and_phase_rejected():
    tbl, _, fd_of = make_table([])
    insns = load_insns("flt", FILTER_ALWAYS_77, [], fd_of, "filter")
    with pytest.raises(KeyError):
        tbl.attach("sys_nope", "enter", "flt", insns, [])
    with pytest.raises(ValueError):
        tbl.attach("sys_log", "during", "flt", insns, [])
    with pytest.raises(KeyError):
        tbl.invoke("sys_nope", [], impl=lambda: None)


# ---------------------------------------------------------------- shm-backed

def test_shm_backed_host_maps_visible_to_daemon(tmp_path, capsys):
    """Syscall-hook map updates hit the mmapped host section directly:
    a daemon attached to the region (read-only, fleet layout) sees them
    WITHOUT any publish step, and the CLI can dump them."""
    root = str(tmp_path / "shm")
    rt = BpftimeRuntime()
    pid = rt.load_asm("sum_ret", SUM_RET_BY_SYSCALL, [ARR], "tracepoint")
    rt.setup_shm(root, worker_id="w0")
    rt.attach(pid, "tracepoint:sys_serve_admit:exit")

    rt.syscalls.invoke("sys_serve_admit", [5], impl=lambda: True,
                       ret_code_of=lambda v: 3)
    rt.syscalls.invoke("sys_serve_admit", [6], impl=lambda: True,
                       ret_code_of=lambda v: 4)

    sid = S.SYSCALL_IDS["sys_serve_admit"]
    other = ShmRegion.attach(root, mode="r", worker_id="w0")
    assert int(other.host["ret_log"]["values"][sid]) == 7

    rc = daemon.main([root, "map", "dump", "ret_log",
                      "--section", "host", "--worker", "w0"])
    assert rc == 0
    out = capsys.readouterr().out
    assert f"{sid}: 7" in out
