"""Syscall-override failure drills (DESIGN.md §11): the paper's syscall
filtering turned into a self-test of our own fault tolerance. An eBPF
filter armed by faults.arm_syscall_fault overrides a framework syscall with
-EIO while a map-resident budget lasts; the consumers (checkpoint save /
restore, data pipeline, serve admission, the training loop) must retry
within bounds and then DEGRADE — never crash, never spin forever.

Convention under test: a NEGATIVE override return code is a transient
fault (bounded retry); a non-negative override is a policy veto (final,
no retry).
"""
import os

import jax
import numpy as np
import pytest

from repro.ckpt import checkpoint as CK
from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import faults as F
from repro.core.runtime import BpftimeRuntime
from repro.data.pipeline import SyntheticDataset

pytestmark = pytest.mark.chaos

CFG = registry.smoke("qwen2-0.5b")


def _veto_filter(rt, sys_name, code=0):
    """Filter that always overrides with a NON-NEGATIVE code: policy veto."""
    pid = rt.load_asm(f"veto_{sys_name}", f"""
        mov r1, {code}
        call override_return
        mov r0, 0
        exit
    """, [], "filter")
    return rt.attach(pid, f"filter:{sys_name}")


# --------------------------------------------------------------------------
# the convention itself
# --------------------------------------------------------------------------

def test_negative_override_is_fault_positive_is_veto():
    rt = BpftimeRuntime()
    F.arm_syscall_fault(rt, "sys_log", budget=1)
    res = rt.syscalls.invoke("sys_log", [0], impl=lambda: "x")
    assert res.overridden and res.ret_code == -F.EIO and res.fault
    res = rt.syscalls.invoke("sys_log", [0], impl=lambda: "x")
    assert not res.overridden and res.value == "x"

    rt2 = BpftimeRuntime()
    _veto_filter(rt2, "sys_log", code=429)
    res = rt2.syscalls.invoke("sys_log", [0], impl=lambda: "x")
    assert res.overridden and res.ret_code == 429 and not res.fault


def test_budget_drains_exactly_then_recovers():
    """The map-backed budget makes exactly N consecutive calls fail — and
    the drained budget is eBPF-visible (drill_remaining reads the map)."""
    rt = BpftimeRuntime()
    F.arm_syscall_fault(rt, "sys_log", budget=3)
    faults = [rt.syscalls.invoke("sys_log", [i], impl=lambda: i).fault
              for i in range(5)]
    assert faults == [True, True, True, False, False]
    assert F.drill_remaining(rt) <= 0


def test_rearming_refills_budget():
    rt = BpftimeRuntime()
    F.arm_syscall_fault(rt, "sys_log", budget=1)
    assert rt.syscalls.invoke("sys_log", [0], impl=lambda: 1).fault
    assert not rt.syscalls.invoke("sys_log", [0], impl=lambda: 1).fault
    F.arm_syscall_fault(rt, "sys_log", budget=1)   # refill, no re-attach
    assert rt.syscalls.invoke("sys_log", [0], impl=lambda: 1).fault


# --------------------------------------------------------------------------
# checkpoint save / restore
# --------------------------------------------------------------------------

def _tiny_state(step=1):
    return {"step": np.int64(step), "w": np.arange(6, dtype=np.float32)}


def test_checkpoint_save_survives_transient_eio(tmp_path):
    rt = BpftimeRuntime()
    F.arm_syscall_fault(rt, "sys_checkpoint_save", budget=2)
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    CK.save(d, 1, _tiny_state(1), runtime=rt, blocking=True)
    assert CK.latest(d) == 1                       # committed despite 2 EIOs
    assert rt.syscalls.counts["sys_checkpoint_save"] == 3   # 2 faults + 1 ok
    assert F.drill_remaining(rt) <= 0


def test_checkpoint_save_degrades_on_persistent_eio(tmp_path):
    """Budget beyond the retry bound: the save is SKIPPED (training keeps
    the previous committed checkpoint) after exactly retries+1 attempts."""
    rt = BpftimeRuntime()
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    CK.save(d, 1, _tiny_state(1), runtime=rt, blocking=True)
    F.arm_syscall_fault(rt, "sys_checkpoint_save", budget=100)
    n0 = rt.syscalls.counts["sys_checkpoint_save"]
    CK.save(d, 2, _tiny_state(2), runtime=rt, blocking=True,
            fault_retries=3)
    assert rt.syscalls.counts["sys_checkpoint_save"] - n0 == 4   # bounded
    assert CK.latest(d) == 1                       # previous commit stays


def test_checkpoint_save_veto_skips_without_retry(tmp_path):
    rt = BpftimeRuntime()
    _veto_filter(rt, "sys_checkpoint_save")
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    CK.save(d, 1, _tiny_state(1), runtime=rt, blocking=True)
    assert rt.syscalls.counts["sys_checkpoint_save"] == 1    # no retry
    assert CK.latest(d) is None


def test_checkpoint_restore_survives_transient_eio(tmp_path):
    rt = BpftimeRuntime()
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    st = _tiny_state(3)
    CK.save(d, 3, st, runtime=rt, blocking=True)
    F.arm_syscall_fault(rt, "sys_checkpoint_restore", budget=2)
    out = CK.restore(d, 3, st, runtime=rt)
    assert out is not None
    np.testing.assert_array_equal(np.asarray(out["w"]), st["w"])
    F.arm_syscall_fault(rt, "sys_checkpoint_restore", budget=100)
    assert CK.restore(d, 3, st, runtime=rt) is None          # degrade


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def _dataset(rt):
    tcfg = TrainConfig(total_steps=8)
    shape = ShapeConfig("drill", 16, 2, "train")
    return SyntheticDataset(CFG, shape, tcfg, runtime=rt)


def test_data_fetch_survives_transient_eio():
    rt = BpftimeRuntime()
    ds = _dataset(rt)
    ref = _dataset(None)
    F.arm_syscall_fault(rt, "sys_data_fetch", budget=2)
    batch = ds.next()                              # retried through 2 EIOs
    assert batch is not None
    np.testing.assert_array_equal(batch["tokens"], ref.next()["tokens"])
    assert rt.syscalls.counts["sys_data_fetch"] == 3


def test_data_fetch_degrades_to_skip_on_persistent_eio():
    rt = BpftimeRuntime()
    ds = _dataset(rt)
    F.arm_syscall_fault(rt, "sys_data_fetch", budget=100)
    assert ds.next() is None                       # bounded retry, then skip
    assert rt.syscalls.counts["sys_data_fetch"] == ds.fault_retries + 1
    assert ds.step == 1                            # cursor still advanced


def test_data_fetch_veto_no_retry():
    rt = BpftimeRuntime()
    ds = _dataset(rt)
    _veto_filter(rt, "sys_data_fetch")
    assert ds.next() is None
    assert rt.syscalls.counts["sys_data_fetch"] == 1


# --------------------------------------------------------------------------
# serve admission
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def params():
    from repro.models import registry as MR
    return MR.init_params(jax.random.PRNGKey(0), CFG)


def test_serve_admit_survives_transient_eio(params):
    from repro.serve.engine import Request, ServeEngine
    rt = BpftimeRuntime()
    eng = ServeEngine(params, CFG, slots=2, max_seq=32, runtime=rt)
    F.arm_syscall_fault(rt, "sys_serve_admit", budget=2)
    reqs = [Request(rid=i, prompt=[1 + i, 2], max_new=3) for i in range(3)]
    eng.submit_all(reqs)
    assert all(r.done for r in reqs)
    assert not any(r.rejected for r in reqs)       # EIOs retried through
    assert all(len(r.out) >= 3 for r in reqs)


def test_serve_admit_degrades_to_reject_on_persistent_eio(params):
    from repro.serve.engine import Request, ServeEngine
    rt = BpftimeRuntime()
    eng = ServeEngine(params, CFG, slots=2, max_seq=32, runtime=rt)
    F.arm_syscall_fault(rt, "sys_serve_admit", budget=1000)
    reqs = [Request(rid=i, prompt=[1, 2], max_new=3) for i in range(2)]
    eng.submit_all(reqs)                           # completes, no crash
    assert all(r.rejected and r.done for r in reqs)
    assert all(r.out == [] for r in reqs)


# --------------------------------------------------------------------------
# the training loop end to end
# --------------------------------------------------------------------------

def test_train_loop_survives_ckpt_and_data_eio(tmp_path):
    """run_training with BOTH drills armed: transient data-read faults and
    checkpoint-write faults are absorbed by bounded retries — every step
    runs, the checkpoint still commits."""
    from repro.launch.train import run_training
    rt = BpftimeRuntime()
    F.arm_syscall_fault(rt, "sys_data_fetch", budget=2)
    ckpt = str(tmp_path / "ckpt")
    os.makedirs(ckpt)
    state, hist = run_training(
        "qwen2-0.5b", steps=3, smoke=True, runtime=rt, ckpt_dir=ckpt,
        save_every=2, seq_len=16, batch=2, log_every=0)
    assert len(hist) == 3                          # no step lost to EIO
    assert CK.latest(ckpt) == 2
    assert F.drill_remaining(rt) <= 0


def test_train_loop_bounded_spin_on_total_veto():
    """A filter vetoing EVERY data fetch must not hang the loop: the
    max_data_skips guard turns the spin into an explicit error."""
    from repro.launch.train import run_training
    rt = BpftimeRuntime()
    _veto_filter(rt, "sys_data_fetch")
    with pytest.raises(RuntimeError, match="vetoing every fetch"):
        run_training("qwen2-0.5b", steps=2, smoke=True, runtime=rt,
                     seq_len=16, batch=2, log_every=0, max_data_skips=5)
