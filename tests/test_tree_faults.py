"""Chaos matrix for HIERARCHICAL aggregation (DESIGN.md §15).

Every fault class from the flat chaos plane, re-aimed at the new tree
boundaries: a node aggregator crashing mid-fold, in the emit/commit
window, or between commit and journal; a committed delta batch corrupted
on disk; a node process SIGKILLed outright. The invariant is the flat
plane's, lifted one level: after recovery the global view is bit-identical
to the no-fault oracle — forfeit-never-double at every tree level.

The seeded single-process matrix carries the `chaos` marker (tier-1 runs
a fast subset; CI's chaos job runs everything); the wide sweeps and the
real-SIGKILL scenarios are `chaos + slow`.
"""
import os
import signal
import time

import numpy as np
import pytest

import waiters
from repro.core import daemon as D, faults as F, maps as M, shm as SH
from repro.core.treeagg import NodeAggregator, TreeAggregator

from test_shm_merge_differential import (
    SPECS, apply_event, assert_global_matches_oracle, gen_tape,
    oracle_states)

pytestmark = pytest.mark.chaos


def _fast_cfg(**kw):
    kw.setdefault("snapshot_retries", 8)
    kw.setdefault("backoff_base", 1e-5)
    kw.setdefault("backoff_max", 1e-4)
    return D.AggregatorConfig(**kw)


def _make_tree(root, n_workers, fan_in, depth, config):
    return TreeAggregator(root, fan_in=fan_in, depth=depth, config=config,
                          worker_ids=[f"w{w}" for w in range(n_workers)])


def _chaos_tree(root, tape, n_workers, plan, fan_in=2, depth=1, rounds=4,
                config=None):
    """The tree twin of test_faults._chaos_fleet: a crash anywhere in the
    tree (a node's fold/emit window or the root's own cycle) tears down
    the WHOLE in-process tree and rebuilds it — every node recovers from
    its journal + its own stream (the WAL replay path), the root from its
    journal + stream cursors. Ends with a fault-free convergence round."""
    config = config or _fast_cfg()
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(n_workers)}
    states = {w: M.init_states(SPECS, np) for w in range(n_workers)}
    per_worker = {w: [t for t in tape if t[1] == w]
                  for w in range(n_workers)}
    chunks = {w: np.array_split(np.arange(len(per_worker[w])), rounds)
              for w in range(n_workers)}
    tree = _make_tree(root, n_workers, fan_in, depth, config)
    restarts = 0
    with F.plan(plan):
        for r in range(rounds):
            for w in range(n_workers):
                for i in chunks[w][r]:
                    step, _, _, ev = per_worker[w][i]
                    apply_event(states[w], ev, step)
                try:
                    regions[w].publish_device(states[w])
                except F.TornPublish:
                    pass
            try:
                tree.poll_once()
            except F.InjectedCrash:
                tree = _make_tree(root, n_workers, fan_in, depth, config)
                restarts += 1
    for w in range(n_workers):
        regions[w].publish_device(states[w])
    tree.poll_once()
    status = tree.poll_once()
    return tree, status, restarts


# --------------------------------------------------------------------------
# node crash mid-fold / emit window: seeded sweeps
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_node_crash_mid_fold_converges(tmp_path, seed):
    """InjectedCrash at a seeded agg:* point INSIDE one node aggregator
    (crash_who pins the schedule to that node): the rebuilt tree must
    converge bit-identical — the node's journal covers an emit boundary,
    so a crash mid-fold re-folds idempotent cumulative deltas."""
    root = str(tmp_path / "shm")
    rng = np.random.default_rng(200 + seed)
    tape = gen_tape(rng, 4, n_events=80)
    crash_at = int(rng.integers(1, 25))
    plan = F.FaultPlan(seed=seed, crash_at=crash_at, crash_who="n0_0")
    _, _, restarts = _chaos_tree(root, tape, 4, plan, rounds=5)
    assert restarts == 1 and plan.counters["daemon_crash"] == 1
    assert_global_matches_oracle(root, oracle_states(tape))


@pytest.mark.parametrize("occurrence", [1, 2, 3, 4])
def test_node_crash_in_emit_commit_window_converges(tmp_path, occurrence):
    """node_crash_at sweeps the node:pre_emit / node:post_commit points —
    the commit-vs-journal window where double-emission would be born. A
    crash after post_commit but before the journal is the hazard: the
    restarted node must replay its own committed batch into the emit base
    (stream-as-WAL) and never re-emit it."""
    root = str(tmp_path / "shm")
    rng = np.random.default_rng(300 + occurrence)
    tape = gen_tape(rng, 4, n_events=80)
    plan = F.FaultPlan(seed=occurrence, node_crash_at=occurrence)
    _, _, restarts = _chaos_tree(root, tape, 4, plan, rounds=5)
    assert restarts == 1 and plan.counters["node_crash"] == 1
    assert_global_matches_oracle(root, oracle_states(tape))


def test_node_crash_between_commit_and_journal_no_double_fold(tmp_path):
    """Deterministic pin of the node-level double-fold hazard (the tree
    twin of the flat crash_at=6 test): batch committed to the stream,
    journal NOT yet written. The restarted node replays the batch into its
    emit base, so the content is emitted exactly once; the parent folds it
    exactly once."""
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][2] = 10
    region.publish_device(st)
    node = NodeAggregator(root, "n0_0", workers=["w0"])
    node.poll_once()                    # batch 1 committed + journaled
    root_agg = D.Aggregator(root)
    root_agg.poll_once()
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][2]) == 10

    st["arr"]["values"][2] = 17         # +7 delta
    region.publish_device(st)
    # node:post_commit is the 2nd node:* point of the cycle — the crash
    # lands with the batch durable on the stream and the journal stale
    plan = F.FaultPlan(seed=0, node_crash_at=2)
    with F.plan(plan):
        with pytest.raises(F.InjectedCrash):
            node.poll_once()
    assert plan.points.get("node:post_commit", 0) == 1
    assert node.stream.head() == 2

    node2 = NodeAggregator(root, "n0_0", workers=["w0"])   # WAL replay
    node2.poll_once()
    node2.poll_once()
    # batch 2's CONTENT is never re-emitted: a restarted node may push one
    # membership heartbeat batch (parent health refresh), but every batch
    # past the replayed one must carry zero data updates
    for seq, payload in node2.stream.poll(2):
        assert payload is not None and payload.get("updates", 0) == 0, \
            f"batch {seq} re-emitted content after WAL replay"
    root_agg.poll_once()
    root_agg.poll_once()
    assert int(g.snapshot("arr")["values"][2]) == 17       # NOT 24


def test_parent_crash_before_journal_refolds_batch_idempotently(tmp_path):
    """The consumer-side window: the root folds a node batch, publishes,
    then crashes before journaling its stream cursor. The restarted root
    re-reads the unacked batch — ringbuf replay guards and cumulative
    summary deltas make the re-fold land on the identical view."""
    root = str(tmp_path / "shm")
    rng = np.random.default_rng(77)
    tape = gen_tape(rng, 2, n_events=60)
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(2)}
    states = {w: M.init_states(SPECS, np) for w in range(2)}
    for step, w, _, ev in tape:
        apply_event(states[w], ev, step)
    for w in range(2):
        regions[w].publish_device(states[w])
    node = NodeAggregator(root, "n0_0", workers=["w0", "w1"])
    node.poll_once()

    root_agg = D.Aggregator(root)
    # cycle with no direct workers: cycle_begin, node pre/post_merge,
    # pre_publish, post_publish, then pre_journal (6th) — crash there
    plan = F.FaultPlan(seed=0, crash_at=6, crash_who="global")
    with F.plan(plan):
        with pytest.raises(F.InjectedCrash):
            root_agg.poll_once()
    assert plan.points.get("agg:post_publish", 0) == 1
    assert node.stream.acked() == 0     # ack follows the JOURNAL, not fold

    root2 = D.Aggregator(root)          # journal restart: re-reads batch 1
    root2.poll_once()
    root2.poll_once()
    assert node.stream.acked() == 1
    assert_global_matches_oracle(root, oracle_states(tape))


# --------------------------------------------------------------------------
# stream corruption: detect-and-skip with accounting, never silent-fold
# --------------------------------------------------------------------------

def test_stream_corrupt_batch_detected_and_accounted(tmp_path):
    """Bytes flipped in a committed batch AFTER node:post_commit: the
    parent must detect (embedded CRC / container damage), skip the batch
    with stream_lost accounting, and keep folding later clean batches.
    Forfeit with a receipt — never a torn fold, never a crash."""
    root = str(tmp_path / "shm")
    region = SH.ShmRegion.create(root, SPECS, worker_id="w0")
    st = M.init_states(SPECS, np)
    st["arr"]["values"][0] = 5
    region.publish_device(st)
    node = NodeAggregator(root, "n0_0", workers=["w0"])
    plan = F.FaultPlan(seed=1, rates={"stream_corrupt": 1.0})
    with F.plan(plan):
        node.poll_once()                # batch 1 committed, then scribbled
    assert plan.counters["stream_corrupt"] == 1

    root_agg = D.Aggregator(root)
    status = root_agg.poll_once()
    assert status["stream_lost"].get("n0_0") == 1
    g = SH.GlobalView.attach(root)
    assert int(g.snapshot("arr")["values"][0]) == 0   # forfeited, not torn

    # the stream keeps working: the next clean batch folds normally
    st["arr"]["values"][0] = 12
    region.publish_device(st)
    node.poll_once()                    # batch 2: +7 delta, clean
    status = root_agg.poll_once()
    assert int(g.snapshot("arr")["values"][0]) == 7
    assert status["stream_lost"].get("n0_0") == 1     # no new loss


@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_full_tree_fault_matrix_converges(tmp_path, seed):
    """Everything at once, tree edition: worker publish faults, node
    crashes, root crashes, and corrupt worker snapshots across a depth-2
    tree. Stream corruption is excluded here — it forfeits real content
    by design (accounted, tested above), which breaks oracle identity."""
    root = str(tmp_path / "shm")
    tape = gen_tape(np.random.default_rng(400 + seed), 6, n_events=120)
    plan = F.FaultPlan(
        seed=seed, crash_at=11 + 5 * seed, node_crash_at=3 + seed,
        rates={"torn_publish": 0.2, "stuck_odd": 0.1,
               "corrupt_snapshot": 0.2, "slow_worker": 0.05},
        slow_s=0.0003)
    _, _, restarts = _chaos_tree(root, tape, 6, plan, fan_in=2, depth=2,
                                 rounds=6)
    assert restarts >= 2
    assert plan.counters["node_crash"] >= 1
    assert plan.counters["daemon_crash"] >= 1
    assert_global_matches_oracle(root, oracle_states(tape))


# --------------------------------------------------------------------------
# SIGKILL of a real node process mid-tree
# --------------------------------------------------------------------------

def _node_child(root, node_id, workers, ready_file):
    cfg = D.AggregatorConfig(snapshot_retries=8, backoff_base=1e-5,
                             backoff_max=1e-4)
    na = NodeAggregator(root, node_id, workers=workers, config=cfg)
    with open(ready_file, "w") as f:
        f.write("ok")
    while True:
        na.poll_once()
        time.sleep(0.005)


@pytest.mark.slow
def test_sigkill_node_process_harvest_restart_converges(tmp_path):
    """A REAL node process SIGKILLed mid-run: the root harvests whatever
    the dead incarnation committed, retires the node, and a restarted node
    process (same id, new boot, journal + stream intact) is re-admitted
    at the kept cursor. Final view: bit-identical to the oracle."""
    import multiprocessing as mp
    root = str(tmp_path / "shm")
    rng = np.random.default_rng(500)
    n_workers = 4
    tape = gen_tape(rng, n_workers, n_events=100)
    regions = {w: SH.ShmRegion.create(root, SPECS, worker_id=f"w{w}")
               for w in range(n_workers)}
    states = {w: M.init_states(SPECS, np) for w in range(n_workers)}
    per_worker = {w: [t for t in tape if t[1] == w]
                  for w in range(n_workers)}
    chunks = {w: np.array_split(np.arange(len(per_worker[w])), 2)
              for w in range(n_workers)}

    ctx = mp.get_context("spawn")
    ready = str(tmp_path / "ready")
    p = ctx.Process(target=_node_child,
                    args=(root, "n0_0", ["w0", "w1"], ready))
    p.start()
    try:
        waiters.wait_for_path(ready)
        # w2/w3 under a second, in-process node; root consumes both
        node_b = NodeAggregator(root, "n0_1", workers=["w2", "w3"])
        root_agg = D.Aggregator(root)

        for w in range(n_workers):           # round 1
            for i in chunks[w][0]:
                step, _, _, ev = per_worker[w][i]
                apply_event(states[w], ev, step)
            regions[w].publish_device(states[w])
        node_b.poll_once()
        # wait until the child consumed round 1 and committed a batch
        stream_a = SH.DeltaStream.attach(root, "n0_0")
        waiters.wait_for(lambda: stream_a.head() >= 1,
                         msg="child node emit")
        root_agg.poll_once()

        os.kill(p.pid, signal.SIGKILL)
        waiters.wait_for_exit(p)
        status = root_agg.poll_once()        # harvest + retire
        assert status["nodes"]["n0_0"]["alive"] is False

        for w in range(n_workers):           # round 2
            for i in chunks[w][1]:
                step, _, _, ev = per_worker[w][i]
                apply_event(states[w], ev, step)
            regions[w].publish_device(states[w])
        node_b.poll_once()

        # supervisor restarts the node: new boot, same id, kept cursor
        ready2 = str(tmp_path / "ready2")
        p = ctx.Process(target=_node_child,
                        args=(root, "n0_0", ["w0", "w1"], ready2))
        p.start()
        waiters.wait_for_path(ready2)
        waiters.wait_for(lambda: stream_a.head() >= 2,
                         msg="restarted node emit")

        def converged():
            root_agg.poll_once()
            try:
                assert_global_matches_oracle(root, oracle_states(tape))
                return True
            except AssertionError:
                return False
        waiters.wait_for(converged, timeout=30, msg="tree convergence")
    finally:
        if p.is_alive():
            p.kill()
            p.join()
