"""Fleet AOT artifact cache (DESIGN.md §13): durable round-trips,
CRC-detected corruption degrading to recompile, fingerprint keying
(the promote.py under-keying regression), and cross-process reuse."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import events as E, faults as F
from repro.core.artifact_cache import ArtifactCache
from repro.core.maps import MapKind, MapSpec
from repro.core.runtime import BpftimeRuntime


# ------------------------------------------------------------- round trips
def test_bytes_round_trip_and_counters(tmp_path):
    c = ArtifactCache(str(tmp_path))
    assert c.get_bytes("k1") is None
    assert c.counters["misses"] == 1
    c.put_bytes("k1", b"payload", "table")
    assert c.get_bytes("k1") == b"payload"
    assert c.get_bytes("k1", kind="step") is None     # kind mismatch drops
    assert c.counters == {"hits": 1, "misses": 1, "stores": 1,
                          "corrupt": 1, "purged": 0, "evicted": 0}
    assert c.get_bytes("k1") is None                  # entry was dropped


def test_table_image_round_trip(tmp_path):
    c = ArtifactCache(str(tmp_path))
    arrays = {"op": np.arange(12, dtype=np.int32),
              "imm": np.ones((3, 4), np.int64)}
    c.put_table("t", arrays)
    out = c.get_table("t")
    assert set(out) == {"op", "imm"}
    assert np.array_equal(out["op"], arrays["op"])
    assert np.array_equal(out["imm"], arrays["imm"])


def test_step_round_trip_is_callable(tmp_path):
    import jax
    import jax.numpy as jnp

    c = ArtifactCache(str(tmp_path))
    compiled = jax.jit(lambda x: x * 2 + 1).lower(
        jnp.arange(4.0)).compile()
    assert c.put_step("s", compiled)
    loaded = c.get_step("s")
    assert loaded is not None
    assert np.array_equal(np.asarray(loaded(jnp.arange(4.0))),
                          np.asarray(compiled(jnp.arange(4.0))))


def test_purge(tmp_path):
    c = ArtifactCache(str(tmp_path))
    c.put_bytes("a", b"1", "table")
    c.put_bytes("b", b"2", "table")
    assert c.purge("a") == 1
    assert c.get_bytes("b") == b"2"
    assert c.purge() == 1
    assert c.stats()["entries"] == 0
    assert c.counters["purged"] == 2


# ------------------------------------------------------------- eviction
def test_lru_eviction_respects_budget(tmp_path):
    """Oldest-accessed entries go first; the directory ends under budget
    and evictions are counted (surfaced by `prog cache stat`)."""
    c = ArtifactCache(str(tmp_path), max_bytes=256)
    c.put_bytes("a", b"x" * 100, "table")
    c.put_bytes("b", b"y" * 100, "table")
    assert c.counters["evicted"] == 0                 # under budget: no-op
    os.utime(c._bin("a"), (1, 1))                     # make "a" the LRU
    c.put_bytes("c", b"z" * 100, "table")             # 300 > 256 -> evict
    assert c.counters["evicted"] == 1
    assert c.get_bytes("a") is None                   # LRU victim
    assert c.get_bytes("b") == b"y" * 100
    assert c.get_bytes("c") == b"z" * 100
    assert c.stats()["bytes"] <= 256
    assert c.stats()["max_bytes"] == 256


def test_eviction_hit_refreshes_recency(tmp_path):
    """A get_bytes hit bumps the entry's recency, so a recently-read
    entry survives eviction over a never-read older store."""
    c = ArtifactCache(str(tmp_path), max_bytes=256)
    c.put_bytes("a", b"x" * 100, "table")
    c.put_bytes("b", b"y" * 100, "table")
    os.utime(c._bin("a"), (1, 1))
    os.utime(c._bin("b"), (2, 2))
    assert c.get_bytes("a") == b"x" * 100             # refresh "a"
    c.put_bytes("c", b"z" * 100, "table")
    assert c.get_bytes("a") is not None               # read-recency saved it
    assert c.get_bytes("b") is None                   # cold entry evicted


def test_eviction_never_removes_just_written_entry(tmp_path):
    """An artifact larger than the whole budget still serves its writer:
    the store that triggered eviction is shielded from it."""
    c = ArtifactCache(str(tmp_path), max_bytes=64)
    c.put_bytes("big", b"x" * 1000, "table")
    assert c.get_bytes("big") == b"x" * 1000
    c.put_bytes("big2", b"y" * 1000, "table")         # evicts "big" only
    assert c.get_bytes("big") is None
    assert c.get_bytes("big2") == b"y" * 1000


def test_no_budget_no_eviction(tmp_path):
    c = ArtifactCache(str(tmp_path))                  # max_bytes=None
    for i in range(8):
        c.put_bytes(f"k{i}", b"x" * 512, "table")
    assert c.counters["evicted"] == 0
    assert c.stats()["entries"] == 8
    assert c.stats()["max_bytes"] is None


# ------------------------------------------------------------- corruption
def test_manual_corruption_detected_and_dropped(tmp_path):
    c = ArtifactCache(str(tmp_path))
    c.put_bytes("k", b"x" * 64, "table")
    with open(c._bin("k"), "r+b") as f:
        f.seek(10)
        f.write(b"\xff")
    assert c.get_bytes("k") is None
    assert c.counters["corrupt"] == 1
    assert not os.path.exists(c._bin("k"))            # torn entry reclaimed
    # degrade to recompile: a fresh store of the same key works
    c.put_bytes("k", b"y" * 64, "table")
    assert c.get_bytes("k") == b"y" * 64


def test_fault_plan_corrupts_artifact_and_cache_degrades(tmp_path):
    """The chaos drill in miniature: corrupt_artifact fires on the
    cache:post_store hook, the CRC catches it on read, and the caller
    sees a plain miss — never a torn artifact, never a crash."""
    c = ArtifactCache(str(tmp_path))
    with F.plan(F.FaultPlan(seed=0,
                            rates={"corrupt_artifact": 1.0})) as p:
        c.put_bytes("k", b"z" * 256, "step")
        assert p.counters["corrupt_artifact"] == 1
    assert c.get_bytes("k", kind="step") is None
    assert c.counters["corrupt"] == 1
    assert c.counters["hits"] == 0
    # and with the plan gone, the rewrite round-trips
    c.put_bytes("k", b"z" * 256, "step")
    assert c.get_bytes("k", kind="step") == b"z" * 256


# ------------------------------------------------------------- keying
def _runtime(specs):
    rt = BpftimeRuntime()
    for s in specs:
        rt.create_map(s)
    return rt


def test_same_attach_signature_different_registry_different_key():
    """Regression for the promote.py under-keying bug: the compile cache
    was keyed on attach_signature alone, so two worlds with the same
    attach set but different map registries collided — the second world
    would be served the first world's executable."""
    from repro.core.promote import PromotionEngine

    rt_a = _runtime([MapSpec("m", MapKind.ARRAY, max_entries=64)])
    rt_b = _runtime([MapSpec("m", MapKind.ARRAY, max_entries=64),
                     MapSpec("extra", MapKind.HASH, max_entries=32)])

    class _Link:
        _parsed = (E.SITES.get_or_create("keying_site"), E.KIND_ENTRY)
        pid = 1

    key_a = PromotionEngine(rt_a, lambda: None, ())._cache_key(_Link())
    key_b = PromotionEngine(rt_b, lambda: None, ())._cache_key(_Link())
    # identical post-promotion attach signatures...
    assert (PromotionEngine(rt_a, None, ())._target_signature(_Link())
            == PromotionEngine(rt_b, None, ())._target_signature(_Link()))
    # ...must still key to different artifacts
    assert key_a != key_b


def test_layout_fingerprint_separates_attach_sets():
    rt = _runtime([MapSpec("m", MapKind.ARRAY, max_entries=64)])
    base = rt.layout_fingerprint()
    assert rt.layout_fingerprint(attach_sig=((("s", 0), (1,)),)) != base
    assert rt.layout_fingerprint(extra=("batch", 8)) != base
    assert rt.layout_fingerprint() == base            # deterministic


# ------------------------------------------------------------- aot_step
def test_aot_step_round_trip_same_process(tmp_path):
    import jax
    import jax.numpy as jnp

    def boot():
        rt = _runtime([MapSpec("m", MapKind.ARRAY, max_entries=64)])
        rt.enable_artifact_cache(str(tmp_path))
        calls = []

        def build():
            calls.append(1)
            return jax.jit(lambda x: x + 1)

        compiled, hit = rt.aot_step(build, (jnp.arange(8.0),))
        return compiled, hit, len(calls), rt

    c1, hit1, calls1, _ = boot()
    assert (hit1, calls1) == (False, 1)
    c2, hit2, calls2, rt2 = boot()
    assert (hit2, calls2) == (True, 0)                # zero retraces
    assert np.array_equal(np.asarray(c1(jnp.arange(8.0))),
                          np.asarray(c2(jnp.arange(8.0))))
    assert rt2.artifact_cache.counters["hits"] == 1


_WORKER_SRC = r"""
import json, sys
import jax, jax.numpy as jnp
from repro.core.maps import MapKind, MapSpec
from repro.core.runtime import BpftimeRuntime

cache_dir = sys.argv[1]
rt = BpftimeRuntime()
rt.create_map(MapSpec("m", MapKind.ARRAY, max_entries=64))
rt.enable_artifact_cache(cache_dir)
builds = []
def build():
    builds.append(1)
    return jax.jit(lambda x: x * 3)
compiled, hit = rt.aot_step(build, (jnp.arange(4.0),))
out = [float(v) for v in compiled(jnp.arange(4.0))]
print(json.dumps({"hit": hit, "builds": len(builds), "out": out,
                  "counters": rt.artifact_cache.counters}))
"""


@pytest.mark.slow
def test_cross_process_cache_reuse(tmp_path):
    """Worker A populates the shared cache directory; a FRESH process B
    derives the same fingerprint, hits, and never builds/retraces."""
    env = dict(os.environ, PYTHONPATH="src")

    def worker():
        r = subprocess.run(
            [sys.executable, "-c", _WORKER_SRC, str(tmp_path)],
            capture_output=True, text=True, env=env, cwd=os.getcwd())
        assert r.returncode == 0, r.stderr
        return json.loads(r.stdout.strip().splitlines()[-1])

    a = worker()
    assert a["hit"] is False and a["builds"] == 1
    assert a["counters"]["stores"] == 1
    b = worker()
    assert b["hit"] is True
    assert b["builds"] == 0                           # zero retraces in B
    assert b["counters"] == {"hits": 1, "misses": 0, "stores": 0,
                             "corrupt": 0, "purged": 0, "evicted": 0}
    assert a["out"] == b["out"]
