"""Bounded condition-wait helpers for multi-process tests.

Every cross-process rendezvous in the suite goes through these instead of
bare ``time.sleep`` loops: each wait has an explicit deadline, polls with
exponential backoff (fast when the condition flips quickly, cheap when it
does not), and raises a TimeoutError naming the condition — so a hung
child turns into a diagnosable failure, never a silent 10-minute stall.
"""
from __future__ import annotations

import signal
import subprocess
import time


def wait_for(pred, timeout: float = 60.0, msg: str = "condition",
             initial: float = 0.001, max_interval: float = 0.05):
    """Poll `pred` until truthy; returns its value. Backoff doubles from
    `initial` to `max_interval`, so a condition that flips in microseconds
    costs microseconds and a slow one costs ~20 polls/second, not a spin."""
    deadline = time.monotonic() + timeout
    interval = initial
    while True:
        val = pred()
        if val:
            return val
        if time.monotonic() > deadline:
            raise TimeoutError(f"timed out after {timeout}s waiting "
                               f"for {msg}")
        time.sleep(interval)
        interval = min(interval * 2, max_interval)


def wait_for_path(path: str, timeout: float = 60.0):
    """Wait for a file to exist (child-process ready files)."""
    import os
    return wait_for(lambda: os.path.exists(path), timeout=timeout,
                    msg=f"path {path}")


def wait_for_exit(proc, timeout: float = 60.0):
    """Join a multiprocessing.Process with a deadline; SIGKILL + reap on
    timeout so the test fails with a message instead of leaking a child.
    Returns the exit code."""
    proc.join(timeout=timeout)
    if proc.is_alive():
        proc.kill()
        proc.join()
        raise TimeoutError(f"process pid={proc.pid} still alive after "
                           f"{timeout}s; killed")
    return proc.exitcode


def park() -> None:
    """Block until a signal arrives — for victim children the parent will
    SIGKILL. Unlike ``time.sleep(<huge>)`` this documents the intent and
    never outlives the test on its own (pytest-level timeouts see a
    signal-interruptible wait, and any terminating signal ends it)."""
    while True:
        signal.pause()


def run_cli(cmd, timeout: float = 120.0, **kw) -> subprocess.CompletedProcess:
    """subprocess.run with capture + a bounded deadline that reports the
    child's output so far on expiry (subprocess.TimeoutExpired swallows it
    unless capture was requested — always request it)."""
    kw.setdefault("capture_output", True)
    kw.setdefault("text", True)
    try:
        return subprocess.run(cmd, timeout=timeout, **kw)
    except subprocess.TimeoutExpired as e:
        out = (e.stdout or b"")
        err = (e.stderr or b"")
        if isinstance(out, bytes):
            out = out.decode(errors="replace")
        if isinstance(err, bytes):
            err = err.decode(errors="replace")
        raise TimeoutError(
            f"{cmd[:3]}... exceeded {timeout}s\n"
            f"--- stdout so far ---\n{out[-2000:]}\n"
            f"--- stderr so far ---\n{err[-2000:]}") from e
