"""BTF-lite layout schema — the CO-RE vocabulary (DESIGN.md §13).

The paper's compatibility pillar is CO-RE: a probe binary carries symbolic
references (field names, map names) plus the layout it was compiled
against, and a loader relocates it onto whatever concrete layout the
target process actually has.  This module is our BTF: it names the two
abstract surfaces a program can reference —

  * :class:`CtxLayout` — the event-row schema (field name -> i64 word
    index).  Programs written as ``ldxdw r6, [r1+ctx:layer]`` are
    assembled against ONE CtxLayout and re-offset onto any other at load
    time (core/reloc.py), exactly how CO-RE rewrites field offsets from
    the compile-time BTF to the running kernel's.
  * :class:`MapLayout` — the declared shape of one map (kind + dims) a
    program references by ``lddw rX, map:NAME``.  Verification proves
    helper/kind compatibility against the DECLARATION; relocation binds
    the name to a concrete registry fd and re-checks only the cheap
    structural facts (kind equality, record width).

It also owns the canonical **layout fingerprint** — the cache key of the
fleet-wide AOT artifact cache (core/artifact_cache.py).  DESIGN.md §9
proves the live-table step's compiled graph depends only on (map
registry, ctx width, table dims); §12 adds the static attach signature
for the fused lane.  ``layout_fingerprint`` hashes exactly that basis and
nothing else, so two workers with bit-identical trace inputs derive the
same key and the Nth worker joining the fleet reuses the first worker's
executable instead of retracing.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

from .maps import MapKind, MapSpec

FINGERPRINT_VERSION = "bpftime-layout-v1"


class LayoutError(ValueError):
    pass


# --------------------------------------------------------------------------
# ctx layout (the event-row "struct")
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class CtxLayout:
    """Named i64-word layout of a probe context row.

    ``fields`` is a sorted tuple of (name, word_index); ``words`` is the
    row width a program verified against this layout may assume.  The
    byte offset of a field is ``8 * word`` — the event tape is a flat
    i64 vector, so there is no padding or nesting to model (BTF-lite)."""
    name: str
    fields: tuple[tuple[str, int], ...]
    words: int

    def __post_init__(self):
        seen: dict[str, int] = {}
        for f, w in self.fields:
            if f in seen:
                raise LayoutError(f"duplicate ctx field {f!r}")
            if not 0 <= w < self.words:
                raise LayoutError(
                    f"ctx field {f!r} at word {w} outside layout "
                    f"({self.words} words)")
            seen[f] = w

    @staticmethod
    def from_btf(name: str, table: dict[str, int],
                 words: int = 16) -> "CtxLayout":
        return CtxLayout(name=name,
                         fields=tuple(sorted(table.items())),
                         words=words)

    def table(self) -> dict[str, int]:
        return dict(self.fields)

    def word_of(self, field: str) -> int:
        for f, w in self.fields:
            if f == field:
                return w
        raise LayoutError(f"unknown ctx field {field!r} in layout "
                          f"{self.name!r}")

    def byte_of(self, field: str) -> int:
        return 8 * self.word_of(field)

    def has(self, field: str) -> bool:
        return any(f == field for f, _ in self.fields)

    def fingerprint_basis(self) -> tuple:
        return ("ctx", self.name, self.fields, self.words)


# canonical BTF tables (single source of truth; loader re-exports them).
# Event row layout: DESIGN.md §3 / events.EVENT_WIDTH.
EVENT_BTF = {
    "site_id": 0, "kind": 1, "layer": 2, "step": 3,
    "numel": 4, "mean": 5, "rms": 6, "min": 7, "max": 8, "absmax": 9,
    "nan_cnt": 10, "inf_cnt": 11,
}
SYSCALL_BTF = {"sys_id": 0, "arg0": 1, "arg1": 2, "arg2": 3, "arg3": 4,
               "arg4": 5, "ret": 6}

EVENT_LAYOUT = CtxLayout.from_btf("event", EVENT_BTF, words=16)
SYSCALL_LAYOUT = CtxLayout.from_btf("syscall", SYSCALL_BTF, words=16)


def layout_for(prog_type: str, btf: dict | None = None,
               words: int = 16) -> CtxLayout:
    """The CtxLayout a program of this type is assembled/verified against."""
    if btf is not None:
        return CtxLayout.from_btf("custom", dict(btf), words=words)
    if prog_type in ("tracepoint", "filter"):
        return SYSCALL_LAYOUT if words == 16 else \
            CtxLayout.from_btf("syscall", SYSCALL_BTF, words=words)
    return EVENT_LAYOUT if words == 16 else \
        CtxLayout.from_btf("event", EVENT_BTF, words=words)


# --------------------------------------------------------------------------
# map layout (the declared shape a program verifies against)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MapLayout:
    """Abstract declaration of one referenced map.

    This is the per-program view: verification proves helper calls are
    legal for ``kind`` and (for ringbufs) sized within ``rec_width``;
    relocation binds ``name`` to a concrete registry fd whose spec must
    be :meth:`compatible` — kind equality plus a record width at least
    as wide as declared (lookups/folds never index past the concrete
    map's own dims: the j_* twins clamp/probe within their state)."""
    name: str
    kind: MapKind
    max_entries: int = 64
    rec_width: int = 4
    num_shards: int = 1

    @staticmethod
    def from_spec(spec: MapSpec) -> "MapLayout":
        return MapLayout(name=spec.name, kind=spec.kind,
                         max_entries=spec.max_entries,
                         rec_width=spec.rec_width,
                         num_shards=spec.num_shards)

    def to_spec(self) -> MapSpec:
        return MapSpec(name=self.name, kind=self.kind,
                       max_entries=self.max_entries,
                       rec_width=self.rec_width,
                       num_shards=self.num_shards)

    def compatible(self, spec: MapSpec) -> str | None:
        """None if a program verified against this layout may run against
        ``spec``; else a human-readable reason."""
        if spec.kind != self.kind:
            return (f"map {self.name!r}: declared kind {self.kind.value}, "
                    f"registry has {spec.kind.value}")
        if spec.kind == MapKind.RINGBUF and spec.rec_width < self.rec_width:
            return (f"ringbuf {self.name!r}: declared rec_width "
                    f"{self.rec_width}, registry has {spec.rec_width}")
        return None


# --------------------------------------------------------------------------
# fingerprints (the artifact-cache key basis)
# --------------------------------------------------------------------------

def registry_basis(map_specs) -> tuple:
    """Canonical identity of a map registry IN FD ORDER — the trace of
    every lane indexes maps positionally, so fd order is part of the
    compiled graph (same set of maps in a different order is a different
    world).  Flags are advisory and excluded (cf. table_interp._spec_key).
    """
    return tuple((s.name, s.kind.value, s.max_entries, s.rec_width,
                  s.num_shards) for s in map_specs)


def layout_fingerprint(map_specs, ctx_words: int,
                       table_dims: tuple | None = None,
                       attach_sig: tuple | None = None,
                       extra: tuple = ()) -> str:
    """The canonical cache key: sha256 over exactly the trace-stability
    basis (DESIGN.md §9/§13) —

        (map registry shape/kinds in fd order, ctx words,
         live-table dims, static attach signature, caller extras)

    Two processes whose steps trace bit-identical graphs derive the same
    key; ANY divergence in the basis (a new map, a wider table, a
    different attach set) derives a different key, which is the whole
    invalidation rule: artifacts are never invalidated in place, they are
    simply keyed away from."""
    basis = (FINGERPRINT_VERSION, registry_basis(map_specs),
             int(ctx_words), tuple(table_dims or ()),
             tuple(attach_sig or ()), tuple(extra))
    return hashlib.sha256(repr(basis).encode()).hexdigest()[:24]


def program_digest(insns_blob: bytes) -> str:
    """Content address of one encoded program (table-image cache keys)."""
    return hashlib.sha256(insns_blob).hexdigest()[:16]
