"""Bytecode -> JAX JIT: the LLVM-JIT analogue, emitting jnp ops that fuse
into the enclosing XLA step function (the "inline in the target process"
property that gives bpftime its 10x).

Two tiers, selected by the verifier's CFG analysis:

  T1 ("dag")  : programs whose CFG is acyclic are fully if-converted into
                straight-line predicated dataflow. Registers/stack are merged
                per-block with selects; map/aux side effects are gated by the
                block's arrival predicate and threaded linearly (disjoint
                predicates make the order across sibling branches
                irrelevant). Zero control flow in the lowered HLO.
  T2 ("loop") : programs with (fuel-bounded) loops become a
                lax.while_loop over a basic-block dispatcher (lax.switch),
                the classic JIT block-threading scheme.

The verifier has already proven every memory access static and in-bounds, so
codegen performs NO runtime checks — verify once, run fast (paper SP1).

A third compiler, `compile_vectorized`, is the TPU-native beyond-paper path:
for DAG programs whose side effects are all commutative (fetch-add family),
events are executed as one batched tensor program (scatter-adds) instead of a
sequential scan. See DESIGN.md §2 adaptation 1.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import isa, maps as M
from .isa import (BPF_ALU, BPF_ALU64, BPF_JMP, BPF_JMP32, BPF_LDX, BPF_ST,
                  BPF_STX, CTX_BASE, OP_MASK, SIZE_BYTES, SIZE_MASK, SRC_MASK,
                  STACK_BASE, STACK_SIZE)
from .verifier import CallAnn, MemAnn, VerifiedProgram

I64 = jnp.int64
U8 = jnp.uint8

# helpers safe for the vectorized (batched-events) compiler: commutative
# side effects only.
VECTOR_SAFE_HELPERS = {1001, 1005, 1004, 5, 8, 14, 1002, 7, 6, 1003, 130}

# word-oriented stack: 512 bytes modelled as 64 little-endian i64 lanes.
# Verifier-proven aligned 8-byte accesses lower to ONE dynamic-slice /
# scatter; unaligned and sub-word accesses keep byte-exact semantics via
# static shift/mask codegen over at most two words.
STACK_WORDS = STACK_SIZE // 8
_U64_FULL = 0xFFFFFFFFFFFFFFFF


def make_aux(time_ns=0, cpu=0, pid=0, rand=0x12345678):
    return {
        "time_ns": jnp.asarray(time_ns, I64),
        "cpu": jnp.asarray(cpu, I64),
        "pid": jnp.asarray(pid, I64),
        "rand": jnp.asarray(rand, I64),
        "override_set": jnp.asarray(0, I64),
        "override_val": jnp.asarray(0, I64),
        "printk_buf": jnp.zeros((8, 2), I64),
        "printk_n": jnp.asarray(0, I64),
    }


# --------------------------------------------------------------------------
# shared scalar machinery
# --------------------------------------------------------------------------

def _u(x):  # bit-pattern reinterpret to unsigned for u64 compares/shifts
    return x.astype(jnp.uint64)


def _alu_jax(op: int, d, s, is64: bool):
    """d, s: i64 traced. 32-bit ops work on the low 32 bits, zero-extend."""
    if not is64:
        d = jnp.bitwise_and(d, jnp.int64(0xFFFFFFFF))
        s = jnp.bitwise_and(s, jnp.int64(0xFFFFFFFF))
    bits = jnp.int64(63 if is64 else 31)
    if op == isa.BPF_ADD:
        r = d + s
    elif op == isa.BPF_SUB:
        r = d - s
    elif op == isa.BPF_MUL:
        r = d * s
    elif op == isa.BPF_DIV:
        r = jnp.where(s == 0, jnp.int64(0),
                      (_u(d) // _u(jnp.where(s == 0, 1, s))).astype(I64))
    elif op == isa.BPF_MOD:
        r = jnp.where(s == 0, d,
                      (_u(d) % _u(jnp.where(s == 0, 1, s))).astype(I64))
    elif op == isa.BPF_OR:
        r = d | s
    elif op == isa.BPF_AND:
        r = d & s
    elif op == isa.BPF_XOR:
        r = d ^ s
    elif op == isa.BPF_LSH:
        r = (_u(d) << _u(s & bits)).astype(I64)
    elif op == isa.BPF_RSH:
        r = (_u(d) >> _u(s & bits)).astype(I64)
    elif op == isa.BPF_ARSH:
        if is64:
            r = d >> (s & bits)
        else:
            r = _s32_view(d) >> (s & bits)
    elif op == isa.BPF_MOV:
        r = s
    elif op == isa.BPF_NEG:
        r = -d
    else:
        raise AssertionError(f"alu op {op:#x}")
    if not is64:
        r = jnp.bitwise_and(r, jnp.int64(0xFFFFFFFF))
    return r


def _s32_view(x):
    """low 32 bits of i64, sign-extended (as i64)."""
    lo = jnp.bitwise_and(x, jnp.int64(0xFFFFFFFF))
    return jnp.where(lo >> 31 != 0, lo - jnp.int64(1 << 32), lo)


def _jmp_cond_jax(op: int, lhs, rhs, is64: bool):
    if is64:
        ul, ur = _u(lhs), _u(rhs)
        sl, sr = lhs, rhs
    else:
        ul = _u(jnp.bitwise_and(lhs, jnp.int64(0xFFFFFFFF)))
        ur = _u(jnp.bitwise_and(rhs, jnp.int64(0xFFFFFFFF)))
        sl, sr = _s32_view(lhs), _s32_view(rhs)
    if op == isa.BPF_JEQ:
        return ul == ur
    if op == isa.BPF_JNE:
        return ul != ur
    if op == isa.BPF_JGT:
        return ul > ur
    if op == isa.BPF_JGE:
        return ul >= ur
    if op == isa.BPF_JLT:
        return ul < ur
    if op == isa.BPF_JLE:
        return ul <= ur
    if op == isa.BPF_JSGT:
        return sl > sr
    if op == isa.BPF_JSGE:
        return sl >= sr
    if op == isa.BPF_JSLT:
        return sl < sr
    if op == isa.BPF_JSLE:
        return sl <= sr
    if op == isa.BPF_JSET:
        return (ul & ur) != jnp.uint64(0)
    raise AssertionError(f"jmp op {op:#x}")


def _stack_load(stack, off: int, size: int, aligned: bool | None = None):
    """Static-offset little-endian load from the i64-word stack, zero-
    extended to i64. `aligned` is the verifier's proof of natural 8-byte
    alignment (derived from the static offset when not supplied): that path
    is a single word gather; the general path reads the one or two covering
    words and shifts/masks — all offsets/sizes are compile-time constants,
    so the lowered HLO contains no byte-lane loops."""
    if aligned is None:
        aligned = off % 8 == 0 and size == 8
    w0, rb = divmod(off, 8)
    if aligned:
        return stack[w0]
    lo = _u(stack[w0]) >> jnp.uint64(8 * rb)
    if rb + size > 8:                       # spans into the next word
        lo = lo | (_u(stack[w0 + 1]) << jnp.uint64(8 * (8 - rb)))
    if size < 8:
        lo = lo & jnp.uint64((1 << (8 * size)) - 1)
    return lo.astype(I64)


def _stack_store(stack, off: int, size: int, val, aligned: bool | None = None):
    """Static-offset little-endian store of the low `size` bytes of `val`
    into the i64-word stack. Aligned 8-byte stores are one scatter; the
    general path read-modify-writes the one or two covering words."""
    if aligned is None:
        aligned = off % 8 == 0 and size == 8
    if aligned:
        return stack.at[off // 8].set(val)
    w0, rb = divmod(off, 8)
    v = _u(val)
    if size < 8:
        v = v & jnp.uint64((1 << (8 * size)) - 1)
    nb0 = min(size, 8 - rb)                 # bytes landing in word0
    m0 = ((1 << (8 * nb0)) - 1) << (8 * rb)
    w0_new = ((_u(stack[w0]) & jnp.uint64(m0 ^ _U64_FULL))
              | ((v << jnp.uint64(8 * rb)) & jnp.uint64(m0)))
    stack = stack.at[w0].set(w0_new.astype(I64))
    if rb + size > 8:
        m1 = (1 << (8 * (rb + size - 8))) - 1
        w1_new = ((_u(stack[w0 + 1]) & jnp.uint64(m1 ^ _U64_FULL))
                  | ((v >> jnp.uint64(8 * (8 - rb))) & jnp.uint64(m1)))
        stack = stack.at[w0 + 1].set(w1_new.astype(I64))
    return stack


def dyn_word_load(words, off, size):
    """Little-endian load of `size` bytes at DYNAMIC byte offset `off` from
    an i64 word array — the traced-offset twin of `_stack_load`, used by the
    program-table interpreter where offsets are data, not constants. The
    verifier has proven accesses in bounds before a program is table-encoded;
    indices are clipped only to keep XLA gathers well-defined. Shift amounts
    are masked to [0, 63] with `where` guards for the rb == 0 / size == 8
    edge cases (a shift by 64 is undefined in XLA)."""
    nwords = words.shape[0]
    w0 = jnp.clip(off >> 3, 0, nwords - 1).astype(jnp.int32)
    w1 = jnp.minimum(w0 + 1, nwords - 1)
    rb = _u(off & 7)
    lo = _u(words[w0]) >> (jnp.uint64(8) * rb)
    hi_sh = (jnp.uint64(64) - jnp.uint64(8) * rb) & jnp.uint64(63)
    hi = jnp.where(rb == 0, jnp.uint64(0), _u(words[w1]) << hi_sh)
    v = lo | hi
    nbits = (jnp.uint64(8) * _u(size)) & jnp.uint64(63)
    mask = jnp.where(size >= 8, jnp.uint64(_U64_FULL),
                     (jnp.uint64(1) << nbits) - jnp.uint64(1))
    return (v & mask).astype(I64)


def dyn_word_store(words, off, size, val):
    """Little-endian store of the low `size` bytes of `val` at DYNAMIC byte
    offset `off` — the traced-offset twin of `_stack_store`. Read-modify-
    writes the one or two covering words; the second-word write is a
    self-assignment when the access doesn't span (and the spanning case is
    verifier-proven in bounds, so w1 never aliases w0)."""
    nwords = words.shape[0]
    w0 = jnp.clip(off >> 3, 0, nwords - 1).astype(jnp.int32)
    w1 = jnp.minimum(w0 + 1, nwords - 1)
    rb = off & 7
    nbits = (jnp.uint64(8) * _u(size)) & jnp.uint64(63)
    v = jnp.where(size >= 8, _u(val),
                  _u(val) & ((jnp.uint64(1) << nbits) - jnp.uint64(1)))
    nb0 = jnp.minimum(size, 8 - rb)              # bytes landing in word0
    m0_bits = (jnp.uint64(8) * _u(nb0)) & jnp.uint64(63)
    m0 = jnp.where(nb0 >= 8, jnp.uint64(_U64_FULL),
                   (jnp.uint64(1) << m0_bits) - jnp.uint64(1)) \
        << (jnp.uint64(8) * _u(rb))
    new0 = (_u(words[w0]) & ~m0) | ((v << (jnp.uint64(8) * _u(rb))) & m0)
    spans = (rb + size) > 8
    nb1 = jnp.clip(rb + size - 8, 0, 7)
    m1 = (jnp.uint64(1) << (jnp.uint64(8) * _u(nb1))) - jnp.uint64(1)
    sh1 = (jnp.uint64(8) * _u(8 - rb)) & jnp.uint64(63)
    new1 = (_u(words[w1]) & ~m1) | ((v >> sh1) & m1)
    # word1 first: when not spanning this is a self-assignment, so it cannot
    # clobber the word0 write even if w1 was clipped onto w0.
    words = words.at[w1].set(jnp.where(spans, new1.astype(I64), words[w1]))
    words = words.at[w0].set(new0.astype(I64))
    return words


def _imm_src(ins, is64: bool):
    if is64:
        return jnp.int64(ins.imm)          # sign-extended s32 -> s64
    return jnp.int64(ins.imm & 0xFFFFFFFF)


@dataclass
class _Machine:
    regs: list          # 11 traced i64 scalars
    stack: object       # i64[STACK_WORDS] (little-endian byte semantics)


def _exec_straightline(vprog: VerifiedProgram, lo: int, hi: int, m: _Machine,
                       maps_state, aux, pred, ctx, helper_cb=None):
    """Execute insns [lo, hi) except a trailing terminator handled by caller.
    Side effects gated by `pred` (traced bool scalar). helper_cb overrides
    helper execution (used by the vectorized shadow pass)."""
    helper_cb = helper_cb or _exec_helper
    for pc in range(lo, hi):
        ins = vprog.insns[pc]
        cls = ins.cls
        if ins.is_lddw():
            m.regs[ins.dst] = jnp.int64(isa.s64(ins.imm64 or 0))
        elif cls in (BPF_ALU64, BPF_ALU):
            op = ins.op & OP_MASK
            is64 = cls == BPF_ALU64
            if op == isa.BPF_NEG:
                m.regs[ins.dst] = _alu_jax(op, m.regs[ins.dst],
                                           jnp.int64(0), is64)
            else:
                s = (m.regs[ins.src] if ins.op & SRC_MASK
                     else _imm_src(ins, is64))
                m.regs[ins.dst] = _alu_jax(op, m.regs[ins.dst], s, is64)
        elif cls == BPF_LDX:
            ann: MemAnn = vprog.anns[pc]
            size = SIZE_BYTES[ins.op & SIZE_MASK]
            if ann.region == "stack":
                m.regs[ins.dst] = _stack_load(m.stack, ann.off, size,
                                              aligned=ann.aligned)
            else:  # ctx — i64 word array, static offset
                word, rem = divmod(ann.off, 8)
                v = ctx[word]
                if rem or size != 8:
                    v = (v >> (8 * rem))
                    if size < 8:
                        v = jnp.bitwise_and(
                            v, jnp.int64((1 << (8 * size)) - 1))
                m.regs[ins.dst] = v
        elif cls in (BPF_STX, BPF_ST):
            ann = vprog.anns[pc]
            size = SIZE_BYTES[ins.op & SIZE_MASK]
            # ST: imm sign-extended, low `size` bytes written (oracle parity)
            val = m.regs[ins.src] if cls == BPF_STX else jnp.int64(ins.imm)
            m.stack = _stack_store(m.stack, ann.off, size, val,
                                   aligned=ann.aligned)
        elif cls in (BPF_JMP, BPF_JMP32) and (ins.op & OP_MASK) == isa.BPF_CALL:
            ann = vprog.anns[pc]
            r0, maps_state, aux = helper_cb(vprog, ann, m, maps_state,
                                            aux, pred)
            m.regs[0] = r0
            for r in range(1, 6):
                m.regs[r] = jnp.int64(0)
        else:
            raise AssertionError(f"terminator {pc} inside straight-line run")
    return m, maps_state, aux


def _neg7():
    return jnp.int64(-7)


def _exec_helper(vprog, ann: CallAnn, m: _Machine, maps_state, aux, pred):
    name, st_args = ann.name, ann.statics
    specs = vprog.map_specs

    def load_key(off):
        return _stack_load(m.stack, off, 8)

    zero = jnp.int64(0)

    if name == "map_lookup_elem":
        fd, koff = st_args
        sp = specs[fd]
        key = load_key(koff)
        mstate = maps_state[sp.name]
        if sp.kind == M.MapKind.ARRAY:
            r0 = M.j_array_lookup(mstate, key, pred)
        elif sp.kind == M.MapKind.PERCPU_ARRAY:
            r0 = M.j_percpu_lookup(mstate, aux["cpu"], key, pred)
        else:
            r0 = M.j_hash_lookup(mstate, key, pred)
        return r0, maps_state, aux

    if name == "map_update_elem":
        fd, koff, voff, _ = st_args
        sp = specs[fd]
        key, val = load_key(koff), load_key(voff)
        mstate = maps_state[sp.name]
        if sp.kind == M.MapKind.ARRAY:
            new = M.j_array_update(mstate, key, val, pred)
            r0 = zero
        else:
            new, ok = M.j_hash_update(mstate, key, val, pred)
            r0 = jnp.where(ok, zero, _neg7())
        return r0, {**maps_state, sp.name: new}, aux

    if name == "map_delete_elem":
        fd, koff = st_args
        sp = specs[fd]
        new, found = M.j_hash_delete(maps_state[sp.name], load_key(koff), pred)
        r0 = jnp.where(found, zero, jnp.int64(-2))
        return r0, {**maps_state, sp.name: new}, aux

    if name == "map_fetch_add":
        fd, koff, _ = st_args
        sp = specs[fd]
        key, delta = load_key(koff), m.regs[3]
        mstate = maps_state[sp.name]
        if sp.kind == M.MapKind.ARRAY:
            new, old = M.j_array_fetch_add(mstate, key, delta, pred)
        else:
            new, old = M.j_hash_fetch_add(mstate, key, delta, pred)
        return old, {**maps_state, sp.name: new}, aux

    if name == "percpu_fetch_add":
        fd, koff, _ = st_args
        sp = specs[fd]
        new, old = M.j_percpu_fetch_add(maps_state[sp.name], aux["cpu"],
                                        load_key(koff), m.regs[3], pred)
        return old, {**maps_state, sp.name: new}, aux

    if name == "hist_add":
        fd, _ = st_args
        sp = specs[fd]
        new = M.j_hist_add(maps_state[sp.name], m.regs[2], pred)
        return zero, {**maps_state, sp.name: new}, aux

    if name == "ringbuf_output":
        fd, doff, size, _ = st_args
        sp = specs[fd]
        rec = [_stack_load(m.stack, doff + 8 * i, 8) for i in range(size // 8)]
        rec += [zero] * (sp.rec_width - len(rec))
        new = M.j_ringbuf_emit(maps_state[sp.name], jnp.stack(rec), pred)
        return zero, {**maps_state, sp.name: new}, aux

    if name == "ktime_get_ns":
        return aux["time_ns"], maps_state, aux
    if name == "get_smp_processor_id":
        return aux["cpu"], maps_state, aux
    if name == "get_current_pid_tgid":
        return aux["pid"], maps_state, aux
    if name == "log2":
        return M.jnp_log2_bin(m.regs[1]).astype(I64), maps_state, aux
    if name == "get_prandom_u32":
        x = jnp.bitwise_and(aux["rand"], jnp.int64(0xFFFFFFFF))
        x = jnp.where(x == 0, jnp.int64(1), x)
        x = jnp.bitwise_and(x ^ (x << 13), jnp.int64(0xFFFFFFFF))
        x = x ^ (x >> 17)
        x = jnp.bitwise_and(x ^ (x << 5), jnp.int64(0xFFFFFFFF))
        new_rand = jnp.where(pred, x, aux["rand"])
        return jnp.where(pred, x, jnp.int64(0)), maps_state, \
            {**aux, "rand": new_rand}
    if name == "trace_printk":
        slot = jnp.clip(aux["printk_n"], 0, 7).astype(jnp.int32)
        row = jnp.stack([m.regs[1], m.regs[2]])
        buf = aux["printk_buf"].at[slot].set(
            jnp.where(pred, row, aux["printk_buf"][slot]))
        n = aux["printk_n"] + jnp.where(pred, jnp.int64(1), jnp.int64(0))
        return zero, maps_state, {**aux, "printk_buf": buf, "printk_n": n}
    if name == "override_return":
        ov_s = jnp.where(pred, jnp.int64(1), aux["override_set"])
        ov_v = jnp.where(pred, m.regs[1], aux["override_val"])
        return zero, maps_state, {**aux, "override_set": ov_s,
                                  "override_val": ov_v}
    raise AssertionError(f"helper {name} not implemented in JIT")


# --------------------------------------------------------------------------
# Tier 1: DAG if-conversion
# --------------------------------------------------------------------------

def _topo_order(vprog: VerifiedProgram) -> list[int]:
    """Kahn's algorithm from the entry block; unreachable blocks excluded."""
    from collections import deque
    n = len(vprog.blocks)
    indeg = [0] * n
    for b in vprog.blocks:
        for s in b.succ:
            indeg[s] += 1
    dq = deque([0])
    seen = {0}
    out: list[int] = []
    while dq:
        u = dq.popleft()
        out.append(u)
        for s in vprog.blocks[u].succ:
            indeg[s] -= 1
            if indeg[s] <= 0 and s not in seen:
                seen.add(s)
                dq.append(s)
    return out


def compile_t1(vprog: VerifiedProgram, helper_cb=None):
    assert vprog.tier == "dag"
    order = _topo_order(vprog)

    def run(ctx, maps_state, aux, entry_pred=None):
        """ctx: i64[ctx_words]; returns (r0, maps_state, aux).
        `entry_pred` (traced bool) is folded into the entry block's arrival
        predicate: every side effect in the program is already gated on its
        block predicate, so an invalid event becomes a complete no-op with
        NO post-hoc state select — the fused pipeline's per-event gate."""
        regs0 = [jnp.int64(0)] * 11
        regs0[isa.R1] = jnp.int64(CTX_BASE)
        regs0[isa.R10] = jnp.int64(STACK_BASE + STACK_SIZE)
        p0 = jnp.asarray(True) if entry_pred is None else entry_pred
        entry = (p0, regs0, jnp.zeros((STACK_WORDS,), I64))
        incoming: dict[int, tuple] = {0: entry}
        exits = []  # (pred, r0)

        for bid in order:
            if bid not in incoming:
                continue
            pred, regs, stack = incoming[bid]
            m = _Machine(list(regs), stack)
            blk = vprog.blocks[bid]
            term_pc = blk.end - 1
            body_hi = blk.end if blk.term == "fall" else term_pc
            m, maps_state, aux = _exec_straightline(
                vprog, blk.start, body_hi, m, maps_state, aux, pred, ctx,
                helper_cb)

            def send(tgt: int, p, mm):
                if tgt in incoming:
                    p0, r0s, st0 = incoming[tgt]
                    merged_regs = [jnp.where(p, a, b)
                                   for a, b in zip(mm.regs, r0s)]
                    merged_stack = jnp.where(p, mm.stack, st0)
                    incoming[tgt] = (p0 | p, merged_regs, merged_stack)
                else:
                    incoming[tgt] = (p, list(mm.regs), mm.stack)

            if blk.term == "fall":
                send(blk.succ[0], pred, m)
            elif blk.term == "ja":
                send(blk.succ[0], pred, m)
            elif blk.term == "exit":
                exits.append((pred, m.regs[0]))
            else:  # cond
                ins = vprog.insns[term_pc]
                is64 = ins.cls == BPF_JMP
                lhs = m.regs[ins.dst]
                rhs = (m.regs[ins.src] if ins.op & SRC_MASK
                       else _imm_src(ins, is64))
                c = _jmp_cond_jax(ins.op & OP_MASK, lhs, rhs, is64)
                send(blk.succ[0], pred & c, m)
                send(blk.succ[1], pred & ~c, m)

        r0 = jnp.int64(0)
        for p, v in exits:
            r0 = jnp.where(p, v, r0)
        return r0, maps_state, aux

    return run


# --------------------------------------------------------------------------
# Tier 2: while_loop block dispatcher
# --------------------------------------------------------------------------

def compile_t2(vprog: VerifiedProgram):
    nblocks = len(vprog.blocks)
    true_ = None  # placeholder

    def block_fn(bid: int):
        blk = vprog.blocks[bid]
        term_pc = blk.end - 1
        body_hi = blk.end if blk.term == "fall" else term_pc

        def f(carry):
            regs_arr, stack, maps_state, aux, r0, _bid = carry
            m = _Machine([regs_arr[i] for i in range(11)], stack)
            pred = jnp.asarray(True)
            m, maps_state2, aux2 = _exec_straightline(
                vprog, blk.start, body_hi, m, maps_state, aux, pred, f.ctx)
            if blk.term == "exit":
                nxt = jnp.int32(nblocks)           # sentinel: done
                r0n = m.regs[0]
            elif blk.term in ("ja", "fall"):
                nxt = jnp.int32(blk.succ[0])
                r0n = r0
            else:
                ins = vprog.insns[term_pc]
                is64 = ins.cls == BPF_JMP
                lhs = m.regs[ins.dst]
                rhs = (m.regs[ins.src] if ins.op & SRC_MASK
                       else _imm_src(ins, is64))
                c = _jmp_cond_jax(ins.op & OP_MASK, lhs, rhs, is64)
                nxt = jnp.where(c, jnp.int32(blk.succ[0]),
                                jnp.int32(blk.succ[1]))
                r0n = r0
            return (jnp.stack(m.regs), m.stack, maps_state2, aux2, r0n, nxt)

        return f

    fns = [block_fn(b) for b in range(nblocks)]

    def run(ctx, maps_state, aux):
        for f in fns:
            f.ctx = ctx  # bind ctx for this trace

        regs0 = jnp.zeros((11,), I64)
        regs0 = regs0.at[isa.R1].set(jnp.int64(CTX_BASE))
        regs0 = regs0.at[isa.R10].set(jnp.int64(STACK_BASE + STACK_SIZE))
        stack0 = jnp.zeros((STACK_WORDS,), I64)

        def cond(state):
            carry, fuel = state
            return (carry[5] < nblocks) & (fuel > 0)

        def body(state):
            carry, fuel = state
            bid = carry[5]
            new_carry = jax.lax.switch(jnp.clip(bid, 0, nblocks - 1),
                                       fns, carry)
            return new_carry, fuel - 1

        init = ((regs0, stack0, maps_state, aux, jnp.int64(0), jnp.int32(0)),
                jnp.int32(vprog.max_insns))
        (carry, _fuel) = jax.lax.while_loop(cond, body, init)
        _regs, _stack, maps_out, aux_out, r0, _bid = carry
        return r0, maps_out, aux_out

    return run


def compile_program(vprog: VerifiedProgram):
    """Scalar probe function: (ctx i64[W], maps, aux) -> (r0, maps, aux)."""
    return compile_t1(vprog) if vprog.tier == "dag" else compile_t2(vprog)


def run_over_events(vprog: VerifiedProgram, ctxs, valid, maps_state, aux):
    """Sequentially-consistent batched execution: lax.scan the compiled
    program over event rows. ctxs: i64[B, W]; valid: bool[B]."""
    prog = compile_program(vprog)

    def step(carry, xs):
        maps_state, aux = carry
        ctx, ok = xs
        # gate: invalid rows are no-ops. T1 gating via entry pred would be
        # cheaper but T2 has no pred; use a state-select for uniformity.
        r0, maps2, aux2 = prog(ctx, maps_state, aux)
        sel = lambda a, b: jnp.where(ok, a, b)
        maps3 = jax.tree.map(sel, maps2, maps_state)
        aux3 = jax.tree.map(sel, aux2, aux)
        return (maps3, aux3), r0

    (maps_out, aux_out), r0s = jax.lax.scan(step, (maps_state, aux),
                                            (ctxs, valid))
    return r0s, maps_out, aux_out


def run_fused_scan(entries, ctxs, maps_state, aux):
    """ONE combined lax.scan over the event tape for every scan-mode
    attachment — the fused pipeline's fallback lane (see DESIGN.md §2).

    entries: [(site_id, kind, vprog)]. Each scan step runs every program on
    the row, gated by that program's (site, kind) validity:
      * T1 (DAG) programs fold validity into the entry-block predicate, so
        invalid rows cost nothing and NO state select is emitted;
      * T2 (loop) programs run unconditionally and select — but only over
        the maps/aux fields in the program's verified touched-maps
        footprint, not the whole state tree.
    Cost: O(events) scan steps total instead of O(programs x events), with
    per-step select work O(touched_state) instead of O(total_state)."""
    compiled = [(sid, kind, vp, compile_program(vp))
                for sid, kind, vp in entries]

    def step(carry, row):
        maps_state, aux = carry
        for sid, kind, vp, prog in compiled:
            ok = (row[0] == jnp.int64(sid)) & (row[1] == jnp.int64(kind))
            if vp.tier == "dag":
                _r0, maps_state, aux = prog(row, maps_state, aux,
                                            entry_pred=ok)
            else:
                _r0, maps2, aux2 = prog(row, maps_state, aux)
                sel = lambda a, b: jnp.where(ok, a, b)     # noqa: E731
                upd = {nm: jax.tree.map(sel, maps2[nm], maps_state[nm])
                       for nm in vp.touched_map_names()}
                maps_state = {**maps_state, **upd}
                aux = {**aux, **{k: sel(aux2[k], aux[k])
                                 for k in sorted(vp.touched_aux)}}
        return (maps_state, aux), jnp.int64(0)

    (maps_out, aux_out), _ = jax.lax.scan(step, (maps_state, aux), ctxs)
    return maps_out, aux_out
