"""Reference eBPF interpreter — the "ubpf" analogue and differential-testing
oracle for the JAX JIT. Executes on python ints + numpy map states, with the
same memory model the verifier reasons about (bounds-checked at runtime here;
proven statically for the JIT).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, field

import numpy as np

from . import isa, maps as M
from .helpers import HELPERS
from .isa import (BPF_ALU, BPF_ALU64, BPF_JMP, BPF_JMP32, BPF_LDX, BPF_ST,
                  BPF_STX, CTX_BASE, Insn, OP_MASK, SIZE_BYTES, SIZE_MASK,
                  SRC_MASK, STACK_BASE, STACK_SIZE, s32, s64, u32, u64)


class VMError(RuntimeError):
    pass


@dataclass
class Aux:
    time_ns: int = 0
    cpu: int = 0
    pid: int = 0
    rand_state: int = 0x12345678
    override_set: int = 0
    override_val: int = 0
    printk: list = field(default_factory=list)


@dataclass
class VMResult:
    r0: int
    aux: Aux
    insns_executed: int


def run(insns: list[Insn], ctx: bytes, map_specs: list[M.MapSpec],
        map_states: dict, aux: Aux | None = None,
        max_insns: int = 1 << 20) -> VMResult:
    """Execute. map_states (numpy pytrees) are mutated in place."""
    aux = aux or Aux()
    slots = isa.insn_slots(insns)
    slot2idx = {s: i for i, s in enumerate(slots)}
    regs = [0] * 11
    regs[isa.R1] = CTX_BASE
    regs[isa.R10] = STACK_BASE + STACK_SIZE
    stack = bytearray(STACK_SIZE)
    executed = 0
    pc = 0  # index into insns

    def mem_read(addr: int, size: int) -> int:
        if STACK_BASE <= addr and addr + size <= STACK_BASE + STACK_SIZE:
            off = addr - STACK_BASE
            return int.from_bytes(stack[off:off + size], "little")
        if CTX_BASE <= addr and addr + size <= CTX_BASE + len(ctx):
            off = addr - CTX_BASE
            return int.from_bytes(ctx[off:off + size], "little")
        raise VMError(f"oob read @{addr:#x} size {size}")

    def mem_write(addr: int, size: int, val: int) -> None:
        if STACK_BASE <= addr and addr + size <= STACK_BASE + STACK_SIZE:
            off = addr - STACK_BASE
            stack[off:off + size] = u64(val).to_bytes(8, "little")[:size]
            return
        raise VMError(f"oob write @{addr:#x} size {size}")

    def helper_call(hid: int) -> int:
        sig = HELPERS.get(hid)
        if sig is None:
            raise VMError(f"unknown helper {hid}")
        a = [regs[i] for i in range(1, 6)]

        def key_at(ptr):
            return s64(mem_read(ptr, 8))

        def spec_state(fd):
            if not 0 <= fd < len(map_specs):
                raise VMError(f"bad map fd {fd}")
            sp = map_specs[fd]
            return sp, map_states[sp.name]

        name = sig.name
        if name == "map_lookup_elem":
            sp, st = spec_state(a[0])
            k = key_at(a[1])
            if sp.kind == M.MapKind.ARRAY:
                return u64(M.n_array_lookup(st, k))
            if sp.kind == M.MapKind.PERCPU_ARRAY:
                row = {"values": st["values"][aux.cpu % sp.num_shards]}
                return u64(M.n_array_lookup(row, k))
            return u64(M.n_hash_lookup(st, k))
        if name == "map_update_elem":
            sp, st = spec_state(a[0])
            k, v = key_at(a[1]), s64(mem_read(a[2], 8))
            if sp.kind == M.MapKind.ARRAY:
                M.n_array_update(st, k, v)
                return 0
            return 0 if M.n_hash_update(st, k, v) else u64(-7)  # E2BIG
        if name == "map_delete_elem":
            _, st = spec_state(a[0])
            return 0 if M.n_hash_delete(st, key_at(a[1])) else u64(-2)
        if name == "map_fetch_add":
            sp, st = spec_state(a[0])
            k = key_at(a[1])
            d = s64(a[2])
            if sp.kind == M.MapKind.ARRAY:
                return u64(M.n_array_fetch_add(st, k, d))
            return u64(M.n_hash_fetch_add(st, k, d))
        if name == "percpu_fetch_add":
            sp, st = spec_state(a[0])
            row = {"values": st["values"][aux.cpu % sp.num_shards]}
            return u64(M.n_array_fetch_add(row, key_at(a[1]), s64(a[2])))
        if name == "hist_add":
            _, st = spec_state(a[0])
            M.n_hist_add(st, s64(a[1]))
            return 0
        if name == "ringbuf_output":
            sp, st = spec_state(a[0])
            size = a[2]
            if size % 8 or size == 0 or size > 8 * sp.rec_width:
                raise VMError(f"bad ringbuf size {size}")
            rec = [s64(mem_read(a[1] + 8 * i, 8)) for i in range(size // 8)]
            rec += [0] * (sp.rec_width - len(rec))
            M.n_ringbuf_emit(st, rec)
            return 0
        if name == "ktime_get_ns":
            return u64(aux.time_ns)
        if name == "get_smp_processor_id":
            return u64(aux.cpu)
        if name == "get_current_pid_tgid":
            return u64(aux.pid)
        if name == "get_prandom_u32":
            # xorshift32, deterministic given aux seed (reproducible traces)
            x = aux.rand_state & 0xFFFFFFFF or 1
            x ^= (x << 13) & 0xFFFFFFFF
            x ^= x >> 17
            x ^= (x << 5) & 0xFFFFFFFF
            aux.rand_state = x
            return x
        if name == "trace_printk":
            aux.printk.append((s64(a[0]), s64(a[1])))
            return 0
        if name == "log2":
            return M.np_log2_bin(s64(a[0]))
        if name == "override_return":
            aux.override_set = 1
            aux.override_val = u64(a[0])
            return 0
        raise VMError(f"unimplemented helper {name}")

    while True:
        if pc >= len(insns):
            raise VMError("fell off end of program")
        executed += 1
        if executed > max_insns:
            raise VMError("instruction budget exceeded")
        ins = insns[pc]
        cls = ins.cls
        nxt = pc + 1

        if ins.is_lddw():
            regs[ins.dst] = u64(ins.imm64 or 0)
        elif cls in (BPF_ALU64, BPF_ALU):
            op = ins.op & OP_MASK
            is64 = cls == BPF_ALU64
            if op == isa.BPF_NEG:
                v = regs[ins.dst]
                regs[ins.dst] = u64(-s64(v)) if is64 else u32(-s32(v))
            else:
                if ins.op & SRC_MASK:
                    src = regs[ins.src]
                else:
                    src = u64(ins.imm) if is64 else u32(ins.imm)
                d = regs[ins.dst]
                if not is64:
                    d, src = u32(d), u32(src)
                regs[ins.dst] = _alu(op, d, src, is64)
        elif cls == BPF_LDX:
            size = SIZE_BYTES[ins.op & SIZE_MASK]
            regs[ins.dst] = mem_read(u64(regs[ins.src] + ins.off), size)
        elif cls == BPF_STX:
            size = SIZE_BYTES[ins.op & SIZE_MASK]
            mem_write(u64(regs[ins.dst] + ins.off), size, regs[ins.src])
        elif cls == BPF_ST:
            size = SIZE_BYTES[ins.op & SIZE_MASK]
            mem_write(u64(regs[ins.dst] + ins.off), size, u64(ins.imm))
        elif cls in (BPF_JMP, BPF_JMP32):
            op = ins.op & OP_MASK
            if op == isa.BPF_EXIT:
                return VMResult(regs[0], aux, executed)
            if op == isa.BPF_CALL:
                regs[0] = u64(helper_call(ins.imm))
                regs[1] = regs[2] = regs[3] = regs[4] = regs[5] = 0
            elif op == isa.BPF_JA:
                nxt = slot2idx[slots[pc] + 1 + ins.off]
            else:
                is64 = cls == BPF_JMP
                lhs = regs[ins.dst]
                rhs = regs[ins.src] if ins.op & SRC_MASK else u64(ins.imm)
                if not is64:
                    lhs, rhs = u32(lhs), u32(rhs)
                if _jmp_taken(op, lhs, rhs, is64):
                    nxt = slot2idx[slots[pc] + 1 + ins.off]
        else:
            raise VMError(f"bad insn class {cls:#x} at {pc}")
        pc = nxt


def _alu(op: int, d: int, s: int, is64: bool) -> int:
    mask = u64 if is64 else u32
    bits = 63 if is64 else 31
    if op == isa.BPF_ADD:
        return mask(d + s)
    if op == isa.BPF_SUB:
        return mask(d - s)
    if op == isa.BPF_MUL:
        return mask(d * s)
    if op == isa.BPF_DIV:
        return mask(d // s) if s else 0
    if op == isa.BPF_MOD:
        return mask(d % s) if s else mask(d)
    if op == isa.BPF_OR:
        return mask(d | s)
    if op == isa.BPF_AND:
        return mask(d & s)
    if op == isa.BPF_XOR:
        return mask(d ^ s)
    if op == isa.BPF_LSH:
        return mask(d << (s & bits))
    if op == isa.BPF_RSH:
        return mask(d >> (s & bits))
    if op == isa.BPF_ARSH:
        sv = s64(d) if is64 else s32(d)
        return mask(sv >> (s & bits))
    if op == isa.BPF_MOV:
        return mask(s)
    if op == isa.BPF_NEG:
        return mask(-(s64(d) if is64 else s32(d)))
    raise VMError(f"bad alu op {op:#x}")


def _jmp_taken(op: int, lhs: int, rhs: int, is64: bool) -> bool:
    sl = s64(lhs) if is64 else s32(lhs)
    sr = s64(rhs) if is64 else s32(rhs)
    if op == isa.BPF_JEQ:
        return lhs == rhs
    if op == isa.BPF_JNE:
        return lhs != rhs
    if op == isa.BPF_JGT:
        return lhs > rhs
    if op == isa.BPF_JGE:
        return lhs >= rhs
    if op == isa.BPF_JLT:
        return lhs < rhs
    if op == isa.BPF_JLE:
        return lhs <= rhs
    if op == isa.BPF_JSGT:
        return sl > sr
    if op == isa.BPF_JSGE:
        return sl >= sr
    if op == isa.BPF_JSLT:
        return sl < sr
    if op == isa.BPF_JSLE:
        return sl <= sr
    if op == isa.BPF_JSET:
        return (lhs & rhs) != 0
    raise VMError(f"bad jmp op {op:#x}")


def pack_ctx(words: list[int]) -> bytes:
    """Pack i64 words into a little-endian ctx blob (read via ldxdw [r1+8i])."""
    return b"".join(struct.pack("<q", s64(u64(w))) for w in words)
