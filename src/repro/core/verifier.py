"""Static verifier — the userspace analogue of the kernel eBPF verifier (SP1).

Abstract interpretation over the CFG with a small lattice per register:

    uninit < {scalar, const(v), ptr_stack(off), ptr_ctx(off)} < conflict

plus a per-state set of initialized stack bytes. Guarantees provided to the
JIT (which therefore needs NO runtime checks — the paper's "verify once,
run fast" property):

  * every memory access has a statically known (region, offset, size),
    in bounds, and reads only initialized bytes;
  * ctx is read-only; r10 is never written; no variable pointer arithmetic;
  * helper args are well-typed; map fds and ringbuf sizes are compile-time
    constants resolving to bound maps of the right kind;
  * r0 is set before EXIT; execution is bounded (DAG, or loops with an
    explicit fuel bound — the analogue of the kernel's 1M-insn budget);
  * no unknown opcodes / helpers; program length capped.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

from . import isa, vm
from .helpers import HELPERS
from .isa import (BPF_ALU, BPF_ALU64, BPF_JMP, BPF_JMP32, BPF_LDX, BPF_ST,
                  BPF_STX, COND_JMP_OPS, Insn, OP_MASK, SIZE_BYTES, SIZE_MASK,
                  SRC_MASK, STACK_SIZE, s64, u32, u64)
from .maps import MapKind, MapSpec

MAX_PROG_INSNS = 4096

# Monotone counters — tests assert relocation does ZERO re-verification by
# pinning verify_calls across a relocate-to-N-worlds loop. Increments are
# serialized under _STATS_LOCK so the background promotion thread and the
# fuzz harness cannot lose updates; the object stays a plain dict (tests
# assign STATS["verify_calls"] = 0 directly).
STATS = {"verify_calls": 0}
_STATS_LOCK = threading.Lock()


def reset_stats() -> None:
    """Zero all counters (harness entry points call this between runs)."""
    with _STATS_LOCK:
        for k in STATS:
            STATS[k] = 0


class VerifierError(ValueError):
    pass


# ---------------------------------------------------------------- reg lattice
UNINIT, SCALAR, CONST, PTR_STACK, PTR_CTX, CONFLICT = range(6)
# Abstract map reference (the kernel's CONST_PTR_TO_MAP analogue): produced
# only by `lddw rX, map:NAME` in abstract mode, val = object-local map index.
# It may be mov-copied and passed as a helper mapfd arg — nothing else — so
# relocation can rebind names to concrete fds knowing every mapfd a helper
# sees is provenance-tracked (a forged scalar fd cannot sneak past rebinding).
MAPVAL = 6
_KIND_NAMES = {UNINIT: "uninit", SCALAR: "scalar", CONST: "const",
               PTR_STACK: "ptr_stack", PTR_CTX: "ptr_ctx",
               CONFLICT: "conflict", MAPVAL: "mapval"}


@dataclass(frozen=True)
class Reg:
    kind: int = UNINIT
    val: int = 0  # const value (u64) or pointer offset from region base

    def __repr__(self):
        return f"{_KIND_NAMES[self.kind]}({self.val})"


def _merge_reg(a: Reg, b: Reg) -> Reg:
    if a == b:
        return a
    if UNINIT in (a.kind, b.kind):
        return Reg(UNINIT)
    ka, kb = a.kind, b.kind
    if {ka, kb} <= {SCALAR, CONST}:
        return Reg(SCALAR)
    if ka == kb and ka in (PTR_STACK, PTR_CTX):
        return Reg(CONFLICT)  # same region, different offset
    return Reg(CONFLICT)


@dataclass(frozen=True)
class AbsState:
    regs: tuple[Reg, ...]
    stack_init: frozenset[int]
    # statically-known stack words: (byte_off, u64_value) for every aligned
    # 8-byte slot last written with a compile-time constant on ALL paths.
    # Merge is set intersection; any overlapping store invalidates. This is
    # what lets a helper's key pointer resolve to a STATIC key value — the
    # raw material of the effect-footprint lattice (DESIGN.md §14).
    stack_const: frozenset[tuple[int, int]] = frozenset()

    def with_reg(self, i: int, r: Reg) -> "AbsState":
        rs = list(self.regs)
        rs[i] = r
        return AbsState(tuple(rs), self.stack_init, self.stack_const)


def _merge_state(a: AbsState, b: AbsState) -> AbsState:
    return AbsState(tuple(_merge_reg(x, y) for x, y in zip(a.regs, b.regs)),
                    a.stack_init & b.stack_init,
                    a.stack_const & b.stack_const)


# ---------------------------------------------------------------- annotations
@dataclass
class MemAnn:
    region: str     # 'stack' | 'ctx'
    off: int        # byte offset from region base
    size: int
    # verifier-proven natural 8-byte alignment: the JIT's word-oriented
    # stack lowers these to a single word load/store (no shifts/masks).
    aligned: bool = False


@dataclass
class CallAnn:
    hid: int
    name: str
    # per-arg resolved statics: for mapfd -> fd int; kptr -> stack off;
    # cscalar -> value; scalar -> None
    statics: list
    # per-arg statically-known POINTEE values: for a kptr arg whose stack
    # word holds a path-invariant constant, the s64 value; None elsewhere.
    # Layout-independent (stack contents), so relocation carries it over.
    key_vals: list | None = None


# helpers whose map side effects commute across programs/events (order-free);
# the single source of truth for runtime._COMMUTATIVE_HELPERS and
# table_interp._BATCH_EFFECT.
COMMUTATIVE_HELPERS = frozenset(
    {"map_fetch_add", "percpu_fetch_add", "hist_add"})

# which helper arg (0-based) is the MAP KEY pointer, for key-addressed ops
_KEY_ARG = {"map_lookup_elem": 1, "map_update_elem": 1, "map_delete_elem": 1,
            "map_fetch_add": 1, "percpu_fetch_add": 1}


@dataclass(frozen=True)
class MapFootprint:
    """Per-map effect footprint — what the program can do to one map.

    ``ops`` are the helper names touching it; ``commutative_only`` means
    every touch is in COMMUTATIVE_HELPERS (order across programs is
    unobservable in the map's final state); ``static_keys`` is the exact
    set of s64 key values the program can address when EVERY key-addressed
    touch resolved to a stack constant, else None (some key is dynamic).
    The widening rules in runtime._has_ordering_conflict and
    table_interp._recompute_vec PROVE commutativity from these instead of
    assuming conflict (DESIGN.md §14)."""
    fd: int
    name: str
    kind: MapKind
    max_entries: int
    ops: frozenset[str]
    commutative_only: bool
    static_keys: frozenset[int] | None


def compute_footprints(anns: dict, map_specs) -> dict[int, MapFootprint]:
    """Derive per-map footprints from the CallAnns of a verified program.
    Shared by verify() and reloc.resolve() (which rebinds fds and must
    recompute against the concrete registry)."""
    touches: dict[int, dict] = {}
    for ann in anns.values():
        if not isinstance(ann, CallAnn):
            continue
        sig = HELPERS[ann.hid]
        for i, kind in enumerate(sig.args):
            if kind != "mapfd":
                continue
            fd = ann.statics[i]
            t = touches.setdefault(
                fd, {"ops": set(), "comm": True, "keys": set(),
                     "static": True})
            t["ops"].add(sig.name)
            t["comm"] = t["comm"] and sig.name in COMMUTATIVE_HELPERS
            ka = _KEY_ARG.get(sig.name)
            kv = (ann.key_vals[ka] if ka is not None
                  and ann.key_vals is not None else None)
            if kv is None:
                t["static"] = False      # non-keyed op or dynamic key
            else:
                t["keys"].add(kv)
    return {fd: MapFootprint(
        fd=fd, name=map_specs[fd].name, kind=map_specs[fd].kind,
        max_entries=map_specs[fd].max_entries, ops=frozenset(t["ops"]),
        commutative_only=t["comm"],
        static_keys=frozenset(t["keys"]) if t["static"] else None)
        for fd, t in touches.items()}


# map kinds whose storage is positional (cell = key), so the layout never
# depends on op order — the precondition of widening rule 1 (HASH is
# excluded: inserts shape the physical probe-chain layout)
_POSITIONAL_KINDS = (MapKind.ARRAY, MapKind.PERCPU_ARRAY)


def footprints_disjoint(fa: MapFootprint | None,
                        fb: MapFootprint | None) -> bool:
    """Widening rule 1 (DESIGN.md §14): two programs sharing one map
    non-commutatively still cannot observe each other's order when the map
    is positional (ARRAY / PERCPU_ARRAY), both key sets are fully static
    and in bounds, and the sets are disjoint — each program's reads and
    writes are confined to its own cells, and every execution lane
    preserves each program's own op order. Certified by the fuzz harness
    (tests/test_widening.py)."""
    if fa is None or fb is None:
        return False
    if fa.kind not in _POSITIONAL_KINDS:
        return False
    if fa.static_keys is None or fb.static_keys is None:
        return False
    n = fa.max_entries
    if any(not 0 <= k < n for k in fa.static_keys | fb.static_keys):
        return False        # out-of-bounds keys clamp/no-op: don't reason
    return not (fa.static_keys & fb.static_keys)


@dataclass
class Block:
    start: int
    end: int                      # exclusive, insn indices
    succ: list[int] = field(default_factory=list)   # successor block ids
    # terminator kind: 'cond' (succ=[taken, fall]), 'ja', 'exit', 'fall'
    term: str = "fall"


@dataclass
class VerifiedProgram:
    insns: list[Insn]
    map_specs: list[MapSpec]
    ctx_words: int
    anns: dict[int, object]       # insn idx -> MemAnn | CallAnn
    blocks: list[Block]
    block_of: dict[int, int]      # leader insn idx -> block id
    tier: str                     # 'dag' | 'loop'
    max_insns: int
    helper_ids_used: set[int] = field(default_factory=set)
    # static side-effect footprint (the touched-maps analysis): which map
    # fds this program can write/read through helpers, and which aux fields
    # it can write. The fused runtime pipeline gates per-event state selects
    # to exactly this footprint instead of selecting over ALL map state.
    touched_map_fds: frozenset = frozenset()
    touched_aux: frozenset = frozenset()
    # fd -> MapFootprint (the effect-footprint lattice, DESIGN.md §14):
    # proven per-map op sets, commutativity, and static key ranges. The
    # fused/batched schedulers widen their ordering guards from these.
    footprints: dict = field(default_factory=dict)
    # relocation record (reloc.RelocRecord) when verified in abstract mode:
    # insn index -> symbolic ref, plus the layouts verified against. None
    # for layout-concrete programs. An abstract program is NOT runnable —
    # core/reloc.resolve() binds it to a concrete world first.
    reloc: object = None

    @property
    def is_abstract(self) -> bool:
        return self.reloc is not None and not getattr(
            self.reloc, "resolved", False)

    def touched_map_names(self) -> tuple[str, ...]:
        return tuple(self.map_specs[fd].name
                     for fd in sorted(self.touched_map_fds))

    def footprint_of(self, name: str) -> MapFootprint | None:
        for fp in self.footprints.values():
            if fp.name == name:
                return fp
        return None


def verify(insns: list[Insn], map_specs: list[MapSpec], ctx_words: int = 16,
           max_insns: int = 65536, *, map_refs: dict[int, str] | None = None,
           ctx_refs: dict[int, str] | None = None,
           ctx_layout=None) -> VerifiedProgram:
    """Verify a program against a world of maps + ctx layout.

    Concrete mode (default): `map_specs` is the runtime's registry in fd
    order; lddw imm64s are already-patched fds. Abstract mode (any of
    `map_refs`/`ctx_refs`/`ctx_layout` given): `map_specs` is the
    program's DECLARED map list (object-local order), `map_refs` names
    the `lddw rX, map:NAME` insns and `ctx_refs` the insns whose off
    came from a `ctx:FIELD` substitution against `ctx_layout`. The
    result carries a relocation record and binds to any concrete
    registry via core/reloc.resolve() — verify once, relocate anywhere.
    """
    with _STATS_LOCK:
        STATS["verify_calls"] += 1
    abstract = (map_refs is not None or ctx_refs is not None
                or ctx_layout is not None)
    if not insns:
        raise VerifierError("empty program")
    if len(insns) > MAX_PROG_INSNS:
        raise VerifierError(f"program too long ({len(insns)} insns)")
    if ctx_words * 8 > isa.MAX_CTX_BYTES:
        raise VerifierError("ctx too large")
    ctx_bytes = ctx_words * 8

    if ctx_refs and ctx_layout is None:
        raise VerifierError("ctx_refs given without the ctx_layout they "
                            "were assembled against")
    # symbolic map refs -> object-local indices, validated up front
    map_local_of: dict[int, int] = {}
    if map_refs:
        name_to_local = {s.name: i for i, s in enumerate(map_specs)}
        for idx, mname in map_refs.items():
            if not 0 <= idx < len(insns) or not insns[idx].is_lddw():
                raise VerifierError(
                    f"map reloc at insn {idx} is not an lddw")
            if mname not in name_to_local:
                raise VerifierError(
                    f"insn {idx}: reference to undeclared map {mname!r}")
            map_local_of[idx] = name_to_local[mname]

    slots = isa.insn_slots(insns)
    slot2idx = {s: i for i, s in enumerate(slots)}

    def jump_target(pc: int) -> int:
        tgt_slot = slots[pc] + 1 + insns[pc].off
        if tgt_slot not in slot2idx:
            raise VerifierError(f"insn {pc}: jump to invalid slot {tgt_slot}")
        return slot2idx[tgt_slot]

    # ---------------- successor graph on insn indices
    succs: dict[int, list[int]] = {}
    for pc, ins in enumerate(insns):
        cls = ins.cls
        if cls in (BPF_JMP, BPF_JMP32):
            op = ins.op & OP_MASK
            if op == isa.BPF_EXIT:
                succs[pc] = []
                continue
            if op == isa.BPF_JA:
                succs[pc] = [jump_target(pc)]
                continue
            if op in COND_JMP_OPS:
                fall = pc + 1
                if fall >= len(insns):
                    raise VerifierError(f"insn {pc}: cond jump falls off end")
                succs[pc] = [jump_target(pc), fall]
                continue
        if pc + 1 >= len(insns):
            raise VerifierError(f"insn {pc}: program falls off end")
        succs[pc] = [pc + 1]

    # ---------------- abstract interpretation (worklist to fixpoint)
    entry_regs = [Reg(UNINIT)] * 11
    entry_regs[isa.R1] = Reg(PTR_CTX, 0)
    entry_regs[isa.R10] = Reg(PTR_STACK, STACK_SIZE)
    entry = AbsState(tuple(entry_regs), frozenset())

    in_states: dict[int, AbsState] = {0: entry}
    work = [0]
    anns: dict[int, object] = {}
    helper_ids_used: set[int] = set()
    iters = 0
    while work:
        iters += 1
        if iters > 200_000:
            raise VerifierError("verifier fixpoint did not converge")
        pc = work.pop()
        out = _transfer(pc, insns[pc], in_states[pc], map_specs, ctx_bytes,
                        anns, helper_ids_used, map_local_of, abstract)
        for s in succs[pc]:
            merged = out if s not in in_states else _merge_state(in_states[s], out)
            if s not in in_states or merged != in_states[s]:
                in_states[s] = merged
                work.append(s)

    reachable = set(in_states)

    # ---------------- blocks
    leaders = {0}
    for pc in reachable:
        ins = insns[pc]
        cls = ins.cls
        if cls in (BPF_JMP, BPF_JMP32):
            op = ins.op & OP_MASK
            if op in COND_JMP_OPS or op == isa.BPF_JA:
                for s in succs[pc]:
                    leaders.add(s)
                if pc + 1 < len(insns):
                    leaders.add(pc + 1)
            elif op == isa.BPF_EXIT and pc + 1 < len(insns):
                leaders.add(pc + 1)
    leaders = sorted(x for x in leaders if x in reachable)
    block_of: dict[int, int] = {l: i for i, l in enumerate(leaders)}
    blocks: list[Block] = []
    for bi, start in enumerate(leaders):
        end = start
        while True:
            ins = insns[end]
            cls = ins.cls
            is_term = (cls in (BPF_JMP, BPF_JMP32) and
                       (ins.op & OP_MASK) in
                       (*COND_JMP_OPS, isa.BPF_JA, isa.BPF_EXIT))
            nxt = end + 1
            if is_term or (nxt < len(insns) and nxt in block_of) or nxt >= len(insns):
                break
            end = nxt
        blk = Block(start=start, end=end + 1)
        op = insns[end].op
        cls = insns[end].cls
        jop = op & OP_MASK
        if cls in (BPF_JMP, BPF_JMP32) and jop == isa.BPF_EXIT:
            blk.term = "exit"
        elif cls in (BPF_JMP, BPF_JMP32) and jop == isa.BPF_JA:
            blk.term = "ja"
            blk.succ = [block_of[succs[end][0]]]
        elif cls in (BPF_JMP, BPF_JMP32) and jop in COND_JMP_OPS:
            blk.term = "cond"
            blk.succ = [block_of[s] for s in succs[end]]
        else:
            blk.term = "fall"
            blk.succ = [block_of[end + 1]]
        blocks.append(blk)

    # ---------------- loop detection (back edges on block graph)
    tier = "dag"
    color = {}

    def dfs(b: int) -> bool:
        color[b] = 1
        for s in blocks[b].succ:
            if color.get(s, 0) == 1:
                return True
            if color.get(s, 0) == 0 and dfs(s):
                return True
        color[b] = 2
        return False

    if dfs(0):
        tier = "loop"

    # ---------------- touched-maps / touched-aux footprint
    from .helpers import AUX_WRITES
    touched_fds: set[int] = set()
    touched_aux: set[str] = set()
    for ann in anns.values():
        if not isinstance(ann, CallAnn):
            continue
        sig = HELPERS[ann.hid]
        for i, kind in enumerate(sig.args):
            if kind == "mapfd":
                touched_fds.add(ann.statics[i])
        touched_aux.update(AUX_WRITES.get(ann.name, ()))

    # ---------------- relocation record (abstract mode)
    record = None
    if abstract:
        live_ctx_refs: dict[int, str] = {}
        for idx, fld in sorted((ctx_refs or {}).items()):
            if idx not in reachable:
                continue  # dead code never executes; leave it un-relocated
            ann = anns.get(idx)
            if not (isinstance(ann, MemAnn) and ann.region == "ctx"):
                raise VerifierError(
                    f"insn {idx}: ctx:{fld} reference is not a direct ctx "
                    f"load — indirect ctx offsets are not relocatable")
            live_ctx_refs[idx] = fld
        from .layout import MapLayout  # late: layout never imports verifier
        from .reloc import RelocRecord
        record = RelocRecord(
            map_layouts=tuple(MapLayout.from_spec(s) for s in map_specs),
            map_lddw=dict(map_local_of),
            ctx_refs=live_ctx_refs,
            ctx_layout=ctx_layout)

    return VerifiedProgram(insns=insns, map_specs=list(map_specs),
                           ctx_words=ctx_words, anns=anns, blocks=blocks,
                           block_of=block_of, tier=tier, max_insns=max_insns,
                           helper_ids_used=helper_ids_used,
                           touched_map_fds=frozenset(touched_fds),
                           touched_aux=frozenset(touched_aux),
                           footprints=compute_footprints(anns, map_specs),
                           reloc=record)


def check_table_encodable(vprog: VerifiedProgram, n_maps: int,
                          max_insns: int, ctx_words: int) -> None:
    """Gate for hot-attaching into a live program table (table_interp.py).

    The table interpreter is compiled ONCE against a fixed universe — the
    padded insn dimension, the event-row width, and the map registry as of
    interpreter compile time. A verified program may still be impossible to
    attach without a retrace; this raises VerifierError for each such case
    so the control plane can reject the request cleanly (generation counter
    untouched)."""
    if len(vprog.insns) > max_insns:
        raise VerifierError(
            f"program has {len(vprog.insns)} insns, live table is padded to "
            f"{max_insns} — recompile the step with a larger table")
    if vprog.ctx_words > ctx_words:
        raise VerifierError(
            f"program reads {vprog.ctx_words} ctx words, live table rows "
            f"carry {ctx_words}")
    for ann in vprog.anns.values():
        if isinstance(ann, CallAnn):
            sig = HELPERS[ann.hid]
            for i, kind in enumerate(sig.args):
                if kind == "mapfd" and ann.statics[i] >= n_maps:
                    raise VerifierError(
                        f"program touches map fd {ann.statics[i]} "
                        f"({vprog.map_specs[ann.statics[i]].name!r}) created "
                        f"after the live table was compiled "
                        f"(knows fds 0..{n_maps - 1})")


# ---------------------------------------------------------------- transfer fn

def _require_init(st: AbsState, r: int, pc: int, what: str) -> Reg:
    reg = st.regs[r]
    if reg.kind == UNINIT:
        raise VerifierError(f"insn {pc}: {what} reads uninitialized r{r}")
    if reg.kind == CONFLICT:
        raise VerifierError(f"insn {pc}: {what} reads r{r} with conflicting "
                            "types across paths")
    return reg


def _check_stack_access(st: AbsState, base: Reg, off: int, size: int,
                        pc: int, write: bool) -> int:
    lo = base.val + off
    if lo < 0 or lo + size > STACK_SIZE:
        raise VerifierError(f"insn {pc}: stack access [{lo},{lo + size}) "
                            "out of bounds")
    if not write:
        missing = [b for b in range(lo, lo + size) if b not in st.stack_init]
        if missing:
            raise VerifierError(f"insn {pc}: read of uninitialized stack "
                                f"byte(s) {missing[:4]}")
    return lo


def _transfer(pc: int, ins: Insn, st: AbsState, map_specs, ctx_bytes: int,
              anns: dict, helper_ids_used: set,
              map_local_of: dict[int, int] | None = None,
              abstract: bool = False) -> AbsState:
    cls = ins.cls

    if ins.is_lddw():
        if map_local_of and pc in map_local_of:
            return st.with_reg(ins.dst, Reg(MAPVAL, map_local_of[pc]))
        return st.with_reg(ins.dst, Reg(CONST, u64(ins.imm64 or 0)))

    if cls in (BPF_ALU64, BPF_ALU):
        if ins.dst == isa.R10:
            raise VerifierError(f"insn {pc}: write to frame pointer r10")
        op = ins.op & OP_MASK
        is64 = cls == BPF_ALU64
        if op == isa.BPF_NEG:
            d = _require_init(st, ins.dst, pc, "neg")
            if d.kind in (PTR_STACK, PTR_CTX, MAPVAL):
                raise VerifierError(f"insn {pc}: arithmetic on pointer")
            if d.kind == CONST:
                return st.with_reg(ins.dst, Reg(CONST, vm._alu(op, d.val, 0, is64)))
            return st.with_reg(ins.dst, Reg(SCALAR))

        if ins.op & SRC_MASK:
            s = _require_init(st, ins.src, pc, "alu")
        else:
            s = Reg(CONST, u64(ins.imm) if is64 else u32(ins.imm))

        if op == isa.BPF_MOV:
            if not is64 and s.kind in (PTR_STACK, PTR_CTX, MAPVAL):
                return st.with_reg(ins.dst, Reg(SCALAR))  # truncation kills ptr
            if not is64 and s.kind == CONST:
                return st.with_reg(ins.dst, Reg(CONST, u32(s.val)))
            return st.with_reg(ins.dst, s)

        d = _require_init(st, ins.dst, pc, "alu")
        if MAPVAL in (d.kind, s.kind):
            raise VerifierError(f"insn {pc}: arithmetic on map reference")
        d_ptr = d.kind in (PTR_STACK, PTR_CTX)
        s_ptr = s.kind in (PTR_STACK, PTR_CTX)
        if d_ptr or s_ptr:
            if not is64:
                raise VerifierError(f"insn {pc}: 32-bit arithmetic on pointer")
            if op not in (isa.BPF_ADD, isa.BPF_SUB):
                raise VerifierError(f"insn {pc}: op {op:#x} on pointer")
            if d_ptr and s_ptr:
                raise VerifierError(f"insn {pc}: pointer +/- pointer")
            if d_ptr:
                if s.kind != CONST:
                    raise VerifierError(f"insn {pc}: variable pointer "
                                        "arithmetic (offset not constant)")
                delta = s64(s.val)
                newoff = d.val + (delta if op == isa.BPF_ADD else -delta)
                return st.with_reg(ins.dst, Reg(d.kind, newoff))
            # scalar + ptr (ADD only)
            if op != isa.BPF_ADD or d.kind != CONST:
                raise VerifierError(f"insn {pc}: unsupported pointer form")
            return st.with_reg(ins.dst, Reg(s.kind, s.val + s64(d.val)))

        if d.kind == CONST and s.kind == CONST:
            dv = d.val if is64 else u32(d.val)
            sv = s.val if is64 else u32(s.val)
            return st.with_reg(ins.dst, Reg(CONST, vm._alu(op, dv, sv, is64)))
        return st.with_reg(ins.dst, Reg(SCALAR))

    if cls == BPF_LDX:
        base = _require_init(st, ins.src, pc, "load")
        size = SIZE_BYTES[ins.op & SIZE_MASK]
        if base.kind == PTR_STACK:
            lo = _check_stack_access(st, base, ins.off, size, pc, write=False)
            anns[pc] = MemAnn("stack", lo, size,
                              aligned=(lo % 8 == 0 and size == 8))
        elif base.kind == PTR_CTX:
            lo = base.val + ins.off
            if lo < 0 or lo + size > ctx_bytes:
                raise VerifierError(f"insn {pc}: ctx read [{lo},{lo + size}) "
                                    f"out of bounds (ctx={ctx_bytes}B)")
            if lo % size:
                raise VerifierError(f"insn {pc}: unaligned ctx read at {lo} "
                                    f"(size {size})")
            anns[pc] = MemAnn("ctx", lo, size,
                              aligned=(lo % 8 == 0 and size == 8))
        else:
            raise VerifierError(f"insn {pc}: load via non-pointer r{ins.src}")
        return st.with_reg(ins.dst, Reg(SCALAR))

    if cls in (BPF_STX, BPF_ST):
        base = _require_init(st, ins.dst, pc, "store")
        size = SIZE_BYTES[ins.op & SIZE_MASK]
        if base.kind == PTR_CTX:
            raise VerifierError(f"insn {pc}: store to read-only ctx")
        if base.kind != PTR_STACK:
            raise VerifierError(f"insn {pc}: store via non-pointer r{ins.dst}")
        v = None
        if cls == BPF_STX:
            v = _require_init(st, ins.src, pc, "store value")
            if v.kind in (PTR_STACK, PTR_CTX, MAPVAL):
                raise VerifierError(f"insn {pc}: spilling pointers to stack "
                                    "is not supported")
        lo = _check_stack_access(st, base, ins.off, size, pc, write=True)
        anns[pc] = MemAnn("stack", lo, size,
                          aligned=(lo % 8 == 0 and size == 8))
        # stack-constant tracking: any overlapping store invalidates; a
        # fresh aligned 8-byte constant store (re)establishes the slot
        sc = frozenset(e for e in st.stack_const
                       if not (lo < e[0] + 8 and e[0] < lo + size))
        if size == 8 and lo % 8 == 0:
            if cls == BPF_ST:
                sc = sc | {(lo, u64(ins.imm))}
            elif v is not None and v.kind == CONST:
                sc = sc | {(lo, u64(v.val))}
        return AbsState(st.regs,
                        st.stack_init | frozenset(range(lo, lo + size)), sc)

    if cls in (BPF_JMP, BPF_JMP32):
        op = ins.op & OP_MASK
        if op == isa.BPF_EXIT:
            r0 = _require_init(st, isa.R0, pc, "exit")
            if r0.kind == MAPVAL:
                raise VerifierError(f"insn {pc}: returning a map reference "
                                    "(its concrete value is layout-dependent)")
            return st
        if op == isa.BPF_JA:
            return st
        if op == isa.BPF_CALL:
            return _transfer_call(pc, ins, st, map_specs, anns,
                                  helper_ids_used, abstract)
        # conditional jump
        d = _require_init(st, ins.dst, pc, "jump")
        if d.kind in (PTR_STACK, PTR_CTX, MAPVAL):
            raise VerifierError(f"insn {pc}: comparison on pointer")
        if ins.op & SRC_MASK:
            s = _require_init(st, ins.src, pc, "jump")
            if s.kind in (PTR_STACK, PTR_CTX, MAPVAL):
                raise VerifierError(f"insn {pc}: comparison on pointer")
        return st

    raise VerifierError(f"insn {pc}: unknown opcode {ins.op:#x}")


def _transfer_call(pc: int, ins: Insn, st: AbsState, map_specs, anns,
                   helper_ids_used, abstract: bool = False) -> AbsState:
    sig = HELPERS.get(ins.imm)
    if sig is None:
        raise VerifierError(f"insn {pc}: unknown helper {ins.imm}")
    helper_ids_used.add(ins.imm)
    statics: list = []
    for i, kind in enumerate(sig.args):
        r = 1 + i
        reg = _require_init(st, r, pc, f"call {sig.name} arg{i + 1}")
        if kind == "mapfd":
            if reg.kind == MAPVAL:
                fd = reg.val
            elif reg.kind == CONST and not abstract:
                fd = s64(reg.val)
            else:
                # abstract mode refuses scalar-forged fds: positional rebinding
                # at relocation time must never silently retarget them
                raise VerifierError(
                    f"insn {pc}: {sig.name} arg{i + 1} map fd must be "
                    + ("a symbolic map reference (lddw rX, map:NAME)"
                       if abstract else "a compile-time constant"))
            if not 0 <= fd < len(map_specs):
                raise VerifierError(f"insn {pc}: map fd {fd} out of range")
            if sig.map_kinds and map_specs[fd].kind not in sig.map_kinds:
                raise VerifierError(
                    f"insn {pc}: {sig.name} on map of kind "
                    f"{map_specs[fd].kind.value} not allowed")
            statics.append(fd)
        elif kind == "kptr":
            if reg.kind != PTR_STACK:
                raise VerifierError(f"insn {pc}: {sig.name} arg{i + 1} must "
                                    "be a stack pointer")
            nbytes = 8
            if sig.name == "ringbuf_output":
                # size checked below once cscalar seen; defer with off only
                pass
            lo = _check_stack_access(st, reg, 0, nbytes, pc, write=False)
            statics.append(lo)
        elif kind == "cscalar":
            if reg.kind != CONST:
                raise VerifierError(f"insn {pc}: {sig.name} arg{i + 1} must "
                                    "be a compile-time constant")
            statics.append(s64(reg.val))
        else:  # scalar
            if reg.kind in (PTR_STACK, PTR_CTX, MAPVAL):
                raise VerifierError(f"insn {pc}: {sig.name} arg{i + 1} must "
                                    "be a scalar, not a pointer")
            statics.append(None)

    if sig.name == "ringbuf_output":
        fd, data_off, size = statics[0], statics[1], statics[2]
        spec = map_specs[fd]
        if size <= 0 or size % 8 or size > 8 * spec.rec_width:
            raise VerifierError(f"insn {pc}: ringbuf_output size {size} "
                                f"invalid for rec_width {spec.rec_width}")
        for b in range(data_off, data_off + size):
            if b not in st.stack_init:
                raise VerifierError(f"insn {pc}: ringbuf_output reads "
                                    f"uninitialized stack byte {b}")

    # statically-known pointee values for kptr args (footprint static keys)
    consts = dict(st.stack_const)
    key_vals: list = [None] * len(sig.args)
    for i, kind in enumerate(sig.args):
        if kind == "kptr" and statics[i] % 8 == 0 and statics[i] in consts:
            key_vals[i] = s64(consts[statics[i]])

    anns[pc] = CallAnn(hid=ins.imm, name=sig.name, statics=statics,
                       key_vals=key_vals)
    rs = list(st.regs)
    rs[0] = Reg(SCALAR)
    for r in range(1, 6):
        rs[r] = Reg(UNINIT)
    return AbsState(tuple(rs), st.stack_init, st.stack_const)
