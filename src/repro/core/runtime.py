"""BpftimeRuntime — the runtime manager (bpftime's agent + syscall-compat
library rolled into one).

Responsibilities:
  * global map registry (create/bind by name — objects share maps by name);
  * program load: relocate (CO-RE-lite) -> verify -> store;
  * attachments:
      device:  uprobe:SITE / uretprobe:SITE / probe:SITE   (in-graph)
      host:    tracepoint:SYS:enter|exit / filter:SYS      (interpreter)
  * the per-step probe-execution stage (compiled into the train/serve step);
  * attach/detach WITHOUT restart: every device change bumps `attach_epoch`;
    the training loop re-jits its step on epoch change and carries state
    over — the ptrace-pause analogue;
  * attach/detach WITHOUT RECOMPILATION: the live program-table lane
    (`enable_live_attach` + `attach(mode="table")`) encodes verified
    bytecode into a device-resident table read by a generic in-graph
    interpreter — dispatch is data, so a hot attach is a buffer write, not
    a retrace (DESIGN.md §9, §12; `attach_live`/`detach_live` remain as
    deprecated shims);
  * ONE attach API over all of it: `attach(pid, target, *, mode, promote)`
    returns a `Link` (lane + slot + promotion state); `mode="auto"` routes
    to the table lane when the program can land on the running step, and
    `promote=True` arms background promotion — `core/promote.py` retraces
    the fused lane off the critical path and `sync_live_table` swaps it in
    at the next generation boundary, bit-identical (DESIGN.md §12);
  * shm control plane: publish device maps, poll daemon attach requests.
"""
from __future__ import annotations

import contextlib
import itertools
import threading
import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import events as E, jit as J, loader, maps as M, syscalls as S, vm
from .helpers import HELPERS
from .loader import ProgramObject
from .maps import MapSpec
from .verifier import (CallAnn, COMMUTATIVE_HELPERS as _COMMUTATIVE_HELPERS,
                       VerifiedProgram, footprints_disjoint, verify)

_AUX_RESOURCES = {"trace_printk": "printk", "override_return": "override",
                  "get_prandom_u32": "rand"}

# observability: how often the footprint proofs fired (fuzz/bench reports)
WIDEN_STATS = {"fused_disjoint_pairs": 0}


def _ordering_resources(vprog: VerifiedProgram) -> dict:
    """{resource: commutative?} for one program. Two DIFFERENT programs may
    be scheduled on different fused lanes (or reordered within one) only if
    every resource they share is touched commutatively by both; otherwise
    the fused pipeline must keep the seed scan ordering (see DESIGN.md §2).
    """
    out: dict = {}
    for ann in vprog.anns.values():
        if not isinstance(ann, CallAnn):
            continue
        sig = HELPERS[ann.hid]
        comm = sig.name in _COMMUTATIVE_HELPERS
        for i, kind in enumerate(sig.args):
            if kind == "mapfd":
                key = ("map", vprog.map_specs[ann.statics[i]].name)
                out[key] = out.get(key, True) and comm
        if sig.name in _AUX_RESOURCES:
            out[("aux", _AUX_RESOURCES[sig.name])] = False
    return out


def _has_ordering_conflict(vprogs: list) -> bool:
    """True iff any resource is shared non-commutatively across two
    distinct programs (same program attached to several sites is fine —
    its per-attachment order is preserved by the fused scheduler) AND the
    verifier's effect footprints cannot prove the sharing unobservable
    (disjoint static cells on a positional map — widening rule 1)."""
    res = [_ordering_resources(vp) for vp in vprogs]
    for i in range(len(res)):
        for j in range(i + 1, len(res)):
            for key, comm_i in res[i].items():
                if key not in res[j] or (comm_i and res[j][key]):
                    continue
                if key[0] == "map" and footprints_disjoint(
                        vprogs[i].footprint_of(key[1]),
                        vprogs[j].footprint_of(key[1])):
                    WIDEN_STATS["fused_disjoint_pairs"] += 1
                    continue
                return True
    return False


@dataclass
class LoadedProg:
    pid: int
    name: str
    prog_type: str
    insns: list
    vprog: VerifiedProgram
    # the abstract (pre-relocation) verification, when the program came in
    # through the CO-RE path — re-bindable to other worlds without
    # re-verification (None for programs verified concretely)
    vabs: VerifiedProgram | None = None


@dataclass(eq=False)
class Link:
    """Handle for one attachment, whatever lane it executes on.

    ``lane`` is where the program runs right now: ``"fused"`` (traced into
    the step), ``"table"`` (live program-table interpreter) or ``"host"``
    (syscall tracepoints/filters).  A table link carries its ``slot`` and a
    ``promotion_state`` driven by core/promote.py:
    ``interp -> compiling -> ready -> fused`` (or ``cancelled``/``failed``).
    The handle coerces to its integer link id (``int(link)``), so it can be
    stored, serialized, and passed back to ``Runtime.detach``.
    """
    link_id: int
    pid: int
    target: str
    lane: str = "fused"
    slot: int | None = None
    promotion_state: str = "none"
    promote: bool = False
    promotion_error: str | None = None
    _parsed: tuple | None = field(default=None, repr=False)
    _rt: object = field(default=None, repr=False)

    def detach(self) -> None:
        self._rt.detach(self)

    def __int__(self) -> int:
        return self.link_id

    def __index__(self) -> int:
        return self.link_id


class BpftimeRuntime:
    def __init__(self, pid: int = 0):
        self.map_specs: list[MapSpec] = []
        self.fd_of: dict[str, int] = {}
        self.progs: dict[int, LoadedProg] = {}
        self._next_pid = itertools.count(1)
        self._next_link = itertools.count(1)
        self.links: dict[int, Link] = {}
        # device attachments: (site_id, kind) -> [pid]
        self.device_attach: dict[tuple[int, int], list[int]] = {}
        self.attach_epoch = 0
        # host side
        self.host_maps: dict = {}
        self.syscalls = S.SyscallTable(self.host_maps, self.map_specs,
                                       pid=pid)
        self.shm = None
        self._req_cursor = 0
        self._objects: dict[str, str] = {}   # name -> serialized object
        # 'fused' (default): single-pass multi-program dispatch;
        # 'scan' / 'vectorized': the per-attachment seed paths.
        self.exec_mode = "fused"
        # live program-table lane (enable_live_attach)
        self.live = None
        self._armed: set[tuple[int, int]] = set()
        self._live_slot_of: dict[int, int] = {}   # link_id -> table slot
        self._table_writer = None
        self._synced_gen = 0                      # last gen pushed to device
        # background promotion (enable_promotion / core/promote.py)
        self._promoter = None
        self._promoted_step = None    # AOT-compiled step awaiting pickup
        self._overlay_tls = threading.local()
        # fleet-wide AOT artifact cache (enable_artifact_cache /
        # core/artifact_cache.py); setup_shm auto-joins <root>/cache
        self.artifact_cache = None

    # ---------------------------------------------------------------- maps
    def create_map(self, spec: MapSpec) -> int:
        if spec.name in self.fd_of:
            old = self.map_specs[self.fd_of[spec.name]]
            if (old.kind, old.max_entries, old.rec_width, old.num_shards) != \
               (spec.kind, spec.max_entries, spec.rec_width, spec.num_shards):
                raise loader.LoadError(
                    f"map {spec.name!r} redeclared with incompatible spec")
            return self.fd_of[spec.name]
        fd = len(self.map_specs)
        self.map_specs.append(spec)
        self.fd_of[spec.name] = fd
        self.host_maps[spec.name] = M.init_state(spec, np)
        return fd

    def init_device_maps(self) -> dict:
        st = M.init_states(self.map_specs, jnp)
        if self.live is not None:
            st["__live_table__"] = self.live.device_state()
        return st

    # ---------------------------------------------------------------- load
    def load_object(self, obj: ProgramObject) -> int:
        """Verify ONCE against the object's own declared layout (abstract
        mode), then bind to this runtime's registry by relocation — the
        CO-RE pipeline.  The abstract VerifiedProgram is kept on the
        LoadedProg so the same verification can be re-bound to any other
        world (load_relocatable / `prog relocate`) without re-running the
        verifier."""
        from . import reloc
        vabs = reloc.verify_relocatable(obj)
        for spec in obj.map_specs():
            self.create_map(spec)
        vprog = reloc.resolve(vabs, self.fd_of, self.map_specs)
        pid = next(self._next_pid)
        self.progs[pid] = LoadedProg(pid, obj.name, obj.prog_type,
                                     vprog.insns, vprog, vabs=vabs)
        self._objects[obj.name] = obj.to_json()
        if self.shm is not None:
            self.shm.publish_program(obj.to_json(), obj.name)
        return pid

    def load_relocatable(self, vabs: VerifiedProgram, name: str,
                         prog_type: str = "uprobe") -> int:
        """Bind an ALREADY-verified abstract program to this runtime —
        zero verifier work, pure relocation (the fleet path: verify on one
        worker, relocate on N).  Declared maps are created on demand, like
        load_object."""
        from . import reloc
        if not vabs.is_abstract:
            raise loader.LoadError("load_relocatable needs an abstract "
                                   "VerifiedProgram (verify_relocatable)")
        for ml in vabs.reloc.map_layouts:
            self.create_map(ml.to_spec())
        vprog = reloc.resolve(vabs, self.fd_of, self.map_specs)
        pid = next(self._next_pid)
        self.progs[pid] = LoadedProg(pid, name, prog_type, vprog.insns,
                                     vprog, vabs=vabs)
        return pid

    def load_asm(self, name: str, text: str, maps: list[MapSpec] = (),
                 prog_type: str = "uprobe", ctx_words: int = 16) -> int:
        obj = loader.build_object(name, text, list(maps), prog_type,
                                  ctx_words=ctx_words)
        return self.load_object(obj)

    # ---------------------------------------------------------------- live lane
    @staticmethod
    def _parse_device_target(target: str):
        """(site_id, event_kind) for a device target, None for host targets."""
        parts = target.split(":")
        if parts[0] not in ("uprobe", "uretprobe", "probe"):
            return None
        ev_kind = {"uprobe": E.KIND_ENTRY, "uretprobe": E.KIND_EXIT,
                   "probe": E.KIND_TRACEPOINT}[parts[0]]
        return E.SITES.get_or_create(parts[1]), ev_kind

    def enable_live_attach(self, max_programs: int = 4, max_insns: int = 64,
                           arm=()):
        """Opt into the program-table interpreter lane. Must run BEFORE the
        step function is traced (it changes the trace: the table joins the
        map-state pytree and the interpreter joins probe_stage) — after
        which attach_live/detach_live never retrace. `arm` pre-declares
        device targets whose events are collected even with no program
        attached (the paper's patched-but-idle trampoline), since event
        collection is fixed at trace time."""
        from .table_interp import LiveTable
        self.live = LiveTable(list(self.map_specs),
                              ctx_words=E.EVENT_WIDTH,
                              max_programs=max_programs,
                              max_insns=max_insns)
        for target in arm:
            self.arm_site(target)
        self.attach_epoch += 1
        return self.live

    def arm_site(self, target: str) -> None:
        """Collect events for a device target so hot-attached programs can
        consume them. Changes the trace (bump epoch); call before compile."""
        parsed = self._parse_device_target(target)
        if parsed is None:
            raise ValueError(f"cannot arm non-device target {target!r}")
        if parsed not in self._armed:
            self._armed.add(parsed)
            self.attach_epoch += 1

    def attach_live(self, pid: int, target: str) -> Link:
        """Deprecated shim — use ``attach(pid, target, mode="table")``."""
        warnings.warn(
            "attach_live() is deprecated; use "
            "attach(pid, target, mode='table')", DeprecationWarning,
            stacklevel=2)
        return self.attach(pid, target, mode="table", promote=False)

    def detach_live(self, link_id) -> None:
        """Deprecated shim — use ``detach(link)`` / ``link.detach()``."""
        warnings.warn("detach_live() is deprecated; use detach()",
                      DeprecationWarning, stacklevel=2)
        self.detach(link_id)

    def _attach_table(self, pid: int, target: str, promote: bool) -> Link:
        """Attach a loaded+verified program to an already-compiled step via
        the live table: encode into a free slot, bump the generation
        counter. NO attach_epoch bump — the caller pushes the new table with
        sync_live_table() and keeps using the same compiled step."""
        if self.live is None:
            raise loader.LoadError("enable_live_attach() was not called "
                                   "before the step was compiled")
        prog = self.progs[pid]
        parsed = self._parse_device_target(target)
        if parsed is None:
            raise ValueError(f"live attach needs a device target, got "
                             f"{target!r}")
        from .verifier import check_table_encodable
        check_table_encodable(prog.vprog, n_maps=self.live.n_maps,
                              max_insns=self.live.max_insns,
                              ctx_words=self.live.ctx_words)
        slot = self.live.free_slot()
        if slot is None:
            raise loader.LoadError(
                f"live table full ({self.live.max_programs} slots)")
        sid, ev_kind = parsed
        # encoded table images are content-addressed in the fleet artifact
        # cache (setup_shm auto-joins <root>/cache): the daemon fanning an
        # attach out to N workers encodes once, N-1 workers reuse the image
        self.live.encode_slot(slot, prog.vprog, sid, ev_kind, pid=pid,
                              cache=self.artifact_cache)
        lid = next(self._next_link)
        link = Link(lid, pid, target, lane="table", slot=slot,
                    promotion_state="interp", promote=promote,
                    _parsed=parsed, _rt=self)
        self.links[lid] = link
        self._live_slot_of[lid] = slot
        if promote and self._promoter is not None:
            self._promoter.schedule(link)
        self.publish_status()
        return link

    def _table_attachable(self, pid: int, parsed) -> bool:
        """mode="auto" heuristic: route through the live table iff it can
        actually execute the program RIGHT NOW without a retrace — the lane
        exists, the target site's events are already collected (armed or
        statically attached), a slot is free, and the bytecode is
        encodable.  Anything else falls back to the fused (epoch-bump)
        path, which can always host the program."""
        if self.live is None or parsed is None:
            return False
        if parsed not in self.wanted_sites():
            return False               # trace-fixed collector never fires it
        if self.live.free_slot() is None:
            return False
        from .verifier import VerifierError, check_table_encodable
        try:
            check_table_encodable(self.progs[pid].vprog,
                                  n_maps=self.live.n_maps,
                                  max_insns=self.live.max_insns,
                                  ctx_words=self.live.ctx_words)
        except VerifierError:
            return False
        return True

    def sync_live_table(self, map_states, force: bool = False):
        """Push the host-side table into the device map-state WITHOUT
        retracing: shapes/dtypes are unchanged and the old table buffers are
        donated, so this is a pure buffer update on the running state.
        Generation-gated: an idle call (no attach/detach since the last
        sync) returns the state untouched, so the training loop can call it
        every step for free."""
        if self.live is None or "__live_table__" not in map_states:
            return map_states
        if self._promoter is not None:
            # generation boundary = promotion boundary: swap in any
            # background-compiled fused step (clears the table slot, so the
            # gen check below pushes the new table in the same call)
            self._promoter.apply_ready()
        gen = int(self.live.host["gen"][0])
        if not force and gen == self._synced_gen:
            return map_states
        self._synced_gen = gen
        if self._table_writer is None:
            # buffer donation is a no-op (with a warning) on CPU backends
            donate = (0,) if jax.default_backend() != "cpu" else ()
            self._table_writer = jax.jit(lambda old, new: new,
                                         donate_argnums=donate)
        new = self._table_writer(map_states["__live_table__"],
                                 self.live.device_state())
        return {**map_states, "__live_table__": new}

    # ---------------------------------------------------------------- attach
    def attach(self, pid: int, target: str, *, mode: str = "auto",
               promote: bool = True) -> Link:
        """Attach a loaded program; ONE entry point for every lane.

        target: uprobe:SITE | uretprobe:SITE | probe:SITE |
        tracepoint:SYS:enter|exit | filter:SYS

        mode:
          * "auto" (default) — device targets go through the live table
            when that is free (live lane enabled, site armed/collected,
            slot available, bytecode encodable): instant attach, no
            retrace; otherwise the classic fused path (attach_epoch bump
            -> the loop re-jits).  Host targets always take the host lane.
          * "fused" — force the epoch-bumping trace-time path.
          * "table" — force the live table; raises if unavailable.

        promote: table-lane links are handed to the promotion engine
        (enable_promotion), which retraces the fused lane in the
        background and swaps it in at the next generation boundary —
        steady state converges to fused cost while attach latency stays
        ~1.4ms (DESIGN.md §12).  promote=False pins the link to the
        interpreter.

        Returns a Link handle (``link.lane``, ``link.promotion_state``,
        ``link.detach()``); it coerces to its integer link id.
        """
        if mode not in ("auto", "fused", "table"):
            raise ValueError(f"bad attach mode {mode!r}")
        prog = self.progs[pid]
        parsed = self._parse_device_target(target)
        if parsed is None:                               # host lane
            if mode == "table":
                raise ValueError(f"live attach needs a device target, got "
                                 f"{target!r}")
            parts = target.split(":")
            if parts[0] == "tracepoint":
                self.syscalls.attach(parts[1], parts[2], prog.name,
                                     prog.insns, self.map_specs)
            elif parts[0] == "filter":
                self.syscalls.attach(parts[1], "enter", prog.name,
                                     prog.insns, self.map_specs)
            else:
                raise ValueError(f"bad attach target {target!r}")
            lid = next(self._next_link)
            link = Link(lid, pid, target, lane="host", _rt=self)
            self.links[lid] = link
            return link
        if mode == "table" or (mode == "auto"
                               and self._table_attachable(pid, parsed)):
            return self._attach_table(pid, target, promote)
        self.device_attach.setdefault(parsed, []).append(pid)
        self.attach_epoch += 1
        lid = next(self._next_link)
        link = Link(lid, pid, target, lane="fused", _parsed=parsed,
                    _rt=self)
        self.links[lid] = link
        return link

    def detach(self, link) -> None:
        """Detach by Link handle or integer link id (either lane)."""
        link_id = int(link)
        lk = self.links.pop(link_id)
        if lk.lane == "table":
            if lk.promotion_state in ("compiling", "ready"):
                lk.promotion_state = "cancelled"   # promote thread backs off
            slot = self._live_slot_of.pop(link_id)
            self.live.clear_slot(slot)
            self.publish_status()
            return
        prog = self.progs[lk.pid]
        parts = lk.target.split(":")
        kind = parts[0]
        if kind in ("uprobe", "uretprobe", "probe"):
            sid, ev_kind = lk._parsed or self._parse_device_target(lk.target)
            lst = self.device_attach.get((sid, ev_kind), [])
            if lk.pid in lst:
                lst.remove(lk.pid)
            if not lst:
                self.device_attach.pop((sid, ev_kind), None)
            self.attach_epoch += 1
        elif kind == "tracepoint":
            self.syscalls.detach(parts[1], parts[2], prog.name)
        elif kind == "filter":
            self.syscalls.detach(parts[1], "enter", prog.name)

    # ---------------------------------------------------------------- cache
    def enable_artifact_cache(self, root: str, max_bytes: int | None = None):
        """Join (or create) an AOT artifact cache directory. Compiled steps
        produced by aot_step()/PromotionEngine are stored under the layout
        fingerprint; any process sharing the directory and the same layout
        basis reuses them instead of retracing. ``max_bytes`` arms the LRU
        size budget for long-lived fleets (see artifact_cache.py)."""
        from .artifact_cache import ArtifactCache
        self.artifact_cache = ArtifactCache(root, max_bytes=max_bytes)
        return self.artifact_cache

    def layout_fingerprint(self, attach_sig: tuple | None = None,
                           extra: tuple = ()) -> str:
        """Canonical cache key for artifacts compiled against THIS
        runtime's world: map registry (fd order), event-row width, live
        table dims, plus the static attach signature the trace bakes in
        (defaults to the current device_attach) — exactly the
        trace-stability basis of DESIGN.md §9/§12."""
        from . import layout as L
        from .promote import attach_signature
        if attach_sig is None:
            attach_sig = attach_signature(self.device_attach)
        dims = ()
        if self.live is not None:
            dims = (self.live.max_programs, self.live.max_insns,
                    self.live.n_maps, self.live.ctx_words)
        return L.layout_fingerprint(self.map_specs, E.EVENT_WIDTH,
                                    table_dims=dims, attach_sig=attach_sig,
                                    extra=extra)

    def aot_step(self, build_fn, example_args, extra_key: tuple = ()):
        """Consult-or-compile-and-store: the worker cold-join fast path.

        Returns ``(compiled, hit)``. On a warm cache the executable
        deserializes in ~10ms; on a miss (or with no cache enabled) this
        is exactly the old ``jit(...).lower().compile()`` boot, plus a
        background-free store for the next joiner. ``build_fn()`` must
        return a fresh jit-wrapped step; ``example_args`` concrete or
        ShapeDtypeStruct arguments. ``extra_key`` folds caller facts the
        trace also depends on (e.g. batch geometry) into the key."""
        key = self.layout_fingerprint(extra=tuple(extra_key))
        if self.artifact_cache is not None:
            compiled = self.artifact_cache.get_step(key)
            if compiled is not None:
                return compiled, True
        fn = build_fn()
        compiled = fn.lower(*example_args).compile()
        if self.artifact_cache is not None:
            self.artifact_cache.put_step(key, compiled)
        return compiled, False

    # ---------------------------------------------------------------- promote
    def enable_promotion(self, step_builder, example_args,
                         background: bool = True):
        """Arm background promotion of table-lane links (DESIGN.md §12).

        step_builder() must return a fresh jit-wrapped step traced against
        this runtime's current attach state; example_args are the
        (concrete or ShapeDtypeStruct) arguments the loop calls the step
        with.  Existing table links attached with promote=True are
        scheduled immediately.  background=False compiles synchronously
        inside schedule() — deterministic, for tests."""
        from .promote import PromotionEngine
        self._promoter = PromotionEngine(self, step_builder, example_args,
                                         background=background)
        for lk in self.links.values():
            if lk.lane == "table" and lk.promote:
                self._promoter.schedule(lk)
        return self._promoter

    def take_promoted_step(self):
        """Hand the loop the AOT-compiled step from the last promotion (or
        None).  Pattern: on attach_epoch change, try this before re-jitting
        — a promoted epoch never blocks on a foreground compile."""
        step, self._promoted_step = self._promoted_step, None
        return step

    def _promote_table_link(self, link: Link, compiled) -> None:
        """The atomic swap, called by PromotionEngine.apply_ready at a
        generation boundary: retire the table slot and install the static
        attachment in one host-side critical section, so the very next
        step executes the program on the fused lane exactly once."""
        slot = self._live_slot_of.pop(link.link_id)
        self.live.clear_slot(slot)              # gen bump -> table resync
        self.device_attach.setdefault(link._parsed, []).append(link.pid)
        self.attach_epoch += 1                  # loop picks a new step fn
        link.lane, link.slot = "fused", None
        link.promotion_state = "fused"
        self._promoted_step = compiled
        self.publish_status()

    @contextlib.contextmanager
    def _attach_overlay(self, extra: dict):
        """Thread-locally overlay extra device attachments — the promotion
        thread traces the FUTURE attach state through this without the
        foreground step's trace (or jit cache) ever seeing it."""
        prev = getattr(self._overlay_tls, "extra", None)
        self._overlay_tls.extra = extra
        try:
            yield
        finally:
            self._overlay_tls.extra = prev

    def _effective_attach(self) -> dict:
        extra = getattr(self._overlay_tls, "extra", None)
        if not extra:
            return self.device_attach
        merged = {k: list(v) for k, v in self.device_attach.items()}
        for k, pids in extra.items():
            merged.setdefault(k, []).extend(pids)
        return merged

    # ---------------------------------------------------------------- device
    def wanted_sites(self) -> set[tuple[int, int]]:
        return set(self._effective_attach().keys()) | self._armed

    def collector(self, stats_fn=None) -> E.Collector:
        return E.Collector(self.wanted_sites(), stats_fn=stats_fn)

    def probe_stage(self, event_rows, map_states, aux, mode=None):
        """Run all attached device programs over the step's event tape.
        Traced inside the step function. event_rows: i64[N, 16].

        'fused' (default) makes ONE pass over the tape: all vector-safe
        programs across all attachments share a single shadow vmap whose
        per-program validity is folded into the entry predicate, with side
        effects applied once per call site; the remaining programs share one
        combined scan whose per-event selects are gated to each program's
        touched-maps footprint. Cost: O(events + call_sites) instead of the
        seed's O(programs x events x total_state).
        'scan' / 'vectorized' keep the seed per-attachment behavior (oracle
        for differential tests and the benchmark baseline).

        When the live lane is enabled, a third stage runs after the static
        lanes: the program-table interpreter executes whatever verified
        bytecode the `__live_table__` data currently holds (DESIGN.md §9) —
        its trace never depends on which programs are attached."""
        mode = mode or self.exec_mode
        table = None
        if "__live_table__" in map_states:
            table = map_states["__live_table__"]
            map_states = {k: v for k, v in map_states.items()
                          if k != "__live_table__"}
        map_states, aux = self._static_lanes(event_rows, map_states, aux,
                                             mode)
        if table is not None:
            if self.live is not None and event_rows.shape[0] > 0:
                map_states, aux = self.live.run(table, event_rows,
                                                map_states, aux)
            map_states = {**map_states, "__live_table__": table}
        return map_states, aux

    def _static_lanes(self, event_rows, map_states, aux, mode):
        # the promotion thread traces through a thread-local overlay that
        # already contains the link being promoted (see _attach_overlay)
        device_attach = self._effective_attach()
        if event_rows.shape[0] == 0 or not device_attach:
            return map_states, aux
        if mode == "fused":
            from . import vectorized as V
            # ordering guard: distinct programs sharing state
            # non-commutatively (ringbuf streams, rw maps, override/printk/
            # rand aux) would observe a different interleaving across the
            # fused lanes than under the seed per-attachment order — fall
            # back to scan mode for exactness (rare; typical instrumentation
            # uses disjoint or fetch-add/hist maps).
            uniq = {pid: self.progs[pid].vprog
                    for pids in device_attach.values() for pid in pids}
            n_attach = {pid: sum(pids.count(pid)
                                 for pids in device_attach.values())
                        for pid in uniq}
            # multi-attached scan-lane programs also lose per-attachment
            # order in the combined scan (the vector lane preserves it)
            self_conflict = any(
                n_attach[pid] > 1 and not V.is_vector_safe(vp)
                and any(not c for c in _ordering_resources(vp).values())
                for pid, vp in uniq.items())
            if not self_conflict and \
                    not _has_ordering_conflict(list(uniq.values())):
                vec, rest = [], []
                for (sid, kind), pids in sorted(device_attach.items()):
                    for pid in pids:
                        vprog = self.progs[pid].vprog
                        lane = vec if V.is_vector_safe(vprog) else rest
                        lane.append((sid, kind, vprog))
                if vec:
                    map_states, aux = V.run_fused_vector(
                        vec, event_rows, map_states, aux)
                if rest:
                    map_states, aux = J.run_fused_scan(
                        rest, event_rows, map_states, aux)
                return map_states, aux
            mode = "scan"
        for (sid, kind), pids in sorted(device_attach.items()):
            valid = ((event_rows[:, 0] == sid) &
                     (event_rows[:, 1] == kind))
            for pid in pids:
                vprog = self.progs[pid].vprog
                if mode == "vectorized":
                    from . import vectorized as V
                    if V.is_vector_safe(vprog):
                        map_states, aux = V.run_vectorized(
                            vprog, event_rows, valid, map_states, aux)
                        continue
                _, map_states, aux = J.run_over_events(
                    vprog, event_rows, valid, map_states, aux)
        return map_states, aux

    # ---------------------------------------------------------------- shm
    def setup_shm(self, root: str, worker_id: str | None = None,
                  group: str | None = None):
        """Join the shm control plane. worker_id=None keeps the seed
        single-process layout; a worker id places this process's device
        snapshots, host maps, and control queue under
        `<root>/workers/<wid>/` so a fleet daemon can aggregate N such
        processes into one global view (DESIGN.md §10). `group` names the
        node aggregator that folds this worker in a hierarchical fleet
        (DESIGN.md §15) — the node claims its group members dynamically,
        so the worker may join before or after its node boots."""
        from .shm import ShmRegion
        self.shm = ShmRegion.create(root, self.map_specs,
                                    worker_id=worker_id, group=group)
        # host maps become shm-backed (live for the daemon)
        for spec in self.map_specs:
            self.host_maps[spec.name] = self.shm.host[spec.name]
        for name, obj_json in self._objects.items():
            self.shm.publish_program(obj_json, name)
        # every fleet member shares one artifact cache next to the shm
        # plane — the Nth joiner reuses the first joiner's compiles
        if self.artifact_cache is None:
            import os
            self.enable_artifact_cache(os.path.join(root, "cache"))
        self.publish_status()
        return self.shm

    def publish(self, map_states) -> None:
        if self.shm is None:
            return
        host_states = jax.tree.map(np.asarray, map_states)
        self.syscalls.invoke(
            "sys_shm_publish", [len(host_states)],
            impl=lambda: self.shm.publish_device(host_states))

    def poll_control(self) -> list[dict]:
        """Pick up daemon attach/detach/load requests (between steps).
        Everything routes through the unified attach(): requests carry
        "mode" ("auto"/"fused"/"table") and "promote"; legacy requests
        with "live": true map to mode="table" (the running compiled step
        picks them up after the loop calls sync_live_table()), legacy
        requests without either map to mode="fused" (the epoch-bumping
        path), exactly as before the API was unified.  Each applied
        load_attach reports the assigned link id, lane, and promotion
        state so the daemon can detach/confirm it later."""
        if self.shm is None:
            return []
        reqs, self._req_cursor = self.shm.poll_requests(self._req_cursor)
        applied = []
        for r in reqs:
            try:
                if r["op"] == "load_attach":
                    obj = ProgramObject.from_json(r["object"])
                    pid = self.load_object(obj)
                    tgt = r.get("target") or obj.attach_to
                    mode = r.get("mode") or ("table" if r.get("live")
                                             else "fused")
                    # missing "promote" (hand-rolled/legacy request) pins
                    # the link to its lane — promotion is strictly opt-in
                    # over the wire (request_load_attach sends it)
                    link = self.attach(pid, tgt, mode=mode,
                                       promote=bool(r.get("promote", False)))
                    applied.append({**r, "link_id": int(link),
                                    "lane": link.lane,
                                    "promotion": link.promotion_state})
                    continue
                elif r["op"] == "detach":
                    self.detach(int(r["link_id"]))
                applied.append(r)
            except Exception as e:  # control plane must not kill training
                applied.append({**r, "error": str(e)})
        if applied:     # idle polls stay a pure request-counter read
            self.publish_status()
        return applied

    def publish_status(self) -> None:
        """Expose the control plane's view to the daemon: live-table
        generation + active links, so a requester can confirm its program
        went live (or was rejected) without attaching a debugger."""
        if self.shm is None:
            return
        import os
        self.shm.publish_status({
            "worker_id": self.shm.worker_id,
            "pid": os.getpid(),
            "attach_epoch": self.attach_epoch,
            "live_gen": int(self.live.host["gen"][0]) if self.live else 0,
            "live_slots": ({str(p): (self.progs[pid].name
                                     if pid is not None else None)
                            for p, pid in enumerate(self.live.slot_pid)}
                           if self.live else {}),
            "links": {str(lid): lk.target for lid, lk in self.links.items()},
            "promotions": {str(lid): {"lane": lk.lane,
                                      "state": lk.promotion_state}
                           for lid, lk in self.links.items()},
            "cache": (dict(self.artifact_cache.counters)
                      if self.artifact_cache is not None else {}),
        })

    # ---------------------------------------------------------------- misc
    def ringbuf_drain(self, map_states, name: str, cursor: int):
        st = jax.tree.map(np.asarray, map_states[name])
        return M.n_ringbuf_drain(st, cursor)

    def hist_snapshot(self, map_states, name: str):
        return np.asarray(map_states[name]["bins"])
