"""BpftimeRuntime — the runtime manager (bpftime's agent + syscall-compat
library rolled into one).

Responsibilities:
  * global map registry (create/bind by name — objects share maps by name);
  * program load: relocate (CO-RE-lite) -> verify -> store;
  * attachments:
      device:  uprobe:SITE / uretprobe:SITE / probe:SITE   (in-graph)
      host:    tracepoint:SYS:enter|exit / filter:SYS      (interpreter)
  * the per-step probe-execution stage (compiled into the train/serve step);
  * attach/detach WITHOUT restart: every device change bumps `attach_epoch`;
    the training loop re-jits its step on epoch change and carries state
    over — the ptrace-pause analogue;
  * shm control plane: publish device maps, poll daemon attach requests.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import events as E, jit as J, loader, maps as M, syscalls as S, vm
from .helpers import HELPERS
from .loader import ProgramObject
from .maps import MapSpec
from .verifier import CallAnn, VerifiedProgram, verify

# helpers whose map side effects commute across programs (order-free)
_COMMUTATIVE_HELPERS = {"map_fetch_add", "percpu_fetch_add", "hist_add"}
_AUX_RESOURCES = {"trace_printk": "printk", "override_return": "override",
                  "get_prandom_u32": "rand"}


def _ordering_resources(vprog: VerifiedProgram) -> dict:
    """{resource: commutative?} for one program. Two DIFFERENT programs may
    be scheduled on different fused lanes (or reordered within one) only if
    every resource they share is touched commutatively by both; otherwise
    the fused pipeline must keep the seed scan ordering (see DESIGN.md §2).
    """
    out: dict = {}
    for ann in vprog.anns.values():
        if not isinstance(ann, CallAnn):
            continue
        sig = HELPERS[ann.hid]
        comm = sig.name in _COMMUTATIVE_HELPERS
        for i, kind in enumerate(sig.args):
            if kind == "mapfd":
                key = ("map", vprog.map_specs[ann.statics[i]].name)
                out[key] = out.get(key, True) and comm
        if sig.name in _AUX_RESOURCES:
            out[("aux", _AUX_RESOURCES[sig.name])] = False
    return out


def _has_ordering_conflict(vprogs: list) -> bool:
    """True iff any resource is shared non-commutatively across two
    distinct programs (same program attached to several sites is fine —
    its per-attachment order is preserved by the fused scheduler)."""
    res = [_ordering_resources(vp) for vp in vprogs]
    for i in range(len(res)):
        for j in range(i + 1, len(res)):
            for key, comm_i in res[i].items():
                if key in res[j] and not (comm_i and res[j][key]):
                    return True
    return False


@dataclass
class LoadedProg:
    pid: int
    name: str
    prog_type: str
    insns: list
    vprog: VerifiedProgram


@dataclass
class Link:
    link_id: int
    pid: int
    target: str


class BpftimeRuntime:
    def __init__(self, pid: int = 0):
        self.map_specs: list[MapSpec] = []
        self.fd_of: dict[str, int] = {}
        self.progs: dict[int, LoadedProg] = {}
        self._next_pid = itertools.count(1)
        self._next_link = itertools.count(1)
        self.links: dict[int, Link] = {}
        # device attachments: (site_id, kind) -> [pid]
        self.device_attach: dict[tuple[int, int], list[int]] = {}
        self.attach_epoch = 0
        # host side
        self.host_maps: dict = {}
        self.syscalls = S.SyscallTable(self.host_maps, self.map_specs,
                                       pid=pid)
        self.shm = None
        self._req_cursor = 0
        self._objects: dict[str, str] = {}   # name -> serialized object
        # 'fused' (default): single-pass multi-program dispatch;
        # 'scan' / 'vectorized': the per-attachment seed paths.
        self.exec_mode = "fused"

    # ---------------------------------------------------------------- maps
    def create_map(self, spec: MapSpec) -> int:
        if spec.name in self.fd_of:
            old = self.map_specs[self.fd_of[spec.name]]
            if (old.kind, old.max_entries, old.rec_width, old.num_shards) != \
               (spec.kind, spec.max_entries, spec.rec_width, spec.num_shards):
                raise loader.LoadError(
                    f"map {spec.name!r} redeclared with incompatible spec")
            return self.fd_of[spec.name]
        fd = len(self.map_specs)
        self.map_specs.append(spec)
        self.fd_of[spec.name] = fd
        self.host_maps[spec.name] = M.init_state(spec, np)
        return fd

    def init_device_maps(self) -> dict:
        return M.init_states(self.map_specs, jnp)

    # ---------------------------------------------------------------- load
    def load_object(self, obj: ProgramObject) -> int:
        for spec in obj.map_specs():
            self.create_map(spec)
        insns = loader.relocate(obj, self.fd_of)
        vprog = verify(insns, self.map_specs, ctx_words=obj.ctx_words)
        pid = next(self._next_pid)
        self.progs[pid] = LoadedProg(pid, obj.name, obj.prog_type, insns,
                                     vprog)
        self._objects[obj.name] = obj.to_json()
        if self.shm is not None:
            self.shm.publish_program(obj.to_json(), obj.name)
        return pid

    def load_asm(self, name: str, text: str, maps: list[MapSpec] = (),
                 prog_type: str = "uprobe", ctx_words: int = 16) -> int:
        obj = loader.build_object(name, text, list(maps), prog_type,
                                  ctx_words=ctx_words)
        return self.load_object(obj)

    # ---------------------------------------------------------------- attach
    def attach(self, pid: int, target: str) -> int:
        """target: uprobe:SITE | uretprobe:SITE | probe:SITE |
        tracepoint:SYS:enter|exit | filter:SYS"""
        prog = self.progs[pid]
        parts = target.split(":")
        kind = parts[0]
        if kind in ("uprobe", "uretprobe", "probe"):
            site = parts[1]
            ev_kind = {"uprobe": E.KIND_ENTRY, "uretprobe": E.KIND_EXIT,
                       "probe": E.KIND_TRACEPOINT}[kind]
            sid = E.SITES.get_or_create(site)
            self.device_attach.setdefault((sid, ev_kind), []).append(pid)
            self.attach_epoch += 1
        elif kind == "tracepoint":
            sys_name, phase = parts[1], parts[2]
            self.syscalls.attach(sys_name, phase, prog.name, prog.insns,
                                 self.map_specs)
        elif kind == "filter":
            sys_name = parts[1]
            self.syscalls.attach(sys_name, "enter", prog.name, prog.insns,
                                 self.map_specs)
        else:
            raise ValueError(f"bad attach target {target!r}")
        lid = next(self._next_link)
        self.links[lid] = Link(lid, pid, target)
        return lid

    def detach(self, link_id: int) -> None:
        link = self.links.pop(link_id)
        prog = self.progs[link.pid]
        parts = link.target.split(":")
        kind = parts[0]
        if kind in ("uprobe", "uretprobe", "probe"):
            ev_kind = {"uprobe": E.KIND_ENTRY, "uretprobe": E.KIND_EXIT,
                       "probe": E.KIND_TRACEPOINT}[kind]
            sid = E.SITES.get_or_create(parts[1])
            lst = self.device_attach.get((sid, ev_kind), [])
            if link.pid in lst:
                lst.remove(link.pid)
            if not lst:
                self.device_attach.pop((sid, ev_kind), None)
            self.attach_epoch += 1
        elif kind == "tracepoint":
            self.syscalls.detach(parts[1], parts[2], prog.name)
        elif kind == "filter":
            self.syscalls.detach(parts[1], "enter", prog.name)

    # ---------------------------------------------------------------- device
    def wanted_sites(self) -> set[tuple[int, int]]:
        return set(self.device_attach.keys())

    def collector(self, stats_fn=None) -> E.Collector:
        return E.Collector(self.wanted_sites(), stats_fn=stats_fn)

    def probe_stage(self, event_rows, map_states, aux, mode=None):
        """Run all attached device programs over the step's event tape.
        Traced inside the step function. event_rows: i64[N, 16].

        'fused' (default) makes ONE pass over the tape: all vector-safe
        programs across all attachments share a single shadow vmap whose
        per-program validity is folded into the entry predicate, with side
        effects applied once per call site; the remaining programs share one
        combined scan whose per-event selects are gated to each program's
        touched-maps footprint. Cost: O(events + call_sites) instead of the
        seed's O(programs x events x total_state).
        'scan' / 'vectorized' keep the seed per-attachment behavior (oracle
        for differential tests and the benchmark baseline)."""
        mode = mode or self.exec_mode
        if event_rows.shape[0] == 0 or not self.device_attach:
            return map_states, aux
        if mode == "fused":
            from . import vectorized as V
            # ordering guard: distinct programs sharing state
            # non-commutatively (ringbuf streams, rw maps, override/printk/
            # rand aux) would observe a different interleaving across the
            # fused lanes than under the seed per-attachment order — fall
            # back to scan mode for exactness (rare; typical instrumentation
            # uses disjoint or fetch-add/hist maps).
            uniq = {pid: self.progs[pid].vprog
                    for pids in self.device_attach.values() for pid in pids}
            n_attach = {pid: sum(pids.count(pid)
                                 for pids in self.device_attach.values())
                        for pid in uniq}
            # multi-attached scan-lane programs also lose per-attachment
            # order in the combined scan (the vector lane preserves it)
            self_conflict = any(
                n_attach[pid] > 1 and not V.is_vector_safe(vp)
                and any(not c for c in _ordering_resources(vp).values())
                for pid, vp in uniq.items())
            if not self_conflict and \
                    not _has_ordering_conflict(list(uniq.values())):
                vec, rest = [], []
                for (sid, kind), pids in sorted(self.device_attach.items()):
                    for pid in pids:
                        vprog = self.progs[pid].vprog
                        lane = vec if V.is_vector_safe(vprog) else rest
                        lane.append((sid, kind, vprog))
                if vec:
                    map_states, aux = V.run_fused_vector(
                        vec, event_rows, map_states, aux)
                if rest:
                    map_states, aux = J.run_fused_scan(
                        rest, event_rows, map_states, aux)
                return map_states, aux
            mode = "scan"
        for (sid, kind), pids in sorted(self.device_attach.items()):
            valid = ((event_rows[:, 0] == sid) &
                     (event_rows[:, 1] == kind))
            for pid in pids:
                vprog = self.progs[pid].vprog
                if mode == "vectorized":
                    from . import vectorized as V
                    if V.is_vector_safe(vprog):
                        map_states, aux = V.run_vectorized(
                            vprog, event_rows, valid, map_states, aux)
                        continue
                _, map_states, aux = J.run_over_events(
                    vprog, event_rows, valid, map_states, aux)
        return map_states, aux

    # ---------------------------------------------------------------- shm
    def setup_shm(self, root: str):
        from .shm import ShmRegion
        self.shm = ShmRegion.create(root, self.map_specs)
        # host maps become shm-backed (live for the daemon)
        for spec in self.map_specs:
            self.host_maps[spec.name] = self.shm.host[spec.name]
        for name, obj_json in self._objects.items():
            self.shm.publish_program(obj_json, name)
        return self.shm

    def publish(self, map_states) -> None:
        if self.shm is None:
            return
        host_states = jax.tree.map(np.asarray, map_states)
        self.syscalls.invoke(
            "sys_shm_publish", [len(host_states)],
            impl=lambda: self.shm.publish_device(host_states))

    def poll_control(self) -> list[dict]:
        """Pick up daemon attach/detach/load requests (between steps)."""
        if self.shm is None:
            return []
        reqs, self._req_cursor = self.shm.poll_requests(self._req_cursor)
        applied = []
        for r in reqs:
            try:
                if r["op"] == "load_attach":
                    obj = ProgramObject.from_json(r["object"])
                    pid = self.load_object(obj)
                    tgt = r.get("target") or obj.attach_to
                    self.attach(pid, tgt)
                elif r["op"] == "detach":
                    self.detach(int(r["link_id"]))
                applied.append(r)
            except Exception as e:  # control plane must not kill training
                applied.append({**r, "error": str(e)})
        return applied

    # ---------------------------------------------------------------- misc
    def ringbuf_drain(self, map_states, name: str, cursor: int):
        st = jax.tree.map(np.asarray, map_states[name])
        return M.n_ringbuf_drain(st, cursor)

    def hist_snapshot(self, map_states, name: str):
        return np.asarray(map_states[name]["bins"])
