"""bpftime-daemon analogue: a separate monitor/control process that

  * attaches to the shm region (no privileges over the trainer needed —
    plain file permissions, paper SP4);
  * reads live host maps and seqlocked device-map snapshots;
  * renders bcc-style log2 histograms / counters;
  * queues load+attach requests the trainer applies at the next step
    boundary (injection-without-restart, paper C5).

Usable as a library (tests) or CLI:
    python -m repro.core.daemon <shm_dir> [--watch SECONDS] [--once]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from .maps import MapKind
from .shm import ShmRegion


def render_log2_hist(bins: np.ndarray, label: str = "value") -> str:
    """bcc/bpftrace-style ASCII histogram (fixed-point Q47.16 bins)."""
    total = int(bins.sum())
    out = [f"{label:>16} : count    distribution"]
    if total == 0:
        return "\n".join(out + ["(empty)"])
    top = int(bins.max())
    nz = np.nonzero(bins)[0]
    lo, hi = int(nz.min()), int(nz.max())
    for b in range(lo, hi + 1):
        c = int(bins[b])
        bar = "*" * int(40 * c / top)
        # bin k holds fx values with bit_length == k; fx = v * 2^16
        lo_v = 0.0 if b == 0 else (1 << (b - 1)) / 65536.0
        hi_v = (1 << b) / 65536.0
        out.append(f"{lo_v:10.4g} -> {hi_v:<10.4g} : {c:<8d} |{bar}|")
    return "\n".join(out)


def summarize(shm: ShmRegion, section: str = "device") -> str:
    lines = []
    for spec in shm.specs:
        st = (shm.snapshot_device(spec.name) if section == "device"
              else {f: np.array(a) for f, a in shm.host[spec.name].items()})
        if spec.kind == MapKind.LOG2HIST:
            lines.append(f"[{spec.name}] log2 histogram:")
            lines.append(render_log2_hist(st["bins"]))
        elif spec.kind == MapKind.ARRAY:
            nz = np.nonzero(st["values"])[0]
            kv = {int(i): int(st["values"][i]) for i in nz[:16]}
            lines.append(f"[{spec.name}] array: {kv}")
        elif spec.kind == MapKind.HASH:
            used = np.nonzero(st["used"])[0]
            kv = {int(st['keys'][i]): int(st['values'][i]) for i in used[:16]}
            lines.append(f"[{spec.name}] hash: {kv}")
        elif spec.kind == MapKind.PERCPU_ARRAY:
            tot = st["values"].sum(axis=0)
            nz = np.nonzero(tot)[0]
            lines.append(f"[{spec.name}] percpu (summed): "
                         f"{ {int(i): int(tot[i]) for i in nz[:16]} }")
        elif spec.kind == MapKind.RINGBUF:
            lines.append(f"[{spec.name}] ringbuf head={int(st['head'][0])}")
    return "\n".join(lines)


def request_load_attach(shm: ShmRegion, obj_json: str,
                        target: str | None = None,
                        live: bool = False) -> None:
    """live=True routes into the trainer's program-table interpreter lane:
    the program goes live on the ALREADY-COMPILED step (no retrace) — watch
    `live_gen` in read_status() bump to confirm application."""
    shm.request({"op": "load_attach", "object": obj_json, "target": target,
                 "live": live})


def request_detach(shm: ShmRegion, link_id: int) -> None:
    shm.request({"op": "detach", "link_id": link_id})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("shm_dir")
    ap.add_argument("--watch", type=float, default=2.0)
    ap.add_argument("--once", action="store_true")
    ap.add_argument("--attach", help="path to a ProgramObject json to inject")
    ap.add_argument("--target", help="attach target for --attach")
    ap.add_argument("--live", action="store_true",
                    help="inject via the live program table (no retrace in "
                         "the target process)")
    ap.add_argument("--detach", type=int, metavar="LINK_ID",
                    help="queue a detach of a previously applied link")
    args = ap.parse_args(argv)

    shm = ShmRegion.attach(args.shm_dir)
    if args.attach:
        with open(args.attach) as f:
            request_load_attach(shm, f.read(), args.target, live=args.live)
        print(f"queued {'live ' if args.live else ''}load+attach "
              f"of {args.attach}")
        return
    if args.detach is not None:
        request_detach(shm, args.detach)
        print(f"queued detach of link {args.detach}")
        return
    while True:
        status = shm.read_status()
        print(f"=== {time.strftime('%H:%M:%S')} "
              f"programs: {list(shm.read_programs())} "
              f"live_gen: {status.get('live_gen', 0)} "
              f"links: {status.get('links', {})}")
        print(summarize(shm))
        if args.once:
            break
        time.sleep(args.watch)


if __name__ == "__main__":
    main()
