"""bpftime-daemon analogue: a separate monitor/control process that

  * attaches to the shm region (no privileges over the trainer needed —
    plain file permissions, paper SP4);
  * reads live host maps and seqlocked device-map snapshots;
  * aggregates a FLEET of worker processes into one global map view
    (`Aggregator`, DESIGN.md §10): per-cycle delta extraction against a
    last-seen baseline, commutative merge per map kind, dead/stale worker
    detection, seqlocked publish under `<dir>/global/`;
  * renders bcc-style log2 histograms / counters;
  * queues load+attach requests the trainer applies at the next step
    boundary (injection-without-restart, paper C5) — fanned out to every
    worker of a fleet.

Usable as a library (tests) or CLI. bpftool-style subcommands:

    python -m repro.core.daemon <shm_dir> map dump [MAP] [--section S]
    python -m repro.core.daemon <shm_dir> map top MAP [-n K]
    python -m repro.core.daemon <shm_dir> prog list
    python -m repro.core.daemon <shm_dir> prog cache [ls|stat|purge [KEY]]
    python -m repro.core.daemon <shm_dir> prog relocate NAME [--json]
    python -m repro.core.daemon <shm_dir> attach OBJ.json [--target T]
                                [--mode auto|fused|table] [--no-promote]
    python -m repro.core.daemon <shm_dir> detach LINK_ID
    python -m repro.core.daemon <shm_dir> agg [--watch SECONDS] [--once]
    python -m repro.core.daemon <shm_dir> fleet health [--json]

plus the legacy single-process watcher flags:

    python -m repro.core.daemon <shm_dir> [--watch SECONDS] [--once]
                                [--attach OBJ --live] [--detach LINK_ID]
"""
from __future__ import annotations

import argparse
import base64
import json
import os
import sys
import time
from dataclasses import dataclass

import numpy as np

from . import faults, maps as M, shm as SH
from .maps import MapKind, MapSpec
from .shm import GlobalView, ShmRegion, SnapshotCorruption

from repro.ft import fault_tolerance as FT


def render_log2_hist(bins: np.ndarray, label: str = "value") -> str:
    """bcc/bpftrace-style ASCII histogram (fixed-point Q47.16 bins)."""
    total = int(bins.sum())
    out = [f"{label:>16} : count    distribution"]
    if total == 0:
        return "\n".join(out + ["(empty)"])
    top = int(bins.max())
    nz = np.nonzero(bins)[0]
    lo, hi = int(nz.min()), int(nz.max())
    for b in range(lo, hi + 1):
        c = int(bins[b])
        bar = "*" * int(40 * c / top)
        # bin k holds fx values with bit_length == k; fx = v * 2^16
        lo_v = 0.0 if b == 0 else (1 << (b - 1)) / 65536.0
        hi_v = (1 << b) / 65536.0
        out.append(f"{lo_v:10.4g} -> {hi_v:<10.4g} : {c:<8d} |{bar}|")
    return "\n".join(out)


def _summarize_state(spec: MapSpec, st: dict) -> list[str]:
    lines = []
    if spec.kind == MapKind.LOG2HIST:
        lines.append(f"[{spec.name}] log2 histogram:")
        lines.append(render_log2_hist(st["bins"]))
    elif spec.kind == MapKind.ARRAY:
        nz = np.nonzero(st["values"])[0]
        kv = {int(i): int(st["values"][i]) for i in nz[:16]}
        lines.append(f"[{spec.name}] array: {kv}")
    elif spec.kind == MapKind.HASH:
        items = M.n_hash_items(st)
        kv = dict(sorted(items.items())[:16])
        lines.append(f"[{spec.name}] hash: {kv}")
    elif spec.kind == MapKind.PERCPU_ARRAY:
        tot = st["values"].sum(axis=0)
        nz = np.nonzero(tot)[0]
        lines.append(f"[{spec.name}] percpu (summed): "
                     f"{ {int(i): int(tot[i]) for i in nz[:16]} }")
    elif spec.kind == MapKind.RINGBUF:
        lines.append(f"[{spec.name}] ringbuf head={int(st['head'][0])} "
                     f"dropped={int(st['dropped'][0])}")
    return lines


def summarize(shm: ShmRegion, section: str = "device") -> str:
    lines = []
    for spec in shm.specs:
        st = (shm.snapshot_device(spec.name) if section == "device"
              else {f: np.array(a) for f, a in shm.host[spec.name].items()})
        lines.extend(_summarize_state(spec, st))
    return "\n".join(lines)


def request_load_attach(shm: ShmRegion, obj_json: str,
                        target: str | None = None,
                        live: bool = False, mode: str | None = None,
                        promote: bool = True) -> None:
    """Queue a load+attach through the trainer's unified attach API.

    mode: "auto" | "fused" | "table" (None keeps the legacy mapping —
    live=True means mode="table", otherwise mode="fused").  mode="table"
    (or live=True) goes live on the ALREADY-COMPILED step (no retrace) —
    watch `live_gen` in read_status() bump to confirm application; with
    promote=True the trainer's promotion engine then retrains the link
    onto the fused lane in the background (`promotions` in the status
    doc walks interp -> compiling -> fused)."""
    req = {"op": "load_attach", "object": obj_json, "target": target,
           "live": live or mode == "table", "promote": promote}
    if mode is not None:
        req["mode"] = mode
    shm.request(req)


def request_detach(shm: ShmRegion, link_id: int) -> None:
    shm.request({"op": "detach", "link_id": link_id})


# --------------------------------------------------------------------------
# aggregation engine (DESIGN.md §10)
# --------------------------------------------------------------------------

class SeqRegression(Exception):
    """A worker's seqlock went BACKWARDS: its shm section was re-created
    (restart) under the aggregator. The cycle's snapshot is a different
    incarnation's state and must be forfeited, never diffed."""


# per-worker health states (DESIGN.md §11) — deterministic, cycle-counted
# thresholds so the state machine is testable without wall-clock sleeps
HEALTHY = "HEALTHY"
DEGRADED = "DEGRADED"
STALE = "STALE"
DEAD = "DEAD"


@dataclass
class AggregatorConfig:
    """Aggregation-engine tunables (satellite: no more hardcoded constants
    in shm.py/daemon.py).

    Seqlock reads back off exponentially: the first retry sleeps
    `backoff_base` seconds, doubling per attempt up to `backoff_max` —
    a one-publish collision resolves in ~50us (vs the old fixed 1ms),
    while a stuck writer costs at most retries * backoff_max before the
    worker is demoted to stale for the cycle."""
    snapshot_retries: int = 50
    backoff_base: float = SH.BACKOFF_BASE
    backoff_max: float = SH.BACKOFF_MAX
    poll_interval: float = 2.0          # loop() cadence, seconds
    # health state machine (cycle-counted)
    degraded_after: int = 3             # merges with no seq advance
    quarantine_after: int = 2           # consecutive failed cycles
    quarantine_probe_retries: int = 2   # reduced budget while quarantined
    # back-pressure: skip the global rebuild+publish while a cycle folds
    # more than coalesce_threshold updates (None = always publish), but
    # never let more than publish_max_lag cycles go unpublished
    coalesce_threshold: int | None = None
    publish_max_lag: int = 4
    # crash recovery
    journal: bool = True
    # journal cadence: write the fold journal every K output events (root:
    # cycles; node: emits). Lag is safe — restores re-extract idempotently
    # against the journaled baselines — and amortizes the json encode on
    # the hot fleet path
    journal_every: int = 1
    # tree aggregation (DESIGN.md §15)
    # publish sharded global hash views (keyspace partitioned over the
    # home-slot hash); None = single unsharded view only
    hash_shards: int | None = None
    # node-level folds run as jitted device reductions over the whole
    # worker group (False = numpy twins, bit-identical)
    device_fold: bool = True
    # ft wiring: heartbeats count aggregation cycles since the worker's
    # seqlock last advanced; step_time_map names a host ARRAY map of
    # per-step wall times the workers publish (sys_step_end probe)
    heartbeat_timeout_cycles: float = 5.0
    step_time_map: str | None = None
    straggler_factor: float = 1.5
    straggler_min_samples: int = 5


def _fresh_health() -> dict:
    return {"state": HEALTHY, "consec_fail": 0, "no_advance": 0,
            "quarantined": False, "transitions": []}


def _enc_arr(a) -> dict:
    """Journal array codec: raw little-endian int64 bytes, base64'd. An
    int-by-int JSON list costs ~40x the encode time at fleet scale (every
    worker baseline re-encodes each cycle); the decoder still accepts the
    old list form, so pre-existing journals restore unchanged."""
    a = np.ascontiguousarray(np.asarray(a), dtype="<i8")
    return {"s": list(a.shape),
            "z": base64.b64encode(a.tobytes()).decode("ascii")}


def _dec_arr(v) -> np.ndarray:
    if isinstance(v, dict):
        return np.frombuffer(base64.b64decode(v["z"]),
                             dtype="<i8").reshape(v["s"]).astype(np.int64)
    return np.asarray(v, np.int64)


def _enc_state(st: dict) -> dict:
    return {f: _enc_arr(a) for f, a in st.items()}


def _dec_state(d: dict) -> dict:
    return {f: _dec_arr(v) for f, v in d.items()}


def _enc_items(items: dict) -> dict:
    ks = sorted(items)
    k = np.fromiter(ks, np.int64, len(ks))
    v = (np.array([items[x] for x in ks], np.int64) if ks
         else np.zeros(0, np.int64))
    return {"k": _enc_arr(k), "v": _enc_arr(v)}


def _dec_items(x) -> dict:
    if isinstance(x, dict):
        k, v = _dec_arr(x["k"]), _dec_arr(x["v"])
        return dict(zip(k.tolist(), v.tolist()))
    return {int(k): int(v) for k, v in x}     # old list-of-pairs journals


class Aggregator:
    """Polls every worker's seqlocked device snapshots, extracts per-cycle
    deltas against a last-seen baseline, and folds them into one global
    view with the commutative merge twins (maps.n_summary_merge /
    n_hash_fetch_add_batch / ringbuf_merge_global).

    Failure/eviction rules:
      * a worker whose registered pid is gone is DEAD: its final on-disk
        snapshot is harvested ONCE (the mmap files outlive the process;
        a crash mid-publish leaves the seqlock odd and forfeits only that
        last delta), then it is excluded from polling — its already-merged
        contribution stays in the global view (summary aggregation keeps
        fleet totals). A dead worker id is RE-ADMITTED, with a fresh
        baseline, once a new incarnation appears under it (boot id
        changed);
      * a worker whose seqlock cannot be read within the retry budget
        (crashed mid-publish) is STALE for the cycle: skipped, baseline
        kept, retried next cycle; it turns dead once its pid goes;
      * a worker whose boot id changed RESTARTED: its baseline resets to
        zero so the fresh process's counts merge from scratch (the old
        incarnation's contribution stays, like a dead worker's);
      * a worker whose seqlock REGRESSED (a restart re-created the section
        under the aggregator — zeroed files, seq back to 0 — before
        worker.json caught up) forfeits that cycle's delta entirely: the
        zeroed snapshot must never fold as a negative delta. Merges are
        snapshot-all-then-fold, so a mid-cycle failure never lands a
        partial merge;
      * a worker whose section read back a CHECKSUM MISMATCH (consistent
        seqlock, damaged payload) is skipped for the cycle exactly like a
        stale one — corruption is detect-and-skip, never silent-merge —
        and counted in `corrupt_skipped`.

    Crash recovery (DESIGN.md §11): with config.journal on, the engine
    persists a fold journal under global/ at the END of every cycle (after
    the publish). A restarted aggregator resumes from the journaled
    accumulators + per-worker baselines: folds the crash lost in memory
    re-extract idempotently against the journaled baselines (worker
    snapshots are cumulative), so no delta is double-folded or lost and
    the recovered global view is bit-identical to an uninterrupted run
    (hash tables republish canonicalized, so accumulator layout drift
    after a restore is invisible).
    """

    # tree position: None = the global root; NodeAggregator overrides with
    # its node id. Children publishing delta streams under nodes/<nid>/ are
    # matched against this to wire the tree.
    _node_id: str | None = None

    def __init__(self, root: str, snapshot_retries: int | None = None,
                 config: AggregatorConfig | None = None):
        self.config = config or AggregatorConfig()
        if snapshot_retries is not None:
            self.config.snapshot_retries = snapshot_retries
        self.snapshot_retries = self.config.snapshot_retries
        self.root = root
        self.specs = SH.read_meta_specs(root)
        self.view = self._make_output()
        # global accumulators
        self.summary = {s.name: M.init_state(s, np) for s in self.specs
                        if M.is_summary_kind(s.kind)}
        self.hash_tbl = {s.name: M.init_state(s, np) for s in self.specs
                         if s.kind == MapKind.HASH}
        # keys lost because the UNION of worker keys overflowed the
        # (spec-sized) global table — counted and surfaced in the status,
        # never silent (the advanced baseline makes the loss permanent)
        self.hash_dropped = {s.name: 0 for s in self.specs
                             if s.kind == MapKind.HASH}
        # ringbuf: per-worker retained tagged records + per-worker heads.
        # rb_offset is each worker's PERMANENT stream base: past
        # incarnations' final heads, so a restarted worker's positions
        # continue after the old incarnation's instead of restarting at 0
        # (the global head must never regress).
        self.rb_tagged: dict[str, dict[str, list]] = \
            {s.name: {} for s in self.specs if s.kind == MapKind.RINGBUF}
        self.rb_heads: dict[str, dict[str, int]] = \
            {s.name: {} for s in self.specs if s.kind == MapKind.RINGBUF}
        self.rb_offset: dict[str, dict[str, int]] = \
            {s.name: {} for s in self.specs if s.kind == MapKind.RINGBUF}
        # per-worker step floor: interleave keys must be monotone in each
        # worker's emit order (maps.ringbuf_merge_global's window
        # argument), so step tags are clamped to never regress — a
        # restarted worker whose steps restart at 0 sorts after its old
        # incarnation, not before it
        self.rb_step_floor: dict[str, dict[str, int]] = \
            {s.name: {} for s in self.specs if s.kind == MapKind.RINGBUF}
        # ringbuf records overwritten in a worker's ring BEFORE the
        # aggregator read them (back-pressure drop accounting, explicit
        # in the status — never silent)
        self.rb_lost: dict[str, dict[str, int]] = \
            {s.name: {} for s in self.specs if s.kind == MapKind.RINGBUF}
        # per-worker poll state; dead maps worker id -> boot id at death,
        # so a NEW incarnation under the same id is re-admitted
        self.workers: dict[str, dict] = {}
        self.dead: dict[str, str | None] = {}
        self.health: dict[str, dict] = {}
        self.corrupt_skipped: dict[str, int] = {}
        self.cycles = 0
        self.merged_updates = 0
        self.coalesced_cycles = 0
        self._publish_lag = 0
        self.last_states: dict = {}
        self._published = False
        self._stragglers: list[str] = []
        self.hb = FT.HeartbeatMonitor(
            num_hosts=0, timeout_s=self.config.heartbeat_timeout_cycles)
        # tree aggregation (DESIGN.md §15): child node-aggregators feed this
        # level through seq-numbered delta streams instead of raw snapshots
        self.nodes: dict[str, dict] = {}
        self.stream_lost: dict[str, int] = {}     # gc'd/corrupt batches
        self.node_coalesced: dict[str, int] = {}  # subtree back-pressure
        self._subtree: dict[str, dict] = {}       # last alive/dead rollup
        self._journal_nodes: dict[str, dict] = {}
        self._journal_due = 0
        # sharded global hash views: root-only, dirty shards republished
        self.shards = None
        self._shard_last: dict[tuple, tuple] = {}
        self.shard_publishes = 0
        if self.config.hash_shards and self._node_id is None:
            self.shards = SH.HashShards.create(
                root, self.specs, int(self.config.hash_shards))
        # crash recovery: resume accumulators + baselines from the fold
        # journal the previous incarnation persisted at its last completed
        # cycle (missing/invalid journal = cold start)
        self._journal_workers: dict[str, dict] = {}
        self._journal_raw: dict | None = None
        if self.config.journal:
            self._restore_journal()

    # -------------------------------------------------------------- tree hooks
    def _make_output(self):
        """Where this level's merged state goes: the root publishes the
        seqlocked global view; a NodeAggregator emits delta batches into
        its stream instead."""
        return GlobalView.create(self.root, self.specs)

    def _who(self) -> str:
        return self._node_id or "global"

    # ---------------------------------------------------------------- journal
    def _journal_path(self) -> str:
        return os.path.join(self.root, "global", "journal.json")

    def _journal_dict(self) -> dict:
        workers = {}
        for wid, w in self.workers.items():
            b = w["base"]
            workers[wid] = {
                "boot": w["boot"], "seq": int(w.get("seq", 0)),
                "base": {
                    "summary": {n: _enc_state(st)
                                for n, st in b["summary"].items()},
                    "hash_items": {n: _enc_items(d)
                                   for n, d in b["hash_items"].items()},
                    "rb_head": {n: int(v)
                                for n, v in b["rb_head"].items()},
                }}
        return {
            "version": 1,
            "cycles": self.cycles,
            "merged_updates": self.merged_updates,
            "coalesced_cycles": self.coalesced_cycles,
            "summary": {n: _enc_state(st) for n, st in self.summary.items()},
            "hash_items": {n: _enc_items(M.n_hash_items(t))
                           for n, t in self.hash_tbl.items()},
            "hash_dropped": dict(self.hash_dropped),
            "rb_tagged": {n: {wid: [[list(tag), [int(x) for x in rec]]
                                    for tag, rec in buf]
                              for wid, buf in d.items()}
                          for n, d in self.rb_tagged.items()},
            "rb_heads": {n: dict(d) for n, d in self.rb_heads.items()},
            "rb_offset": {n: dict(d) for n, d in self.rb_offset.items()},
            "rb_step_floor": {n: dict(d)
                              for n, d in self.rb_step_floor.items()},
            "rb_lost": {n: dict(d) for n, d in self.rb_lost.items()},
            "corrupt_skipped": dict(self.corrupt_skipped),
            "dead": dict(self.dead),
            "workers": workers,
            "health": self.health,
            "hb_last": dict(self.hb.last),
            # tree: consumption cursors per child node stream. The stream
            # writer only GCs batches at or below the JOURNALED cursor (we
            # ack after journaling), so a crashed parent re-reads anything
            # folded-but-unjournaled idempotently.
            "node_children": {nid: {"boot": nc["boot"],
                                    "last_seq": int(nc["last_seq"]),
                                    "retired": bool(nc.get("retired"))}
                              for nid, nc in self.nodes.items()},
            "stream_lost": dict(self.stream_lost),
            "node_coalesced": dict(self.node_coalesced),
        }

    def _restore_journal(self) -> None:
        p = self._journal_path()
        if not os.path.exists(p):
            return
        try:
            with open(p) as f:
                j = json.load(f)
        except (OSError, ValueError):
            return               # unreadable journal: cold start
        if j.get("version") != 1:
            return
        self._journal_raw = j
        spec_of = {s.name: s for s in self.specs}
        self.cycles = int(j["cycles"])
        self.merged_updates = int(j["merged_updates"])
        self.coalesced_cycles = int(j.get("coalesced_cycles", 0))
        for n, d in j["summary"].items():
            if n in self.summary:
                self.summary[n] = _dec_state(d)
        for n, items in j["hash_items"].items():
            if n in self.hash_tbl:
                # canonical rebuild: content identical; layout drift is
                # invisible because publishes canonicalize again
                self.hash_tbl[n] = M.n_hash_canonical(
                    spec_of[n], _dec_items(items))
        self.hash_dropped.update(
            {n: int(v) for n, v in j["hash_dropped"].items()
             if n in self.hash_dropped})
        for n, d in j["rb_tagged"].items():
            if n in self.rb_tagged:
                self.rb_tagged[n] = {
                    wid: [(tuple(tag), np.asarray(rec, np.int64))
                          for tag, rec in buf]
                    for wid, buf in d.items()}
        for attr in ("rb_heads", "rb_offset", "rb_step_floor", "rb_lost"):
            mine = getattr(self, attr)
            for n, d in j[attr].items():
                if n in mine:
                    mine[n] = {wid: int(v) for wid, v in d.items()}
        self.corrupt_skipped = {w: int(v)
                                for w, v in j["corrupt_skipped"].items()}
        self._journal_nodes = {nid: dict(nc) for nid, nc in
                               j.get("node_children", {}).items()}
        self.stream_lost = {nid: int(v) for nid, v in
                            j.get("stream_lost", {}).items()}
        self.node_coalesced = {nid: int(v) for nid, v in
                               j.get("node_coalesced", {}).items()}
        self.dead = dict(j["dead"])
        self.health = j["health"]
        self.hb.last = {w: float(t) for w, t in j.get("hb_last", {}).items()}
        for wid, w in j["workers"].items():
            b = w["base"]
            self._journal_workers[wid] = {
                "boot": w["boot"], "seq": int(w["seq"]),
                "base": {
                    "summary": {n: _dec_state(st)
                                for n, st in b["summary"].items()},
                    "hash_items": {n: _dec_items(items)
                                   for n, items in b["hash_items"].items()},
                    "rb_head": {n: int(v)
                                for n, v in b["rb_head"].items()},
                }}

    # ---------------------------------------------------------------- workers
    def _fresh_baseline(self) -> dict:
        return {"summary": {s.name: M.init_state(s, np) for s in self.specs
                            if M.is_summary_kind(s.kind)},
                "hash_items": {s.name: {} for s in self.specs
                               if s.kind == MapKind.HASH},
                "rb_head": {s.name: 0 for s in self.specs
                            if s.kind == MapKind.RINGBUF}}

    def _worker_candidates(self) -> list[str]:
        """Workers THIS level polls directly. The root skips every worker a
        registered node-aggregator claims (dead or alive: the node's stream
        is that worker's only fold path — folding it directly too would
        double-count); NodeAggregator overrides with its assigned group."""
        claimed = SH.claimed_workers(self.root)
        return [w for w in SH.list_workers(self.root) if w not in claimed]

    def _discover(self) -> None:
        for wid in self._worker_candidates():
            if wid in self.workers:
                continue
            boot = SH.worker_info(self.root, wid).get("boot")
            if wid in self.dead:
                if boot == self.dead[wid]:
                    continue            # same incarnation: stays retired
                del self.dead[wid]      # new incarnation: re-admit
                for name in self.rb_offset:
                    self.rb_offset[name][wid] = \
                        self.rb_heads[name].get(wid, 0)
                self._set_state(wid, HEALTHY, "new_incarnation")
            jw = self._journal_workers.pop(wid, None)
            if jw is not None and jw["boot"] == boot:
                # crash recovery: resume from the journaled baseline, so
                # deltas the previous incarnation folded in memory (after
                # its last journal write) re-extract — and already-journaled
                # folds don't re-extract (idempotent re-fold)
                base, seq, adopt = jw["base"], jw["seq"], False
            else:
                # adopt mode (node cold start without a journal but with
                # emitted stream history): the first snapshot becomes the
                # baseline WITHOUT folding — already-emitted content must
                # never re-emit (forfeit the gap, never double-fold)
                base, seq = self._fresh_baseline(), 0
                adopt = getattr(self, "_adopt_admits", False)
            self.workers[wid] = {
                "region": ShmRegion.attach(self.root, mode="r",
                                           worker_id=wid),
                "boot": boot,
                "base": base,
                "seq": seq,
                "adopt": adopt,
            }
            if wid not in self.health:
                self.health[wid] = _fresh_health()
                self.hb.beat(wid, t=float(self.cycles))

    def _check_restart(self, wid: str, w: dict) -> None:
        boot = SH.worker_info(self.root, wid).get("boot")
        if boot != w["boot"]:
            w["boot"] = boot
            w["base"] = self._fresh_baseline()
            w["seq"] = 0
            w["adopt"] = False   # a fresh incarnation's deltas DO fold
            w["region"] = ShmRegion.attach(self.root, mode="r",
                                           worker_id=wid)
            # the old incarnation's ringbuf contribution stays: its final
            # head becomes the new incarnation's stream base
            for name in self.rb_offset:
                self.rb_offset[name][wid] = self.rb_heads[name].get(wid, 0)

    # ---------------------------------------------------------------- merge
    def _snapshot_worker(self, wid: str, w: dict,
                         retries: int | None = None) -> dict:
        """Seqlocked snapshot of ALL of one worker's maps (none folded yet,
        so a failure mid-cycle never lands a partial merge). Raises
        TimeoutError if the seqlock never settles, SnapshotCorruption on a
        checksum mismatch (damaged bytes behind a consistent seqlock),
        SeqRegression if the section was re-created under us (restart mid
        detection: zeroed files must never fold as a negative delta)."""
        cfg = self.config
        retries = cfg.snapshot_retries if retries is None else retries
        region = w["region"]
        snaps = {}
        seq_seen = w.get("seq", 0)
        for spec in self.specs:
            cur, seq, _ = region.snapshot_device_meta(
                spec.name, retries=retries,
                backoff_base=cfg.backoff_base, backoff_max=cfg.backoff_max)
            if seq < w.get("seq", 0):
                raise SeqRegression(wid)
            seq_seen = max(seq_seen, seq)
            snaps[spec.name] = cur
        w["seq"] = seq_seen
        return snaps

    def _adopt_baseline(self, wid: str, w: dict, snaps: dict) -> None:
        """Adopt-mode admission: the snapshot becomes the baseline without
        folding. Used when a node aggregator cold-starts over a stream it
        already emitted into (journal lost): the worker's cumulative state
        includes content the previous incarnation already emitted — fold
        nothing, forfeit the gap, never double-emit."""
        base = w["base"]
        for spec in self.specs:
            cur = snaps[spec.name]
            if M.is_summary_kind(spec.kind):
                base["summary"][spec.name] = cur
            elif spec.kind == MapKind.HASH:
                base["hash_items"][spec.name] = M.n_hash_items(cur)
            elif spec.kind == MapKind.RINGBUF:
                lane = spec.flags.get("step_lane")
                _, head = M.n_ringbuf_tagged(cur, wid, lo=0, step_lane=lane)
                base["rb_head"][spec.name] = head
                # align the permanent stream so the NEXT record's global
                # position continues right after the last emitted head
                self.rb_offset[spec.name][wid] = \
                    self.rb_heads[spec.name].get(wid, 0) - head

    def _fold_worker(self, wid: str, w: dict, snaps: dict) -> int:
        """Delta + fold of one worker's snapshots into this level's
        accumulators. Returns the number of updates merged."""
        if w.pop("adopt", False):
            self._adopt_baseline(wid, w, snaps)
            return 0
        base = w["base"]
        updates = 0
        for spec in self.specs:
            cur = snaps[spec.name]
            if M.is_summary_kind(spec.kind):
                delta = M.n_summary_delta(spec, cur, base["summary"][spec.name])
                M.n_summary_merge(spec, self.summary[spec.name], delta)
                updates += int(sum(np.abs(d).sum() for d in delta.values()))
                base["summary"][spec.name] = cur
            elif spec.kind == MapKind.HASH:
                items = M.n_hash_items(cur)
                adds, dels = M.n_hash_delta(items,
                                            base["hash_items"][spec.name])
                if adds:
                    keys = np.array([k for k, _ in adds], np.int64)
                    deltas = np.array([d for _, d in adds], np.int64)
                    M.n_hash_fetch_add_batch(self.hash_tbl[spec.name],
                                             keys, deltas)
                    resident = M.n_hash_slots(self.hash_tbl[spec.name])
                    lost = sum(1 for k, _ in adds if k not in resident)
                    self.hash_dropped[spec.name] += lost
                for k in dels:
                    M.n_hash_delete(self.hash_tbl[spec.name], k)
                updates += len(adds) + len(dels)
                base["hash_items"][spec.name] = items
            elif spec.kind == MapKind.RINGBUF:
                updates += self._fold_rb(spec, wid, base, cur)
        return updates

    def _fold_rb(self, spec: MapSpec, wid: str, base: dict,
                 cur: dict) -> int:
        """Fold one worker's ringbuf snapshot (shared by the per-worker and
        the node-level group fold paths — rings stay per-worker tuples)."""
        lane = spec.flags.get("step_lane")
        lo = base["rb_head"][spec.name]
        tagged, head = M.n_ringbuf_tagged(
            cur, wid, lo=lo, step_lane=lane)
        # records the ring overwrote before we read them — the
        # aggregator fell behind; accounted, never silent
        lost = max(0, (head - spec.max_entries) - lo)
        if lost:
            self.rb_lost[spec.name][wid] = \
                self.rb_lost[spec.name].get(wid, 0) + lost
        # shift this incarnation's local positions onto the
        # worker's permanent stream, and clamp step tags to the
        # worker's floor: the interleave key stays monotone in
        # emit order across restarts (records keep their real
        # step values — only the sort tags are clamped)
        off = self.rb_offset[spec.name].get(wid, 0)
        floor = self.rb_step_floor[spec.name].get(wid, 0)
        adj = []
        for (s, w_, i), rec in tagged:
            floor = max(floor, s)
            adj.append(((floor, w_, off + i), rec))
        tagged = adj
        self.rb_step_floor[spec.name][wid] = floor
        buf = self.rb_tagged[spec.name].setdefault(wid, [])
        buf.extend(tagged)
        del buf[:-spec.max_entries]     # ring retention mirror
        self.rb_heads[spec.name][wid] = off + head
        base["rb_head"][spec.name] = head
        return len(tagged)

    def _merge_worker(self, wid: str, w: dict,
                      retries: int | None = None) -> int:
        """Snapshot-all-then-fold for one worker (harvest/compat path)."""
        snaps = self._snapshot_worker(wid, w, retries=retries)
        return self._fold_worker(wid, w, snaps)

    # ---------------------------------------------------------------- health
    def _set_state(self, wid: str, to: str, reason: str) -> None:
        h = self.health.setdefault(wid, _fresh_health())
        if h["state"] != to:
            h["transitions"].append([self.cycles, h["state"], to, reason])
            h["state"] = to

    def _fail_event(self, wid: str, reason: str) -> None:
        h = self.health.setdefault(wid, _fresh_health())
        h["consec_fail"] += 1
        self._set_state(wid, STALE, reason)
        if not h["quarantined"] and \
                h["consec_fail"] >= self.config.quarantine_after:
            h["quarantined"] = True
            h["transitions"].append([self.cycles, STALE, STALE,
                                     "quarantined"])

    def _ok_event(self, wid: str, advanced: bool) -> None:
        h = self.health.setdefault(wid, _fresh_health())
        h["consec_fail"] = 0
        if h["quarantined"]:
            h["quarantined"] = False
            h["transitions"].append([self.cycles, h["state"], h["state"],
                                     "readmitted"])
        if advanced:
            h["no_advance"] = 0
            if h["state"] != HEALTHY:
                self._set_state(wid, HEALTHY, "recovered")
            self.hb.beat(wid, t=float(self.cycles))
        else:
            h["no_advance"] += 1
            if h["state"] == HEALTHY and \
                    h["no_advance"] >= self.config.degraded_after:
                self._set_state(wid, DEGRADED, "no_seq_advance")

    def _detect_stragglers(self) -> list[str]:
        """ft wiring: per-step wall times the workers' sys_step_end probes
        publish into a host ARRAY map become the daemon's straggler signal
        (paper SP4 — no cooperation from the trainer needed)."""
        name = self.config.step_time_map
        if not name:
            return []
        wids, rows = [], []
        for wid in sorted(self.workers):
            host = self.workers[wid]["region"].host
            if name in host:
                wids.append(wid)
                rows.append(np.asarray(host[name]["values"],
                                       np.float64).reshape(-1))
        if not rows:
            return []
        idx = FT.detect_stragglers(
            np.stack(rows), factor=self.config.straggler_factor,
            min_samples=self.config.straggler_min_samples)
        return [wids[i] for i in idx]

    # ---------------------------------------------------------------- cycle
    def poll_once(self) -> dict:
        """One aggregation cycle: discover, poll, merge, publish, journal.
        Returns the status dict also written to <dir>/global/status.json."""
        cfg = self.config
        faults.fire("agg:cycle_begin", cycle=self.cycles, who=self._who())
        self._discover()
        stale = []
        cycle_updates = 0
        polled = []
        for wid in sorted(self.workers):
            w = self.workers[wid]
            faults.fire("agg:pre_merge", wid=wid, cycle=self.cycles,
                        who=self._who())
            # restart detection FIRST, even for a dead worker: a worker
            # that restarted AND died within one poll interval must be
            # harvested against the new incarnation's (zero) baseline and
            # recorded dead under the new boot id — else its contribution
            # would be mis-diffed now and double-counted on re-admission
            self._check_restart(wid, w)
            if not SH.worker_alive(self.root, wid):
                try:        # harvest the final snapshot, then retire
                    cycle_updates += self._merge_worker(wid, w)
                except (TimeoutError, SeqRegression, SnapshotCorruption):
                    pass    # died mid-publish / restart under way:
                            # the last delta is forfeit
                self.dead[wid] = w["boot"]
                del self.workers[wid]
                self._set_state(wid, DEAD, "pid_gone")
                continue
            h = self.health.setdefault(wid, _fresh_health())
            retries = (cfg.quarantine_probe_retries if h["quarantined"]
                       else cfg.snapshot_retries)
            seq_before = w.get("seq", 0)
            try:
                snaps = self._snapshot_worker(wid, w, retries=retries)
            except SnapshotCorruption:
                self.corrupt_skipped[wid] = \
                    self.corrupt_skipped.get(wid, 0) + 1
                stale.append(wid)
                self._fail_event(wid, "snapshot_corrupt")
            except TimeoutError:
                stale.append(wid)       # crashed mid-publish? retry next
                self._fail_event(wid, "seqlock_timeout")
            except SeqRegression:
                stale.append(wid)
                self._fail_event(wid, "seq_regression")
            else:
                polled.append((wid, w, snaps, seq_before))
        # fold phase: every snapshot already taken, so a fold is pure-local
        # (NodeAggregator overrides this with one batched device pass over
        # the whole group)
        cycle_updates += self._fold_polled(polled)
        # tree: fold child node-aggregators' delta-stream batches
        cycle_updates += self._poll_node_children()
        self._stragglers = self._detect_stragglers()
        for wid in self._stragglers:
            if self.health.get(wid, {}).get("state") == HEALTHY:
                self._set_state(wid, DEGRADED, "straggler")
        self.merged_updates += cycle_updates
        self.cycles += 1
        # rebuild + republish only when something merged: idle polling
        # stays O(workers), not O(total map state). Back-pressure: while a
        # cycle folds more than coalesce_threshold updates the rebuild is
        # skipped (deltas coalesce in the accumulators; ring overruns are
        # counted in rb_lost), but never for more than publish_max_lag
        # cycles.
        publish_now = self._publish_cycle(cycle_updates)
        faults.fire("agg:pre_journal", who=self._who())
        self._maybe_journal(publish_now)
        hb_dead = [w for w in self.hb.dead(now=float(self.cycles))
                   if w in self.workers]
        status = {
            # alive/dead roll up the whole subtree: direct workers plus
            # everything the child-node batches reported below them
            "alive": sorted(set(self.workers) | {
                a for st in self._subtree.values()
                for a in st.get("alive", [])}),
            "dead": sorted(set(self.dead) | {
                d for st in self._subtree.values()
                for d in st.get("dead", [])}),
            "stale": stale,
            "cycles": self.cycles,
            "merged_updates": self.merged_updates,
            "hash_dropped": dict(self.hash_dropped),
            "rb_heads": {n: dict(h) for n, h in self.rb_heads.items()},
            "rb_lost": {n: dict(d) for n, d in self.rb_lost.items()},
            "corrupt_skipped": dict(self.corrupt_skipped),
            "coalesced_cycles": self.coalesced_cycles,
            "stragglers": self._stragglers,
            "hb_dead": hb_dead,
            "health": {w: {"state": h["state"],
                           "quarantined": h["quarantined"],
                           "transitions": h["transitions"]}
                       for w, h in self.health.items()},
            # tree: per-child-node consumption + back-pressure rollup
            "nodes": {nid: {"state": self.health.get(nid, {}).get(
                                "state", HEALTHY),
                            "last_seq": int(nc["last_seq"]),
                            "alive": not nc.get("retired", False),
                            "workers": nc.get("workers", []),
                            "subtree": self._subtree.get(nid, {})}
                      for nid, nc in self.nodes.items()},
            "stream_lost": dict(self.stream_lost),
            "node_coalesced": dict(self.node_coalesced),
            "hash_shards": int(self.config.hash_shards or 0),
            "shard_publishes": self.shard_publishes,
            "time": time.time(),
        }
        self._publish_status(status)
        faults.fire("agg:cycle_end", cycle=self.cycles, who=self._who())
        return status

    def _fold_polled(self, polled: list) -> int:
        """Fold every successfully-snapshotted worker, in worker-id order."""
        updates = 0
        for wid, w, snaps, seq_before in polled:
            updates += self._fold_worker(wid, w, snaps)
            faults.fire("agg:post_merge", wid=wid, who=self._who())
            self._ok_event(wid, advanced=w.get("seq", 0) > seq_before)
        return updates

    def _publish_cycle(self, cycle_updates: int) -> bool:
        """Rebuild + publish the global view (coalescing under
        back-pressure). Returns whether an output event happened this
        cycle; NodeAggregator overrides to emit a delta batch instead."""
        cfg = self.config
        publish_now = (bool(cycle_updates) or not self._published
                       or self._publish_lag > 0)   # flush pending coalesce
        if (publish_now and cfg.coalesce_threshold is not None
                and self._published
                and cycle_updates > cfg.coalesce_threshold
                and self._publish_lag + 1 < cfg.publish_max_lag):
            self._publish_lag += 1
            self.coalesced_cycles += 1
            publish_now = False
        if publish_now:
            self._publish_lag = 0
            faults.fire("agg:pre_publish", who=self._who())
            self.last_states = self.global_states()
            self.view.publish(self.last_states)
            self._publish_shards()
            self._published = True
            faults.fire("agg:post_publish", who=self._who())
        return publish_now

    def _publish_shards(self) -> None:
        """Republish DIRTY shards of the sharded global hash views: a
        shard whose key-partition content didn't change since its last
        publish is skipped, so steady-state republish cost scales with the
        touched keyspace, not the table size."""
        if self.shards is None:
            return
        n_sh = self.shards.n_shards
        for spec in self.specs:
            if spec.kind != MapKind.HASH:
                continue
            ck, cv = M.n_hash_content(self.hash_tbl[spec.name])
            sh = M.n_shard_of_keys(ck, spec.max_entries, n_sh)
            for s in range(n_sh):
                m = sh == s
                k_s, v_s = ck[m], cv[m]
                last = self._shard_last.get((spec.name, s))
                if last is not None and np.array_equal(last[0], k_s) \
                        and np.array_equal(last[1], v_s):
                    continue
                st = M.n_hash_canonical(
                    spec, dict(zip(k_s.tolist(), v_s.tolist())))
                self.shards.publish(spec.name, s, st)
                self._shard_last[(spec.name, s)] = (k_s, v_s)
                self.shard_publishes += 1

    def _publish_status(self, status: dict) -> None:
        self.view.publish_status(status)

    def _maybe_journal(self, output_happened: bool) -> None:
        cfg = self.config
        if not cfg.journal:
            # no crash-consistency promised: release child batches eagerly
            for nc in self.nodes.values():
                if nc.get("stream") is not None:
                    nc["stream"].ack(nc["last_seq"])
            return
        self._journal_due += 1
        if self._journal_due < max(1, cfg.journal_every):
            return
        if not self._journal_ok(output_happened):
            return
        SH._atomic_json(self._journal_path(), self._journal_dict())
        self._journal_due = 0
        self._post_journal()
        # ack only what the journal now covers: the stream writer GCs
        # acked batches, and a crashed parent must be able to re-read
        # anything newer than its last journal
        for nc in self.nodes.values():
            if nc.get("stream") is not None:
                nc["stream"].ack(nc["last_seq"])

    def _journal_ok(self, output_happened: bool) -> bool:
        return True          # root: any cycle boundary is consistent

    def _post_journal(self) -> None:
        pass

    # ------------------------------------------------------------ tree fold
    def _discover_nodes(self) -> None:
        """Admit child node-aggregators (nodes whose registered parent is
        this level). Dead nodes follow the worker rules: harvested once,
        retired, re-admitted with their stream cursor intact when a new
        incarnation (boot change) appears."""
        for nid in SH.list_nodes(self.root):
            info = SH.node_info(self.root, nid)
            if info.get("parent") != self._node_id:
                continue
            boot = info.get("boot")
            cur = self.nodes.get(nid)
            if cur is not None and cur["boot"] == boot:
                continue
            if cur is not None:
                last = int(cur["last_seq"])     # restart: cursor continues
                self._set_state(nid, HEALTHY, "new_incarnation")
            else:
                jn = self._journal_nodes.pop(nid, None)
                last = int(jn["last_seq"]) if jn else 0
            stream = (SH.DeltaStream.attach(self.root, nid)
                      if SH.DeltaStream.exists(self.root, nid) else None)
            if stream is not None and stream.head() < last:
                last = 0        # stream was wiped: node re-emits from zero
            self.nodes[nid] = {
                "boot": boot, "stream": stream, "last_seq": last,
                "workers": info.get("workers", []),
                "children": info.get("children", []),
            }
            if nid not in self.health:
                self.health[nid] = _fresh_health()
                self.hb.beat(nid, t=float(self.cycles))

    def _poll_node_children(self) -> int:
        """Consume every child node's delta stream past our cursor and fold
        the batches. Batches are idempotent WAL entries: a crashed parent
        re-reads anything past its journaled cursor; corrupt or GC'd-away
        batches are detect-and-skip, counted in stream_lost."""
        self._discover_nodes()
        updates = 0
        for nid in sorted(self.nodes):
            nc = self.nodes[nid]
            if nc.get("retired"):
                continue
            stream = nc.get("stream")
            if stream is None:
                if SH.DeltaStream.exists(self.root, nid):
                    nc["stream"] = stream = \
                        SH.DeltaStream.attach(self.root, nid)
                else:
                    continue
            faults.fire("agg:pre_merge", wid=nid, cycle=self.cycles,
                        who=self._who())
            before = nc["last_seq"]
            for seq, payload in stream.poll(nc["last_seq"]):
                if payload is None:
                    self.stream_lost[nid] = \
                        self.stream_lost.get(nid, 0) + 1
                else:
                    updates += self._fold_batch(nid, payload)
                nc["last_seq"] = seq
            faults.fire("agg:post_merge", wid=nid, who=self._who())
            if not SH.node_alive(self.root, nid):
                # harvest-once then retire (same contract as dead workers:
                # the merged contribution stays; a new boot re-admits)
                nc["retired"] = True
                self._set_state(nid, DEAD, "node_gone")
            else:
                self._ok_event(nid, advanced=nc["last_seq"] > before)
        return updates

    def _fold_batch(self, nid: str, payload: dict) -> int:
        """Fold one child delta batch into this level's accumulators. Every
        piece is commutative/idempotent-by-construction: summary deltas
        add, hash adds re-coalesce, ringbuf records carry their original
        (step, wid, pos) tags end-to-end (replayed positions below our
        per-worker head are skipped)."""
        js = payload["json"]
        arrs = payload["arrays"]
        spec_of = {s.name: s for s in self.specs}
        for key, arr in arrs.items():
            parts = key.split("/")
            if parts[0] == "summary" and parts[1] in self.summary:
                with np.errstate(over="ignore"):
                    self.summary[parts[1]][parts[2]] += \
                        np.asarray(arr, np.int64)
        for name in self.hash_tbl:
            ak = arrs.get(f"hash/{name}/keys")
            if ak is not None and ak.size:
                ad = np.asarray(arrs[f"hash/{name}/deltas"], np.int64)
                ak = np.asarray(ak, np.int64)
                M.n_hash_fetch_add_batch(self.hash_tbl[name], ak, ad)
                res_k, _ = M.n_hash_content(self.hash_tbl[name])
                lost = int(np.count_nonzero(~np.isin(ak, res_k)))
                if lost:
                    self.hash_dropped[name] += lost
            for k in js.get("hash_dels", {}).get(name, []):
                M.n_hash_delete(self.hash_tbl[name], int(k))
        for name, per_wid in js.get("rb_meta", {}).items():
            if name not in self.rb_tagged:
                continue
            spec = spec_of[name]
            for wid, meta in per_wid.items():
                buf = self.rb_tagged[name].setdefault(wid, [])
                cur_head = self.rb_heads[name].get(wid, 0)
                steps = arrs.get(f"rb/{name}/{wid}/steps")
                if steps is not None and np.asarray(steps).size:
                    poss = np.asarray(arrs[f"rb/{name}/{wid}/pos"],
                                      np.int64)
                    recs = np.asarray(arrs[f"rb/{name}/{wid}/recs"],
                                      np.int64)
                    for s, p, rec in zip(
                            np.asarray(steps, np.int64).tolist(),
                            poss.tolist(), recs):
                        if p < cur_head:
                            continue    # replayed batch: already folded
                        buf.append(((int(s), wid, int(p)), rec))
                    del buf[:-spec.max_entries]
                self.rb_heads[name][wid] = max(cur_head,
                                               int(meta["head"]))
                self.rb_step_floor[name][wid] = max(
                    self.rb_step_floor[name].get(wid, 0),
                    int(meta.get("floor", 0)))
                lost_d = int(meta.get("lost_delta", 0))
                if lost_d:
                    self.rb_lost[name][wid] = \
                        self.rb_lost[name].get(wid, 0) + lost_d
        for name, v in js.get("hash_dropped_delta", {}).items():
            if name in self.hash_dropped:
                self.hash_dropped[name] += int(v)
        for wid, v in js.get("corrupt_delta", {}).items():
            self.corrupt_skipped[wid] = \
                self.corrupt_skipped.get(wid, 0) + int(v)
        if js.get("coalesced_delta"):
            self.node_coalesced[nid] = \
                self.node_coalesced.get(nid, 0) + int(js["coalesced_delta"])
        for wid, h in js.get("health", {}).items():
            self.health[wid] = h        # transitive subtree health rollup
        self._subtree[nid] = {"alive": js.get("alive", []),
                              "dead": js.get("dead", []),
                              "stream_lost": js.get("stream_lost", {})}
        return int(js.get("updates", 0))

    def global_states(self) -> dict:
        """The merged global view, deterministic for a given set of worker
        contributions: summary kinds are element-wise sums, hash tables are
        canonicalized (sorted-key rebuild), ringbufs are the (step, wid,
        seq) interleave of every worker's retained records."""
        out = {}
        for spec in self.specs:
            if M.is_summary_kind(spec.kind):
                out[spec.name] = {f: a.copy()
                                  for f, a in self.summary[spec.name].items()}
            elif spec.kind == MapKind.HASH:
                items = M.n_hash_items(self.hash_tbl[spec.name])
                out[spec.name] = M.n_hash_canonical(spec, items)
            elif spec.kind == MapKind.RINGBUF:
                tagged = [t for buf in self.rb_tagged[spec.name].values()
                          for t in buf]
                total = sum(self.rb_heads[spec.name].values())
                out[spec.name] = M.ringbuf_merge_global(spec, tagged, total)
        return out

    def loop(self, watch: float | None = None, once: bool = False,
             out=sys.stdout) -> None:
        watch = self.config.poll_interval if watch is None else watch
        while True:
            status = self.poll_once()
            print(f"=== {time.strftime('%H:%M:%S')} agg cycle "
                  f"{status['cycles']} alive={status['alive']} "
                  f"dead={status['dead']} stale={status['stale']} "
                  f"merged={status['merged_updates']}", file=out)
            for spec in self.specs:
                if spec.name in self.last_states:
                    print("\n".join(_summarize_state(
                        spec, self.last_states[spec.name])), file=out)
            if once:
                break
            time.sleep(watch)


# --------------------------------------------------------------------------
# bpftool-style CLI
# --------------------------------------------------------------------------

_SUBCOMMANDS = ("map", "prog", "attach", "detach", "agg", "node", "fleet")


def _section_loader(root: str, section: str, worker: str | None):
    """One attach for the whole CLI invocation; returns name -> state."""
    if section == "global":
        view = GlobalView.attach(root)
        return view.snapshot
    region = ShmRegion.attach(root, mode="r", worker_id=worker)
    if section == "device":
        return region.snapshot_device
    return lambda name: {f: np.array(a) for f, a in region.host[name].items()}


def _default_section(root: str) -> str:
    return "global" if GlobalView.exists(root) else "device"


def _state_to_json(spec: MapSpec, st: dict) -> dict:
    return {"name": spec.name, "kind": spec.kind.value,
            **{f: np.asarray(a).tolist() for f, a in st.items()}}


def _top_entries(spec: MapSpec, st: dict, n: int) -> list[tuple]:
    """(key, value) rows sorted by value desc — bpftool's `map top`."""
    if spec.kind == MapKind.ARRAY:
        vals = np.asarray(st["values"])
        idx = np.argsort(-vals, kind="stable")[:n]
        return [(int(i), int(vals[i])) for i in idx if vals[i] != 0]
    if spec.kind == MapKind.PERCPU_ARRAY:
        tot = np.asarray(st["values"]).sum(axis=0)
        idx = np.argsort(-tot, kind="stable")[:n]
        return [(int(i), int(tot[i])) for i in idx if tot[i] != 0]
    if spec.kind == MapKind.HASH:
        items = M.n_hash_items(st)
        return sorted(items.items(), key=lambda kv: (-kv[1], kv[0]))[:n]
    if spec.kind == MapKind.LOG2HIST:
        bins = np.asarray(st["bins"])
        idx = np.argsort(-bins, kind="stable")[:n]
        return [(int(i), int(bins[i])) for i in idx if bins[i] != 0]
    if spec.kind == MapKind.RINGBUF:
        recs, _ = M.n_ringbuf_drain(
            {f: np.asarray(a) for f, a in st.items()}, 0)
        return [(i, tuple(r)) for i, r in enumerate(recs[-n:])]
    return []


def _cmd_map_shard(root: str, args) -> int:
    """`map dump|top --shard K`: one keyspace partition of the sharded
    global hash views (global/shards/), seqlock+CRC consistent."""
    if not SH.HashShards.exists(root):
        print("no sharded views published — run `agg --shards K` first",
              file=sys.stderr)
        return 1
    shards = SH.HashShards.attach(root)
    meta = SH.HashShards.read_meta(root)
    k = int(args.shard)
    if not 0 <= k < meta["n_shards"]:
        print(f"shard {k} out of range (n_shards={meta['n_shards']})",
              file=sys.stderr)
        return 1
    specs = [s for s in SH.read_meta_specs(root)
             if s.kind == MapKind.HASH and args.name in (None, s.name)]
    if not specs:
        print(f"no hash map matches {args.name!r} (shards hold hash maps "
              f"only)", file=sys.stderr)
        return 1
    out_json = []
    for spec in specs:
        st, seq, _ = shards.snapshot(spec.name, k)
        if args.action == "dump":
            if args.json:
                out_json.append({**_state_to_json(spec, st),
                                 "shard": k, "seq": seq})
            else:
                print(f"# shard={k}/{meta['n_shards']} seq={seq}")
                print("\n".join(_summarize_state(spec, st)))
        else:
            rows = _top_entries(spec, st, args.top_n)
            if args.json:
                out_json.append({"name": spec.name, "shard": k,
                                 "top": rows})
            else:
                print(f"[{spec.name}] shard {k}/{meta['n_shards']} "
                      f"top {len(rows)}:")
                for key, v in rows:
                    print(f"  {key:>8} : {v}")
    if args.json:
        print(json.dumps(out_json, indent=1))
    return 0


def _drop_accounting(root: str) -> list[str]:
    """Back-pressure/drop counters from the aggregation status, for the
    `map` footer: what the numbers being dumped do NOT include."""
    if not GlobalView.exists(root):
        return []
    status = GlobalView.attach(root).read_status()
    lines = []
    rb_lost = {n: d for n, d in status.get("rb_lost", {}).items()
               if any(d.values())}
    if rb_lost:
        lines.append(f"rb_lost={rb_lost}")
    hd = {n: v for n, v in status.get("hash_dropped", {}).items() if v}
    if hd:
        lines.append(f"hash_dropped={hd}")
    if status.get("coalesced_cycles"):
        lines.append(f"coalesced_cycles={status['coalesced_cycles']}")
    sl = {n: v for n, v in status.get("stream_lost", {}).items() if v}
    if sl:
        lines.append(f"stream_lost={sl}")
    return lines


def _cmd_map(root: str, args) -> int:
    if getattr(args, "shard", None) is not None:
        return _cmd_map_shard(root, args)
    specs = SH.read_meta_specs(root)
    section = args.section or _default_section(root)
    wids = SH.list_workers(root)
    if section == "global" and not GlobalView.exists(root):
        print("no global view published yet — run `agg` first, or pass "
              "--section device --worker W", file=sys.stderr)
        return 1
    if section in ("device", "host") and wids and args.worker is None:
        print(f"fleet layout: pass --worker (workers: {', '.join(wids)})",
              file=sys.stderr)
        return 1
    if args.worker is not None and _check_workers(root, [args.worker]):
        return 1
    chosen = [s for s in specs if args.name in (None, s.name)]
    if not chosen:
        print(f"no such map: {args.name}", file=sys.stderr)
        return 1
    load = _section_loader(root, section, args.worker)
    out_json = []
    for spec in chosen:
        st = load(spec.name)
        if args.action == "dump":
            if args.json:
                out_json.append(_state_to_json(spec, st))
            else:
                print(f"# section={section}"
                      + (f" worker={args.worker}" if args.worker else ""))
                print("\n".join(_summarize_state(spec, st)))
        else:  # top
            rows = _top_entries(spec, st, args.top_n)
            if args.json:
                out_json.append({"name": spec.name, "top": rows})
            else:
                print(f"[{spec.name}] top {len(rows)} ({section}):")
                for k, v in rows:
                    print(f"  {k:>8} : {v}")
    if args.json:
        print(json.dumps(out_json, indent=1))
    elif section == "global":
        footer = _drop_accounting(root)
        if footer:
            print("# drops: " + " ".join(footer))
    return 0


def _worker_cache_counters(root: str) -> dict:
    """wid -> artifact-cache hit/miss counters, from worker status.json."""
    out = {}
    for wid in SH.list_workers(root) or [None]:
        try:
            status = ShmRegion.attach(root, mode="r",
                                      worker_id=wid).read_status()
        except OSError:
            continue
        if status.get("cache"):
            out[wid or "-"] = status["cache"]
    return out


def _cmd_prog_cache(root: str, args) -> int:
    """`prog cache ls|stat|purge [KEY]` over the fleet artifact cache at
    <root>/cache (the directory setup_shm auto-joins)."""
    from .artifact_cache import ArtifactCache
    action = args.arg or "stat"
    if action not in ("ls", "stat", "purge"):
        print(f"prog cache: unknown action {action!r} (ls|stat|purge)",
              file=sys.stderr)
        return 2
    cache = ArtifactCache(os.path.join(root, "cache"))
    if action == "ls":
        rows = cache.ls()
        if args.json:
            print(json.dumps(rows, indent=1))
        else:
            print(f"{'KEY':26s} {'KIND':6s} {'BYTES':>10s}")
            for r in rows:
                print(f"{r['key']:26s} {r['kind']:6s} {r['size']:>10d}")
            print(f"{len(rows)} artifact(s), "
                  f"{sum(r['size'] for r in rows)} bytes")
        return 0
    if action == "purge":
        n = cache.purge(args.arg2)
        print(f"purged {n} artifact(s)"
              + (f" for key {args.arg2}" if args.arg2 else ""))
        return 0
    # stat: disk contents + per-worker hit/miss counters (status.json)
    st = cache.stats()
    evicted = sum(c.get("evicted", 0)
                  for c in _worker_cache_counters(root).values())
    out = {"root": st["root"], "entries": st["entries"],
           "bytes": st["bytes"], "max_bytes": st["max_bytes"],
           "evicted": evicted,
           "workers": _worker_cache_counters(root)}
    if args.json:
        print(json.dumps(out, indent=1))
        return 0
    budget = ("no budget" if out["max_bytes"] is None
              else f"budget {out['max_bytes']} bytes")
    print(f"artifact cache {out['root']}: {out['entries']} entr"
          f"{'y' if out['entries'] == 1 else 'ies'}, {out['bytes']} bytes "
          f"({budget}, {out['evicted']} evicted)")
    for wid, c in sorted(out["workers"].items()):
        print(f"  worker {wid}: hits={c.get('hits', 0)} "
              f"misses={c.get('misses', 0)} stores={c.get('stores', 0)} "
              f"corrupt={c.get('corrupt', 0)} "
              f"evicted={c.get('evicted', 0)}")
    return 0


def _cmd_prog_relocate(root: str, args) -> int:
    """`prog relocate NAME`: dry-run — abstract-verify the published
    object, print its relocation record, and show how it binds against
    this fleet's concrete registry (without touching any worker)."""
    from . import reloc
    from .loader import ProgramObject
    name = args.arg
    if not name:
        print("prog relocate needs a program name", file=sys.stderr)
        return 2
    progs = SH.read_programs(root)
    if name not in progs:
        print(f"no such program: {name} (loaded: {sorted(progs)})",
              file=sys.stderr)
        return 1
    obj = ProgramObject.from_json(progs[name])
    try:
        vabs = reloc.verify_relocatable(obj)
    except Exception as e:
        print(f"abstract verification failed: {e}", file=sys.stderr)
        return 1
    rows = reloc.relocation_table(vabs)
    specs = SH.read_meta_specs(root)
    fd_of = {s.name: i for i, s in enumerate(specs)}
    bound = err = None
    try:
        bound = reloc.resolve(vabs, fd_of, specs)
    except reloc.RelocationError as e:
        err = str(e)
    out = {"program": name, "tier": vabs.tier,
           "declared_maps": [ml.name for ml in vabs.reloc.map_layouts],
           "registry": [s.name for s in specs],
           "relocations": rows, "resolved": bound is not None,
           "error": err,
           "bound": reloc.relocation_table(bound) if bound else None}
    if args.json:
        print(json.dumps(out, indent=1))
        return 0 if bound else 1
    print(f"program {name}: {len(rows)} relocation(s), "
          f"declared maps {out['declared_maps']}")
    for r in rows:
        if r["kind"] == "map":
            print(f"  insn {r['insn']:3d}  map  {r['symbol']:16s} "
                  f"local_fd={r['local_fd']}  {r['disasm']}")
        else:
            print(f"  insn {r['insn']:3d}  ctx  {r['symbol']:16s} "
                  f"byte={r['byte']}  {r['disasm']}")
    if bound is not None:
        binds = ", ".join(
            f"{r['symbol']}->fd{r['bound_fd']}"
            for r in out["bound"] if r["kind"] == "map")
        print(f"resolves against registry {out['registry']}: "
              f"{binds or 'no map refs'}")
    else:
        print(f"does NOT resolve against this registry: {err}")
    return 0 if bound else 1


def _cmd_prog(root: str, args) -> int:
    from .loader import ProgramObject
    if args.action == "cache":
        return _cmd_prog_cache(root, args)
    if args.action == "relocate":
        return _cmd_prog_relocate(root, args)
    progs = SH.read_programs(root)
    wids = SH.list_workers(root)
    links: dict[str, list] = {}
    for wid in wids or [None]:
        try:
            status = ShmRegion.attach(root, mode="r",
                                      worker_id=wid).read_status()
        except OSError:
            continue
        promos = status.get("promotions", {})
        for lid, target in status.get("links", {}).items():
            pr = promos.get(lid, {})
            links.setdefault(wid or "-", []).append(
                (lid, target, pr.get("lane", "?"), pr.get("state", "?")))
    rows = []
    for name, obj_json in progs.items():
        obj = ProgramObject.from_json(obj_json)
        rows.append({"name": name, "type": obj.prog_type,
                     "attach_to": obj.attach_to,
                     "maps": [m["name"] for m in obj.maps]})
    if args.json:
        print(json.dumps({"programs": rows,
                          "links": {w: ls for w, ls in links.items()}},
                         indent=1))
        return 0
    print(f"{'NAME':20s} {'TYPE':12s} {'ATTACH_TO':24s} MAPS")
    for r in rows:
        print(f"{r['name']:20s} {r['type']:12s} "
              f"{str(r['attach_to']):24s} {','.join(r['maps'])}")
    for w, ls in sorted(links.items()):
        for lid, target, lane, state in ls:
            print(f"link {lid} -> {target} (worker {w}) "
                  f"lane={lane} promotion={state}")
    return 0


def _check_workers(root: str, requested) -> int:
    """0 if every requested worker id is registered, else 1 + message."""
    known = SH.list_workers(root)
    unknown = [w for w in (requested or []) if w not in known]
    if unknown:
        print(f"unknown worker(s): {', '.join(unknown)} "
              f"(registered: {', '.join(known) or 'none'})", file=sys.stderr)
        return 1
    return 0


def _cmd_attach(root: str, args) -> int:
    if _check_workers(root, args.worker):
        return 1
    with open(args.object) as f:
        obj_json = f.read()
    mode = args.mode or ("table" if args.live else None)
    req = {"op": "load_attach", "object": obj_json,
           "target": args.target, "live": args.live or mode == "table",
           "promote": not args.no_promote}
    if mode is not None:
        req["mode"] = mode
    wids = args.worker or SH.list_workers(root)
    if wids:
        reached = SH.fanout_request(root, req, wids)
        print(f"queued {'live ' if args.live else ''}load+attach of "
              f"{args.object} to workers {reached}")
    else:
        ShmRegion.attach(root).request(req)
        print(f"queued {'live ' if args.live else ''}load+attach "
              f"of {args.object}")
    return 0


def _cmd_detach(root: str, args) -> int:
    if _check_workers(root, args.worker):
        return 1
    req = {"op": "detach", "link_id": args.link_id}
    wids = args.worker or SH.list_workers(root)
    if wids:
        reached = SH.fanout_request(root, req, wids)
        print(f"queued detach of link {args.link_id} to workers {reached}")
    else:
        ShmRegion.attach(root).request(req)
        print(f"queued detach of link {args.link_id}")
    return 0


def _cmd_node(root: str, args) -> int:
    """`node run|ls|rm`: one level of the aggregation tree. `run` hosts a
    NodeAggregator for a worker group (its parent — another node or the
    global root — consumes the delta stream it emits); `ls` shows the
    registered tree topology + stream cursors; `rm` retires a node's
    registration (its stream stays for the parent to drain)."""
    from .treeagg import NodeAggregator
    if args.action == "ls":
        rows = []
        for nid in SH.list_nodes(root):
            info = SH.node_info(root, nid) or {}
            stream = SH.DeltaStream.attach(root, nid)
            rows.append({"node": nid, "parent": info.get("parent"),
                         "workers": info.get("workers", []),
                         "children": info.get("children", []),
                         "alive": SH.node_alive(root, nid),
                         "head": stream.head(), "acked": stream.acked()})
        if args.json:
            print(json.dumps(rows, indent=1))
            return 0
        if not rows:
            print("no nodes registered")
            return 0
        print(f"{'NODE':10s} {'PARENT':10s} {'ALIVE':6s} "
              f"{'HEAD':>6s} {'ACKED':>6s} WORKERS/CHILDREN")
        for r in rows:
            members = ",".join(r["workers"] + r["children"]) or "-"
            print(f"{r['node']:10s} {str(r['parent'] or '-'):10s} "
                  f"{('yes' if r['alive'] else 'no'):6s} "
                  f"{r['head']:>6d} {r['acked']:>6d} {members}")
        return 0
    if args.action == "rm":
        if not args.node_id:
            print("node rm needs a node id", file=sys.stderr)
            return 2
        if not SH.unregister_node(root, args.node_id):
            print(f"no such node: {args.node_id}", file=sys.stderr)
            return 1
        print(f"retired node {args.node_id} (stream left for the parent "
              f"to drain)")
        return 0
    # run
    if not args.node_id:
        print("node run needs a node id", file=sys.stderr)
        return 2
    workers = [w for w in (args.workers or "").split(",") if w]
    children = [c for c in (args.children or "").split(",") if c]
    if _check_workers(root, workers):
        return 1
    if not workers and not children:
        # group-only start: trainers that join with
        # --worker-group <node_id> are claimed dynamically
        print(f"node {args.node_id}: no explicit members — folding "
              f"workers that join group {args.node_id!r}")
    cfg = AggregatorConfig()
    if args.no_device_fold:
        cfg.device_fold = False
    na = NodeAggregator(root, args.node_id, workers=workers,
                        children=children, parent=args.parent, config=cfg)
    na.loop(watch=args.watch, once=args.once)
    return 0


def _cmd_fleet(root: str, args) -> int:
    """`fleet health`: the per-worker state machine the aggregation engine
    maintains (HEALTHY/DEGRADED/STALE/DEAD, quarantine, transitions) as
    published in global/status.json."""
    if not GlobalView.exists(root):
        print("no aggregated fleet — run `agg` first", file=sys.stderr)
        return 1
    status = GlobalView.attach(root).read_status()
    if not status:
        print("no aggregation status published yet", file=sys.stderr)
        return 1
    cache_by_worker = _worker_cache_counters(root)
    if args.json:
        print(json.dumps({**status, "cache": cache_by_worker}, indent=1))
        return 0
    print(f"fleet health @ cycle {status.get('cycles', 0)}: "
          f"alive={status.get('alive', [])} dead={status.get('dead', [])} "
          f"stale={status.get('stale', [])}")
    extras = []
    for key in ("stragglers", "hb_dead"):
        if status.get(key):
            extras.append(f"{key}={status[key]}")
    if any(status.get("corrupt_skipped", {}).values()):
        extras.append(f"corrupt_skipped={status['corrupt_skipped']}")
    if any(v for d in status.get("rb_lost", {}).values()
           for v in d.values()):
        extras.append(f"rb_lost={status['rb_lost']}")
    if status.get("coalesced_cycles"):
        extras.append(f"coalesced_cycles={status['coalesced_cycles']}")
    if any(status.get("stream_lost", {}).values()):
        extras.append(f"stream_lost={status['stream_lost']}")
    if status.get("hash_shards"):
        extras.append(f"hash_shards={status['hash_shards']} "
                      f"shard_publishes={status.get('shard_publishes', 0)}")
    if cache_by_worker:
        hits = sum(c.get("hits", 0) for c in cache_by_worker.values())
        misses = sum(c.get("misses", 0) for c in cache_by_worker.values())
        corrupt = sum(c.get("corrupt", 0) for c in cache_by_worker.values())
        extras.append(f"cache_hits={hits} cache_misses={misses}"
                      + (f" cache_corrupt={corrupt}" if corrupt else ""))
    if extras:
        print("  " + " ".join(extras))
    nodes = status.get("nodes", {})
    if nodes:
        print(f"{'NODE':12s} {'STATE':10s} {'SEQ':>6s} {'ALIVE':>6s} "
              f"WORKERS/SUBTREE")
        for nid, n in sorted(nodes.items()):
            sub = n.get("subtree", {})
            members = ",".join(n.get("workers", [])) or "-"
            if sub.get("alive"):
                members += f" (subtree alive={len(sub['alive'])})"
            print(f"{nid:12s} {n.get('state', '?'):10s} "
                  f"{n.get('last_seq', 0):>6d} "
                  f"{('yes' if n.get('alive') else 'no'):>6s} {members}")
    print(f"{'WORKER':12s} {'STATE':10s} {'QUARANTINED':12s} TRANSITIONS")
    for wid, h in sorted(status.get("health", {}).items()):
        print(f"{wid:12s} {h['state']:10s} "
              f"{('yes' if h.get('quarantined') else '-'):12s} "
              f"{len(h.get('transitions', []))}")
        for cyc, frm, to, reason in h.get("transitions", []):
            print(f"    cycle {cyc}: {frm} -> {to} ({reason})")
    return 0


def _main_bpftool(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.core.daemon")
    ap.add_argument("shm_dir")
    sub = ap.add_subparsers(dest="cmd", required=True)

    mp = sub.add_parser("map", help="dump or rank map contents")
    mp.add_argument("action", choices=("dump", "top"))
    mp.add_argument("name", nargs="?")
    mp.add_argument("--section", choices=("global", "device", "host"),
                    help="default: global if aggregated, else device")
    mp.add_argument("--worker", help="worker id for device/host sections")
    mp.add_argument("-n", "--top-n", type=int, default=10)
    mp.add_argument("--shard", type=int, default=None,
                    help="read one keyspace partition of the sharded "
                         "global hash views instead of a section")
    mp.add_argument("--json", action="store_true")

    pp = sub.add_parser("prog",
                        help="list programs/links, inspect the artifact "
                             "cache, or dry-run a relocation")
    pp.add_argument("action", choices=("list", "cache", "relocate"))
    pp.add_argument("arg", nargs="?",
                    help="cache: ls|stat|purge; relocate: program name")
    pp.add_argument("arg2", nargs="?",
                    help="cache purge: specific key (default: all)")
    pp.add_argument("--json", action="store_true")

    at = sub.add_parser("attach", help="queue load+attach (fleet fan-out)")
    at.add_argument("object", help="path to a ProgramObject json")
    at.add_argument("--target")
    at.add_argument("--mode", choices=("auto", "fused", "table"),
                    help="attach lane: auto picks the live table when it "
                         "is instantly available, fused forces the "
                         "epoch-bump (retrace) path, table forces the "
                         "live program table")
    at.add_argument("--no-promote", action="store_true",
                    help="pin a table-lane link to the interpreter "
                         "(skip background promotion to the fused lane)")
    at.add_argument("--live", action="store_true",
                    help="alias for --mode table (no retrace "
                         "in any worker)")
    at.add_argument("--worker", action="append",
                    help="restrict to worker id(s); default: all workers")

    dt = sub.add_parser("detach", help="queue a detach (fleet fan-out)")
    dt.add_argument("link_id", type=int)
    dt.add_argument("--worker", action="append")

    ag = sub.add_parser("agg", help="run the fleet aggregation engine")
    ag.add_argument("--watch", type=float, default=None,
                    help="poll cadence (default: AggregatorConfig."
                         "poll_interval)")
    ag.add_argument("--once", action="store_true")
    ag.add_argument("--tree", action="store_true",
                    help="hierarchical aggregation: group workers under "
                         "node-local aggregators (one process drives the "
                         "whole tree; use `node run` for one-process-per-"
                         "node fleets)")
    ag.add_argument("--fan-in", type=int, default=4,
                    help="workers (or child nodes) per node aggregator")
    ag.add_argument("--depth", type=int, default=1,
                    help="levels of node aggregators below the root")
    ag.add_argument("--shards", type=int, default=None,
                    help="also publish the global hash views partitioned "
                         "into K keyspace shards (map ... --shard K)")

    nd = sub.add_parser("node", help="node-level aggregators (tree levels)")
    nd.add_argument("action", choices=("run", "ls", "rm"))
    nd.add_argument("node_id", nargs="?")
    nd.add_argument("--workers", help="comma-separated worker group")
    nd.add_argument("--children", help="comma-separated child node ids")
    nd.add_argument("--parent", help="parent node id (default: the root)")
    nd.add_argument("--watch", type=float, default=None)
    nd.add_argument("--once", action="store_true")
    nd.add_argument("--no-device-fold", action="store_true",
                    help="use the numpy fold twins instead of the jitted "
                         "device reductions")
    nd.add_argument("--json", action="store_true")

    fl = sub.add_parser("fleet", help="fleet health / failure introspection")
    fl.add_argument("action", choices=("health",))
    fl.add_argument("--json", action="store_true")

    args = ap.parse_args(argv)
    if args.cmd == "fleet":
        return _cmd_fleet(args.shm_dir, args)
    if args.cmd == "map":
        return _cmd_map(args.shm_dir, args)
    if args.cmd == "prog":
        return _cmd_prog(args.shm_dir, args)
    if args.cmd == "attach":
        return _cmd_attach(args.shm_dir, args)
    if args.cmd == "detach":
        return _cmd_detach(args.shm_dir, args)
    if args.cmd == "node":
        return _cmd_node(args.shm_dir, args)
    if args.cmd == "agg":
        cfg = AggregatorConfig()
        if args.shards:
            cfg.hash_shards = args.shards
        if args.tree:
            from .treeagg import TreeAggregator
            TreeAggregator(args.shm_dir, fan_in=args.fan_in,
                           depth=args.depth, config=cfg).loop(
                watch=args.watch, once=args.once)
        else:
            Aggregator(args.shm_dir, config=cfg).loop(
                watch=args.watch, once=args.once)
        return 0
    return 2            # pragma: no cover - argparse enforces choices


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) >= 2 and argv[1] in _SUBCOMMANDS:
        return _main_bpftool(argv)

    ap = argparse.ArgumentParser()
    ap.add_argument("shm_dir")
    ap.add_argument("--watch", type=float, default=2.0)
    ap.add_argument("--once", action="store_true")
    ap.add_argument("--attach", help="path to a ProgramObject json to inject")
    ap.add_argument("--target", help="attach target for --attach")
    ap.add_argument("--live", action="store_true",
                    help="alias for --mode table (no retrace in the "
                         "target process)")
    ap.add_argument("--mode", choices=("auto", "fused", "table"))
    ap.add_argument("--no-promote", action="store_true")
    ap.add_argument("--detach", type=int, metavar="LINK_ID",
                    help="queue a detach of a previously applied link")
    args = ap.parse_args(argv)

    if not os.path.exists(os.path.join(args.shm_dir, "device", ".seq.npy")) \
            and SH.list_workers(args.shm_dir):
        print("fleet-layout region (no single-process section): use the "
              "subcommands — map/prog/attach/detach/agg", file=sys.stderr)
        return 1
    shm = ShmRegion.attach(args.shm_dir)
    if args.attach:
        with open(args.attach) as f:
            request_load_attach(shm, f.read(), args.target, live=args.live,
                                mode=args.mode,
                                promote=not args.no_promote)
        print(f"queued {'live ' if args.live else ''}load+attach "
              f"of {args.attach}")
        return
    if args.detach is not None:
        request_detach(shm, args.detach)
        print(f"queued detach of link {args.detach}")
        return
    while True:
        status = shm.read_status()
        print(f"=== {time.strftime('%H:%M:%S')} "
              f"programs: {list(shm.read_programs())} "
              f"live_gen: {status.get('live_gen', 0)} "
              f"links: {status.get('links', {})}")
        print(summarize(shm))
        if args.once:
            break
        time.sleep(args.watch)


if __name__ == "__main__":
    sys.exit(main())
