"""Fleet-wide AOT artifact cache — compile once, every worker reuses.

Every worker joining the fleet today pays the full ~0.3–5s trace+compile
of its step function even though DESIGN.md §9 proves the compiled graph
depends only on (map registry, ctx width, table dims, attach signature).
This cache turns that invariant into reuse: executables produced by
``fn.lower(*args).compile()`` are serialized (jax.experimental.
serialize_executable) and stored on disk under the canonical layout
fingerprint (core/layout.layout_fingerprint), alongside encoded
table-program images.  The Nth worker derives the same key from the same
trace inputs and deserializes in ~10ms instead of retracing — the
<100ms warm cold-join measured by benchmarks.measure_cold_join.

Durability model (same discipline as the shm plane, DESIGN.md §10/§11):

  * writes are atomic (tmp + os.replace) with a zlib.crc32 over the
    payload in a JSON meta sidecar — readers can never observe a torn
    artifact;
  * reads verify the CRC; a mismatch DELETES the entry, bumps the
    ``corrupt`` counter, and returns a miss — the caller recompiles.
    Corruption degrades to the cold path, it never crashes a worker and
    never serves a torn executable (chaos-drilled via the
    ``corrupt_artifact`` fault kind on the ``cache:post_store`` hook);
  * invalidation is purely key-derivation: any change to the fingerprint
    basis lands on a different key.  Stale entries are garbage, not
    hazards — ``purge`` (CLI: ``prog cache purge``) reclaims them.

Deserialization failures (version skew, backend mismatch) are treated
exactly like corruption: count, delete, recompile.
"""
from __future__ import annotations

import io
import json
import os
import pickle
import zlib

import numpy as np

from . import faults

COUNTER_KEYS = ("hits", "misses", "stores", "corrupt", "purged", "evicted")


class ArtifactCache:
    """One directory of <key>.bin payloads + <key>.json CRC sidecars.

    Safe for concurrent use by N processes: entries are content-complete
    before they are visible (atomic rename), reads never lock, and two
    workers racing to store the same key write identical bytes (the key
    IS the trace-stability invariant), so last-rename-wins is benign.

    ``max_bytes`` arms an LRU size budget: after every store, least-
    recently-used entries (payload mtime, refreshed on every hit) are
    deleted until the directory fits.  Eviction is safe for the same
    reason purge is — an evicted key is a future miss, and the caller's
    recompile path regenerates identical bytes.  The entry just written
    is never the eviction victim, so a single artifact larger than the
    budget still serves its own writer."""

    def __init__(self, root: str, max_bytes: int | None = None):
        self.root = str(root)
        self.max_bytes = max_bytes
        os.makedirs(self.root, exist_ok=True)
        self.counters: dict[str, int] = {k: 0 for k in COUNTER_KEYS}

    # ------------------------------------------------------------ raw bytes
    def _bin(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.bin")

    def _meta(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.json")

    def put_bytes(self, key: str, payload: bytes, kind: str,
                  meta: dict | None = None) -> None:
        binpath, metapath = self._bin(key), self._meta(key)
        tmp = f"{binpath}.{os.getpid()}.tmp"
        with open(tmp, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, binpath)
        mtmp = f"{metapath}.{os.getpid()}.tmp"
        with open(mtmp, "w") as f:
            json.dump({"kind": kind, "crc": zlib.crc32(payload),
                       "size": len(payload), **(meta or {})}, f)
        os.replace(mtmp, metapath)
        self.counters["stores"] += 1
        faults.fire("cache:post_store", path=binpath, key=key)
        self._evict(exclude=key)

    def get_bytes(self, key: str, kind: str | None = None) -> bytes | None:
        binpath, metapath = self._bin(key), self._meta(key)
        try:
            with open(metapath) as f:
                meta = json.load(f)
            with open(binpath, "rb") as f:
                payload = f.read()
        except (OSError, ValueError):
            self.counters["misses"] += 1
            return None
        bad = (zlib.crc32(payload) != meta.get("crc")
               or len(payload) != meta.get("size")
               or (kind is not None and meta.get("kind") != kind))
        if bad:
            self._drop_corrupt(key)
            return None
        self.counters["hits"] += 1
        try:                       # refresh LRU recency (payload mtime)
            os.utime(binpath)
        except OSError:
            pass
        return payload

    def _evict(self, exclude: str | None = None) -> int:
        """Delete LRU entries until the directory fits ``max_bytes``.
        Recency is the payload file's mtime (stores and hits both refresh
        it).  ``exclude`` shields the entry just written.  Returns the
        number of entries evicted."""
        if self.max_bytes is None:
            return 0
        entries = []        # (mtime, key, size)
        total = 0
        for r in self.ls():
            try:
                mtime = os.stat(self._bin(r["key"])).st_mtime
            except OSError:
                continue
            entries.append((mtime, r["key"], r["size"]))
            total += r["size"]
        entries.sort()      # oldest first
        n = 0
        for mtime, key, size in entries:
            if total <= self.max_bytes:
                break
            if key == exclude:
                continue
            for p in (self._bin(key), self._meta(key)):
                try:
                    os.unlink(p)
                except OSError:
                    pass
            total -= size
            n += 1
        self.counters["evicted"] += n
        return n

    def _drop_corrupt(self, key: str) -> None:
        self.counters["corrupt"] += 1
        for p in (self._bin(key), self._meta(key)):
            try:
                os.unlink(p)
            except OSError:
                pass

    # ------------------------------------------------------------ executables
    def put_step(self, key: str, compiled) -> bool:
        """Serialize one AOT-compiled executable. Returns False (and stores
        nothing) if this backend/version cannot serialize it — callers just
        lose reuse, never correctness."""
        try:
            from jax.experimental.serialize_executable import serialize
            payload, in_tree, out_tree = serialize(compiled)
            blob = pickle.dumps({"payload": payload, "in_tree": in_tree,
                                 "out_tree": out_tree},
                                protocol=pickle.HIGHEST_PROTOCOL)
        except Exception:
            return False
        self.put_bytes(key, blob, "step")
        return True

    def get_step(self, key: str):
        """Load + deserialize an executable, or None on miss/corruption."""
        blob = self.get_bytes(key, kind="step")
        if blob is None:
            return None
        try:
            from jax.experimental.serialize_executable import (
                deserialize_and_load)
            d = pickle.loads(blob)
            return deserialize_and_load(d["payload"], d["in_tree"],
                                        d["out_tree"])
        except Exception:
            # undetected-by-CRC skew (jax/backend version): same degrade
            self.counters["hits"] -= 1
            self._drop_corrupt(key)
            return None

    # ------------------------------------------------------------ table images
    def put_table(self, key: str, arrays: dict) -> None:
        """Store one encoded table-program image (isa.encode_table_program
        output + metadata rows) as an npz blob."""
        buf = io.BytesIO()
        np.savez(buf, **{k: np.asarray(v) for k, v in arrays.items()})
        self.put_bytes(key, buf.getvalue(), "table")

    def get_table(self, key: str) -> dict | None:
        blob = self.get_bytes(key, kind="table")
        if blob is None:
            return None
        try:
            with np.load(io.BytesIO(blob)) as z:
                return {k: z[k] for k in z.files}
        except Exception:
            self.counters["hits"] -= 1
            self._drop_corrupt(key)
            return None

    # ------------------------------------------------------------ introspection
    def ls(self) -> list[dict]:
        rows = []
        for fn in sorted(os.listdir(self.root)):
            if not fn.endswith(".json") or fn.endswith(".tmp"):
                continue
            key = fn[:-5]
            try:
                with open(self._meta(key)) as f:
                    meta = json.load(f)
            except (OSError, ValueError):
                continue
            rows.append({"key": key, "kind": meta.get("kind", "?"),
                         "size": meta.get("size", 0),
                         "crc": meta.get("crc", 0)})
        return rows

    def stats(self) -> dict:
        rows = self.ls()
        return {"root": self.root, "entries": len(rows),
                "bytes": sum(r["size"] for r in rows),
                "max_bytes": self.max_bytes,
                **self.counters}

    def purge(self, key: str | None = None) -> int:
        """Delete one entry (or all). Returns entries removed."""
        keys = [key] if key is not None else [r["key"] for r in self.ls()]
        n = 0
        for k in keys:
            existed = os.path.exists(self._meta(k)) or \
                os.path.exists(self._bin(k))
            for p in (self._bin(k), self._meta(k)):
                try:
                    os.unlink(p)
                except OSError:
                    pass
            if existed:
                n += 1
        self.counters["purged"] += n
        return n
