"""\"Kernel-mode\" probe baseline — the analogue of kernel uprobes.

Events cross the device->host boundary via io_callback (the int3 trap +
double context switch of the paper), execute in the reference interpreter
on host numpy maps, and the device waits. This is the baseline bpftime
beats by 10x; benchmarks/table1_probe_latency.py measures our version of
the same gap against the in-graph probe stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import vm
from .events import EVENT_WIDTH


def host_probe_stage(runtime, event_rows, step):
    """Insert a host round-trip probe-execution into a traced step.

    event_rows: traced i64[N, 16]. Side effects land in runtime.host_maps.
    Returns a token to thread (forces ordering).
    """
    attach = sorted(runtime.device_attach.items())
    progs = {pid: runtime.progs[pid] for _, pids in attach for pid in pids}

    def host_fn(rows_np, step_np):
        rows_np = np.asarray(rows_np)
        for (sid, kind), pids in attach:
            mask = (rows_np[:, 0] == sid) & (rows_np[:, 1] == kind)
            for pid in pids:
                p = progs[pid]
                for row in rows_np[mask]:
                    row = row.copy()
                    row[3] = int(step_np)
                    ctx = vm.pack_ctx([int(x) for x in row])
                    vm.run(p.insns, ctx, runtime.map_specs,
                           runtime.host_maps,
                           vm.Aux(time_ns=int(step_np), pid=runtime.syscalls.pid))
        return np.int64(rows_np.shape[0])

    return jax.experimental.io_callback(
        host_fn, jax.ShapeDtypeStruct((), jnp.int64),
        event_rows, step, ordered=True)
