"""Device-resident program-table interpreter — live attach/detach without
recompilation (the dispatch-as-data tier; DESIGN.md §9).

The fused/scan lanes (vectorized.py, jit.py) specialize the step HLO to the
attached program SET: any attach/detach changes the traced computation and
forces a retrace + XLA recompile — the exact restart-analogue the paper's
userspace runtime eliminates. This module compiles ONE generic in-graph eBPF
interpreter whose behavior is driven entirely by tensor DATA:

  * verified bytecode is packed by `isa.encode_table_program` into flat i64
    arrays (handler class, regs, immediates, pre-resolved jump targets,
    helper branch indices) and padded into a `max_programs x max_insns`
    table that rides inside the step's map-state pytree;
  * the interpreter is a `lax.while_loop` stepping a pc through the padded
    rows, dispatching on the encoded handler class with one `lax.switch`
    (ALU/cond ops use compute-all-then-select — branch-free on a vector
    machine), helper calls with a nested switch over the helper table and,
    inside map helpers, over the map registry as of compile time;
  * memory accesses reuse jit.py's word-oriented machinery via the
    dynamic-offset twins `dyn_word_load` / `dyn_word_store`; the verifier
    has proven every access in bounds before a program may be encoded
    (`verifier.check_table_encodable`), so no dynamic indexing can escape
    the padded table.

The compiled graph depends only on (map registry, ctx width, table dims) —
never on table contents — so `BpftimeRuntime.attach_live` / `detach_live`
just write new table rows + a generation counter through a donated buffer
update and the running compiled step picks them up on its next call: the
paper's attach-to-a-running-PID, with zero retrace.

Semantics are bit-identical to scan mode (`jit.run_over_events`): the same
maps.j_* twins, the same predication, the same aux handling — pinned by the
full differential corpus in tests/test_vm_jit_differential.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import isa, jit as J, maps as M
from .helpers import HELPERS
from .isa import (TABLE_FIELDS, TH_EXIT, STACK_BASE, STACK_SIZE, CTX_BASE)
from .verifier import (COMMUTATIVE_HELPERS, MapFootprint, VerifiedProgram,
                       footprints_disjoint)

I64 = jnp.int64

# stable helper branch order for TH_CALL dispatch (encode-time index)
TABLE_HELPER_IDS = tuple(sorted(HELPERS))
TABLE_HELPER_INDEX = {hid: i for i, hid in enumerate(TABLE_HELPER_IDS)}

# per-program metadata rows carried next to the packed insn arrays.
# "vec" routes the slot to the batched lockstep machine (still DATA — the
# scheduling decision rides in the table, so flipping it never retraces).
META_FIELDS = ("active", "site", "kind", "n_insns", "fuel", "vec")

# ALU handler order — index == (op & OP_MASK) >> 4
_ALU_ORDER = (isa.BPF_ADD, isa.BPF_SUB, isa.BPF_MUL, isa.BPF_DIV, isa.BPF_OR,
              isa.BPF_AND, isa.BPF_LSH, isa.BPF_RSH, isa.BPF_NEG, isa.BPF_MOD,
              isa.BPF_XOR, isa.BPF_MOV, isa.BPF_ARSH)
# cond-jump ops by (op & OP_MASK) >> 4 slot; None slots (ja/call/exit) are
# structurally present so the encoded index addresses the stack directly
_COND_ORDER = (None, isa.BPF_JEQ, isa.BPF_JGT, isa.BPF_JGE, isa.BPF_JSET,
               isa.BPF_JNE, isa.BPF_JSGT, isa.BPF_JSGE, None, None,
               isa.BPF_JLT, isa.BPF_JLE, isa.BPF_JSLT, isa.BPF_JSLE)


def _spec_key(specs) -> tuple:
    """Hashable identity of a map universe (flags don't affect codegen)."""
    return tuple((s.name, s.kind.value, s.max_entries, s.rec_width,
                  s.num_shards) for s in specs)


def _specs_from_key(key):
    return [M.MapSpec(name=n, kind=M.MapKind(k), max_entries=me,
                      rec_width=rw, num_shards=ns)
            for n, k, me, rw, ns in key]


# --------------------------------------------------------------------------
# the generic interpreter (compiled once per map universe)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _build_core(spec_key: tuple, ctx_words: int):
    """Build the single-(program, event) interpreter for a fixed map
    universe. The returned `core(prog, ctx_row, maps_state, aux, pred)`
    traces a graph whose SHAPE depends only on (spec_key, ctx_words) and the
    padded insn dimension — table contents are pure data, which is the whole
    trace-stability invariant."""
    specs = _specs_from_key(spec_key)
    nmaps = len(specs)

    def core(prog: dict, ctx_row, maps_state, aux, pred):
        """prog: {field: i64[N]} packed rows + 'fuel' i64 scalar. Returns
        (r0, maps_state, aux); all side effects are gated on `pred` exactly
        like the scan-lane helpers, so an invalid event is a no-op (and the
        while loop is skipped outright via the initial done flag)."""
        n_pad = prog["hcls"].shape[0]
        zero = jnp.int64(0)

        def key_at(stack, ptr):
            return J.dyn_word_load(stack, ptr - STACK_BASE, jnp.int64(8))

        def map_switch(fd, mk_branch, operand, fallback):
            """Dispatch on a DYNAMIC map fd over the compile-time registry.
            mk_branch(spec) -> fn(operand) -> (r0, ms, aux)."""
            if nmaps == 0:
                return fallback
            idx = jnp.clip(fd, 0, nmaps - 1).astype(jnp.int32)
            return jax.lax.switch(idx, [mk_branch(sp) for sp in specs],
                                  operand)

        # ---- helper branches: (regs, stack, ms, aux) -> (r0, ms, aux)
        def h_map_lookup_elem(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])

            def mk(sp):
                def br(o2):
                    key, ms, aux = o2
                    st = ms[sp.name]
                    if sp.kind == M.MapKind.ARRAY:
                        r0 = M.j_array_lookup(st, key, pred)
                    elif sp.kind == M.MapKind.PERCPU_ARRAY:
                        r0 = M.j_percpu_lookup(st, aux["cpu"], key, pred)
                    elif sp.kind == M.MapKind.HASH:
                        r0 = M.j_hash_lookup(st, key, pred)
                    else:           # verifier-rejected kind; structural only
                        r0 = jnp.int64(0)
                    return r0, ms, aux
                return br
            return map_switch(regs[1], mk, (key, ms, aux), (zero, ms, aux))

        def h_map_update_elem(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])
            val = key_at(stack, regs[3])

            def mk(sp):
                def br(o2):
                    key, val, ms, aux = o2
                    st = ms[sp.name]
                    if sp.kind == M.MapKind.ARRAY:
                        new = M.j_array_update(st, key, val, pred)
                        r0 = jnp.int64(0)
                    elif sp.kind == M.MapKind.HASH:
                        new, ok = M.j_hash_update(st, key, val, pred)
                        r0 = jnp.where(ok, jnp.int64(0), jnp.int64(-7))
                    else:
                        return jnp.int64(0), ms, aux
                    return r0, {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (key, val, ms, aux),
                              (zero, ms, aux))

        def h_map_delete_elem(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])

            def mk(sp):
                def br(o2):
                    key, ms, aux = o2
                    if sp.kind != M.MapKind.HASH:
                        return jnp.int64(0), ms, aux
                    new, found = M.j_hash_delete(ms[sp.name], key, pred)
                    r0 = jnp.where(found, jnp.int64(0), jnp.int64(-2))
                    return r0, {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (key, ms, aux), (zero, ms, aux))

        def h_map_fetch_add(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])

            def mk(sp):
                def br(o2):
                    key, delta, ms, aux = o2
                    st = ms[sp.name]
                    if sp.kind == M.MapKind.ARRAY:
                        new, old = M.j_array_fetch_add(st, key, delta, pred)
                    elif sp.kind == M.MapKind.HASH:
                        new, old = M.j_hash_fetch_add(st, key, delta, pred)
                    else:
                        return jnp.int64(0), ms, aux
                    return old, {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (key, regs[3], ms, aux),
                              (zero, ms, aux))

        def h_percpu_fetch_add(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])

            def mk(sp):
                def br(o2):
                    key, delta, ms, aux = o2
                    if sp.kind != M.MapKind.PERCPU_ARRAY:
                        return jnp.int64(0), ms, aux
                    new, old = M.j_percpu_fetch_add(
                        ms[sp.name], aux["cpu"], key, delta, pred)
                    return old, {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (key, regs[3], ms, aux),
                              (zero, ms, aux))

        def h_hist_add(o):
            regs, stack, ms, aux = o

            def mk(sp):
                def br(o2):
                    v, ms, aux = o2
                    if sp.kind != M.MapKind.LOG2HIST:
                        return jnp.int64(0), ms, aux
                    new = M.j_hist_add(ms[sp.name], v, pred)
                    return jnp.int64(0), {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (regs[2], ms, aux),
                              (zero, ms, aux))

        def h_ringbuf_output(o):
            regs, stack, ms, aux = o
            size = regs[3]

            def mk(sp):
                def br(o2):
                    ptr, size, ms, aux = o2
                    if sp.kind != M.MapKind.RINGBUF:
                        return jnp.int64(0), ms, aux
                    # read rec_width lanes, zero those beyond the dynamic
                    # size — matches the scan lane's zero padding exactly
                    lanes = [jnp.where(
                        jnp.int64(8 * i) < size,
                        J.dyn_word_load(stack, ptr - STACK_BASE + 8 * i,
                                        jnp.int64(8)),
                        jnp.int64(0)) for i in range(sp.rec_width)]
                    new = M.j_ringbuf_emit(ms[sp.name], jnp.stack(lanes),
                                           pred)
                    return jnp.int64(0), {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (regs[2], size, ms, aux),
                              (zero, ms, aux))

        def h_ktime_get_ns(o):
            regs, stack, ms, aux = o
            return aux["time_ns"], ms, aux

        def h_get_smp_processor_id(o):
            regs, stack, ms, aux = o
            return aux["cpu"], ms, aux

        def h_get_current_pid_tgid(o):
            regs, stack, ms, aux = o
            return aux["pid"], ms, aux

        def h_log2(o):
            regs, stack, ms, aux = o
            return M.jnp_log2_bin(regs[1]).astype(I64), ms, aux

        def h_get_prandom_u32(o):
            regs, stack, ms, aux = o
            x = jnp.bitwise_and(aux["rand"], jnp.int64(0xFFFFFFFF))
            x = jnp.where(x == 0, jnp.int64(1), x)
            x = jnp.bitwise_and(x ^ (x << 13), jnp.int64(0xFFFFFFFF))
            x = x ^ (x >> 17)
            x = jnp.bitwise_and(x ^ (x << 5), jnp.int64(0xFFFFFFFF))
            new_rand = jnp.where(pred, x, aux["rand"])
            return jnp.where(pred, x, jnp.int64(0)), ms, \
                {**aux, "rand": new_rand}

        def h_trace_printk(o):
            regs, stack, ms, aux = o
            slot = jnp.clip(aux["printk_n"], 0, 7).astype(jnp.int32)
            row = jnp.stack([regs[1], regs[2]])
            buf = aux["printk_buf"].at[slot].set(
                jnp.where(pred, row, aux["printk_buf"][slot]))
            n = aux["printk_n"] + jnp.where(pred, jnp.int64(1), jnp.int64(0))
            return zero, ms, {**aux, "printk_buf": buf, "printk_n": n}

        def h_override_return(o):
            regs, stack, ms, aux = o
            ov_s = jnp.where(pred, jnp.int64(1), aux["override_set"])
            ov_v = jnp.where(pred, regs[1], aux["override_val"])
            return zero, ms, {**aux, "override_set": ov_s,
                              "override_val": ov_v}

        helper_fns = {
            "map_lookup_elem": h_map_lookup_elem,
            "map_update_elem": h_map_update_elem,
            "map_delete_elem": h_map_delete_elem,
            "map_fetch_add": h_map_fetch_add,
            "percpu_fetch_add": h_percpu_fetch_add,
            "hist_add": h_hist_add,
            "ringbuf_output": h_ringbuf_output,
            "ktime_get_ns": h_ktime_get_ns,
            "get_smp_processor_id": h_get_smp_processor_id,
            "get_current_pid_tgid": h_get_current_pid_tgid,
            "log2": h_log2,
            "get_prandom_u32": h_get_prandom_u32,
            "trace_printk": h_trace_printk,
            "override_return": h_override_return,
        }
        helper_branches = [helper_fns[HELPERS[hid].name]
                           for hid in TABLE_HELPER_IDS]

        # ---- opcode handlers: opnd -> (regs, stack, ms, aux, taken)
        def b_alu(is64):
            def br(o):
                dst, src, off, imm, aluop, use_imm, size, hid, \
                    regs, stack, ms, aux = o
                d = regs[dst]
                s = jnp.where(use_imm != 0, imm, regs[src])
                rs = [J._alu_jax(op, d, s, is64) for op in _ALU_ORDER]
                r = jnp.stack(rs)[jnp.clip(aluop, 0, 12).astype(jnp.int32)]
                return regs.at[dst].set(r), stack, ms, aux, jnp.asarray(True)
            return br

        def b_lddw(o):
            dst, src, off, imm, aluop, use_imm, size, hid, \
                regs, stack, ms, aux = o
            return regs.at[dst].set(imm), stack, ms, aux, jnp.asarray(True)

        def b_ldx(o):
            dst, src, off, imm, aluop, use_imm, size, hid, \
                regs, stack, ms, aux = o
            addr = regs[src] + off
            is_ctx = addr >= CTX_BASE
            v_stack = J.dyn_word_load(stack, addr - STACK_BASE, size)
            v_ctx = J.dyn_word_load(ctx_row, addr - CTX_BASE, size)
            v = jnp.where(is_ctx, v_ctx, v_stack)
            return regs.at[dst].set(v), stack, ms, aux, jnp.asarray(True)

        def b_store(from_reg):
            def br(o):
                dst, src, off, imm, aluop, use_imm, size, hid, \
                    regs, stack, ms, aux = o
                val = regs[src] if from_reg else imm
                stack = J.dyn_word_store(stack, regs[dst] + off - STACK_BASE,
                                         size, val)
                return regs, stack, ms, aux, jnp.asarray(True)
            return br

        def b_nop(o):      # ja (tgt pre-resolved) and exit (done set outside)
            dst, src, off, imm, aluop, use_imm, size, hid, \
                regs, stack, ms, aux = o
            return regs, stack, ms, aux, jnp.asarray(True)

        def b_jcond(is64):
            def br(o):
                dst, src, off, imm, aluop, use_imm, size, hid, \
                    regs, stack, ms, aux = o
                lhs = regs[dst]
                rhs = jnp.where(use_imm != 0, imm, regs[src])
                conds = [jnp.asarray(False) if op is None
                         else J._jmp_cond_jax(op, lhs, rhs, is64)
                         for op in _COND_ORDER]
                taken = jnp.stack(conds)[
                    jnp.clip(aluop, 0, len(conds) - 1).astype(jnp.int32)]
                return regs, stack, ms, aux, taken
            return br

        def b_call(o):
            dst, src, off, imm, aluop, use_imm, size, hid, \
                regs, stack, ms, aux = o
            idx = jnp.clip(hid, 0, len(helper_branches) - 1).astype(jnp.int32)
            r0, ms, aux = jax.lax.switch(idx, helper_branches,
                                         (regs, stack, ms, aux))
            regs = regs.at[0].set(r0)
            regs = regs.at[1:6].set(jnp.zeros((5,), I64))
            return regs, stack, ms, aux, jnp.asarray(True)

        branches = [b_alu(True), b_alu(False), b_lddw, b_ldx,
                    b_store(False), b_store(True), b_nop,
                    b_jcond(True), b_jcond(False), b_call, b_nop]

        def loop_cond(c):
            pc, fuel, regs, stack, ms, ax, done = c
            return (~done) & (fuel > 0)

        def loop_body(c):
            pc, fuel, regs, stack, ms, ax, done = c
            i = jnp.clip(pc, 0, n_pad - 1).astype(jnp.int32)
            hcls = prog["hcls"][i]
            opnd = (prog["dst"][i], prog["src"][i], prog["off"][i],
                    prog["imm"][i], prog["aluop"][i], prog["use_imm"][i],
                    prog["size"][i], prog["hid"][i], regs, stack, ms, ax)
            regs, stack, ms, ax, taken = jax.lax.switch(
                jnp.clip(hcls, 0, len(branches) - 1).astype(jnp.int32),
                branches, opnd)
            nxt = jnp.where(taken, prog["tgt"][i], pc + 1)
            return (nxt, fuel - 1, regs, stack, ms, ax,
                    done | (hcls == TH_EXIT))

        regs0 = jnp.zeros((11,), I64)
        regs0 = regs0.at[isa.R1].set(jnp.int64(CTX_BASE))
        regs0 = regs0.at[isa.R10].set(jnp.int64(STACK_BASE + STACK_SIZE))
        stack0 = jnp.zeros((J.STACK_WORDS,), I64)
        init = (jnp.int64(0), prog["fuel"], regs0, stack0, maps_state, aux,
                ~pred)
        _pc, _fuel, regs, _stack, ms, ax, _done = jax.lax.while_loop(
            loop_cond, loop_body, init)
        return regs[0], ms, ax

    return core


# --------------------------------------------------------------------------
# batched lockstep machine — the vectorized interpreter lane
# --------------------------------------------------------------------------
#
# The sequential core above scans the tape one event at a time: every event
# pays a full while_loop of per-instruction lax.switch dispatches (~28x the
# scan lane). The batched machine instead runs ONE slot's program over ALL
# matching events in lockstep SIMT style: machine state is per-LANE
# (pc[B], fuel[B], regs[B,11], stack[B,64], done[B]); each machine step
# gathers the instruction fields at every lane's pc and executes all handler
# classes compute-all-then-select — the vector-machine translation of the
# opcode switch. Map side effects collapse to the same batched primitives the
# fused lane uses (scatter-add, j_hash_fetch_add_batch, searchsorted hist),
# so the per-event cost drops from O(insns) switch dispatches to
# O(max_live_path) machine steps amortized over the whole batch.
#
# Bit-identity contract (vs the sequential scan order):
#   * only programs whose helper calls are pure or commutative-effect
#     (fetch-add family, hist_add) are eligible (`batched_encodable`);
#     fetch-add results must be dead — integer adds commute, so any
#     cross-lane interleave yields the same end state;
#   * HASH fetch_add additionally changes table LAYOUT at each key's first
#     insert, which is order-sensitive: a hash-touching program is eligible
#     only if it has no conditional branches, so every live lane reaches the
#     call at the same machine step in lane (= event) order and
#     `j_hash_fetch_add_batch`'s first-occurrence insert order matches the
#     sequential scan exactly;
#   * cross-slot sharing is resolved host-side (`LiveTable._recompute_vec`):
#     a batched slot never shares a hash map with any other slot, nor any
#     map with a sequential slot that touches it non-commutatively.

# effectful helpers whose map writes commute (candidates for batching) —
# single source of truth next to the footprints (verifier.py)
_BATCH_EFFECT = COMMUTATIVE_HELPERS

# observability: how often the footprint proofs fired (fuzz/bench reports)
WIDEN_STATS = {"batched_hash_widened": 0, "seq_disjoint_widened": 0}

# The batched machine carries a NARROW per-lane stack — the top
# `_BATCH_STACK_WORDS` words of the 512-byte frame — because the [B, words]
# stack is copied every machine step and the full 64-word frame dominates
# the per-step cost on CPU (the scatter/select traffic is ~8x the rest of
# the machine combined). Probe programs keep keys/scratch at r10-8..r10-64,
# so eligibility (`_fits_batch_stack`) checks the verifier's static offsets.
_BATCH_STACK_WORDS = 8


def _fits_batch_stack(vprog: VerifiedProgram) -> bool:
    """True iff every verified stack access (loads/stores and helper key
    pointers) lands in the top `_BATCH_STACK_WORDS * 8` bytes of the frame
    — the only region the batched machine materializes."""
    from .verifier import CallAnn, MemAnn
    floor = STACK_SIZE - 8 * _BATCH_STACK_WORDS
    for ann in vprog.anns.values():
        if isinstance(ann, MemAnn):
            if ann.region == "stack" and ann.off < floor:
                return False
        elif isinstance(ann, CallAnn):
            sig = HELPERS[ann.hid]
            for i, kind in enumerate(sig.args):
                if kind == "kptr" and ann.statics[i] is not None \
                        and ann.statics[i] < floor:
                    return False
    return True


def _has_cond_branch(vprog: VerifiedProgram) -> bool:
    for ins in vprog.insns:
        if ins.cls in (isa.BPF_JMP, isa.BPF_JMP32):
            op = ins.op & isa.OP_MASK
            if op not in (isa.BPF_JA, isa.BPF_CALL, isa.BPF_EXIT):
                return True
    return False


def _hash_fp_order_free(fp: MapFootprint | None) -> bool:
    """A hash footprint whose touches cannot observe insert order by
    themselves: only map_fetch_add (no deletes -> no tombstones) with
    fully-static keys."""
    return (fp is not None and fp.static_keys is not None
            and fp.ops <= {"map_fetch_add"})


def _home_slots_distinct(keys, max_entries: int) -> bool:
    """True iff every distinct key lands on its own home slot under the
    open-addressing hash — no probe chains, so the physical layout is the
    same for ANY insert order (and values are commutative sums)."""
    homes: dict[int, int] = {}
    for k in keys:
        h = M._np_hash_idx(k, max_entries)
        if homes.setdefault(h, k) != k:
            return False
    return True


def _self_hash_collision_free(vprog: VerifiedProgram) -> bool:
    """Widening rule 3 (DESIGN.md §14): a program whose every HASH touch is
    fetch_add on static, home-slot-distinct keys produces the same table
    layout under any per-lane execution order — lockstep divergence
    (conditional branches) stops being observable."""
    for fp in vprog.footprints.values():
        if fp.kind != M.MapKind.HASH:
            continue
        if not (_hash_fp_order_free(fp)
                and _home_slots_distinct(fp.static_keys, fp.max_entries)):
            return False
    return True


def batched_encodable(vprog: VerifiedProgram) -> bool:
    """True iff this program may run on the batched lockstep machine with
    end states bit-identical to the sequential scan order. Loops are fine
    (the machine steps diverged lanes independently); the constraints are
    commutative-only effects, dead fetch-add results, stack traffic within
    the machine's narrow frame, and — for HASH fetch_add, whose insert
    order shapes the table layout — either perfect lockstep (no
    conditional branches) or a footprint PROOF that the program's static
    key set is home-slot collision-free (widening rule 3)."""
    from .vectorized import _PURE, _r0_dead_after
    from .verifier import CallAnn
    if not _fits_batch_stack(vprog):
        return False
    touches_hash = False
    for pc, ann in vprog.anns.items():
        if not isinstance(ann, CallAnn):
            continue
        if ann.name in _PURE:
            continue
        if ann.name not in _BATCH_EFFECT:
            return False
        if ann.name in ("map_fetch_add", "percpu_fetch_add") and \
                not _r0_dead_after(vprog, pc):
            return False
        if ann.name == "map_fetch_add" and \
                vprog.map_specs[ann.statics[0]].kind == M.MapKind.HASH:
            touches_hash = True
    if touches_hash and _has_cond_branch(vprog) \
            and not _self_hash_collision_free(vprog):
        return False
    return True


def _slot_resources(vprog: VerifiedProgram):
    """({map_name: commutative-by-this-program}, {hash map names touched})
    — the host-side footprint `_recompute_vec` resolves conflicts with."""
    from .verifier import CallAnn
    res: dict[str, bool] = {}
    hashes: set[str] = set()
    for ann in vprog.anns.values():
        if not isinstance(ann, CallAnn):
            continue
        sig = HELPERS[ann.hid]
        comm = sig.name in _BATCH_EFFECT
        for i, kind in enumerate(sig.args):
            if kind == "mapfd":
                sp = vprog.map_specs[ann.statics[i]]
                res[sp.name] = res.get(sp.name, True) and comm
                if sp.kind == M.MapKind.HASH:
                    hashes.add(sp.name)
    return res, hashes


@functools.lru_cache(maxsize=64)
def _build_batched_core(spec_key: tuple, ctx_words: int):
    """Build the batched lockstep interpreter for a fixed map universe.
    `bcore(prog, ctx_rows, maps_state, aux, preds)` runs ONE table slot over
    a whole event batch and returns (r0[B], maps_state). Like the sequential
    core, the traced graph depends only on (spec_key, ctx_words) and the
    padded dims — table contents stay pure data."""
    specs = _specs_from_key(spec_key)
    nmaps = len(specs)
    hnames = [HELPERS[hid].name for hid in TABLE_HELPER_IDS]

    vload = jax.vmap(J.dyn_word_load)

    def _sel(rows, idx, hi):
        """compute-all-then-select: rows is a list of [B] arrays, idx a [B]
        selector — the batched form of `jnp.stack(rs)[op]`."""
        ii = jnp.clip(idx, 0, hi).astype(jnp.int32)
        return jnp.take_along_axis(jnp.stack(rows), ii[None, :], axis=0)[0]

    def _batch_word_store(words, off, size, val):
        """Elementwise twin of vmap(dyn_word_store) over the narrow [B, W]
        stack: the two covering words are rewritten via word-index selects
        instead of a batched scatter (XLA CPU serializes vmapped scatters —
        this formulation is ~10x cheaper and bit-identical). Word1 is
        selected first so a clipped w1 alias can never clobber the word0
        write, mirroring dyn_word_store's write order."""
        nwords = words.shape[1]
        u = J._u
        w0 = jnp.clip(off >> 3, 0, nwords - 1)
        w1 = jnp.minimum(w0 + 1, nwords - 1)
        rb = off & 7
        old0 = jnp.take_along_axis(
            words, w0[:, None].astype(jnp.int32), axis=1)[:, 0]
        old1 = jnp.take_along_axis(
            words, w1[:, None].astype(jnp.int32), axis=1)[:, 0]
        nbits = (jnp.uint64(8) * u(size)) & jnp.uint64(63)
        v = jnp.where(size >= 8, u(val),
                      u(val) & ((jnp.uint64(1) << nbits) - jnp.uint64(1)))
        nb0 = jnp.minimum(size, 8 - rb)
        m0_bits = (jnp.uint64(8) * u(nb0)) & jnp.uint64(63)
        m0 = jnp.where(nb0 >= 8, jnp.uint64(J._U64_FULL),
                       (jnp.uint64(1) << m0_bits) - jnp.uint64(1)) \
            << (jnp.uint64(8) * u(rb))
        new0 = (u(old0) & ~m0) | ((v << (jnp.uint64(8) * u(rb))) & m0)
        spans = (rb + size) > 8
        nb1 = jnp.clip(rb + size - 8, 0, 7)
        m1 = (jnp.uint64(1) << (jnp.uint64(8) * u(nb1))) - jnp.uint64(1)
        sh1 = (jnp.uint64(8) * u(8 - rb)) & jnp.uint64(63)
        new1 = (u(old1) & ~m1) | ((v >> sh1) & m1)
        wcol = jnp.arange(nwords, dtype=jnp.int64)[None, :]
        out = jnp.where((wcol == w1[:, None]) & spans[:, None],
                        new1.astype(I64)[:, None], words)
        out = jnp.where(wcol == w0[:, None], new0.astype(I64)[:, None], out)
        return out

    # Every map apply sits behind a lax.cond on "any lane fires": scatters
    # (and the hash sort+probe twin) are the expensive per-step ops, and at
    # most one machine step per program actually executes each call site —
    # the cond makes every other step skip them at runtime.
    def _apply_fetch_add(ms, fds, keys, deltas, m):
        if nmaps == 0:
            return ms
        fdix = jnp.clip(fds, 0, nmaps - 1)
        for si, sp in enumerate(specs):
            mm = m & (fdix == si)
            st = ms[sp.name]
            if sp.kind == M.MapKind.ARRAY:
                n = sp.max_entries

                def do_array(o, n=n):
                    st_, keys_, deltas_, mm_ = o
                    inb = mm_ & (keys_ >= 0) & (keys_ < n)
                    idx = jnp.clip(keys_, 0, n - 1).astype(jnp.int32)
                    vals = st_["values"].at[idx].add(
                        jnp.where(inb, deltas_, jnp.int64(0)))
                    return {"values": vals}

                new = jax.lax.cond(jnp.any(mm), do_array, lambda o: o[0],
                                   (st, keys, deltas, mm))
                ms = {**ms, sp.name: new}
            elif sp.kind == M.MapKind.HASH:
                new = jax.lax.cond(
                    jnp.any(mm),
                    lambda o: M.j_hash_fetch_add_batch(o[0], o[1], o[2],
                                                       o[3]),
                    lambda o: o[0],
                    (st, keys, deltas, mm))
                ms = {**ms, sp.name: new}
        return ms

    def _apply_percpu_fetch_add(ms, aux, fds, keys, deltas, m):
        if nmaps == 0:
            return ms
        fdix = jnp.clip(fds, 0, nmaps - 1)
        for si, sp in enumerate(specs):
            if sp.kind != M.MapKind.PERCPU_ARRAY:
                continue
            mm = m & (fdix == si)
            st = ms[sp.name]
            n = sp.max_entries

            def do_percpu(o, n=n, sp=sp):
                st_, keys_, deltas_, mm_, cpu = o
                inb = mm_ & (keys_ >= 0) & (keys_ < n)
                idx = jnp.clip(keys_, 0, n - 1).astype(jnp.int32)
                sh = jnp.clip(cpu, 0, sp.num_shards - 1).astype(jnp.int32)
                vals = st_["values"].at[sh, idx].add(
                    jnp.where(inb, deltas_, jnp.int64(0)))
                return {"values": vals}

            new = jax.lax.cond(jnp.any(mm), do_percpu, lambda o: o[0],
                               (st, keys, deltas, mm, aux["cpu"]))
            ms = {**ms, sp.name: new}
        return ms

    def _apply_hist_add(ms, fds, values, m):
        if nmaps == 0:
            return ms
        fdix = jnp.clip(fds, 0, nmaps - 1)
        pow2 = jnp.asarray(M._POW2)
        for si, sp in enumerate(specs):
            if sp.kind != M.MapKind.LOG2HIST:
                continue
            mm = m & (fdix == si)
            st = ms[sp.name]

            def do_hist(o):
                st_, values_, mm_ = o
                bl = jnp.searchsorted(pow2, values_, side="right").astype(
                    jnp.int32)
                bins_idx = jnp.where(values_ <= 0, 0, jnp.minimum(63, bl))
                bins = st_["bins"].at[bins_idx].add(
                    jnp.where(mm_, jnp.int64(1), jnp.int64(0)))
                return {"bins": bins}

            new = jax.lax.cond(jnp.any(mm), do_hist, lambda o: o[0],
                               (st, values, mm))
            ms = {**ms, sp.name: new}
        return ms

    def bcore(prog: dict, ctx_rows, maps_state, aux, preds):
        n_pad = prog["hcls"].shape[0]
        B = ctx_rows.shape[0]
        col = jnp.arange(11, dtype=jnp.int64)[None, :]
        # byte address of the narrow stack's word 0 (top of the real frame)
        sbase = jnp.int64(STACK_BASE + STACK_SIZE - 8 * _BATCH_STACK_WORDS)

        def machine_cond(c):
            pc, fuel, regs, stacks, ms, done = c
            return jnp.any((~done) & (fuel > 0))

        def machine_step(c):
            pc, fuel, regs, stacks, ms, done = c
            live = (~done) & (fuel > 0)
            i = jnp.clip(pc, 0, n_pad - 1).astype(jnp.int32)
            g = {f: prog[f][i] for f in TABLE_FIELDS}   # [B] field gathers
            hcls = g["hcls"]
            dst = jnp.clip(g["dst"], 0, 10)
            src = jnp.clip(g["src"], 0, 10)
            d = jnp.take_along_axis(
                regs, dst[:, None].astype(jnp.int32), axis=1)[:, 0]
            sreg = jnp.take_along_axis(
                regs, src[:, None].astype(jnp.int32), axis=1)[:, 0]
            s = jnp.where(g["use_imm"] != 0, g["imm"], sreg)

            # ALU, both widths — compute-all-then-select, elementwise [B]
            v64 = _sel([J._alu_jax(op, d, s, True) for op in _ALU_ORDER],
                       g["aluop"], 12)
            v32 = _sel([J._alu_jax(op, d, s, False) for op in _ALU_ORDER],
                       g["aluop"], 12)

            # LDX — per-lane dynamic loads from stack or ctx row
            addr = sreg + g["off"]
            v_st = vload(stacks, addr - sbase, g["size"])
            v_cx = vload(ctx_rows, addr - CTX_BASE, g["size"])
            v_ldx = jnp.where(addr >= CTX_BASE, v_cx, v_st)

            # register writeback (alu / lddw / ldx)
            wval = v64
            wval = jnp.where(hcls == isa.TH_ALU32, v32, wval)
            wval = jnp.where(hcls == isa.TH_LDDW, g["imm"], wval)
            wval = jnp.where(hcls == isa.TH_LDX, v_ldx, wval)
            wmask = live & ((hcls == isa.TH_ALU64) | (hcls == isa.TH_ALU32)
                            | (hcls == isa.TH_LDDW) | (hcls == isa.TH_LDX))
            regs = jnp.where(wmask[:, None] & (col == dst[:, None]),
                             wval[:, None], regs)

            # stores (ST imm / STX reg) — d is the pre-write base pointer.
            # Masked lanes store with size 0: dyn_word_store then writes the
            # covering words back unchanged, so no outer select over the
            # whole [B, words] stack is needed.
            st_mask = live & ((hcls == isa.TH_ST) | (hcls == isa.TH_STX))
            stval = jnp.where(hcls == isa.TH_STX, sreg, g["imm"])
            stacks = _batch_word_store(
                stacks, d + g["off"] - sbase,
                jnp.where(st_mask, g["size"], jnp.int64(0)), stval)

            # helper calls — masked batched applies, one per (helper, spec)
            at_call = live & (hcls == isa.TH_CALL)
            r1, r2, r3 = regs[:, 1], regs[:, 2], regs[:, 3]
            keys8 = vload(stacks, r2 - sbase, jnp.full((B,), 8, dtype=I64))
            r0c = jnp.zeros((B,), I64)
            for hi, name in enumerate(hnames):
                m = at_call & (g["hid"] == hi)
                if name == "ktime_get_ns":
                    r0c = jnp.where(m, aux["time_ns"], r0c)
                elif name == "get_smp_processor_id":
                    r0c = jnp.where(m, aux["cpu"], r0c)
                elif name == "get_current_pid_tgid":
                    r0c = jnp.where(m, aux["pid"], r0c)
                elif name == "log2":
                    r0c = jnp.where(
                        m, jax.vmap(M.jnp_log2_bin)(r1).astype(I64), r0c)
                elif name == "map_fetch_add":
                    # r0 is verified dead (batched_encodable) -> stays 0
                    ms = _apply_fetch_add(ms, r1, keys8, r3, m)
                elif name == "percpu_fetch_add":
                    ms = _apply_percpu_fetch_add(ms, aux, r1, keys8, r3, m)
                elif name == "hist_add":
                    ms = _apply_hist_add(ms, r1, r2, m)
                # any other helper is unreachable in a vec slot
                # (batched_encodable gates encoding) — mask stays a no-op
            regs = jnp.where(at_call[:, None] & (col == 0),
                             r0c[:, None], regs)
            regs = jnp.where(at_call[:, None] & (col >= 1) & (col <= 5),
                             jnp.int64(0), regs)

            # control flow: cond-jumps select, everything else falls through
            # to the pre-resolved tgt (ja) or pc+1
            c64 = _sel([jnp.zeros((B,), bool) if op is None
                        else J._jmp_cond_jax(op, d, s, True)
                        for op in _COND_ORDER], g["aluop"],
                       len(_COND_ORDER) - 1)
            c32 = _sel([jnp.zeros((B,), bool) if op is None
                        else J._jmp_cond_jax(op, d, s, False)
                        for op in _COND_ORDER], g["aluop"],
                       len(_COND_ORDER) - 1)
            taken = jnp.where(hcls == isa.TH_JCOND64, c64,
                              jnp.where(hcls == isa.TH_JCOND32, c32,
                                        jnp.ones((B,), bool)))
            nxt = jnp.where(taken, g["tgt"], pc + 1)
            return (jnp.where(live, nxt, pc),
                    jnp.where(live, fuel - 1, fuel),
                    regs, stacks, ms,
                    done | (live & (hcls == TH_EXIT)))

        regs0 = jnp.zeros((B, 11), I64)
        regs0 = regs0.at[:, isa.R1].set(jnp.int64(CTX_BASE))
        regs0 = regs0.at[:, isa.R10].set(jnp.int64(STACK_BASE + STACK_SIZE))
        stacks0 = jnp.zeros((B, _BATCH_STACK_WORDS), I64)
        init = (jnp.zeros((B,), I64),
                jnp.broadcast_to(prog["fuel"], (B,)),
                regs0, stacks0, maps_state, ~preds)
        _pc, _fuel, regs, _stacks, ms, _done = jax.lax.while_loop(
            machine_cond, machine_step, init)
        return regs[:, 0], ms

    return bcore


# --------------------------------------------------------------------------
# the live table (host-side owner + in-step lane driver)
# --------------------------------------------------------------------------

class LiveTable:
    """Host-side owner of the device-resident program table.

    Encoding/clearing mutates numpy arrays here and bumps the generation
    counter; `BpftimeRuntime.sync_live_table` pushes the arrays into the
    step's map-state pytree through a donated buffer update. The device copy
    is read-only in-graph."""

    def __init__(self, map_specs, ctx_words: int = 16, max_programs: int = 4,
                 max_insns: int = 64):
        self.spec_key = _spec_key(map_specs)
        self.n_maps = len(self.spec_key)
        self.ctx_words = ctx_words
        self.max_programs = max_programs
        self.max_insns = max_insns
        self.host: dict[str, np.ndarray] = {
            f: np.zeros((max_programs, max_insns), np.int64)
            for f in TABLE_FIELDS}
        # padded rows halt immediately if a (verified-impossible) runaway pc
        # ever lands on them
        self.host["hcls"][:, :] = TH_EXIT
        for f in META_FIELDS:
            self.host[f] = np.zeros((max_programs,), np.int64)
        self.host["gen"] = np.zeros((1,), np.int64)
        self.slot_pid: list[int | None] = [None] * max_programs
        # host-side scheduling inputs for the batched lane (never traced)
        self._slot_vec_ok: list[bool] = [False] * max_programs
        self._slot_res: list[dict] = [{}] * max_programs
        self._slot_hash: list[set] = [set()] * max_programs
        # per-slot effect footprints by map name (verifier.MapFootprint) —
        # what _recompute_vec's widening rules prove commutativity from
        self._slot_fp: list[dict] = [{}] * max_programs

    # ------------------------------------------------------------- host side
    def device_state(self) -> dict:
        return {k: jnp.asarray(v) for k, v in self.host.items()}

    def free_slot(self) -> int | None:
        for p in range(self.max_programs):
            if not self.host["active"][p]:
                return p
        return None

    @staticmethod
    def image_key(vprog: VerifiedProgram) -> str:
        """Content address of one encoded table image: the insn blob plus
        the helper-dispatch order the encoding bakes in. Table dims don't
        enter — padding happens at slot-write time."""
        from .layout import program_digest
        blob = b"".join(i.encode() for i in vprog.insns)
        blob += repr(TABLE_HELPER_IDS).encode()
        return f"tblimg-{program_digest(blob)}"

    def _encoded_image(self, vprog: VerifiedProgram, cache) -> dict:
        """Fetch the packed insn arrays from the fleet artifact cache, or
        encode and publish them — the live-attach fanout path encodes each
        program once fleet-wide instead of once per worker."""
        n = len(vprog.insns)
        key = None
        if cache is not None:
            key = self.image_key(vprog)
            img = cache.get_table(key)
            if img is not None and set(img) >= set(TABLE_FIELDS) and \
                    all(len(img[f]) == n for f in TABLE_FIELDS):
                return img
        tp = isa.encode_table_program(vprog.insns, TABLE_HELPER_INDEX)
        if cache is not None:
            cache.put_table(key, {f: np.asarray(tp[f], np.int64)
                                  for f in TABLE_FIELDS})
        return tp

    def encode_slot(self, slot: int, vprog: VerifiedProgram, site_id: int,
                    kind: int, pid: int = 0, cache=None) -> None:
        tp = self._encoded_image(vprog, cache)
        n = len(vprog.insns)
        for f in TABLE_FIELDS:
            self.host[f][slot, :] = TH_EXIT if f == "hcls" else 0
            self.host[f][slot, :n] = tp[f]
        self.host["active"][slot] = 1
        self.host["site"][slot] = site_id
        self.host["kind"][slot] = kind
        self.host["n_insns"][slot] = n
        # fuel in INSN steps. The scan-lane T2 budget is vprog.max_insns
        # BLOCK-dispatch steps (jit.compile_t2); scale by the longest block
        # so any execution that completes within the scan lane's budget also
        # completes here — exhausting either budget (the kernel's 1M-insn
        # safety net, not a semantic) is outside the equivalence contract.
        max_block = max((b.end - b.start for b in vprog.blocks), default=1)
        self.host["fuel"][slot] = vprog.max_insns * max(1, max_block)
        self._slot_vec_ok[slot] = batched_encodable(vprog)
        self._slot_res[slot], self._slot_hash[slot] = _slot_resources(vprog)
        self._slot_fp[slot] = {fp.name: fp
                               for fp in vprog.footprints.values()}
        self._recompute_vec()
        self.host["gen"][0] += 1
        self.slot_pid[slot] = pid

    def clear_slot(self, slot: int) -> None:
        self.host["active"][slot] = 0
        self._slot_vec_ok[slot] = False
        self._slot_res[slot] = {}
        self._slot_hash[slot] = set()
        self._slot_fp[slot] = {}
        self._recompute_vec()
        self.host["gen"][0] += 1
        self.slot_pid[slot] = None

    def _hash_sharing_widened(self, mname: str) -> bool:
        """Widening rule 2 (DESIGN.md §14): a HASH map shared across slots
        stays batchable when EVERY active slot touching it does so only via
        map_fetch_add with fully-static keys, and the UNION of those keys
        is home-slot collision-free — every insert lands in its home slot
        whatever the order, so the physical layout is identical and values
        are commutative sums. Certified by tests/test_widening.py."""
        keys: set[int] = set()
        n = None
        for q in range(self.max_programs):
            if not self.host["active"][q] or \
                    mname not in self._slot_res[q]:
                continue
            fp = self._slot_fp[q].get(mname)
            if not _hash_fp_order_free(fp):
                return False
            keys |= fp.static_keys
            n = fp.max_entries
        if n is None or not _home_slots_distinct(keys, n):
            return False
        WIDEN_STATS["batched_hash_widened"] += 1
        return True

    def _recompute_vec(self) -> None:
        """Resolve which active slots run on the batched machine. A slot
        starts from its program's own eligibility (`batched_encodable`) and
        is demoted to the sequential lane when cross-slot sharing would make
        the batched interleave observable:

          * it touches a HASH map that ANY other active slot also touches —
            hash layout is insert-order-sensitive, and batching one slot
            reorders its inserts relative to the per-event interleave —
            UNLESS the union footprint is provably order-free
            (`_hash_sharing_widened`, widening rule 2);
          * it shares a map with a sequential slot that touches it
            NON-commutatively (lookup/update/delete observe order) —
            UNLESS the two footprints address provably disjoint static
            cells of a positional map (widening rule 1).

        Demotions only remove batched slots (a demoted slot is commutative
        on everything it touches), so the fixpoint is reached in one or two
        sweeps. The result is written into the `vec` meta row — pure table
        DATA, so rescheduling never retraces."""
        P = self.max_programs
        eff = [bool(self.host["active"][p]) and self._slot_vec_ok[p]
               for p in range(P)]
        changed = True
        while changed:
            changed = False
            for p in range(P):
                if not eff[p]:
                    continue
                for q in range(P):
                    if q == p or not self.host["active"][q]:
                        continue
                    shared = set(self._slot_res[p]) & set(self._slot_res[q])
                    for mname in shared:
                        if mname in self._slot_hash[p]:
                            if self._hash_sharing_widened(mname):
                                continue
                            eff[p] = False
                            changed = True
                            break
                        if not eff[q] and not self._slot_res[q][mname]:
                            if footprints_disjoint(
                                    self._slot_fp[p].get(mname),
                                    self._slot_fp[q].get(mname)):
                                WIDEN_STATS["seq_disjoint_widened"] += 1
                                continue
                            eff[p] = False
                            changed = True
                            break
                    if not eff[p]:
                        break
        for p in range(P):
            self.host["vec"][p] = 1 if eff[p] else 0

    # ------------------------------------------------------------- device side
    def run(self, table_state: dict, event_rows, maps_state, aux):
        """The interpreter lane, two sub-lanes selected by table DATA:

          * slots with `vec == 0` share one sequential lax.scan over the
            tape (slot order per event — the combined-scan interleave, like
            jit.run_fused_scan). The whole scan sits behind a `lax.cond` on
            "any sequential slot active", so an all-batched table skips the
            per-event while_loops entirely at runtime;
          * slots with `vec == 1` each run the batched lockstep machine over
            the full tape (commutative effects make the slot-vs-slot order
            unobservable — enforced host-side by `_recompute_vec`).

        Traced inside the step function; everything about `table_state` is
        data, so attach/detach/rescheduling never retraces."""
        core = _build_core(self.spec_key, self.ctx_words)
        bcore = _build_batched_core(self.spec_key, self.ctx_words)
        active = table_state["active"]
        vec = table_state["vec"]

        def seq_branch(op):
            ms, ax = op

            def step(carry, row):
                ms, ax = carry
                for p in range(self.max_programs):
                    prog = {f: table_state[f][p] for f in TABLE_FIELDS}
                    prog["fuel"] = table_state["fuel"][p]
                    pred = ((active[p] != 0) & (vec[p] == 0)
                            & (row[0] == table_state["site"][p])
                            & (row[1] == table_state["kind"][p]))
                    _r0, ms, ax = core(prog, row, ms, ax, pred)
                return (ms, ax), jnp.int64(0)

            (ms, ax), _ = jax.lax.scan(step, (ms, ax), event_rows)
            return ms, ax

        ms, ax = jax.lax.cond(jnp.any((active != 0) & (vec == 0)),
                              seq_branch, lambda op: op, (maps_state, aux))

        for p in range(self.max_programs):
            prog = {f: table_state[f][p] for f in TABLE_FIELDS}
            prog["fuel"] = table_state["fuel"][p]
            preds = ((active[p] != 0) & (vec[p] != 0)
                     & (event_rows[:, 0] == table_state["site"][p])
                     & (event_rows[:, 1] == table_state["kind"][p]))
            _r0, ms = bcore(prog, event_rows, ms, ax, preds)
        return ms, ax


# --------------------------------------------------------------------------
# differential-test entry point
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 1))
def _jit_run_single(spec_key, ctx_words, prog, ctx_row, maps_state, aux):
    core = _build_core(spec_key, ctx_words)
    return core(prog, ctx_row, maps_state, aux, jnp.asarray(True))


def run_program(vprog: VerifiedProgram, ctx_row, maps_state, aux,
                pad_insns: int = 128):
    """Run ONE verified program through the table interpreter on a single
    ctx row with pred=True — the differential-test twin of
    `jit.compile_program`. Padded to a shared width so the corpus reuses one
    compiled interpreter per (map universe, ctx width)."""
    lt = LiveTable(vprog.map_specs, ctx_words=vprog.ctx_words,
                   max_programs=1,
                   max_insns=max(pad_insns, len(vprog.insns)))
    lt.encode_slot(0, vprog, site_id=0, kind=0)
    tbl = lt.device_state()
    prog = {f: tbl[f][0] for f in TABLE_FIELDS}
    prog["fuel"] = tbl["fuel"][0]
    return _jit_run_single(lt.spec_key, lt.ctx_words, prog,
                           jnp.asarray(ctx_row, I64), maps_state, aux)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _jit_run_batched(spec_key, ctx_words, prog, ctx_rows, maps_state, aux,
                     preds):
    bcore = _build_batched_core(spec_key, ctx_words)
    return bcore(prog, ctx_rows, maps_state, aux, preds)


def run_program_batched(vprog: VerifiedProgram, ctx_rows, maps_state, aux,
                        pad_insns: int = 128):
    """Run ONE batched-eligible program through the lockstep machine over a
    [B, ctx_words] batch with every lane valid — the differential twin of
    the vec sub-lane (`(r0[B], maps_state)`). Callers gate on
    `batched_encodable(vprog)`."""
    lt = LiveTable(vprog.map_specs, ctx_words=vprog.ctx_words,
                   max_programs=1,
                   max_insns=max(pad_insns, len(vprog.insns)))
    lt.encode_slot(0, vprog, site_id=0, kind=0)
    tbl = lt.device_state()
    prog = {f: tbl[f][0] for f in TABLE_FIELDS}
    prog["fuel"] = tbl["fuel"][0]
    rows = jnp.asarray(ctx_rows, I64)
    preds = jnp.ones((rows.shape[0],), bool)
    return _jit_run_batched(lt.spec_key, lt.ctx_words, prog, rows,
                            maps_state, aux, preds)
