"""Device-resident program-table interpreter — live attach/detach without
recompilation (the dispatch-as-data tier; DESIGN.md §9).

The fused/scan lanes (vectorized.py, jit.py) specialize the step HLO to the
attached program SET: any attach/detach changes the traced computation and
forces a retrace + XLA recompile — the exact restart-analogue the paper's
userspace runtime eliminates. This module compiles ONE generic in-graph eBPF
interpreter whose behavior is driven entirely by tensor DATA:

  * verified bytecode is packed by `isa.encode_table_program` into flat i64
    arrays (handler class, regs, immediates, pre-resolved jump targets,
    helper branch indices) and padded into a `max_programs x max_insns`
    table that rides inside the step's map-state pytree;
  * the interpreter is a `lax.while_loop` stepping a pc through the padded
    rows, dispatching on the encoded handler class with one `lax.switch`
    (ALU/cond ops use compute-all-then-select — branch-free on a vector
    machine), helper calls with a nested switch over the helper table and,
    inside map helpers, over the map registry as of compile time;
  * memory accesses reuse jit.py's word-oriented machinery via the
    dynamic-offset twins `dyn_word_load` / `dyn_word_store`; the verifier
    has proven every access in bounds before a program may be encoded
    (`verifier.check_table_encodable`), so no dynamic indexing can escape
    the padded table.

The compiled graph depends only on (map registry, ctx width, table dims) —
never on table contents — so `BpftimeRuntime.attach_live` / `detach_live`
just write new table rows + a generation counter through a donated buffer
update and the running compiled step picks them up on its next call: the
paper's attach-to-a-running-PID, with zero retrace.

Semantics are bit-identical to scan mode (`jit.run_over_events`): the same
maps.j_* twins, the same predication, the same aux handling — pinned by the
full differential corpus in tests/test_vm_jit_differential.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import isa, jit as J, maps as M
from .helpers import HELPERS
from .isa import (TABLE_FIELDS, TH_EXIT, STACK_BASE, STACK_SIZE, CTX_BASE)
from .verifier import VerifiedProgram

I64 = jnp.int64

# stable helper branch order for TH_CALL dispatch (encode-time index)
TABLE_HELPER_IDS = tuple(sorted(HELPERS))
TABLE_HELPER_INDEX = {hid: i for i, hid in enumerate(TABLE_HELPER_IDS)}

# per-program metadata rows carried next to the packed insn arrays
META_FIELDS = ("active", "site", "kind", "n_insns", "fuel")

# ALU handler order — index == (op & OP_MASK) >> 4
_ALU_ORDER = (isa.BPF_ADD, isa.BPF_SUB, isa.BPF_MUL, isa.BPF_DIV, isa.BPF_OR,
              isa.BPF_AND, isa.BPF_LSH, isa.BPF_RSH, isa.BPF_NEG, isa.BPF_MOD,
              isa.BPF_XOR, isa.BPF_MOV, isa.BPF_ARSH)
# cond-jump ops by (op & OP_MASK) >> 4 slot; None slots (ja/call/exit) are
# structurally present so the encoded index addresses the stack directly
_COND_ORDER = (None, isa.BPF_JEQ, isa.BPF_JGT, isa.BPF_JGE, isa.BPF_JSET,
               isa.BPF_JNE, isa.BPF_JSGT, isa.BPF_JSGE, None, None,
               isa.BPF_JLT, isa.BPF_JLE, isa.BPF_JSLT, isa.BPF_JSLE)


def _spec_key(specs) -> tuple:
    """Hashable identity of a map universe (flags don't affect codegen)."""
    return tuple((s.name, s.kind.value, s.max_entries, s.rec_width,
                  s.num_shards) for s in specs)


def _specs_from_key(key):
    return [M.MapSpec(name=n, kind=M.MapKind(k), max_entries=me,
                      rec_width=rw, num_shards=ns)
            for n, k, me, rw, ns in key]


# --------------------------------------------------------------------------
# the generic interpreter (compiled once per map universe)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _build_core(spec_key: tuple, ctx_words: int):
    """Build the single-(program, event) interpreter for a fixed map
    universe. The returned `core(prog, ctx_row, maps_state, aux, pred)`
    traces a graph whose SHAPE depends only on (spec_key, ctx_words) and the
    padded insn dimension — table contents are pure data, which is the whole
    trace-stability invariant."""
    specs = _specs_from_key(spec_key)
    nmaps = len(specs)

    def core(prog: dict, ctx_row, maps_state, aux, pred):
        """prog: {field: i64[N]} packed rows + 'fuel' i64 scalar. Returns
        (r0, maps_state, aux); all side effects are gated on `pred` exactly
        like the scan-lane helpers, so an invalid event is a no-op (and the
        while loop is skipped outright via the initial done flag)."""
        n_pad = prog["hcls"].shape[0]
        zero = jnp.int64(0)

        def key_at(stack, ptr):
            return J.dyn_word_load(stack, ptr - STACK_BASE, jnp.int64(8))

        def map_switch(fd, mk_branch, operand, fallback):
            """Dispatch on a DYNAMIC map fd over the compile-time registry.
            mk_branch(spec) -> fn(operand) -> (r0, ms, aux)."""
            if nmaps == 0:
                return fallback
            idx = jnp.clip(fd, 0, nmaps - 1).astype(jnp.int32)
            return jax.lax.switch(idx, [mk_branch(sp) for sp in specs],
                                  operand)

        # ---- helper branches: (regs, stack, ms, aux) -> (r0, ms, aux)
        def h_map_lookup_elem(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])

            def mk(sp):
                def br(o2):
                    key, ms, aux = o2
                    st = ms[sp.name]
                    if sp.kind == M.MapKind.ARRAY:
                        r0 = M.j_array_lookup(st, key, pred)
                    elif sp.kind == M.MapKind.PERCPU_ARRAY:
                        r0 = M.j_percpu_lookup(st, aux["cpu"], key, pred)
                    elif sp.kind == M.MapKind.HASH:
                        r0 = M.j_hash_lookup(st, key, pred)
                    else:           # verifier-rejected kind; structural only
                        r0 = jnp.int64(0)
                    return r0, ms, aux
                return br
            return map_switch(regs[1], mk, (key, ms, aux), (zero, ms, aux))

        def h_map_update_elem(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])
            val = key_at(stack, regs[3])

            def mk(sp):
                def br(o2):
                    key, val, ms, aux = o2
                    st = ms[sp.name]
                    if sp.kind == M.MapKind.ARRAY:
                        new = M.j_array_update(st, key, val, pred)
                        r0 = jnp.int64(0)
                    elif sp.kind == M.MapKind.HASH:
                        new, ok = M.j_hash_update(st, key, val, pred)
                        r0 = jnp.where(ok, jnp.int64(0), jnp.int64(-7))
                    else:
                        return jnp.int64(0), ms, aux
                    return r0, {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (key, val, ms, aux),
                              (zero, ms, aux))

        def h_map_delete_elem(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])

            def mk(sp):
                def br(o2):
                    key, ms, aux = o2
                    if sp.kind != M.MapKind.HASH:
                        return jnp.int64(0), ms, aux
                    new, found = M.j_hash_delete(ms[sp.name], key, pred)
                    r0 = jnp.where(found, jnp.int64(0), jnp.int64(-2))
                    return r0, {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (key, ms, aux), (zero, ms, aux))

        def h_map_fetch_add(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])

            def mk(sp):
                def br(o2):
                    key, delta, ms, aux = o2
                    st = ms[sp.name]
                    if sp.kind == M.MapKind.ARRAY:
                        new, old = M.j_array_fetch_add(st, key, delta, pred)
                    elif sp.kind == M.MapKind.HASH:
                        new, old = M.j_hash_fetch_add(st, key, delta, pred)
                    else:
                        return jnp.int64(0), ms, aux
                    return old, {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (key, regs[3], ms, aux),
                              (zero, ms, aux))

        def h_percpu_fetch_add(o):
            regs, stack, ms, aux = o
            key = key_at(stack, regs[2])

            def mk(sp):
                def br(o2):
                    key, delta, ms, aux = o2
                    if sp.kind != M.MapKind.PERCPU_ARRAY:
                        return jnp.int64(0), ms, aux
                    new, old = M.j_percpu_fetch_add(
                        ms[sp.name], aux["cpu"], key, delta, pred)
                    return old, {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (key, regs[3], ms, aux),
                              (zero, ms, aux))

        def h_hist_add(o):
            regs, stack, ms, aux = o

            def mk(sp):
                def br(o2):
                    v, ms, aux = o2
                    if sp.kind != M.MapKind.LOG2HIST:
                        return jnp.int64(0), ms, aux
                    new = M.j_hist_add(ms[sp.name], v, pred)
                    return jnp.int64(0), {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (regs[2], ms, aux),
                              (zero, ms, aux))

        def h_ringbuf_output(o):
            regs, stack, ms, aux = o
            size = regs[3]

            def mk(sp):
                def br(o2):
                    ptr, size, ms, aux = o2
                    if sp.kind != M.MapKind.RINGBUF:
                        return jnp.int64(0), ms, aux
                    # read rec_width lanes, zero those beyond the dynamic
                    # size — matches the scan lane's zero padding exactly
                    lanes = [jnp.where(
                        jnp.int64(8 * i) < size,
                        J.dyn_word_load(stack, ptr - STACK_BASE + 8 * i,
                                        jnp.int64(8)),
                        jnp.int64(0)) for i in range(sp.rec_width)]
                    new = M.j_ringbuf_emit(ms[sp.name], jnp.stack(lanes),
                                           pred)
                    return jnp.int64(0), {**ms, sp.name: new}, aux
                return br
            return map_switch(regs[1], mk, (regs[2], size, ms, aux),
                              (zero, ms, aux))

        def h_ktime_get_ns(o):
            regs, stack, ms, aux = o
            return aux["time_ns"], ms, aux

        def h_get_smp_processor_id(o):
            regs, stack, ms, aux = o
            return aux["cpu"], ms, aux

        def h_get_current_pid_tgid(o):
            regs, stack, ms, aux = o
            return aux["pid"], ms, aux

        def h_log2(o):
            regs, stack, ms, aux = o
            return M.jnp_log2_bin(regs[1]).astype(I64), ms, aux

        def h_get_prandom_u32(o):
            regs, stack, ms, aux = o
            x = jnp.bitwise_and(aux["rand"], jnp.int64(0xFFFFFFFF))
            x = jnp.where(x == 0, jnp.int64(1), x)
            x = jnp.bitwise_and(x ^ (x << 13), jnp.int64(0xFFFFFFFF))
            x = x ^ (x >> 17)
            x = jnp.bitwise_and(x ^ (x << 5), jnp.int64(0xFFFFFFFF))
            new_rand = jnp.where(pred, x, aux["rand"])
            return jnp.where(pred, x, jnp.int64(0)), ms, \
                {**aux, "rand": new_rand}

        def h_trace_printk(o):
            regs, stack, ms, aux = o
            slot = jnp.clip(aux["printk_n"], 0, 7).astype(jnp.int32)
            row = jnp.stack([regs[1], regs[2]])
            buf = aux["printk_buf"].at[slot].set(
                jnp.where(pred, row, aux["printk_buf"][slot]))
            n = aux["printk_n"] + jnp.where(pred, jnp.int64(1), jnp.int64(0))
            return zero, ms, {**aux, "printk_buf": buf, "printk_n": n}

        def h_override_return(o):
            regs, stack, ms, aux = o
            ov_s = jnp.where(pred, jnp.int64(1), aux["override_set"])
            ov_v = jnp.where(pred, regs[1], aux["override_val"])
            return zero, ms, {**aux, "override_set": ov_s,
                              "override_val": ov_v}

        helper_fns = {
            "map_lookup_elem": h_map_lookup_elem,
            "map_update_elem": h_map_update_elem,
            "map_delete_elem": h_map_delete_elem,
            "map_fetch_add": h_map_fetch_add,
            "percpu_fetch_add": h_percpu_fetch_add,
            "hist_add": h_hist_add,
            "ringbuf_output": h_ringbuf_output,
            "ktime_get_ns": h_ktime_get_ns,
            "get_smp_processor_id": h_get_smp_processor_id,
            "get_current_pid_tgid": h_get_current_pid_tgid,
            "log2": h_log2,
            "get_prandom_u32": h_get_prandom_u32,
            "trace_printk": h_trace_printk,
            "override_return": h_override_return,
        }
        helper_branches = [helper_fns[HELPERS[hid].name]
                           for hid in TABLE_HELPER_IDS]

        # ---- opcode handlers: opnd -> (regs, stack, ms, aux, taken)
        def b_alu(is64):
            def br(o):
                dst, src, off, imm, aluop, use_imm, size, hid, \
                    regs, stack, ms, aux = o
                d = regs[dst]
                s = jnp.where(use_imm != 0, imm, regs[src])
                rs = [J._alu_jax(op, d, s, is64) for op in _ALU_ORDER]
                r = jnp.stack(rs)[jnp.clip(aluop, 0, 12).astype(jnp.int32)]
                return regs.at[dst].set(r), stack, ms, aux, jnp.asarray(True)
            return br

        def b_lddw(o):
            dst, src, off, imm, aluop, use_imm, size, hid, \
                regs, stack, ms, aux = o
            return regs.at[dst].set(imm), stack, ms, aux, jnp.asarray(True)

        def b_ldx(o):
            dst, src, off, imm, aluop, use_imm, size, hid, \
                regs, stack, ms, aux = o
            addr = regs[src] + off
            is_ctx = addr >= CTX_BASE
            v_stack = J.dyn_word_load(stack, addr - STACK_BASE, size)
            v_ctx = J.dyn_word_load(ctx_row, addr - CTX_BASE, size)
            v = jnp.where(is_ctx, v_ctx, v_stack)
            return regs.at[dst].set(v), stack, ms, aux, jnp.asarray(True)

        def b_store(from_reg):
            def br(o):
                dst, src, off, imm, aluop, use_imm, size, hid, \
                    regs, stack, ms, aux = o
                val = regs[src] if from_reg else imm
                stack = J.dyn_word_store(stack, regs[dst] + off - STACK_BASE,
                                         size, val)
                return regs, stack, ms, aux, jnp.asarray(True)
            return br

        def b_nop(o):      # ja (tgt pre-resolved) and exit (done set outside)
            dst, src, off, imm, aluop, use_imm, size, hid, \
                regs, stack, ms, aux = o
            return regs, stack, ms, aux, jnp.asarray(True)

        def b_jcond(is64):
            def br(o):
                dst, src, off, imm, aluop, use_imm, size, hid, \
                    regs, stack, ms, aux = o
                lhs = regs[dst]
                rhs = jnp.where(use_imm != 0, imm, regs[src])
                conds = [jnp.asarray(False) if op is None
                         else J._jmp_cond_jax(op, lhs, rhs, is64)
                         for op in _COND_ORDER]
                taken = jnp.stack(conds)[
                    jnp.clip(aluop, 0, len(conds) - 1).astype(jnp.int32)]
                return regs, stack, ms, aux, taken
            return br

        def b_call(o):
            dst, src, off, imm, aluop, use_imm, size, hid, \
                regs, stack, ms, aux = o
            idx = jnp.clip(hid, 0, len(helper_branches) - 1).astype(jnp.int32)
            r0, ms, aux = jax.lax.switch(idx, helper_branches,
                                         (regs, stack, ms, aux))
            regs = regs.at[0].set(r0)
            regs = regs.at[1:6].set(jnp.zeros((5,), I64))
            return regs, stack, ms, aux, jnp.asarray(True)

        branches = [b_alu(True), b_alu(False), b_lddw, b_ldx,
                    b_store(False), b_store(True), b_nop,
                    b_jcond(True), b_jcond(False), b_call, b_nop]

        def loop_cond(c):
            pc, fuel, regs, stack, ms, ax, done = c
            return (~done) & (fuel > 0)

        def loop_body(c):
            pc, fuel, regs, stack, ms, ax, done = c
            i = jnp.clip(pc, 0, n_pad - 1).astype(jnp.int32)
            hcls = prog["hcls"][i]
            opnd = (prog["dst"][i], prog["src"][i], prog["off"][i],
                    prog["imm"][i], prog["aluop"][i], prog["use_imm"][i],
                    prog["size"][i], prog["hid"][i], regs, stack, ms, ax)
            regs, stack, ms, ax, taken = jax.lax.switch(
                jnp.clip(hcls, 0, len(branches) - 1).astype(jnp.int32),
                branches, opnd)
            nxt = jnp.where(taken, prog["tgt"][i], pc + 1)
            return (nxt, fuel - 1, regs, stack, ms, ax,
                    done | (hcls == TH_EXIT))

        regs0 = jnp.zeros((11,), I64)
        regs0 = regs0.at[isa.R1].set(jnp.int64(CTX_BASE))
        regs0 = regs0.at[isa.R10].set(jnp.int64(STACK_BASE + STACK_SIZE))
        stack0 = jnp.zeros((J.STACK_WORDS,), I64)
        init = (jnp.int64(0), prog["fuel"], regs0, stack0, maps_state, aux,
                ~pred)
        _pc, _fuel, regs, _stack, ms, ax, _done = jax.lax.while_loop(
            loop_cond, loop_body, init)
        return regs[0], ms, ax

    return core


# --------------------------------------------------------------------------
# the live table (host-side owner + in-step lane driver)
# --------------------------------------------------------------------------

class LiveTable:
    """Host-side owner of the device-resident program table.

    Encoding/clearing mutates numpy arrays here and bumps the generation
    counter; `BpftimeRuntime.sync_live_table` pushes the arrays into the
    step's map-state pytree through a donated buffer update. The device copy
    is read-only in-graph."""

    def __init__(self, map_specs, ctx_words: int = 16, max_programs: int = 4,
                 max_insns: int = 64):
        self.spec_key = _spec_key(map_specs)
        self.n_maps = len(self.spec_key)
        self.ctx_words = ctx_words
        self.max_programs = max_programs
        self.max_insns = max_insns
        self.host: dict[str, np.ndarray] = {
            f: np.zeros((max_programs, max_insns), np.int64)
            for f in TABLE_FIELDS}
        # padded rows halt immediately if a (verified-impossible) runaway pc
        # ever lands on them
        self.host["hcls"][:, :] = TH_EXIT
        for f in META_FIELDS:
            self.host[f] = np.zeros((max_programs,), np.int64)
        self.host["gen"] = np.zeros((1,), np.int64)
        self.slot_pid: list[int | None] = [None] * max_programs

    # ------------------------------------------------------------- host side
    def device_state(self) -> dict:
        return {k: jnp.asarray(v) for k, v in self.host.items()}

    def free_slot(self) -> int | None:
        for p in range(self.max_programs):
            if not self.host["active"][p]:
                return p
        return None

    def encode_slot(self, slot: int, vprog: VerifiedProgram, site_id: int,
                    kind: int, pid: int = 0) -> None:
        tp = isa.encode_table_program(vprog.insns, TABLE_HELPER_INDEX)
        n = len(vprog.insns)
        for f in TABLE_FIELDS:
            self.host[f][slot, :] = TH_EXIT if f == "hcls" else 0
            self.host[f][slot, :n] = tp[f]
        self.host["active"][slot] = 1
        self.host["site"][slot] = site_id
        self.host["kind"][slot] = kind
        self.host["n_insns"][slot] = n
        # fuel in INSN steps. The scan-lane T2 budget is vprog.max_insns
        # BLOCK-dispatch steps (jit.compile_t2); scale by the longest block
        # so any execution that completes within the scan lane's budget also
        # completes here — exhausting either budget (the kernel's 1M-insn
        # safety net, not a semantic) is outside the equivalence contract.
        max_block = max((b.end - b.start for b in vprog.blocks), default=1)
        self.host["fuel"][slot] = vprog.max_insns * max(1, max_block)
        self.host["gen"][0] += 1
        self.slot_pid[slot] = pid

    def clear_slot(self, slot: int) -> None:
        self.host["active"][slot] = 0
        self.host["gen"][0] += 1
        self.slot_pid[slot] = None

    # ------------------------------------------------------------- device side
    def run(self, table_state: dict, event_rows, maps_state, aux):
        """The interpreter lane: scan the event tape, running every active
        table slot on each row (slot order — the combined-scan interleave,
        like jit.run_fused_scan). Traced inside the step function; everything
        about `table_state` is data."""
        core = _build_core(self.spec_key, self.ctx_words)

        def step(carry, row):
            ms, ax = carry
            for p in range(self.max_programs):
                prog = {f: table_state[f][p] for f in TABLE_FIELDS}
                prog["fuel"] = table_state["fuel"][p]
                pred = ((table_state["active"][p] != 0)
                        & (row[0] == table_state["site"][p])
                        & (row[1] == table_state["kind"][p]))
                _r0, ms, ax = core(prog, row, ms, ax, pred)
            return (ms, ax), jnp.int64(0)

        (ms, ax), _ = jax.lax.scan(step, (maps_state, aux), event_rows)
        return ms, ax


# --------------------------------------------------------------------------
# differential-test entry point
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 1))
def _jit_run_single(spec_key, ctx_words, prog, ctx_row, maps_state, aux):
    core = _build_core(spec_key, ctx_words)
    return core(prog, ctx_row, maps_state, aux, jnp.asarray(True))


def run_program(vprog: VerifiedProgram, ctx_row, maps_state, aux,
                pad_insns: int = 128):
    """Run ONE verified program through the table interpreter on a single
    ctx row with pred=True — the differential-test twin of
    `jit.compile_program`. Padded to a shared width so the corpus reuses one
    compiled interpreter per (map universe, ctx width)."""
    lt = LiveTable(vprog.map_specs, ctx_words=vprog.ctx_words,
                   max_programs=1,
                   max_insns=max(pad_insns, len(vprog.insns)))
    lt.encode_slot(0, vprog, site_id=0, kind=0)
    tbl = lt.device_state()
    prog = {f: tbl[f][0] for f in TABLE_FIELDS}
    prog["fuel"] = tbl["fuel"][0]
    return _jit_run_single(lt.spec_key, lt.ctx_words, prog,
                           jnp.asarray(ctx_row, I64), maps_state, aux)
