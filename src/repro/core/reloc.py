"""Load-time relocation — the CO-RE resolver (verify once, relocate anywhere).

A program verified in abstract mode (verifier.verify with map_refs /
ctx_refs) carries a :class:`RelocRecord`: which insns hold symbolic map
references, which insns took their ctx offset from a named field, and
the layouts they were verified against.  :func:`resolve` binds that
program to ANY concrete world — a map registry (name -> fd) and a target
ctx layout — without re-running the verifier fixpoint:

  * `lddw rX, map:NAME`  : imm64 patched local-index -> concrete fd, and
    every CallAnn mapfd static remapped the same way (the verifier's
    MAPVAL lattice kind guarantees those are the ONLY places a map
    reference can flow, so positional rebinding is sound);
  * ctx loads            : `off` re-offset from the source layout's byte
    of the field to the target layout's, with the MemAnn moved by the
    same delta and re-bounds/alignment-checked against the target width.

Everything verification actually proved — bounded execution, typed
helper args, initialized stack reads — is layout-independent and carries
over verbatim; relocation re-checks only the cheap structural facts
(symbol exists, kind matches, field in bounds).  All failures raise
:class:`RelocationError` BEFORE any output is built, so a bad target
world leaves nothing half-bound (the live-table generation counter is
never touched by a failed attach).
"""
from __future__ import annotations

from dataclasses import dataclass, replace as _dc_replace

from . import isa
from .isa import Insn
from .layout import CtxLayout, MapLayout
from .maps import MapSpec
from .verifier import CallAnn, MemAnn, VerifiedProgram
from .helpers import HELPERS


class RelocationError(ValueError):
    pass


@dataclass(frozen=True)
class RelocRecord:
    """insn index -> symbolic ref, plus the world verified against.

    ``map_layouts`` is the declared object-local map list (local index =
    position); ``map_lddw`` maps lddw insn idx -> local map index;
    ``ctx_refs`` maps ldx insn idx -> ctx field name; ``ctx_layout`` is
    the layout those offsets were assembled against (None when the
    program reads no named ctx fields).  ``resolved`` marks a record
    carried on an already-bound program (display only — re-resolving
    always starts from the abstract program)."""
    map_layouts: tuple[MapLayout, ...]
    map_lddw: dict[int, int]
    ctx_refs: dict[int, str]
    ctx_layout: CtxLayout | None = None
    resolved: bool = False

    def map_name(self, local: int) -> str:
        return self.map_layouts[local].name

    def symbols(self) -> tuple[str, ...]:
        return tuple(ml.name for ml in self.map_layouts)


def resolve(vabs: VerifiedProgram, fd_of: dict[str, int],
            concrete_specs: list[MapSpec],
            ctx_layout: CtxLayout | None = None,
            ctx_words: int | None = None) -> VerifiedProgram:
    """Bind an abstract VerifiedProgram to a concrete world.

    ``fd_of``/``concrete_specs`` describe the target registry (fd order);
    ``ctx_layout`` the target event-row layout (defaults to the source
    layout — pure map rebinding); ``ctx_words`` the target row width
    (defaults to the target layout's, else the program's). Returns a NEW
    runnable VerifiedProgram; ``vabs`` is never mutated, and on any
    error nothing is produced at all."""
    rec = vabs.reloc
    if not isinstance(rec, RelocRecord):
        raise RelocationError("program was not verified in abstract mode "
                              "(no relocation record)")
    if rec.resolved:
        raise RelocationError("program is already resolved — relocate from "
                              "the abstract original")

    # ---- phase 1: validate the whole binding, touching nothing ----------
    local_fd: dict[int, int] = {}
    for li, ml in enumerate(rec.map_layouts):
        fd = fd_of.get(ml.name)
        if fd is None:
            raise RelocationError(f"missing map symbol {ml.name!r} in target "
                                  f"registry (has {sorted(fd_of)})")
        if not 0 <= fd < len(concrete_specs):
            raise RelocationError(f"map {ml.name!r}: fd {fd} out of range "
                                  f"for registry of {len(concrete_specs)}")
        why = ml.compatible(concrete_specs[fd])
        if why:
            raise RelocationError(why)
        local_fd[li] = fd

    src_layout = rec.ctx_layout
    tgt_layout = ctx_layout or src_layout
    if ctx_words is None:
        ctx_words = tgt_layout.words if tgt_layout is not None else vabs.ctx_words
    ctx_bytes = 8 * ctx_words
    if rec.ctx_refs and (src_layout is None or tgt_layout is None):
        raise RelocationError("program has ctx relocations but no ctx layout")

    ctx_patch: dict[int, int] = {}   # insn idx -> new byte offset
    for idx, fld in rec.ctx_refs.items():
        if not tgt_layout.has(fld):
            raise RelocationError(
                f"insn {idx}: ctx field {fld!r} missing from target layout "
                f"{tgt_layout.name!r}")
        ann = vabs.anns.get(idx)
        assert isinstance(ann, MemAnn) and ann.region == "ctx"
        delta = tgt_layout.byte_of(fld) - src_layout.byte_of(fld)
        new_off = ann.off + delta
        if new_off < 0 or new_off + ann.size > ctx_bytes:
            raise RelocationError(
                f"insn {idx}: ctx field {fld!r} relocates to "
                f"[{new_off},{new_off + ann.size}) outside target ctx "
                f"({ctx_bytes}B)")
        if new_off % ann.size:
            raise RelocationError(
                f"insn {idx}: ctx field {fld!r} relocates to unaligned "
                f"offset {new_off} (size {ann.size})")
        ctx_patch[idx] = delta

    # non-relocated ctx accesses must still fit the (possibly narrower)
    # target row: their offsets are layout constants the program hard-coded
    for idx, ann in vabs.anns.items():
        if (isinstance(ann, MemAnn) and ann.region == "ctx"
                and idx not in ctx_patch):
            if ann.off + ann.size > ctx_bytes:
                raise RelocationError(
                    f"insn {idx}: fixed ctx access [{ann.off},"
                    f"{ann.off + ann.size}) outside target ctx ({ctx_bytes}B)")

    # ---- phase 2: build the bound program (fresh objects throughout) ----
    insns: list[Insn] = list(vabs.insns)
    for idx, li in rec.map_lddw.items():
        fd = local_fd[li]
        old = insns[idx]
        insns[idx] = Insn(old.op, old.dst, old.src, old.off,
                          imm=fd & 0xFFFFFFFF, imm64=fd)
    for idx, delta in ctx_patch.items():
        old = insns[idx]
        insns[idx] = Insn(old.op, old.dst, old.src, old.off + delta,
                          imm=old.imm, imm64=old.imm64)

    anns: dict[int, object] = {}
    for idx, ann in vabs.anns.items():
        if isinstance(ann, MemAnn):
            if idx in ctx_patch:
                off = ann.off + ctx_patch[idx]
                ann = MemAnn(ann.region, off, ann.size,
                             aligned=(off % 8 == 0 and ann.size == 8))
            else:
                ann = MemAnn(ann.region, ann.off, ann.size, aligned=ann.aligned)
        elif isinstance(ann, CallAnn):
            sig = HELPERS[ann.hid]
            statics = list(ann.statics)
            for i, kind in enumerate(sig.args):
                if kind == "mapfd":
                    statics[i] = local_fd[statics[i]]
            # key_vals are stack constants — layout-independent, carry over
            ann = CallAnn(hid=ann.hid, name=ann.name, statics=statics,
                          key_vals=ann.key_vals)
        anns[idx] = ann

    touched = frozenset(local_fd[li] for li in vabs.touched_map_fds)
    from .verifier import compute_footprints
    return VerifiedProgram(
        insns=insns, map_specs=list(concrete_specs), ctx_words=ctx_words,
        anns=anns, blocks=vabs.blocks, block_of=vabs.block_of,
        tier=vabs.tier, max_insns=vabs.max_insns,
        helper_ids_used=set(vabs.helper_ids_used),
        touched_map_fds=touched, touched_aux=vabs.touched_aux,
        footprints=compute_footprints(anns, concrete_specs),
        reloc=_dc_replace(rec, resolved=True))


def verify_relocatable(obj) -> VerifiedProgram:
    """Abstract-verify a loader.ProgramObject once, against its own
    declared maps and BTF — the artifact a fleet ships around and
    resolves per-world (the runtime path and `prog relocate` both come
    through here)."""
    from .layout import layout_for
    from .verifier import verify
    insns = obj.decode_insns()
    declared = obj.map_specs()
    src_layout = layout_for(obj.prog_type, obj.btf, obj.ctx_words)
    return verify(
        insns, declared, ctx_words=obj.ctx_words,
        map_refs={int(k): v for k, v in obj.relocs.items()},
        ctx_refs={int(k): v for k, v in getattr(obj, "ctx_relocs", {}).items()},
        ctx_layout=src_layout)


def relocation_table(vprog: VerifiedProgram) -> list[dict]:
    """Human/JSON rows for the `prog relocate` dry-run."""
    rec = vprog.reloc
    if not isinstance(rec, RelocRecord):
        return []
    rows = []
    for idx in sorted(rec.map_lddw):
        li = rec.map_lddw[idx]
        rows.append({"insn": idx, "kind": "map",
                     "symbol": rec.map_name(li), "local_fd": li,
                     "bound_fd": int(vprog.insns[idx].imm64 or 0)
                     if rec.resolved else None,
                     "disasm": isa.disasm_one(vprog.insns[idx])})
    for idx in sorted(rec.ctx_refs):
        fld = rec.ctx_refs[idx]
        rows.append({"insn": idx, "kind": "ctx", "symbol": fld,
                     "byte": vprog.insns[idx].off,
                     "src_byte": (rec.ctx_layout.byte_of(fld)
                                  if rec.ctx_layout else None),
                     "disasm": isa.disasm_one(vprog.insns[idx])})
    return rows
