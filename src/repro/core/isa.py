"""eBPF-subset ISA: faithful 8-byte instruction encoding (Linux uapi layout).

Instruction layout (little-endian, struct '<BBhi'):
    opcode:u8 | dst_reg:4,src_reg:4 | off:s16 | imm:s32
LDDW (BPF_LD|BPF_IMM|BPF_DW) is the only 16-byte insn; the second slot
carries the high 32 bits of the 64-bit immediate in its imm field.

Registers: r0 (return value), r1-r5 (helper args, caller-saved),
r6-r9 (callee-saved), r10 (read-only frame pointer).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

# ---------------------------------------------------------------- classes
BPF_LD = 0x00
BPF_LDX = 0x01
BPF_ST = 0x02
BPF_STX = 0x03
BPF_ALU = 0x04
BPF_JMP = 0x05
BPF_JMP32 = 0x06
BPF_ALU64 = 0x07

CLS_MASK = 0x07

# ---------------------------------------------------------------- sizes (ld/st)
BPF_W = 0x00   # u32
BPF_H = 0x08   # u16
BPF_B = 0x10   # u8
BPF_DW = 0x18  # u64
SIZE_MASK = 0x18
SIZE_BYTES = {BPF_W: 4, BPF_H: 2, BPF_B: 1, BPF_DW: 8}

# ---------------------------------------------------------------- modes (ld/st)
BPF_IMM = 0x00
BPF_MEM = 0x60
MODE_MASK = 0xE0

# ---------------------------------------------------------------- alu/jmp source
BPF_K = 0x00   # use imm
BPF_X = 0x08   # use src reg
SRC_MASK = 0x08

# ---------------------------------------------------------------- alu ops
BPF_ADD = 0x00
BPF_SUB = 0x10
BPF_MUL = 0x20
BPF_DIV = 0x30
BPF_OR = 0x40
BPF_AND = 0x50
BPF_LSH = 0x60
BPF_RSH = 0x70
BPF_NEG = 0x80
BPF_MOD = 0x90
BPF_XOR = 0xA0
BPF_MOV = 0xB0
BPF_ARSH = 0xC0
OP_MASK = 0xF0

ALU_OP_NAMES = {
    BPF_ADD: "add", BPF_SUB: "sub", BPF_MUL: "mul", BPF_DIV: "div",
    BPF_OR: "or", BPF_AND: "and", BPF_LSH: "lsh", BPF_RSH: "rsh",
    BPF_NEG: "neg", BPF_MOD: "mod", BPF_XOR: "xor", BPF_MOV: "mov",
    BPF_ARSH: "arsh",
}

# ---------------------------------------------------------------- jmp ops
BPF_JA = 0x00
BPF_JEQ = 0x10
BPF_JGT = 0x20
BPF_JGE = 0x30
BPF_JSET = 0x40
BPF_JNE = 0x50
BPF_JSGT = 0x60
BPF_JSGE = 0x70
BPF_CALL = 0x80
BPF_EXIT = 0x90
BPF_JLT = 0xA0
BPF_JLE = 0xB0
BPF_JSLT = 0xC0
BPF_JSLE = 0xD0

JMP_OP_NAMES = {
    BPF_JA: "ja", BPF_JEQ: "jeq", BPF_JGT: "jgt", BPF_JGE: "jge",
    BPF_JSET: "jset", BPF_JNE: "jne", BPF_JSGT: "jsgt", BPF_JSGE: "jsge",
    BPF_CALL: "call", BPF_EXIT: "exit", BPF_JLT: "jlt", BPF_JLE: "jle",
    BPF_JSLT: "jslt", BPF_JSLE: "jsle",
}
COND_JMP_OPS = (BPF_JEQ, BPF_JGT, BPF_JGE, BPF_JSET, BPF_JNE, BPF_JSGT,
                BPF_JSGE, BPF_JLT, BPF_JLE, BPF_JSLT, BPF_JSLE)

# ---------------------------------------------------------------- memory map
# Pointer values are plain 64-bit integers; regions are carved out of the
# address space so both the interpreter and verifier can classify them.
STACK_SIZE = 512
STACK_BASE = 0x1_0000_0000          # r10 == STACK_BASE + STACK_SIZE
CTX_BASE = 0x2_0000_0000            # r1 at entry (read-only)
MAX_CTX_BYTES = 512

NUM_REGS = 11
R0, R1, R2, R3, R4, R5, R6, R7, R8, R9, R10 = range(11)

U64 = (1 << 64) - 1
U32 = (1 << 32) - 1


def u64(x: int) -> int:
    return x & U64


def s64(x: int) -> int:
    x &= U64
    return x - (1 << 64) if x >> 63 else x


def u32(x: int) -> int:
    return x & U32


def s32(x: int) -> int:
    x &= U32
    return x - (1 << 32) if x >> 31 else x


@dataclass(frozen=True)
class Insn:
    op: int
    dst: int = 0
    src: int = 0
    off: int = 0
    imm: int = 0
    # imm64 is only meaningful for LDDW; carried unencoded for convenience.
    imm64: int | None = None

    @property
    def cls(self) -> int:
        return self.op & CLS_MASK

    def is_lddw(self) -> bool:
        return self.op == (BPF_LD | BPF_IMM | BPF_DW)

    def encode(self) -> bytes:
        regs = ((self.src & 0xF) << 4) | (self.dst & 0xF)
        if self.is_lddw():
            v = u64(self.imm64 if self.imm64 is not None else self.imm)
            lo = v & U32
            hi = (v >> 32) & U32
            return (struct.pack("<BBhi", self.op, regs, self.off, s32(lo))
                    + struct.pack("<BBhi", 0, 0, 0, s32(hi)))
        return struct.pack("<BBhi", self.op, regs, self.off, s32(self.imm))

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return disasm_one(self)


def encode_program(insns: list[Insn]) -> bytes:
    return b"".join(i.encode() for i in insns)


def decode_program(blob: bytes) -> list[Insn]:
    if len(blob) % 8:
        raise ValueError("program length not a multiple of 8")
    raw = [struct.unpack_from("<BBhi", blob, i) for i in range(0, len(blob), 8)]
    out: list[Insn] = []
    i = 0
    while i < len(raw):
        op, regs, off, imm = raw[i]
        dst, src = regs & 0xF, (regs >> 4) & 0xF
        if op == (BPF_LD | BPF_IMM | BPF_DW):
            if i + 1 >= len(raw):
                raise ValueError("truncated lddw")
            _, _, _, hi = raw[i + 1]
            imm64 = u64((u32(hi) << 32) | u32(imm))
            out.append(Insn(op, dst, src, off, imm, imm64=imm64))
            i += 2
            continue
        out.append(Insn(op, dst, src, off, imm))
        i += 1
    return out


def insn_slots(insns: list[Insn]) -> list[int]:
    """Slot index (in 8-byte units) of each decoded insn — jump offsets are
    expressed in slots, and LDDW occupies two."""
    slots, cur = [], 0
    for ins in insns:
        slots.append(cur)
        cur += 2 if ins.is_lddw() else 1
    return slots


# ---------------------------------------------------------------- table form
# Handler classes for the device-resident program-table interpreter
# (table_interp.py): every decoded insn maps to one of these at ENCODE time,
# so the in-graph interpreter dispatches on a small data-driven switch
# instead of decoding opcodes with tensor bit arithmetic.
(TH_ALU64, TH_ALU32, TH_LDDW, TH_LDX, TH_ST, TH_STX, TH_JA, TH_JCOND64,
 TH_JCOND32, TH_CALL, TH_EXIT) = range(11)

# Fields of the packed form, one flat i64 array per field (length = n insns):
#   hcls     handler class (TH_*)
#   dst/src  register numbers
#   off      s16 memory offset (jump offsets are pre-resolved into `tgt`)
#   imm      sign-extended immediate; full s64 value for LDDW
#   aluop    (op & OP_MASK) >> 4 — ALU op index, or cond-jump op index
#   use_imm  1 when the K (immediate) source form is used
#   size     access width in bytes for ld/st
#   tgt      next insn INDEX when the insn transfers control (ja/taken cond);
#            i + 1 for everything else, so `tgt` is the universal "taken" pc
#   hid      helper BRANCH index (via helper_index) for TH_CALL
TABLE_FIELDS = ("hcls", "dst", "src", "off", "imm", "aluop", "use_imm",
                "size", "tgt", "hid")


def encode_table_program(insns: list[Insn],
                         helper_index: dict[int, int] | None = None) -> dict:
    """Pack decoded (already verified) bytecode into fixed-layout i64 arrays
    for the table interpreter. Jump targets are resolved from slot units to
    decoded-insn indices here, so the interpreter never touches slot math.
    Returns {field: list[int]} of equal length (see TABLE_FIELDS)."""
    n = len(insns)
    slots = insn_slots(insns)
    slot2idx = {s: i for i, s in enumerate(slots)}
    out = {f: [0] * n for f in TABLE_FIELDS}

    def jump_target(i: int) -> int:
        tgt_slot = slots[i] + 1 + insns[i].off
        if tgt_slot not in slot2idx:
            raise ValueError(f"insn {i}: jump to invalid slot {tgt_slot}")
        return slot2idx[tgt_slot]

    for i, ins in enumerate(insns):
        cls = ins.cls
        out["dst"][i] = ins.dst
        out["src"][i] = ins.src
        out["off"][i] = ins.off
        out["tgt"][i] = i + 1
        if ins.is_lddw():
            out["hcls"][i] = TH_LDDW
            out["imm"][i] = s64(ins.imm64 or 0)
        elif cls in (BPF_ALU64, BPF_ALU):
            out["hcls"][i] = TH_ALU64 if cls == BPF_ALU64 else TH_ALU32
            out["aluop"][i] = (ins.op & OP_MASK) >> 4
            out["use_imm"][i] = 0 if ins.op & SRC_MASK else 1
            out["imm"][i] = ins.imm
        elif cls == BPF_LDX:
            out["hcls"][i] = TH_LDX
            out["size"][i] = SIZE_BYTES[ins.op & SIZE_MASK]
        elif cls in (BPF_ST, BPF_STX):
            out["hcls"][i] = TH_ST if cls == BPF_ST else TH_STX
            out["size"][i] = SIZE_BYTES[ins.op & SIZE_MASK]
            out["imm"][i] = ins.imm
        elif cls in (BPF_JMP, BPF_JMP32):
            jop = ins.op & OP_MASK
            if jop == BPF_EXIT:
                out["hcls"][i] = TH_EXIT
            elif jop == BPF_JA:
                out["hcls"][i] = TH_JA
                out["tgt"][i] = jump_target(i)
            elif jop == BPF_CALL:
                out["hcls"][i] = TH_CALL
                out["hid"][i] = (helper_index[ins.imm] if helper_index
                                 else ins.imm)
            else:
                out["hcls"][i] = (TH_JCOND64 if cls == BPF_JMP
                                  else TH_JCOND32)
                out["aluop"][i] = jop >> 4
                out["use_imm"][i] = 0 if ins.op & SRC_MASK else 1
                out["imm"][i] = ins.imm
                out["tgt"][i] = jump_target(i)
        else:
            raise ValueError(f"insn {i}: unknown class {cls:#x}")
    return out


def disasm_one(ins: Insn) -> str:
    cls = ins.cls
    if ins.is_lddw():
        return f"lddw r{ins.dst}, {ins.imm64:#x}"
    if cls in (BPF_ALU, BPF_ALU64):
        name = ALU_OP_NAMES.get(ins.op & OP_MASK, "?")
        w = "" if cls == BPF_ALU64 else "32"
        if (ins.op & OP_MASK) == BPF_NEG:
            return f"neg{w} r{ins.dst}"
        src = f"r{ins.src}" if ins.op & BPF_X else f"{ins.imm}"
        return f"{name}{w} r{ins.dst}, {src}"
    if cls in (BPF_JMP, BPF_JMP32):
        jop = ins.op & OP_MASK
        name = JMP_OP_NAMES.get(jop, "?")
        if jop == BPF_EXIT:
            return "exit"
        if jop == BPF_CALL:
            return f"call {ins.imm}"
        if jop == BPF_JA:
            return f"ja +{ins.off}"
        src = f"r{ins.src}" if ins.op & BPF_X else f"{ins.imm}"
        w = "" if cls == BPF_JMP else "32"
        return f"{name}{w} r{ins.dst}, {src}, +{ins.off}"
    if cls in (BPF_LDX, BPF_ST, BPF_STX):
        sz = {BPF_W: "w", BPF_H: "h", BPF_B: "b", BPF_DW: "dw"}[ins.op & SIZE_MASK]
        if cls == BPF_LDX:
            return f"ldx{sz} r{ins.dst}, [r{ins.src}{ins.off:+d}]"
        if cls == BPF_STX:
            return f"stx{sz} [r{ins.dst}{ins.off:+d}], r{ins.src}"
        return f"st{sz} [r{ins.dst}{ins.off:+d}], {ins.imm}"
    return f"raw op={ins.op:#x}"


def disasm(insns: list[Insn]) -> str:
    return "\n".join(f"{i:4d}: {disasm_one(x)}" for i, x in enumerate(insns))
