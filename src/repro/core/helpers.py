"""Helper-function table (the BPF_CALL interface).

IDs match Linux where an equivalent exists; runtime-specific helpers live in
the 1000+ range (like bpftime's userspace-only helpers). The signature table
drives verifier arg-checking; execution lives in vm.py (numpy twin) and
jit.py (jnp twin).

Arg kinds:
  mapfd   const scalar naming a bound map (verifier must know it statically —
          the analogue of the kernel requiring a map fd via LDDW relocation)
  kptr    readable stack pointer, 8 initialized bytes (a key/value cell)
  scalar  any initialized scalar
  cscalar const scalar (e.g. ringbuf output size)
"""
from __future__ import annotations

from dataclasses import dataclass

from .maps import MapKind


@dataclass(frozen=True)
class HelperSig:
    hid: int
    name: str
    args: tuple[str, ...]
    # map kinds accepted for the mapfd arg (None = any)
    map_kinds: tuple[MapKind, ...] | None = None


HELPERS: dict[int, HelperSig] = {h.hid: h for h in [
    HelperSig(1, "map_lookup_elem", ("mapfd", "kptr"),
              (MapKind.ARRAY, MapKind.HASH, MapKind.PERCPU_ARRAY)),
    HelperSig(2, "map_update_elem", ("mapfd", "kptr", "kptr", "scalar"),
              (MapKind.ARRAY, MapKind.HASH)),
    HelperSig(3, "map_delete_elem", ("mapfd", "kptr"), (MapKind.HASH,)),
    HelperSig(5, "ktime_get_ns", ()),
    HelperSig(6, "trace_printk", ("scalar", "scalar")),
    HelperSig(7, "get_prandom_u32", ()),
    HelperSig(8, "get_smp_processor_id", ()),
    HelperSig(14, "get_current_pid_tgid", ()),
    HelperSig(130, "ringbuf_output", ("mapfd", "kptr", "cscalar", "scalar"),
              (MapKind.RINGBUF,)),
    HelperSig(1001, "map_fetch_add", ("mapfd", "kptr", "scalar"),
              (MapKind.ARRAY, MapKind.HASH)),
    HelperSig(1002, "log2", ("scalar",)),
    HelperSig(1003, "override_return", ("scalar",)),
    HelperSig(1004, "hist_add", ("mapfd", "scalar"), (MapKind.LOG2HIST,)),
    HelperSig(1005, "percpu_fetch_add", ("mapfd", "kptr", "scalar"),
              (MapKind.PERCPU_ARRAY,)),
]}

HELPER_IDS: dict[str, int] = {h.name: h.hid for h in HELPERS.values()}

# aux fields each helper may WRITE — drives the verifier's touched-aux
# analysis (fused pipeline gates per-event aux selects to this footprint).
AUX_WRITES: dict[str, tuple[str, ...]] = {
    "get_prandom_u32": ("rand",),
    "trace_printk": ("printk_buf", "printk_n"),
    "override_return": ("override_set", "override_val"),
}
