"""mmap-backed shared-memory control plane — bpftime's shm maps + daemon
handshake, adapted to the host side of a TPU trainer fleet.

Layout under a shm directory (SP3 segregation: program text, device-map
snapshots, and host-map data live in separate sections; the agent may write
only map-data sections — enforced here by API shape, in production by file
permissions, see DESIGN.md §5):

    <dir>/meta.json                 map specs + layout (written once, shared)
    <dir>/progs/<name>.json         program objects (read-only to agents)

Single-process layout (worker_id=None — the seed shape, unchanged):

    <dir>/host/<map>.<field>.npy    live host-side maps (memmapped, rw)
    <dir>/device/<map>.<field>.npy  per-step snapshots of device maps
    <dir>/device/.seq.npy           seqlock (odd while a publish is in flight)
    <dir>/control/requests.json     daemon -> trainer attach/detach requests
    <dir>/control/.reqseq.npy       request counter
    <dir>/control/status.json       trainer -> daemon control-plane status

Fleet layout (worker_id="w0", "w1", ... — DESIGN.md §10): every worker owns
the SAME section tree under its own base, so one daemon can observe N
train/serve processes as one system:

    <dir>/workers/<wid>/worker.json  pid + boot id (liveness / restart detect)
    <dir>/workers/<wid>/{host,device,control}/...   as above, per worker
    <dir>/global/<map>.<field>.npy   daemon-merged view of the whole fleet
    <dir>/global/.seq.npy            seqlock for the merged view
    <dir>/global/status.json         aggregation status (alive/dead workers,
                                     per-worker heads, merge stats)
"""
from __future__ import annotations

import json
import os
import time
import uuid
import zlib
from dataclasses import dataclass

import numpy as np

from . import faults, maps as M
from .maps import MapKind, MapSpec


class SnapshotCorruption(Exception):
    """A seqlocked section read consistently (even, stable seq) but its
    payload does not match the checksum the publisher wrote: the bytes were
    damaged AFTER the publish. Detect-and-skip, never silent-merge."""


def _memmap(path, shape, mode):
    if mode == "w+":
        return np.lib.format.open_memmap(path, mode="w+", dtype=np.int64,
                                         shape=shape)
    return np.lib.format.open_memmap(path, mode=mode)


def _atomic_json(path: str, obj) -> None:
    tmp = f"{path}.{os.getpid()}.tmp"
    # dumps + one write: json.dump streams thousands of tiny writes per
    # fleet journal, which dominates hot aggregation cycles
    buf = json.dumps(obj)
    with open(tmp, "w") as f:
        f.write(buf)
    os.replace(tmp, path)          # atomic for concurrent readers/writers


def _specs_to_meta(specs: list[MapSpec]) -> dict:
    return {"specs": [{"name": s.name, "kind": s.kind.value,
                       "max_entries": s.max_entries,
                       "rec_width": s.rec_width,
                       "num_shards": s.num_shards,
                       "flags": s.flags} for s in specs],
            "version": 2}


def _specs_from_meta(meta: dict) -> list[MapSpec]:
    return [MapSpec(name=m["name"], kind=MapKind(m["kind"]),
                    max_entries=m["max_entries"],
                    rec_width=m["rec_width"],
                    num_shards=m["num_shards"],
                    flags=m.get("flags", {})) for m in meta["specs"]]


def read_meta_specs(root: str) -> list[MapSpec]:
    with open(os.path.join(root, "meta.json")) as f:
        return _specs_from_meta(json.load(f))


def _worker_base(root: str, worker_id: str | None) -> str:
    if worker_id is None:
        return root
    return os.path.join(root, "workers", str(worker_id))


# --------------------------------------------------------------------------
# seqlocked field sections (shared by per-worker device dirs and global/)
# --------------------------------------------------------------------------

def _create_section(dirpath: str, specs: list[MapSpec]) -> dict:
    """Create (or re-create, on worker restart) a section's field files.
    Existing files are reused IN PLACE ('r+', zeroed) rather than
    truncated: a live reader's mmap of the same inode keeps working and
    simply observes the zeroed state — open_memmap('w+') would shrink the
    inode to 0 bytes for a moment, turning a concurrent read into SIGBUS."""
    os.makedirs(dirpath, exist_ok=True)
    out = {}
    for s in specs:
        tmpl = M.init_state(s, np)
        out[s.name] = {}
        for field, arr in tmpl.items():
            path = os.path.join(dirpath, f"{s.name}.{field}.npy")
            if os.path.exists(path):
                mm = _memmap(path, None, "r+")
            else:
                mm = _memmap(path, arr.shape, "w+")
            mm[...] = 0
            out[s.name][field] = mm
    return out


def _attach_section(dirpath: str, specs: list[MapSpec], mode: str) -> dict:
    out = {}
    for s in specs:
        out[s.name] = {}
        for field in M.init_state(s, np):
            out[s.name][field] = _memmap(
                os.path.join(dirpath, f"{s.name}.{field}.npy"), None, mode)
    return out


def _crc_of(state: dict) -> int:
    """CRC32 over a map state's field bytes, fields in sorted order — the
    per-section corruption check written under the seqlock."""
    c = 0
    for f in sorted(state):
        c = zlib.crc32(np.ascontiguousarray(state[f]).tobytes(), c)
    return c


def _crc_path(dirpath: str) -> str:
    return os.path.join(dirpath, ".crc.npy")


def _crc_create(dirpath: str, n: int) -> np.memmap:
    p = _crc_path(dirpath)
    crc = _memmap(p, None, "r+") if os.path.exists(p) \
        else _memmap(p, (n,), "w+")
    crc[...] = 0
    crc.flush()
    return crc


def _crc_attach(dirpath: str, mode: str) -> np.memmap | None:
    p = _crc_path(dirpath)
    if not os.path.exists(p):
        return None              # pre-checksum region: no validation
    return _memmap(p, None, "r+" if mode != "r" else "r")


# Seqlock backoff defaults (satellite: configurable via AggregatorConfig).
# First retry sleeps BACKOFF_BASE, doubling up to BACKOFF_MAX per attempt:
# the common one-publish-in-flight case resolves in ~50us instead of the
# old fixed 1ms, while a genuinely stuck writer still costs at most
# retries * BACKOFF_MAX before TimeoutError.
BACKOFF_BASE = 5e-5
BACKOFF_MAX = 0.01


def _seq_publish(seq: np.memmap, section: dict, states: dict,
                 crc: np.memmap | None = None,
                 order: list[str] | None = None,
                 role: str = "worker") -> None:
    # parity self-heal: an odd seq here means a prior publisher died (or
    # injected-crashed) mid-publish — we are already "in flight", so don't
    # flip again; completing this publish returns the section to even with
    # fully consistent contents
    # NO msync in the hot path: MAP_SHARED readers on the same host see
    # these stores through the unified page cache immediately — msync only
    # forces disk writeback, and crash durability is the JOURNAL's job
    # (the view is rebuilt from it on restart). Consistency comes from seq
    # parity + the CRC sidecar, never from flush ordering.
    if int(seq[0]) % 2 == 0:
        seq[0] += 1          # odd: write in flight
    # role tags who is publishing: worker-side fault classes (torn/stuck/
    # corrupt/kill/slow) only target "worker" publishes — daemon failures
    # are modeled by the agg:* crash schedule, not by tearing the global
    # view's own seqlocked publish
    faults.fire("shm:publish_begin", role=role)
    for name, st in states.items():
        if name not in section:
            continue
        for field, arr in st.items():
            faults.fire("shm:publish_field", map=name, field=field,
                        role=role)
            section[name][field][...] = np.asarray(arr)
    if crc is not None:
        # recomputed from SECTION content (not `states`): maps skipped
        # this publish keep a checksum matching what is actually on disk
        for i, name in enumerate(order):
            crc[i] = _crc_of(section[name])
    faults.fire("shm:publish_commit", section=section, role=role)
    seq[0] += 1          # even: consistent


def _seq_snapshot(seq: np.memmap, section: dict, name: str, retries: int,
                  backoff_base: float = BACKOFF_BASE,
                  backoff_max: float = BACKOFF_MAX,
                  crc: np.memmap | None = None,
                  crc_idx: int | None = None) -> tuple[dict, int, int]:
    """Returns (state, seq_observed, retries_used). A successful read always
    observes an EVEN sequence number, unchanged across the copy, and (when
    the section carries checksums) a payload matching the publisher's CRC.
    Retries back off exponentially from backoff_base to backoff_max."""
    faults.fire("shm:snapshot_begin", name=name)
    delay = backoff_base
    for attempt in range(retries):
        s0 = int(seq[0])
        if s0 % 2 == 0:
            out = {f: np.array(a) for f, a in section[name].items()}
            want = int(crc[crc_idx]) if crc is not None else None
            if int(seq[0]) == s0:
                # seq 0 = never published: the zeroed crc array is not the
                # crc of the zeroed section, so validation starts at the
                # first real publish
                if want is not None and s0 > 0 and _crc_of(out) != want:
                    raise SnapshotCorruption(
                        f"{name}: checksum mismatch at seq {s0}")
                return out, s0, attempt
        time.sleep(delay)
        delay = min(delay * 2, backoff_max)
    raise TimeoutError("seqlock retry budget exceeded")


@dataclass
class ShmRegion:
    root: str
    specs: list[MapSpec]
    host: dict          # name -> {field: memmap}
    device: dict
    seq: np.memmap
    reqseq: np.memmap
    worker_id: str | None = None
    base: str = ""      # section base dir: root, or root/workers/<wid>
    crc: np.memmap | None = None   # device-section checksums (sorted names)

    @property
    def _order(self) -> list[str]:
        return sorted(s.name for s in self.specs)

    # ---------------------------------------------------------------- create
    @staticmethod
    def create(root: str, specs: list[MapSpec],
               worker_id: str | None = None,
               group: str | None = None) -> "ShmRegion":
        base = _worker_base(root, worker_id)
        os.makedirs(os.path.join(root, "progs"), exist_ok=True)
        os.makedirs(os.path.join(base, "control"), exist_ok=True)
        # meta.json is shared and created atomically + EXCLUSIVELY
        # (os.link fails on an existing target), so concurrently launching
        # workers race safely: exactly one spec set lands, every other
        # worker must agree with it
        meta_path = os.path.join(root, "meta.json")
        tmp = f"{meta_path}.{os.getpid()}.link.tmp"   # distinct from
        with open(tmp, "w") as f:                     # _atomic_json's tmp
            json.dump(_specs_to_meta(specs), f)
        try:
            os.link(tmp, meta_path)
        except FileExistsError:
            prior = read_meta_specs(root)
            # dataclass equality covers every field, flags included —
            # flags are load-bearing (step_lane drives the global ringbuf
            # interleave), so a silent mismatch would change merge
            # semantics
            if prior != list(specs):
                if worker_id is not None:
                    raise ValueError(
                        f"shm region {root} already holds incompatible "
                        f"specs")
                # single-process layout: one creator by construction, so a
                # re-run with evolved specs rebuilds the region (the seed
                # behavior) instead of demanding a manual delete; stale
                # section files go first — their shapes may not match
                _atomic_json(meta_path, _specs_to_meta(specs))
                for sub in ("host", "device"):
                    d = os.path.join(base, sub)
                    if os.path.isdir(d):
                        for fn in os.listdir(d):
                            if fn.endswith(".npy") and \
                                    not fn.startswith("."):
                                os.unlink(os.path.join(d, fn))
        finally:
            os.unlink(tmp)
        host = _create_section(os.path.join(base, "host"), specs)
        # the device section is (re-)zeroed UNDER its seqlock: on a worker
        # restart a live reader (the aggregator) must never observe a torn
        # mix, and the counter restarting at 0 is exactly the aggregator's
        # SeqRegression signal
        os.makedirs(os.path.join(base, "device"), exist_ok=True)
        seq_path = os.path.join(base, "device", ".seq.npy")
        if os.path.exists(seq_path):
            seq = _memmap(seq_path, None, "r+")
            if int(seq[0]) % 2 == 0:
                seq[0] += 1            # mark in-flight before zeroing
                seq.flush()
        else:
            seq = _memmap(seq_path, (1,), "w+")
            seq[0] = 1
            seq.flush()
        device = _create_section(os.path.join(base, "device"), specs)
        # checksums (re-)zeroed inside the same odd window; seq restarting
        # at 0 tells readers validation begins at the first publish
        crc = _crc_create(os.path.join(base, "device"), len(specs))
        seq[0] = 0
        seq.flush()
        # control-queue reset under the same flock _queue_request takes,
        # so a restart doesn't race a concurrent request writer
        import fcntl
        with open(os.path.join(base, "control", ".requests.lock"),
                  "w") as lk:
            fcntl.flock(lk, fcntl.LOCK_EX)
            reqseq_path = os.path.join(base, "control", ".reqseq.npy")
            reqseq = (_memmap(reqseq_path, None, "r+")
                      if os.path.exists(reqseq_path)
                      else _memmap(reqseq_path, (1,), "w+"))
            reqseq[0] = 0
            reqseq.flush()
            _atomic_json(os.path.join(base, "control", "requests.json"), [])
        if worker_id is not None:
            # liveness + restart detection for the aggregation engine.
            # pid_start (the kernel's process start tick) distinguishes THIS
            # process from a later one the OS handed the same pid — the
            # pid-reuse hazard in dead-worker harvest
            info = {"worker_id": str(worker_id), "pid": os.getpid(),
                    "pid_start": _pid_start(os.getpid()),
                    "boot": uuid.uuid4().hex,
                    "started_at": time.time()}
            if group is not None:
                # aggregation-group membership: the node aggregator named
                # `group` claims this worker (tree fold path)
                info["group"] = str(group)
            _atomic_json(os.path.join(base, "worker.json"), info)
            # registration contract for the list_workers cache: the
            # worker.json may land inside an ALREADY-existing subdir
            # (restart), which would not touch workers/ — bump it so
            # aggregators' cached listings see the newcomer
            os.utime(os.path.join(root, "workers"))
        return ShmRegion(root, specs, host, device, seq, reqseq,
                         worker_id=worker_id, base=base, crc=crc)

    # ---------------------------------------------------------------- attach
    @staticmethod
    def attach(root: str, mode: str = "r+",
               worker_id: str | None = None) -> "ShmRegion":
        specs = read_meta_specs(root)
        base = _worker_base(root, worker_id)
        host = _attach_section(os.path.join(base, "host"), specs, mode)
        device = _attach_section(os.path.join(base, "device"), specs, "r")
        seq = _memmap(os.path.join(base, "device", ".seq.npy"), None, "r+")
        reqseq = _memmap(os.path.join(base, "control", ".reqseq.npy"),
                         None, "r+")
        crc = _crc_attach(os.path.join(base, "device"), mode)
        return ShmRegion(root, specs, host, device, seq, reqseq,
                         worker_id=worker_id, base=base, crc=crc)

    # ---------------------------------------------------------------- publish
    def publish_device(self, states: dict) -> None:
        """Seqlocked snapshot of (host-fetched) device map states."""
        _seq_publish(self.seq, self.device, states,
                     crc=self.crc, order=self._order)

    def snapshot_device(self, name: str, retries: int = 100,
                        backoff_base: float = BACKOFF_BASE,
                        backoff_max: float = BACKOFF_MAX) -> dict:
        out, _, _ = self.snapshot_device_meta(
            name, retries=retries, backoff_base=backoff_base,
            backoff_max=backoff_max)
        return out

    def snapshot_device_meta(self, name: str, retries: int = 100,
                             backoff_base: float = BACKOFF_BASE,
                             backoff_max: float = BACKOFF_MAX,
                             ) -> tuple[dict, int, int]:
        """(state, seq_observed, retries_used) — the torn-read test surface:
        seq_observed is always even on a successful read."""
        return _seq_snapshot(
            self.seq, self.device, name, retries,
            backoff_base=backoff_base, backoff_max=backoff_max,
            crc=self.crc,
            crc_idx=self._order.index(name) if self.crc is not None
            else None)

    # ---------------------------------------------------------------- progs
    def publish_program(self, obj_json: str, name: str) -> None:
        with open(os.path.join(self.root, "progs", f"{name}.json"), "w") as f:
            f.write(obj_json)

    def read_programs(self) -> dict[str, str]:
        return read_programs(self.root)

    # ---------------------------------------------------------------- status
    def publish_status(self, status: dict) -> None:
        """trainer side: publish the control plane's state (live-table
        generation, active links) for daemons to poll."""
        _atomic_json(os.path.join(self.base, "control", "status.json"),
                     status)

    def read_status(self) -> dict:
        p = os.path.join(self.base, "control", "status.json")
        if not os.path.exists(p):
            return {}
        with open(p) as f:
            return json.load(f)

    # ---------------------------------------------------------------- control
    def request(self, req: dict) -> None:
        """daemon side: queue an attach/detach/load request."""
        _queue_request(self.base, req, reqseq=self.reqseq)

    def poll_requests(self, last_seen: int) -> tuple[list[dict], int]:
        """trainer side: fetch requests newer than last_seen."""
        cur = int(self.reqseq[0])
        if cur == last_seen:
            return [], last_seen
        p = os.path.join(self.base, "control", "requests.json")
        with open(p) as f:
            reqs = json.load(f)
        return reqs[last_seen:cur], cur


# --------------------------------------------------------------------------
# fleet helpers (worker discovery, liveness, request fan-out)
# --------------------------------------------------------------------------

def read_programs(root: str) -> dict[str, str]:
    """Program objects published to the shared progs/ section — layout-
    independent (works for both single-process and fleet trees)."""
    d = os.path.join(root, "progs")
    out = {}
    if not os.path.isdir(d):
        return out
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            with open(os.path.join(d, fn)) as f:
                out[fn[:-5]] = f.read()
    return out


# worker-listing cache keyed by the workers/ dir stat. Sound because every
# membership change bumps the dir mtime: subdir create/remove does so via
# the kernel, and late worker.json registration inside an existing subdir
# does so via the explicit os.utime in ShmRegion.create. Content changes
# to an existing worker.json don't alter the NAME list, so they need no
# invalidation here (worker_info has its own per-file stat key).
_workers_list_cache: dict[str, tuple] = {}


def list_workers(root: str) -> list[str]:
    d = os.path.join(root, "workers")
    try:
        st = os.stat(d)
    except OSError:
        return []
    key = (st.st_ino, st.st_mtime_ns)
    hit = _workers_list_cache.get(d)
    # 100ms settle window: dir mtimes tick on the kernel's COARSE clock,
    # so two registrations inside one tick can alias to the same
    # mtime_ns. A recently-modified dir is re-listed until it quiesces.
    if (hit is not None and hit[0] == key
            and time.time() * 1e9 - st.st_mtime_ns > 1e8):
        return list(hit[1])
    out = sorted(w for w in os.listdir(d)
                 if os.path.exists(os.path.join(d, w, "worker.json")))
    _workers_list_cache[d] = (key, out)
    return out


# registry-file parse cache keyed by (inode, mtime_ns, size): every writer
# goes through _atomic_json (tmp + rename -> fresh inode), so a key match
# is an exact content match. Hot aggregator loops re-validate each
# worker.json/node.json with one stat per read instead of re-parsing —
# a 32-worker tree otherwise parses every registry file several times per
# cycle (group scans + boot checks + liveness).
_registry_cache: dict[str, tuple] = {}


def _cached_registry_json(path: str) -> dict:
    st = os.stat(path)
    key = (st.st_ino, st.st_mtime_ns, st.st_size)
    hit = _registry_cache.get(path)
    if hit is not None and hit[0] == key:
        return hit[1]
    with open(path) as f:
        data = json.load(f)
    _registry_cache[path] = (key, data)
    return data


def worker_info(root: str, worker_id: str) -> dict:
    p = os.path.join(_worker_base(root, worker_id), "worker.json")
    # shallow copy: callers mutate the result (update_node_workers), the
    # cached parse must stay pristine
    return dict(_cached_registry_json(p))


def workers_in_group(root: str, group: str) -> list[str]:
    """Workers that registered with this aggregation group (the
    `--worker-group` a trainer joins with): a node aggregator claims its
    group's members dynamically, so workers may start after their node."""
    out = []
    for wid in list_workers(root):
        try:
            if worker_info(root, wid).get("group") == group:
                out.append(wid)
        except (OSError, ValueError):
            continue
    return out


def _pid_start(pid: int) -> str | None:
    """The kernel's start tick for `pid` (/proc/<pid>/stat field 22) — a
    (pid, start) pair names one process incarnation uniquely, so pid reuse
    after a worker's death is detectable. None where /proc is unreadable
    (worker_alive falls back to the plain existence check)."""
    try:
        with open(f"/proc/{pid}/stat", "rb") as f:
            stat = f.read().decode("latin-1")
        # comm may contain spaces/parens: fields resume after the LAST ')'
        rest = stat[stat.rindex(")") + 2:].split()
        return rest[19]          # field 22, 1-indexed
    except (OSError, ValueError, IndexError):
        return None


# pidfd liveness cache: (pid, registered_start) -> pidfd. A pidfd pins ONE
# process incarnation — the fd turns readable exactly when that process
# (and only that one: a recycled pid cannot alias an open fd) exits — so
# steady-state liveness is a zero-timeout poll instead of a per-cycle
# /proc/<pid>/stat parse. Falls back to the /proc path where pidfd_open
# is unavailable.
_pidfd_cache: dict[tuple, int] = {}
_PIDFD_OK = hasattr(os, "pidfd_open")


def _pid_incarnation_alive(pid: int, registered: str | None) -> bool:
    key = (pid, registered)
    fd = _pidfd_cache.get(key)
    if fd is not None:
        import select
        r, _, _ = select.select([fd], [], [], 0)
        if r:
            os.close(fd)
            del _pidfd_cache[key]
            return False
        return True
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:      # exists, owned by someone else
        pass
    if registered is not None:
        current = _pid_start(pid)
        if current is not None and current != registered:
            return False         # pid reused by a different process
    if _PIDFD_OK and registered is not None:
        if len(_pidfd_cache) > 512:   # restart-churn bound: drop and re-pin
            for old in _pidfd_cache.values():
                os.close(old)
            _pidfd_cache.clear()
        try:
            fd = os.pidfd_open(pid)
        except OSError:
            return True          # alive per the checks above; stay on /proc
        # the pid may have been recycled between the checks above and the
        # open: the fd pins SOME process with this pid, so re-verify the
        # incarnation before trusting it
        if _pid_start(pid) != registered:
            os.close(fd)
            return False
        _pidfd_cache[key] = fd
    return True


def worker_alive(root: str, worker_id: str) -> bool:
    """A worker is alive iff the pid it registered still exists AND (where
    /proc is readable) still names the same process incarnation: a recycled
    pid has a different start tick, so a dead worker whose pid the OS
    handed to an unrelated process is correctly reported dead. A stale
    seqlock additionally demotes a worker to 'stale' in the aggregator,
    see daemon.Aggregator."""
    try:
        info = worker_info(root, worker_id)
        pid = int(info["pid"])
    except (OSError, ValueError, KeyError):
        return False
    return _pid_incarnation_alive(pid, info.get("pid_start"))


def _queue_request(base: str, req: dict, reqseq=None) -> None:
    """Append one request to a control queue and bump its counter — the
    only files the request path touches (no map sections opened). The
    rewrite is atomic (workers poll requests.json every step: a truncate
    window would crash them on a half-written file) and the append is
    serialized with an flock so two concurrent requesters can't lose an
    entry while bumping reqseq twice."""
    import fcntl
    with open(os.path.join(base, "control", ".requests.lock"), "w") as lk:
        fcntl.flock(lk, fcntl.LOCK_EX)
        p = os.path.join(base, "control", "requests.json")
        with open(p) as f:
            reqs = json.load(f)
        reqs.append(req)
        _atomic_json(p, reqs)
        if reqseq is None:
            reqseq = _memmap(os.path.join(base, "control", ".reqseq.npy"),
                             None, "r+")
        reqseq[0] += 1
        reqseq.flush()


def fanout_request(root: str, req: dict,
                   worker_ids: list[str] | None = None) -> list[str]:
    """Queue one request into EVERY worker's control queue (live attach
    fan-out: the whole fleet picks the program up without recompiling).
    Returns the worker ids reached."""
    wids = list_workers(root) if worker_ids is None else list(worker_ids)
    for wid in wids:
        _queue_request(_worker_base(root, wid), req)
    return wids


# --------------------------------------------------------------------------
# tree aggregation: node registry + inter-level delta streams (DESIGN.md §15)
# --------------------------------------------------------------------------

def node_base(root: str, node_id: str) -> str:
    return os.path.join(root, "nodes", str(node_id))


def list_nodes(root: str) -> list[str]:
    d = os.path.join(root, "nodes")
    if not os.path.isdir(d):
        return []
    return sorted(n for n in os.listdir(d)
                  if os.path.exists(os.path.join(d, n, "node.json")))


def node_info(root: str, node_id: str) -> dict:
    return dict(_cached_registry_json(
        os.path.join(node_base(root, node_id), "node.json")))


def node_alive(root: str, node_id: str) -> bool:
    """Same liveness rules as worker_alive: registered pid must exist AND
    (where /proc is readable) still name the same incarnation (pid-reuse
    detection via the kernel start tick)."""
    try:
        info = node_info(root, node_id)
        pid = int(info["pid"])
    except (OSError, ValueError, KeyError):
        return False
    return _pid_incarnation_alive(pid, info.get("pid_start"))


def register_node(root: str, node_id: str, parent: str | None,
                  workers: list[str], children: list[str]) -> dict:
    """Write node.json: the tree-topology record (who this node folds, who
    consumes its stream) plus the liveness/restart identity (pid, pid_start,
    boot) that gives node aggregators the same failure rules as workers."""
    info = {"node_id": str(node_id), "parent": parent,
            "workers": sorted(workers), "children": sorted(children),
            "pid": os.getpid(), "pid_start": _pid_start(os.getpid()),
            "boot": uuid.uuid4().hex, "started_at": time.time()}
    base = node_base(root, node_id)
    os.makedirs(base, exist_ok=True)
    _atomic_json(os.path.join(base, "node.json"), info)
    return info


def update_node_workers(root: str, node_id: str,
                        workers: list[str]) -> dict:
    """Refresh a registered node's worker claim in place — same pid/boot
    incarnation, so the parent does NOT see a restart. Used when group
    membership grows (a worker joined its group after the node booted)."""
    info = node_info(root, node_id)
    info["workers"] = sorted(workers)
    _atomic_json(os.path.join(node_base(root, node_id), "node.json"), info)
    return info


def unregister_node(root: str, node_id: str) -> bool:
    """Tear a node out of the topology (CLI `node rm`): its workers go back
    to being polled directly by the parent. The stream directory stays on
    disk so unconsumed batches can still be harvested."""
    p = os.path.join(node_base(root, node_id), "node.json")
    try:
        os.unlink(p)
        return True
    except OSError:
        return False


def claimed_workers(root: str) -> set[str]:
    """Worker ids owned by SOME node aggregator — the set a parent level
    must not also fold directly (each worker has exactly one fold path up
    the tree)."""
    out: set[str] = set()
    for nid in list_nodes(root):
        try:
            out.update(node_info(root, nid).get("workers", []))
        except (OSError, ValueError):
            continue
    return out


class StreamCorruption(Exception):
    """A delta-stream batch file read back with a checksum mismatch: the
    bytes were damaged after the atomic commit. Detect-and-skip with drop
    accounting, never silent-fold (same contract as SnapshotCorruption)."""


class DeltaStream:
    """Incremental delta channel between tree levels: a node aggregator
    emits sequence-numbered batch files (atomic tmp+rename commit, CRC32
    over the payload), its parent consumes every seq exactly once and acks,
    the writer garbage-collects acked batches. The stream doubles as the
    node's write-ahead log: a restarted node replays its own committed
    batches past the journal to rebuild the emit baseline, so deltas are
    never double-emitted (and journal lag costs only re-extraction).

        <root>/nodes/<nid>/stream/delta_<seq>.dsb   committed batches
        <root>/nodes/<nid>/stream/.head.npy         last committed seq
        <root>/nodes/<nid>/stream/.ack.npy          last seq the parent
                                                    has folded AND journaled
    """

    def __init__(self, root: str, node_id: str, head: np.memmap,
                 ack: np.memmap):
        self.root = root
        self.node_id = node_id
        self._head = head
        self._ack = ack

    @staticmethod
    def _dir(root: str, node_id: str) -> str:
        return os.path.join(node_base(root, node_id), "stream")

    @staticmethod
    def _batch_path(root: str, node_id: str, seq: int) -> str:
        return os.path.join(DeltaStream._dir(root, node_id),
                            f"delta_{seq:010d}.dsb")

    @staticmethod
    def create(root: str, node_id: str) -> "DeltaStream":
        """Writer side. Head/ack PERSIST across node restarts — the stream
        outlives any one incarnation (it is the level's crash-recovery
        ledger), unlike a worker's device section which resets with it."""
        d = DeltaStream._dir(root, node_id)
        os.makedirs(d, exist_ok=True)
        hp = os.path.join(d, ".head.npy")
        head = _memmap(hp, None, "r+") if os.path.exists(hp) \
            else _memmap(hp, (1,), "w+")
        ap = os.path.join(d, ".ack.npy")
        ack = _memmap(ap, None, "r+") if os.path.exists(ap) \
            else _memmap(ap, (1,), "w+")
        return DeltaStream(root, node_id, head, ack)

    @staticmethod
    def attach(root: str, node_id: str) -> "DeltaStream":
        """Consumer side (needs write access to .ack.npy only)."""
        d = DeltaStream._dir(root, node_id)
        head = _memmap(os.path.join(d, ".head.npy"), None, "r")
        ack = _memmap(os.path.join(d, ".ack.npy"), None, "r+")
        return DeltaStream(root, node_id, head, ack)

    @staticmethod
    def exists(root: str, node_id: str) -> bool:
        return os.path.exists(os.path.join(
            DeltaStream._dir(root, node_id), ".head.npy"))

    # ------------------------------------------------------------ serialize
    _MAGIC = b"DSB1"

    @staticmethod
    def _serialize(batch: dict) -> bytes:
        """Flat length-prefixed container: magic | header json (array
        names/dtypes/shapes + blob length + CRC) | json blob | packed raw
        array bytes. The CRC spans the blob and every array, so a scribble
        anywhere is detect-and-skip, same as the old npz container — but
        without the per-array zipfile bookkeeping that dominated the
        root's poll at fleet scale."""
        arrays = {k: np.ascontiguousarray(np.asarray(v))
                  for k, v in batch.get("arrays", {}).items()}
        blob = json.dumps(batch.get("json", {}),
                          sort_keys=True).encode("utf-8")
        crc = zlib.crc32(blob)
        meta, parts = [], []
        for k in sorted(arrays):
            a = arrays[k]
            raw = a.tobytes()
            crc = zlib.crc32(raw, crc)
            meta.append({"n": k, "d": a.dtype.str, "s": list(a.shape)})
            parts.append(raw)
        head = json.dumps({"a": meta, "j": len(blob), "c": crc},
                          sort_keys=True).encode("utf-8")
        return b"".join([DeltaStream._MAGIC,
                         len(head).to_bytes(4, "little"), head, blob,
                         *parts])

    @staticmethod
    def _deserialize(data: bytes) -> dict:
        try:
            if data[:4] != DeltaStream._MAGIC:
                raise ValueError("bad magic")
            hl = int.from_bytes(data[4:8], "little")
            head = json.loads(data[8:8 + hl].decode("utf-8"))
            off = 8 + hl
            blob = bytes(data[off:off + int(head["j"])])
            if len(blob) != int(head["j"]):
                raise ValueError("truncated json blob")
            off += len(blob)
            crc = zlib.crc32(blob)
            mv = memoryview(data)
            arrays = {}
            for m in head["a"]:
                dt = np.dtype(m["d"])
                nb = dt.itemsize * int(np.prod(m["s"], dtype=np.int64))
                raw = mv[off:off + nb]
                if len(raw) != nb:
                    raise ValueError("truncated array bytes")
                crc = zlib.crc32(raw, crc)
                arrays[m["n"]] = np.frombuffer(raw, dt).reshape(
                    m["s"]).copy()
                off += nb
            if crc != int(head["c"]):
                raise StreamCorruption("delta batch checksum mismatch")
            return {"json": json.loads(blob.decode("utf-8")),
                    "arrays": arrays}
        except StreamCorruption:
            raise
        except Exception as exc:   # scribbled header / layout
            raise StreamCorruption(f"delta batch unreadable: {exc}") from exc

    # ------------------------------------------------------------ writer
    def head(self) -> int:
        return int(self._head[0])

    def acked(self) -> int:
        return int(self._ack[0])

    def emit(self, seq: int, batch: dict) -> str:
        """Atomically commit batch `seq` (must be head+1) and advance the
        head. A crash between the rename and the head bump is healed by the
        consumer (it probes head+1 on disk) and by the writer's next
        restart."""
        path = self._batch_path(self.root, self.node_id, seq)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "wb") as f:
            f.write(self._serialize(batch))
        os.replace(tmp, path)
        # no msync: same-host readers see the bump via the page cache; a
        # machine-crash-lost bump is healed by the consumer probing one
        # past the head (below) and by the writer's restart re-emit
        self._head[0] = seq
        return path

    def gc(self, limit: int | None = None) -> int:
        """Remove batches the consumer has folded AND journaled. Anything
        newer stays: a crashed parent re-reads them idempotently. The
        writer passes its OWN journaled emit seq as `limit` — batches past
        the writer's journal are its recovery WAL and must survive even
        after the consumer acks them."""
        bound = self.acked() if limit is None else min(self.acked(), limit)
        n = 0
        for seq in range(bound, 0, -1):
            p = self._batch_path(self.root, self.node_id, seq)
            if not os.path.exists(p):
                break
            os.unlink(p)
            n += 1
        return n

    # ------------------------------------------------------------ consumer
    def poll(self, last_seen: int) -> list[tuple[int, dict | None]]:
        """Batches with seq > last_seen in order. A committed-but-unbumped
        head (writer died mid-emit) is healed by probing one past the head.
        Corrupt or vanished batches yield (seq, None): the consumer counts
        them as stream_lost — detect-and-skip, never silent."""
        out = []
        hi = self.head()
        seq = last_seen + 1
        while True:
            p = self._batch_path(self.root, self.node_id, seq)
            if not os.path.exists(p):
                if seq <= hi:
                    out.append((seq, None))   # gc'd past us / vanished
                    seq += 1
                    continue
                break
            try:
                with open(p, "rb") as f:
                    out.append((seq, self._deserialize(f.read())))
            except (StreamCorruption, OSError, ValueError):
                out.append((seq, None))
            seq += 1
        return out

    def ack(self, seq: int) -> None:
        # no msync: an ack lost to a machine crash only makes the parent
        # re-read batches it already folded — idempotent by design
        if seq > self.acked():
            self._ack[0] = seq


# --------------------------------------------------------------------------
# sharded global hash views (keyspace partition over the home-slot hash)
# --------------------------------------------------------------------------

class HashShards:
    """The global HASH maps republished as independently seqlocked shards:
    shard s of map m holds exactly the keys whose home slot
    (maps._np_hash_idx — the probe start every lookup already uses) is
    congruent to s mod n_shards. Every key lands in exactly one shard, each
    shard has its own seqlock + CRC (same torn-read/corruption contract as
    any section), and the aggregator republishes ONLY dirty shards — a
    reader polling one shard never retries against writes to the others.

        <root>/global/shards/meta.json              {n_shards, maps}
        <root>/global/shards/<map>/<s>/*.npy        canonicalized subtable
        <root>/global/shards/<map>/<s>/.seq.npy     per-shard seqlock
        <root>/global/shards/<map>/<s>/.crc.npy     per-shard checksum
    """

    def __init__(self, root: str, specs: list[MapSpec], n_shards: int,
                 shards: dict):
        self.root = root
        self.specs = specs
        self.n_shards = n_shards
        self._shards = shards     # (name, s) -> (section, seq, crc)

    @staticmethod
    def _dir(root: str) -> str:
        return os.path.join(root, "global", "shards")

    @staticmethod
    def _hash_specs(specs: list[MapSpec]) -> list[MapSpec]:
        return [s for s in specs if s.kind == MapKind.HASH]

    @staticmethod
    def exists(root: str) -> bool:
        return os.path.exists(os.path.join(HashShards._dir(root),
                                           "meta.json"))

    @staticmethod
    def read_meta(root: str) -> dict:
        with open(os.path.join(HashShards._dir(root), "meta.json")) as f:
            return json.load(f)

    @staticmethod
    def _open(root: str, spec: MapSpec, s: int, create: bool):
        d = os.path.join(HashShards._dir(root), spec.name, str(s))
        seq_path = os.path.join(d, ".seq.npy")
        if create:
            # same restart discipline as GlobalView.create: reset under the
            # seqlock so a reader's live mmaps never observe a torn mix
            if os.path.exists(seq_path):
                section = _attach_section(d, [spec], "r+")
                seq = _memmap(seq_path, None, "r+")
                if int(seq[0]) % 2 == 0:
                    seq[0] += 1
                    seq.flush()
                for arr in section[spec.name].values():
                    arr[...] = 0
                crc = _crc_create(d, 1)
                crc[0] = _crc_of(section[spec.name])
                crc.flush()
                seq[0] += 1
                seq.flush()
            else:
                section = _create_section(d, [spec])
                crc = _crc_create(d, 1)
                seq = _memmap(seq_path, (1,), "w+")
                seq[0] = 0
        else:
            section = _attach_section(d, [spec], "r")
            seq = _memmap(seq_path, None, "r")
            crc = _crc_attach(d, "r")
        return section, seq, crc

    @staticmethod
    def create(root: str, specs: list[MapSpec],
               n_shards: int) -> "HashShards":
        hs = HashShards._hash_specs(specs)
        os.makedirs(HashShards._dir(root), exist_ok=True)
        shards = {}
        for spec in hs:
            for s in range(n_shards):
                shards[(spec.name, s)] = HashShards._open(
                    root, spec, s, create=True)
        _atomic_json(os.path.join(HashShards._dir(root), "meta.json"),
                     {"n_shards": n_shards, "maps": [s.name for s in hs],
                      "version": 1})
        return HashShards(root, specs, n_shards, shards)

    @staticmethod
    def attach(root: str) -> "HashShards":
        meta = HashShards.read_meta(root)
        specs = read_meta_specs(root)
        spec_of = {s.name: s for s in specs}
        shards = {}
        for name in meta["maps"]:
            for s in range(meta["n_shards"]):
                shards[(name, s)] = HashShards._open(
                    root, spec_of[name], s, create=False)
        return HashShards(root, specs, meta["n_shards"], shards)

    def publish(self, name: str, s: int, state: dict) -> None:
        section, seq, crc = self._shards[(name, s)]
        _seq_publish(seq, section, {name: state}, crc=crc, order=[name],
                     role="global")

    def snapshot(self, name: str, s: int, retries: int = 100
                 ) -> tuple[dict, int, int]:
        """(state, seq_observed, retries_used) — the per-shard torn-read
        test surface; seq_observed is always even on success."""
        section, seq, crc = self._shards[(name, s)]
        return _seq_snapshot(seq, section, name, retries, crc=crc,
                             crc_idx=0 if crc is not None else None)


# --------------------------------------------------------------------------
# global (daemon-merged) view
# --------------------------------------------------------------------------

@dataclass
class GlobalView:
    """The aggregation engine's output: one seqlocked section holding the
    merged state of every worker's maps, readable by any observer exactly
    like a per-worker device section."""
    root: str
    specs: list[MapSpec]
    section: dict
    seq: np.memmap
    crc: np.memmap | None = None

    @property
    def _order(self) -> list[str]:
        return sorted(s.name for s in self.specs)

    @staticmethod
    def _dir(root: str) -> str:
        return os.path.join(root, "global")

    @staticmethod
    def create(root: str, specs: list[MapSpec] | None = None) -> "GlobalView":
        specs = read_meta_specs(root) if specs is None else specs
        d = GlobalView._dir(root)
        seq_path = os.path.join(d, ".seq.npy")
        order = sorted(s.name for s in specs)
        if os.path.exists(seq_path):
            # an aggregator restart over a published section: readers may
            # hold these very mmaps, so the reset must happen UNDER the
            # seqlock — never truncate/zero the files in place
            section = _attach_section(d, specs, "r+")
            seq = _memmap(seq_path, None, "r+")
            if int(seq[0]) % 2 == 0:       # else: prior writer died odd —
                seq[0] += 1                # stay in its in-flight cycle
                seq.flush()
            for name in section:
                for arr in section[name].values():
                    arr[...] = 0
            # seq continues > 0, so readers WILL validate: the checksums
            # must match the zeroed payload, still inside the odd window
            crc = _crc_create(d, len(specs))
            for i, name in enumerate(order):
                crc[i] = _crc_of(section[name])
            crc.flush()
            seq[0] += 1                    # even: consistent zero state
            seq.flush()
            return GlobalView(root, specs, section, seq, crc=crc)
        section = _create_section(d, specs)
        crc = _crc_create(d, len(specs))
        seq = _memmap(seq_path, (1,), "w+")
        seq[0] = 0
        return GlobalView(root, specs, section, seq, crc=crc)

    @staticmethod
    def attach(root: str, mode: str = "r") -> "GlobalView":
        specs = read_meta_specs(root)
        d = GlobalView._dir(root)
        section = _attach_section(d, specs, mode)
        seq = _memmap(os.path.join(d, ".seq.npy"), None,
                      "r+" if mode != "r" else "r")
        return GlobalView(root, specs, section, seq,
                          crc=_crc_attach(d, mode))

    @staticmethod
    def exists(root: str) -> bool:
        return os.path.exists(os.path.join(GlobalView._dir(root),
                                           ".seq.npy"))

    def publish(self, states: dict) -> None:
        _seq_publish(self.seq, self.section, states,
                     crc=self.crc, order=self._order, role="global")

    def snapshot(self, name: str, retries: int = 100) -> dict:
        out, _, _ = _seq_snapshot(
            self.seq, self.section, name, retries, crc=self.crc,
            crc_idx=self._order.index(name) if self.crc is not None
            else None)
        return out

    def publish_status(self, status: dict) -> None:
        _atomic_json(os.path.join(self._dir(self.root), "status.json"),
                     status)

    def read_status(self) -> dict:
        p = os.path.join(self._dir(self.root), "status.json")
        if not os.path.exists(p):
            return {}
        with open(p) as f:
            return json.load(f)
