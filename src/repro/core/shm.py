"""mmap-backed shared-memory control plane — bpftime's shm maps + daemon
handshake, adapted to the host side of a TPU trainer fleet.

Layout under a shm directory (SP3 segregation: program text, device-map
snapshots, and host-map data live in separate sections; the agent may write
only map-data sections — enforced here by API shape, in production by file
permissions, see DESIGN.md §5):

    <dir>/meta.json                 map specs + layout (written once, shared)
    <dir>/progs/<name>.json         program objects (read-only to agents)

Single-process layout (worker_id=None — the seed shape, unchanged):

    <dir>/host/<map>.<field>.npy    live host-side maps (memmapped, rw)
    <dir>/device/<map>.<field>.npy  per-step snapshots of device maps
    <dir>/device/.seq.npy           seqlock (odd while a publish is in flight)
    <dir>/control/requests.json     daemon -> trainer attach/detach requests
    <dir>/control/.reqseq.npy       request counter
    <dir>/control/status.json       trainer -> daemon control-plane status

Fleet layout (worker_id="w0", "w1", ... — DESIGN.md §10): every worker owns
the SAME section tree under its own base, so one daemon can observe N
train/serve processes as one system:

    <dir>/workers/<wid>/worker.json  pid + boot id (liveness / restart detect)
    <dir>/workers/<wid>/{host,device,control}/...   as above, per worker
    <dir>/global/<map>.<field>.npy   daemon-merged view of the whole fleet
    <dir>/global/.seq.npy            seqlock for the merged view
    <dir>/global/status.json         aggregation status (alive/dead workers,
                                     per-worker heads, merge stats)
"""
from __future__ import annotations

import json
import os
import time
import uuid
import zlib
from dataclasses import dataclass

import numpy as np

from . import faults, maps as M
from .maps import MapKind, MapSpec


class SnapshotCorruption(Exception):
    """A seqlocked section read consistently (even, stable seq) but its
    payload does not match the checksum the publisher wrote: the bytes were
    damaged AFTER the publish. Detect-and-skip, never silent-merge."""


def _memmap(path, shape, mode):
    if mode == "w+":
        return np.lib.format.open_memmap(path, mode="w+", dtype=np.int64,
                                         shape=shape)
    return np.lib.format.open_memmap(path, mode=mode)


def _atomic_json(path: str, obj) -> None:
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)          # atomic for concurrent readers/writers


def _specs_to_meta(specs: list[MapSpec]) -> dict:
    return {"specs": [{"name": s.name, "kind": s.kind.value,
                       "max_entries": s.max_entries,
                       "rec_width": s.rec_width,
                       "num_shards": s.num_shards,
                       "flags": s.flags} for s in specs],
            "version": 2}


def _specs_from_meta(meta: dict) -> list[MapSpec]:
    return [MapSpec(name=m["name"], kind=MapKind(m["kind"]),
                    max_entries=m["max_entries"],
                    rec_width=m["rec_width"],
                    num_shards=m["num_shards"],
                    flags=m.get("flags", {})) for m in meta["specs"]]


def read_meta_specs(root: str) -> list[MapSpec]:
    with open(os.path.join(root, "meta.json")) as f:
        return _specs_from_meta(json.load(f))


def _worker_base(root: str, worker_id: str | None) -> str:
    if worker_id is None:
        return root
    return os.path.join(root, "workers", str(worker_id))


# --------------------------------------------------------------------------
# seqlocked field sections (shared by per-worker device dirs and global/)
# --------------------------------------------------------------------------

def _create_section(dirpath: str, specs: list[MapSpec]) -> dict:
    """Create (or re-create, on worker restart) a section's field files.
    Existing files are reused IN PLACE ('r+', zeroed) rather than
    truncated: a live reader's mmap of the same inode keeps working and
    simply observes the zeroed state — open_memmap('w+') would shrink the
    inode to 0 bytes for a moment, turning a concurrent read into SIGBUS."""
    os.makedirs(dirpath, exist_ok=True)
    out = {}
    for s in specs:
        tmpl = M.init_state(s, np)
        out[s.name] = {}
        for field, arr in tmpl.items():
            path = os.path.join(dirpath, f"{s.name}.{field}.npy")
            if os.path.exists(path):
                mm = _memmap(path, None, "r+")
            else:
                mm = _memmap(path, arr.shape, "w+")
            mm[...] = 0
            out[s.name][field] = mm
    return out


def _attach_section(dirpath: str, specs: list[MapSpec], mode: str) -> dict:
    out = {}
    for s in specs:
        out[s.name] = {}
        for field in M.init_state(s, np):
            out[s.name][field] = _memmap(
                os.path.join(dirpath, f"{s.name}.{field}.npy"), None, mode)
    return out


def _crc_of(state: dict) -> int:
    """CRC32 over a map state's field bytes, fields in sorted order — the
    per-section corruption check written under the seqlock."""
    c = 0
    for f in sorted(state):
        c = zlib.crc32(np.ascontiguousarray(state[f]).tobytes(), c)
    return c


def _crc_path(dirpath: str) -> str:
    return os.path.join(dirpath, ".crc.npy")


def _crc_create(dirpath: str, n: int) -> np.memmap:
    p = _crc_path(dirpath)
    crc = _memmap(p, None, "r+") if os.path.exists(p) \
        else _memmap(p, (n,), "w+")
    crc[...] = 0
    crc.flush()
    return crc


def _crc_attach(dirpath: str, mode: str) -> np.memmap | None:
    p = _crc_path(dirpath)
    if not os.path.exists(p):
        return None              # pre-checksum region: no validation
    return _memmap(p, None, "r+" if mode != "r" else "r")


# Seqlock backoff defaults (satellite: configurable via AggregatorConfig).
# First retry sleeps BACKOFF_BASE, doubling up to BACKOFF_MAX per attempt:
# the common one-publish-in-flight case resolves in ~50us instead of the
# old fixed 1ms, while a genuinely stuck writer still costs at most
# retries * BACKOFF_MAX before TimeoutError.
BACKOFF_BASE = 5e-5
BACKOFF_MAX = 0.01


def _seq_publish(seq: np.memmap, section: dict, states: dict,
                 crc: np.memmap | None = None,
                 order: list[str] | None = None,
                 role: str = "worker") -> None:
    # parity self-heal: an odd seq here means a prior publisher died (or
    # injected-crashed) mid-publish — we are already "in flight", so don't
    # flip again; completing this publish returns the section to even with
    # fully consistent contents
    if int(seq[0]) % 2 == 0:
        seq[0] += 1          # odd: write in flight
    seq.flush()
    # role tags who is publishing: worker-side fault classes (torn/stuck/
    # corrupt/kill/slow) only target "worker" publishes — daemon failures
    # are modeled by the agg:* crash schedule, not by tearing the global
    # view's own seqlocked publish
    faults.fire("shm:publish_begin", role=role)
    for name, st in states.items():
        if name not in section:
            continue
        for field, arr in st.items():
            faults.fire("shm:publish_field", map=name, field=field,
                        role=role)
            section[name][field][...] = np.asarray(arr)
    if crc is not None:
        # recomputed from SECTION content (not `states`): maps skipped
        # this publish keep a checksum matching what is actually on disk
        for i, name in enumerate(order):
            crc[i] = _crc_of(section[name])
        crc.flush()
    faults.fire("shm:publish_commit", section=section, role=role)
    seq[0] += 1          # even: consistent
    seq.flush()


def _seq_snapshot(seq: np.memmap, section: dict, name: str, retries: int,
                  backoff_base: float = BACKOFF_BASE,
                  backoff_max: float = BACKOFF_MAX,
                  crc: np.memmap | None = None,
                  crc_idx: int | None = None) -> tuple[dict, int, int]:
    """Returns (state, seq_observed, retries_used). A successful read always
    observes an EVEN sequence number, unchanged across the copy, and (when
    the section carries checksums) a payload matching the publisher's CRC.
    Retries back off exponentially from backoff_base to backoff_max."""
    faults.fire("shm:snapshot_begin", name=name)
    delay = backoff_base
    for attempt in range(retries):
        s0 = int(seq[0])
        if s0 % 2 == 0:
            out = {f: np.array(a) for f, a in section[name].items()}
            want = int(crc[crc_idx]) if crc is not None else None
            if int(seq[0]) == s0:
                # seq 0 = never published: the zeroed crc array is not the
                # crc of the zeroed section, so validation starts at the
                # first real publish
                if want is not None and s0 > 0 and _crc_of(out) != want:
                    raise SnapshotCorruption(
                        f"{name}: checksum mismatch at seq {s0}")
                return out, s0, attempt
        time.sleep(delay)
        delay = min(delay * 2, backoff_max)
    raise TimeoutError("seqlock retry budget exceeded")


@dataclass
class ShmRegion:
    root: str
    specs: list[MapSpec]
    host: dict          # name -> {field: memmap}
    device: dict
    seq: np.memmap
    reqseq: np.memmap
    worker_id: str | None = None
    base: str = ""      # section base dir: root, or root/workers/<wid>
    crc: np.memmap | None = None   # device-section checksums (sorted names)

    @property
    def _order(self) -> list[str]:
        return sorted(s.name for s in self.specs)

    # ---------------------------------------------------------------- create
    @staticmethod
    def create(root: str, specs: list[MapSpec],
               worker_id: str | None = None) -> "ShmRegion":
        base = _worker_base(root, worker_id)
        os.makedirs(os.path.join(root, "progs"), exist_ok=True)
        os.makedirs(os.path.join(base, "control"), exist_ok=True)
        # meta.json is shared and created atomically + EXCLUSIVELY
        # (os.link fails on an existing target), so concurrently launching
        # workers race safely: exactly one spec set lands, every other
        # worker must agree with it
        meta_path = os.path.join(root, "meta.json")
        tmp = f"{meta_path}.{os.getpid()}.link.tmp"   # distinct from
        with open(tmp, "w") as f:                     # _atomic_json's tmp
            json.dump(_specs_to_meta(specs), f)
        try:
            os.link(tmp, meta_path)
        except FileExistsError:
            prior = read_meta_specs(root)
            # dataclass equality covers every field, flags included —
            # flags are load-bearing (step_lane drives the global ringbuf
            # interleave), so a silent mismatch would change merge
            # semantics
            if prior != list(specs):
                if worker_id is not None:
                    raise ValueError(
                        f"shm region {root} already holds incompatible "
                        f"specs")
                # single-process layout: one creator by construction, so a
                # re-run with evolved specs rebuilds the region (the seed
                # behavior) instead of demanding a manual delete; stale
                # section files go first — their shapes may not match
                _atomic_json(meta_path, _specs_to_meta(specs))
                for sub in ("host", "device"):
                    d = os.path.join(base, sub)
                    if os.path.isdir(d):
                        for fn in os.listdir(d):
                            if fn.endswith(".npy") and \
                                    not fn.startswith("."):
                                os.unlink(os.path.join(d, fn))
        finally:
            os.unlink(tmp)
        host = _create_section(os.path.join(base, "host"), specs)
        # the device section is (re-)zeroed UNDER its seqlock: on a worker
        # restart a live reader (the aggregator) must never observe a torn
        # mix, and the counter restarting at 0 is exactly the aggregator's
        # SeqRegression signal
        os.makedirs(os.path.join(base, "device"), exist_ok=True)
        seq_path = os.path.join(base, "device", ".seq.npy")
        if os.path.exists(seq_path):
            seq = _memmap(seq_path, None, "r+")
            if int(seq[0]) % 2 == 0:
                seq[0] += 1            # mark in-flight before zeroing
                seq.flush()
        else:
            seq = _memmap(seq_path, (1,), "w+")
            seq[0] = 1
            seq.flush()
        device = _create_section(os.path.join(base, "device"), specs)
        # checksums (re-)zeroed inside the same odd window; seq restarting
        # at 0 tells readers validation begins at the first publish
        crc = _crc_create(os.path.join(base, "device"), len(specs))
        seq[0] = 0
        seq.flush()
        # control-queue reset under the same flock _queue_request takes,
        # so a restart doesn't race a concurrent request writer
        import fcntl
        with open(os.path.join(base, "control", ".requests.lock"),
                  "w") as lk:
            fcntl.flock(lk, fcntl.LOCK_EX)
            reqseq_path = os.path.join(base, "control", ".reqseq.npy")
            reqseq = (_memmap(reqseq_path, None, "r+")
                      if os.path.exists(reqseq_path)
                      else _memmap(reqseq_path, (1,), "w+"))
            reqseq[0] = 0
            reqseq.flush()
            _atomic_json(os.path.join(base, "control", "requests.json"), [])
        if worker_id is not None:
            # liveness + restart detection for the aggregation engine.
            # pid_start (the kernel's process start tick) distinguishes THIS
            # process from a later one the OS handed the same pid — the
            # pid-reuse hazard in dead-worker harvest
            _atomic_json(os.path.join(base, "worker.json"),
                         {"worker_id": str(worker_id), "pid": os.getpid(),
                          "pid_start": _pid_start(os.getpid()),
                          "boot": uuid.uuid4().hex,
                          "started_at": time.time()})
        return ShmRegion(root, specs, host, device, seq, reqseq,
                         worker_id=worker_id, base=base, crc=crc)

    # ---------------------------------------------------------------- attach
    @staticmethod
    def attach(root: str, mode: str = "r+",
               worker_id: str | None = None) -> "ShmRegion":
        specs = read_meta_specs(root)
        base = _worker_base(root, worker_id)
        host = _attach_section(os.path.join(base, "host"), specs, mode)
        device = _attach_section(os.path.join(base, "device"), specs, "r")
        seq = _memmap(os.path.join(base, "device", ".seq.npy"), None, "r+")
        reqseq = _memmap(os.path.join(base, "control", ".reqseq.npy"),
                         None, "r+")
        crc = _crc_attach(os.path.join(base, "device"), mode)
        return ShmRegion(root, specs, host, device, seq, reqseq,
                         worker_id=worker_id, base=base, crc=crc)

    # ---------------------------------------------------------------- publish
    def publish_device(self, states: dict) -> None:
        """Seqlocked snapshot of (host-fetched) device map states."""
        _seq_publish(self.seq, self.device, states,
                     crc=self.crc, order=self._order)

    def snapshot_device(self, name: str, retries: int = 100,
                        backoff_base: float = BACKOFF_BASE,
                        backoff_max: float = BACKOFF_MAX) -> dict:
        out, _, _ = self.snapshot_device_meta(
            name, retries=retries, backoff_base=backoff_base,
            backoff_max=backoff_max)
        return out

    def snapshot_device_meta(self, name: str, retries: int = 100,
                             backoff_base: float = BACKOFF_BASE,
                             backoff_max: float = BACKOFF_MAX,
                             ) -> tuple[dict, int, int]:
        """(state, seq_observed, retries_used) — the torn-read test surface:
        seq_observed is always even on a successful read."""
        return _seq_snapshot(
            self.seq, self.device, name, retries,
            backoff_base=backoff_base, backoff_max=backoff_max,
            crc=self.crc,
            crc_idx=self._order.index(name) if self.crc is not None
            else None)

    # ---------------------------------------------------------------- progs
    def publish_program(self, obj_json: str, name: str) -> None:
        with open(os.path.join(self.root, "progs", f"{name}.json"), "w") as f:
            f.write(obj_json)

    def read_programs(self) -> dict[str, str]:
        return read_programs(self.root)

    # ---------------------------------------------------------------- status
    def publish_status(self, status: dict) -> None:
        """trainer side: publish the control plane's state (live-table
        generation, active links) for daemons to poll."""
        _atomic_json(os.path.join(self.base, "control", "status.json"),
                     status)

    def read_status(self) -> dict:
        p = os.path.join(self.base, "control", "status.json")
        if not os.path.exists(p):
            return {}
        with open(p) as f:
            return json.load(f)

    # ---------------------------------------------------------------- control
    def request(self, req: dict) -> None:
        """daemon side: queue an attach/detach/load request."""
        _queue_request(self.base, req, reqseq=self.reqseq)

    def poll_requests(self, last_seen: int) -> tuple[list[dict], int]:
        """trainer side: fetch requests newer than last_seen."""
        cur = int(self.reqseq[0])
        if cur == last_seen:
            return [], last_seen
        p = os.path.join(self.base, "control", "requests.json")
        with open(p) as f:
            reqs = json.load(f)
        return reqs[last_seen:cur], cur


# --------------------------------------------------------------------------
# fleet helpers (worker discovery, liveness, request fan-out)
# --------------------------------------------------------------------------

def read_programs(root: str) -> dict[str, str]:
    """Program objects published to the shared progs/ section — layout-
    independent (works for both single-process and fleet trees)."""
    d = os.path.join(root, "progs")
    out = {}
    if not os.path.isdir(d):
        return out
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            with open(os.path.join(d, fn)) as f:
                out[fn[:-5]] = f.read()
    return out


def list_workers(root: str) -> list[str]:
    d = os.path.join(root, "workers")
    if not os.path.isdir(d):
        return []
    return sorted(w for w in os.listdir(d)
                  if os.path.exists(os.path.join(d, w, "worker.json")))


def worker_info(root: str, worker_id: str) -> dict:
    p = os.path.join(_worker_base(root, worker_id), "worker.json")
    with open(p) as f:
        return json.load(f)


def _pid_start(pid: int) -> str | None:
    """The kernel's start tick for `pid` (/proc/<pid>/stat field 22) — a
    (pid, start) pair names one process incarnation uniquely, so pid reuse
    after a worker's death is detectable. None where /proc is unreadable
    (worker_alive falls back to the plain existence check)."""
    try:
        with open(f"/proc/{pid}/stat", "rb") as f:
            stat = f.read().decode("latin-1")
        # comm may contain spaces/parens: fields resume after the LAST ')'
        rest = stat[stat.rindex(")") + 2:].split()
        return rest[19]          # field 22, 1-indexed
    except (OSError, ValueError, IndexError):
        return None


def worker_alive(root: str, worker_id: str) -> bool:
    """A worker is alive iff the pid it registered still exists AND (where
    /proc is readable) still names the same process incarnation: a recycled
    pid has a different start tick, so a dead worker whose pid the OS
    handed to an unrelated process is correctly reported dead. A stale
    seqlock additionally demotes a worker to 'stale' in the aggregator,
    see daemon.Aggregator."""
    try:
        info = worker_info(root, worker_id)
        pid = int(info["pid"])
    except (OSError, ValueError, KeyError):
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:      # exists, owned by someone else
        pass
    registered = info.get("pid_start")
    if registered is not None:
        current = _pid_start(pid)
        if current is not None and current != registered:
            return False         # pid reused by a different process
    return True


def _queue_request(base: str, req: dict, reqseq=None) -> None:
    """Append one request to a control queue and bump its counter — the
    only files the request path touches (no map sections opened). The
    rewrite is atomic (workers poll requests.json every step: a truncate
    window would crash them on a half-written file) and the append is
    serialized with an flock so two concurrent requesters can't lose an
    entry while bumping reqseq twice."""
    import fcntl
    with open(os.path.join(base, "control", ".requests.lock"), "w") as lk:
        fcntl.flock(lk, fcntl.LOCK_EX)
        p = os.path.join(base, "control", "requests.json")
        with open(p) as f:
            reqs = json.load(f)
        reqs.append(req)
        _atomic_json(p, reqs)
        if reqseq is None:
            reqseq = _memmap(os.path.join(base, "control", ".reqseq.npy"),
                             None, "r+")
        reqseq[0] += 1
        reqseq.flush()


def fanout_request(root: str, req: dict,
                   worker_ids: list[str] | None = None) -> list[str]:
    """Queue one request into EVERY worker's control queue (live attach
    fan-out: the whole fleet picks the program up without recompiling).
    Returns the worker ids reached."""
    wids = list_workers(root) if worker_ids is None else list(worker_ids)
    for wid in wids:
        _queue_request(_worker_base(root, wid), req)
    return wids


# --------------------------------------------------------------------------
# global (daemon-merged) view
# --------------------------------------------------------------------------

@dataclass
class GlobalView:
    """The aggregation engine's output: one seqlocked section holding the
    merged state of every worker's maps, readable by any observer exactly
    like a per-worker device section."""
    root: str
    specs: list[MapSpec]
    section: dict
    seq: np.memmap
    crc: np.memmap | None = None

    @property
    def _order(self) -> list[str]:
        return sorted(s.name for s in self.specs)

    @staticmethod
    def _dir(root: str) -> str:
        return os.path.join(root, "global")

    @staticmethod
    def create(root: str, specs: list[MapSpec] | None = None) -> "GlobalView":
        specs = read_meta_specs(root) if specs is None else specs
        d = GlobalView._dir(root)
        seq_path = os.path.join(d, ".seq.npy")
        order = sorted(s.name for s in specs)
        if os.path.exists(seq_path):
            # an aggregator restart over a published section: readers may
            # hold these very mmaps, so the reset must happen UNDER the
            # seqlock — never truncate/zero the files in place
            section = _attach_section(d, specs, "r+")
            seq = _memmap(seq_path, None, "r+")
            if int(seq[0]) % 2 == 0:       # else: prior writer died odd —
                seq[0] += 1                # stay in its in-flight cycle
                seq.flush()
            for name in section:
                for arr in section[name].values():
                    arr[...] = 0
            # seq continues > 0, so readers WILL validate: the checksums
            # must match the zeroed payload, still inside the odd window
            crc = _crc_create(d, len(specs))
            for i, name in enumerate(order):
                crc[i] = _crc_of(section[name])
            crc.flush()
            seq[0] += 1                    # even: consistent zero state
            seq.flush()
            return GlobalView(root, specs, section, seq, crc=crc)
        section = _create_section(d, specs)
        crc = _crc_create(d, len(specs))
        seq = _memmap(seq_path, (1,), "w+")
        seq[0] = 0
        return GlobalView(root, specs, section, seq, crc=crc)

    @staticmethod
    def attach(root: str, mode: str = "r") -> "GlobalView":
        specs = read_meta_specs(root)
        d = GlobalView._dir(root)
        section = _attach_section(d, specs, mode)
        seq = _memmap(os.path.join(d, ".seq.npy"), None,
                      "r+" if mode != "r" else "r")
        return GlobalView(root, specs, section, seq,
                          crc=_crc_attach(d, mode))

    @staticmethod
    def exists(root: str) -> bool:
        return os.path.exists(os.path.join(GlobalView._dir(root),
                                           ".seq.npy"))

    def publish(self, states: dict) -> None:
        _seq_publish(self.seq, self.section, states,
                     crc=self.crc, order=self._order, role="global")

    def snapshot(self, name: str, retries: int = 100) -> dict:
        out, _, _ = _seq_snapshot(
            self.seq, self.section, name, retries, crc=self.crc,
            crc_idx=self._order.index(name) if self.crc is not None
            else None)
        return out

    def publish_status(self, status: dict) -> None:
        _atomic_json(os.path.join(self._dir(self.root), "status.json"),
                     status)

    def read_status(self) -> dict:
        p = os.path.join(self._dir(self.root), "status.json")
        if not os.path.exists(p):
            return {}
        with open(p) as f:
            return json.load(f)
