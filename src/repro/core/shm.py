"""mmap-backed shared-memory control plane — bpftime's shm maps + daemon
handshake, adapted to the host side of a TPU trainer.

Layout under a shm directory (SP3 segregation: program text, device-map
snapshots, and host-map data live in separate sections; the agent may write
only map-data sections — enforced here by API shape, in production by file
permissions, see DESIGN.md §5):

    <dir>/meta.json                 map specs + layout (control plane writes once)
    <dir>/progs/<name>.json         program objects (read-only to agents)
    <dir>/host/<map>.<field>.npy    live host-side maps (memmapped, rw)
    <dir>/device/<map>.<field>.npy  per-step snapshots of device maps
    <dir>/device/.seq.npy           seqlock (odd while a publish is in flight)
    <dir>/control/requests.json     daemon -> trainer attach/detach requests
    <dir>/control/.reqseq.npy       request counter
    <dir>/control/status.json       trainer -> daemon control-plane status
                                    (live-table generation, active links)
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass

import numpy as np

from . import maps as M
from .maps import MapKind, MapSpec


def _memmap(path, shape, mode):
    if mode == "w+":
        return np.lib.format.open_memmap(path, mode="w+", dtype=np.int64,
                                         shape=shape)
    return np.lib.format.open_memmap(path, mode=mode)


@dataclass
class ShmRegion:
    root: str
    specs: list[MapSpec]
    host: dict          # name -> {field: memmap}
    device: dict
    seq: np.memmap
    reqseq: np.memmap

    # ---------------------------------------------------------------- create
    @staticmethod
    def create(root: str, specs: list[MapSpec]) -> "ShmRegion":
        for sub in ("progs", "host", "device", "control"):
            os.makedirs(os.path.join(root, sub), exist_ok=True)
        meta = {"specs": [{"name": s.name, "kind": s.kind.value,
                           "max_entries": s.max_entries,
                           "rec_width": s.rec_width,
                           "num_shards": s.num_shards} for s in specs],
                "version": 1}
        with open(os.path.join(root, "meta.json"), "w") as f:
            json.dump(meta, f)
        host, device = {}, {}
        for s in specs:
            tmpl = M.init_state(s, np)
            host[s.name], device[s.name] = {}, {}
            for field, arr in tmpl.items():
                for sec, d in (("host", host), ("device", device)):
                    p = os.path.join(root, sec, f"{s.name}.{field}.npy")
                    mm = _memmap(p, arr.shape, "w+")
                    mm[...] = 0
                    d[s.name][field] = mm
        seq = _memmap(os.path.join(root, "device", ".seq.npy"), (1,), "w+")
        seq[0] = 0
        reqseq = _memmap(os.path.join(root, "control", ".reqseq.npy"),
                         (1,), "w+")
        reqseq[0] = 0
        with open(os.path.join(root, "control", "requests.json"), "w") as f:
            json.dump([], f)
        return ShmRegion(root, specs, host, device, seq, reqseq)

    # ---------------------------------------------------------------- attach
    @staticmethod
    def attach(root: str, mode: str = "r+") -> "ShmRegion":
        with open(os.path.join(root, "meta.json")) as f:
            meta = json.load(f)
        specs = [MapSpec(name=m["name"], kind=MapKind(m["kind"]),
                         max_entries=m["max_entries"],
                         rec_width=m["rec_width"],
                         num_shards=m["num_shards"]) for m in meta["specs"]]
        host, device = {}, {}
        for s in specs:
            host[s.name], device[s.name] = {}, {}
            tmpl = M.init_state(s, np)
            for field in tmpl:
                host[s.name][field] = _memmap(
                    os.path.join(root, "host", f"{s.name}.{field}.npy"),
                    None, mode)
                device[s.name][field] = _memmap(
                    os.path.join(root, "device", f"{s.name}.{field}.npy"),
                    None, "r")
        seq = _memmap(os.path.join(root, "device", ".seq.npy"), None, "r+")
        reqseq = _memmap(os.path.join(root, "control", ".reqseq.npy"),
                         None, "r+")
        return ShmRegion(root, specs, host, device, seq, reqseq)

    # ---------------------------------------------------------------- publish
    def publish_device(self, states: dict) -> None:
        """Seqlocked snapshot of (host-fetched) device map states."""
        self.seq[0] += 1          # odd: write in flight
        self.seq.flush()
        for name, st in states.items():
            if name not in self.device:
                continue
            for field, arr in st.items():
                self.device[name][field][...] = np.asarray(arr)
        self.seq[0] += 1          # even: consistent
        self.seq.flush()

    def snapshot_device(self, name: str, retries: int = 100) -> dict:
        for _ in range(retries):
            s0 = int(self.seq[0])
            if s0 % 2 == 0:
                out = {f: np.array(a) for f, a in self.device[name].items()}
                if int(self.seq[0]) == s0:
                    return out
            time.sleep(0.001)
        raise TimeoutError("seqlock retry budget exceeded")

    # ---------------------------------------------------------------- progs
    def publish_program(self, obj_json: str, name: str) -> None:
        with open(os.path.join(self.root, "progs", f"{name}.json"), "w") as f:
            f.write(obj_json)

    def read_programs(self) -> dict[str, str]:
        d = os.path.join(self.root, "progs")
        out = {}
        for fn in sorted(os.listdir(d)):
            if fn.endswith(".json"):
                with open(os.path.join(d, fn)) as f:
                    out[fn[:-5]] = f.read()
        return out

    # ---------------------------------------------------------------- status
    def publish_status(self, status: dict) -> None:
        """trainer side: publish the control plane's state (live-table
        generation, active links) for daemons to poll."""
        p = os.path.join(self.root, "control", "status.json")
        tmp = p + ".tmp"
        with open(tmp, "w") as f:
            json.dump(status, f)
        os.replace(tmp, p)              # atomic for concurrent readers

    def read_status(self) -> dict:
        p = os.path.join(self.root, "control", "status.json")
        if not os.path.exists(p):
            return {}
        with open(p) as f:
            return json.load(f)

    # ---------------------------------------------------------------- control
    def request(self, req: dict) -> None:
        """daemon side: queue an attach/detach/load request."""
        p = os.path.join(self.root, "control", "requests.json")
        with open(p) as f:
            reqs = json.load(f)
        reqs.append(req)
        with open(p, "w") as f:
            json.dump(reqs, f)
        self.reqseq[0] += 1
        self.reqseq.flush()

    def poll_requests(self, last_seen: int) -> tuple[list[dict], int]:
        """trainer side: fetch requests newer than last_seen."""
        cur = int(self.reqseq[0])
        if cur == last_seen:
            return [], last_seen
        p = os.path.join(self.root, "control", "requests.json")
        with open(p) as f:
            reqs = json.load(f)
        return reqs[last_seen:cur], cur
