"""Grammar-based program fuzzer + differential lane harness (DESIGN.md §14).

The runtime's safety story rests on two checkers: the verifier (which
programs may run) and the lane gates (which programs may run FAST —
fused, batched, vectorized).  Both are exercised here adversarially:

  1. a seed-deterministic GRAMMAR GENERATOR emits random programs from
     weighted production rules over the full ISA — ALU/branch/stack word
     traffic, bounded loops, helper calls and map ops across all five
     map kinds, ctx loads — constructed so the verifier's path-sensitive
     lattice accepts them (tracked register/stack-init state, structured
     forward branches with init-set intersection at joins);
  2. a REPAIR pass fixes the residual breakage the generator injects on
     purpose (dangling jump targets, reads of uninitialized registers)
     so acceptance stays high even for "raw" material;
  3. every accepted program is DIFFERENTIALLY EXECUTED across every lane
     that will take it — numpy oracle VM, JAX JIT scan, sequential table
     interpreter, batched lockstep machine, shadow-vmap vectorized lane —
     on a random event tape, and across N-worker splits of that tape
     through the shm-merge plane (ShmRegion -> Aggregator -> GlobalView)
     when the program's effect footprint is commutative-only;
  4. any divergence is SHRUNK to a minimal reproducer by deterministic
     line deletion to a fixpoint.

Determinism / thread-safety: there is NO module-level RNG state — every
case derives from a private ``random.Random(seed)``, so concurrent
harnesses (the promotion thread, parallel CI shards) can never corrupt
each other's streams, and a seed is a complete reproducer.  The verifier
counter plane has the same property via ``verifier.reset_stats()``.

CLI::

    python -m repro.core.fuzz --seeds 0-99 [--events 6] [--out DIR]

exits 1 on any lane divergence or verifier crash, writing minimized
reproducers (JSON, replayable by tests/test_fuzz_corpus.py) to --out.
"""
from __future__ import annotations

import argparse
import json
import os
import random
import re
import shutil
import sys
import tempfile
from dataclasses import dataclass, field

import numpy as np

from . import asm, isa, jit, maps as M, table_interp, vectorized, verifier, vm
from .helpers import HELPERS

CTX_WORDS = 8

# The fixed map universe every fuzz program runs against (numeric fds by
# position).  Fixing it means the table/batched interpreter cores compile
# ONCE for the whole campaign (their trace key is (spec_key, ctx_words)).
FUZZ_SPECS = [
    M.MapSpec("arr", M.MapKind.ARRAY, max_entries=8),
    M.MapSpec("hsh", M.MapKind.HASH, max_entries=8),
    M.MapSpec("pc", M.MapKind.PERCPU_ARRAY, max_entries=8, num_shards=2),
    M.MapSpec("hist", M.MapKind.LOG2HIST),
    M.MapSpec("rb", M.MapKind.RINGBUF, max_entries=4, rec_width=2),
]
_FD = {s.name: i for i, s in enumerate(FUZZ_SPECS)}

_ALU = ("add", "sub", "mul", "div", "or", "and", "lsh", "rsh", "mod",
        "xor", "arsh")
_COND = ("jeq", "jne", "jgt", "jge", "jlt", "jle", "jsgt", "jsge", "jset")
_NARGS = {h.name: len(h.args) for h in HELPERS.values()}


# ==========================================================================
# grammar generator
# ==========================================================================

class _Gen:
    """One program's worth of generator state: the emitted lines plus the
    tracked abstract state (initialized registers, initialized stack dw
    slots) that keeps productions verifier-acceptable by construction."""

    KEY_SLOT, VAL_SLOT, RB_SLOT = -8, -16, -32
    SCRATCH_SLOTS = (-40, -48, -56, -64)

    def __init__(self, rng: random.Random, breakage: float = 0.0):
        self.rng = rng
        self.out: list[str] = []
        self.init: set[int] = set()       # registers holding defined values
        self.stack: set[int] = set()      # dw-aligned initialized byte offs
        self.n_label = 0
        self.breakage = breakage

    def emit(self, s: str) -> None:
        self.out.append(s)

    def label(self) -> str:
        self.n_label += 1
        return f"L{self.n_label}"

    def reg(self) -> int:
        return self.rng.choice(sorted(self.init))

    def imm(self) -> int:
        r = self.rng.random()
        if r < 0.7:
            return self.rng.randrange(-4, 17)
        if r < 0.95:
            return self.rng.randrange(-(1 << 15), 1 << 15)
        return self.rng.randrange(-(1 << 31), 1 << 31)

    # ---------------------------------------------------------- productions
    def p_mov_imm(self) -> None:
        d = self.rng.randrange(10)
        self.emit(f"mov r{d}, {self.imm()}")
        self.init.add(d)

    def p_lddw(self) -> None:
        d = self.rng.randrange(10)
        self.emit(f"lddw r{d}, {self.rng.getrandbits(63)}")
        self.init.add(d)

    def p_alu_imm(self) -> None:
        if not self.init:
            return self.p_mov_imm()
        op = self.rng.choice(_ALU)
        w = "32" if self.rng.random() < 0.25 else ""
        self.emit(f"{op}{w} r{self.reg()}, {self.imm()}")

    def p_alu_reg(self) -> None:
        if not self.init:
            return self.p_mov_imm()
        op = self.rng.choice(_ALU + ("mov",))
        w = "32" if self.rng.random() < 0.25 else ""
        d = self.reg() if op != "mov" else self.rng.randrange(10)
        self.emit(f"{op}{w} r{d}, r{self.reg()}")
        self.init.add(d)

    def p_neg(self) -> None:
        if not self.init:
            return self.p_mov_imm()
        self.emit(f"neg r{self.reg()}")

    def p_stack_store(self) -> None:
        off = self.rng.choice(self.SCRATCH_SLOTS)
        if self.init and self.rng.random() < 0.6:
            self.emit(f"stxdw [r10{off}], r{self.reg()}")
        else:
            self.emit(f"stdw [r10{off}], {self.imm()}")
        self.stack.add(off)

    def p_stack_load(self) -> None:
        if not self.stack:
            return self.p_stack_store()
        off = self.rng.choice(sorted(self.stack))
        sz = self.rng.choice(("b", "h", "w", "dw"))
        d = self.rng.randrange(10)
        self.emit(f"ldx{sz} r{d}, [r10{off}]")
        self.init.add(d)

    def p_branch(self, depth: int) -> None:
        if not self.init:
            return self.p_mov_imm()
        lbl = self.label()
        target = lbl
        if self.rng.random() < self.breakage:
            target = f"missing_{lbl}"     # repaired by repair()
        cond = self.rng.choice(_COND)
        w = "32" if self.rng.random() < 0.2 else ""
        if self.rng.random() < 0.4 and len(self.init) > 1:
            self.emit(f"{cond}{w} r{self.reg()}, r{self.reg()}, {target}")
        else:
            self.emit(f"{cond}{w} r{self.reg()}, {self.imm()}, {target}")
        snap_init, snap_stack = set(self.init), set(self.stack)
        for _ in range(self.rng.randrange(1, 4)):
            self.step(depth + 1)
        self.emit(f"{lbl}:")
        # join: the taken edge carries the snapshot — keep the intersection
        # (calls in the body clobber r1-r5; the snapshot side never saw
        # the body's inits)
        self.init, self.stack = snap_init & self.init, snap_stack

    def p_loop(self) -> None:
        c = self.rng.randrange(2, 10)
        self.emit(f"mov r{c}, {self.rng.randrange(1, 7)}")
        self.init.add(c)
        lbl = self.label()
        self.emit(f"{lbl}:")
        for _ in range(self.rng.randrange(1, 4)):
            if not (self.init - {c}):
                break
            op = self.rng.choice(_ALU)
            d = self.rng.choice(sorted(self.init - {c}))
            self.emit(f"{op} r{d}, {self.imm()}")
        self.emit(f"sub r{c}, 1")
        self.emit(f"jgt r{c}, 0, {lbl}")

    # ------------------------------------------------------------- helpers
    def _post_call(self, r0_live_p: float = 0.3) -> None:
        self.init -= {1, 2, 3, 4, 5}
        self.init.add(0)
        if self.init - {0} and self.rng.random() < r0_live_p:
            self.emit(f"mov r{self.rng.choice(sorted(self.init - {0}))}, r0")

    def _emit_key(self, slot: int, static_p: float = 0.75,
                  lo: int = -2, hi: int = 12) -> None:
        """Store a map key at [r10+slot]: usually a static constant (so
        the footprint lattice sees it), sometimes a masked dynamic value."""
        if not self.init or self.rng.random() < static_p:
            self.emit(f"stdw [r10{slot}], {self.rng.randrange(lo, hi)}")
        else:
            t = self.rng.randrange(2, 10)
            self.emit(f"mov r{t}, r{self.reg()}")
            self.emit(f"and r{t}, 7")
            self.emit(f"stxdw [r10{slot}], r{t}")
            self.init.add(t)
        self.stack.add(slot)

    def _kptr(self, argreg: int, slot: int) -> None:
        self.emit(f"mov r{argreg}, r10")
        self.emit(f"add r{argreg}, {slot}")

    def p_call(self) -> None:
        kind = self.rng.choices(
            ("fetch_add", "percpu", "hist", "lookup", "update", "delete",
             "ringbuf", "pure", "printk", "override"),
            weights=(10, 3, 4, 5, 5, 2, 2, 5, 1, 1))[0]
        if kind == "fetch_add":
            fd = self.rng.choice((_FD["arr"], _FD["hsh"]))
            self._emit_key(self.KEY_SLOT)
            self.emit(f"mov r1, {fd}")
            self._kptr(2, self.KEY_SLOT)
            self.emit(f"mov r3, {self.rng.randrange(-9, 10)}")
            self.emit("call map_fetch_add")
            # a live fetch-add result demotes the vector/batched lanes —
            # keep it rare so those lanes stay well exercised
            self._post_call(r0_live_p=0.15)
        elif kind == "percpu":
            self._emit_key(self.KEY_SLOT)
            self.emit(f"mov r1, {_FD['pc']}")
            self._kptr(2, self.KEY_SLOT)
            self.emit(f"mov r3, {self.rng.randrange(1, 9)}")
            self.emit("call percpu_fetch_add")
            self._post_call(r0_live_p=0.15)
        elif kind == "hist":
            self.emit(f"mov r1, {_FD['hist']}")
            if self.init and self.rng.random() < 0.5:
                self.emit(f"mov r2, r{self.reg()}")
            else:
                self.emit(f"mov r2, {self.rng.randrange(0, 1 << 20)}")
            self.emit("call hist_add")
            self._post_call()
        elif kind == "lookup":
            fd = self.rng.choice((_FD["arr"], _FD["hsh"]))
            self._emit_key(self.KEY_SLOT)
            self.emit(f"mov r1, {fd}")
            self._kptr(2, self.KEY_SLOT)
            self.emit("call map_lookup_elem")
            self._post_call(r0_live_p=0.6)
        elif kind == "update":
            fd = self.rng.choice((_FD["arr"], _FD["hsh"]))
            self._emit_key(self.KEY_SLOT)
            self._emit_key(self.VAL_SLOT, static_p=0.6, lo=-99, hi=100)
            self.emit(f"mov r1, {fd}")
            self._kptr(2, self.KEY_SLOT)
            self._kptr(3, self.VAL_SLOT)
            self.emit("mov r4, 0")
            self.emit("call map_update_elem")
            self._post_call()
        elif kind == "delete":
            self._emit_key(self.KEY_SLOT)
            self.emit(f"mov r1, {_FD['hsh']}")
            self._kptr(2, self.KEY_SLOT)
            self.emit("call map_delete_elem")
            self._post_call()
        elif kind == "ringbuf":
            self.emit(f"stdw [r10{self.RB_SLOT}], {self.imm()}")
            self.emit(f"stdw [r10{self.RB_SLOT + 8}], {self.imm()}")
            self.stack.update((self.RB_SLOT, self.RB_SLOT + 8))
            self.emit(f"mov r1, {_FD['rb']}")
            self._kptr(2, self.RB_SLOT)
            self.emit("mov r3, 16")
            self.emit("mov r4, 0")
            self.emit("call ringbuf_output")
            self._post_call()
        elif kind == "pure":
            h = self.rng.choice(("ktime_get_ns", "get_smp_processor_id",
                                 "get_current_pid_tgid", "get_prandom_u32",
                                 "log2"))
            if h == "log2":
                self.emit(f"mov r1, {self.rng.randrange(0, 1 << 20)}")
            self.emit(f"call {h}")
            self._post_call(r0_live_p=0.6)
        elif kind == "printk":
            self.emit(f"mov r1, {self.imm()}")
            self.emit(f"mov r2, {self.imm()}")
            self.emit("call trace_printk")
            self._post_call()
        else:  # override
            self.emit(f"mov r1, {self.rng.randrange(0, 256)}")
            self.emit("call override_return")
            self._post_call()

    # --------------------------------------------------------------- driver
    def step(self, depth: int = 0) -> None:
        prods = [(self.p_alu_imm, 26), (self.p_alu_reg, 14),
                 (self.p_mov_imm, 10), (self.p_lddw, 3), (self.p_neg, 2),
                 (self.p_stack_store, 8), (self.p_stack_load, 8),
                 (self.p_call, 18)]
        if depth < 2:
            prods.append((lambda: self.p_branch(depth), 9))
        if depth == 0:
            prods.append((self.p_loop, 2))
        fns, ws = zip(*prods)
        self.rng.choices(fns, weights=ws)[0]()

    def generate(self, n_steps: int | None = None) -> str:
        # prologue: bank a few ctx words in callee-ish regs while r1 is
        # still the ctx pointer
        for r in range(6, 6 + self.rng.randrange(1, 5)):
            self.emit(f"ldxdw r{r}, [r1+{8 * self.rng.randrange(CTX_WORDS)}]")
            self.init.add(r)
        for _ in range(n_steps or self.rng.randrange(6, 22)):
            self.step()
        if self.rng.random() < self.breakage:
            self.emit(f"add r{self.rng.randrange(10)}, 1")  # maybe uninit
        if 0 in self.init and self.rng.random() < 0.7:
            pass                           # exit with whatever r0 holds
        else:
            self.emit("mov r0, 0")
        self.emit("exit")
        return "\n".join(self.out)


def generate_text(rng: random.Random, breakage: float = 0.0,
                  n_steps: int | None = None) -> str:
    return _Gen(rng, breakage=breakage).generate(n_steps)


# ==========================================================================
# repair pass
# ==========================================================================

_MEM_RE = re.compile(r"\[r(\d+)[+-]\d+\]")
_REG_RE = re.compile(r"\br(\d+)\b")


def _uses(ln: str) -> tuple[set[int], set[int], bool]:
    """(reads, writes, is_call) for one asm line — enough structure for a
    linear conservative liveness scan (labels read/write nothing)."""
    if ln.endswith(":"):
        return set(), set(), False
    parts = ln.replace(",", " ").split()
    mn = parts[0]
    regs = [int(m) for m in _REG_RE.findall(ln)]
    mem = _MEM_RE.search(ln)
    base = {int(mem.group(1))} if mem else set()
    if mn == "exit":
        return {0}, set(), False
    if mn == "call":
        return set(range(1, 1 + _NARGS.get(parts[1], 5))), {0}, True
    if mn == "ja":
        return set(), set(), False
    if mn.startswith("j"):
        return set(regs), set(), False
    if mn.startswith("ldx"):
        return base, {regs[0]}, False
    if mn.startswith("stx"):
        return base | {regs[-1]}, set(), False
    if mn.startswith("st"):
        return base, set(), False
    if mn == "lddw":
        return set(), {regs[0]}, False
    if mn.startswith("mov"):
        return set(regs[1:]), {regs[0]}, False
    if mn.startswith("neg"):
        return {regs[0]}, {regs[0]}, False
    return set(regs), {regs[0]} if regs else set(), False   # alu


def repair(text: str) -> str:
    """Fix the two classes of breakage raw generation leaves behind so the
    verifier's acceptance rate stays high:

      * branches to undefined labels are redirected to a fresh landing pad
        (``__repair_out: mov r0, 0; exit``) appended after the program;
      * registers read while unwritten (linear conservative scan; calls
        clobber r1–r5, ``exit`` reads r0) get a zeroing ``mov`` inserted
        IMMEDIATELY before the offending line — a prologue zero would not
        survive call clobbers, and zeroing r1 up front would destroy the
        ctx pointer.

    Idempotent on already-well-formed programs."""
    lines = [ln.strip() for ln in text.splitlines() if ln.strip()]
    labels = {ln[:-1] for ln in lines if ln.endswith(":")}
    fixed: list[str] = []
    patched = False
    for ln in lines:
        mn = ln.split()[0]
        if (mn == "ja" or (mn.startswith("j") and mn != "ja")) \
                and not ln.endswith(":"):
            target = ln.replace(",", " ").split()[-1]
            if not _REG_RE.fullmatch(target) and not \
                    re.fullmatch(r"-?\d+", target) and target not in labels:
                ln = ln[: ln.rfind(target)] + "__repair_out"
                patched = True
        fixed.append(ln)
    written = {1, 10}                      # r1 = ctx ptr, r10 = frame ptr
    out: list[str] = []
    for ln in fixed:
        reads, writes, is_call = _uses(ln)
        for r in sorted(reads - written - {10}):
            out.append(f"mov r{r}, 0")
            written.add(r)
        if is_call:
            written -= {1, 2, 3, 4, 5}
        written |= writes
        out.append(ln)
    if not out or out[-1] != "exit":
        out += ["mov r0, 0", "exit"]
    if patched:
        out += ["__repair_out:", "mov r0, 0", "exit"]
    return "\n".join(out)


# ==========================================================================
# case model + differential matrix
# ==========================================================================

@dataclass
class FuzzCase:
    """A complete reproducer: program text + the event tape it ran on."""
    seed: int
    text: str
    tape: list[list[int]]                  # B rows x CTX_WORDS u64 words

    def to_json(self) -> dict:
        return {"seed": self.seed, "text": self.text, "tape": self.tape,
                "ctx_words": CTX_WORDS}

    @classmethod
    def from_json(cls, d: dict) -> "FuzzCase":
        return cls(seed=int(d["seed"]), text=d["text"],
                   tape=[[int(w) for w in row] for row in d["tape"]])


@dataclass
class CaseResult:
    accepted: bool = False
    rejected: str | None = None            # VerifierError text
    crashed: str | None = None             # non-VerifierError from verify
    mismatches: list[str] = field(default_factory=list)
    lanes: list[str] = field(default_factory=list)

    @property
    def diverged(self) -> bool:
        return bool(self.mismatches) or self.crashed is not None


def _gen_tape(rng: random.Random, events: int) -> list[list[int]]:
    rows = []
    for _ in range(events):
        rows.append([rng.getrandbits(63) if rng.random() < 0.1
                     else rng.randrange(0, 200) for _ in range(CTX_WORDS)])
    return rows


def generate_case(seed: int, events: int = 6,
                  breakage: float = 0.15) -> FuzzCase:
    """Seed -> (repaired program, event tape), fully deterministic."""
    rng = random.Random(seed)
    text = repair(generate_text(rng, breakage=breakage))
    return FuzzCase(seed=seed, text=text, tape=_gen_tape(rng, events))


def _aux_kw(i: int) -> dict:
    """Aux constants for event i — CONSTANT across the tape, because the
    batched/vectorized lanes execute a whole batch under one aux block
    (time/cpu/pid are per-batch constants in the runtime), so per-event
    variation would manufacture divergence that is a harness artifact,
    not a lane bug.  cpu=1 on purpose: it catches any lane that silently
    lands per-cpu traffic on shard 0."""
    return dict(time_ns=1000, cpu=1, pid=77)


def _cmp_maps(label: str, got, want_np, out: list[str]) -> None:
    for sp in FUZZ_SPECS:
        for k, arr in want_np[sp.name].items():
            if not np.array_equal(np.asarray(got[sp.name][k]), arr):
                out.append(f"{label}: map {sp.name}.{k} "
                           f"{np.asarray(got[sp.name][k]).tolist()} != "
                           f"{arr.tolist()}")


def run_case(case: FuzzCase) -> CaseResult:
    """The full differential matrix for one case.  Lanes that a gate
    rejects are skipped (that is the gate doing its job); lanes that run
    must be bit-identical to the numpy oracle."""
    import jax
    import jax.numpy as jnp

    res = CaseResult()
    a = asm.assemble(case.text)
    assert not a.map_relocs
    try:
        vprog = verifier.verify(a.insns, FUZZ_SPECS, ctx_words=CTX_WORDS)
    except verifier.VerifierError as e:
        res.rejected = str(e)
        return res
    except Exception as e:                 # verifier CRASH — always a bug
        res.crashed = f"{type(e).__name__}: {e}"
        return res
    res.accepted = True

    jrows = jnp.asarray([[isa.s64(isa.u64(w)) for w in row]
                         for row in case.tape], jnp.int64)
    mm = res.mismatches

    # ---- oracle: sequential vm over the tape on accumulating numpy maps
    np_maps = M.init_states(FUZZ_SPECS, np)
    oracle: list[vm.Result] = []
    for i, row in enumerate(case.tape):
        oracle.append(vm.run(a.insns, vm.pack_ctx(row), FUZZ_SPECS,
                             np_maps, vm.Aux(**_aux_kw(i))))

    # ---- JIT scan + sequential table lanes, event by event
    res.lanes += ["jit", "table"]
    prog = jit.compile_program(vprog)
    f = jax.jit(lambda c, m, x: prog(c, m, x))
    j_maps = M.init_states(FUZZ_SPECS, jnp)
    t_maps = M.init_states(FUZZ_SPECS, jnp)
    for i, row in enumerate(case.tape):
        ctx = jrows[i]
        r0, j_maps, jaux = f(ctx, j_maps, jit.make_aux(**_aux_kw(i)))
        t_r0, t_maps, taux = table_interp.run_program(
            vprog, ctx, t_maps, jit.make_aux(**_aux_kw(i)))
        want = oracle[i]
        for label, got_r0, got_aux in (("jit", r0, jaux),
                                       ("table", t_r0, taux)):
            if isa.u64(int(got_r0)) != isa.u64(want.r0):
                mm.append(f"{label}[ev{i}]: r0 {isa.u64(int(got_r0)):#x} != "
                          f"{isa.u64(want.r0):#x}")
            if int(got_aux["override_set"]) != want.aux.override_set or (
                    want.aux.override_set and
                    isa.u64(int(got_aux["override_val"]))
                    != want.aux.override_val):
                mm.append(f"{label}[ev{i}]: override aux mismatch")
    _cmp_maps("jit[final]", j_maps, np_maps, mm)
    _cmp_maps("table[final]", t_maps, np_maps, mm)

    # ---- batched lockstep machine over the whole tape at once
    if table_interp.batched_encodable(vprog):
        res.lanes.append("batched")
        b_maps = M.init_states(FUZZ_SPECS, jnp)
        b_r0, b_maps = table_interp.run_program_batched(
            vprog, jrows, b_maps, jit.make_aux(**_aux_kw(0)))
        _cmp_maps("batched[final]", b_maps, np_maps, mm)

    # ---- shadow-vmap vectorized lane over the whole tape
    if vectorized.is_vector_safe(vprog):
        res.lanes.append("vectorized")
        v_maps = M.init_states(FUZZ_SPECS, jnp)
        valid = jnp.ones(len(case.tape), bool)
        v_maps, _ = vectorized.run_vectorized(
            vprog, jrows, valid, v_maps, jit.make_aux(**_aux_kw(0)))
        _cmp_maps("vectorized[final]", v_maps, np_maps, mm)

    # ---- N-worker splits through the shm merge plane
    if _merge_eligible(vprog):
        for n in (1, 2, 3):
            res.lanes.append(f"merge{n}")
            mm.extend(_check_merge_split(case, a.insns, np_maps, n))
    return res


def _merge_eligible(vprog) -> bool:
    """The merge plane's contract (DESIGN.md §10): cross-worker ops on
    shared state must be commutative AND unobserved.  The footprint
    lattice states the first half per program (every touched map
    commutative-only); the second half is fetch-add RESULT deadness —
    a live r0 reads the accumulated value, which depends on how the tape
    was split (found by the fuzz harness: a live fetch_add result fed
    into hist_add diverged under 2/3-way splits, pinned in
    tests/corpus/live_fetch_add_split.json)."""
    from .vectorized import _r0_dead_after
    from .verifier import CallAnn
    fps = [vprog.footprints.get(fd) for fd in vprog.touched_map_fds]
    if not fps or not all(fp is not None and fp.commutative_only
                          for fp in fps):
        return False
    for pc, ann in vprog.anns.items():
        if isinstance(ann, CallAnn) and \
                ann.name in ("map_fetch_add", "percpu_fetch_add") and \
                not _r0_dead_after(vprog, pc):
            return False
    return True


def _check_merge_split(case: FuzzCase, insns, oracle_maps,
                       n_workers: int) -> list[str]:
    """Split the tape round-robin across N workers, each applying its
    share to its OWN map state through the vm, publish through the shm
    plane, aggregate, and compare the global view to the sequential
    oracle (hash compared canonicalized, as the plane publishes it)."""
    from . import daemon as D, shm as SH
    root = tempfile.mkdtemp(prefix="fuzzmerge_")
    out: list[str] = []
    try:
        regions = {w: SH.ShmRegion.create(root, FUZZ_SPECS,
                                          worker_id=f"w{w}")
                   for w in range(n_workers)}
        states = {w: M.init_states(FUZZ_SPECS, np)
                  for w in range(n_workers)}
        for i, row in enumerate(case.tape):
            w = i % n_workers
            vm.run(insns, vm.pack_ctx(row), FUZZ_SPECS, states[w],
                   vm.Aux(**_aux_kw(i)))
        agg = D.Aggregator(root)
        for w in range(n_workers):
            regions[w].publish_device(states[w])
        agg.poll_once()
        g = SH.GlobalView.attach(root)
        for sp in FUZZ_SPECS:
            got = g.snapshot(sp.name)
            if sp.kind == M.MapKind.HASH:
                want = M.n_hash_canonical(
                    sp, M.n_hash_items(oracle_maps[sp.name]))
            else:
                want = oracle_maps[sp.name]
            for fld in got:
                if not np.array_equal(got[fld], np.asarray(want[fld])):
                    out.append(f"merge{n_workers}: {sp.name}.{fld} "
                               f"{got[fld].tolist()} != "
                               f"{np.asarray(want[fld]).tolist()}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


# ==========================================================================
# shrinker
# ==========================================================================

def _still_fails(text: str, case: FuzzCase) -> bool:
    cand = FuzzCase(seed=case.seed, text=text, tape=case.tape)
    try:
        r = run_case(cand)
    except Exception:
        return False                       # breakage, not the divergence
    return r.accepted and r.diverged


def shrink_case(case: FuzzCase, still_fails=None) -> FuzzCase:
    """Deterministic line-deletion to a fixpoint: drop every line (largest
    chunks first) whose removal keeps the program verifier-accepted AND
    still diverging.  O(lines^2) worst case on programs of ~dozens of
    lines — fine for a reproducer pass.  ``still_fails(text, case)`` is
    injectable so the shrink loop itself is unit-testable without a real
    lane divergence."""
    if still_fails is None:
        still_fails = _still_fails
    lines = case.text.splitlines()
    chunk = max(1, len(lines) // 2)
    while chunk >= 1:
        i = 0
        while i < len(lines):
            cand = lines[:i] + lines[i + chunk:]
            if cand and still_fails("\n".join(cand), case):
                lines = cand
            else:
                i += chunk
        chunk //= 2
    return FuzzCase(seed=case.seed, text="\n".join(lines), tape=case.tape)


# ==========================================================================
# campaign driver
# ==========================================================================

def fuzz(seeds, events: int = 6, out_dir: str | None = None,
         shrink: bool = True, breakage: float = 0.15) -> dict:
    """Run the matrix over a seed list.  Returns a summary dict; writes
    minimized reproducers to ``out_dir`` (one JSON per divergent seed)."""
    total = accepted = 0
    failures: list[dict] = []
    for seed in seeds:
        case = generate_case(seed, events=events, breakage=breakage)
        r = run_case(case)
        total += 1
        accepted += r.accepted
        if r.diverged:
            mini = shrink_case(case) if shrink and not r.crashed else case
            rec = {**mini.to_json(),
                   "crashed": r.crashed, "mismatches": r.mismatches,
                   "lanes": r.lanes, "original_text": case.text}
            failures.append(rec)
            if out_dir:
                os.makedirs(out_dir, exist_ok=True)
                with open(os.path.join(out_dir, f"repro_{seed}.json"),
                          "w") as fh:
                    json.dump(rec, fh, indent=1)
    return {"seeds": total, "accepted": accepted,
            "acceptance_rate": accepted / max(total, 1),
            "divergences": len(failures), "failures": failures}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.fuzz",
        description="differential fuzz harness over all execution lanes")
    ap.add_argument("--seeds", default="0-49",
                    help="'A-B' inclusive range or comma list (default 0-49)")
    ap.add_argument("--events", type=int, default=6)
    ap.add_argument("--out", default=None,
                    help="directory for minimized reproducer JSONs")
    ap.add_argument("--no-shrink", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    if "-" in args.seeds and not args.seeds.startswith("-"):
        lo, hi = args.seeds.split("-")
        seeds = range(int(lo), int(hi) + 1)
    else:
        seeds = [int(s) for s in args.seeds.split(",")]
    summary = fuzz(seeds, events=args.events, out_dir=args.out,
                   shrink=not args.no_shrink)
    if args.json:
        print(json.dumps(summary, indent=1))
    else:
        print(f"fuzz: {summary['seeds']} seeds, "
              f"{summary['accepted']} accepted "
              f"({summary['acceptance_rate']:.0%}), "
              f"{summary['divergences']} divergence(s)")
        for f_ in summary["failures"]:
            print(f"  seed {f_['seed']}: "
                  + (f_["crashed"] or "; ".join(f_["mismatches"][:3])))
            if args.out:
                print(f"    reproducer: {args.out}/repro_{f_['seed']}.json")
    return 1 if summary["divergences"] else 0


if __name__ == "__main__":                 # pragma: no cover
    sys.exit(main())
