"""Program-object loader — the libbpf/CO-RE analogue.

A ProgramObject is the serialized unit a control plane ships around (the
".o" file): bytecode + map specs + symbolic relocations + attach metadata.
Programs reference maps ONLY via `lddw rX, map:NAME` relocations; the
runtime binds NAME -> global map fd at load time and patches the imm64
(exactly how libbpf fixes up BPF_PSEUDO_MAP_FD). Map specs are unified by
name across objects — two tools declaring map "counts" share one map, the
paper's cross-process aggregation story.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

from . import asm, isa
from .isa import Insn
from .layout import EVENT_BTF, SYSCALL_BTF  # canonical tables live in layout
from .maps import MapKind, MapSpec


class LoadError(ValueError):
    pass


@dataclass
class ProgramObject:
    name: str
    prog_type: str                  # uprobe|uretprobe|tracepoint|filter
    insns_hex: str
    maps: list[dict]                # serialized MapSpecs (object-local order)
    relocs: dict[str, str] = field(default_factory=dict)   # insn idx -> map name
    ctx_words: int = 16
    attach_to: str | None = None    # default target, e.g. "uprobe:mlp"
    btf: dict | None = None         # ctx field names -> word index (CO-RE-lite)
    # insn idx -> ctx field name: which insns took their `off` operand from a
    # `ctx:FIELD` substitution, so the program can be re-offset onto another
    # ctx layout without re-assembly (core/reloc.py).  Default {} keeps old
    # serialized objects loading unchanged.
    ctx_relocs: dict[str, str] = field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)

    @staticmethod
    def from_json(s: str) -> "ProgramObject":
        d = json.loads(s)
        return ProgramObject(**d)

    def decode_insns(self) -> list[Insn]:
        return isa.decode_program(bytes.fromhex(self.insns_hex))

    def map_specs(self) -> list[MapSpec]:
        out = []
        for m in self.maps:
            m = dict(m)
            m["kind"] = MapKind(m["kind"]) if not isinstance(m["kind"], MapKind) else m["kind"]
            out.append(MapSpec(**m))
        return out


def _spec_dict(s: MapSpec) -> dict:
    return {"name": s.name, "kind": s.kind.value,
            "max_entries": s.max_entries, "rec_width": s.rec_width,
            "num_shards": s.num_shards}


def build_object(name: str, text: str, maps: list[MapSpec],
                 prog_type: str = "uprobe", attach_to: str | None = None,
                 ctx_words: int = 16, btf: dict | None = None) -> ProgramObject:
    """Assemble source with CO-RE-lite field substitution.

    Occurrences of `ctx:FIELD` in ldx offsets are replaced using the btf
    table (defaults to the event layout), so programs survive event-layout
    changes by re-assembly — the relocation story of CO-RE.
    """
    table = btf or (SYSCALL_BTF if prog_type in ("tracepoint", "filter")
                    else EVENT_BTF)
    out_lines = []
    line_fields: dict[int, list[str]] = {}   # source line -> ctx fields used
    for lineno, line in enumerate(text.splitlines()):
        while "ctx:" in line:
            pre, rest = line.split("ctx:", 1)
            fieldname = ""
            for ch in rest:
                if ch.isalnum() or ch == "_":
                    fieldname += ch
                else:
                    break
            if fieldname not in table:
                raise LoadError(f"unknown ctx field {fieldname!r}")
            line_fields.setdefault(lineno, []).append(fieldname)
            line = pre + str(8 * table[fieldname]) + rest[len(fieldname):]
        out_lines.append(line)
    a = asm.assemble("\n".join(out_lines))
    local_names = [m.name for m in maps]
    for idx, mname in a.map_relocs.items():
        if mname not in local_names:
            raise LoadError(f"program references undeclared map {mname!r}")
    # map each ctx substitution back onto the insn its line assembled into
    ctx_relocs: dict[str, str] = {}
    for idx, lineno in enumerate(a.src_lines):
        fields = line_fields.get(lineno)
        if not fields:
            continue
        if len(fields) > 1:
            raise LoadError(
                f"line {lineno}: multiple ctx: references in one insn are "
                f"not relocatable")
        ctx_relocs[str(idx)] = fields[0]
    return ProgramObject(
        name=name, prog_type=prog_type,
        insns_hex=isa.encode_program(a.insns).hex(),
        maps=[_spec_dict(m) for m in maps],
        relocs={str(k): v for k, v in a.map_relocs.items()},
        ctx_words=ctx_words, attach_to=attach_to, btf=table,
        ctx_relocs=ctx_relocs)


def relocate(obj: ProgramObject, fd_of: dict[str, int]) -> list[Insn]:
    """Patch lddw map relocations with bound global fds."""
    insns = obj.decode_insns()
    for k, mname in obj.relocs.items():
        idx = int(k)
        if mname not in fd_of:
            raise LoadError(f"unbound map {mname!r}")
        old = insns[idx]
        if not old.is_lddw():
            raise LoadError(f"reloc target insn {idx} is not lddw")
        insns[idx] = Insn(old.op, old.dst, old.src, old.off,
                          imm=fd_of[mname] & 0xFFFFFFFF, imm64=fd_of[mname])
    return insns
