"""Probe sites + event collection — the binary-rewriting analogue.

Model/framework code is annotated with zero-cost markers:

    x = probe_site("attn.out", x)            # free-standing site
    @traceable("mlp")                        # uprobe (entry) + uretprobe (exit)
    def mlp(params, x): ...

With no probe attached, a site is a Python `if` that immediately returns —
the "5-byte nop". When the runtime attaches a program to a site, the next
trace of the step function "patches" the site: the tensor is reduced to a
16-lane stat row (Pallas fused-stats kernel on the heavy path) and appended
to the step's event tape. One probe-execution stage per step then runs the
attached eBPF programs over the tape (see runtime.py) — events never cross
the device/host boundary (the paper's inline-execution property).

Event row layout (i64 lanes; stats in saturating Q47.16 fixed point):
    0 site_id   1 kind    2 layer     3 step
    4 numel     5 mean    6 rms       7 min
    8 max       9 absmax  10 nan_cnt  11 inf_cnt
    12..15 user/spare (zero)
"""
from __future__ import annotations

import functools
import threading
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

EVENT_WIDTH = 16
KIND_ENTRY = 0    # uprobe
KIND_EXIT = 1     # uretprobe
KIND_TRACEPOINT = 2

FX_SHIFT = 16
FX_ONE = 1 << FX_SHIFT
_FX_MAX = (1 << 62) - 1

I64 = jnp.int64


def _fallback_tensor_stats(x) -> dict:
    """Self-contained jnp twin of repro.kernels.ref.tensor_stats — the
    EXPLICIT fallback used when the Pallas kernels package is unavailable
    (optional layer). Semantics must match the kernel exactly; the
    differential test in tests/test_kernels_fallback.py pins it."""
    xf = jnp.asarray(x, jnp.float32).reshape(-1)
    nan = jnp.isnan(xf)
    inf = jnp.isinf(xf)
    bad = nan | inf
    n_ok = jnp.maximum(jnp.sum(~bad).astype(jnp.float32), 1.0)
    z = jnp.where(bad, 0.0, xf)
    mn = jnp.min(jnp.where(bad, jnp.inf, xf))
    mx = jnp.max(jnp.where(bad, -jnp.inf, xf))
    any_ok = jnp.any(~bad)
    mn = jnp.where(any_ok, mn, 0.0)
    mx = jnp.where(any_ok, mx, 0.0)
    return {
        "mean": jnp.sum(z) / n_ok,
        "rms": jnp.sqrt(jnp.sum(z * z) / n_ok),
        "min": mn,
        "max": mx,
        "absmax": jnp.maximum(jnp.abs(mn), jnp.abs(mx)),
        "nan_cnt": jnp.sum(nan).astype(I64),
        "inf_cnt": jnp.sum(inf).astype(I64),
    }


def default_tensor_stats(tensor) -> dict:
    """The collector's stats path: the fused kernels package when
    importable, else the in-module jnp fallback — probes keep working on
    hosts without the accelerator toolchain."""
    try:
        from repro.kernels import ops
    except ImportError:
        return _fallback_tensor_stats(tensor)
    return ops.tensor_stats(tensor)


def to_fx(x):
    """f32 -> saturating Q47.16 fixed-point i64 (NaN -> 0)."""
    x = jnp.asarray(x, jnp.float32)
    v = jnp.where(jnp.isnan(x), 0.0, x) * float(FX_ONE)
    v = jnp.clip(v, -float(_FX_MAX), float(_FX_MAX))
    return v.astype(I64)


def from_fx(v):
    return jnp.asarray(v, jnp.float32) / float(FX_ONE)


# --------------------------------------------------------------------------
# site registry (stable name -> id, registration order)
# --------------------------------------------------------------------------

class SiteRegistry:
    def __init__(self):
        self._ids: dict[str, int] = {}
        self._names: list[str] = []
        self._lock = threading.Lock()

    def get_or_create(self, name: str) -> int:
        with self._lock:
            if name not in self._ids:
                self._ids[name] = len(self._names)
                self._names.append(name)
            return self._ids[name]

    def name_of(self, site_id: int) -> str:
        return self._names[site_id]

    def known(self) -> dict[str, int]:
        return dict(self._ids)


SITES = SiteRegistry()


# --------------------------------------------------------------------------
# collector (trace-time ambient; push/pop frames for scan/remat bodies)
# --------------------------------------------------------------------------

@dataclass
class _Frame:
    rows: list = field(default_factory=list)


class Collector:
    """Active during step-function tracing when >=1 device probe is attached.
    `wanted` is the set of (site_id, kind) pairs with attached programs —
    unattached sites stay nops even while a collector is active."""

    _tls = threading.local()

    def __init__(self, wanted: set[tuple[int, int]], stats_fn=None):
        self.wanted = wanted
        self.frames: list[_Frame] = [_Frame()]
        self.layer_ctx = jnp.asarray(0, I64)
        self.stats_fn = stats_fn  # tensor -> dict of stats (see ops.tensor_stats)

    # ---- ambient management
    @classmethod
    def active(cls) -> "Collector | None":
        return getattr(cls._tls, "collector", None)

    def __enter__(self):
        if Collector.active() is not None:
            raise RuntimeError("nested Collector activation")
        Collector._tls.collector = self
        return self

    def __exit__(self, *exc):
        Collector._tls.collector = None
        return False

    # ---- frames
    class _FrameCtx:
        def __init__(self, col):
            self.col = col

        def __enter__(self):
            self.frame = _Frame()
            self.col.frames.append(self.frame)
            return self.frame

        def __exit__(self, *exc):
            assert self.col.frames.pop() is self.frame
            return False

    def frame(self):
        return Collector._FrameCtx(self)

    # ---- emission
    def wants(self, site_id: int, kind: int) -> bool:
        return (site_id, kind) in self.wanted

    def emit_row(self, row):
        assert row.shape == (EVENT_WIDTH,)
        self.frames[-1].rows.append(row)

    def emit_many(self, rows):
        """rows: i64[N, W] (e.g. reshaped scan ys)."""
        assert rows.ndim == 2 and rows.shape[1] == EVENT_WIDTH
        self.frames[-1].rows.append(rows)

    def emit_tensor_event(self, site_id: int, kind: int, tensor):
        st = self._stats(tensor)
        row = jnp.stack([
            jnp.asarray(site_id, I64),
            jnp.asarray(kind, I64),
            jnp.asarray(self.layer_ctx, I64),
            jnp.asarray(0, I64),                       # step, filled later
            jnp.asarray(tensor.size, I64),
            to_fx(st["mean"]), to_fx(st["rms"]),
            to_fx(st["min"]), to_fx(st["max"]), to_fx(st["absmax"]),
            st["nan_cnt"].astype(I64), st["inf_cnt"].astype(I64),
            jnp.asarray(0, I64), jnp.asarray(0, I64),
            jnp.asarray(0, I64), jnp.asarray(0, I64),
        ])
        self.emit_row(row)

    def _stats(self, tensor):
        if self.stats_fn is not None:
            return self.stats_fn(tensor)
        return default_tensor_stats(tensor)

    def stacked_rows(self, frame: _Frame):
        parts = []
        for r in frame.rows:
            parts.append(r[None, :] if r.ndim == 1 else r)
        if not parts:
            return jnp.zeros((0, EVENT_WIDTH), I64)
        return jnp.concatenate(parts, axis=0)

    def take_all_rows(self):
        assert len(self.frames) == 1, "unbalanced frames"
        rows = self.stacked_rows(self.frames[0])
        self.frames[0].rows.clear()
        return rows


# --------------------------------------------------------------------------
# site markers used by model/framework code
# --------------------------------------------------------------------------

def probe_site(name: str, tensor, kind: int = KIND_TRACEPOINT):
    """Zero-cost marker. Returns `tensor` unchanged (identity in the graph)."""
    col = Collector.active()
    if col is None:
        return tensor
    sid = SITES.get_or_create(name)
    if col.wants(sid, kind):
        col.emit_tensor_event(sid, kind, tensor)
    return tensor


def _first_array_leaf(tree):
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "shape") and getattr(leaf, "size", 0) > 0:
            return leaf
    return None


def traceable(name: str):
    """uprobe/uretprobe pair on a function: entry summarizes the first array
    argument leaf, exit summarizes the first output leaf."""
    sid = SITES.get_or_create(name)

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            col = Collector.active()
            if col is not None and col.wants(sid, KIND_ENTRY):
                leaf = _first_array_leaf((args, kwargs))
                if leaf is not None:
                    col.emit_tensor_event(sid, KIND_ENTRY, leaf)
            out = fn(*args, **kwargs)
            if col is not None and col.wants(sid, KIND_EXIT):
                leaf = _first_array_leaf(out)
                if leaf is not None:
                    col.emit_tensor_event(sid, KIND_EXIT, leaf)
            return out
        return wrapper
    return deco


# --------------------------------------------------------------------------
# scan/remat-aware collection
# --------------------------------------------------------------------------

def probed_scan(body, carry, xs, *, length=None, remat=False,
                remat_policy=None, layer_ids=True):
    """lax.scan that routes probe emissions from inside the body out as
    stacked ys (events survive the scan boundary). The row-collection wrapper
    sits INSIDE the remat boundary so emissions are explicit outputs (no
    leaked tracers, stats are primal outputs and not recomputed).

    body: (carry, x) -> (carry, y)
    """
    col = Collector.active()
    if col is None:
        f = jax.checkpoint(body, policy=remat_policy) if remat else body
        return jax.lax.scan(f, carry, xs, length=length)

    n = length
    if n is None:
        n = jax.tree.leaves(xs)[0].shape[0]
    xs2 = (xs, jnp.arange(n, dtype=I64)) if layer_ids else (xs, None)

    def with_rows(c, x2):
        x, lid = x2
        old = col.layer_ctx
        if lid is not None:
            col.layer_ctx = lid
        with col.frame() as fr:
            c2, y = body(c, x)
        rows = col.stacked_rows(fr)
        col.layer_ctx = old
        return c2, (y, rows)

    f = jax.checkpoint(with_rows, policy=remat_policy) if remat else with_rows
    c_out, (ys, rows) = jax.lax.scan(f, carry, xs2, length=n)
    col.emit_many(rows.reshape(-1, EVENT_WIDTH))
    return c_out, ys
