"""Background promotion: table-lane links converge to the fused lane.

The live program-table lane (DESIGN.md §9) buys ~1.4ms attach latency by
interpreting bytecode that rides in device *data* — but even vectorized,
interpretation costs a small multiple of the scan lane forever.  bpftime's
steady-state claim is that probes become near-free once the dust settles,
so the runtime closes the gap the way a JIT tier does: every table-lane
link with ``promote=True`` is handed to this engine, which retraces the
fused lane OFF the critical path (a daemon thread) and atomically swaps
the compiled artifact in at the next generation boundary
(``Runtime.sync_live_table``).  The training loop never blocks on a
compile and never observes a half-promoted world:

    interp ──schedule──▶ compiling ──▶ ready ──apply_ready──▶ fused
        │                    │
        └──── detach ────────┴──────▶ cancelled        (compile error
                                                        ──▶ failed)

Correctness rules (tested in tests/test_promotion.py):

  * the background trace sees the FUTURE attach state through a
    thread-local overlay (``runtime._effective_attach``) — the foreground
    step keeps tracing the present, so the jit cache of the live step
    never grows;
  * the compiled artifact is keyed on the full post-promotion attach
    signature; if the world moved between compile and apply (another
    attach/detach bumped the epoch), ``apply_ready`` discards the stale
    artifact and re-schedules instead of swapping in a wrong trace;
  * the swap itself happens entirely between steps: clear the table slot
    (generation bump) + append the static attachment (epoch bump) in one
    host-side critical section, then pre-populate the loop's step cache
    via ``runtime.take_promoted_step()`` — each event is executed by
    exactly one lane on every step, so the map state stays bit-identical
    across the boundary.
"""
from __future__ import annotations

import threading
import traceback


def attach_signature(attach_map: dict) -> tuple:
    """Hashable invariant the fused lane's trace depends on: the exact
    multiset of (site, kind) -> program ids (SNIPPETS.md §1 — cache on
    what the trace reads, nothing else)."""
    return tuple(sorted((sk, tuple(pids)) for sk, pids in attach_map.items()
                        if pids))


class PromotionEngine:
    """Owns the background compiles and the ready queue for one runtime.

    ``step_builder()`` must return a *fresh* jit-wrapped step function
    traced against the runtime's current (overlaid) attach state;
    ``example_args`` are the concrete-or-ShapeDtypeStruct arguments the
    loop will keep calling the step with (AOT: lower + compile up front,
    so the foreground swap is a dictionary insert, not a trace)."""

    def __init__(self, runtime, step_builder, example_args,
                 background: bool = True):
        self.runtime = runtime
        self.step_builder = step_builder
        self.example_args = tuple(example_args)
        self.background = background
        self.compiles = 0                 # background traces actually run
        # full layout fingerprint -> compiled step.  The key folds the map
        # registry / ctx width / table dims AND the post-promotion attach
        # signature: an attach signature alone under-keys — the same attach
        # set over a different registry traces a different graph, and a
        # signature-only cache would serve the stale executable.
        self._cache: dict[str, object] = {}
        self._ready: list = []            # links compiled + waiting to swap
        self._threads: list[threading.Thread] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------ schedule
    def schedule(self, link) -> None:
        """Kick off (or reuse) a compile for one table-lane link."""
        if link.lane != "table" or link.promotion_state not in ("interp",
                                                                "failed"):
            return
        link.promotion_state = "compiling"
        if not self.background:
            self._compile(link)
            return
        t = threading.Thread(target=self._compile, args=(link,),
                             name=f"promote-{link.link_id}", daemon=True)
        with self._lock:
            self._threads.append(t)
        t.start()

    def _target_signature(self, link) -> tuple:
        """Attach signature of the world AFTER this link is promoted."""
        merged = {k: list(v) for k, v in self.runtime.device_attach.items()}
        merged.setdefault(link._parsed, []).append(link.pid)
        return attach_signature(merged)

    def _cache_key(self, link) -> str:
        """The FULL trace-stability key for this link's promoted world:
        layout fingerprint (registry, ctx, table dims) + post-promotion
        attach signature — never the signature alone."""
        return self.runtime.layout_fingerprint(
            attach_sig=self._target_signature(link))

    def _compile(self, link) -> None:
        try:
            sig = self._target_signature(link)
            key = self._cache_key(link)
            cache = self.runtime.artifact_cache
            with self._lock:
                compiled = self._cache.get(key)
            if compiled is None and cache is not None:
                # another fleet member may have promoted this exact world
                compiled = cache.get_step(key)
                if compiled is not None:
                    with self._lock:
                        self._cache[key] = compiled
            if compiled is None:
                # trace against the future: the overlay makes
                # _static_lanes/_effective_attach on THIS thread see the
                # link as a static attachment; the foreground trace (and
                # its jit cache) is untouched.
                with self.runtime._attach_overlay({link._parsed: [link.pid]}):
                    fn = self.step_builder()
                    compiled = fn.lower(*self.example_args).compile()
                with self._lock:
                    self._cache[key] = compiled
                    self.compiles += 1
                if cache is not None:
                    cache.put_step(key, compiled)
            if link.promotion_state != "compiling":    # detached mid-compile
                return
            link.promotion_state = "ready"
            with self._lock:
                self._ready.append((link, sig, compiled))
        except Exception:
            link.promotion_state = "failed"
            link.promotion_error = traceback.format_exc(limit=4)

    # ------------------------------------------------------------ apply
    def apply_ready(self) -> bool:
        """Called by the runtime at every generation boundary
        (sync_live_table).  Swap in every compiled link whose signature
        still matches the current world; re-schedule the ones the world
        moved out from under.  Returns True iff any link was promoted."""
        with self._lock:
            ready, self._ready = self._ready, []
        promoted = False
        for link, sig, compiled in ready:
            if link.promotion_state != "ready":        # detach won the race
                continue
            if self._target_signature(link) != sig:
                # another attach/detach changed the fused trace since this
                # artifact was built — it would execute the wrong program
                # set.  Recompile against the new world.
                link.promotion_state = "interp"
                self.schedule(link)
                continue
            self.runtime._promote_table_link(link, compiled)
            promoted = True
        return promoted

    # ------------------------------------------------------------ waiting
    def wait(self, timeout: float = 30.0) -> None:
        """Join outstanding compile threads (tests / shutdown)."""
        with self._lock:
            threads, self._threads = self._threads, []
        for t in threads:
            t.join(timeout)

    def pending(self) -> int:
        with self._lock:
            return len(self._ready)
