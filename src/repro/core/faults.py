"""Deterministic fault injection for the fleet plane (DESIGN.md §11).

The shm/daemon boundary code calls ``faults.fire(point, **ctx)`` at named
production points; the default hook set is inert (one module-global check).
Installing a seed-driven :class:`FaultPlan` turns those call sites into
chaos injection points WITHOUT monkeypatching — the code path under test is
exactly the code path in production, per SafeBPF's "isolation claims are
only as strong as the failure modes actually tested".

Hook points (ctx keys in parentheses):

    shm:publish_begin     seqlock just went odd, publish in flight
    shm:publish_field     about to copy one field into the section
                          (map, field)
    shm:publish_commit    all fields + CRC written, seq still odd (section)
    shm:snapshot_begin    reader about to attempt a seqlocked read (name)
    agg:cycle_begin       aggregation cycle starting (cycle)
    agg:pre_merge         about to snapshot+fold one worker (wid, cycle)
    agg:post_merge        one worker folded into the accumulators (wid)
    agg:pre_publish       about to publish the merged global view
    agg:post_publish      global view published, journal not yet written
    agg:pre_journal       about to persist the fold journal
    agg:cycle_end         cycle complete, journal durable (cycle)
    node:pre_emit         node aggregator about to serialize + commit one
                          delta batch to its stream (node, seq, who)
    node:post_commit      delta batch durable on the stream, head bumped,
                          journal not yet written (node, seq, path, who)
    cache:post_store      AOT artifact payload + CRC meta just written to
                          the artifact cache (path, key)

All agg:* points carry ``who`` (the aggregator identity: ``"global"`` for
the root, the node id for a NodeAggregator), so a tree chaos schedule can
target one level of the tree without perturbing the others.

Fault classes (each has a counter, asserted by the chaos tests):

    torn_publish      abandon a publish mid-field-copy (partial section,
                      seqlock left odd)
    stuck_odd         abandon a publish right after the odd flip (seqlock
                      stuck odd with the previous consistent data intact)
    corrupt_snapshot  scribble bytes into a published section AFTER its CRC
                      was written (consistent seq, corrupt payload)
    kill_worker       SIGKILL the calling process mid-publish
    daemon_crash      raise InjectedCrash at a seeded aggregator point
                      (poll/fold/publish/journal boundary)
    pid_reuse         rewrite worker.json to a recycled pid (scenario
                      helper, see simulate_pid_reuse)
    slow_worker       seeded delay inside the publish window (skew)
    corrupt_artifact  scribble bytes into a stored cache artifact AFTER its
                      CRC meta was written — CRC-detectable on read, so the
                      cache must degrade to recompile, never serve it
    node_crash        raise InjectedCrash at a seeded node:* boundary point
                      (the emit/commit window of a node aggregator)
    stream_corrupt    scribble bytes into a committed delta batch AFTER its
                      CRC was embedded — the parent must detect it
                      (StreamCorruption) and count it as stream loss, never
                      fold a torn batch
"""
from __future__ import annotations

import json
import os
import signal
import time
from contextlib import contextmanager

import numpy as np

KINDS = ("torn_publish", "stuck_odd", "corrupt_snapshot", "kill_worker",
         "daemon_crash", "pid_reuse", "slow_worker", "corrupt_artifact",
         "node_crash", "stream_corrupt")

EIO = 5            # injected errno for syscall drills (override value -EIO)


class InjectedCrash(RuntimeError):
    """Deterministic daemon crash at an aggregator boundary point."""


class TornPublish(RuntimeError):
    """A publish abandoned mid-flight: section partially written (or not at
    all, for stuck_odd) and the seqlock left odd — exactly what a worker
    dying inside publish_device leaves behind."""


class FaultHooks:
    """Inert base hook set — production runs on this."""

    def fire(self, point: str, **ctx) -> None:
        pass


_active: FaultHooks | None = None


def install(hooks: FaultHooks) -> None:
    global _active
    _active = hooks


def uninstall() -> None:
    global _active
    _active = None


def active() -> bool:
    return _active is not None


def fire(point: str, **ctx) -> None:
    if _active is not None:
        _active.fire(point, **ctx)


@contextmanager
def plan(p: "FaultPlan"):
    """Install a plan for the duration of a with-block (tests)."""
    install(p)
    try:
        yield p
    finally:
        uninstall()


class FaultPlan(FaultHooks):
    """Seed-driven fault schedule. Same seed + same call sequence =>
    identical injections, so every chaos scenario replays exactly.

    rates      {kind: probability} rolled at that kind's natural point
    kill_at    1-based occurrence of shm:publish_begin at which the calling
               process SIGKILLs itself (workers install this)
    crash_at   1-based occurrence of any agg:* point at which InjectedCrash
               is raised (the daemon-crash schedule)
    crash_who  restrict crash_at / node_crash_at counting to agg:*/node:*
               points fired by this aggregator identity ("global" or a node
               id); None counts every aggregator — the flat behaviour
    node_crash_at  1-based occurrence of any node:* point at which
               InjectedCrash is raised (the node emit/commit window)
    counter_file  path the counters are flushed to before any destructive
               action (SIGKILL survives no in-process assertion)
    """

    def __init__(self, seed: int = 0, rates: dict | None = None, *,
                 kill_at: int | None = None, crash_at: int | None = None,
                 crash_who: str | None = None,
                 node_crash_at: int | None = None,
                 slow_s: float = 0.002, corrupt_nbytes: int = 8,
                 counter_file: str | None = None):
        self.rng = np.random.default_rng(seed)
        self.rates = dict(rates or {})
        unknown = set(self.rates) - set(KINDS)
        if unknown:
            raise ValueError(f"unknown fault kind(s): {sorted(unknown)}")
        self.kill_at = kill_at
        self.crash_at = crash_at
        self.crash_who = crash_who
        self.node_crash_at = node_crash_at
        self.slow_s = slow_s
        self.corrupt_nbytes = corrupt_nbytes
        self.counter_file = counter_file
        self.counters: dict[str, int] = {k: 0 for k in KINDS}
        self.points: dict[str, int] = {}
        self._agg_seen = 0
        self._node_seen = 0
        self._publish_begins = 0

    # ------------------------------------------------------------------ roll
    def _roll(self, kind: str) -> bool:
        rate = self.rates.get(kind, 0.0)
        if rate <= 0.0:
            return False
        # always draw, so the injection sequence depends only on the seed
        # and the call sequence, not on which kinds are enabled elsewhere
        return float(self.rng.random()) < rate

    def _count(self, kind: str) -> None:
        self.counters[kind] += 1

    def flush_counters(self) -> None:
        if self.counter_file:
            tmp = f"{self.counter_file}.tmp"
            with open(tmp, "w") as f:
                json.dump({"counters": self.counters,
                           "points": self.points}, f)
            os.replace(tmp, self.counter_file)

    # ------------------------------------------------------------------ fire
    def fire(self, point: str, **ctx) -> None:
        self.points[point] = self.points.get(point, 0) + 1
        if point.startswith("agg:"):
            if self.crash_who is not None and \
                    ctx.get("who", "global") != self.crash_who:
                return
            self._agg_seen += 1
            if self.crash_at is not None and self._agg_seen == self.crash_at:
                self._count("daemon_crash")
                self.flush_counters()
                raise InjectedCrash(f"{point} (occurrence {self._agg_seen})")
            return
        if point.startswith("node:"):
            if self.crash_who is not None and \
                    ctx.get("who", ctx.get("node")) != self.crash_who:
                return
            self._node_seen += 1
            if self.node_crash_at is not None and \
                    self._node_seen == self.node_crash_at:
                self._count("node_crash")
                self.flush_counters()
                raise InjectedCrash(f"{point} (occurrence {self._node_seen})")
            if point == "node:post_commit" and self._roll("stream_corrupt"):
                self._scribble_file(ctx["path"])
                self._count("stream_corrupt")
                self.flush_counters()
            return
        if point == "cache:post_store":
            if self._roll("corrupt_artifact"):
                self._scribble_file(ctx["path"])
                self._count("corrupt_artifact")
                self.flush_counters()
            return
        if ctx.get("role", "worker") != "worker":
            return      # publish-side fault classes model WORKER failures;
                        # the daemon's own global publish is failed via the
                        # agg:* crash schedule instead
        if point == "shm:publish_begin":
            self._publish_begins += 1
            if self.kill_at is not None and \
                    self._publish_begins == self.kill_at:
                self._count("kill_worker")
                self.flush_counters()
                os.kill(os.getpid(), signal.SIGKILL)
            if self._roll("stuck_odd"):
                self._count("stuck_odd")
                self.flush_counters()
                raise TornPublish("stuck_odd: publish abandoned at the "
                                  "odd flip")
            if self._roll("slow_worker"):
                self._count("slow_worker")
                time.sleep(self.slow_s * (0.5 + float(self.rng.random())))
        elif point == "shm:publish_field":
            if self._roll("torn_publish"):
                self._count("torn_publish")
                self.flush_counters()
                raise TornPublish(
                    f"torn_publish: abandoned before "
                    f"{ctx.get('map')}.{ctx.get('field')}")
        elif point == "shm:publish_commit":
            if self._roll("corrupt_snapshot"):
                self._scribble(ctx["section"])
                self._count("corrupt_snapshot")
                self.flush_counters()

    def _scribble(self, section: dict) -> None:
        """Flip bytes in one random field of one random map — AFTER the CRC
        was computed, so the corruption is CRC-detectable, never a valid
        alternate state."""
        names = sorted(section)
        name = names[int(self.rng.integers(len(names)))]
        fields = sorted(section[name])
        arr = section[name][fields[int(self.rng.integers(len(fields)))]]
        flat = arr.reshape(-1).view(np.uint8)
        n = min(self.corrupt_nbytes, flat.shape[0])
        idx = self.rng.integers(0, flat.shape[0], size=n)
        flat[idx] ^= np.uint8(0xA5)

    def _scribble_file(self, path: str) -> None:
        """Flip bytes in a stored artifact file in place — the CRC in its
        meta sidecar was already written, so the next read must detect it."""
        with open(path, "r+b") as f:
            data = bytearray(f.read())
            if not data:
                return
            n = min(self.corrupt_nbytes, len(data))
            for i in self.rng.integers(0, len(data), size=n):
                data[int(i)] ^= 0xA5
            f.seek(0)
            f.write(bytes(data))


# --------------------------------------------------------------------------
# scenario helpers
# --------------------------------------------------------------------------

def simulate_pid_reuse(root: str, wid: str, imposter_pid: int,
                       p: FaultPlan | None = None) -> None:
    """The pid-reuse hazard: the registered worker died and the OS handed
    its pid to an unrelated process. worker.json keeps the DEAD worker's
    identity (boot id, pid_start) but now names a live pid — exactly the
    state the aggregator must not mistake for a live worker."""
    path = os.path.join(root, "workers", str(wid), "worker.json")
    with open(path) as f:
        info = json.load(f)
    info["pid"] = int(imposter_pid)
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(info, f)
    os.replace(tmp, path)
    if p is not None:
        p.counters["pid_reuse"] += 1


# --------------------------------------------------------------------------
# syscall-override failure drills (the paper's syscall-hook capability
# turned into a self-test of our own fault tolerance)
# --------------------------------------------------------------------------

# Overrides the syscall with -EIO while a shm/map-resident budget lasts:
# each invocation fetch-adds -1 and faults only while the OLD value was
# positive, so exactly `budget` consecutive calls fail, then the real
# implementation runs again — a transient-fault generator with eBPF-visible
# accounting (the drained budget is readable via `map dump`).
EIO_FILTER_ASM = """
    mov r6, 0
    stxdw [r10-8], r6
    lddw r1, map:{map}
    mov r2, r10
    add r2, -8
    mov r3, -1
    call map_fetch_add
    jsle r0, 0, out
    mov r1, -{err}
    call override_return
out:
    mov r0, 0
    exit
"""


def arm_syscall_fault(runtime, sys_name: str, budget: int, *,
                      err: int = EIO, map_name: str = "eio_budget",
                      prog_name: str | None = None) -> int:
    """Load + attach the transient-fault filter on `sys_name` with `budget`
    failures left. Returns the link id (detach to disarm). The budget map
    is created on the runtime if absent; re-arming just refills it."""
    from . import maps as M
    spec = M.MapSpec(map_name, M.MapKind.ARRAY, max_entries=1)
    if map_name not in runtime.host_maps:
        runtime.create_map(spec)
    runtime.host_maps[map_name]["values"][0] = int(budget)
    name = prog_name or f"eio_{sys_name}"
    asm = EIO_FILTER_ASM.format(map=map_name, err=int(err))
    pid = runtime.load_asm(name, asm, [spec], "filter")
    return runtime.attach(pid, f"filter:{sys_name}")


def drill_remaining(runtime, map_name: str = "eio_budget") -> int:
    """Failures left in the drill budget (<= 0 once the drill has drained
    and the syscall path recovered)."""
    return int(runtime.host_maps[map_name]["values"][0])
