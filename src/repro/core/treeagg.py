# Hierarchical fleet aggregation (DESIGN.md §15): worker -> node-local
# aggregator -> global. A NodeAggregator folds its whole worker group in
# ONE batched device pass (the per-kind merge twins were designed
# commutative precisely so they reassociate into a tree), then forwards an
# incremental DELTA BATCH — not a full snapshot — up its seq-numbered
# stream. Every chaos-plane guarantee (CRC'd sections, fold journal,
# health machine, pid-reuse rules) holds at every level:
#
#   * the stream IS the node's write-ahead log: the journal is written only
#     at emit boundaries (accumulators == emit base), and batches past the
#     journaled emit seq survive GC so a restarted node replays its own
#     committed batches into the emit base — forfeit-never-double;
#   * the parent acks only its JOURNALED cursor, so a crashed parent
#     re-reads anything newer idempotently (ringbuf records keep their
#     original (step, wid, pos) tags; replayed positions are skipped);
#   * a node that cold-starts over a stream it already emitted into
#     (journal lost) ADOPTS its workers' snapshots as baselines: nothing
#     re-emits, the gap is forfeited, the parent never double-folds.

from __future__ import annotations

import copy

import numpy as np

from . import faults
from . import maps as M
from . import shm as SH
from .daemon import Aggregator, AggregatorConfig
from .maps import MapKind, MapSpec


def plan_tree(worker_ids, fan_in: int = 4, depth: int = 1) -> dict:
    """Contiguous grouping of sorted worker ids into an aggregation tree.
    Level-0 nodes fold workers; level-l nodes fold level-(l-1) node
    streams; the top level's nodes have parent None (the global root
    consumes them). Levels stop early once a single node covers
    everything — no chains of one-child nodes."""
    wids = sorted(map(str, worker_ids))
    fan_in = max(2, int(fan_in))
    depth = max(1, int(depth))
    levels: list[list[dict]] = []
    nodes: dict[str, dict] = {}
    cur = [{"kind": "worker", "id": w} for w in wids]
    for lvl in range(depth):
        if not cur or (lvl > 0 and len(cur) <= 1):
            break
        groups = [cur[i:i + fan_in] for i in range(0, len(cur), fan_in)]
        level_nodes = []
        for gi, grp in enumerate(groups):
            nd = {"id": f"n{lvl}_{gi}", "level": lvl, "parent": None,
                  "workers": [m["id"] for m in grp
                              if m["kind"] == "worker"],
                  "children": [m["id"] for m in grp if m["kind"] == "node"]}
            for m in grp:
                if m["kind"] == "node":
                    nodes[m["id"]]["parent"] = nd["id"]
            nodes[nd["id"]] = nd
            level_nodes.append(nd)
        levels.append(level_nodes)
        cur = [{"kind": "node", "id": nd["id"]} for nd in level_nodes]
    return {"levels": levels, "nodes": nodes}


class NodeAggregator(Aggregator):
    """One tree level: polls an assigned worker group (and/or child node
    streams), folds the whole group in one batched device reduction, and
    emits delta batches into its own stream for the parent level."""

    def __init__(self, root: str, node_id: str, workers=(), children=(),
                 parent: str | None = None,
                 config: AggregatorConfig | None = None):
        self.node_id = str(node_id)
        self._node_id = self.node_id
        self._assigned = sorted(map(str, workers))
        self.children_ids = sorted(map(str, children))
        self.parent = parent
        self._adopt_admits = False
        super().__init__(root, config=config)
        self._spec_of = {s.name: s for s in self.specs}
        self._emit_seq = 0
        self._journaled_emit_seq = 0
        self._emit_base = self._fresh_emit_base()
        head = self.stream.head()
        meta = None
        if self.config.journal and self._journal_raw:
            meta = self._journal_raw.get("node")
        if meta is not None:
            # journal written only at emit boundaries, so the restored
            # accumulators ARE the emit base at the journaled seq
            s_j = int(meta.get("emit_seq", 0))
            self._emit_base = self._emit_base_from_acc()
            if s_j <= head:
                self._emit_seq = self._journaled_emit_seq = s_j
                # replay OWN committed batches past the journal into the
                # emit base: already-emitted content must never re-emit
                for seq, payload in self.stream.poll(s_j):
                    if payload is not None:
                        self._replay_into_emit_base(payload)
                    self._emit_seq = seq
                self._journaled_emit_seq = s_j
            else:
                # stream wiped under an intact journal: emit only future
                # deltas; the parent's cursor resets along with the stream
                self._emit_seq = self._journaled_emit_seq = head
        elif head > 0:
            # cold start over an already-emitted stream (journal lost):
            # adopt-mode — first snapshots become baselines, no fold
            self._adopt_admits = True
            self._emit_seq = self._journaled_emit_seq = head
        elif self.config.journal:
            # true cold start: seed the seq-0 journal immediately, so a
            # crash inside the very FIRST commit->journal window recovers
            # through WAL replay instead of the content-forfeiting adopt
            # path (batch 1 would otherwise be durable downstream while
            # its workers' later traffic got adopted as baseline)
            SH._atomic_json(self._journal_path(), self._journal_dict())

    # -------------------------------------------------------------- plumbing
    def _make_output(self):
        self.info = SH.register_node(self.root, self.node_id, self.parent,
                                     self._assigned, self.children_ids)
        self.stream = SH.DeltaStream.create(self.root, self.node_id)
        return None

    def _journal_path(self) -> str:
        return SH.os.path.join(SH.node_base(self.root, self.node_id),
                               "journal.json")

    def _journal_dict(self) -> dict:
        d = super()._journal_dict()
        d["node"] = {"id": self.node_id, "emit_seq": int(self._emit_seq)}
        return d

    def _worker_candidates(self) -> list[str]:
        listed = set(SH.list_workers(self.root))
        # dynamic group claim: workers that registered with
        # group == this node id join the fold even if they started after
        # the node (launch/train.py --worker-group). The node.json claim
        # is refreshed IN PLACE (same boot id) so the parent does not
        # mistake the wider claim for a node restart.
        grouped = set(SH.workers_in_group(self.root, self.node_id))
        new = grouped - set(self._assigned)
        if new:
            self._assigned = sorted(set(self._assigned) | grouped)
            self.info = SH.update_node_workers(self.root, self.node_id,
                                               self._assigned)
        return [w for w in self._assigned if w in listed]

    def _journal_ok(self, output_happened: bool) -> bool:
        # only an emit boundary is journal-consistent for a node: the
        # journaled accumulators must equal the emit base
        return output_happened

    def _post_journal(self) -> None:
        self._journaled_emit_seq = self._emit_seq

    def _publish_status(self, status: dict) -> None:
        SH._atomic_json(SH.os.path.join(
            SH.node_base(self.root, self.node_id), "status.json"), status)

    # -------------------------------------------------------------- emit base
    def _fresh_emit_base(self) -> dict:
        return {
            "summary": {n: {f: np.zeros_like(np.asarray(a, np.int64))
                            for f, a in st.items()}
                        for n, st in self.summary.items()},
            "hash": {n: (M._EMPTY_I64, M._EMPTY_I64)
                     for n in self.hash_tbl},
            "rb_heads": {n: {} for n in self.rb_tagged},
            "rb_lost": {n: {} for n in self.rb_tagged},
            "counters": {"merged_updates": 0, "hash_dropped": {},
                         "corrupt": {}, "coalesced": 0},
        }

    def _emit_base_from_acc(self) -> dict:
        return {
            "summary": {n: {f: np.asarray(a, np.int64).copy()
                            for f, a in st.items()}
                        for n, st in self.summary.items()},
            "hash": {n: M.n_hash_content(t)
                     for n, t in self.hash_tbl.items()},
            "rb_heads": {n: dict(d) for n, d in self.rb_heads.items()},
            "rb_lost": {n: dict(d) for n, d in self.rb_lost.items()},
            "counters": {"merged_updates": int(self.merged_updates),
                         "hash_dropped": dict(self.hash_dropped),
                         "corrupt": dict(self.corrupt_skipped),
                         "coalesced": int(self.coalesced_cycles)},
        }

    def _replay_into_emit_base(self, payload: dict) -> None:
        js, arrs = payload["json"], payload["arrays"]
        eb = self._emit_base
        for key, arr in arrs.items():
            p = key.split("/")
            if p[0] == "summary" and p[1] in eb["summary"]:
                with np.errstate(over="ignore"):
                    eb["summary"][p[1]][p[2]] = (
                        eb["summary"][p[1]][p[2]]
                        + np.asarray(arr, np.int64))
        for name in eb["hash"]:
            ak = arrs.get(f"hash/{name}/keys")
            dels = js.get("hash_dels", {}).get(name, [])
            if (ak is None or not np.asarray(ak).size) and not dels:
                continue
            bk, bv = eb["hash"][name]
            d = dict(zip(bk.tolist(), bv.tolist()))
            if ak is not None and np.asarray(ak).size:
                ad = np.asarray(arrs[f"hash/{name}/deltas"], np.int64)
                for k, dv in zip(np.asarray(ak, np.int64).tolist(),
                                 ad.tolist()):
                    d[k] = int(np.int64(d.get(k, 0) + dv))
            for k in dels:
                d.pop(int(k), None)
            ks = np.fromiter(sorted(d), np.int64, len(d))
            eb["hash"][name] = (ks, np.array([d[k] for k in sorted(d)],
                                             np.int64)
                                if d else M._EMPTY_I64)
        for name, per_wid in js.get("rb_meta", {}).items():
            if name in eb["rb_heads"]:
                for wid, meta in per_wid.items():
                    eb["rb_heads"][name][wid] = max(
                        eb["rb_heads"][name].get(wid, 0),
                        int(meta["head"]))
                    eb["rb_lost"][name][wid] = \
                        eb["rb_lost"][name].get(wid, 0) + \
                        int(meta.get("lost_delta", 0))
        c = eb["counters"]
        c["merged_updates"] += int(js.get("updates", 0))
        for name, v in js.get("hash_dropped_delta", {}).items():
            c["hash_dropped"][name] = c["hash_dropped"].get(name, 0) + int(v)
        for wid, v in js.get("corrupt_delta", {}).items():
            c["corrupt"][wid] = c["corrupt"].get(wid, 0) + int(v)
        c["coalesced"] += int(js.get("coalesced_delta", 0))

    # -------------------------------------------------------------- group fold
    def _fold_polled(self, polled: list) -> int:
        """ONE batched device pass folds the whole worker group: summary
        fields stack into (W, *shape) arrays for a single jitted
        reduction; hash deltas extract vectorized, concatenate, coalesce
        per key (device segment-sum) and land in one fetch-add batch.
        Ringbufs stay per-worker tuples (tags are identity)."""
        updates = 0
        folds = []
        for wid, w, snaps, seq_before in polled:
            if w.pop("adopt", False):
                self._adopt_baseline(wid, w, snaps)
                faults.fire("agg:post_merge", wid=wid, who=self._who())
                self._ok_event(wid, advanced=w.get("seq", 0) > seq_before)
            else:
                folds.append((wid, w, snaps, seq_before))
        if not folds:
            return updates
        use_dev = bool(self.config.device_fold)
        group_stacks: dict[str, tuple] = {}
        for spec in self.specs:
            if not M.is_summary_kind(spec.kind):
                continue
            name = spec.name
            fields = M.SUMMARY_FIELDS[spec.kind]
            curs = {f: np.stack(
                [np.asarray(s[name][f], np.int64)
                 for _, _, s, _ in folds]) for f in fields}
            bases = {f: np.stack(
                [np.asarray(w["base"]["summary"][name][f], np.int64)
                 for _, w, _, _ in folds]) for f in fields}
            group_stacks[name] = (fields, curs, bases)
        if group_stacks:
            fold_in = {name: {f: (self.summary[name][f], curs[f], bases[f])
                              for f in fields}
                       for name, (fields, curs, bases)
                       in group_stacks.items()}
            fold_fn = (M.j_group_summary_fold_multi if use_dev
                       else M.n_group_summary_fold_multi)
            new_accs = fold_fn(fold_in)
            for name, (fields, curs, bases) in group_stacks.items():
                with np.errstate(over="ignore"):
                    updates += int(sum(np.abs(curs[f] - bases[f]).sum()
                                       for f in fields))
                # np.array (not asarray): a device result views as
                # read-only, but the accumulator is merged in place by the
                # sequential paths (dead-worker harvest, quarantine)
                self.summary[name] = {f: np.array(new_accs[name][f],
                                                  np.int64)
                                      for f in fields}
                for _, w, s, _ in folds:
                    w["base"]["summary"][name] = s[name]
        for spec in self.specs:
            name = spec.name
            if M.is_summary_kind(spec.kind):
                pass
            elif spec.kind == MapKind.HASH:
                group_k, group_d, all_dels = [], [], []
                for wid, w, s, _ in folds:
                    ck, cv = M.n_hash_content(s[name])
                    base = w["base"]
                    bk, bv = base.setdefault("hash_arr", {}).get(
                        name, (None, None))
                    if bk is None:
                        items = base["hash_items"][name]
                        sk = sorted(items)
                        bk = np.fromiter(sk, np.int64, len(sk))
                        bv = (np.array([items[k] for k in sk], np.int64)
                              if sk else M._EMPTY_I64)
                    ak, ad, dk = M.n_hash_delta_arrays(ck, cv, bk, bv)
                    group_k.append(ak)
                    group_d.append(ad)
                    all_dels.extend(dk.tolist())
                    updates += int(ak.size + dk.size)
                    base["hash_arr"][name] = (ck, cv)
                    base["hash_items"][name] = dict(
                        zip(ck.tolist(), cv.tolist()))
                gk = (np.concatenate(group_k) if group_k
                      else M._EMPTY_I64)
                if gk.size:
                    gd = np.concatenate(group_d)
                    co = M.j_hash_coalesce if use_dev else M.n_hash_coalesce
                    ck2, cd2 = co(gk, gd)
                    M.n_hash_fetch_add_batch(self.hash_tbl[name], ck2, cd2)
                    res_k, _ = M.n_hash_content(self.hash_tbl[name])
                    lost = int(np.count_nonzero(~np.isin(ck2, res_k)))
                    if lost:
                        self.hash_dropped[name] += lost
                for k in all_dels:     # owner-only dels: order-safe
                    M.n_hash_delete(self.hash_tbl[name], int(k))
            elif spec.kind == MapKind.RINGBUF:
                for wid, w, s, _ in folds:
                    updates += self._fold_rb(spec, wid, w["base"], s[name])
        for wid, w, _, seq_before in folds:
            faults.fire("agg:post_merge", wid=wid, who=self._who())
            self._ok_event(wid, advanced=w.get("seq", 0) > seq_before)
        return updates

    # -------------------------------------------------------------- emission
    def _build_batch(self) -> dict:
        """The delta between the accumulators and the emit base, as one
        atomic batch; advances the emit base to the current accumulators."""
        arrs: dict[str, np.ndarray] = {}
        js: dict = {"node_id": self.node_id, "cycle": int(self.cycles)}
        eb = self._emit_base
        for name, acc in self.summary.items():
            for f in M.SUMMARY_FIELDS[
                    self._spec_of[name].kind]:
                a = np.asarray(acc[f], np.int64)
                with np.errstate(over="ignore"):
                    d = a - eb["summary"][name][f]
                if np.any(d):
                    arrs[f"summary/{name}/{f}"] = d
                eb["summary"][name][f] = a.copy()
        hash_dels: dict[str, list] = {}
        for name, tbl in self.hash_tbl.items():
            ck, cv = M.n_hash_content(tbl)
            bk, bv = eb["hash"][name]
            ak, ad, dk = M.n_hash_delta_arrays(ck, cv, bk, bv)
            if ak.size:
                arrs[f"hash/{name}/keys"] = ak
                arrs[f"hash/{name}/deltas"] = ad
            if dk.size:
                hash_dels[name] = [int(k) for k in dk]
            eb["hash"][name] = (ck, cv)
        if hash_dels:
            js["hash_dels"] = hash_dels
        rb_meta: dict[str, dict] = {}
        for name, per_wid in self.rb_tagged.items():
            spec = self._spec_of[name]
            meta: dict[str, dict] = {}
            wids = set(per_wid) | set(self.rb_heads[name]) \
                | set(self.rb_lost[name])
            for wid in sorted(wids):
                buf = per_wid.get(wid, [])
                head = int(self.rb_heads[name].get(wid, 0))
                eh = int(eb["rb_heads"][name].get(wid, 0))
                lost_cum = int(self.rb_lost[name].get(wid, 0))
                lost_prev = int(eb["rb_lost"][name].get(wid, 0))
                if head <= eh and lost_cum <= lost_prev:
                    continue
                # records that fell out of the retention window before we
                # forwarded them: the node fell behind — accounted upward
                start = buf[0][0][2] if buf else head
                gap = max(0, min(start, head) - eh)
                if gap:
                    self.rb_lost[name][wid] = lost_cum = lost_cum + gap
                new = [(t, r) for (t, r) in buf if t[2] >= eh]
                entry: dict = {
                    "head": head,
                    "floor": int(self.rb_step_floor[name].get(wid, 0))}
                if lost_cum > lost_prev:
                    entry["lost_delta"] = lost_cum - lost_prev
                eb["rb_lost"][name][wid] = lost_cum
                if new:
                    arrs[f"rb/{name}/{wid}/steps"] = np.array(
                        [t[0] for t, _ in new], np.int64)
                    arrs[f"rb/{name}/{wid}/pos"] = np.array(
                        [t[2] for t, _ in new], np.int64)
                    arrs[f"rb/{name}/{wid}/recs"] = np.stack(
                        [np.asarray(r, np.int64) for _, r in new])
                meta[wid] = entry
                eb["rb_heads"][name][wid] = head
            if meta:
                rb_meta[name] = meta
        if rb_meta:
            js["rb_meta"] = rb_meta
        c = eb["counters"]
        js["updates"] = max(0, int(self.merged_updates)
                            - c["merged_updates"])
        c["merged_updates"] = int(self.merged_updates)
        hdd = {}
        for name, v in self.hash_dropped.items():
            pv = c["hash_dropped"].get(name, 0)
            if v > pv:
                hdd[name] = int(v - pv)
                c["hash_dropped"][name] = int(v)
        if hdd:
            js["hash_dropped_delta"] = hdd
        cd = {}
        for wid, v in self.corrupt_skipped.items():
            pv = c["corrupt"].get(wid, 0)
            if v > pv:
                cd[wid] = int(v - pv)
                c["corrupt"][wid] = int(v)
        if cd:
            js["corrupt_delta"] = cd
        co = int(self.coalesced_cycles) - c["coalesced"]
        if co > 0:
            js["coalesced_delta"] = co
            c["coalesced"] = int(self.coalesced_cycles)
        # transitive rollup: this level's health map already contains the
        # subtree's entries (child batches fold their health into ours)
        js["health"] = self.health
        sub_alive = [a for st in self._subtree.values()
                     for a in st.get("alive", [])]
        sub_dead = [d for st in self._subtree.values()
                    for d in st.get("dead", [])]
        js["alive"] = sorted(set(self.workers) | set(sub_alive))
        js["dead"] = sorted(set(self.dead) | set(sub_dead))
        if self.stream_lost:
            js["stream_lost"] = dict(self.stream_lost)
        return {"json": js, "arrays": arrs}

    def _membership(self) -> tuple:
        """What the parent knows about this subtree's liveness/health —
        a change here is emit-worthy even with zero data updates (a dead
        worker must propagate up the tree without waiting for traffic)."""
        sub_alive = [a for st in self._subtree.values()
                     for a in st.get("alive", [])]
        sub_dead = [d for st in self._subtree.values()
                    for d in st.get("dead", [])]
        return (tuple(sorted(set(self.workers) | set(sub_alive))),
                tuple(sorted(set(self.dead) | set(sub_dead))),
                tuple(sorted((w, h["state"])
                             for w, h in self.health.items())))

    def _publish_cycle(self, cycle_updates: int) -> bool:
        cfg = self.config
        membership = self._membership()
        publish_now = (bool(cycle_updates) or not self._published
                       or self._publish_lag > 0
                       or membership != getattr(self, "_last_membership",
                                                None))
        if (publish_now and cfg.coalesce_threshold is not None
                and self._published
                and cycle_updates > cfg.coalesce_threshold
                and self._publish_lag + 1 < cfg.publish_max_lag):
            self._publish_lag += 1
            self.coalesced_cycles += 1
            publish_now = False
        if publish_now:
            self._publish_lag = 0
            faults.fire("agg:pre_publish", who=self._who())
            seq = self._emit_seq + 1
            faults.fire("node:pre_emit", node=self.node_id, seq=seq,
                        who=self._who())
            batch = self._build_batch()
            path = self.stream.emit(seq, batch)
            self._emit_seq = seq
            self._published = True
            self._last_membership = membership
            faults.fire("node:post_commit", node=self.node_id, seq=seq,
                        path=path, who=self._who())
            faults.fire("agg:post_publish", who=self._who())
            # GC is bounded by BOTH cursors: the parent's ack (it folded
            # and journaled the batch) and our own journaled emit seq (the
            # batch is still our recovery WAL until the journal covers it)
            self.stream.gc(self._journaled_emit_seq
                           if cfg.journal else None)
        return publish_now


class TreeAggregator:
    """Drives a whole aggregation tree in one process (tests, benchmarks,
    and the CLI's --tree mode; production fleets run each NodeAggregator
    in its own process via `node run`). Nodes poll leaves-first so one
    tree cycle moves every worker delta all the way to the root view."""

    def __init__(self, root: str, fan_in: int = 4, depth: int = 1,
                 config: AggregatorConfig | None = None,
                 worker_ids=None):
        self.root = root
        self.config = config or AggregatorConfig()
        wids = sorted(worker_ids if worker_ids is not None
                      else SH.list_workers(root))
        self.plan = plan_tree(wids, fan_in=fan_in, depth=depth)
        self.node_aggs: list[NodeAggregator] = []
        for level in self.plan["levels"]:
            for nd in level:
                self.node_aggs.append(NodeAggregator(
                    root, nd["id"], workers=nd["workers"],
                    children=nd["children"], parent=nd["parent"],
                    config=copy.copy(self.config)))
        self.root_agg = Aggregator(root, config=copy.copy(self.config))

    @property
    def view(self):
        return self.root_agg.view

    def poll_once(self) -> dict:
        for na in self.node_aggs:
            na.poll_once()
        return self.root_agg.poll_once()

    def global_states(self) -> dict:
        return self.root_agg.global_states()

    def loop(self, watch: float | None = None, once: bool = False,
             out=None) -> None:
        import sys
        import time
        out = sys.stdout if out is None else out
        watch = self.config.poll_interval if watch is None else watch
        while True:
            status = self.poll_once()
            nodes = status.get("nodes", {})
            print(f"=== {time.strftime('%H:%M:%S')} tree cycle "
                  f"{status['cycles']} nodes={sorted(nodes)} "
                  f"alive={status['alive']} dead={status['dead']} "
                  f"merged={status['merged_updates']}", file=out)
            if once:
                break
            time.sleep(watch)


__all__ = ["plan_tree", "NodeAggregator", "TreeAggregator", "MapSpec"]
