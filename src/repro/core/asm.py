"""Tiny eBPF assembler — the `clang -target bpf` stand-in.

Syntax (one insn per line, `;` comments, `label:` lines):

    mov   r6, 0            ; alu64 imm
    add32 r6, r7           ; alu32 reg
    lddw  r1, map:counts   ; 64-bit imm w/ symbolic map relocation
    ldxdw r2, [r1+8]       ; loads/stores: b/h/w/dw
    stxdw [r10-8], r2
    jeq   r2, 0, out       ; cond jumps take a label
    call  map_fetch_add    ; helper by name or id
    exit
    out:
    exit

`lddw rX, map:NAME` emits a relocation entry ("CO-RE-lite"): the loader
patches the imm64 with the bound map fd at load time.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from . import isa
from .isa import Insn


class AsmError(ValueError):
    pass


@dataclass
class Assembled:
    insns: list[Insn]
    # relocations: insn index -> symbolic map name (patched by the loader)
    map_relocs: dict[int, str] = field(default_factory=dict)
    # insn index -> 0-based source line number in the assembled text; lets
    # the loader map textual `ctx:FIELD` substitutions back onto the insn
    # they landed in (CO-RE ctx relocation records)
    src_lines: list[int] = field(default_factory=list)


_ALU_OPS = {
    "add": isa.BPF_ADD, "sub": isa.BPF_SUB, "mul": isa.BPF_MUL,
    "div": isa.BPF_DIV, "or": isa.BPF_OR, "and": isa.BPF_AND,
    "lsh": isa.BPF_LSH, "rsh": isa.BPF_RSH, "mod": isa.BPF_MOD,
    "xor": isa.BPF_XOR, "mov": isa.BPF_MOV, "arsh": isa.BPF_ARSH,
}
_JMP_OPS = {
    "jeq": isa.BPF_JEQ, "jgt": isa.BPF_JGT, "jge": isa.BPF_JGE,
    "jset": isa.BPF_JSET, "jne": isa.BPF_JNE, "jsgt": isa.BPF_JSGT,
    "jsge": isa.BPF_JSGE, "jlt": isa.BPF_JLT, "jle": isa.BPF_JLE,
    "jslt": isa.BPF_JSLT, "jsle": isa.BPF_JSLE,
}
_SIZES = {"b": isa.BPF_B, "h": isa.BPF_H, "w": isa.BPF_W, "dw": isa.BPF_DW}


def _reg(tok: str) -> int:
    tok = tok.strip().rstrip(",")
    if not tok.startswith("r") or not tok[1:].isdigit():
        raise AsmError(f"expected register, got {tok!r}")
    n = int(tok[1:])
    if not 0 <= n <= 10:
        raise AsmError(f"bad register r{n}")
    return n


def _int(tok: str) -> int:
    tok = tok.strip().rstrip(",")
    try:
        return int(tok, 0)
    except ValueError as e:
        raise AsmError(f"expected integer, got {tok!r}") from e


def _mem(tok: str) -> tuple[int, int]:
    """parse `[rX+off]` / `[rX-off]` / `[rX]` -> (reg, off)"""
    tok = tok.strip().rstrip(",")
    if not (tok.startswith("[") and tok.endswith("]")):
        raise AsmError(f"expected [rX+off], got {tok!r}")
    body = tok[1:-1].replace(" ", "")
    for sep in ("+", "-"):
        if sep in body[1:]:
            i = body.index(sep, 1)
            off = int(body[i:], 0)
            return _reg(body[:i]), off
    return _reg(body), 0


def assemble(text: str, helper_ids: dict[str, int] | None = None) -> Assembled:
    from .helpers import HELPER_IDS  # late import to avoid cycle
    helper_ids = {**HELPER_IDS, **(helper_ids or {})}

    lines: list[tuple[int, str, list[str]]] = []
    for lineno, raw in enumerate(text.splitlines()):
        line = raw.split(";")[0].split("//")[0].strip()
        if not line:
            continue
        parts = line.replace(",", " , ").split()
        parts = [p for p in parts if p != ","]
        lines.append((lineno, line, parts))

    # pass 1: label -> slot index
    labels: dict[str, int] = {}
    slot = 0
    for _, line, parts in lines:
        if len(parts) == 1 and parts[0].endswith(":"):
            name = parts[0][:-1]
            if name in labels:
                raise AsmError(f"duplicate label {name}")
            labels[name] = slot
            continue
        slot += 2 if parts[0] == "lddw" else 1

    # pass 2: emit
    out = Assembled(insns=[])
    slot = 0
    for lineno, line, parts in lines:
        if len(parts) == 1 and parts[0].endswith(":"):
            continue
        mn = parts[0].lower()
        args = parts[1:]
        try:
            ins, reloc = _emit(mn, args, labels, slot, helper_ids)
        except AsmError as e:
            raise AsmError(f"{e} in line: {line!r}") from None
        if reloc is not None:
            out.map_relocs[len(out.insns)] = reloc
        out.insns.append(ins)
        out.src_lines.append(lineno)
        slot += 2 if ins.is_lddw() else 1
    return out


def _emit(mn: str, a: list[str], labels: dict[str, int], slot: int,
          helper_ids: dict[str, int]) -> tuple[Insn, str | None]:
    def label_off(tok: str) -> int:
        tok = tok.strip()
        if tok in labels:
            return labels[tok] - slot - 1
        return _int(tok)

    if mn == "lddw":
        dst = _reg(a[0])
        tok = a[1].strip()
        if tok.startswith("map:"):
            return Insn(isa.BPF_LD | isa.BPF_IMM | isa.BPF_DW, dst=dst,
                        imm=0, imm64=0), tok[4:]
        return Insn(isa.BPF_LD | isa.BPF_IMM | isa.BPF_DW, dst=dst,
                    imm=0, imm64=isa.u64(_int(tok))), None

    if mn in ("exit", "ret"):
        return Insn(isa.BPF_JMP | isa.BPF_EXIT), None

    if mn == "call":
        tok = a[0].strip()
        hid = helper_ids.get(tok)
        if hid is None:
            hid = _int(tok)
        return Insn(isa.BPF_JMP | isa.BPF_CALL, imm=hid), None

    if mn == "ja":
        return Insn(isa.BPF_JMP | isa.BPF_JA, off=label_off(a[0])), None

    w32 = mn.endswith("32")
    base = mn[:-2] if w32 else mn

    if base in ("neg",):
        cls = isa.BPF_ALU if w32 else isa.BPF_ALU64
        return Insn(cls | isa.BPF_NEG, dst=_reg(a[0])), None

    if base in _ALU_OPS:
        cls = isa.BPF_ALU if w32 else isa.BPF_ALU64
        dst = _reg(a[0])
        srctok = a[1].strip()
        if srctok.startswith("r") and srctok[1:].isdigit():
            return Insn(cls | _ALU_OPS[base] | isa.BPF_X, dst=dst,
                        src=_reg(srctok)), None
        return Insn(cls | _ALU_OPS[base] | isa.BPF_K, dst=dst,
                    imm=_int(srctok)), None

    if base in _JMP_OPS:
        cls = isa.BPF_JMP32 if w32 else isa.BPF_JMP
        dst = _reg(a[0])
        srctok = a[1].strip()
        off = label_off(a[2])
        if srctok.startswith("r") and srctok[1:].isdigit():
            return Insn(cls | _JMP_OPS[base] | isa.BPF_X, dst=dst,
                        src=_reg(srctok), off=off), None
        return Insn(cls | _JMP_OPS[base] | isa.BPF_K, dst=dst,
                    imm=_int(srctok), off=off), None

    if base.startswith("ldx"):
        sz = _SIZES[base[3:]]
        dst = _reg(a[0])
        src, off = _mem(a[1])
        return Insn(isa.BPF_LDX | isa.BPF_MEM | sz, dst=dst, src=src,
                    off=off), None

    if base.startswith("stx"):
        sz = _SIZES[base[3:]]
        dst, off = _mem(a[0])
        src = _reg(a[1])
        return Insn(isa.BPF_STX | isa.BPF_MEM | sz, dst=dst, src=src,
                    off=off), None

    if base.startswith("st"):
        sz = _SIZES[base[2:]]
        dst, off = _mem(a[0])
        return Insn(isa.BPF_ST | isa.BPF_MEM | sz, dst=dst, off=off,
                    imm=_int(a[1])), None

    raise AsmError(f"unknown mnemonic {mn!r}")
