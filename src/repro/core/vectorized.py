"""Vectorized + fused probe execution — the TPU-native beyond-paper
optimization.

The paper JITs each probe invocation to straight-line native code; on a
vector machine the equivalent is executing probe programs over a whole
event batch as tensor ops. For DAG programs whose map side effects are
commutative (fetch-add family), the sequential lax.scan over events
(jit.run_over_events) collapses to:

  1. a SHADOW pass: vmap the T1 if-converted dataflow over event rows with
     side-effect helpers replaced by recorders -> per-call-site batched
     (pred, args) tensors. Event validity is folded into the entry-block
     predicate, so recorded preds already carry it;
  2. an APPLY pass: one scatter-add / segment-sum / histogram-add /
     batched-ringbuf op per call site over the whole batch.

`run_fused_vector` goes one step further (the fused pipeline, DESIGN.md §2):
ALL vector-safe programs across ALL (site, kind) attachments share ONE
shadow vmap pass over the tape — each program's validity mask is its entry
predicate — and side effects apply once per call site. The probe stage then
costs O(events + call_sites) instead of O(programs x events x total_state).

Cost drops from O(B) sequential program bodies to O(call_sites) vector ops.
Semantic deltas vs scan mode (checked by is_vector_safe / documented):
  * fetch-add return values must be dead (we verify this statically);
  * HASH-map fetch_add is batched via sort-by-key + segment_sum + a
    per-unique-key probe/insert pass (maps.j_hash_fetch_add_batch) —
    end states are bit-identical to the sequential twin;
  * ringbuf rows keep batch order; override takes the first valid lane;
  * trace_printk is counted, not stored.
End map states are bit-identical to scan mode for safe programs (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import isa, jit as J, maps as M
from .isa import BPF_JMP, BPF_JMP32, OP_MASK
from .verifier import CallAnn, VerifiedProgram

I64 = jnp.int64

_PURE = {"ktime_get_ns", "get_smp_processor_id", "get_current_pid_tgid",
         "log2"}
_EFFECT = {"map_fetch_add", "percpu_fetch_add", "hist_add", "ringbuf_output",
           "override_return", "trace_printk"}


def _ringbuf_emit_batch_fallback(data, head, rows, valid):
    """Self-contained lax.scan twin of kernels.ref.ringbuf_emit_batch —
    the EXPLICIT fallback when the optional kernels package is absent
    (pinned by tests/test_kernels_fallback.py)."""
    cap = data.shape[0]

    def one(carry, ev):
        d, h = carry
        row, ok = ev
        slot = (h[0] % cap).astype(jnp.int32)
        d = d.at[slot].set(jnp.where(ok, row, d[slot]))
        h = h.at[0].add(jnp.where(ok, jnp.int64(1), jnp.int64(0)))
        return (d, h), jnp.int64(0)

    (d, h), _ = jax.lax.scan(one, (data, head), (rows, valid))
    return d, h


def _ringbuf_emit_batch(data, head, rows, valid):
    try:
        from repro.kernels import ref as KREF
    except ImportError:
        return _ringbuf_emit_batch_fallback(data, head, rows, valid)
    return KREF.ringbuf_emit_batch(data, head, rows, valid)


def _r0_dead_after(vprog: VerifiedProgram, call_pc: int) -> bool:
    """Conservative: r0 (the fetch-add result) must be overwritten before any
    read, scanning forward in instruction order (over-approximates across
    branches; good enough for probe programs)."""
    for pc in range(call_pc + 1, len(vprog.insns)):
        ins = vprog.insns[pc]
        cls = ins.cls
        if cls in (isa.BPF_ALU, isa.BPF_ALU64):
            op = ins.op & OP_MASK
            reads_dst = op != isa.BPF_MOV
            if ins.dst == 0 and not reads_dst:
                return True                      # overwritten
            if (ins.dst == 0 and reads_dst) or \
               (ins.op & isa.SRC_MASK and ins.src == 0):
                return False
        elif cls == isa.BPF_LDX:
            if ins.src == 0:
                return False
            if ins.dst == 0:
                return True
        elif cls in (isa.BPF_STX,):
            if ins.src == 0 or ins.dst == 0:
                return False
        elif cls in (BPF_JMP, BPF_JMP32):
            op = ins.op & OP_MASK
            if op == isa.BPF_CALL:
                return True                      # call clobbers r0
            if op == isa.BPF_EXIT:
                return False                     # r0 is the return value
            if ins.dst == 0 or (ins.op & isa.SRC_MASK and ins.src == 0):
                return False
        elif ins.is_lddw() and ins.dst == 0:
            return True
    return True


def is_vector_safe(vprog: VerifiedProgram) -> bool:
    """True iff the program can run on the batched (shadow+apply) path.
    ARRAY *and* HASH fetch_add are both batchable (hash via the sorted
    segment-scatter in maps.j_hash_fetch_add_batch); the remaining
    requirements are an acyclic CFG, dead fetch-add results, and at most
    ONE ringbuf_output site per ring — effects apply per call SITE, so a
    second site emitting to the same ring would land its whole batch
    after the first site's instead of interleaving per event (found by
    the fuzz harness, pinned in tests/corpus/ringbuf_two_sites.json)."""
    if vprog.tier != "dag":
        return False
    rb_fds: set[int] = set()
    for pc, ann in vprog.anns.items():
        if not isinstance(ann, CallAnn):
            continue
        if ann.name in _PURE:
            continue
        if ann.name not in _EFFECT:
            return False
        if ann.name in ("map_fetch_add", "percpu_fetch_add"):
            if not _r0_dead_after(vprog, pc):
                return False
        if ann.name == "ringbuf_output":
            fd = ann.statics[0]
            if fd in rb_fds:
                return False
            rb_fds.add(fd)
    return True


# --------------------------------------------------------------------------
# shadow pass: record (pred, args) per call site instead of executing
# --------------------------------------------------------------------------

def _make_shadow_cb(meta: list):
    """Build the helper callback for the shadow pass. Effectful helpers
    append a (pred, *dynamic_args) record; `meta` collects the matching
    static info (program, helper name, statics) — vmap traces the program
    once, so meta sees exactly one append per call site."""

    def shadow_cb(vp, ann, m, ms, aux_l, pred):
        zero = jnp.int64(0)
        name = ann.name
        if name == "ktime_get_ns":
            return aux_l["time_ns"], ms, aux_l
        if name == "get_smp_processor_id":
            return aux_l["cpu"], ms, aux_l
        if name == "get_current_pid_tgid":
            return aux_l["pid"], ms, aux_l
        if name == "log2":
            return M.jnp_log2_bin(m.regs[1]).astype(I64), ms, aux_l
        # effectful: record (pred, dynamic args); statics into meta
        if name == "map_fetch_add":
            rec = (pred, J._stack_load(m.stack, ann.statics[1], 8), m.regs[3])
        elif name == "percpu_fetch_add":
            rec = (pred, J._stack_load(m.stack, ann.statics[1], 8), m.regs[3])
        elif name == "hist_add":
            rec = (pred, m.regs[2])
        elif name == "ringbuf_output":
            fd, doff, size, _ = ann.statics
            w = vp.map_specs[fd].rec_width
            lanes = [J._stack_load(m.stack, doff + 8 * i, 8)
                     for i in range(size // 8)]
            lanes += [zero] * (w - len(lanes))
            rec = (pred, jnp.stack(lanes))
        elif name == "override_return":
            rec = (pred, m.regs[1])
        elif name == "trace_printk":
            rec = (pred,)
        else:  # pragma: no cover - guarded by is_vector_safe
            raise AssertionError(name)
        ms["__recs__"].append(rec)
        meta.append((vp, name, ann.statics))
        return zero, ms, aux_l

    return shadow_cb


# --------------------------------------------------------------------------
# apply pass: one batched op per call site
# --------------------------------------------------------------------------

def _apply_site(vp, name, statics, rec, maps_state, aux):
    """Apply one call site's batched side effect. rec[0] is the per-lane
    predicate with event validity already folded in (entry_pred)."""
    ok = rec[0]
    if name == "map_fetch_add":
        fd = statics[0]
        sp = vp.map_specs[fd]
        st = maps_state[sp.name]
        keys, delta = rec[1], rec[2]
        if sp.kind == M.MapKind.HASH:
            new = M.j_hash_fetch_add_batch(st, keys, delta, ok)
            maps_state = {**maps_state, sp.name: new}
        else:
            n = sp.max_entries
            inb = ok & (keys >= 0) & (keys < n)
            idx = jnp.clip(keys, 0, n - 1).astype(jnp.int32)
            vals = st["values"].at[idx].add(
                jnp.where(inb, delta, jnp.int64(0)))
            maps_state = {**maps_state, sp.name: {"values": vals}}
    elif name == "percpu_fetch_add":
        fd = statics[0]
        sp = vp.map_specs[fd]
        st = maps_state[sp.name]
        keys, delta = rec[1], rec[2]
        n = sp.max_entries
        inb = ok & (keys >= 0) & (keys < n)
        idx = jnp.clip(keys, 0, n - 1).astype(jnp.int32)
        sh = jnp.clip(aux["cpu"], 0, sp.num_shards - 1).astype(jnp.int32)
        vals = st["values"].at[sh, idx].add(
            jnp.where(inb, delta, jnp.int64(0)))
        maps_state = {**maps_state, sp.name: {"values": vals}}
    elif name == "hist_add":
        fd = statics[0]
        sp = vp.map_specs[fd]
        st = maps_state[sp.name]
        v = rec[1]
        # bin = min(63, bit_length(v)) for v > 0: binary search over the
        # sorted powers of two (exact, O(B log 63) — no [B, 63] matrix)
        pow2 = jnp.asarray(M._POW2)
        bl = jnp.searchsorted(pow2, v, side="right").astype(jnp.int32)
        bins_idx = jnp.where(v <= 0, 0, jnp.minimum(63, bl))
        bins = st["bins"].at[bins_idx].add(
            jnp.where(ok, jnp.int64(1), jnp.int64(0)))
        maps_state = {**maps_state, sp.name: {"bins": bins}}
    elif name == "ringbuf_output":
        fd = statics[0]
        sp = vp.map_specs[fd]
        st = maps_state[sp.name]
        head0 = st["head"][0]
        d, h = _ringbuf_emit_batch(st["data"], st["head"], rec[1], ok)
        # dropped accounting, batch form: the i-th valid record lands at
        # monotonic position head0 + rank(i); it laps (overwrites an unread
        # record) when that position >= capacity.
        cap = sp.max_entries
        rank = jnp.cumsum(ok.astype(jnp.int64)) - 1
        lapped = jnp.sum((ok & (head0 + rank >= cap)).astype(jnp.int64))
        dropped = st["dropped"].at[0].add(lapped)
        maps_state = {**maps_state,
                      sp.name: {"data": d, "head": h, "dropped": dropped}}
    elif name == "override_return":
        any_ok = jnp.any(ok)
        first = jnp.argmax(ok.astype(jnp.int32))
        aux = {**aux,
               "override_set": jnp.where(any_ok, jnp.int64(1),
                                         aux["override_set"]),
               "override_val": jnp.where(any_ok, rec[1][first],
                                         aux["override_val"])}
    elif name == "trace_printk":
        aux = {**aux, "printk_n": aux["printk_n"] +
               jnp.sum(ok.astype(I64))}
    return maps_state, aux


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------

def run_vectorized(vprog: VerifiedProgram, event_rows, valid, maps_state,
                   aux):
    """Single-program batched execution (seed 'vectorized' mode).
    event_rows: i64[B, 16]; valid: bool[B] folded into the entry pred."""
    meta: list[tuple] = []
    t1 = J.compile_t1(vprog, helper_cb=_make_shadow_cb(meta))

    def shadow(row, ok):
        ms = {"__recs__": []}
        t1(row, ms, aux, entry_pred=ok)
        return tuple(ms["__recs__"])

    recs = jax.vmap(shadow)(event_rows, valid)
    # meta collected len(recs) times? no: vmap traces once -> one append/site
    assert len(meta) == len(recs)
    for (vp, name, statics), rec in zip(meta, recs):
        maps_state, aux = _apply_site(vp, name, statics, rec, maps_state,
                                      aux)
    return maps_state, aux


def run_fused_vector(entries, event_rows, maps_state, aux):
    """The fused pipeline's vector lane: ONE vmap pass over the event tape
    executing every vector-safe program of every attachment, then one
    batched apply per call site.

    entries: [(site_id, kind, vprog)] in attachment order — apply order
    matches the seed scan mode's sorted-attachment iteration, so per-map
    streams (ringbuf record order, override first-lane) are preserved."""
    meta: list[tuple] = []
    cb = _make_shadow_cb(meta)
    t1s = [(sid, kind, J.compile_t1(vp, helper_cb=cb))
           for sid, kind, vp in entries]

    def shadow(row):
        ms = {"__recs__": []}
        for sid, kind, t1 in t1s:
            pred = (row[0] == jnp.int64(sid)) & (row[1] == jnp.int64(kind))
            t1(row, ms, aux, entry_pred=pred)
        return tuple(ms["__recs__"])

    recs = jax.vmap(shadow)(event_rows)
    assert len(meta) == len(recs)
    for (vp, name, statics), rec in zip(meta, recs):
        maps_state, aux = _apply_site(vp, name, statics, rec, maps_state,
                                      aux)
    return maps_state, aux
