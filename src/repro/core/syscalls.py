"""Framework-syscall tracepoints — the syscall-hook (zpoline) analogue.

Every host-side runtime service (data fetch, checkpoint save, logging,
serve admission, collective-group launch, ...) is routed through a
SyscallTable. Attached `tracepoint` programs observe sys_enter/sys_exit;
attached `filter` programs on sys_enter may call override_return(v) to SKIP
the real implementation and force a return code — the paper's programmatic
syscall filtering (C2), e.g. blocking checkpoints or dropping bad batches.

Host programs execute on the numpy map twins (optionally shm-backed so the
daemon sees updates live), via the reference interpreter — host code is
not latency-critical, and this keeps device/host semantics identical.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from . import vm
from .maps import MapSpec

# stable syscall numbering (the framework's "syscall table")
SYSCALL_IDS = {
    "sys_data_fetch": 1,
    "sys_checkpoint_save": 2,
    "sys_checkpoint_restore": 3,
    "sys_log": 4,
    "sys_serve_admit": 5,
    "sys_serve_evict": 6,
    "sys_collective_launch": 7,
    "sys_shm_publish": 8,
    "sys_step_begin": 9,
    "sys_step_end": 10,
    "sys_heartbeat": 11,
    "sys_elastic_resize": 12,
}


def _signed64(v: int) -> int:
    """The VM keeps registers as u64; override values round-trip through
    that, so a filter injecting -EIO hands back 2^64-5. Interpret override
    return codes as signed 64-bit, like the kernel does."""
    v &= (1 << 64) - 1
    return v - (1 << 64) if v >= (1 << 63) else v


@dataclass
class SyscallResult:
    value: object          # real impl return (None if overridden/skipped)
    ret_code: int          # integer code seen by exit probes
    overridden: bool
    override_val: int = 0

    @property
    def fault(self) -> bool:
        """Convention for callers: a NEGATIVE override return code is an
        injected transient fault (-errno) — retry with bounds, then
        degrade. A non-negative override is a policy veto — skip
        immediately, no retry."""
        return self.overridden and self.ret_code < 0


@dataclass
class _Hook:
    prog_name: str
    insns: list
    map_specs: list[MapSpec]
    phase: str             # 'enter' | 'exit'


class SyscallTable:
    """Host syscall dispatch with eBPF enter/exit hooks."""

    def __init__(self, host_maps: dict, map_specs: list[MapSpec],
                 pid: int = 0):
        self.host_maps = host_maps            # numpy twins (possibly shm)
        self.map_specs = map_specs
        self.hooks: dict[tuple[str, str], list[_Hook]] = {}
        self.pid = pid
        self.counts: dict[str, int] = {}

    def attach(self, sys_name: str, phase: str, prog_name: str, insns,
               map_specs):
        if sys_name not in SYSCALL_IDS:
            raise KeyError(f"unknown syscall {sys_name}")
        if phase not in ("enter", "exit"):
            raise ValueError(phase)
        self.hooks.setdefault((sys_name, phase), []).append(
            _Hook(prog_name, insns, map_specs, phase))

    def detach(self, sys_name: str, phase: str, prog_name: str):
        key = (sys_name, phase)
        self.hooks[key] = [h for h in self.hooks.get(key, [])
                           if h.prog_name != prog_name]

    def _run_hooks(self, key, ctx_words) -> vm.Aux | None:
        """Run hooks; returns the first aux with override set (if any)."""
        override = None
        for h in self.hooks.get(key, []):
            aux = vm.Aux(time_ns=time.monotonic_ns(), cpu=0, pid=self.pid)
            vm.run(h.insns, vm.pack_ctx(ctx_words), h.map_specs,
                   self.host_maps, aux)
            if aux.override_set and override is None:
                override = aux
        return override

    def invoke(self, sys_name: str, args: list[int], impl,
               ret_code_of=lambda v: 0) -> SyscallResult:
        """args: up to 5 ints (the eBPF ctx view of the call)."""
        sid = SYSCALL_IDS[sys_name]
        self.counts[sys_name] = self.counts.get(sys_name, 0) + 1
        a = (list(args) + [0] * 5)[:5]
        ctx = [sid, *a, 0]  # ret slot = 0 on enter

        ov = self._run_hooks((sys_name, "enter"), ctx)
        if ov is not None:
            rc = _signed64(ov.override_val)
            self._run_hooks((sys_name, "exit"), [sid, *a, rc])
            return SyscallResult(value=None, ret_code=rc, overridden=True,
                                 override_val=rc)

        value = impl()
        rc = int(ret_code_of(value))
        self._run_hooks((sys_name, "exit"), [sid, *a, rc])
        return SyscallResult(value=value, ret_code=rc, overridden=False)
