"""eBPF maps, bpftime-style: shared state between probe programs, the host
control plane, and (here) the compiled XLA step function.

Each map kind has two twin implementations with IDENTICAL semantics:
  * jnp ops (predicated, functional) — used by the bytecode->JAX JIT so map
    updates fuse into the step graph;
  * numpy ops (in-place) — used by the reference interpreter (the "ubpf"
    oracle), by host-side ("kernel-mode") probes, and by the shm daemon.

Kinds (subset of Linux's bpf_map_type):
  ARRAY         values i64[N], key = index
  HASH          fixed-capacity open-addressing (linear probe), i64 key/value
  PERCPU_ARRAY  values i64[S, N], one row per device shard
  LOG2HIST      64 power-of-two latency-style bins (bcc's log2 histogram)
  RINGBUF       i64[cap, width] records + monotonic head + dropped counter

Values are 64-bit integers, faithful to eBPF's word size. map_lookup returns
the value (not a pointer) — see DESIGN.md §7 deviation 2.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

_HASH_MULT = 0x9E3779B97F4A7C15  # splitmix64 golden-ratio constant
_U64 = (1 << 64) - 1


class MapKind(enum.Enum):
    ARRAY = "array"
    HASH = "hash"
    PERCPU_ARRAY = "percpu_array"
    LOG2HIST = "log2hist"
    RINGBUF = "ringbuf"


@dataclass(frozen=True)
class MapSpec:
    name: str
    kind: MapKind
    max_entries: int = 64
    # RINGBUF record width in i64 lanes; PERCPU shard count.
    rec_width: int = 4
    num_shards: int = 1
    flags: dict = field(default_factory=dict)

    def validate(self) -> None:
        if self.max_entries <= 0:
            raise ValueError(f"map {self.name}: max_entries must be > 0")
        if self.kind == MapKind.RINGBUF and self.rec_width <= 0:
            raise ValueError(f"map {self.name}: rec_width must be > 0")


# --------------------------------------------------------------------------
# state construction
# --------------------------------------------------------------------------

def _zeros(shape, np_mod):
    return np_mod.zeros(shape, dtype=np_mod.int64)


def init_state(spec: MapSpec, np_mod=jnp):
    """Build the (j)np pytree for one map."""
    n = spec.max_entries
    if spec.kind == MapKind.ARRAY:
        return {"values": _zeros((n,), np_mod)}
    if spec.kind == MapKind.HASH:
        return {"keys": _zeros((n,), np_mod),
                "used": _zeros((n,), np_mod),
                "values": _zeros((n,), np_mod)}
    if spec.kind == MapKind.PERCPU_ARRAY:
        return {"values": _zeros((spec.num_shards, n), np_mod)}
    if spec.kind == MapKind.LOG2HIST:
        return {"bins": _zeros((64,), np_mod)}
    if spec.kind == MapKind.RINGBUF:
        return {"data": _zeros((n, spec.rec_width), np_mod),
                "head": _zeros((1,), np_mod),
                "dropped": _zeros((1,), np_mod)}
    raise ValueError(spec.kind)


def init_states(specs: list[MapSpec], np_mod=jnp) -> dict:
    for s in specs:
        s.validate()
    return {s.name: init_state(s, np_mod) for s in specs}


def state_nbytes(specs: list[MapSpec]) -> int:
    st = init_states(specs, np)
    return sum(a.nbytes for m in st.values() for a in m.values())


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------

def _np_hash_idx(key: int, n: int) -> int:
    h = (int(key) * _HASH_MULT) & _U64
    return int((h >> 33) % n)


def _jnp_hash_idx(key, n: int):
    h = key.astype(jnp.uint64) * jnp.uint64(_HASH_MULT)
    return (h >> jnp.uint64(33)) % jnp.uint64(n)


def np_log2_bin(v: int) -> int:
    v = int(v)
    if v <= 0:
        return 0
    return min(63, v.bit_length())


_POW2 = np.array([1 << k for k in range(63)], dtype=np.int64)


def jnp_log2_bin(v):
    return jnp.where(v <= 0, 0,
                     jnp.minimum(63, jnp.sum((v >= _POW2).astype(jnp.int32))))


# --------------------------------------------------------------------------
# JAX ops (functional, predicated). `pred` gates the side effect so the JIT
# can if-convert branches; lookups return 0 when not found / out of bounds.
# All take and return the per-map pytree.
# --------------------------------------------------------------------------

def _as_i64(x):
    return jnp.asarray(x, dtype=jnp.int64)


def j_array_lookup(st, key, pred):
    n = st["values"].shape[0]
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    return jnp.where(ok, st["values"][idx], jnp.int64(0))


def j_array_update(st, key, value, pred):
    n = st["values"].shape[0]
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    new = st["values"].at[idx].set(jnp.where(ok, value, st["values"][idx]))
    return {"values": new}


def j_array_fetch_add(st, key, delta, pred):
    n = st["values"].shape[0]
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    old = jnp.where(ok, st["values"][idx], jnp.int64(0))
    new = st["values"].at[idx].add(jnp.where(ok, delta, jnp.int64(0)))
    return {"values": new}, old


def j_percpu_lookup(st, shard, key, pred):
    s, n = st["values"].shape
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    sh = jnp.clip(shard, 0, s - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    return jnp.where(ok, st["values"][sh, idx], jnp.int64(0))


def j_percpu_fetch_add(st, shard, key, delta, pred):
    s, n = st["values"].shape
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    sh = jnp.clip(shard, 0, s - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    old = jnp.where(ok, st["values"][sh, idx], jnp.int64(0))
    new = st["values"].at[sh, idx].add(jnp.where(ok, delta, jnp.int64(0)))
    return {"values": new}, old


def _j_hash_find(st, key):
    """Return (slot, found, free_slot, has_free) via full linear probe.

    Scans the whole table from the hash position — identical to the numpy
    twin. Vectorized (no data-dependent loop) so it is vmap/scan friendly:
    capacity is small (probe maps, not model state).
    """
    n = st["keys"].shape[0]
    start = _jnp_hash_idx(_as_i64(key), n).astype(jnp.int32)
    order = (start + jnp.arange(n, dtype=jnp.int32)) % n          # probe seq
    used = st["used"][order] != 0
    match = used & (st["keys"][order] == key)
    free = ~used
    # first index in probe order where match / free occurs
    big = jnp.int32(n)
    first_match = jnp.min(jnp.where(match, jnp.arange(n, dtype=jnp.int32), big))
    first_free = jnp.min(jnp.where(free, jnp.arange(n, dtype=jnp.int32), big))
    found = first_match < big
    has_free = first_free < big
    # an empty slot BEFORE the first match terminates probing in the numpy
    # twin; replicate: a match only counts if it occurs before the first free
    found = found & (first_match < jnp.where(has_free, first_free, big))
    slot = order[jnp.clip(first_match, 0, n - 1)]
    free_slot = order[jnp.clip(first_free, 0, n - 1)]
    return slot, found, free_slot, has_free


def j_hash_lookup(st, key, pred):
    slot, found, _, _ = _j_hash_find(st, key)
    ok = pred & found
    return jnp.where(ok, st["values"][slot], jnp.int64(0))


def j_hash_update(st, key, value, pred):
    slot, found, free_slot, has_free = _j_hash_find(st, key)
    tgt = jnp.where(found, slot, free_slot)
    ok = pred & (found | has_free)
    keys = st["keys"].at[tgt].set(jnp.where(ok, key, st["keys"][tgt]))
    used = st["used"].at[tgt].set(jnp.where(ok, jnp.int64(1), st["used"][tgt]))
    vals = st["values"].at[tgt].set(jnp.where(ok, value, st["values"][tgt]))
    return {"keys": keys, "used": used, "values": vals}, (found | has_free)


def j_hash_fetch_add(st, key, delta, pred):
    slot, found, free_slot, has_free = _j_hash_find(st, key)
    tgt = jnp.where(found, slot, free_slot)
    ok = pred & (found | has_free)
    old = jnp.where(pred & found, st["values"][slot], jnp.int64(0))
    newv = jnp.where(found, st["values"][slot] + delta, delta)
    keys = st["keys"].at[tgt].set(jnp.where(ok, key, st["keys"][tgt]))
    used = st["used"].at[tgt].set(jnp.where(ok, jnp.int64(1), st["used"][tgt]))
    vals = st["values"].at[tgt].set(jnp.where(ok, newv, st["values"][tgt]))
    return {"keys": keys, "used": used, "values": vals}, old


def _next_free_dist(used):
    """For every start position s: probe-order distance to the first free
    slot (>= n means the table is full). One suffix-min over the doubled
    free mask — O(2n), shared across the whole event batch."""
    n = used.shape[0]
    free2 = jnp.concatenate([~used, ~used])
    pos = jnp.arange(2 * n, dtype=jnp.int32)
    cand = jnp.where(free2, pos, jnp.int32(2 * n))
    suffix_min = jax.lax.cummin(cand, reverse=True)
    return suffix_min[:n] - jnp.arange(n, dtype=jnp.int32)


def _j_hash_lookup_batch(st, keys):
    """Vectorized lookup for a whole key batch: (slot, found) per lane,
    agreeing with `_j_hash_find` exactly.

    Key insight: whether a TABLE ENTRY is probe-reachable is a property of
    the table alone — entry j holding key k is found by a probe for k iff
    its probe distance (j - hash(k)) mod n is smaller than the distance to
    the first free slot from hash(k) (`_next_free_dist`); duplicates of a
    key (broken chains) resolve to the smallest probe distance. So the
    whole lookup is O(n log n) table-side preprocessing (lexsort by
    (key, probe_dist)) + an O(B log n) per-lane binary search — no [B, n]
    work at all."""
    kt, ut = st["keys"], st["used"]
    n = kt.shape[0]
    j = jnp.arange(n, dtype=jnp.int32)
    used = ut != 0
    startj = _jnp_hash_idx(kt, n).astype(jnp.int32)
    dmj = j - startj
    dmj = jnp.where(dmj < 0, dmj + n, dmj)       # probe dist of entry j
    reach = used & (dmj < _next_free_dist(used)[startj])
    skey = jnp.where(reach, kt, jnp.int64((1 << 63) - 1))
    sdm = jnp.where(reach, dmj, jnp.int32(n))    # sentinels sort last
    order = jnp.lexsort((sdm, skey))
    skey_s, slot_s, reach_s = skey[order], j[order], reach[order]
    pos = jnp.clip(jnp.searchsorted(skey_s, keys), 0, n - 1)
    found = reach_s[pos] & (skey_s[pos] == keys)
    return slot_s[pos], found


def j_hash_fetch_add_batch(st, keys, deltas, ok):
    """Batched hash fetch-add over a whole event batch: end state is
    bit-identical to applying `j_hash_fetch_add` sequentially over the valid
    lanes in batch order (fetch-add results are not produced — the caller
    has verified they are dead).

    Algorithm (the vectorized-scatter replacement for B sequential O(n)
    probes):
      1. one batched lookup (`_j_hash_lookup_batch`, O(n log n + B log n)):
         every lane whose key is already resident contributes via a single
         scatter-add (duplicate keys accumulate — adds commute);
      2. a `while_loop` over only the MISSING keys: each iteration takes
         the first pending lane, aggregates that key's total delta with one
         masked reduction, probes/inserts, and clears the whole key group —
         so iterations = distinct new keys (0 in steady state), inserted in
         first-occurrence order (slot assignment must match the sequential
         twin).

    Equivalence argument: within a fetch-add-only batch the table's
    STRUCTURE (keys/used) changes only at each key's first valid event, and
    those happen in first-occurrence order in both formulations; value adds
    within one slot commute. Probing inside the insert loop re-runs against
    the updated table, so chains exposed by earlier in-batch inserts behave
    exactly as in the sequential order.
    """
    B = keys.shape[0]
    idxs = jnp.arange(B, dtype=jnp.int32)
    delta_eff = jnp.where(ok, deltas, jnp.int64(0))

    # resident keys: one batched lookup + one scatter-add
    slot, found = _j_hash_lookup_batch(st, keys)
    vals = st["values"].at[slot].add(
        jnp.where(ok & found, delta_eff, jnp.int64(0)))

    # missing keys: insert in first-occurrence order (steady state: 0 iters)
    pending = ok & ~found

    def cond(c):
        return jnp.any(c[3])

    def body(c):
        kt, ut, vt, pend = c
        i = jnp.argmin(jnp.where(pend, idxs, jnp.int32(B)))
        k = keys[i]
        group = ok & (keys == k)
        d = jnp.sum(jnp.where(group, delta_eff, jnp.int64(0)))
        sl, fnd, fsl, hfree = _j_hash_find(
            {"keys": kt, "used": ut, "values": vt}, k)
        tgt = jnp.where(fnd, sl, fsl)
        do = fnd | hfree                          # table full -> drop
        newv = jnp.where(fnd, vt[tgt] + d, d)
        kt = kt.at[tgt].set(jnp.where(do, k, kt[tgt]))
        ut = ut.at[tgt].set(jnp.where(do, jnp.int64(1), ut[tgt]))
        vt = vt.at[tgt].set(jnp.where(do, newv, vt[tgt]))
        return kt, ut, vt, pend & ~group

    kt, ut, vt, _ = jax.lax.while_loop(
        cond, body, (st["keys"], st["used"], vals, pending))
    return {"keys": kt, "used": ut, "values": vt}


def j_hash_delete(st, key, pred):
    # tombstone-free delete: mark unused (probe chains may break for keys
    # inserted past this slot — same limitation in the numpy twin, tested).
    slot, found, _, _ = _j_hash_find(st, key)
    ok = pred & found
    used = st["used"].at[slot].set(jnp.where(ok, jnp.int64(0), st["used"][slot]))
    return {"keys": st["keys"], "used": used, "values": st["values"]}, found


def j_hist_add(st, value, pred):
    b = jnp_log2_bin(_as_i64(value))
    bins = st["bins"].at[b].add(jnp.where(pred, jnp.int64(1), jnp.int64(0)))
    return {"bins": bins}


def j_ringbuf_emit(st, record, pred):
    """record: i64[width]. Overwrite mode (head always advances when pred);
    once the head laps capacity each emit overwrites an unread record and
    bumps the `dropped` counter."""
    cap = st["data"].shape[0]
    head = st["head"][0]
    slot = (head % cap).astype(jnp.int32)
    row = jnp.where(pred, record, st["data"][slot])
    data = st["data"].at[slot].set(row)
    head2 = st["head"].at[0].add(jnp.where(pred, jnp.int64(1), jnp.int64(0)))
    lap = jnp.where(pred & (head >= cap), jnp.int64(1), jnp.int64(0))
    dropped = st["dropped"].at[0].add(lap)
    return {"data": data, "head": head2, "dropped": dropped}


# --------------------------------------------------------------------------
# numpy twins (in-place) — oracle + host-side maps
# --------------------------------------------------------------------------

def n_array_lookup(st, key):
    n = st["values"].shape[0]
    return int(st["values"][key]) if 0 <= key < n else 0


def n_array_update(st, key, value):
    n = st["values"].shape[0]
    if 0 <= key < n:
        st["values"][key] = _to_i64(value)


def n_array_fetch_add(st, key, delta):
    n = st["values"].shape[0]
    if not 0 <= key < n:
        return 0
    old = int(st["values"][key])
    st["values"][key] = _to_i64((old + delta))
    return old


def _to_i64(v: int):
    v &= _U64
    return np.int64(v - (1 << 64)) if v >> 63 else np.int64(v)


def _n_hash_find(st, key):
    n = st["keys"].shape[0]
    start = _np_hash_idx(key, n)
    for j in range(n):
        i = (start + j) % n
        if not st["used"][i]:
            return None, i          # (no match before first free), free slot
        if int(st["keys"][i]) == _s64(key):
            return i, None
    return None, None


def _s64(v: int) -> int:
    v &= _U64
    return v - (1 << 64) if v >> 63 else v


def n_hash_lookup(st, key):
    slot, _ = _n_hash_find(st, key)
    return int(st["values"][slot]) if slot is not None else 0


def n_hash_update(st, key, value):
    slot, free = _n_hash_find(st, key)
    tgt = slot if slot is not None else free
    if tgt is None:
        return False
    st["keys"][tgt] = _to_i64(key)
    st["used"][tgt] = 1
    st["values"][tgt] = _to_i64(value)
    return True


def n_hash_fetch_add(st, key, delta):
    slot, free = _n_hash_find(st, key)
    if slot is not None:
        old = int(st["values"][slot])
        st["values"][slot] = _to_i64(old + delta)
        return old
    if free is not None:
        st["keys"][free] = _to_i64(key)
        st["used"][free] = 1
        st["values"][free] = _to_i64(delta)
    return 0


def n_hash_delete(st, key):
    slot, _ = _n_hash_find(st, key)
    if slot is None:
        return False
    st["used"][slot] = 0
    return True


def n_hist_add(st, value):
    st["bins"][np_log2_bin(value)] += 1


def n_ringbuf_emit(st, record):
    cap = st["data"].shape[0]
    head = int(st["head"][0])
    slot = head % cap
    st["data"][slot, :len(record)] = [_to_i64(x) for x in record]
    st["head"][0] += 1
    if head >= cap:                    # lapped: overwrote an unread record
        st["dropped"][0] += 1


def n_ringbuf_drain(st, last_read: int) -> tuple[list[list[int]], int]:
    """Read records in [last_read, head); returns (records, new_cursor).
    Skips overwritten records (reports via dropped semantics)."""
    cap = st["data"].shape[0]
    head = int(st["head"][0])
    lo = max(last_read, head - cap)
    out = [list(map(int, st["data"][i % cap])) for i in range(lo, head)]
    return out, head
