"""eBPF maps, bpftime-style: shared state between probe programs, the host
control plane, and (here) the compiled XLA step function.

Each map kind has two twin implementations with IDENTICAL semantics:
  * jnp ops (predicated, functional) — used by the bytecode->JAX JIT so map
    updates fuse into the step graph;
  * numpy ops (in-place) — used by the reference interpreter (the "ubpf"
    oracle), by host-side ("kernel-mode") probes, and by the shm daemon.

Kinds (subset of Linux's bpf_map_type):
  ARRAY         values i64[N], key = index
  HASH          fixed-capacity open-addressing (linear probe), i64 key/value
  PERCPU_ARRAY  values i64[S, N], one row per device shard
  LOG2HIST      64 power-of-two latency-style bins (bcc's log2 histogram)
  RINGBUF       i64[cap, width] records + monotonic head + dropped counter

Values are 64-bit integers, faithful to eBPF's word size. map_lookup returns
the value (not a pointer) — see DESIGN.md §7 deviation 2.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

_HASH_MULT = 0x9E3779B97F4A7C15  # splitmix64 golden-ratio constant
_U64 = (1 << 64) - 1


class MapKind(enum.Enum):
    ARRAY = "array"
    HASH = "hash"
    PERCPU_ARRAY = "percpu_array"
    LOG2HIST = "log2hist"
    RINGBUF = "ringbuf"


@dataclass(frozen=True)
class MapSpec:
    name: str
    kind: MapKind
    max_entries: int = 64
    # RINGBUF record width in i64 lanes; PERCPU shard count.
    rec_width: int = 4
    num_shards: int = 1
    flags: dict = field(default_factory=dict)

    def validate(self) -> None:
        if self.max_entries <= 0:
            raise ValueError(f"map {self.name}: max_entries must be > 0")
        if self.kind == MapKind.RINGBUF and self.rec_width <= 0:
            raise ValueError(f"map {self.name}: rec_width must be > 0")


# --------------------------------------------------------------------------
# state construction
# --------------------------------------------------------------------------

def _zeros(shape, np_mod):
    return np_mod.zeros(shape, dtype=np_mod.int64)


def init_state(spec: MapSpec, np_mod=jnp):
    """Build the (j)np pytree for one map."""
    n = spec.max_entries
    if spec.kind == MapKind.ARRAY:
        return {"values": _zeros((n,), np_mod)}
    if spec.kind == MapKind.HASH:
        return {"keys": _zeros((n,), np_mod),
                "used": _zeros((n,), np_mod),
                "values": _zeros((n,), np_mod)}
    if spec.kind == MapKind.PERCPU_ARRAY:
        return {"values": _zeros((spec.num_shards, n), np_mod)}
    if spec.kind == MapKind.LOG2HIST:
        return {"bins": _zeros((64,), np_mod)}
    if spec.kind == MapKind.RINGBUF:
        return {"data": _zeros((n, spec.rec_width), np_mod),
                "head": _zeros((1,), np_mod),
                "dropped": _zeros((1,), np_mod)}
    raise ValueError(spec.kind)


def init_states(specs: list[MapSpec], np_mod=jnp) -> dict:
    for s in specs:
        s.validate()
    return {s.name: init_state(s, np_mod) for s in specs}


def state_nbytes(specs: list[MapSpec]) -> int:
    st = init_states(specs, np)
    return sum(a.nbytes for m in st.values() for a in m.values())


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------

def _np_hash_idx(key: int, n: int) -> int:
    h = (int(key) * _HASH_MULT) & _U64
    return int((h >> 33) % n)


def _jnp_hash_idx(key, n: int):
    h = key.astype(jnp.uint64) * jnp.uint64(_HASH_MULT)
    return (h >> jnp.uint64(33)) % jnp.uint64(n)


def np_log2_bin(v: int) -> int:
    v = int(v)
    if v <= 0:
        return 0
    return min(63, v.bit_length())


_POW2 = np.array([1 << k for k in range(63)], dtype=np.int64)


def jnp_log2_bin(v):
    return jnp.where(v <= 0, 0,
                     jnp.minimum(63, jnp.sum((v >= _POW2).astype(jnp.int32))))


# --------------------------------------------------------------------------
# JAX ops (functional, predicated). `pred` gates the side effect so the JIT
# can if-convert branches; lookups return 0 when not found / out of bounds.
# All take and return the per-map pytree.
# --------------------------------------------------------------------------

def _as_i64(x):
    return jnp.asarray(x, dtype=jnp.int64)


def j_array_lookup(st, key, pred):
    n = st["values"].shape[0]
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    return jnp.where(ok, st["values"][idx], jnp.int64(0))


def j_array_update(st, key, value, pred):
    n = st["values"].shape[0]
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    new = st["values"].at[idx].set(jnp.where(ok, value, st["values"][idx]))
    return {"values": new}


def j_array_fetch_add(st, key, delta, pred):
    n = st["values"].shape[0]
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    old = jnp.where(ok, st["values"][idx], jnp.int64(0))
    new = st["values"].at[idx].add(jnp.where(ok, delta, jnp.int64(0)))
    return {"values": new}, old


def j_percpu_lookup(st, shard, key, pred):
    s, n = st["values"].shape
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    sh = jnp.clip(shard, 0, s - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    return jnp.where(ok, st["values"][sh, idx], jnp.int64(0))


def j_percpu_fetch_add(st, shard, key, delta, pred):
    s, n = st["values"].shape
    idx = jnp.clip(key, 0, n - 1).astype(jnp.int32)
    sh = jnp.clip(shard, 0, s - 1).astype(jnp.int32)
    ok = pred & (key >= 0) & (key < n)
    old = jnp.where(ok, st["values"][sh, idx], jnp.int64(0))
    new = st["values"].at[sh, idx].add(jnp.where(ok, delta, jnp.int64(0)))
    return {"values": new}, old


def _j_hash_find(st, key):
    """Return (slot, found, free_slot, has_free) via full linear probe.

    Scans the whole table from the hash position — identical to the numpy
    twin. Vectorized (no data-dependent loop) so it is vmap/scan friendly:
    capacity is small (probe maps, not model state).

    `used` is tri-state: 0 empty, 1 occupied, 2 tombstone. Probe chains
    terminate at EMPTY slots only — tombstones keep chains intact (deletes
    never unreach other keys, so map content is layout-independent; the
    interprocess merge plane depends on this, DESIGN.md §10). Inserts reuse
    the first tombstone-or-empty slot in probe order.
    """
    n = st["keys"].shape[0]
    start = _jnp_hash_idx(_as_i64(key), n).astype(jnp.int32)
    order = (start + jnp.arange(n, dtype=jnp.int32)) % n          # probe seq
    u = st["used"][order]
    occupied = u == 1
    match = occupied & (st["keys"][order] == key)
    free = ~occupied                     # tombstone or empty: insertable
    empty = u == 0                       # chain terminator
    # first index in probe order where match / free / empty occurs
    big = jnp.int32(n)
    idx = jnp.arange(n, dtype=jnp.int32)
    first_match = jnp.min(jnp.where(match, idx, big))
    first_free = jnp.min(jnp.where(free, idx, big))
    first_empty = jnp.min(jnp.where(empty, idx, big))
    # an EMPTY slot before the first match terminates probing in the numpy
    # twin; tombstones do not
    found = (first_match < big) & (first_match < first_empty)
    has_free = first_free < big
    slot = order[jnp.clip(first_match, 0, n - 1)]
    free_slot = order[jnp.clip(first_free, 0, n - 1)]
    return slot, found, free_slot, has_free


def j_hash_lookup(st, key, pred):
    slot, found, _, _ = _j_hash_find(st, key)
    ok = pred & found
    return jnp.where(ok, st["values"][slot], jnp.int64(0))


def j_hash_update(st, key, value, pred):
    slot, found, free_slot, has_free = _j_hash_find(st, key)
    tgt = jnp.where(found, slot, free_slot)
    ok = pred & (found | has_free)
    keys = st["keys"].at[tgt].set(jnp.where(ok, key, st["keys"][tgt]))
    used = st["used"].at[tgt].set(jnp.where(ok, jnp.int64(1), st["used"][tgt]))
    vals = st["values"].at[tgt].set(jnp.where(ok, value, st["values"][tgt]))
    return {"keys": keys, "used": used, "values": vals}, (found | has_free)


def j_hash_fetch_add(st, key, delta, pred):
    slot, found, free_slot, has_free = _j_hash_find(st, key)
    tgt = jnp.where(found, slot, free_slot)
    ok = pred & (found | has_free)
    old = jnp.where(pred & found, st["values"][slot], jnp.int64(0))
    newv = jnp.where(found, st["values"][slot] + delta, delta)
    keys = st["keys"].at[tgt].set(jnp.where(ok, key, st["keys"][tgt]))
    used = st["used"].at[tgt].set(jnp.where(ok, jnp.int64(1), st["used"][tgt]))
    vals = st["values"].at[tgt].set(jnp.where(ok, newv, st["values"][tgt]))
    return {"keys": keys, "used": used, "values": vals}, old


def _next_free_dist(used):
    """For every start position s: probe-order distance to the first slot
    NOT set in `used` (>= n means none). Pass the occupied-or-tombstone
    mask to get the chain-termination distance (first EMPTY slot). One
    suffix-min over the doubled mask — O(2n), shared across the whole
    event batch."""
    n = used.shape[0]
    free2 = jnp.concatenate([~used, ~used])
    pos = jnp.arange(2 * n, dtype=jnp.int32)
    cand = jnp.where(free2, pos, jnp.int32(2 * n))
    suffix_min = jax.lax.cummin(cand, reverse=True)
    return suffix_min[:n] - jnp.arange(n, dtype=jnp.int32)


def _j_hash_lookup_batch(st, keys):
    """Vectorized lookup for a whole key batch: (slot, found) per lane,
    agreeing with `_j_hash_find` exactly.

    Key insight: whether a TABLE ENTRY is probe-reachable is a property of
    the table alone — entry j holding key k is found by a probe for k iff
    its probe distance (j - hash(k)) mod n is smaller than the distance to
    the first chain-terminating EMPTY slot from hash(k) (`_next_free_dist`
    over the non-empty mask; tombstones block termination). So the whole
    lookup is O(n log n) table-side preprocessing (lexsort by
    (key, probe_dist)) + an O(B log n) per-lane binary search — no [B, n]
    work at all."""
    kt, ut = st["keys"], st["used"]
    n = kt.shape[0]
    j = jnp.arange(n, dtype=jnp.int32)
    used = ut == 1
    nonempty = ut != 0                           # occupied or tombstone
    startj = _jnp_hash_idx(kt, n).astype(jnp.int32)
    dmj = j - startj
    dmj = jnp.where(dmj < 0, dmj + n, dmj)       # probe dist of entry j
    reach = used & (dmj < _next_free_dist(nonempty)[startj])
    skey = jnp.where(reach, kt, jnp.int64((1 << 63) - 1))
    sdm = jnp.where(reach, dmj, jnp.int32(n))    # sentinels sort last
    order = jnp.lexsort((sdm, skey))
    skey_s, slot_s, reach_s = skey[order], j[order], reach[order]
    pos = jnp.clip(jnp.searchsorted(skey_s, keys), 0, n - 1)
    found = reach_s[pos] & (skey_s[pos] == keys)
    return slot_s[pos], found


def j_hash_fetch_add_batch(st, keys, deltas, ok):
    """Batched hash fetch-add over a whole event batch: end state is
    bit-identical to applying `j_hash_fetch_add` sequentially over the valid
    lanes in batch order (fetch-add results are not produced — the caller
    has verified they are dead).

    Algorithm (the vectorized-scatter replacement for B sequential O(n)
    probes):
      1. one batched lookup (`_j_hash_lookup_batch`, O(n log n + B log n)):
         every lane whose key is already resident contributes via a single
         scatter-add (duplicate keys accumulate — adds commute);
      2. a `while_loop` over only the MISSING keys: each iteration takes
         the first pending lane, aggregates that key's total delta with one
         masked reduction, probes/inserts, and clears the whole key group —
         so iterations = distinct new keys (0 in steady state), inserted in
         first-occurrence order (slot assignment must match the sequential
         twin).

    Equivalence argument: within a fetch-add-only batch the table's
    STRUCTURE (keys/used) changes only at each key's first valid event, and
    those happen in first-occurrence order in both formulations; value adds
    within one slot commute. Probing inside the insert loop re-runs against
    the updated table, so chains exposed by earlier in-batch inserts behave
    exactly as in the sequential order.
    """
    B = keys.shape[0]
    idxs = jnp.arange(B, dtype=jnp.int32)
    delta_eff = jnp.where(ok, deltas, jnp.int64(0))

    # resident keys: one batched lookup + one scatter-add
    slot, found = _j_hash_lookup_batch(st, keys)
    vals = st["values"].at[slot].add(
        jnp.where(ok & found, delta_eff, jnp.int64(0)))

    # missing keys: insert in first-occurrence order (steady state: 0 iters)
    pending = ok & ~found

    def cond(c):
        return jnp.any(c[3])

    def body(c):
        kt, ut, vt, pend = c
        i = jnp.argmin(jnp.where(pend, idxs, jnp.int32(B)))
        k = keys[i]
        group = ok & (keys == k)
        d = jnp.sum(jnp.where(group, delta_eff, jnp.int64(0)))
        sl, fnd, fsl, hfree = _j_hash_find(
            {"keys": kt, "used": ut, "values": vt}, k)
        tgt = jnp.where(fnd, sl, fsl)
        do = fnd | hfree                          # table full -> drop
        newv = jnp.where(fnd, vt[tgt] + d, d)
        kt = kt.at[tgt].set(jnp.where(do, k, kt[tgt]))
        ut = ut.at[tgt].set(jnp.where(do, jnp.int64(1), ut[tgt]))
        vt = vt.at[tgt].set(jnp.where(do, newv, vt[tgt]))
        return kt, ut, vt, pend & ~group

    kt, ut, vt, _ = jax.lax.while_loop(
        cond, body, (st["keys"], st["used"], vals, pending))
    return {"keys": kt, "used": ut, "values": vt}


def j_hash_delete(st, key, pred):
    # tombstone delete: the slot becomes insertable (used=2) but keeps
    # probe chains intact, so deleting one key never unreaches another —
    # content is layout-independent (merge plane contract, DESIGN.md §10).
    slot, found, _, _ = _j_hash_find(st, key)
    ok = pred & found
    used = st["used"].at[slot].set(jnp.where(ok, jnp.int64(2), st["used"][slot]))
    return {"keys": st["keys"], "used": used, "values": st["values"]}, found


def j_hist_add(st, value, pred):
    b = jnp_log2_bin(_as_i64(value))
    bins = st["bins"].at[b].add(jnp.where(pred, jnp.int64(1), jnp.int64(0)))
    return {"bins": bins}


def j_ringbuf_emit(st, record, pred):
    """record: i64[width]. Overwrite mode (head always advances when pred);
    once the head laps capacity each emit overwrites an unread record and
    bumps the `dropped` counter."""
    cap = st["data"].shape[0]
    head = st["head"][0]
    slot = (head % cap).astype(jnp.int32)
    row = jnp.where(pred, record, st["data"][slot])
    data = st["data"].at[slot].set(row)
    head2 = st["head"].at[0].add(jnp.where(pred, jnp.int64(1), jnp.int64(0)))
    lap = jnp.where(pred & (head >= cap), jnp.int64(1), jnp.int64(0))
    dropped = st["dropped"].at[0].add(lap)
    return {"data": data, "head": head2, "dropped": dropped}


# --------------------------------------------------------------------------
# numpy twins (in-place) — oracle + host-side maps
# --------------------------------------------------------------------------

def n_array_lookup(st, key):
    n = st["values"].shape[0]
    return int(st["values"][key]) if 0 <= key < n else 0


def n_array_update(st, key, value):
    n = st["values"].shape[0]
    if 0 <= key < n:
        st["values"][key] = _to_i64(value)


def n_array_fetch_add(st, key, delta):
    n = st["values"].shape[0]
    if not 0 <= key < n:
        return 0
    old = int(st["values"][key])
    st["values"][key] = _to_i64((old + delta))
    return old


def _to_i64(v: int):
    v &= _U64
    return np.int64(v - (1 << 64)) if v >> 63 else np.int64(v)


def _n_hash_find(st, key):
    """numpy twin of _j_hash_find. `used` is tri-state (0 empty, 1 occupied,
    2 tombstone): the match scan terminates at the first EMPTY slot only —
    tombstones keep probe chains intact; the free slot is the first
    tombstone-or-empty in probe order (tombstones are reused by inserts)."""
    n = st["keys"].shape[0]
    start = _np_hash_idx(key, n)
    free = None
    for j in range(n):
        i = (start + j) % n
        u = int(st["used"][i])
        if u == 1:
            if int(st["keys"][i]) == _s64(key):
                return i, None
        elif free is None:
            free = i
        if u == 0:
            return None, free       # chain ends: no match past this point
    return None, free


def _s64(v: int) -> int:
    v &= _U64
    return v - (1 << 64) if v >> 63 else v


def n_hash_lookup(st, key):
    slot, _ = _n_hash_find(st, key)
    return int(st["values"][slot]) if slot is not None else 0


def n_hash_update(st, key, value):
    slot, free = _n_hash_find(st, key)
    tgt = slot if slot is not None else free
    if tgt is None:
        return False
    st["keys"][tgt] = _to_i64(key)
    st["used"][tgt] = 1
    st["values"][tgt] = _to_i64(value)
    return True


def n_hash_fetch_add(st, key, delta):
    slot, free = _n_hash_find(st, key)
    if slot is not None:
        old = int(st["values"][slot])
        st["values"][slot] = _to_i64(old + delta)
        return old
    if free is not None:
        st["keys"][free] = _to_i64(key)
        st["used"][free] = 1
        st["values"][free] = _to_i64(delta)
    return 0


def n_hash_delete(st, key):
    # tombstone delete (used=2), twin of j_hash_delete: the slot becomes
    # insertable but keeps probe chains intact, so content stays
    # layout-independent (merge plane contract, DESIGN.md §10)
    slot, _ = _n_hash_find(st, key)
    if slot is None:
        return False
    st["used"][slot] = 2
    return True


def n_hist_add(st, value):
    st["bins"][np_log2_bin(value)] += 1


def n_ringbuf_emit(st, record):
    cap = st["data"].shape[0]
    head = int(st["head"][0])
    slot = head % cap
    st["data"][slot, :len(record)] = [_to_i64(x) for x in record]
    st["head"][0] += 1
    if head >= cap:                    # lapped: overwrote an unread record
        st["dropped"][0] += 1


def n_ringbuf_drain(st, last_read: int) -> tuple[list[list[int]], int]:
    """Read records in [last_read, head); returns (records, new_cursor).
    Skips overwritten records (reports via dropped semantics)."""
    cap = st["data"].shape[0]
    head = int(st["head"][0])
    lo = max(last_read, head - cap)
    out = [list(map(int, st["data"][i % cap])) for i in range(lo, head)]
    return out, head


# --------------------------------------------------------------------------
# interprocess merge plane (DESIGN.md §10): per-kind DELTA extraction and
# COMMUTATIVE merge twins. Worker processes publish cumulative seqlocked
# snapshots; the aggregation engine extracts per-cycle deltas against its
# last-seen baseline and folds them into one global view. Merges commute
# across workers for the ops the differential harness admits:
#   * ARRAY / PERCPU_ARRAY / LOG2HIST — element-wise delta-sum (adds
#     commute unconditionally);
#   * HASH — content delta over probe-REACHABLE entries, merged by the same
#     batched first-occurrence machinery as j_hash_fetch_add_batch
#     (n_hash_fetch_add_batch is its numpy twin); per-key sums commute, and
#     non-commutative ops (update/delete) commute across workers iff each
#     key is owned by one worker — the sharded-aggregation contract;
#   * RINGBUF — records are tagged (step, wid, seq) and interleaved by that
#     key; the global order is a deterministic merge-sort of per-worker
#     streams, with dropped counts derived from the global head.
# The jnp side of the hash merge IS j_hash_fetch_add_batch; summary kinds
# get explicit jnp twins below (j_summary_delta / j_summary_merge).
# --------------------------------------------------------------------------

SUMMARY_FIELDS = {
    MapKind.ARRAY: ("values",),
    MapKind.PERCPU_ARRAY: ("values",),
    MapKind.LOG2HIST: ("bins",),
}


def is_summary_kind(kind: MapKind) -> bool:
    return kind in SUMMARY_FIELDS


def n_summary_delta(spec: MapSpec, cur: dict, base: dict) -> dict:
    """Element-wise delta of two cumulative snapshots (wrapping i64)."""
    return {f: np.asarray(cur[f], np.int64) - np.asarray(base[f], np.int64)
            for f in SUMMARY_FIELDS[spec.kind]}


def n_summary_merge(spec: MapSpec, acc: dict, delta: dict) -> None:
    """In-place commutative fold of one delta into the accumulator."""
    for f in SUMMARY_FIELDS[spec.kind]:
        acc[f] += delta[f]


def j_summary_delta(spec: MapSpec, cur: dict, base: dict) -> dict:
    return {f: cur[f] - base[f] for f in SUMMARY_FIELDS[spec.kind]}


def j_summary_merge(spec: MapSpec, acc: dict, delta: dict) -> dict:
    return {f: acc[f] + delta[f] for f in SUMMARY_FIELDS[spec.kind]}


# ---- hash: reachable-content extraction + batched first-occurrence merge

def _np_hash_idx_vec(keys: np.ndarray, n: int) -> np.ndarray:
    with np.errstate(over="ignore"):
        h = keys.astype(np.uint64) * np.uint64(_HASH_MULT)
    return ((h >> np.uint64(33)) % np.uint64(n)).astype(np.int64)


def _np_next_free_dist(used: np.ndarray) -> np.ndarray:
    """numpy twin of _next_free_dist: probe-order distance from every start
    position to the first free slot (>= n when the table is full)."""
    n = used.shape[0]
    free2 = np.concatenate([~used, ~used])
    pos = np.arange(2 * n)
    cand = np.where(free2, pos, 2 * n)
    suffix_min = np.minimum.accumulate(cand[::-1])[::-1]
    return (suffix_min[:n] - np.arange(n)).astype(np.int64)


def n_hash_slots(st) -> dict[int, int]:
    """{key: slot} for every probe-REACHABLE entry — the numpy twin of
    _j_hash_lookup_batch's table-side preprocessing. Entry j holding key k
    is lookup-visible iff its probe distance (j - hash(k)) mod n is below
    the first-free distance from hash(k); duplicate keys (broken chains)
    resolve to the smallest probe distance, exactly as a sequential probe
    would find them."""
    kt = np.asarray(st["keys"], np.int64)
    u = np.asarray(st["used"], np.int64)
    occupied = u == 1
    nonempty = u != 0                   # occupied or tombstone: chain lives on
    n = kt.shape[0]
    if not occupied.any():
        return {}
    j = np.arange(n)
    start = _np_hash_idx_vec(kt, n)
    dist = (j - start) % n
    reach = occupied & (dist < _np_next_free_dist(nonempty)[start])
    out: dict[int, int] = {}
    for idx in np.lexsort((dist, kt)):
        if reach[idx]:
            k = int(kt[idx])
            if k not in out:
                out[k] = int(idx)
    return out


def n_hash_items(st) -> dict[int, int]:
    """Lookup-visible content of a hash table: {key: value}."""
    vals = np.asarray(st["values"], np.int64)
    return {k: int(vals[s]) for k, s in n_hash_slots(st).items()}


def n_hash_fetch_add_batch(st, keys, deltas, ok=None) -> None:
    """numpy twin of j_hash_fetch_add_batch (in-place): end state is
    bit-identical to applying n_hash_fetch_add sequentially over the valid
    lanes in batch order. Same two phases: resident keys via one reachable
    slot lookup + accumulate; missing keys inserted in first-occurrence
    order with group-summed deltas, re-probing after each insert."""
    keys = np.asarray(keys, np.int64)
    deltas = np.asarray(deltas, np.int64)
    B = keys.shape[0]
    ok = np.ones(B, bool) if ok is None else np.asarray(ok, bool)
    if not ok.any():
        return
    slot_of = n_hash_slots(st)
    slots = np.array([slot_of.get(int(k), -1) for k in keys])
    resident = ok & (slots >= 0)
    with np.errstate(over="ignore"):
        np.add.at(st["values"], slots[resident], deltas[resident])
    pending = ok & ~resident
    for i in range(B):
        if not pending[i]:
            continue
        k = int(keys[i])
        group = ok & (keys == keys[i])
        with np.errstate(over="ignore"):
            d = int(np.sum(deltas[group], dtype=np.int64))
        slot, free = _n_hash_find(st, k)
        tgt = slot if slot is not None else free
        if tgt is not None:                        # table full -> drop
            old = int(st["values"][tgt]) if slot is not None else 0
            st["keys"][tgt] = _to_i64(k)
            st["used"][tgt] = 1
            st["values"][tgt] = _to_i64(old + d)
        pending &= ~group


def n_hash_delta(cur_items: dict, base_items: dict
                 ) -> tuple[list[tuple[int, int]], list[int]]:
    """Content delta between two cumulative snapshots of one worker's hash
    map: (adds, dels). adds = (key, value-delta) for new or changed keys
    (new keys are included even at delta 0 so inserts propagate); dels =
    keys the worker deleted since the baseline. Sorted by key so a given
    (cur, base) pair always yields the same batch."""
    adds = []
    for k in sorted(cur_items):
        d = cur_items[k] - base_items.get(k, 0)
        if d != 0 or k not in base_items:
            adds.append((k, d))
    dels = sorted(k for k in base_items if k not in cur_items)
    return adds, dels


def n_hash_canonical(spec: MapSpec, items: dict) -> dict:
    """Deterministic table layout for a given content: rebuild by inserting
    keys in sorted order. Published global hash maps use this form, so the
    merged view is bit-stable regardless of worker poll order; the
    differential harness compares it against the canonicalized oracle."""
    st = init_state(spec, np)
    for k in sorted(items):
        n_hash_update(st, k, items[k])
    return st


# ---- ringbuf: tagged drain + deterministic global interleave

def n_ringbuf_tagged(st, wid, lo: int = 0, step_lane: int | None = None
                     ) -> tuple[list[tuple[tuple, np.ndarray]], int]:
    """Drain retained records with monotonic position >= lo, each tagged
    with its global interleave key (step, wid, seq): seq is the record's
    position in this worker's stream; step comes from the record lane the
    map spec designates (flags={'step_lane': k}), else 0 — reducing the
    interleave to concatenation by wid."""
    cap = st["data"].shape[0]
    head = int(st["head"][0])
    start = max(lo, head - cap)
    out = []
    for i in range(start, head):
        rec = np.array(st["data"][i % cap])
        step = int(rec[step_lane]) if step_lane is not None else 0
        out.append(((step, wid, i), rec))
    return out, head


# ---- tree aggregation plane (DESIGN.md §15): vectorized content twins,
# ---- batched group folds, and hash keyspace sharding

_EMPTY_I64 = np.zeros(0, np.int64)


def n_hash_content(st) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized twin of n_hash_items: the lookup-visible content of a hash
    table as sorted parallel arrays (keys, values) — no per-entry Python
    loop, so a node aggregator can extract its whole group's content at
    numpy speed. dict(zip(*n_hash_content(st))) == n_hash_items(st)."""
    kt = np.asarray(st["keys"], np.int64)
    u = np.asarray(st["used"], np.int64)
    occupied = u == 1
    nonempty = u != 0
    n = kt.shape[0]
    if not occupied.any():
        return _EMPTY_I64, _EMPTY_I64
    j = np.arange(n)
    start = _np_hash_idx_vec(kt, n)
    dist = (j - start) % n
    reach = occupied & (dist < _np_next_free_dist(nonempty)[start])
    idx = np.nonzero(reach)[0]
    if idx.size == 0:
        return _EMPTY_I64, _EMPTY_I64
    # duplicate keys (broken chains) resolve to the smallest probe
    # distance, exactly like n_hash_slots' sequential scan
    order = np.lexsort((dist[idx], kt[idx]))
    sk = kt[idx][order]
    first = np.concatenate([[True], sk[1:] != sk[:-1]])
    sel = idx[order][first]
    return kt[sel], np.asarray(st["values"], np.int64)[sel]


def n_hash_delta_arrays(cur_k: np.ndarray, cur_v: np.ndarray,
                        base_k: np.ndarray, base_v: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized twin of n_hash_delta over sorted content arrays:
    (add_keys, add_deltas, del_keys). New keys are included even at delta 0
    (inserts must propagate); all outputs sorted by key."""
    cur_k = np.asarray(cur_k, np.int64)
    base_k = np.asarray(base_k, np.int64)
    if base_k.size == 0:
        return cur_k, np.asarray(cur_v, np.int64), _EMPTY_I64
    pos = np.searchsorted(base_k, cur_k)
    posc = np.minimum(pos, base_k.size - 1)
    in_base = (pos < base_k.size) & (base_k[posc] == cur_k)
    with np.errstate(over="ignore"):
        d = np.asarray(cur_v, np.int64) - \
            np.where(in_base, np.asarray(base_v, np.int64)[posc], 0)
    keep = (d != 0) | ~in_base
    if cur_k.size == 0:
        return _EMPTY_I64, _EMPTY_I64, base_k
    bpos = np.searchsorted(cur_k, base_k)
    bposc = np.minimum(bpos, cur_k.size - 1)
    in_cur = (bpos < cur_k.size) & (cur_k[bposc] == base_k)
    return cur_k[keep], d[keep], base_k[~in_cur]


def n_hash_coalesce(keys: np.ndarray, deltas: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Commutative coalesce of a fetch-add batch: per-key delta sums, keys
    sorted. Zero-sum keys are KEPT — an insert at delta 0 must still
    propagate up the tree. The numpy twin of j_hash_coalesce."""
    keys = np.asarray(keys, np.int64)
    deltas = np.asarray(deltas, np.int64)
    if keys.size == 0:
        return _EMPTY_I64, _EMPTY_I64
    uk, inv = np.unique(keys, return_inverse=True)
    ud = np.zeros(uk.size, np.int64)
    with np.errstate(over="ignore"):
        np.add.at(ud, inv, deltas)
    return uk, ud


@jax.jit
def _j_coalesce(keys, deltas):
    order = jnp.argsort(keys, stable=True)
    ks, ds = keys[order], deltas[order]
    first = jnp.concatenate(
        [jnp.ones(1, bool), ks[1:] != ks[:-1]]) if ks.shape[0] else \
        jnp.ones(0, bool)
    gid = jnp.cumsum(first.astype(jnp.int64)) - 1
    sums = jnp.zeros_like(ds).at[gid].add(ds)
    out_k = jnp.zeros_like(ks).at[gid].set(ks)
    return out_k, sums, first.sum()


def j_hash_coalesce(keys, deltas) -> tuple[np.ndarray, np.ndarray]:
    """Device-side coalesce (sort + segment-sum) — one jitted reduction for
    a whole worker group's concatenated fetch-add batch. Returns compacted
    host arrays; bit-identical to n_hash_coalesce."""
    keys = np.asarray(keys, np.int64)
    deltas = np.asarray(deltas, np.int64)
    if keys.size == 0:
        return _EMPTY_I64, _EMPTY_I64
    # pad to a power-of-two bucket with (keys[0], 0) no-op entries: the
    # padding folds into an already-present group (delta 0, no phantom
    # zero-sum key is born), while the bucketed shape keeps the jit cache
    # warm — otherwise every cycle's distinct delta count recompiles
    n = keys.size
    cap = max(16, 1 << (n - 1).bit_length())
    pk = np.full(cap, keys[0], np.int64)
    pk[:n] = keys
    pd = np.zeros(cap, np.int64)
    pd[:n] = deltas
    out_k, sums, ng = _j_coalesce(jnp.asarray(pk), jnp.asarray(pd))
    ng = int(ng)
    return np.asarray(out_k[:ng]), np.asarray(sums[:ng])


@jax.jit
def _j_stack_fold(acc, curs, bases):
    return acc + jnp.sum(curs - bases, axis=0)


def j_group_summary_fold(spec: MapSpec, acc: dict, cur_stack: dict,
                         base_stack: dict) -> dict:
    """One batched device reduction folds a whole worker group's summary
    deltas: acc[f] + sum_w(cur[w][f] - base[w][f]). cur_stack/base_stack
    hold (W, *field_shape) arrays; returns new acc field arrays (host)."""
    out = {}
    for f in SUMMARY_FIELDS[spec.kind]:
        out[f] = np.asarray(_j_stack_fold(
            jnp.asarray(acc[f]), jnp.asarray(cur_stack[f]),
            jnp.asarray(base_stack[f])))
    return out


def n_group_summary_fold(spec: MapSpec, acc: dict, cur_stack: dict,
                         base_stack: dict) -> dict:
    """numpy twin of j_group_summary_fold (wrapping i64)."""
    out = {}
    for f in SUMMARY_FIELDS[spec.kind]:
        with np.errstate(over="ignore"):
            out[f] = acc[f] + np.sum(
                np.asarray(cur_stack[f], np.int64)
                - np.asarray(base_stack[f], np.int64), axis=0)
    return out


@jax.jit
def _j_stack_fold_tree(tree):
    return jax.tree_util.tree_map(
        lambda t: t[0] + jnp.sum(t[1] - t[2], axis=0), tree,
        is_leaf=lambda x: isinstance(x, tuple))


def j_group_summary_fold_multi(stacks: dict) -> dict:
    """ONE device dispatch folds every summary spec's worker-group delta
    at once: stacks[name][field] = (acc, cur_stack, base_stack) with
    (W, *shape) stacks. Returns {name: {field: host array}}. Bit-identical
    to per-spec j_group_summary_fold; the pytree batching exists because
    per-field dispatch overhead dominated the node poll at fleet scale."""
    out = _j_stack_fold_tree(stacks)
    return {n: {f: np.asarray(a) for f, a in d.items()}
            for n, d in out.items()}


def n_group_summary_fold_multi(stacks: dict) -> dict:
    """numpy twin of j_group_summary_fold_multi (wrapping i64)."""
    out: dict = {}
    for n, d in stacks.items():
        out[n] = {}
        for f, (acc, cur, base) in d.items():
            with np.errstate(over="ignore"):
                out[n][f] = np.asarray(acc, np.int64) + np.sum(
                    np.asarray(cur, np.int64)
                    - np.asarray(base, np.int64), axis=0)
    return out


def n_shard_of_keys(keys: np.ndarray, n: int, n_shards: int) -> np.ndarray:
    """Keyspace partition for sharded global views: a key's shard is its
    home slot (the same splitmix64 probe start every lookup uses) mod the
    shard count — every key lands in exactly one shard, and co-homed keys
    stay together."""
    keys = np.asarray(keys, np.int64)
    if keys.size == 0:
        return _EMPTY_I64
    return (_np_hash_idx_vec(keys, n) % n_shards).astype(np.int64)


def n_shard_of_key(key: int, n: int, n_shards: int) -> int:
    return _np_hash_idx(key, n) % n_shards


def ringbuf_merge_global(spec: MapSpec, tagged: list, total: int) -> dict:
    """Build the global ringbuf state from every worker's retained tagged
    records. The merged order sorts by (step, wid, seq); the global state is
    exactly what one ring of the same capacity would hold after emitting the
    merged sequence: data holds the last `cap` records at their global
    rank mod cap, head counts every emit, dropped counts emits that
    overwrote an unread record (total - cap, clamped at 0).

    Window argument (DESIGN.md §10): each worker's sort key is monotone in
    its emit order, so the global tail's restriction to worker w is a suffix
    of w's stream of length <= cap — always within what w's own ring still
    retains. The tail of the retained union therefore IS the global tail."""
    st = init_state(spec, np)
    cap = spec.max_entries
    recs = sorted(tagged, key=lambda t: t[0])
    tail = recs[-cap:]
    k = len(tail)
    for i, (_, rec) in enumerate(tail):
        rank = total - k + i
        st["data"][rank % cap, :] = rec
    st["head"][0] = total
    st["dropped"][0] = max(0, total - cap)
    return st
