"""Fault tolerance: heartbeats, straggler detection, restart-from-checkpoint,
elastic re-mesh.

The bpftime twist: the *telemetry that feeds these decisions comes from the
probe runtime* — per-step wall times land in a shared-memory ARRAY map via
the sys_step_end tracepoint, so the (unprivileged, out-of-process) daemon
detects stragglers/stalls without touching the trainer (paper SP4). On a
real cluster each host runs one HeartbeatMonitor; here single-process tests
simulate missed beats and dead hosts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class HeartbeatMonitor:
    """Tracks per-host liveness. beat() on every step; dead() lists hosts
    whose last beat is older than `timeout_s`. Hosts are integer ranks
    when num_hosts > 0; with num_hosts=0 the monitor tracks whatever ids
    have ever beaten (the fleet daemon's string worker ids)."""
    num_hosts: int
    timeout_s: float = 60.0
    last: dict = field(default_factory=dict)
    clock: object = time.monotonic

    def beat(self, host, t: float | None = None):
        self.last[host] = self.clock() if t is None else t

    def dead(self, now: float | None = None) -> list:
        now = self.clock() if now is None else now
        hosts = (range(self.num_hosts) if self.num_hosts
                 else sorted(self.last))
        return [h for h in hosts
                if now - self.last.get(h, -1e30) > self.timeout_s]


def detect_stragglers(step_times: np.ndarray, *, factor: float = 1.5,
                      min_samples: int = 5) -> list[int]:
    """step_times: [hosts, window] seconds (0 = missing). A host is a
    straggler when its median step time exceeds factor x fleet median."""
    if step_times.ndim != 2 or step_times.shape[1] < 1:
        return []
    med = []
    for h in range(step_times.shape[0]):
        v = step_times[h][step_times[h] > 0]
        med.append(np.median(v) if len(v) >= min_samples else np.nan)
    med = np.asarray(med)
    fleet = np.nanmedian(med)
    if not np.isfinite(fleet):
        return []
    return [int(h) for h in range(len(med))
            if np.isfinite(med[h]) and med[h] > factor * fleet]


@dataclass
class ElasticPlan:
    """Given a device loss, the new mesh shape + what must happen."""
    old_shape: tuple
    new_shape: tuple
    action: str             # 'continue' | 'reshard' | 'halt'
    lost: int = 0


def plan_elastic(mesh_shape: tuple[int, ...], devices_lost: int,
                 *, model_axis_last: bool = True) -> ElasticPlan:
    """Shrink the DATA axis (never the model axis — TP degree is baked into
    layouts) to the largest size that keeps all remaining devices busy.
    Restart path: reshard the latest checkpoint onto the new mesh
    (ckpt.restore with new shardings) and continue."""
    *lead, model = mesh_shape if model_axis_last else (*mesh_shape, 1)
    total = int(np.prod(mesh_shape))
    remaining = total - devices_lost
    if devices_lost == 0:
        return ElasticPlan(mesh_shape, mesh_shape, "continue")
    new_data = remaining // model
    if new_data < 1:
        return ElasticPlan(mesh_shape, mesh_shape, "halt", devices_lost)
    if len(lead) == 2:       # (pod, data, model): fold pods into data
        new_shape = (1, new_data, model)
    else:
        new_shape = (new_data, model)
    return ElasticPlan(mesh_shape, new_shape, "reshard", devices_lost)


@dataclass
class TrainSupervisor:
    """Restart-from-checkpoint driver: wraps the step loop; on failure
    (exception or dead host), restores the latest checkpoint and resumes.
    Tested with injected failures in tests/test_ft.py."""
    ckpt_dir: str
    save_every: int = 10
    max_restarts: int = 3
    restarts: int = 0

    def run(self, state, step_fn, data_next, total_steps: int,
            save_fn, restore_fn, failure_hook=None):
        step = int(np.asarray(state["step"]))
        while step < total_steps:
            try:
                if failure_hook is not None:
                    failure_hook(step)
                batch = data_next()
                if batch is None:      # eBPF filter skipped the batch
                    continue
                state, metrics = step_fn(state, batch)
                step = int(np.asarray(state["step"]))
                if step % self.save_every == 0:
                    save_fn(step, state)
            except _Injected as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state = restore_fn()
                step = int(np.asarray(state["step"]))
        return state


class _Injected(RuntimeError):
    """Injected failure type used by tests (stands in for host loss)."""
