"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
— MoE 16 routed experts top-1 + 1 shared expert, every layer (Scout's
interleave_moe_layer_step=1), early fusion."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    rope_theta=500_000.0,
    num_experts=16, experts_per_token=1, moe_d_ff=8192, moe_shared=True,
    moe_every=1, moe_offset=0, superblock=1,
)
