"""--arch <id> registry: maps the assigned architecture ids to configs,
plus reduced same-family smoke configs (small layers/width/experts/vocab)."""
from __future__ import annotations

import dataclasses

from .base import ModelConfig
from . import (jamba_v0_1_52b, kimi_k2_1t_a32b, llama3_2_1b,
               llama4_scout_17b_a16e, mamba2_780m, phi4_mini_3_8b,
               qwen2_0_5b, qwen2_vl_72b, seamless_m4t_medium,
               starcoder2_15b)

ARCHS: dict[str, ModelConfig] = {
    "qwen2-0.5b": qwen2_0_5b.CONFIG,
    "phi4-mini-3.8b": phi4_mini_3_8b.CONFIG,
    "llama3.2-1b": llama3_2_1b.CONFIG,
    "starcoder2-15b": starcoder2_15b.CONFIG,
    "qwen2-vl-72b": qwen2_vl_72b.CONFIG,
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
    "mamba2-780m": mamba2_780m.CONFIG,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.CONFIG,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.CONFIG,
    "jamba-v0.1-52b": jamba_v0_1_52b.CONFIG,
}


def get(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def smoke(arch: str) -> ModelConfig:
    """Reduced config of the same family: tiny widths, few layers/experts,
    small vocab — runs a forward/train step on CPU in seconds."""
    cfg = get(arch)
    r = dict(
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32",
        frontend_tokens=8 if cfg.frontend != "none" else 0,
        ssm_state=16, ssm_headdim=16, ssm_chunk=2,
    )
    if cfg.family == "ssm":
        r.update(num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0)
    if cfg.rope_kind == "mrope":
        r.update(mrope_sections=(2, 3, 3))   # sums to smoke hd/2
    if cfg.num_experts:
        r.update(num_experts=4,
                 experts_per_token=min(2, cfg.experts_per_token),
                 moe_d_ff=128)
    if cfg.family == "encdec":
        r.update(enc_layers=2, dec_layers=2, num_layers=0, num_kv_heads=4)
    else:
        r.update(num_layers=2 * cfg.superblock)
    return dataclasses.replace(cfg, **r)
