"""qwen2-vl-72b [arXiv:2409.12191; hf] — M-RoPE, dynamic-resolution vision
frontend (STUB: patch embeddings provided via input_specs)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
    rope_kind="mrope", mrope_sections=(16, 24, 24),
    frontend="vision", frontend_tokens=1024,
)
