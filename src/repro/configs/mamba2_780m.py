"""mamba2-780m [arXiv:2405.21060; unverified] — SSD (state-space duality),
attention-free. d_inner = 2*1536 = 3072, 48 heads of dim 64, N=128."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, rope_kind="none",
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    ssm_chunk=256, tie_embeddings=True,
)
