from .base import (MeshConfig, ModelConfig, ShapeConfig, SHAPES, TrainConfig,
                   shape_applicable)
from .registry import ARCHS, get, smoke

__all__ = ["MeshConfig", "ModelConfig", "ShapeConfig", "SHAPES",
           "TrainConfig", "shape_applicable", "ARCHS", "get", "smoke"]
