"""seamless-m4t-medium [arXiv:2308.11596; hf] — enc-dec multimodal backbone
(audio frontend STUB: frame embeddings via input_specs). MHA (kv=16)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=0, enc_layers=12, dec_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    frontend="audio", frontend_tokens=0,
)
