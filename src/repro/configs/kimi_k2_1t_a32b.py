"""kimi-k2-1t-a32b [arXiv:2501.kimi2; unverified] — trillion-param MoE:
384 experts, top-8, expert d_ff=2048. All layers MoE (the assigned table's
per-layer pattern; the release's single dense first layer is noted in
DESIGN.md §Arch-applicability)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=112,
    rope_theta=50_000.0,
    num_experts=384, experts_per_token=8, moe_d_ff=2048,
    moe_every=1, moe_offset=0, superblock=1,
    dtype="bfloat16",
)
