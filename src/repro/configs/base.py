"""Config schema: model / shape / mesh / train / serve.

Every assigned architecture instantiates ModelConfig exactly once in its own
file under repro/configs/, and is selectable via --arch <id> through
configs.registry.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    rope_kind: str = "rope"     # rope | mrope | none
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    tie_embeddings: bool = False
    act: str = "swiglu"         # swiglu | gelu
    # ---- MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0           # expert hidden dim (d_ff used for dense ffn)
    moe_every: int = 1          # layer i uses MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    moe_shared: bool = False    # always-on shared expert alongside routed
    capacity_factor: float = 1.25
    # ---- SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # ---- hybrid (jamba): attention layer every `attn_every`, at offset
    attn_every: int = 0         # 0 -> all attention (or all ssm if family=ssm)
    attn_offset: int = 4
    # ---- enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # ---- modality frontend stub (vlm/audio): inputs arrive as embeddings
    frontend: str = "none"      # none | vision | audio
    frontend_tokens: int = 0    # prefix positions fed as embeddings
    # ---- numerics
    dtype: str = "bfloat16"
    # superblock: scan unit = this many consecutive layers (hetero patterns)
    superblock: int = 1

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """vocab padded to 256 for clean TP sharding (loss masks padding)."""
        return -(-self.vocab_size // 256) * 256

    def block_kind(self, i: int) -> str:
        """'attn' or 'mamba' for layer i."""
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid" and self.attn_every:
            return "attn" if i % self.attn_every == self.attn_offset else "mamba"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """'moe' | 'dense' | 'none' for layer i."""
        if self.family == "ssm":
            return "none"
        if self.num_experts and i % self.moe_every == self.moe_offset:
            return "moe"
        return "dense"

    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def ssm_heads(self) -> int:
        return self.d_inner() // self.ssm_headdim

    # ---------------- parameter counting (for roofline MODEL_FLOPS)
    def param_counts(self) -> dict:
        D, V = self.d_model, self.vocab_size
        hd, H, KH = self.hd, self.num_heads, self.num_kv_heads
        attn = D * H * hd + 2 * D * KH * hd + H * hd * D
        if self.qkv_bias:
            attn += (H + 2 * KH) * hd
        dense_ffn = 3 * D * self.d_ff if self.act == "swiglu" else 2 * D * self.d_ff
        shared = 3 * D * self.moe_d_ff if self.moe_shared else 0
        moe_ffn = (self.num_experts * 3 * D * self.moe_d_ff
                   + D * self.num_experts + shared)
        act_moe_ffn = (self.experts_per_token * 3 * D * self.moe_d_ff
                       + D * self.num_experts + shared)
        di, N = self.d_inner(), self.ssm_state
        nh, G = self.ssm_heads(), self.ssm_ngroups
        mamba = (D * (2 * di + 2 * G * N + nh)       # in_proj
                 + self.ssm_conv * (di + 2 * G * N)  # depthwise conv
                 + nh * 3                            # A_log, D, dt_bias
                 + di * D)                           # out_proj
        total = acttotal = V * D * (1 if self.tie_embeddings else 2)
        n_layers = self.num_layers or (self.enc_layers + self.dec_layers)
        for i in range(n_layers):
            blk = mamba if self.block_kind(i) == "mamba" else attn
            ffn = {"dense": dense_ffn, "moe": moe_ffn, "none": 0}[self.ffn_kind(i)]
            affn = {"dense": dense_ffn, "moe": act_moe_ffn, "none": 0}[self.ffn_kind(i)]
            total += blk + ffn + 2 * D
            acttotal += blk + affn + 2 * D
        if self.family == "encdec":  # cross-attention in decoder
            total += self.dec_layers * (attn + D)
            acttotal += self.dec_layers * (attn + D)
        return {"total": total, "active": acttotal}


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                   # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence mixing: only SSM/hybrid archs run it
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "long_500k skipped: full-attention arch (see DESIGN.md)"
    return True, ""


@dataclass(frozen=True)
class TrainConfig:
    microbatch: int = 0          # 0 -> no grad accumulation
    remat: bool = True
    optimizer: str = "adamw"     # adamw | adafactor
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compression: str = "none"   # none | int8
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...] = (16, 16)
    axes: tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes


def scaled(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced-config variant of the same family (smoke tests)."""
    return replace(cfg, **overrides)
