"""jamba-v0.1-52b [arXiv:2403.19887; hf] — hybrid Mamba+attention 1:7
interleave (attention at layer offset 4 of each 8), MoE 16e top-2 every
other layer. Attention layers carry no RoPE (positions via SSM)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    rope_kind="none",
    num_experts=16, experts_per_token=2, moe_d_ff=14336,
    moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4, superblock=8,
    ssm_state=16, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1, ssm_chunk=256,
)
