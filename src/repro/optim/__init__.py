from .optimizers import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, global_norm,
                         make_optimizer, warmup_cosine)

__all__ = ["adamw_init", "adamw_update", "adafactor_init",
           "adafactor_update", "clip_by_global_norm", "global_norm",
           "warmup_cosine", "make_optimizer"]
