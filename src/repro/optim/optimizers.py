"""Optimizers (no optax in this container): AdamW and Adafactor.

Adafactor (factored second moment, no momentum) is the default for the
≥100B MoE configs — 2 fp32 moments on a 1T-param model do not fit a single
v5e pod (see DESIGN.md hardware-adaptation notes and EXPERIMENTS.md
§Dry-run memory table).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), n


def warmup_cosine(step, *, lr, warmup, total):
    step = step.astype(F32)
    warm = lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, cos)


# ----------------------------------------------------------------- AdamW

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(params, grads, opt, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, step=None):
    t = (step + 1).astype(F32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(F32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v}


# --------------------------------------------------------------- Adafactor

def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], F32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
        return {"v": jnp.zeros(p.shape, F32)}
    return {"f": jax.tree.map(init, params,
                              is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(params, grads, opt, lr, *, decay=0.8, eps=1e-30,
                     weight_decay=0.0, clip_thresh=1.0, step=None):
    t = (step + 1).astype(F32)
    beta = 1.0 - t ** (-decay)

    def upd(p, g, st):
        gf = g.astype(F32)
        g2 = gf * gf + eps
        if _factored(p.shape):
            vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            rfac = lax_rsqrt(vr / jnp.maximum(
                jnp.mean(vr, axis=-1, keepdims=True), eps))
            cfac = lax_rsqrt(vc)
            u = gf * rfac[..., None] * cfac[..., None, :]
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta * st["v"] + (1 - beta) * g2
            u = gf * lax_rsqrt(v)
            new_st = {"v": v}
        # update clipping (RMS <= clip_thresh)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip_thresh)
        delta = u + weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), new_st

    is_state = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    out = jax.tree.map(upd, params, grads, opt["f"], is_leaf=None)
    # out mirrors params' structure with (p, st) tuples at leaves
    new_p = jax.tree.map(lambda o: o[0],
                         out, is_leaf=lambda x: isinstance(x, tuple))
    new_f = jax.tree.map(lambda o: o[1],
                         out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"f": new_f}


def lax_rsqrt(x):
    return jax.lax.rsqrt(jnp.maximum(x, 1e-30))


# ----------------------------------------------------------------- factory

def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise KeyError(name)
