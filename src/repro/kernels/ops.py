"""jit'd public wrappers for the Pallas kernels, with impl dispatch.

impl:
  'ref'               pure-jnp oracle (default on CPU — this container)
  'pallas'            compiled Pallas (TPU target)
  'pallas_interpret'  Pallas kernel body interpreted on CPU (tests)

Default comes from REPRO_KERNEL_IMPL or the backend: TPU->pallas, else ref.
"""
from __future__ import annotations

import os

import jax

from . import hash_update, ref, ringbuf_emit, tensor_stats as ts

_DEFAULT = None


def default_impl() -> str:
    global _DEFAULT
    if _DEFAULT is None:
        env = os.environ.get("REPRO_KERNEL_IMPL")
        if env:
            _DEFAULT = env
        else:
            _DEFAULT = ("pallas" if jax.default_backend() == "tpu" else "ref")
    return _DEFAULT


def set_default_impl(impl: str | None):
    global _DEFAULT
    _DEFAULT = impl


def tensor_stats(x, impl: str | None = None) -> dict:
    impl = impl or default_impl()
    if impl == "ref":
        return ref.tensor_stats(x)
    return ts.tensor_stats_pallas(x, interpret=(impl == "pallas_interpret"))


def log2_histogram(x, n_bins: int = 64, impl: str | None = None):
    # histogram builds on the same pass; ref-only jnp fallback provided
    return ref.log2_histogram(x, n_bins)


def hash_fetch_add_batch(keys_tbl, used_tbl, vals_tbl, keys, deltas, valid,
                         impl: str | None = None):
    impl = impl or default_impl()
    if impl == "ref":
        return ref.hash_fetch_add_batch(keys_tbl, used_tbl, vals_tbl,
                                        keys, deltas, valid)
    return hash_update.hash_fetch_add_batch_pallas(
        keys_tbl, used_tbl, vals_tbl, keys, deltas, valid,
        interpret=(impl == "pallas_interpret"))


def ringbuf_emit_batch(data, head, rows, valid, impl: str | None = None):
    impl = impl or default_impl()
    if impl == "ref":
        return ref.ringbuf_emit_batch(data, head, rows, valid)
    return ringbuf_emit.ringbuf_emit_batch_pallas(
        data, head, rows, valid, interpret=(impl == "pallas_interpret"))
