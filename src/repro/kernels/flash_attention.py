"""Pallas flash attention (causal, GQA) — forward + backward TPU kernels.

The dry-run HLO audit showed the step's dominant HBM traffic is the
attention interior (per-chunk [Lq, Lkv] scores/probs, ~900GB/step/device on
train_4k cells): XLA materializes them, a fused kernel keeps them in VMEM.
This kernel is the TPU-native answer (FlashAttention re-tiled for MXU/VMEM):

  forward   grid (BH, nq, nk): online-softmax accumulation in VMEM scratch
            (m, l, acc persist across the sequential nk axis), output
            written at the last kv step.
  backward  two kernels: dkv (grid BH, nk, nq) and dq (grid BH, nq, nk),
            recomputing p from the saved logsumexp (flash-2 style).

GQA: q is [B*H, Sq, hd] with H = KH*R; k/v are [B*KH, Skv, hd]; the index
maps route q head bh to kv head bh // R — KV is never repeated in memory.
Used via ops.flash_attention (ref oracle: models.layers.flash_attention).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
NEG = -1e30


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, causal, nk, lq, lkv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        run = ki * lkv <= qi * lq + lq - 1   # any unmasked pair in block?

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(F32)             # [Lq, hd]
        k = k_ref[0].astype(F32)             # [Lkv, hd]
        v = v_ref[0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = qi * lq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (lq, lkv), 0)
            kpos = ki * lkv + jax.lax.broadcasted_iota(jnp.int32,
                                                       (lq, lkv), 1)
            s = jnp.where(qpos >= kpos, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(l))[:, 0]


@functools.partial(jax.jit, static_argnames=("causal", "lq", "lkv", "rep",
                                             "interpret"))
def flash_fwd(q, k, v, *, causal=True, lq=256, lkv=256, rep=1,
              interpret=False):
    """q: [BH, Sq, hd]; k, v: [BKH, Skv, hd]; BH = BKH * rep.
    Returns (o [BH, Sq, hd], lse [BH, Sq])."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    lq, lkv = min(lq, Sq), min(lkv, Skv)
    assert Sq % lq == 0 and Skv % lkv == 0
    nq, nk = Sq // lq, Skv // lkv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               nk=nk, lq=lq, lkv=lkv)
    o, lse = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, lq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, lkv, hd),
                         lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
            pl.BlockSpec((1, lkv, hd),
                         lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, lq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, lq), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), F32),
        ],
        scratch_shapes=[
            pltpu_vmem((lq, 1), F32),
            pltpu_vmem((lq, 1), F32),
            pltpu_vmem((lq, hd), F32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


def pltpu_vmem(shape, dtype):
    """VMEM scratch allocation (interpret-mode friendly)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


# --------------------------------------------------------------------------
# backward
# --------------------------------------------------------------------------

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, scale, causal, nq, lq, lkv, rep):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    run = True
    if causal:
        run = ki * lkv <= qi * lq + lq - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(F32)
        k = k_ref[0].astype(F32)
        v = v_ref[0].astype(F32)
        do = do_ref[0].astype(F32)
        lse = lse_ref[0]                       # [Lq]
        delta = delta_ref[0]                   # [Lq]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = qi * lq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (lq, lkv), 0)
            kpos = ki * lkv + jax.lax.broadcasted_iota(jnp.int32,
                                                       (lq, lkv), 1)
            s = jnp.where(qpos >= kpos, s, NEG)
        p = jnp.exp(s - lse[:, None])          # [Lq, Lkv]
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(qi == nq - 1)
    def _finish():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_scr, *, scale, causal, nk, lq, lkv, rep):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    run = True
    if causal:
        run = ki * lkv <= qi * lq + lq - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(F32)
        k = k_ref[0].astype(F32)
        v = v_ref[0].astype(F32)
        do = do_ref[0].astype(F32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = qi * lq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (lq, lkv), 0)
            kpos = ki * lkv + jax.lax.broadcasted_iota(jnp.int32,
                                                       (lq, lkv), 1)
            s = jnp.where(qpos >= kpos, s, NEG)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None]) * scale
        dq_scr[...] += jax.lax.dot(ds, k)

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "lq", "lkv", "rep",
                                             "interpret"))
def flash_bwd(q, k, v, o, lse, do, *, causal=True, lq=256, lkv=256, rep=1,
              interpret=False):
    BH, Sq, hd = q.shape
    BKH, Skv, _ = k.shape
    lq, lkv = min(lq, Sq), min(lkv, Skv)
    nq, nk = Sq // lq, Skv // lkv
    scale = 1.0 / math.sqrt(hd)
    delta = jnp.sum(do.astype(F32) * o.astype(F32), axis=-1)   # [BH, Sq]

    # dk/dv accumulate over q for each kv head-group member separately,
    # then sum the rep groups outside (keeps kernels simple).
    dkv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal, nq=nq,
                          lq=lq, lkv=lkv, rep=rep),
        grid=(BH, nk, nq),
        in_specs=[
            pl.BlockSpec((1, lq, hd), lambda bh, ki, qi: (bh, qi, 0)),
            pl.BlockSpec((1, lkv, hd),
                         lambda bh, ki, qi, rep=rep: (bh // rep, ki, 0)),
            pl.BlockSpec((1, lkv, hd),
                         lambda bh, ki, qi, rep=rep: (bh // rep, ki, 0)),
            pl.BlockSpec((1, lq, hd), lambda bh, ki, qi: (bh, qi, 0)),
            pl.BlockSpec((1, lq), lambda bh, ki, qi: (bh, qi)),
            pl.BlockSpec((1, lq), lambda bh, ki, qi: (bh, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, lkv, hd), lambda bh, ki, qi: (bh, ki, 0)),
            pl.BlockSpec((1, lkv, hd), lambda bh, ki, qi: (bh, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Skv, hd), k.dtype),
            jax.ShapeDtypeStruct((BH, Skv, hd), v.dtype),
        ],
        scratch_shapes=[pltpu_vmem((lkv, hd), F32),
                        pltpu_vmem((lkv, hd), F32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk_full, dv_full = dkv
    dk = dk_full.reshape(BKH, rep, Skv, hd).sum(axis=1).astype(k.dtype)
    dv = dv_full.reshape(BKH, rep, Skv, hd).sum(axis=1).astype(v.dtype)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal, nk=nk,
                          lq=lq, lkv=lkv, rep=rep),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, lq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, lkv, hd),
                         lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
            pl.BlockSpec((1, lkv, hd),
                         lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
            pl.BlockSpec((1, lq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, lq), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, lq), lambda bh, qi, ki: (bh, qi)),
        ],
        out_specs=[pl.BlockSpec((1, lq, hd), lambda bh, qi, ki: (bh, qi, 0))],
        out_shape=[jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype)],
        scratch_shapes=[pltpu_vmem((lq, hd), F32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)[0]
    return dq, dk, dv


# --------------------------------------------------------------------------
# differentiable wrapper
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_pallas(q, k, v, causal=True, lq=256, lkv=256, rep=1,
                           interpret=False):
    o, _ = flash_fwd(q, k, v, causal=causal, lq=lq, lkv=lkv, rep=rep,
                     interpret=interpret)
    return o


def _fa_fwd(q, k, v, causal, lq, lkv, rep, interpret):
    o, lse = flash_fwd(q, k, v, causal=causal, lq=lq, lkv=lkv, rep=rep,
                       interpret=interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, lq, lkv, rep, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_bwd(q, k, v, o, lse, do, causal=causal, lq=lq,
                           lkv=lkv, rep=rep, interpret=interpret)
    return dq, dk, dv


flash_attention_pallas.defvjp(_fa_fwd, _fa_bwd)
