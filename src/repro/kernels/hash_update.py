"""Batched hash-map fetch-add Pallas kernel.

The probe-execution stage's map-update hot path: apply B (key, delta)
fetch-adds to an open-addressing table in one kernel launch, with the whole
table resident in VMEM (probe maps are small — KBs) and the event batch
streamed through. Sequential semantics identical to ref.hash_fetch_add_batch.

TPU adaptation: instead of per-event atomic CAS chains (the GPU/x86 shape),
the table lives in VMEM for the kernel's lifetime and events are applied by
a fori_loop; the grid is a single step, so there is no write contention by
construction (TPU grids execute sequentially per core).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_HASH_MULT = 0x9E3779B97F4A7C15


def _kernel(keys_ev_ref, deltas_ref, valid_ref,
            kt_in_ref, ut_in_ref, vt_in_ref,
            kt_ref, ut_ref, vt_ref, *, n: int, batch: int):
    # copy table in -> out once, then mutate out in place
    kt_ref[...] = kt_in_ref[...]
    ut_ref[...] = ut_in_ref[...]
    vt_ref[...] = vt_in_ref[...]
    ar = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def body(b, _):
        key = keys_ev_ref[b]
        delta = deltas_ref[b]
        ok = valid_ref[b] != 0

        h = key.astype(jnp.uint64) * jnp.uint64(_HASH_MULT)
        start = ((h >> jnp.uint64(33)) % jnp.uint64(n)).astype(jnp.int32)
        order = (start + ar) % n
        kt = kt_ref[...]
        ut = ut_ref[...]
        u_o = ut[order]
        occupied = u_o == 1           # tri-state used: 2 = tombstone
        match = occupied & (kt[order] == key)
        free = ~occupied              # tombstone or empty: insertable
        empty = u_o == 0              # chain terminator
        big = jnp.int32(n)
        fm = jnp.min(jnp.where(match, ar, big))
        ff = jnp.min(jnp.where(free, ar, big))
        fe = jnp.min(jnp.where(empty, ar, big))
        found = (fm < big) & (fm < fe)
        has_free = ff < big
        slot = order[jnp.clip(fm, 0, n - 1)]
        fslot = order[jnp.clip(ff, 0, n - 1)]
        tgt = jnp.where(found, slot, fslot)
        do = ok & (found | has_free)

        cur = vt_ref[tgt]
        newv = jnp.where(found, cur + delta, delta)
        kt_ref[tgt] = jnp.where(do, key, kt_ref[tgt])
        ut_ref[tgt] = jnp.where(do, jnp.int64(1), ut_ref[tgt])
        vt_ref[tgt] = jnp.where(do, newv, vt_ref[tgt])
        return ()

    jax.lax.fori_loop(0, batch, body, ())


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_fetch_add_batch_pallas(keys_tbl, used_tbl, vals_tbl, keys, deltas,
                                valid, *, interpret: bool = False):
    n = keys_tbl.shape[0]
    b = keys.shape[0]
    # no grid: single step, whole arrays as VMEM blocks
    kt, ut, vt = pl.pallas_call(
        functools.partial(_kernel, n=n, batch=b),
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int64)] * 3,
        interpret=interpret,
    )(keys, deltas, valid.astype(jnp.int64), keys_tbl, used_tbl, vals_tbl)
    return kt, ut, vt
