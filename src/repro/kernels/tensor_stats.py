"""Fused tensor-statistics Pallas kernel — the probe hot path.

One pass over HBM computes sum/sumsq/min/max/nan/inf simultaneously, so an
attached probe costs ~1 read of the tensor (memory-roofline optimal) instead
of 6 separate reductions. TPU adaptation of the paper's JIT'd probe body:
the working set is tiled (BR, 1024) into VMEM; lane dim 1024 = 8×128 keeps
the VPU fully packed; the grid walks rows sequentially and accumulates into
(1,1) scalar output blocks (legal on TPU because the grid is sequential).

Layout: the wrapper flattens + zero-pads x to (R, 1024); a global-index mask
inside the kernel excludes padding from every statistic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024       # 8 sublanes * 128 lanes
DEF_BLOCK_ROWS = 8


def _kernel(x_ref, sum_ref, ssq_ref, min_ref, max_ref, nan_ref, inf_ref,
            *, numel: int, lanes: int):
    i = pl.program_id(0)
    br = x_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)

    # mask out padding via global element index
    row0 = i * br
    ridx = jax.lax.broadcasted_iota(jnp.int32, (br, lanes), 0)
    cidx = jax.lax.broadcasted_iota(jnp.int32, (br, lanes), 1)
    gidx = (row0 + ridx) * lanes + cidx
    pad = gidx >= numel

    nan = jnp.isnan(x) & ~pad
    inf = jnp.isinf(x) & ~pad
    bad = nan | inf | pad
    z = jnp.where(bad, 0.0, x)

    psum = jnp.sum(z)
    pssq = jnp.sum(z * z)
    pmin = jnp.min(jnp.where(bad, jnp.inf, x))
    pmax = jnp.max(jnp.where(bad, -jnp.inf, x))
    pnan = jnp.sum(nan.astype(jnp.float32))
    pinf = jnp.sum(inf.astype(jnp.float32))

    @pl.when(i == 0)
    def _init():
        sum_ref[0, 0] = jnp.float32(0.0)
        ssq_ref[0, 0] = jnp.float32(0.0)
        min_ref[0, 0] = jnp.float32(jnp.inf)
        max_ref[0, 0] = jnp.float32(-jnp.inf)
        nan_ref[0, 0] = jnp.float32(0.0)
        inf_ref[0, 0] = jnp.float32(0.0)

    sum_ref[0, 0] += psum
    ssq_ref[0, 0] += pssq
    min_ref[0, 0] = jnp.minimum(min_ref[0, 0], pmin)
    max_ref[0, 0] = jnp.maximum(max_ref[0, 0], pmax)
    nan_ref[0, 0] += pnan
    inf_ref[0, 0] += pinf


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def tensor_stats_pallas(x, *, block_rows: int = DEF_BLOCK_ROWS,
                        interpret: bool = False) -> dict:
    numel = x.size
    xf = jnp.asarray(x, jnp.float32).reshape(-1)
    rows = max(1, -(-numel // LANES))
    rows_pad = -(-rows // block_rows) * block_rows
    xf = jnp.pad(xf, (0, rows_pad * LANES - numel))
    xf = xf.reshape(rows_pad, LANES)

    grid = (rows_pad // block_rows,)
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((1, 1), jnp.float32)] * 6
    s, ss, mn, mx, nan, inf = pl.pallas_call(
        functools.partial(_kernel, numel=numel, lanes=LANES),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=[scalar_spec] * 6,
        out_shape=out_shape,
        interpret=interpret,
    )(xf)

    s, ss = s[0, 0], ss[0, 0]
    mn, mx = mn[0, 0], mx[0, 0]
    nan_c, inf_c = nan[0, 0], inf[0, 0]
    n_ok = jnp.maximum(jnp.float32(numel) - nan_c - inf_c, 1.0)
    any_ok = (nan_c + inf_c) < jnp.float32(numel)
    mn = jnp.where(any_ok, mn, 0.0)
    mx = jnp.where(any_ok, mx, 0.0)
    return {
        "mean": s / n_ok,
        "rms": jnp.sqrt(ss / n_ok),
        "min": mn,
        "max": mx,
        "absmax": jnp.maximum(jnp.abs(mn), jnp.abs(mx)),
        "nan_cnt": nan_c.astype(jnp.int64),
        "inf_cnt": inf_c.astype(jnp.int64),
    }
