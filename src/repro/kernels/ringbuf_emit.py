"""Batched ring-buffer emit Pallas kernel.

Appends the valid rows of an event batch to a ring buffer in one launch
(reserve/commit collapses to a prefix-count because the TPU grid is
sequential — no CAS needed, the adaptation of bpftime's shm ringbuf).
Semantics identical to ref.ringbuf_emit_batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(rows_ref, valid_ref, data_in_ref, head_in_ref,
            data_ref, head_ref, *, cap: int, batch: int):
    data_ref[...] = data_in_ref[...]
    head0 = head_in_ref[0]

    def body(b, count):
        ok = valid_ref[b] != 0
        slot = ((head0 + count) % cap).astype(jnp.int32)
        row = rows_ref[b, :]
        data_ref[slot, :] = jnp.where(ok, row, data_ref[slot, :])
        return count + jnp.where(ok, jnp.int64(1), jnp.int64(0))

    total = jax.lax.fori_loop(0, batch, body, jnp.int64(0))
    head_ref[0] = head0 + total


@functools.partial(jax.jit, static_argnames=("interpret",))
def ringbuf_emit_batch_pallas(data, head, rows, valid, *,
                              interpret: bool = False):
    cap, w = data.shape
    b = rows.shape[0]
    d, h = pl.pallas_call(
        functools.partial(_kernel, cap=cap, batch=b),
        out_shape=[jax.ShapeDtypeStruct((cap, w), jnp.int64),
                   jax.ShapeDtypeStruct((1,), jnp.int64)],
        interpret=interpret,
    )(rows, valid.astype(jnp.int64), data, head)
    return d, h
