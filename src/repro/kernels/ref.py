"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the kernels must match them exactly
(tests sweep shapes/dtypes and assert_allclose against these).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

I64 = jnp.int64
_HASH_MULT = 0x9E3779B97F4A7C15


# --------------------------------------------------------------------------
# tensor_stats: one-pass fused summary of an arbitrary tensor
# --------------------------------------------------------------------------

def tensor_stats(x) -> dict:
    """Returns f32 scalars mean/rms/min/max/absmax over FINITE elements and
    i64 nan/inf counts. Empty or all-non-finite tensors give zeros."""
    xf = jnp.asarray(x, jnp.float32).reshape(-1)
    nan = jnp.isnan(xf)
    inf = jnp.isinf(xf)
    bad = nan | inf
    n_ok = jnp.maximum(jnp.sum(~bad).astype(jnp.float32), 1.0)
    z = jnp.where(bad, 0.0, xf)
    s = jnp.sum(z)
    ss = jnp.sum(z * z)
    mn = jnp.min(jnp.where(bad, jnp.inf, xf))
    mx = jnp.max(jnp.where(bad, -jnp.inf, xf))
    any_ok = jnp.any(~bad)
    mn = jnp.where(any_ok, mn, 0.0)
    mx = jnp.where(any_ok, mx, 0.0)
    return {
        "mean": s / n_ok,
        "rms": jnp.sqrt(ss / n_ok),
        "min": mn,
        "max": mx,
        "absmax": jnp.maximum(jnp.abs(mn), jnp.abs(mx)),
        "nan_cnt": jnp.sum(nan).astype(I64),
        "inf_cnt": jnp.sum(inf).astype(I64),
    }


def log2_histogram(x, n_bins: int = 64):
    """bcc-style log2 histogram of |x| in Q47.16 fixed point (i64 view):
    bin 0 = zero/negative fx value; bin k = bit_length(v) for v>0."""
    v = jnp.abs(jnp.asarray(x, jnp.float32).reshape(-1))
    v = jnp.where(jnp.isfinite(v), v, 0.0)
    fx = jnp.clip(v * 65536.0, 0, float(2**62)).astype(I64)
    pow2 = jnp.asarray([1 << k for k in range(63)], I64)
    bins = jnp.where(fx <= 0, 0,
                     jnp.minimum(n_bins - 1,
                                 jnp.sum((fx[:, None] >= pow2[None, :])
                                         .astype(jnp.int32), axis=1)))
    return jnp.zeros((n_bins,), I64).at[bins].add(1)


# --------------------------------------------------------------------------
# hash_fetch_add_batch: sequential batched open-addressing fetch-add
# --------------------------------------------------------------------------

def _hash_idx(key, n):
    h = key.astype(jnp.uint64) * jnp.uint64(_HASH_MULT)
    return ((h >> jnp.uint64(33)) % jnp.uint64(n)).astype(jnp.int32)


def hash_fetch_add_batch(keys_tbl, used_tbl, vals_tbl, keys, deltas, valid):
    """Apply fetch-add(key[i], delta[i]) for each valid event IN ORDER.
    Semantics identical to maps.j_hash_fetch_add applied sequentially."""
    n = keys_tbl.shape[0]
    ar = jnp.arange(n, dtype=jnp.int32)

    def one(tbl, ev):
        kt, ut, vt = tbl
        key, delta, ok = ev
        start = _hash_idx(key, n)
        order = (start + ar) % n
        u_o = ut[order]
        occupied = u_o == 1           # tri-state used: 2 = tombstone
        match = occupied & (kt[order] == key)
        free = ~occupied              # tombstone or empty: insertable
        empty = u_o == 0              # chain terminator
        big = jnp.int32(n)
        fm = jnp.min(jnp.where(match, ar, big))
        ff = jnp.min(jnp.where(free, ar, big))
        fe = jnp.min(jnp.where(empty, ar, big))
        found = (fm < big) & (fm < fe)
        has_free = ff < big
        slot = order[jnp.clip(fm, 0, n - 1)]
        fslot = order[jnp.clip(ff, 0, n - 1)]
        tgt = jnp.where(found, slot, fslot)
        do = ok & (found | has_free)
        newv = jnp.where(found, vt[tgt] + delta, delta)
        kt = kt.at[tgt].set(jnp.where(do, key, kt[tgt]))
        ut = ut.at[tgt].set(jnp.where(do, jnp.int64(1), ut[tgt]))
        vt = vt.at[tgt].set(jnp.where(do, newv, vt[tgt]))
        return (kt, ut, vt), jnp.int64(0)

    (kt, ut, vt), _ = lax.scan(one, (keys_tbl, used_tbl, vals_tbl),
                               (keys, deltas, valid))
    return kt, ut, vt


# --------------------------------------------------------------------------
# ringbuf_emit_batch: append valid rows at head, head advances per valid row
# --------------------------------------------------------------------------

def ringbuf_emit_batch(data, head, rows, valid):
    """data: i64[cap, W]; head: i64[1]; rows: i64[B, W]; valid: bool[B]."""
    cap = data.shape[0]

    def one(carry, ev):
        d, h = carry
        row, ok = ev
        slot = (h[0] % cap).astype(jnp.int32)
        d = d.at[slot].set(jnp.where(ok, row, d[slot]))
        h = h.at[0].add(jnp.where(ok, jnp.int64(1), jnp.int64(0)))
        return (d, h), jnp.int64(0)

    (d, h), _ = lax.scan(one, (data, head), (rows, valid))
    return d, h
