"""Serving driver: batched requests against a (smoke or full) model with
optional bpftime instrumentation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --requests 8 --max-new 8 [--admit-limit 12]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--admit-limit", type=int, default=0,
                    help="reject prompts longer than this via eBPF filter")
    args = ap.parse_args(argv)

    from repro.configs import registry
    from repro.core.runtime import BpftimeRuntime
    from repro.models import registry as MR
    from repro.serve.engine import Request, ServeEngine

    rt = None
    if args.admit_limit:
        rt = BpftimeRuntime()
        pid = rt.load_asm("admit", f"""
            ldxdw r6, [r1+ctx:arg1]
            jle r6, {args.admit_limit}, ok
            mov r1, 429
            call override_return
            ok:
            mov r0, 0
            exit
        """, [], "filter")
        rt.attach(pid, "filter:sys_serve_admit")

    cfg = registry.smoke(args.arch)
    params = MR.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, slots=args.slots,
                         max_seq=args.max_seq, runtime=rt)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(3, 24))).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    engine.submit_all(reqs)
    done = sum(1 for r in reqs if r.done and not r.rejected)
    rej = sum(1 for r in reqs if r.rejected)
    print(f"served {done}, rejected {rej}, decode steps "
          f"{engine.step_count}")
    for r in reqs[:4]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out[:8]}"
              f"{' (rejected)' if r.rejected else ''}")


if __name__ == "__main__":
    main()
