"""Production mesh construction.

Importing this module never touches jax device state — meshes are built
inside functions only (dryrun.py sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import math


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    import jax
    import numpy as np
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — run under "
            "dryrun.py (XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    arr = np.asarray(devs[:n]).reshape(shape)
    from jax.sharding import Mesh
    return Mesh(arr, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over whatever devices exist (tests / CPU runs)."""
    import jax
    import numpy as np
    n = math.prod(shape)
    devs = jax.devices()[:n]
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs).reshape(shape), axes)
