"""Per-architecture production training presets: microbatching, optimizer
choice (Adafactor for >=50B — two fp32 Adam moments on 1T params cannot fit
a 4TB pod, see EXPERIMENTS.md memory table), and param dtype."""
from __future__ import annotations

from repro.configs.base import TrainConfig

# arch id -> (micro_bs for train_4k, optimizer, param_dtype)
_PRESETS = {
    "qwen2-0.5b":            (0,  "adamw",     "float32"),
    "phi4-mini-3.8b":        (64, "adamw",     "float32"),
    "llama3.2-1b":           (0,  "adamw",     "float32"),
    "starcoder2-15b":        (32, "adamw",     "bfloat16"),
    "qwen2-vl-72b":          (16, "adafactor", "bfloat16"),
    "seamless-m4t-medium":   (0,  "adamw",     "float32"),
    "mamba2-780m":           (0,  "adamw",     "float32"),
    "llama4-scout-17b-a16e": (16, "adafactor", "bfloat16"),
    "kimi-k2-1t-a32b":       (16, "adafactor", "bfloat16"),
    "jamba-v0.1-52b":        (32, "adafactor", "bfloat16"),
}


def train_config(arch: str, **overrides) -> TrainConfig:
    import os
    micro, opt, pdt = _PRESETS[arch]
    if os.environ.get("REPRO_MICRO"):        # §Perf sweep override
        micro = int(os.environ["REPRO_MICRO"])
    kw = dict(microbatch=micro, optimizer=opt, param_dtype=pdt, remat=True)
    kw.update(overrides)
    return TrainConfig(**kw)
