"""End-to-end training driver (runs for real on CPU with smoke configs;
lowers for the production mesh via dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 [--probes obj.json ...] [--shm /dev/shm/bpftime]

Integration points exercised here (the paper's workflow, §3.2):
  * probes attach/detach between steps WITHOUT restarting training —
    attach_epoch changes re-jit the step, state carries over;
  * a shm control plane lets an external daemon inject programs live;
  * per-step syscalls (data fetch / checkpoint / step begin+end) run their
    eBPF hooks; filter programs can veto batches or checkpoints.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_training(arch: str, *, steps: int = 20, smoke: bool = True,
                 runtime=None, shm_dir: str | None = None,
                 worker_id: str | None = None,
                 worker_group: str | None = None,
                 ckpt_dir: str | None = None, save_every: int = 0,
                 probe_mode: str = "scan", seq_len: int = 64,
                 batch: int = 8, microbatch: int = 0, log_every: int = 10,
                 on_step=None, max_data_skips: int = 1000,
                 cache_dir: str | None = None):
    from repro.configs import registry
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.data.pipeline import SyntheticDataset
    from repro.train.train_step import init_train_state, make_train_step
    from repro.ckpt import checkpoint as CK

    cfg = registry.smoke(arch) if smoke else registry.get(arch)
    tcfg = TrainConfig(microbatch=microbatch, remat=True, warmup=10,
                       total_steps=steps)
    shape = ShapeConfig("driver", seq_len, batch, "train")
    if runtime is not None and cache_dir:
        # explicit cache dir wins over the <shm>/cache default setup_shm
        # would otherwise join
        runtime.enable_artifact_cache(cache_dir)
    if runtime is not None and shm_dir:
        # worker_id=None keeps the single-process layout; with an id, this
        # trainer joins <shm_dir>/workers/<wid>/ so a fleet daemon can
        # aggregate several trainers into one global map view; worker_group
        # additionally names the node aggregator that folds this trainer in
        # a hierarchical fleet (DESIGN.md §15)
        runtime.setup_shm(shm_dir, worker_id=worker_id, group=worker_group)

    data = SyntheticDataset(cfg, shape, tcfg, runtime=runtime)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg, runtime)

    jit_cache: dict[int, object] = {}

    def build_step():
        return jax.jit(
            make_train_step(cfg, tcfg, runtime, probe_mode=probe_mode))

    def _call_sig(batch_np):
        return jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)),
            (state, batch_np))

    # trace facts the layout fingerprint can't see from the runtime alone:
    # model + batch geometry + schedule length all shape the compiled graph
    aot_key = ("train_step", arch, bool(smoke), seq_len, batch, microbatch,
               probe_mode, steps)

    def get_step_fn(batch_np):
        epoch = runtime.attach_epoch if runtime else 0
        if epoch not in jit_cache:
            # a background-promoted table link pre-compiles the new epoch's
            # step (core/promote.py) — never block the loop on a re-jit
            # that promotion already paid for
            promoted = runtime.take_promoted_step() if runtime else None
            if promoted is None and runtime is not None \
                    and runtime.artifact_cache is not None:
                # fleet cold-join fast path: reuse another worker's AOT
                # executable (or compile-and-store for the next joiner)
                compiled, _hit = runtime.aot_step(
                    build_step, _call_sig(batch_np), extra_key=aot_key)
                jit_cache[epoch] = compiled
            else:
                jit_cache[epoch] = promoted or build_step()
        return jit_cache[epoch]

    def arm_promotion(batch_np):
        """Hand the promotion engine the loop's step builder + the exact
        call signature, so table-lane links injected later (poll_control)
        converge to the fused lane without a foreground compile."""
        if runtime is None or runtime.live is None \
                or runtime._promoter is not None:
            return
        sig = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)),
            (state, batch_np))
        runtime.enable_promotion(build_step, sig)

    history = []
    t0 = time.time()
    skips = 0          # consecutive vetoed/faulted batches: bounded spin
    while int(state["step"]) < steps:
        if runtime is not None:
            runtime.poll_control()          # daemon injection point
            # push any live-table change onto the running compiled step
            # (no-op unless a live attach/detach happened since last sync)
            state["maps"] = runtime.sync_live_table(state["maps"])
            runtime.syscalls.invoke("sys_step_begin", [int(state["step"])],
                                    impl=lambda: None)
        batch_np = data.next()
        if batch_np is None:                 # vetoed/faulted batch
            skips += 1
            if max_data_skips and skips >= max_data_skips:
                raise RuntimeError(
                    f"data pipeline yielded no batch {skips} times in a "
                    f"row — a filter is vetoing every fetch")
            continue
        skips = 0
        arm_promotion(batch_np)              # no-op after the first batch
        step_fn = get_step_fn(batch_np)      # re-jits only on attach change
        state, metrics = step_fn(state, batch_np)
        history.append({k: float(np.asarray(v)) for k, v in metrics.items()})
        s = int(state["step"])
        if runtime is not None:
            runtime.publish(state["maps"])
            runtime.syscalls.invoke(
                "sys_step_end", [s, int(1e6 * (time.time() - t0))],
                impl=lambda: None)
        if ckpt_dir and save_every and s % save_every == 0:
            CK.save(ckpt_dir, s, state, runtime=runtime, blocking=True)
        if on_step is not None:
            on_step(s, state, metrics)
        if log_every and s % log_every == 0:
            print(f"step {s}: loss={history[-1]['loss']:.4f} "
                  f"gnorm={history[-1]['grad_norm']:.3f} "
                  f"({(time.time() - t0) / max(s, 1):.2f}s/step)")
    return state, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--shm")
    ap.add_argument("--worker-id",
                    help="join the fleet layout as <shm>/workers/<id>/ "
                         "(multi-trainer aggregation, DESIGN.md §10)")
    ap.add_argument("--worker-group",
                    help="aggregation group: the node aggregator (`node "
                         "run <group>`) that folds this trainer in a "
                         "hierarchical fleet (DESIGN.md §15)")
    ap.add_argument("--ckpt")
    ap.add_argument("--save-every", type=int, default=0)
    ap.add_argument("--cache",
                    help="AOT artifact cache directory (defaults to "
                         "<shm>/cache when --shm is given)")
    args = ap.parse_args(argv)

    from repro.core.runtime import BpftimeRuntime
    rt = BpftimeRuntime() if (args.shm or args.cache) else None
    state, hist = run_training(
        args.arch, steps=args.steps, smoke=args.smoke, runtime=rt,
        shm_dir=args.shm, worker_id=args.worker_id,
        worker_group=args.worker_group, ckpt_dir=args.ckpt,
        save_every=args.save_every, batch=args.batch, seq_len=args.seq,
        cache_dir=args.cache)
    print(f"final loss {hist[-1]['loss']:.4f} after {len(hist)} steps")


if __name__ == "__main__":
    main()
