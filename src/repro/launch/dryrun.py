import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, extract memory/cost/collective analysis, emit JSON for
EXPERIMENTS.md §Dry-run and §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod] [--probes] [--out results/]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST precede every jax import (jax locks the
device count at first init) — hence the unusual module layout.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                      # noqa: E402

from repro.configs import SHAPES, registry, shape_applicable   # noqa: E402
from repro.dist import sharding as SH       # noqa: E402
from repro.launch import analysis, presets, specs as SP        # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402

SDS = jax.ShapeDtypeStruct


def _state_shardings(state_shape, mesh):
    def one(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        return jax.sharding.NamedSharding(
            mesh, SH.spec_for(keys, leaf.shape, mesh))
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shape)
    return jax.tree_util.tree_unflatten(treedef,
                                        [one(p, l) for p, l in flat])


def _maybe_probe_runtime(cfg):
    """Representative bpftime instrumentation: per-layer activation stats
    into a hash map + rms histogram + router load for MoE."""
    from repro.core import maps as M
    from repro.core.runtime import BpftimeRuntime
    rt = BpftimeRuntime()
    rt.exec_mode = "scan"
    pid = rt.load_asm("layer_counts", """
        mov r9, r1                  ; save ctx across calls
        ldxdw r6, [r1+ctx:layer]
        stxdw [r10-8], r6
        lddw r1, map:layer_counts
        mov r2, r10
        add r2, -8
        mov r3, 1
        call map_fetch_add
        ldxdw r2, [r9+ctx:rms]
        lddw r1, map:rms_hist
        call hist_add
        mov r0, 0
        exit
    """, [M.MapSpec("layer_counts", M.MapKind.ARRAY, max_entries=128),
          M.MapSpec("rms_hist", M.MapKind.LOG2HIST)], "uprobe")
    rt.attach(pid, "uprobe:block")
    rt.attach(pid, "uretprobe:block")
    return rt


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               probes: bool = False, probe_mode: str = "scan",
               donate: bool = True):
    """Returns (jitted, args, mesh, meta) ready to lower."""
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, None, None, {"skip": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    tcfg = presets.train_config(arch)
    rt = _maybe_probe_runtime(cfg) if probes else None

    if shape.mode == "train":
        from repro.train.train_step import (abstract_train_state,
                                            make_train_step)
        state_shape = abstract_train_state(cfg, tcfg, rt)
        state_sh = _state_shardings(state_shape, mesh)
        batch = SP.train_batch_specs(cfg, shape, tcfg)
        batch_sh = SP.batch_shardings(batch, mesh, cfg, shape, tcfg)
        step = make_train_step(cfg, tcfg, rt, probe_mode=probe_mode)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,) if donate else ())
        args = (state_shape, batch)
    elif shape.mode == "prefill":
        from repro.serve.steps import make_prefill_step
        params = SP.abstract_params(cfg, tcfg.param_dtype)
        params_sh = _state_shardings(params, mesh)
        batch = SP.prefill_batch_specs(cfg, shape)
        batch_sh = SP.batch_shardings(
            batch, mesh, cfg, shape, presets.train_config(arch,
                                                          microbatch=0))
        dspec = SP.decode_specs(cfg, shape, tcfg.param_dtype)
        cache_sh = SP.cache_shardings(dspec["cache"], mesh, cfg, shape)
        maps = (jax.eval_shape(rt.init_device_maps) if rt else {})
        maps_sh = jax.tree.map(lambda _: SH.replicated(mesh), maps)
        step = make_prefill_step(cfg, rt)
        jitted = jax.jit(step, in_shardings=(params_sh, batch_sh, cache_sh,
                                             maps_sh),
                         donate_argnums=(2,) if donate else ())
        args = (params, batch, dspec["cache"], maps)
    else:  # decode
        from repro.serve.steps import make_decode_step
        params = SP.abstract_params(cfg, tcfg.param_dtype)
        params_sh = _state_shardings(params, mesh)
        dspec = SP.decode_specs(cfg, shape, tcfg.param_dtype)
        cache_sh = SP.cache_shardings(dspec["cache"], mesh, cfg, shape)
        tok_sh = SP.batch_shardings(
            {"tokens": dspec["tokens"]}, mesh, cfg, shape,
            presets.train_config(arch, microbatch=0))["tokens"]
        maps = (jax.eval_shape(rt.init_device_maps) if rt else {})
        maps_sh = jax.tree.map(lambda _: SH.replicated(mesh), maps)
        step = make_decode_step(cfg, rt, probe_mode=probe_mode)
        jitted = jax.jit(
            step,
            in_shardings=(params_sh, tok_sh, cache_sh, maps_sh,
                          SH.replicated(mesh)),
            donate_argnums=(2,) if donate else ())
        args = (params, dspec["tokens"], dspec["cache"], maps,
                SDS((), jnp.int32))

    meta = {"arch": arch, "shape": shape_name, "mode": shape.mode,
            "mesh": list(mesh.devices.shape), "multi_pod": multi_pod,
            "probes": probes}
    return jitted, args, mesh, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             probes: bool = False, probe_mode: str = "scan",
             verbose: bool = True) -> dict:
    t0 = time.time()
    jitted, args, mesh, meta = build_cell(
        arch, shape_name, multi_pod=multi_pod, probes=probes,
        probe_mode=probe_mode)
    if jitted is None:
        return meta
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    with SH.use_mesh(mesh):
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    out = dict(meta)
    out["lower_s"] = round(t_lower, 1)
    out["compile_s"] = round(t_compile, 1)

    try:
        ma = compiled.memory_analysis()
        out["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # CPU backend may not support it
        out["memory_analysis"] = {"error": str(e)}

    out["analytic_state_bytes_global"] = _analytic_bytes(args, mesh)

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):          # newer jax: list of dicts
        cost = cost[0] if cost else {}
    out["cost_xla_once"] = {          # XLA's own numbers (loop bodies x1)
        k: float(v) for k, v in cost.items()
        if isinstance(v, (int, float)) and
        k in ("flops", "bytes accessed", "optimal_seconds")}

    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text()
    from repro.launch import hlo_cost
    hc = hlo_cost.analyze(text)
    out["collectives"] = {
        "counts": {k: int(v) for k, v in hc.collective_counts.items()},
        "bytes_by_type": {k: float(v)
                          for k, v in hc.collective_bytes.items()},
        "wire_bytes_per_dev": hc.coll_wire,
        "flash_interior_bytes": hc.coll_bytes_flash_interior,
        "wire_fused_per_dev": hc.coll_wire_fused}
    del text

    chips = int(jnp.prod(jnp.asarray(mesh.devices.shape)))
    mf = analysis.model_flops(cfg, shape)
    rf = analysis.roofline_from_hlo(hc, chips, mf, fused_attention=True)
    out["roofline"] = rf.to_dict()
    out["roofline"]["bytes_flash_interior_per_dev"] = hc.bytes_flash_interior
    rf_unfused = analysis.roofline_from_hlo(hc, chips, mf,
                                            fused_attention=False)
    out["roofline_unfused_attention"] = {
        "memory_s": rf_unfused.memory_s,
        "dominant": rf_unfused.dominant,
        "roofline_fraction": rf_unfused.roofline_fraction}
    out["total_s"] = round(time.time() - t0, 1)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={out['mesh']} "
              f"probes={probes}: compile {out['compile_s']}s, dominant="
              f"{rf.dominant}, terms=({rf.compute_s:.4f}, {rf.memory_s:.4f},"
              f" {rf.collective_s:.4f})s, roofline_frac="
              f"{rf.roofline_fraction:.3f}")
    return out


def _analytic_bytes(args, mesh) -> int:
    """Sum per-device bytes of all inputs (leaf bytes / shard count),
    assuming even sharding — the state-fits check for EXPERIMENTS.md."""
    total = 0
    for leaf in jax.tree.leaves(args):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(jnp.dtype(leaf.dtype).itemsize *
                         max(1, jnp.prod(jnp.asarray(leaf.shape))
                             if leaf.shape else 1))
    return total  # global bytes; per-dev table derives in the report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--probes", action="store_true")
    ap.add_argument("--probe-mode", default="scan")
    ap.add_argument("--out", default="results")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in sorted(registry.ARCHS):
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{'mp' if args.multi_pod else 'sp'}" + \
              ("__probes" if args.probes else "")
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[dryrun] skip existing {tag}")
            continue
        try:
            res = run_cell(arch, shape, multi_pod=args.multi_pod,
                           probes=args.probes, probe_mode=args.probe_mode)
        except Exception as e:
            failures += 1
            res = {"arch": arch, "shape": shape, "error": str(e),
                   "traceback": traceback.format_exc()}
            print(f"[dryrun] FAIL {arch} x {shape}: {e}")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
