"""Compiled-artifact analysis: collective-byte extraction from partitioned
HLO + three-term roofline (TPU v5e constants).

Wire-byte model per collective (result/operand shapes in the partitioned
module are PER-DEVICE):
    all-reduce        2x result bytes   (ring: reduce-scatter + all-gather)
    all-gather        1x result bytes   (each device receives ~result)
    reduce-scatter    1x operand bytes ~= result * shards (we use result*1,
                      operands unavailable cheaply; noted underestimate)
    all-to-all        1x result bytes
    collective-permute 1x result bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# ---- TPU v5e
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    bytes_by_type: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_type.values()))

    @property
    def wire_bytes(self) -> float:
        return float(sum(_WIRE_FACTOR[k] * v
                         for k, v in self.bytes_by_type.items()))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device result bytes of every collective op in partitioned
    HLO. Handles `%x = f32[..] all-reduce(..)` and tuple-result forms.
    `-start` variants counted once (`-done` ignored)."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            tok = f" {op}(" if f" {op}(" in line else (
                f" {op}-start(" if f" {op}-start(" in line else None)
            if tok is None:
                continue
            lhs = line.split(tok)[0]
            if "=" in lhs:
                lhs = lhs.split("=", 1)[1]
            total = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(lhs))
            st.counts[op] = st.counts.get(op, 0) + 1
            st.bytes_by_type[op] = st.bytes_by_type.get(op, 0) + total
            break
    return st


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops_total: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        tot = self.hlo_flops_per_dev * self.chips
        return self.model_flops_total / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """fraction of chip peak the step would achieve if it ran exactly at
        the dominant-term time, counting only MODEL flops as useful."""
        if self.bound_s <= 0:
            return 0.0
        ideal = self.model_flops_total / (self.chips * PEAK_FLOPS)
        return ideal / self.bound_s

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops_per_dev": self.hlo_flops_per_dev,
            "hlo_bytes_per_dev": self.hlo_bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def roofline(cost: dict, coll: CollectiveStats, chips: int,
             model_flops_total: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll.wire_bytes / ICI_BW,
        hlo_flops_per_dev=flops,
        hlo_bytes_per_dev=byts,
        coll_bytes_per_dev=coll.wire_bytes,
        model_flops_total=model_flops_total,
        chips=chips,
    )


def roofline_from_hlo(hc, chips: int, model_flops_total: float,
                      fused_attention: bool = True) -> Roofline:
    """Roofline from the trip-count-aware analyzer (hlo_cost.HloCost).
    fused_attention=True uses the memory term with flash-interior bytes
    removed — valid because the shipped Pallas flash kernel keeps them in
    VMEM on the TPU target (kernels/flash_attention.py, validated in
    tests/test_flash_kernel.py)."""
    byts = hc.bytes_fused if fused_attention else hc.bytes
    return Roofline(
        compute_s=hc.flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=hc.coll_wire / ICI_BW,
        hlo_flops_per_dev=hc.flops,
        hlo_bytes_per_dev=byts,
        coll_bytes_per_dev=hc.coll_wire,
        model_flops_total=model_flops_total,
        chips=chips,
    )


def model_flops(cfg, shape) -> float:
    """Useful step flops: 6*N*D train / 2*N*D inference (N = active params,
    embedding lookup table excluded per the Chinchilla convention) PLUS the
    causal-attention quadratic term (2*B*S^2*H*hd fwd; x3 train for bwd) —
    without it, attention-heavy cells (small d_model, long S) would show
    absurd "waste"."""
    pc = cfg.param_counts()
    n = pc["active"] - cfg.vocab_size * cfg.d_model
    B, S = shape.global_batch, shape.seq_len
    n_attn = sum(1 for i in range(cfg.num_layers or
                                  (cfg.enc_layers + cfg.dec_layers))
                 if cfg.block_kind(i) == "attn")
    attn_fwd = 2.0 * B * S * S * cfg.num_heads * cfg.hd * n_attn
    if shape.mode == "train":
        return 6.0 * n * B * S + 3.0 * attn_fwd
    if shape.mode == "prefill":
        return 2.0 * n * B * S + attn_fwd
    # decode: one token attends the full cache (linear, not quadratic)
    attn_dec = 4.0 * B * S * cfg.num_heads * cfg.hd * n_attn
    return 2.0 * n * B + attn_dec
