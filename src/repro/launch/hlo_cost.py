"""Trip-count-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE, which
undercounts scanned layer stacks and microbatch loops by their trip counts
(verified experimentally — see EXPERIMENTS.md §Roofline methodology). This
module re-derives flops / HBM bytes / collective bytes from the optimized
HLO text, multiplying each computation's costs by the product of enclosing
`known_trip_count`s.

Counting rules:
  flops       2 * prod(result_dims) * prod(lhs_contracting_dims) per dot
  bytes       result + operand bytes of every top-level instruction
              (fusion internals excluded — their IO is the fusion node's;
              parameter/tuple/gte/bitcast/constant excluded)
  collectives result bytes by type, with the same multipliers
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_OPERANDS_RE = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_OPS = ("parameter(", "tuple(", "get-tuple-element(", "bitcast(",
             "constant(", "after-all(", "partition-id(", "iota(",
             "copy-done(", "all-reduce-done(", "all-gather-done(")


def _dims_prod(dims: str) -> int:
    if not dims:
        return 1
    return math.prod(int(d) for d in dims.split(","))


def _first_shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        b = _DTYPE_BYTES.get(dt)
        if b:
            total += b * _dims_prod(dims)
    return total


@dataclass
class _Instr:
    name: str
    rhs: str


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # name -> (dtype, dims)


def _parse_computations(text: str) -> tuple[dict, str]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and line.endswith("{") and ") -> " in line:
            cur = _Comp(hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        cur.instrs.append(_Instr(name, rhs))
        sm = _SHAPE_RE.search(rhs)
        if sm:
            cur.shapes[name] = (sm.group(1), sm.group(2))
    return comps, entry


def _instr_flops(ins: _Instr, comp: _Comp) -> float:
    if " dot(" not in ins.rhs and not ins.rhs.startswith("dot("):
        return 0.0
    res = _SHAPE_RE.search(ins.rhs)
    if not res:
        return 0.0
    res_n = _dims_prod(res.group(2))
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rhs)
    # operands may carry inline type annotations (newer XLA prints
    # `dot(f32[32,128]{1,0} %lhs, ...)`) or appear bare (`dot(lhs, rhs)`)
    ops = re.search(r"dot\([^)%]*?%([\w.\-]+)", ins.rhs)
    if ops is None:
        ops = re.search(r"dot\(\s*([\w.\-]+)\s*[,)]", ins.rhs)
    contract = 1
    if cm and ops:
        lhs_shape = comp.shapes.get(ops.group(1))
        if lhs_shape:
            dims = ([int(d) for d in lhs_shape[1].split(",")]
                    if lhs_shape[1] else [])
            for ci in (cm.group(1).split(",") if cm.group(1) else []):
                ci = int(ci)
                if ci < len(dims):
                    contract *= dims[ci]
    return 2.0 * res_n * contract


def _operand_names(rhs: str) -> list[str]:
    return re.findall(r"%([\w.\-]+)", rhs.split("(", 1)[-1])


def _instr_bytes(ins: _Instr, comp: _Comp) -> float:
    rhs = ins.rhs

    def _bytes_of(name):
        sh = comp.shapes.get(name)
        return (_DTYPE_BYTES.get(sh[0], 0) * _dims_prod(sh[1])) if sh else 0

    # in-place windowed ops: traffic = the window, not the whole buffer
    if " dynamic-update-slice(" in rhs or " dynamic-update-slice-start(" in rhs:
        ops = _operand_names(rhs)
        return float(2 * _bytes_of(ops[1])) if len(ops) > 1 else 0.0
    if " dynamic-slice(" in rhs:
        res = _SHAPE_RE.search(rhs)
        if res:
            return float(2 * _DTYPE_BYTES.get(res.group(1), 0)
                         * _dims_prod(res.group(2)))
        return 0.0
    if any(op in rhs for op in _SKIP_OPS):
        return 0.0
    total = 0
    res = _SHAPE_RE.search(rhs)
    if res:
        b = _DTYPE_BYTES.get(res.group(1), 0)
        total += b * _dims_prod(res.group(2))
        # tuple results: count every element shape before the op name
        head = rhs.split("(", 1)[0]
        extra = _SHAPE_RE.findall(head)
        if len(extra) > 1:
            total = sum(_DTYPE_BYTES.get(dt, 0) * _dims_prod(dd)
                        for dt, dd in extra)
    for opname in _operand_names(rhs):
        total += _bytes_of(opname)
    return float(total)


def _attn_matrix_shaped(rhs: str) -> bool:
    """Attention-matrix residuals (flash bwd-through-scan stacking): >=5
    dims with both trailing dims >= 1024. No other tensor in this model
    family has that signature (weights are 2-3D; activations end in
    d_model or hd)."""
    m = _SHAPE_RE.search(rhs)
    if not m or not m.group(2):
        return False
    dims = [int(d) for d in m.group(2).split(",")]
    return len(dims) >= 5 and dims[-1] >= 1024 and dims[-2] >= 1024


def _instr_collective(ins: _Instr) -> tuple[str, float] | None:
    rhs = ins.rhs
    for op in _COLLECTIVES:
        if f" {op}(" in f" {rhs}" or f"{op}-start(" in rhs:
            head = rhs.split(op, 1)[0]
            return op, float(_first_shape_bytes(head))
    return None


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    # HBM traffic of ops tagged 'flash_interior' (jax.named_scope in
    # models.layers.flash_attention): real in this XLA lowering, zero when
    # the Pallas flash kernel (kernels/flash_attention.py) runs on TPU.
    bytes_flash_interior: float = 0.0
    collective_counts: dict = field(default_factory=dict)
    collective_bytes: dict = field(default_factory=dict)
    # collectives emitted INSIDE the flash interior (GSPMD resharding of
    # attention blocks): absent in the shard_map+Pallas deployment where
    # each shard's interior is local (ring-style k/v movement is counted
    # separately in the roofline notes).
    coll_bytes_flash_interior: float = 0.0

    @property
    def bytes_fused(self) -> float:
        return self.bytes - self.bytes_flash_interior

    coll_wire_flash_interior: float = 0.0

    @property
    def coll_wire_fused(self) -> float:
        return max(self.coll_wire - self.coll_wire_flash_interior, 0.0)

    @property
    def coll_total(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def coll_wire(self) -> float:
        f = {"all-reduce": 2.0}
        return sum(v * f.get(k, 1.0)
                   for k, v in self.collective_bytes.items())


def analyze(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        return HloCost()

    # computations whose instruction costs are accounted at the call site:
    # fusion bodies and reduction/scatter/sort combiner lambdas — but NOT
    # call() targets (those execute as real computations).
    fusion_called: set[str] = set()
    _combiner_ops = (" reduce(", " reduce-window(", " scatter(", " sort(",
                     " map(", " select-and-scatter(", " reduce-scatter(",
                     " all-reduce(", " all-reduce-start(")
    for c in comps.values():
        for ins in c.instrs:
            if " fusion(" in ins.rhs or ins.rhs.startswith("fusion("):
                m = _CALLS_RE.search(ins.rhs)
                if m:
                    fusion_called.add(m.group(1))
            if any(op in f" {ins.rhs}" for op in _combiner_ops):
                for m in _TOAPPLY_RE.finditer(ins.rhs):
                    fusion_called.add(m.group(1))

    cost = HloCost()
    seen: set[tuple[str, float]] = set()

    def walk(name: str, mult: float, interior: bool = False):
        """interior=True: this computation runs inside the flash-attention
        scan (the while op carried the tag); XLA-synthesized copies inside
        have no metadata, so interior-ness propagates structurally."""
        comp = comps.get(name)
        if comp is None or name in fusion_called:
            return
        for ins in comp.instrs:
            cost.flops += mult * _instr_flops(ins, comp)
            b = mult * _instr_bytes(ins, comp)
            cost.bytes += b
            if interior or "flash_interior" in ins.rhs or \
                    _attn_matrix_shaped(ins.rhs):
                cost.bytes_flash_interior += b
            if " fusion(" in ins.rhs or ins.rhs.startswith("fusion("):
                # dots INSIDE fusions still burn MXU flops (IO was already
                # charged at this fusion node)
                m = _CALLS_RE.search(ins.rhs)
                fc = comps.get(m.group(1)) if m else None
                if fc is not None:
                    for fins in fc.instrs:
                        cost.flops += mult * _instr_flops(fins, fc)
            coll = _instr_collective(ins)
            if coll:
                op, cb = coll
                cost.collective_counts[op] = \
                    cost.collective_counts.get(op, 0) + mult
                cost.collective_bytes[op] = \
                    cost.collective_bytes.get(op, 0.0) + mult * cb
                if interior or "flash_interior" in ins.rhs or \
                        _attn_matrix_shaped(ins.rhs):
                    cost.coll_bytes_flash_interior += mult * cb
                    wf = 2.0 if op == "all-reduce" else 1.0
                    cost.coll_wire_flash_interior += mult * cb * wf
            if " while(" in ins.rhs or ins.rhs.startswith("while("):
                bm = _BODY_RE.search(ins.rhs)
                cm = _COND_RE.search(ins.rhs)
                tm = _TRIP_RE.search(ins.rhs)
                if tm:
                    trip = float(tm.group(1))
                else:
                    # scan-lowered loops without the annotation: infer the
                    # bound from the largest constant in the condition
                    trip = 1.0
                    if cm and cm.group(1) in comps:
                        consts = [
                            int(v) for i2 in comps[cm.group(1)].instrs
                            for v in re.findall(r"constant\((\d+)\)", i2.rhs)]
                        if consts:
                            trip = float(max(consts))
                sub_interior = interior or "flash_interior" in ins.rhs
                if bm:
                    walk(bm.group(1), mult * trip, sub_interior)
                if cm:
                    walk(cm.group(1), mult * trip, sub_interior)
            elif (" call(" in ins.rhs or " conditional(" in ins.rhs
                  or ins.rhs.startswith("call(")):
                sub_interior = interior or "flash_interior" in ins.rhs
                for m in re.finditer(
                        r"(?:to_apply|true_computation|"
                        r"false_computation)=%?([\w.\-]+)", ins.rhs):
                    walk(m.group(1), mult, sub_interior)
                bm = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
                if bm:
                    for name2 in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                        walk(name2, mult, sub_interior)

    walk(entry, 1.0)
    return cost
