"""ShapeDtypeStruct input stand-ins + shardings for every
(arch x shape x mode) cell — the shannon/kernels pattern: weak-type-correct,
shardable, zero device allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.dist import sharding as SH
from repro.models import registry as MR

SDS = jax.ShapeDtypeStruct


def _tok(shape):
    return SDS(shape, jnp.int32)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      tcfg: TrainConfig):
    B, S = shape.global_batch, shape.seq_len
    Ft = cfg.frontend_tokens
    m = tcfg.microbatch or 0
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def mb(x):  # wrap leading microbatch dims
        if m and B % m == 0 and B // m > 1:
            return (B // m, m) + x
        return (B,) + x

    batch = {}
    if cfg.family == "encdec":
        batch["enc_embeds"] = SDS(mb((S, cfg.d_model)), dt)
        batch["tokens"] = _tok(mb((S,)))
        batch["labels"] = _tok(mb((S,)))
    elif cfg.frontend != "none":
        batch["embeds"] = SDS(mb((Ft, cfg.d_model)), dt)
        batch["tokens"] = _tok(mb((S - Ft,)))
        batch["labels"] = _tok(mb((S,)))
        if cfg.rope_kind == "mrope":
            batch["positions"] = _tok(mb((S, 3)))
    else:
        batch["tokens"] = _tok(mb((S,)))
        batch["labels"] = _tok(mb((S,)))
    return batch


def batch_shardings(batch_specs, mesh, cfg: ModelConfig,
                    shape: ShapeConfig, tcfg: TrainConfig):
    micro = bool(tcfg and tcfg.microbatch and
                 shape.global_batch // max(tcfg.microbatch, 1) > 1)

    def shard_one(path_key, leaf):
        nd = len(leaf.shape)
        # batch dim position: 1 if microbatched (dim0 = microbatch count)
        bpos = 1 if micro else 0
        bsz = leaf.shape[bpos]
        spec = SH.batch_spec(mesh, bsz, extra_dims=nd - bpos - 1)
        if micro:
            spec = P(None, *spec)
        spec = SH.fit_spec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return {k: shard_one(k, v) for k, v in batch_specs.items()}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, param_dtype):
    """(tokens, cache, maps, step) stand-ins for serve decode."""
    B, S = shape.global_batch, shape.seq_len
    cdt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "encdec":
        cache = jax.eval_shape(
            lambda: MR.make_cache(cfg, B, S, cdt, enc_seq=4096))
    else:
        cache = jax.eval_shape(lambda: MR.make_cache(cfg, B, S, cdt))
    return {
        "tokens": _tok((B, 1)),
        "cache": cache,
        "step": SDS((), jnp.int32),
    }


def cache_shardings(cache_specs, mesh, cfg: ModelConfig,
                    shape: ShapeConfig):
    B = shape.global_batch

    def one(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        nd = len(leaf.shape)
        if keys and keys[-1] in ("k", "v", "xk", "xv") and nd == 5:
            return NamedSharding(
                mesh, SH.kv_cache_spec(mesh, B, leaf.shape[3]))
        if keys and keys[-1] == "pos":
            return NamedSharding(mesh, P())
        # mamba states [n, B, ...]: batch over fsdp if divisible
        fs = SH.fsdp_axes(mesh)
        size = int(np.prod([mesh.shape[a] for a in fs]))
        if nd >= 2 and leaf.shape[1] == B and B % size == 0:
            return NamedSharding(
                mesh, P(None, fs if len(fs) > 1 else fs[0],
                        *([None] * (nd - 2))))
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    Ft = cfg.frontend_tokens
    batch = {}
    if cfg.family == "encdec":
        batch["enc_embeds"] = SDS((B, 4096, cfg.d_model), dt)
        batch["tokens"] = _tok((B, S))
    elif cfg.frontend != "none":
        batch["embeds"] = SDS((B, Ft, cfg.d_model), dt)
        batch["tokens"] = _tok((B, S - Ft))
        if cfg.rope_kind == "mrope":
            batch["positions"] = _tok((B, S, 3))
    else:
        batch["tokens"] = _tok((B, S))
    return batch


def abstract_params(cfg: ModelConfig, param_dtype: str):
    shapes = jax.eval_shape(
        lambda: MR.init_params(jax.random.PRNGKey(0), cfg))
    if param_dtype == "bfloat16":
        shapes = jax.tree.map(
            lambda s: SDS(s.shape, jnp.bfloat16), shapes)
    return shapes
