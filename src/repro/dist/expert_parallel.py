"""Explicit expert parallelism (shard_map) — §Perf hillclimb target.

The baseline MoE ("gspmd" path, repro.models.moe.apply_moe) expresses the
expert FFN as global einsums and lets GSPMD insert collectives. This module
pins the communication pattern down by hand: the dispatch tensor [E, C, D]
enters a shard_map sharded on experts over the 'model' axis, each shard runs
only its num_experts / model_parallel experts' swiglu locally, and the
token-side gather/scatter around it becomes the all_to_all pair.

Opt-in via REPRO_MOE_EP=1 (see repro.models.transformer._moe_dispatch);
requires an active mesh whose 'model' axis divides num_experts — otherwise
falls back to the GSPMD path so the call is always safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as SH
from repro.models import moe as MOE


def apply_moe_ep(p, x, cfg):
    """x: [B, S, D] -> [B, S, D]; numerically identical to apply_moe."""
    mesh = SH.active_mesh()
    if (mesh is None or "model" not in mesh.axis_names
            or cfg.num_experts % int(mesh.shape["model"])):
        return MOE.apply_moe(p, x, cfg)
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    dt = x.dtype
    disp, info = MOE.route(p, x, cfg)
    disp = SH.constrain(disp, "model", "data", None)
    spec_e = P("model", None, None)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(spec_e, spec_e, spec_e, spec_e),
                       out_specs=spec_e, check_rep=False)
    def expert_ffn(disp_l, w_in_l, w_gate_l, w_out_l):
        h = jnp.einsum("ecd,edf->ecf", disp_l, w_in_l)
        g = jnp.einsum("ecd,edf->ecf", disp_l, w_gate_l)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(disp_l.dtype) * h
        return jnp.einsum("ecf,efd->ecd", h, w_out_l)

    out_e = expert_ffn(disp, p["w_in"].astype(dt), p["w_gate"].astype(dt),
                       p["w_out"].astype(dt))
    out = MOE.combine(out_e, info)
    MOE.router_probes(info, cfg)
    return out.reshape(B, S, D)
