"""Sharding rules for params/optimizer state/batches on the production
meshes (("data", "model") single-pod, ("pod", "data", "model") multi-pod).

Core ideas:
  * `spec_for(path_keys, shape, mesh)` — name-pattern rules (embedding,
    MoE expert weights) with a generic [in, out] -> ("data", "model")
    default; Adafactor factored moments (`vr`/`vc`) inherit the parent
    param's rule with the reduced dim dropped; stacked leading dims are
    replicated (padded with None).
  * `fit_spec` — divisibility fallback: any dim a mesh axis does not evenly
    divide falls back to replicated on that dim (never crash a lowering
    because a head count is odd).
  * `use_mesh` / `active_mesh` / `constrain` — ambient mesh for
    with_sharding_constraint; everything is a no-op without a mesh, so
    single-device tests run the same model code.
"""
from __future__ import annotations

import contextlib
import math
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_tls = threading.local()


# --------------------------------------------------------------------------
# ambient mesh
# --------------------------------------------------------------------------

def active_mesh():
    stack = getattr(_tls, "meshes", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(mesh):
    stack = getattr(_tls, "meshes", None)
    if stack is None:
        stack = _tls.meshes = []
    stack.append(mesh)
    try:
        with mesh:                      # also enter jax's Mesh context
            yield mesh
    finally:
        stack.pop()


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def fsdp_axes(mesh) -> tuple:
    """Axes batches/fsdp shard over: every axis except tensor-parallel
    'model' (so ('data',) or ('pod', 'data'))."""
    return tuple(a for a in mesh.axis_names if a != "model")


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return math.prod(_axis_size(mesh, a) for a in entry)
    return int(mesh.shape[entry])


def fit_spec(spec: P, shape, mesh) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    entries = entries[:len(shape)]
    out = []
    for dim, entry in zip(shape, entries):
        size = _axis_size(mesh, entry)
        out.append(entry if entry is not None and dim % size == 0 else None)
    return P(*out)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# name -> base spec over the param's trailing dims. Entries are mesh axis
# names; the generic fallback is ("data", "model") = [in-features sharded
# over fsdp, out-features over tensor-parallel].
_RULES: dict[str, tuple] = {
    "embedding": ("model", "data"),       # [V, D]: vocab over TP
    "w_in": ("model", "data", None),      # MoE [E, D, F]: experts over TP
    "w_gate": ("model", "data", None),
    "w_out": ("model", None, "data"),     # MoE [E, F, D]
}


def spec_for(keys, shape, mesh) -> P:
    """Sharding spec for a param (or optimizer-moment) tree leaf.

    keys: path of dict keys from the tree root (strings); shape: leaf
    shape. Factored-moment leaves (`vr` drops the last dim, `vc` the
    second-to-last) inherit the parent param's rule minus that dim."""
    keys = [str(k) for k in keys]
    moment = keys[-1] if keys and keys[-1] in ("vr", "vc") else None
    base_keys = keys[:-1] if moment else keys
    name = next((k for k in reversed(base_keys) if k in _RULES), None)
    param_rank = len(shape) + (1 if moment else 0)
    if name is not None:
        base = list(_RULES[name])
    elif param_rank >= 2:
        base = ["data", "model"]
    else:
        base = []
    if moment == "vr" and base:
        base = base[:-1]
    elif moment == "vc" and len(base) >= 2:
        base = base[:-2] + base[-1:]
    if len(base) < len(shape):           # stacked leading dims: replicate
        base = [None] * (len(shape) - len(base)) + base
    elif len(base) > len(shape):
        base = base[-len(shape):]
    return fit_spec(P(*base), shape, mesh)


# --------------------------------------------------------------------------
# batch / cache rules
# --------------------------------------------------------------------------

def _batch_entry(mesh, dim):
    fs = fsdp_axes(mesh)
    if not fs or dim % _axis_size(mesh, fs):
        return None
    return fs if len(fs) > 1 else fs[0]


def batch_spec(mesh, bsz: int, extra_dims: int = 0) -> P:
    """Leading batch dim over the fsdp axes (when divisible), rest
    replicated."""
    return P(_batch_entry(mesh, bsz), *([None] * extra_dims))


def kv_cache_spec(mesh, batch: int, kv_heads: int) -> P:
    """KV cache leaves [n_layers, B, S, KH, hd]: batch over fsdp, heads
    over 'model' when they divide."""
    m = None
    if "model" in mesh.axis_names and kv_heads % int(mesh.shape["model"]) == 0:
        m = "model"
    return P(None, _batch_entry(mesh, batch), None, m, None)


# --------------------------------------------------------------------------
# in-graph constraints
# --------------------------------------------------------------------------

def constrain(x, *axes):
    """with_sharding_constraint under the ambient mesh; identity without
    one. Axis entries: None, a mesh axis name, or the logical name 'batch'
    (resolves to the fsdp axes). Unknown axes and non-dividing dims fall
    back to replicated on that dim."""
    mesh = active_mesh()
    if mesh is None:
        return x
    entries = []
    for a in axes:
        if a == "batch":
            fs = fsdp_axes(mesh)
            entries.append(fs if len(fs) > 1 else (fs[0] if fs else None))
        elif a is None or a in mesh.axis_names:
            entries.append(a)
        else:
            entries.append(None)
    spec = fit_spec(P(*entries), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
