"""Distribution layer: sharding rules, gradient compression, explicit
expert parallelism. Kept dependency-light — model code imports from here
at module import time."""
