"""Gradient compression: per-leaf symmetric int8 quantization.

`int8_roundtrip` is the wire format simulated in-graph (quantize ->
dequantize); training uses it when tcfg.grad_compression == "int8" to model
8-bit gradient all-reduce. `compression_error` reports the relative L2
error of the roundtrip (monitoring / tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _roundtrip_leaf(g):
    if not jnp.issubdtype(g.dtype, jnp.floating):
        return g
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def int8_roundtrip(tree):
    """Quantize every floating leaf to int8 (per-leaf absmax scale) and
    dequantize back — the gradient-compression wire format."""
    return jax.tree.map(_roundtrip_leaf, tree)


def compression_error(tree) -> jnp.ndarray:
    """Relative global-L2 error of the int8 roundtrip."""
    rt = int8_roundtrip(tree)
    sq_err = sum(jnp.sum((jnp.asarray(a, jnp.float32)
                          - jnp.asarray(b, jnp.float32)) ** 2)
                 for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(rt)))
    sq_ref = sum(jnp.sum(jnp.asarray(a, jnp.float32) ** 2)
                 for a in jax.tree.leaves(tree))
    return jnp.sqrt(sq_err) / jnp.maximum(jnp.sqrt(sq_ref), 1e-30)
